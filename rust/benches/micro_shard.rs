//! Micro-bench: the sharded executor (`--shards N`) against the serial
//! event loop, on the workloads the tentpole targets — a paper-style
//! recovery trial at 4096 ranks (events/s + peak live-task state per
//! rank) and a raw cross-shard channel storm that exercises the
//! window-synchronization machinery (windows advanced, staged vs bypass
//! inbox traffic).
//!
//! Sharding is a host knob: every configuration below produces byte-
//! identical trial results (pinned by `tests/shard_determinism.rs`), so
//! the only thing measured here is host throughput and memory.
//!
//! Emits `BENCH_micro_shard.json` at the repository root so CI and later
//! PRs can track the perf trajectory.

use std::cell::RefCell;
use std::rc::Rc;
use std::time::Instant;

use reinitpp::config::{AppKind, ExperimentConfig, FailureKind, Fidelity, RecoveryKind};
use reinitpp::metrics::{BenchReport, BenchRow};
use reinitpp::recovery::job::run_trial_opts;
use reinitpp::sim::{channel, Sender, Sim, SimDuration, SimSummary};

/// Estimated per-rank live-task state of the seed executor (pre-SoA): the
/// integrity-agreement and restore state machines inlined into every rank
/// future plus the AoS task record. Like the seed rates in
/// `micro_sim_engine`, a reference figure for ratio tracking on one
/// machine, not an absolute.
const SEED_STATE_BYTES_PER_RANK: f64 = 5.4e3;

/// The trial the shard comparison runs: a 4096-rank modeled Reinit++
/// point with a single process failure — the smallest rung the issue's
/// acceptance criteria speak about.
fn trial_cfg() -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.app = AppKind::Hpccg;
    c.recovery = RecoveryKind::Reinit;
    c.failure = FailureKind::Process;
    c.ranks = 4096;
    c.iters = 6;
    c.trials = 1;
    c.fidelity = Fidelity::Modeled;
    c.hpccg_nx = 4;
    c
}

/// (host seconds, DES events, peak live-task state bytes)
fn bench_trial(shards: usize) -> (f64, u64, u64) {
    let cfg = trial_cfg();
    let t0 = Instant::now();
    let r = run_trial_opts(&cfg, 0, None, None, shards);
    assert!(r.completed, "bench trial must complete");
    (
        t0.elapsed().as_secs_f64(),
        r.counters.events,
        r.counters.peak_rank_state_bytes,
    )
}

/// Raw window-sync storm: `pairs` sender/receiver process pairs pinned to
/// *different* shards, every message crossing a shard boundary at exactly
/// the lookahead latency (so it rides the inbox/window-barrier path), with
/// the senders pacing virtual time forward between messages.
fn bench_window_storm(shards: usize, pairs: u64, msgs: u64) -> (f64, u64, SimSummary) {
    let sim = Sim::new();
    sim.set_shards(shards);
    let lookahead = SimDuration::from_micros(2);
    if shards > 1 {
        sim.set_lookahead(lookahead);
    }
    // Receivers first: each creates its channel inside its own task poll so
    // the channel's home shard is the receiver's shard, and parks the
    // sender half in the registry for the sender tasks (global (time, seq)
    // order guarantees every receiver polls before any sender).
    let registry: Rc<RefCell<Vec<Option<Sender<u64>>>>> = Rc::new(RefCell::new(Vec::new()));
    for i in 0..pairs {
        let p = sim.spawn_process(format!("rx{i}"));
        sim.assign_proc_shard(p, (i % shards as u64) as u16);
        let s2 = sim.clone();
        let reg = Rc::clone(&registry);
        sim.spawn(p, async move {
            let (tx, rx) = channel::<u64>(&s2);
            reg.borrow_mut().push(Some(tx));
            for _ in 0..msgs {
                let _ = rx.recv().await;
            }
        });
    }
    for i in 0..pairs {
        let p = sim.spawn_process(format!("tx{i}"));
        // one shard over from the paired receiver: every send is remote
        sim.assign_proc_shard(p, ((i + 1) % shards as u64) as u16);
        let s2 = sim.clone();
        let reg = Rc::clone(&registry);
        sim.spawn(p, async move {
            let tx = reg.borrow_mut()[i as usize].take().expect("receiver ran first");
            for k in 0..msgs {
                tx.send(k, lookahead);
                s2.sleep(SimDuration::from_micros(3)).await;
            }
        });
    }
    let t0 = Instant::now();
    let summary = sim.run();
    (t0.elapsed().as_secs_f64(), pairs * msgs, summary)
}

fn main() {
    let mut report = BenchReport::new("micro_shard");
    println!("| micro-bench | work | host time (s) | rate | notes |");
    println!("|---|---|---|---|---|");

    let ranks = trial_cfg().ranks;
    let (dt1, events1, peak1) = bench_trial(1);
    let bpr1 = peak1 as f64 / ranks as f64;
    println!(
        "| trial serial | {events1} events | {dt1:.3} | {:.2} M ev/s | {bpr1:.0} B/rank |",
        events1 as f64 / dt1 / 1e6
    );
    report.push(
        BenchRow::new("trial_4096_serial", events1, dt1, "events/s")
            .with_extra("ranks", ranks as f64)
            .with_extra("bytes_per_rank", bpr1)
            .with_extra(
                "seed_bytes_per_rank_ratio",
                SEED_STATE_BYTES_PER_RANK / bpr1,
            ),
    );

    let (dt4, events4, peak4) = bench_trial(4);
    assert_eq!(events1, events4, "sharding must not change the event count");
    assert_eq!(peak1, peak4, "sharding must not change the state footprint");
    println!(
        "| trial 4 shards | {events4} events | {dt4:.3} | {:.2} M ev/s | {:.2}x serial |",
        events4 as f64 / dt4 / 1e6,
        dt1 / dt4
    );
    report.push(
        BenchRow::new("trial_4096_shard4", events4, dt4, "events/s")
            .with_extra("ranks", ranks as f64)
            .with_extra("shards", 4.0)
            .with_extra("bytes_per_rank", peak4 as f64 / ranks as f64)
            .with_extra("speedup_vs_serial", dt1 / dt4),
    );

    let (dts, sends, summary) = bench_window_storm(4, 512, 200);
    let st = summary.shards;
    let staged_frac =
        st.inbox_staged as f64 / (st.inbox_staged + st.inbox_bypass).max(1) as f64;
    println!(
        "| window storm (4 shards) | {sends} sends | {dts:.3} | {:.2} M ev/s | \
         {} windows, {:.0}% staged |",
        summary.events as f64 / dts / 1e6,
        st.windows,
        staged_frac * 100.0
    );
    report.push(
        BenchRow::new("window_storm_shard4", summary.events, dts, "events/s")
            .with_extra("cross_shard_sends", sends as f64)
            .with_extra("windows", st.windows as f64)
            .with_extra(
                "events_per_window",
                summary.events as f64 / st.windows.max(1) as f64,
            )
            .with_extra("inbox_staged", st.inbox_staged as f64)
            .with_extra("inbox_bypass", st.inbox_bypass as f64)
            .with_extra("staged_fraction", staged_frac),
    );

    report.write_json(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../BENCH_micro_shard.json"
    ));
}
