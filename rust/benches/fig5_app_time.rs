//! Bench: regenerate Figure 5 (pure application time weak scaling; shows
//! the ULFM fault-free inflation) on the modeled backend.

use reinitpp::config::{ExperimentConfig, Fidelity};
use reinitpp::harness::{default_jobs, fig5, SweepOpts};

fn main() {
    let t0 = std::time::Instant::now();
    let mut base = ExperimentConfig::default();
    base.trials = 5;
    base.iters = 10;
    base.fidelity = Fidelity::Modeled;
    // small per-rank domains keep 1024-rank modeled sweeps tractable;
    // the figure *shapes* come from the protocols, not the compute size
    base.hpccg_nx = 8;
    base.comd_n = 32;
    base.lulesh_nx = 8;
    let opts = SweepOpts {
        max_ranks: 1024,
        outdir: "results/bench".into(),
        jobs: default_jobs(),
        profile: false,
    };
    let points = fig5(&base, &opts);
    eprintln!(
        "\nfig5: {} points, host wall {:.1} s",
        points.len(),
        t0.elapsed().as_secs_f64()
    );
}
