//! Bench: regenerate Figure 4 (total execution time breakdown under a
//! process failure) on the modeled backend. `cargo bench --bench
//! fig4_total_time`. For the full-fidelity version use
//! `reinitpp reproduce --figure 4`.

use reinitpp::config::{ExperimentConfig, Fidelity};
use reinitpp::harness::{default_jobs, fig4, SweepOpts};

fn main() {
    let t0 = std::time::Instant::now();
    let mut base = ExperimentConfig::default();
    base.trials = 5;
    base.iters = 10;
    base.fidelity = Fidelity::Modeled;
    // small per-rank domains keep 1024-rank modeled sweeps tractable;
    // the figure *shapes* come from the protocols, not the compute size
    base.hpccg_nx = 8;
    base.comd_n = 32;
    base.lulesh_nx = 8;
    let opts = SweepOpts {
        max_ranks: 1024,
        outdir: "results/bench".into(),
        jobs: default_jobs(),
        profile: false,
    };
    let points = fig4(&base, &opts);
    eprintln!(
        "\nfig4: {} points, {} trials each, host wall {:.1} s",
        points.len(),
        base.trials,
        t0.elapsed().as_secs_f64()
    );
}
