//! Micro-bench: the MPI layer's collectives — virtual-time latency (the
//! quantity the figures depend on) and host-side simulation cost per
//! collective across the paper's rank counts.

use std::cell::RefCell;
use std::rc::Rc;
use std::time::Instant;

use reinitpp::cluster::Topology;
use reinitpp::config::Calibration;
use reinitpp::mpi::{FtMode, MpiJob, ReduceOp};
use reinitpp::sim::Sim;

fn bench_allreduce(ranks: u32, reps: u32) -> (f64, f64, u64) {
    let sim = Sim::new();
    let topo = Topology::new(ranks, 16, 0);
    let job = MpiJob::new(&sim, topo, FtMode::Reinit, &Calibration::default());
    let done_at = Rc::new(RefCell::new(0.0f64));
    for r in 0..ranks {
        let j2 = job.clone();
        let d2 = Rc::clone(&done_at);
        let node = topo.home_node(r);
        let p = sim.spawn_process(format!("r{r}"));
        let sim2 = sim.clone();
        sim.spawn(p, async move {
            let c = j2.attach(r, node);
            for _ in 0..reps {
                c.allreduce_scalar(1.0, ReduceOp::Sum).await.unwrap();
            }
            if r == 0 {
                *d2.borrow_mut() = sim2.now().secs_f64();
            }
        });
    }
    let t0 = Instant::now();
    let s = sim.run();
    let host = t0.elapsed().as_secs_f64();
    let virt_per_op = *done_at.borrow() / reps as f64;
    (virt_per_op * 1e6, host / reps as f64 * 1e3, s.events)
}

fn main() {
    let mut report = reinitpp::metrics::BenchReport::new("micro_collectives");
    println!("| ranks | allreduce virtual latency (µs) | host cost/op (ms) | total events |");
    println!("|---|---|---|---|");
    for ranks in [16u32, 64, 256, 1024] {
        let reps = 20;
        let (virt_us, host_ms, events) = bench_allreduce(ranks, reps);
        println!("| {ranks} | {virt_us:.1} | {host_ms:.2} | {events} |");
        // rate = simulator events processed per host second
        let host_s = host_ms * 1e-3 * reps as f64;
        report.push(
            reinitpp::metrics::BenchRow::new(
                &format!("allreduce_{ranks}ranks"),
                events,
                host_s,
                "events/s",
            )
            .with_extra("virtual_latency_us", virt_us)
            .with_extra("host_ms_per_op", host_ms),
        );
    }
    println!("\n(virtual latency should grow ~log2(ranks): tree allreduce)");
    report.write_json(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../BENCH_micro_collectives.json"
    ));
}
