//! Micro-bench: raw throughput of the virtual-time DES executor — the L3
//! hot path every experiment rides on. Reports host events/second for
//! timer storms, task churn, and channel messaging.

use std::time::Instant;

use reinitpp::sim::{channel, Sim, SimDuration};

fn bench_timer_storm(tasks: u64, sleeps: u64) -> (f64, u64) {
    let sim = Sim::new();
    let p = sim.spawn_process("bench");
    for i in 0..tasks {
        let s2 = sim.clone();
        sim.spawn(p, async move {
            for k in 0..sleeps {
                s2.sleep(SimDuration::from_nanos(1 + (i * 7 + k) % 97)).await;
            }
        });
    }
    let t0 = Instant::now();
    let summary = sim.run();
    (t0.elapsed().as_secs_f64(), summary.events + summary.polls)
}

fn bench_channel_pingpong(pairs: u64, msgs: u64) -> (f64, u64) {
    let sim = Sim::new();
    let mut count = 0u64;
    for i in 0..pairs {
        let p = sim.spawn_process(format!("p{i}"));
        let (tx_a, rx_a) = channel::<u64>(&sim);
        let (tx_b, rx_b) = channel::<u64>(&sim);
        sim.spawn(p, async move {
            for k in 0..msgs {
                tx_a.send(k, SimDuration::from_nanos(100));
                let _ = rx_b.recv().await;
            }
        });
        sim.spawn(p, async move {
            for _ in 0..msgs {
                let v = rx_a.recv().await.unwrap();
                tx_b.send(v, SimDuration::from_nanos(100));
            }
        });
        count += msgs * 2;
    }
    let t0 = Instant::now();
    sim.run();
    (t0.elapsed().as_secs_f64(), count)
}

fn bench_process_churn(n: u64) -> (f64, u64) {
    let sim = Sim::new();
    for i in 0..n {
        let p = sim.spawn_process(format!("c{i}"));
        let s2 = sim.clone();
        sim.spawn(p, async move {
            s2.sleep(SimDuration::from_micros(1)).await;
        });
        let s3 = sim.clone();
        sim.schedule(SimDuration::from_nanos(500), move || s3.kill(p));
    }
    let t0 = Instant::now();
    let summary = sim.run();
    (t0.elapsed().as_secs_f64(), summary.events)
}

fn main() {
    println!("| micro-bench | work | host time (s) | rate |");
    println!("|---|---|---|---|");

    let (dt, events) = bench_timer_storm(1_000, 200);
    println!(
        "| timer storm | {events} events+polls | {dt:.3} | {:.2} M/s |",
        events as f64 / dt / 1e6
    );

    let (dt, msgs) = bench_channel_pingpong(500, 200);
    println!(
        "| channel ping-pong | {msgs} msgs | {dt:.3} | {:.2} M msg/s |",
        msgs as f64 / dt / 1e6
    );

    let (dt, _events) = bench_process_churn(20_000);
    println!(
        "| process spawn+kill | 20000 procs | {dt:.3} | {:.0} k proc/s |",
        20_000.0 / dt / 1e3
    );
}
