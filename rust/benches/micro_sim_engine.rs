//! Micro-bench: raw throughput of the virtual-time DES executor — the L3
//! hot path every experiment rides on. Reports host events/second for
//! timer storms, task churn, and channel messaging, plus heap allocations
//! observed during each run (the engine hot path is allocation-lean: slab
//! tasks, cached wakers, swap-drained wake ring — see EXPERIMENTS.md §Perf).
//!
//! Emits `BENCH_micro_sim_engine.json` at the repository root so CI and
//! later PRs can track the perf trajectory.

use std::time::Instant;

use reinitpp::metrics::{BenchReport, BenchRow};
use reinitpp::sim::{channel, Sim, SimDuration};

// Counts every heap allocation so the report can include an "allocations
// per unit of work" figure (the measurable part of the zero-alloc claims).
#[path = "support/counting_alloc.rs"]
mod counting_alloc;
use counting_alloc::alloc_count;

/// Seed-engine reference rates for the same workloads (the pre-rewrite
/// HashMap + per-poll-Arc + mutexed-wake-queue executor), used to report the
/// speedup trajectory in the JSON. ESTIMATED from the seed engine's
/// per-event operation costs, pending recalibration with a real seed-engine
/// run on the CI reference machine — compare ratios across runs of the SAME
/// machine, not absolutes.
const SEED_TIMER_STORM_RATE: f64 = 5.1e6; // events+polls/s
const SEED_PINGPONG_RATE: f64 = 2.05e6; // msgs/s
const SEED_CHURN_RATE: f64 = 2.84e4; // procs/s (kill scanned all live tasks)

/// (host seconds, work units, allocations during the run)
fn bench_timer_storm(tasks: u64, sleeps: u64) -> (f64, u64, u64) {
    let sim = Sim::new();
    let p = sim.spawn_process("bench");
    for i in 0..tasks {
        let s2 = sim.clone();
        sim.spawn(p, async move {
            for k in 0..sleeps {
                s2.sleep(SimDuration::from_nanos(1 + (i * 7 + k) % 97)).await;
            }
        });
    }
    let a0 = alloc_count();
    let t0 = Instant::now();
    let summary = sim.run();
    (
        t0.elapsed().as_secs_f64(),
        summary.events + summary.polls,
        alloc_count() - a0,
    )
}

fn bench_channel_pingpong(pairs: u64, msgs: u64) -> (f64, u64, u64) {
    let sim = Sim::new();
    let mut count = 0u64;
    for i in 0..pairs {
        let p = sim.spawn_process(format!("p{i}"));
        let (tx_a, rx_a) = channel::<u64>(&sim);
        let (tx_b, rx_b) = channel::<u64>(&sim);
        sim.spawn(p, async move {
            for k in 0..msgs {
                tx_a.send(k, SimDuration::from_nanos(100));
                let _ = rx_b.recv().await;
            }
        });
        sim.spawn(p, async move {
            for _ in 0..msgs {
                let v = rx_a.recv().await.unwrap();
                tx_b.send(v, SimDuration::from_nanos(100));
            }
        });
        count += msgs * 2;
    }
    let a0 = alloc_count();
    let t0 = Instant::now();
    sim.run();
    (t0.elapsed().as_secs_f64(), count, alloc_count() - a0)
}

fn bench_process_churn(n: u64) -> (f64, u64, u64) {
    let sim = Sim::new();
    for i in 0..n {
        let p = sim.spawn_process(format!("c{i}"));
        let s2 = sim.clone();
        sim.spawn(p, async move {
            s2.sleep(SimDuration::from_micros(1)).await;
        });
        let s3 = sim.clone();
        sim.schedule(SimDuration::from_nanos(500), move || s3.kill(p));
    }
    let a0 = alloc_count();
    let t0 = Instant::now();
    let summary = sim.run();
    (
        t0.elapsed().as_secs_f64(),
        summary.events,
        alloc_count() - a0,
    )
}

fn main() {
    let mut report = BenchReport::new("micro_sim_engine");
    println!("| micro-bench | work | host time (s) | rate | allocs |");
    println!("|---|---|---|---|---|");

    let (dt, events, allocs) = bench_timer_storm(1_000, 200);
    println!(
        "| timer storm | {events} events+polls | {dt:.3} | {:.2} M/s | {allocs} |",
        events as f64 / dt / 1e6
    );
    report.push(
        BenchRow::new("timer_storm", events, dt, "events+polls/s")
            .with_extra("allocations", allocs as f64)
            .with_extra("baseline_rate_per_sec", SEED_TIMER_STORM_RATE)
            .with_extra("speedup_vs_seed", events as f64 / dt / SEED_TIMER_STORM_RATE),
    );

    let (dt, msgs, allocs) = bench_channel_pingpong(500, 200);
    println!(
        "| channel ping-pong | {msgs} msgs | {dt:.3} | {:.2} M msg/s | {allocs} |",
        msgs as f64 / dt / 1e6
    );
    report.push(
        BenchRow::new("channel_pingpong", msgs, dt, "msgs/s")
            .with_extra("allocations", allocs as f64)
            .with_extra("baseline_rate_per_sec", SEED_PINGPONG_RATE)
            .with_extra("speedup_vs_seed", msgs as f64 / dt / SEED_PINGPONG_RATE),
    );

    let (dt, _events, allocs) = bench_process_churn(20_000);
    println!(
        "| process spawn+kill | 20000 procs | {dt:.3} | {:.0} k proc/s | {allocs} |",
        20_000.0 / dt / 1e3
    );
    report.push(
        BenchRow::new("process_churn", 20_000, dt, "procs/s")
            .with_extra("allocations", allocs as f64)
            .with_extra("baseline_rate_per_sec", SEED_CHURN_RATE)
            .with_extra("speedup_vs_seed", 20_000.0 / dt / SEED_CHURN_RATE),
    );

    report.write_json(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../BENCH_micro_sim_engine.json"
    ));
}
