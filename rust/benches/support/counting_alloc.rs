//! Shared counting global allocator for the perf pins: every heap
//! allocation bumps a counter so benches/tests can report (and assert)
//! allocations per unit of work. Included via `#[path]` from the bench
//! and test binaries that need it — keeping the counting strategy in one
//! place so the bench numbers and the pinning tests cannot diverge.
//! Registering the `#[global_allocator]` happens here too, so including
//! this module is all a binary needs.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

pub struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Total heap allocations observed so far (monotonic; diff around the
/// measured region).
pub fn alloc_count() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}
