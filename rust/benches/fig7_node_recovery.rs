//! Bench: regenerate Figure 7 (MPI recovery time, node failure; CR vs
//! Reinit++, file checkpointing) on the modeled backend.

use reinitpp::config::{AppKind, ExperimentConfig, Fidelity, RecoveryKind};
use reinitpp::harness::{default_jobs, fig7, SweepOpts};

fn main() {
    let t0 = std::time::Instant::now();
    let mut base = ExperimentConfig::default();
    base.trials = 5;
    base.iters = 10;
    base.fidelity = Fidelity::Modeled;
    // small per-rank domains keep 1024-rank modeled sweeps tractable;
    // the figure *shapes* come from the protocols, not the compute size
    base.hpccg_nx = 8;
    base.comd_n = 32;
    base.lulesh_nx = 8;
    base.spare_nodes = 1;
    let opts = SweepOpts {
        max_ranks: 1024,
        outdir: "results/bench".into(),
        jobs: default_jobs(),
        profile: false,
    };
    let points = fig7(&base, &opts);

    let mean = |rk: RecoveryKind, ranks: u32| {
        points
            .iter()
            .find(|p| {
                p.cfg.recovery == rk && p.cfg.ranks == ranks && p.cfg.app == AppKind::Hpccg
            })
            .map(|p| p.recovery.mean)
            .unwrap_or(f64::NAN)
    };
    eprintln!(
        "\nCR/Reinit++ node-failure recovery at 1024 ranks: {:.1}x (paper: ~2x)",
        mean(RecoveryKind::Cr, 1024) / mean(RecoveryKind::Reinit, 1024)
    );
    eprintln!(
        "fig7: {} points, host wall {:.1} s",
        points.len(),
        t0.elapsed().as_secs_f64()
    );
}
