//! Micro-bench: the paper's two checkpoint schemes — the Fig. 4 mechanism
//! in isolation. Virtual write cost per scheme as writer count scales
//! (Lustre contention vs local+partner memory), plus host-side simulation
//! cost. See `micro_ckpt_tiers` for the full tier-stack comparison.

use std::cell::RefCell;
use std::rc::Rc;
use std::time::Instant;

use reinitpp::checkpoint::CkptStore;
use reinitpp::cluster::Topology;
use reinitpp::config::{Calibration, CkptKind};
use reinitpp::sim::Sim;

fn bench(scheme: CkptKind, ranks: u32, bytes: usize) -> (f64, f64) {
    let sim = Sim::new();
    let topo = Topology::new(ranks, 16, 0);
    let store = CkptStore::from_kind(&sim, scheme, topo, &Calibration::default());
    let worst = Rc::new(RefCell::new(0.0f64));
    for r in 0..ranks {
        let s2 = store.clone();
        let sim2 = sim.clone();
        let w2 = Rc::clone(&worst);
        let node = topo.home_node(r);
        let p = sim.spawn_process(format!("r{r}"));
        sim.spawn(p, async move {
            let t0 = sim2.now();
            s2.save(r, node, 0, vec![0u8; bytes]).await;
            let dt = (sim2.now() - t0).secs_f64();
            let mut w = w2.borrow_mut();
            if dt > *w {
                *w = dt;
            }
        });
    }
    let t0 = Instant::now();
    sim.run();
    let w = *worst.borrow();
    (w, t0.elapsed().as_secs_f64())
}

fn main() {
    let bytes = 400 * 1024; // ~HPCCG 32^3 x 3 vectors
    let mut report = reinitpp::metrics::BenchReport::new("micro_checkpoint");
    println!("| scheme | ranks | worst virtual write (ms) | host (ms) |");
    println!("|---|---|---|---|");
    for scheme in [CkptKind::Memory, CkptKind::File] {
        for ranks in [16u32, 64, 256, 1024] {
            let (virt, host) = bench(scheme, ranks, bytes);
            println!(
                "| {scheme} | {ranks} | {:.2} | {:.1} |",
                virt * 1e3,
                host * 1e3
            );
            report.push(
                reinitpp::metrics::BenchRow::new(
                    &format!("save_{scheme}_{ranks}ranks"),
                    ranks as u64,
                    host,
                    "rank-saves/s",
                )
                .with_extra("worst_virtual_write_ms", virt * 1e3),
            );
        }
    }
    println!("\n(file scales ~linearly with ranks once aggregate-BW bound;");
    println!(" memory stays flat — the paper's Fig. 4 CR-vs-rest gap)");
    report.write_json(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../BENCH_micro_checkpoint.json"
    ));
}
