//! Micro-bench: the large-rank fast path. One simulated iteration =
//! tree allreduce + 6-face halo exchange + ring heartbeat (a timed recv
//! that completes early — the ULFM liveness pattern), at 1k/4k/16k ranks.
//!
//! Reports host msgs/s, steady-state heap allocations per delivered
//! message (counting global allocator; warm-up subtracted by differencing
//! a 1-iteration run against a longer one), and peak in-flight events.
//! The O(1) fabric routing table, the direct-match receive path and the
//! allocation-lean collectives are what keep these flat as ranks grow.
//!
//! Emits `BENCH_micro_scale.json` at the repository root.

use std::rc::Rc;
use std::time::Instant;

use reinitpp::apps::halo::{grid3, neighbor};
use reinitpp::cluster::Topology;
use reinitpp::config::Calibration;
use reinitpp::metrics::{BenchReport, BenchRow};
use reinitpp::mpi::{FtMode, MpiJob, Payload, RecvSrc, ReduceOp};
use reinitpp::sim::{ProcName, Sim, SimDuration};

#[path = "support/counting_alloc.rs"]
mod counting_alloc;
use counting_alloc::alloc_count;

/// Tag blocks (user tag space, below the collective/control blocks).
const HALO_BASE: u64 = 1 << 32;
const HB_BASE: u64 = 1 << 33;

/// Run `iters` allreduce+halo+heartbeat iterations at `ranks` ranks.
/// Returns (host seconds, fabric messages, allocations, peak inflight).
fn run_scale(ranks: u32, iters: u32) -> (f64, u64, u64, u64) {
    let sim = Sim::new();
    let topo = Topology::new(ranks, 16, 0);
    let job = MpiJob::new(&sim, topo, FtMode::Reinit, &Calibration::default());
    let dims = grid3(ranks);
    // One shared face payload (1 KB) and heartbeat payload: the data plane
    // forwards them by Rc clone, so steady-state sends allocate nothing.
    let face: Payload = Rc::from(vec![0u8; 1024]);
    let hb: Payload = Rc::from(vec![1u8; 8]);
    let prefix: Rc<str> = Rc::from("r");
    for r in 0..ranks {
        let j2 = job.clone();
        let node = topo.home_node(r);
        let p = sim.spawn_process(ProcName::Indexed {
            prefix: Rc::clone(&prefix),
            index: r,
            sub: None,
        });
        let face2 = Rc::clone(&face);
        let hb2 = Rc::clone(&hb);
        sim.spawn(p, async move {
            let c = j2.attach(r, node);
            let next = (r + 1) % ranks;
            let prev = (r + ranks - 1) % ranks;
            for iter in 0..iters as u64 {
                // 6-face halo exchange: post sends, then receive the
                // opposite-direction face from each neighbour.
                let tag = HALO_BASE + iter * 8;
                for f in 0..6 {
                    if let Some(to) = neighbor(r, dims, f) {
                        c.send_payload(to, tag + f as u64, Rc::clone(&face2));
                    }
                }
                for f in 0..6usize {
                    if let Some(from) = neighbor(r, dims, f) {
                        let m = c
                            .recv(RecvSrc::From(from), tag + (f ^ 1) as u64)
                            .await
                            .unwrap();
                        assert_eq!(m.data.len(), 1024);
                    }
                }
                // ring heartbeat (a liveness probe, hence the unchecked
                // timed recv): completes early, leaving only a stale
                // (cancel-aware, allocation-free) timer.
                c.send_payload(next, HB_BASE + iter, Rc::clone(&hb2));
                let m = c
                    .recv_unchecked_timeout(
                        RecvSrc::From(prev),
                        HB_BASE + iter,
                        SimDuration::from_millis(1),
                    )
                    .await;
                assert!(m.is_some(), "heartbeat must beat its deadline");
                // tree allreduce closes the iteration (BSP barrier).
                c.allreduce_scalar(1.0, ReduceOp::Sum).await.unwrap();
            }
        });
    }
    let a0 = alloc_count();
    let t0 = Instant::now();
    let summary = sim.run();
    let host = t0.elapsed().as_secs_f64();
    assert_eq!(summary.tasks_pending, 0, "iteration deadlocked");
    let (msgs, _bytes) = job.fabric_stats();
    (host, msgs, alloc_count() - a0, summary.peak_events_pending)
}

fn main() {
    let mut report = BenchReport::new("micro_scale");
    println!("| ranks | msgs | host (s) | M msg/s | steady allocs/msg | peak inflight |");
    println!("|---|---|---|---|---|---|");
    for ranks in [1024u32, 4096, 16384] {
        // Difference a 1-iteration run against a 4-iteration run on fresh
        // worlds: setup + warm-up (slab growth, scratch capacity) cancels,
        // leaving the steady-state per-message cost.
        let (_, m1, a1, _) = run_scale(ranks, 1);
        let (host, m4, a4, peak) = run_scale(ranks, 4);
        let steady_msgs = m4 - m1;
        let steady_allocs = a4.saturating_sub(a1);
        let allocs_per_msg = steady_allocs as f64 / steady_msgs as f64;
        let rate = m4 as f64 / host;
        println!(
            "| {ranks} | {m4} | {host:.3} | {:.2} | {allocs_per_msg:.3} | {peak} |",
            rate / 1e6
        );
        assert!(
            allocs_per_msg <= 2.0,
            "steady-state allocations per message regressed at {ranks} ranks: \
             {allocs_per_msg:.3} > 2 ({steady_allocs} allocs / {steady_msgs} msgs)"
        );
        report.push(
            BenchRow::new(&format!("scale_{ranks}ranks"), m4, host, "msgs/s")
                .with_extra("ranks", ranks as f64)
                .with_extra("steady_allocs_per_msg", allocs_per_msg)
                .with_extra("peak_inflight", peak as f64),
        );
    }
    println!("\n(acceptance: <= 2 steady-state allocations per message at every scale,");
    println!(" including the 16k-rank allreduce+halo+heartbeat iteration)");
    report.write_json(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../BENCH_micro_scale.json"
    ));
}
