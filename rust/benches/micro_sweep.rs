//! Micro-bench: parallel sweep scheduler throughput — the same figure-style
//! grid run serially (`jobs = 1`) and on all cores, reporting trials/second,
//! worker utilization and the wall-clock speedup, plus a determinism
//! cross-check (parallel summaries must be bit-identical to serial).
//!
//! Emits `BENCH_micro_sweep.json` at the repository root so CI and later
//! PRs can track the scheduler's scaling trajectory.

use reinitpp::config::{
    AppKind, ExperimentConfig, FailureKind, Fidelity, RecoveryKind,
};
use reinitpp::harness::{default_jobs, run_points};
use reinitpp::metrics::{BenchReport, BenchRow};

/// A compact Figure-6-like grid: enough independent trials to saturate a
/// small machine, small enough to stay a smoke test in CI.
fn grid() -> Vec<ExperimentConfig> {
    let mut cfgs = Vec::new();
    for ranks in [16u32, 32] {
        for rk in [RecoveryKind::Cr, RecoveryKind::Ulfm, RecoveryKind::Reinit] {
            let mut c = ExperimentConfig::default();
            c.app = AppKind::Hpccg;
            c.recovery = rk;
            c.failure = FailureKind::Process;
            c.ranks = ranks;
            c.iters = 10;
            c.trials = 8;
            c.fidelity = Fidelity::Modeled;
            c.hpccg_nx = 8;
            cfgs.push(c);
        }
    }
    cfgs
}

fn main() {
    let cfgs = grid();
    let trials: u64 = cfgs.iter().map(|c| c.trials as u64).sum();

    let (p_serial, s_serial) = run_points(&cfgs, 1);
    let (p_par, s_par) = run_points(&cfgs, default_jobs());
    // Report the clamped worker count actually used (the utilization
    // denominator), not the requested one.
    let jobs = s_par.jobs;

    let identical = p_serial.iter().zip(&p_par).all(|(a, b)| {
        a.total == b.total
            && a.ckpt_write == b.ckpt_write
            && a.ckpt_read == b.ckpt_read
            && a.recovery == b.recovery
            && a.app == b.app
    });
    assert!(identical, "parallel sweep must be bit-identical to serial");

    let speedup = if s_par.wall_s > 0.0 {
        s_serial.wall_s / s_par.wall_s
    } else {
        0.0
    };
    println!("| sweep | trials | jobs | wall (s) | trials/s | utilization |");
    println!("|---|---|---|---|---|---|");
    println!(
        "| serial | {trials} | 1 | {:.3} | {:.1} | {:.0}% |",
        s_serial.wall_s,
        s_serial.trials_per_sec(),
        s_serial.utilization() * 100.0
    );
    println!(
        "| parallel | {trials} | {jobs} | {:.3} | {:.1} | {:.0}% |",
        s_par.wall_s,
        s_par.trials_per_sec(),
        s_par.utilization() * 100.0
    );
    println!(
        "\nspeedup: {speedup:.2}x on {jobs} worker(s); outputs identical: {identical}"
    );

    let mut report = BenchReport::new("micro_sweep");
    report.push(
        BenchRow::new("sweep_serial", trials, s_serial.wall_s, "trials/s")
            .with_extra("jobs", 1.0)
            .with_extra("utilization", s_serial.utilization()),
    );
    report.push(
        BenchRow::new("sweep_parallel", trials, s_par.wall_s, "trials/s")
            .with_extra("jobs", jobs as f64)
            .with_extra("utilization", s_par.utilization())
            .with_extra("speedup_vs_serial", speedup)
            .with_extra("outputs_identical", if identical { 1.0 } else { 0.0 }),
    );
    report.write_json(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../BENCH_micro_sweep.json"
    ));
}
