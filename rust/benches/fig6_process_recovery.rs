//! Bench: regenerate Figure 6 (MPI recovery time, process failure) on the
//! modeled backend, and verify the paper's headline ratios.

use reinitpp::config::{AppKind, ExperimentConfig, Fidelity, RecoveryKind};
use reinitpp::harness::{default_jobs, fig6, SweepOpts};

fn main() {
    let t0 = std::time::Instant::now();
    let mut base = ExperimentConfig::default();
    base.trials = 5;
    base.iters = 10;
    base.fidelity = Fidelity::Modeled;
    // small per-rank domains keep 1024-rank modeled sweeps tractable;
    // the figure *shapes* come from the protocols, not the compute size
    base.hpccg_nx = 8;
    base.comd_n = 32;
    base.lulesh_nx = 8;
    let opts = SweepOpts {
        max_ranks: 1024,
        outdir: "results/bench".into(),
        jobs: default_jobs(),
        profile: false,
    };
    let points = fig6(&base, &opts);

    let mean = |rk: RecoveryKind, ranks: u32| {
        points
            .iter()
            .find(|p| {
                p.cfg.recovery == rk && p.cfg.ranks == ranks && p.cfg.app == AppKind::Hpccg
            })
            .map(|p| p.recovery.mean)
            .unwrap_or(f64::NAN)
    };
    eprintln!("\npaper headline checks (HPCCG):");
    eprintln!(
        "  CR/Reinit++ at 1024 ranks: {:.1}x (paper: up to 6x)",
        mean(RecoveryKind::Cr, 1024) / mean(RecoveryKind::Reinit, 1024)
    );
    eprintln!(
        "  ULFM/Reinit++ at 1024 ranks: {:.1}x (paper: up to 3x)",
        mean(RecoveryKind::Ulfm, 1024) / mean(RecoveryKind::Reinit, 1024)
    );
    eprintln!(
        "  ULFM/Reinit++ at 64 ranks: {:.1}x (paper: on par)",
        mean(RecoveryKind::Ulfm, 64) / mean(RecoveryKind::Reinit, 64)
    );
    eprintln!(
        "fig6: {} points, host wall {:.1} s",
        points.len(),
        t0.elapsed().as_secs_f64()
    );
}
