//! Micro-bench: multi-tier checkpoint stacks in isolation — the mechanism
//! behind the `tiers` sweep. For each canonical stack it measures the worst
//! per-rank virtual save cost, the victim's post-failure recovery load cost
//! (cheapest *surviving* tier), and the host-side simulation cost; a final
//! section measures what an async drain takes off the save critical path.
//! Emits BENCH_micro_ckpt.json next to the repository root (CI artifact).

use std::cell::RefCell;
use std::rc::Rc;
use std::time::Instant;

use reinitpp::ckptstore::{CkptStore, StackSpec};
use reinitpp::cluster::Topology;
use reinitpp::config::Calibration;
use reinitpp::sim::Sim;

const RANKS_PER_NODE: u32 = 8;

fn stack(spec: &str, drain_s: f64) -> StackSpec {
    let mut s = StackSpec::parse(spec).expect("bench stack parses");
    s.drain_interval_s = drain_s;
    s
}

/// Save one checkpoint on every rank, then kill rank 0's node's ranks and
/// time the victim's recovery load. Returns (worst virtual save s, victim
/// virtual load s, host s for the whole run).
fn bench_stack(spec: &str, drain_s: f64, ranks: u32, bytes: usize) -> (f64, f64, f64) {
    let sim = Sim::new();
    let topo = Topology::new(ranks, RANKS_PER_NODE, 0);
    let store = CkptStore::new(&sim, &stack(spec, drain_s), topo, &Calibration::default());
    let worst = Rc::new(RefCell::new(0.0f64));
    let host0 = Instant::now();
    for r in 0..ranks {
        let s2 = store.clone();
        let sim2 = sim.clone();
        let w2 = Rc::clone(&worst);
        let node = topo.home_node(r);
        let p = sim.spawn_process(format!("r{r}"));
        sim.spawn(p, async move {
            let t0 = sim2.now();
            s2.save(r, node, 0, vec![0u8; bytes]).await;
            let dt = (sim2.now() - t0).secs_f64();
            let mut w = w2.borrow_mut();
            if dt > *w {
                *w = dt;
            }
        });
    }
    sim.run(); // saves complete; any drain flushes too
    // node failure on the victim's node, then a tier-aware recovery load
    let victims: Vec<u32> = topo.ranks_on_node(0);
    store.lose_node_ranks(&victims);
    let load_t = Rc::new(RefCell::new(-1.0f64));
    {
        let s2 = store.clone();
        let sim2 = sim.clone();
        let l2 = Rc::clone(&load_t);
        let p = sim.spawn_process("loader");
        sim.spawn(p, async move {
            let t0 = sim2.now();
            if s2.load(0, 0, 0).await.is_some() {
                *l2.borrow_mut() = (sim2.now() - t0).secs_f64();
            }
        });
    }
    sim.run();
    (*worst.borrow(), *load_t.borrow(), host0.elapsed().as_secs_f64())
}

fn main() {
    let bytes = 400 * 1024; // ~HPCCG 32^3 x 3 vectors
    let mut report = reinitpp::metrics::BenchReport::new("micro_ckpt");
    println!("| stack | ranks | worst save (ms) | node-fail recover load (ms) | host (ms) |");
    println!("|---|---|---|---|---|");
    for spec in ["fs", "local+partner1", "local+partner2+fs"] {
        for ranks in [16u32, 64, 256] {
            let (save, load, host) = bench_stack(spec, 0.0, ranks, bytes);
            let recov = if load < 0.0 {
                "lost".to_string()
            } else {
                format!("{:.3}", load * 1e3)
            };
            println!(
                "| {spec} | {ranks} | {:.2} | {recov} | {:.1} |",
                save * 1e3,
                host * 1e3
            );
            report.push(
                reinitpp::metrics::BenchRow::new(
                    &format!("save_{}_{}ranks", spec.replace('+', "-"), ranks),
                    ranks as u64,
                    host,
                    "rank-saves/s",
                )
                .with_extra("worst_virtual_save_ms", save * 1e3)
                .with_extra("recover_load_ms", load.max(0.0) * 1e3),
            );
        }
    }

    // Async drain: what leaves the save critical path. Same stack, same
    // payload; the sync write covers local only, the drain trickles the
    // partner + fs copies in the background.
    println!("\n| stack | drain | worst save (ms) |");
    println!("|---|---|---|");
    for (label, drain_s) in [("write-through", 0.0), ("drain 100ms", 0.1)] {
        let (save, _, host) = bench_stack("local+partner1+fs", drain_s, 64, bytes);
        println!("| local+partner1+fs | {label} | {:.2} |", save * 1e3);
        report.push(
            reinitpp::metrics::BenchRow::new(
                &format!("save_drain_{}", if drain_s > 0.0 { "on" } else { "off" }),
                64,
                host,
                "rank-saves/s",
            )
            .with_extra("worst_virtual_save_ms", save * 1e3),
        );
    }
    println!("\n(fs-only recovery pays the contended disk; partner stacks recover");
    println!(" from surviving memory. The drain rows show the blocking cost an");
    println!(" async lower-tier flush removes from the app's checkpoint call.)");
    report.write_json(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../BENCH_micro_ckpt.json"
    ));
}
