//! Allocation pin for the collective hot path (tentpole acceptance):
//! steady-state heap allocations per delivered message of a 256-rank tree
//! allreduce must stay <= 2 — in practice the shared `Rc` payload each
//! sender encodes plus the per-call result `Vec`, amortized over the
//! 2(N-1) messages of a round. Everything else (channel delivery slots,
//! wakers, the out-of-order match buffer, the reduce accumulator, the
//! fabric routing table) must be recycled, not reallocated.
//!
//! Method: run two warm-up allreduce rounds to grow every slab/scratch to
//! its high-water mark, quiesce the simulation with each rank parked on a
//! gate channel, snapshot the counting allocator + fabric counters, then
//! release the gates and measure eight more rounds.

use std::rc::Rc;

use reinitpp::cluster::Topology;
use reinitpp::config::Calibration;
use reinitpp::mpi::{FtMode, MpiJob, ReduceOp};
use reinitpp::sim::{channel, ProcName, Sim, SimDuration};

#[path = "../benches/support/counting_alloc.rs"]
mod counting_alloc;
use counting_alloc::alloc_count;

#[test]
fn allreduce_256_ranks_steady_state_allocs_per_msg_at_most_2() {
    const RANKS: u32 = 256;
    const WARMUP: u32 = 2;
    const MEASURE: u32 = 8;

    let sim = Sim::new();
    // This pin covers the *serial* event loop specifically: the sharded
    // executor (`--shards N`) shares every recycled structure but adds
    // inbox staging on cross-shard sends, so the default single-shard
    // configuration is asserted rather than assumed.
    assert_eq!(sim.shard_count(), 1, "alloc pin measures the serial path");
    let topo = Topology::new(RANKS, 16, 0);
    let job = MpiJob::new(&sim, topo, FtMode::Reinit, &Calibration::default());
    let prefix: Rc<str> = Rc::from("r");
    let mut gates = Vec::new();
    for r in 0..RANKS {
        let (gate_tx, gate_rx) = channel::<u32>(&sim);
        gates.push(gate_tx);
        let j2 = job.clone();
        let node = topo.home_node(r);
        let p = sim.spawn_process(ProcName::Indexed {
            prefix: Rc::clone(&prefix),
            index: r,
            sub: None,
        });
        sim.spawn(p, async move {
            let c = j2.attach(r, node);
            for _ in 0..WARMUP {
                c.allreduce_scalar(1.0, ReduceOp::Sum).await.unwrap();
            }
            gate_rx.recv().await.unwrap(); // quiesce here: measurement gate
            for _ in 0..MEASURE {
                let s = c.allreduce_scalar(1.0, ReduceOp::Sum).await.unwrap();
                assert_eq!(s, RANKS as f32);
            }
        });
    }

    // Phase 1: warm-up rounds, then every task parks on its gate.
    let s1 = sim.run();
    assert_eq!(s1.tasks_pending as u32, RANKS, "all ranks parked at the gate");
    let (msgs0, _) = job.fabric_stats();
    assert_eq!(msgs0 as u32, WARMUP * 2 * (RANKS - 1), "warm-up traffic");

    // Phase 2: release the gates and measure the steady state.
    let a0 = alloc_count();
    for tx in &gates {
        tx.send(1, SimDuration::ZERO);
    }
    let s2 = sim.run();
    let measured_allocs = alloc_count() - a0;
    assert_eq!(s2.tasks_pending, 0, "all ranks finished");

    let (msgs1, _) = job.fabric_stats();
    let measured_msgs = msgs1 - msgs0;
    assert_eq!(measured_msgs as u32, MEASURE * 2 * (RANKS - 1));

    let allocs_per_msg = measured_allocs as f64 / measured_msgs as f64;
    assert!(
        allocs_per_msg <= 2.0,
        "steady-state allocations per message regressed: {allocs_per_msg:.3} > 2 \
         ({measured_allocs} allocs over {measured_msgs} msgs; budget is the \
         sender's Rc payload + the per-call result Vec)"
    );
}
