//! Property tests over the coordinator invariants (DESIGN.md §9.4), using
//! the in-repo seeded property-test micro-framework (`testkit`): randomized
//! kill sequences and fault draws, with replayable case ids on failure.

use reinitpp::cluster::{Cluster, Topology};
use reinitpp::config::{
    AppKind, ExperimentConfig, FailureKind, Fidelity, RecoveryKind,
};
use reinitpp::recovery::job::run_trial;
use reinitpp::sim::rng::Rng;
use reinitpp::sim::Sim;
use reinitpp::testkit::check;

/// Random cluster + random node kill: Algorithm 1's least-loaded choice is
/// always an alive node with minimal occupancy, and respawning the lost
/// ranks there restores the full world (non-shrinking recovery).
#[test]
fn prop_least_loaded_selection_and_nonshrinking_respawn() {
    check(
        "least-loaded-respawn",
        0xA11CE,
        60,
        |rng: &mut Rng| {
            let rpn = 1 + rng.gen_range(16) as u32;
            let nodes = 2 + rng.gen_range(6) as u32;
            let ranks = rpn * nodes;
            let spares = 1 + rng.gen_range(2) as u32;
            let victim_node = rng.gen_range(nodes as u64) as u32;
            (ranks, rpn, spares, victim_node)
        },
        |&(ranks, rpn, spares, victim_node)| {
            let sim = Sim::new();
            let topo = Topology::new(ranks, rpn, spares);
            let c = Cluster::new(&sim, topo, "prop");
            c.kill_node(victim_node);
            let target = c.least_loaded_alive_node();
            if !c.node_is_alive(target) {
                return Err("selected a dead node".into());
            }
            let occ = c.occupied_slots(target);
            for n in 0..topo.total_nodes() {
                if c.node_is_alive(n) && c.occupied_slots(n) < occ {
                    return Err(format!(
                        "node {n} ({} slots) beats target {target} ({occ})",
                        c.occupied_slots(n)
                    ));
                }
            }
            let failed = c.failed_ranks();
            if failed.len() != rpn as usize {
                return Err(format!("expected {rpn} failed, got {}", failed.len()));
            }
            for r in failed {
                c.respawn_rank(r, target);
            }
            // non-shrinking: world membership fully restored
            if c.alive_ranks().len() != ranks as usize {
                return Err("world not restored to full size".into());
            }
            Ok(())
        },
    );
}

/// Every rank is re-spawned at most once per failure, and each incarnation
/// gets a fresh process id.
#[test]
fn prop_respawn_bumps_incarnation_monotonically() {
    check(
        "incarnation-monotone",
        0xBEEF,
        40,
        |rng: &mut Rng| {
            let ranks = 4 + rng.gen_range(60) as u32;
            let kills = 1 + rng.gen_range(5) as usize;
            let seq: Vec<u32> = (0..kills)
                .map(|_| rng.gen_range(ranks as u64) as u32)
                .collect();
            (ranks, seq)
        },
        |&(ranks, ref seq)| {
            let sim = Sim::new();
            let topo = Topology::new(ranks, 8, 0);
            let c = Cluster::new(&sim, topo, "prop");
            for (i, &victim) in seq.iter().enumerate() {
                if !c.rank_is_alive(victim) {
                    continue; // already dead: the RTE would skip it too
                }
                let before = c.rank_slot(victim);
                c.kill_rank(victim);
                let proc = c.respawn_rank(victim, before.node);
                let after = c.rank_slot(victim);
                if after.incarnation != before.incarnation + 1 {
                    return Err(format!("kill {i}: incarnation not bumped"));
                }
                if proc == before.proc {
                    return Err(format!("kill {i}: proc id reused"));
                }
                if !c.rank_is_alive(victim) {
                    return Err(format!("kill {i}: respawn not alive"));
                }
            }
            Ok(())
        },
    );
}

fn base_cfg(recovery: RecoveryKind) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.app = AppKind::Hpccg;
    c.recovery = recovery;
    c.failure = FailureKind::Process;
    c.ranks = 8;
    c.ranks_per_node = 4;
    c.spare_nodes = 1;
    c.iters = 6;
    c.fidelity = Fidelity::Modeled;
    c.hpccg_nx = 4;
    c
}

/// Across random seeds (= random fault iteration/victim draws), every
/// recovery approach completes and reproduces the fault-free digests.
#[test]
fn prop_equivalence_across_random_fault_draws() {
    for recovery in [RecoveryKind::Reinit, RecoveryKind::Cr, RecoveryKind::Ulfm] {
        check(
            "fault-draw-equivalence",
            0xC0FFEE ^ recovery as u64,
            6,
            |rng: &mut Rng| rng.next_u64(),
            |&seed| {
                let mut cfg = base_cfg(recovery);
                cfg.seed = seed;
                let mut free_cfg = cfg.clone();
                free_cfg.failure = FailureKind::None;
                let free = run_trial(&free_cfg, 0, None);
                let faulty = run_trial(&cfg, 0, None);
                if !faulty.completed {
                    return Err(format!("{recovery}: hung on fault {:?}", faulty.faults));
                }
                if faulty.digests != free.digests {
                    return Err(format!(
                        "{recovery}: digests differ for fault {:?}",
                        faulty.faults
                    ));
                }
                if faulty.breakdown.mpi_recovery_s <= 0.0 {
                    return Err("no recovery time recorded".into());
                }
                Ok(())
            },
        );
    }
}

/// The victim's buddy checkpoint is never read from a failed pair: with
/// memory checkpointing, recovery succeeds iff the buddy survived — which a
/// single process failure guarantees (paper Table 2's premise).
#[test]
fn prop_single_process_failure_always_recoverable_from_memory() {
    check(
        "buddy-survives-single-failure",
        0xDADA,
        8,
        |rng: &mut Rng| rng.next_u64(),
        |&seed| {
            let mut cfg = base_cfg(RecoveryKind::Reinit);
            cfg.seed = seed;
            cfg.ckpt = Some(reinitpp::config::CkptKind::Memory);
            let r = run_trial(&cfg, 0, None);
            if !r.completed {
                return Err(format!("hung on {:?}", r.faults));
            }
            Ok(())
        },
    );
}

/// Virtual-time determinism of whole trials: same config + seed => same
/// event count, same final time, same digests (the DES guarantee the whole
/// measurement methodology rests on).
#[test]
fn prop_trials_are_replayable() {
    check(
        "trial-replay",
        0x5EED,
        5,
        |rng: &mut Rng| rng.next_u64(),
        |&seed| {
            let mut cfg = base_cfg(RecoveryKind::Ulfm);
            cfg.seed = seed;
            let a = run_trial(&cfg, 0, None);
            let b = run_trial(&cfg, 0, None);
            if a.sim_events != b.sim_events {
                return Err("event counts differ".into());
            }
            if a.breakdown.total_s != b.breakdown.total_s {
                return Err("total times differ".into());
            }
            if a.digests != b.digests {
                return Err("digests differ".into());
            }
            Ok(())
        },
    );
}
