//! Allocation pin for the tracing layer's DISABLED path (tentpole
//! acceptance: zero cost when off).
//!
//! Every instrumentation site threaded through the executor, MPI layer,
//! checkpoint store and recovery drivers is a branch on one `Cell<bool>`
//! when no recorder is armed: span/counter names are `&'static str` and
//! the disabled path never formats, boxes or buffers anything — so it
//! must add exactly ZERO heap allocations. (The message-path budget in
//! `alloc_pin.rs` runs through the *instrumented* collective hot path
//! with tracing off, so a disabled-path allocation would also trip that
//! budget; this binary pins the tracer API itself, and stays a
//! single-test binary because the counting allocator is process-global.)

use reinitpp::sim::SimTime;
use reinitpp::trace::Tracer;

#[path = "../benches/support/counting_alloc.rs"]
mod counting_alloc;
use counting_alloc::alloc_count;

#[test]
fn disabled_tracer_hot_path_allocates_nothing() {
    let tr = Tracer::new();
    assert!(!tr.is_on());
    let a0 = alloc_count();
    for i in 0..10_000u64 {
        tr.span("mpi", "allreduce", 1, SimTime(i), SimTime(i + 5));
        tr.rank_span("mpi", "recv", (i % 7) as u32, SimTime(i), SimTime(i + 1));
        tr.instant("recovery", "detect", 0, SimTime(i));
        tr.counter("exec", "events_pending", SimTime(i), i);
        tr.add("mpi.recv_direct", 1);
    }
    let added = alloc_count() - a0;
    assert_eq!(
        added, 0,
        "disabled tracer allocated {added} times over 50k no-op sites"
    );
}
