//! The tentpole pin for the sharded executor: `--shards N` is a host
//! knob, so every observable output of a trial — executor counters,
//! per-rank digests, the paper breakdown, the per-failure segments, the
//! peak state footprint — must be *byte-identical* for any shard count.
//!
//! The sharded engine earns this by construction (the K-way merge across
//! shard queues replays the exact global `(time, seq)` order the serial
//! loop pops), but construction arguments rot; these tests re-prove it
//! empirically for all five recovery families under a 3-failure storm,
//! and byte-compare the golden trace artifacts of a serial vs a 4-shard
//! run (modulo the host `wall_us` annotations, which are real wall time
//! and never deterministic).

use std::path::{Path, PathBuf};

use reinitpp::config::{AppKind, ExperimentConfig, Fidelity, RecoveryKind};
use reinitpp::recovery::job::{run_trial_opts, TrialResult};
use reinitpp::trace::TraceConfig;

/// Unique scratch dir per test (no tempdir dependency).
fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "reinitpp-shard-det-{}-{tag}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// A 3-failure process storm at 8 ranks / 4 per node: enough churn to
/// exercise detect → recover → rollback (or failover) three times in
/// every family, small enough to run all fifteen trials in one test.
fn storm_cfg(recovery: RecoveryKind) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.app = AppKind::Hpccg;
    c.recovery = recovery;
    c.ranks = 8;
    c.ranks_per_node = 4;
    c.spare_nodes = 1;
    c.iters = 8;
    c.trials = 1;
    c.fidelity = Fidelity::Modeled;
    c.hpccg_nx = 4;
    c.seed = 42;
    c.apply("failures", "proc@2:r1,proc@4:r3,proc@6:r5").unwrap();
    match recovery {
        // shrink's whole point: survivors absorb the failure, no spares
        RecoveryKind::Shrink => c.spare_nodes = 0,
        // one node-disjoint shadow per rank (2 compute nodes available)
        RecoveryKind::Replication => c.repl_degree = 2,
        _ => {}
    }
    c
}

/// Everything a trial result pins, as one comparable value (the same
/// shape `tests/trace_determinism.rs` uses, plus the SoA footprint).
fn fingerprint(r: &TrialResult) -> String {
    format!(
        "{:?}|{:?}|{:?}|{:?}|{}|{}",
        r.counters, r.digests, r.breakdown, r.segments, r.sim_events,
        r.counters.peak_rank_state_bytes
    )
}

#[test]
fn all_recovery_families_are_shard_count_invariant_under_a_storm() {
    for recovery in RecoveryKind::ALL {
        let cfg = storm_cfg(recovery);
        let serial = run_trial_opts(&cfg, 0, None, None, 1);
        assert!(serial.completed, "{recovery}: serial storm trial hung");
        assert!(
            !serial.segments.is_empty(),
            "{recovery}: storm must fire failures"
        );
        assert!(
            serial.counters.peak_rank_state_bytes > 0,
            "{recovery}: state footprint metric must be populated"
        );
        for shards in [2usize, 4] {
            let sharded = run_trial_opts(&cfg, 0, None, None, shards);
            assert!(sharded.completed, "{recovery}: {shards}-shard trial hung");
            assert_eq!(
                fingerprint(&serial),
                fingerprint(&sharded),
                "{recovery}: --shards {shards} diverged from the serial loop"
            );
        }
    }
}

/// Categories recorded for the golden-trace byte comparison: everything
/// except `shard` (the per-shard fired-event counter tracks exist *only*
/// in sharded runs — they are the one intentional trace difference) and
/// `pool` (host wall time).
fn golden_filter() -> Option<Vec<String>> {
    Some(
        ["exec", "mpi", "ckpt", "recovery", "integrity", "detect"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    )
}

fn trace_into(dir: &Path) -> TraceConfig {
    TraceConfig {
        dir: dir.to_string_lossy().into_owned(),
        filter: golden_filter(),
    }
}

/// Blank out the `"wall_us":<float>` annotations (real host time) so the
/// rest of the trace-event JSON can be compared byte-for-byte.
fn strip_wall_us(trace: &str) -> String {
    let mut out = String::with_capacity(trace.len());
    let mut rest = trace;
    while let Some(i) = rest.find("\"wall_us\":") {
        let tail = &rest[i + "\"wall_us\":".len()..];
        let end = tail
            .find(|c: char| !matches!(c, '0'..='9' | '.' | '-' | '+' | 'e' | 'E'))
            .unwrap_or(tail.len());
        out.push_str(&rest[..i]);
        out.push_str("\"wall_us\":0");
        rest = &tail[end..];
    }
    out.push_str(rest);
    out
}

#[test]
fn golden_trace_artifacts_are_byte_identical_across_shard_counts() {
    let cfg = storm_cfg(RecoveryKind::Reinit);
    let d1 = tmp("serial");
    let d4 = tmp("shard4");
    let serial = run_trial_opts(&cfg, 0, None, Some(&trace_into(&d1)), 1);
    let sharded = run_trial_opts(&cfg, 0, None, Some(&trace_into(&d4)), 4);
    assert!(serial.completed && sharded.completed);
    // `--shards` is not part of the experiment identity, so both runs key
    // their artifacts by the same hash.
    assert_eq!(serial.counters.identity, sharded.counters.identity);
    let id = format!("{:016x}", serial.counters.identity);

    // Folded stacks carry only virtual-time span totals: byte-identical.
    let folded1 = std::fs::read(d1.join(format!("trace_{id}.folded"))).unwrap();
    let folded4 = std::fs::read(d4.join(format!("trace_{id}.folded"))).unwrap();
    assert!(!folded1.is_empty());
    assert_eq!(
        folded1, folded4,
        "folded flamegraph stacks moved between --shards 1 and --shards 4"
    );

    // The Perfetto trace embeds host wall time in args; everything else —
    // event order, virtual timestamps, durations, counters, track names —
    // must match byte-for-byte.
    let t1 = std::fs::read_to_string(d1.join(format!("trace_{id}.trace.json"))).unwrap();
    let t4 = std::fs::read_to_string(d4.join(format!("trace_{id}.trace.json"))).unwrap();
    assert_eq!(
        strip_wall_us(&t1),
        strip_wall_us(&t4),
        "golden trace diverged between --shards 1 and --shards 4"
    );

    for d in [&d1, &d4] {
        let _ = std::fs::remove_dir_all(d);
    }
}
