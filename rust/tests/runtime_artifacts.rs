//! Integration: AOT artifacts (python/compile/aot.py -> HLO text) load,
//! compile and execute through the PJRT CPU client, and their numerics match
//! the pure-Rust native oracle — closing the Python -> HLO -> Rust triangle.
//!
//! Requires `make artifacts` (skipped with a clear message otherwise) and
//! the `pjrt` cargo feature (hermetic builds have no PJRT client).
#![cfg(feature = "pjrt")]

use reinitpp::apps::native;
use reinitpp::runtime::{ArrayF32, XlaRuntime};
use reinitpp::sim::rng::Rng;

fn runtime() -> XlaRuntime {
    XlaRuntime::load("artifacts").expect("run `make artifacts` first")
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

fn rand_array(shape: &[usize], lo: f32, hi: f32, seed: u64) -> ArrayF32 {
    let mut rng = Rng::new(seed);
    let n: usize = shape.iter().product();
    ArrayF32::new(
        shape.to_vec(),
        (0..n).map(|_| rng.gen_f32_range(lo, hi)).collect(),
    )
}

#[test]
fn manifest_lists_all_kernels() {
    let rt = runtime();
    for name in [
        "comd_step_n64",
        "comd_step_n128",
        "hpccg_matvec_8",
        "hpccg_matvec_16",
        "hpccg_update_16",
        "hpccg_direction_16",
        "lulesh_step_8",
        "lulesh_step_16",
    ] {
        assert!(rt.has_artifact(name), "missing artifact {name}");
    }
}

#[test]
fn hpccg_matvec_matches_native() {
    let rt = runtime();
    let nx = 8usize;
    let ph = rand_array(&[nx + 2, nx + 2, nx + 2], -1.0, 1.0, 7);
    let (outs, wall) = rt.execute("hpccg_matvec_8", &[ph.clone()]).unwrap();
    assert!(wall.as_nanos() > 0);
    let (ap_n, pap_n) = native::hpccg_matvec(&ph.data, nx);
    assert!(max_abs_diff(&outs[0].data, &ap_n) < 1e-4);
    let rel = (outs[1].as_scalar() - pap_n).abs() / pap_n.abs().max(1.0);
    assert!(rel < 1e-4, "pAp {} vs {}", outs[1].as_scalar(), pap_n);
}

#[test]
fn hpccg_update_and_direction_match_native() {
    let rt = runtime();
    let nx = 16usize;
    let shape = [nx, nx, nx];
    let x = rand_array(&shape, -1.0, 1.0, 1);
    let r = rand_array(&shape, -1.0, 1.0, 2);
    let p = rand_array(&shape, -1.0, 1.0, 3);
    let ap = rand_array(&shape, -1.0, 1.0, 4);
    let alpha = ArrayF32::scalar(0.37);
    let (outs, _) = rt
        .execute(
            "hpccg_update_16",
            &[x.clone(), r.clone(), p.clone(), ap.clone(), alpha],
        )
        .unwrap();
    let (x2, r2, rr) = native::hpccg_update(&x.data, &r.data, &p.data, &ap.data, 0.37);
    assert!(max_abs_diff(&outs[0].data, &x2) < 1e-5);
    assert!(max_abs_diff(&outs[1].data, &r2) < 1e-5);
    assert!((outs[2].as_scalar() - rr).abs() / rr.max(1.0) < 1e-4);

    let beta = ArrayF32::scalar(0.81);
    let (outs, _) = rt
        .execute("hpccg_direction_16", &[r.clone(), p.clone(), beta])
        .unwrap();
    let p2 = native::hpccg_direction(&r.data, &p.data, 0.81);
    assert!(max_abs_diff(&outs[0].data, &p2) < 1e-5);
}

#[test]
fn lulesh_step_matches_native() {
    let rt = runtime();
    let nx = 8usize;
    let e = rand_array(&[nx, nx, nx], 0.5, 2.0, 5);
    let uh = rand_array(&[nx + 2, nx + 2, nx + 2], -0.1, 0.1, 6);
    let dt = ArrayF32::scalar(1e-3);
    let (outs, _) = rt
        .execute("lulesh_step_8", &[e.clone(), uh.clone(), dt])
        .unwrap();
    let (e2, u2, dtmin) = native::lulesh_step(&e.data, &uh.data, nx, 1e-3);
    assert!(max_abs_diff(&outs[0].data, &e2) < 1e-5);
    assert!(max_abs_diff(&outs[1].data, &u2) < 1e-5);
    assert!((outs[2].as_scalar() - dtmin).abs() < 1e-5);
}

#[test]
fn comd_step_matches_native() {
    let rt = runtime();
    let n = 64usize;
    // physical lattice config (overlapping random positions blow up LJ)
    let state = reinitpp::apps::ComdApp { n: 64, seed: 9 }; // noqa: factory
    let _ = state;
    let mut rng = Rng::new(9);
    let side = 4usize;
    let spacing = 1.25f32;
    let boxl = side as f32 * spacing;
    let mut pos = Vec::with_capacity(n * 3);
    for x in 0..side {
        for y in 0..side {
            for z in 0..side {
                for c in [x, y, z] {
                    pos.push(c as f32 * spacing + 0.6 + rng.gen_f32_range(-0.03, 0.03));
                }
            }
        }
    }
    let vel: Vec<f32> = (0..n * 3).map(|_| rng.gen_f32_range(-0.05, 0.05)).collect();
    let (frc0, _) = native::lj_forces(&pos, n, boxl);
    let inputs = [
        ArrayF32::new(vec![n, 3], pos.clone()),
        ArrayF32::new(vec![n, 3], vel.clone()),
        ArrayF32::new(vec![n, 3], frc0.clone()),
        ArrayF32::scalar(2e-3),
        ArrayF32::scalar(boxl),
    ];
    let (outs, _) = rt.execute("comd_step_n64", &inputs).unwrap();
    let (p2, v2, f2, ke, pe) = native::comd_step(&pos, &vel, &frc0, n, 2e-3, boxl);
    assert!(max_abs_diff(&outs[0].data, &p2) < 1e-4);
    assert!(max_abs_diff(&outs[1].data, &v2) < 2e-3); // force accumulation order
    assert!(max_abs_diff(&outs[2].data, &f2) < 0.5 * f2.iter().fold(1.0f32, |a, &b| a.max(b.abs())) * 1e-3 + 1e-2);
    assert!((outs[3].as_scalar() - ke).abs() / ke.max(1.0) < 1e-3);
    assert!((outs[4].as_scalar() - pe).abs() / pe.abs().max(1.0) < 1e-3);
}

#[test]
fn executable_is_cached_and_reusable() {
    let rt = runtime();
    let nx = 8usize;
    let ph = rand_array(&[nx + 2, nx + 2, nx + 2], -1.0, 1.0, 11);
    let (a, first) = rt.execute("hpccg_matvec_8", &[ph.clone()]).unwrap();
    let (b, _second) = rt.execute("hpccg_matvec_8", &[ph]).unwrap();
    // deterministic across calls (same compiled executable)
    assert_eq!(a[0].data, b[0].data);
    assert!(first.as_nanos() > 0);
}

#[test]
fn shape_validation_rejects_bad_inputs() {
    let rt = runtime();
    let bad = ArrayF32::zeros(&[4, 4, 4]);
    assert!(rt.execute("hpccg_matvec_8", &[bad]).is_err());
    assert!(rt.execute("no_such_kernel", &[]).is_err());
}

#[test]
fn xla_is_bitwise_deterministic() {
    // the equivalence experiments rely on recomputation being exact
    let rt = runtime();
    let nx = 16usize;
    let ph = rand_array(&[nx + 2, nx + 2, nx + 2], -1.0, 1.0, 13);
    let (a, _) = rt.execute("hpccg_matvec_16", &[ph.clone()]).unwrap();
    let (b, _) = rt.execute("hpccg_matvec_16", &[ph]).unwrap();
    assert_eq!(
        a[0].data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        b[0].data.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
    );
    assert_eq!(a[1].as_scalar().to_bits(), b[1].as_scalar().to_bits());
}
