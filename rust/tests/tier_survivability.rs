//! Property-style survivability matrix for the multi-tier checkpoint store:
//! (stack × failure domain × replica count × topology), checked two ways —
//!
//! 1. unit level: inject losses straight into a `CkptStore` and compare
//!    what survives against a placement-derived oracle (a copy survives iff
//!    some host of it is outside the failed set, or it sits on the fs tier);
//! 2. end to end: whole trials through the recovery paths must complete and
//!    reproduce the fault-free digests when the stack can survive the
//!    injected failure — including the new node-failure-over-memory case
//!    that node-disjoint replicas unlock (the acceptance pin: k >= 1
//!    node-disjoint replicas survive a node failure).

use reinitpp::ckptstore::{partners_of, CkptStore, StackSpec, TierSpec};
use reinitpp::cluster::Topology;
use reinitpp::config::{
    AppKind, Calibration, ExperimentConfig, FailureKind, Fidelity, RecoveryKind,
};
use reinitpp::recovery::job::run_trial;
use reinitpp::sim::Sim;

fn store(spec: &str, topo: Topology) -> (Sim, CkptStore) {
    let sim = Sim::new();
    let stack = StackSpec::parse(spec).unwrap();
    let s = CkptStore::new(&sim, &stack, topo, &Calibration::default());
    (sim, s)
}

fn save_all(sim: &Sim, s: &CkptStore, topo: Topology, iter: u32) {
    for r in 0..topo.ranks {
        let s2 = s.clone();
        let node = topo.home_node(r);
        let p = sim.spawn_process(format!("saver{r}"));
        sim.spawn(p, async move {
            s2.save(r, node, iter, vec![r as u8; 16]).await;
        });
    }
    sim.run();
}

/// Placement oracle: does rank `r`'s checkpoint survive losing `dead`?
fn oracle_survives(stack: &StackSpec, topo: Topology, r: u32, dead: &[u32]) -> bool {
    stack.tiers.iter().any(|t| match *t {
        TierSpec::SharedFs => true,
        TierSpec::LocalMem => !dead.contains(&r),
        TierSpec::PartnerMem {
            replicas,
            node_disjoint,
        } => partners_of(&topo, r, replicas, node_disjoint)
            .iter()
            .any(|h| !dead.contains(h)),
    })
}

/// The full unit-level matrix: every stack × every topology × process and
/// node failure domains, store behavior vs the placement oracle.
#[test]
fn survivability_matrix_matches_placement_oracle() {
    let stacks = [
        "fs",
        "local",
        "local+partner1",
        "local+partner1.same",
        "local+partner2",
        "local+partner2+fs",
        "partner3",
    ];
    let topos = [
        Topology::new(8, 4, 1),
        Topology::new(8, 2, 0),
        Topology::new(16, 16, 0), // single node
        Topology::new(12, 5, 2),  // ragged last node
    ];
    for spec in stacks {
        let stack = StackSpec::parse(spec).unwrap();
        for topo in topos {
            // process-failure domains: each rank alone
            for victim in 0..topo.ranks {
                let (sim, s) = store(spec, topo);
                save_all(&sim, &s, topo, 1);
                s.lose_rank(victim);
                for r in 0..topo.ranks {
                    let dead = [victim];
                    assert_eq!(
                        s.latest_iter(r).is_some(),
                        oracle_survives(&stack, topo, r, &dead),
                        "{spec} topo({},{}) victim {victim} rank {r}",
                        topo.ranks,
                        topo.ranks_per_node
                    );
                }
            }
            // node-failure domains: each node's resident ranks
            for node in 0..topo.compute_nodes {
                let (sim, s) = store(spec, topo);
                save_all(&sim, &s, topo, 1);
                let dead = topo.ranks_on_node(node);
                s.lose_node_ranks(&dead);
                for r in 0..topo.ranks {
                    assert_eq!(
                        s.latest_iter(r).is_some(),
                        oracle_survives(&stack, topo, r, &dead),
                        "{spec} topo({},{}) node {node} rank {r}",
                        topo.ranks,
                        topo.ranks_per_node
                    );
                }
            }
        }
    }
}

/// The acceptance pin, stated directly: with k node-disjoint replicas and
/// >= 2 compute nodes, EVERY rank's checkpoint survives ANY single node
/// failure, for k = 1 and k = 2 — while the same-node variant does not.
#[test]
fn node_disjoint_replicas_survive_any_single_node_failure() {
    for spec in ["local+partner1", "local+partner2"] {
        for topo in [Topology::new(8, 4, 1), Topology::new(32, 8, 0)] {
            for node in 0..topo.compute_nodes {
                let (sim, s) = store(spec, topo);
                save_all(&sim, &s, topo, 3);
                s.lose_node_ranks(&topo.ranks_on_node(node));
                for r in 0..topo.ranks {
                    assert_eq!(
                        s.latest_iter(r),
                        Some(3),
                        "{spec}: rank {r} lost to node {node} failure"
                    );
                }
            }
        }
    }
    // counterexample: a same-node (cyclic) buddy loses interior ranks
    let topo = Topology::new(8, 4, 0);
    let (sim, s) = store("local+partner1.same", topo);
    save_all(&sim, &s, topo, 3);
    s.lose_node_ranks(&topo.ranks_on_node(0)); // ranks 0..4
    assert_eq!(s.latest_iter(0), None, "rank 0's cyclic buddy (1) died with it");
    assert_eq!(s.latest_iter(3), Some(3), "rank 3's cyclic buddy (4) is off-node");
}

/// k = 2 replicas survive owner + one replica host dying; losing the last
/// replica host loses the checkpoint.
#[test]
fn replica_count_bounds_multi_failure_survivability() {
    let topo = Topology::new(12, 4, 0);
    for r in 0..topo.ranks {
        let hosts = partners_of(&topo, r, 2, true);
        let (sim, s) = store("local+partner2", topo);
        save_all(&sim, &s, topo, 1);
        s.lose_rank(r);
        s.lose_rank(hosts[0]);
        assert!(
            s.latest_iter(r).is_some(),
            "rank {r}: k=2 must survive owner + one replica host"
        );
        s.lose_rank(hosts[1]);
        assert!(
            s.latest_iter(r).is_none(),
            "rank {r}: all copies gone after the second replica host"
        );
    }
}

// ---- end-to-end trials through the recovery paths ----

fn trial_cfg(
    recovery: RecoveryKind,
    failure: FailureKind,
    stack: &str,
    drain_s: f64,
) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.app = AppKind::Hpccg;
    c.recovery = recovery;
    c.failure = failure;
    c.ranks = 8;
    c.ranks_per_node = 4;
    c.spare_nodes = 1;
    c.iters = 6;
    c.fidelity = Fidelity::Modeled;
    c.hpccg_nx = 4;
    c.seed = 4242;
    c.ckpt_tiers = Some(StackSpec::parse(stack).unwrap());
    c.ckpt_drain_interval_s = drain_s;
    c
}

fn check_equivalence(cfg: &ExperimentConfig, trial: u32) {
    let mut free = cfg.clone();
    free.failure = FailureKind::None;
    let want = run_trial(&free, trial, None);
    assert!(want.completed);
    let got = run_trial(cfg, trial, None);
    assert!(
        got.completed,
        "{}/{}/{} hung (fault {:?})",
        cfg.recovery,
        cfg.failure,
        cfg.effective_stack(),
        got.faults
    );
    assert_eq!(
        got.digests, want.digests,
        "{}/{}/{}: recovered state differs (fault {:?})",
        cfg.recovery,
        cfg.failure,
        cfg.effective_stack(),
        got.faults
    );
}

/// A node failure recovered entirely from memory tiers — impossible under
/// the paper's two-scheme store, unlocked by node-disjoint replicas.
#[test]
fn reinit_node_failure_recovers_from_partner_tier() {
    for trial in 0..3 {
        let cfg = trial_cfg(RecoveryKind::Reinit, FailureKind::Node, "local+partner1", 0.0);
        check_equivalence(&cfg, trial);
        let r = run_trial(&cfg, trial, None);
        assert_eq!(
            r.storage.disk.bytes_read, 0,
            "recovery must never touch the disk with a surviving partner tier"
        );
        assert!(
            r.storage.local.rebuild_bytes + r.storage.partner.rebuild_bytes > 0,
            "the node's victims must rebuild their lost copies"
        );
    }
}

/// ULFM and CR drive the same store through their own recovery paths.
#[test]
fn ulfm_process_failure_over_two_replica_stack() {
    let cfg = trial_cfg(RecoveryKind::Ulfm, FailureKind::Process, "local+partner2", 0.0);
    check_equivalence(&cfg, 1);
}

#[test]
fn cr_abort_falls_back_to_fs_tier() {
    let cfg = trial_cfg(RecoveryKind::Cr, FailureKind::Process, "local+partner1+fs", 0.0);
    check_equivalence(&cfg, 0);
    let r = run_trial(&cfg, 0, None);
    assert!(
        r.storage.disk.bytes_read > 0,
        "CR re-deploy wiped the memory tiers; recovery must read the fs tier"
    );
}

/// Async drain end to end: the failure may land between drain activations,
/// global restart still converges to the fault-free digests.
#[test]
fn drained_stack_recovers_across_failure() {
    for trial in 0..3 {
        let cfg = trial_cfg(
            RecoveryKind::Reinit,
            FailureKind::Process,
            "local+partner1+fs",
            0.05,
        );
        check_equivalence(&cfg, trial);
        let r = run_trial(&cfg, trial, None);
        assert!(
            r.storage.partner.drained_bytes > 0 || r.storage.fs.drained_bytes > 0,
            "the background drain must have moved bytes"
        );
    }
}

/// Replica rebuild restores full redundancy: after recovery, a SECOND
/// failure of the same domain must still be survivable at the store level.
#[test]
fn rebuild_restores_redundancy_for_repeat_failures() {
    let cfg = trial_cfg(RecoveryKind::Reinit, FailureKind::Process, "local+partner1", 0.0);
    let r = run_trial(&cfg, 2, None);
    assert!(r.completed);
    assert!(
        r.storage.local.rebuild_bytes + r.storage.partner.rebuild_bytes > 0,
        "the victim's reinstated copies must be counted as rebuild traffic"
    );
}
