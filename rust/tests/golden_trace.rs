//! Golden-trace regression tests for the DES executor.
//!
//! The engine internals (slab tasks, cached wakers, timer wheel) are free to
//! change, but the *trace* — which events fire, in which order, how many
//! polls the scheduler performs, and where virtual time ends — is the
//! executor's contract with the experiments. These tests pin the exact
//! `(events, polls, end_time)` triple of two mixed workloads to the values
//! derived from the executor's documented semantics (the step-by-step
//! derivations are in the comments), so any rewrite that perturbs scheduling
//! order, wake dedup, kill semantics, or timer ordering fails loudly.

use std::cell::{Cell, RefCell};
use std::rc::Rc;

use reinitpp::cluster::Topology;
use reinitpp::config::Calibration;
use reinitpp::mpi::{FtMode, MpiJob, ReduceOp};
use reinitpp::sim::{channel, Sim, SimDuration, SimTime};

/// Mixed sim-layer workload: sleeps + channel traffic + yield + kill + watch.
///
/// Derivation of the golden counts (task poll = one `poll_task` that reaches
/// the future; event = one popped timer/delivery/closure):
///
/// spawn A1(proc a), B1(proc b), C1(proc c), W(proc b); schedule kill(c)@20µs.
///  p1  A1: sleep(10µs) registers wake@10µs            -> pending
///  p2  B1: recv #1 blocks                             -> pending
///  p3  C1: sleep(100µs) registers wake@100µs          -> pending
///  p4  W:  watch(c) registers watcher                 -> pending
///  e1  wake@10µs   p5  A1: sends msg 1 (delay 5µs -> @15µs) and msg 2
///                       (delay 1µs -> @11µs); sleep(10µs) -> wake@20µs
///  e2  deliver "2"@11µs  p6  B1: recv #1 = Ok(2); recv #2 blocks
///  e3  deliver "1"@15µs  p7  B1: recv #2 = Ok(1); yield_now self-wakes
///  (wake ring)           p8  B1: yield resolves; sleep(2µs) -> wake@17µs
///  e4  wake@17µs         p9  B1: done (completed: 1)
///  e5  kill(c)@20µs: C1's future dropped, watcher woken
///                        p10 W: watch = 20µs, done (completed: 2)
///  e6  wake@20µs         p11 A1: done (completed: 3)
///  e7  wake@100µs: C1's timer fires into the void (task dead) — the event
///      still pops and advances virtual time, exactly like the seed engine.
///  idle.
///
/// => events = 7, polls = 11, end_time = 100 µs, 3 completed, 0 pending.
fn mixed_sim_workload() -> (u64, u64, u64, u64, u64, u64) {
    let sim = Sim::new();
    let a = sim.spawn_process("a");
    let b = sim.spawn_process("b");
    let c = sim.spawn_process("victim");
    let (tx, rx) = channel::<u32>(&sim);
    let watch_at = Rc::new(Cell::new(0u64));

    let s2 = sim.clone();
    sim.spawn(a, async move {
        s2.sleep(SimDuration::from_micros(10)).await;
        tx.send(1, SimDuration::from_micros(5));
        tx.send(2, SimDuration::from_micros(1));
        s2.sleep(SimDuration::from_micros(10)).await;
    });

    let s3 = sim.clone();
    sim.spawn(b, async move {
        let first = rx.recv().await.unwrap();
        let second = rx.recv().await.unwrap();
        assert_eq!((first, second), (2, 1), "low-latency message overtakes");
        s3.yield_now().await;
        s3.sleep(SimDuration::from_micros(2)).await;
    });

    let s4 = sim.clone();
    sim.spawn(c, async move {
        s4.sleep(SimDuration::from_micros(100)).await;
        unreachable!("killed at 20µs");
    });

    let s5 = sim.clone();
    let w2 = Rc::clone(&watch_at);
    sim.spawn(b, async move {
        w2.set(s5.watch(c).await.nanos());
    });

    let s6 = sim.clone();
    sim.schedule(SimDuration::from_micros(20), move || s6.kill(c));

    let s = sim.run();
    assert_eq!(watch_at.get(), 20_000, "watcher saw the kill time");
    (
        s.events,
        s.polls,
        s.end_time.nanos(),
        s.tasks_completed,
        s.tasks_pending,
        watch_at.get(),
    )
}

#[test]
fn golden_trace_mixed_sim_workload() {
    let (events, polls, end_ns, completed, pending, watch_ns) = mixed_sim_workload();
    assert_eq!(
        (events, polls, end_ns),
        (7, 11, 100_000),
        "executor trace drifted from the pinned semantics"
    );
    assert_eq!(completed, 3);
    assert_eq!(pending, 0);
    assert_eq!(watch_ns, 20_000);
}

#[test]
fn golden_trace_is_deterministic_across_runs() {
    assert_eq!(mixed_sim_workload(), mixed_sim_workload());
}

/// 4-rank allreduce with a round-number calibration so every delivery delay
/// is exactly 1001 ns (1 µs latency + 4 B at 4 GB/s = 1 ns).
///
/// Binomial reduce to 0 then broadcast, all ranks on one node, arrivals:
///   reduce: r1->r0 and r3->r2 arrive @1001; r2->r0 arrives @2002
///   bcast:  r0->r2 and r0->r1 arrive @3003; r2->r3 arrives @4004
/// => 6 delivery events, end_time = 4004 ns.
/// Polls per rank (initial poll + one poll per message arrival):
///   r0: 1 + recv(r1) + recv(r2) = 3     r1: 1 + recv(r0) = 2
///   r2: 1 + recv(r3) + recv(r0) = 3     r3: 1 + recv(r2) = 2
/// => polls = 10.
fn allreduce_trace() -> (u64, u64, u64, Vec<u32>) {
    let sim = Sim::new();
    let mut calib = Calibration::default();
    calib.intra_latency_us = 1.0;
    calib.intra_bw_gbps = 4.0;
    let topo = Topology::new(4, 16, 0);
    let job = MpiJob::new(&sim, topo, FtMode::Reinit, &calib);
    let sums: Rc<RefCell<Vec<u32>>> = Rc::new(RefCell::new(Vec::new()));
    for r in 0..4u32 {
        let p = sim.spawn_process(format!("r{r}"));
        let j2 = job.clone();
        let s2 = Rc::clone(&sums);
        sim.spawn(p, async move {
            let c = j2.attach(r, 0);
            let v = c.allreduce_scalar(r as f32, ReduceOp::Sum).await.unwrap();
            s2.borrow_mut().push(v.to_bits());
        });
    }
    let s = sim.run();
    assert_eq!(s.tasks_pending, 0, "collective deadlocked");
    assert_eq!(s.end_time, SimTime(4_004));
    let bits = Rc::try_unwrap(sums).ok().unwrap().into_inner();
    (s.events, s.polls, s.end_time.nanos(), bits)
}

#[test]
fn golden_trace_allreduce_over_mpi_layer() {
    let (events, polls, end_ns, bits) = allreduce_trace();
    assert_eq!(
        (events, polls, end_ns),
        (6, 10, 4_004),
        "collective trace drifted from the pinned semantics"
    );
    assert_eq!(bits.len(), 4);
    assert!(
        bits.iter().all(|&b| b == 6.0f32.to_bits()),
        "fixed combine order: 0+1+2+3 must be exactly 6.0 on every rank"
    );
}

#[test]
fn golden_trace_allreduce_deterministic_across_runs() {
    assert_eq!(allreduce_trace(), allreduce_trace());
}
