//! Integration: the paper-scale configurations run to completion in
//! reasonable wall time — fast fidelity at 256+ ranks (ghost ranks replay
//! live-measured compute costs), modeled fidelity at 1024.

use reinitpp::config::{AppKind, ExperimentConfig, FailureKind, Fidelity, RecoveryKind};
use reinitpp::recovery::job::run_trial;

fn cfg(ranks: u32) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.app = AppKind::Hpccg;
    c.recovery = RecoveryKind::Reinit;
    c.failure = FailureKind::Process;
    c.ranks = ranks;
    c.ranks_per_node = 16;
    c.spare_nodes = 1;
    c.iters = 8;
    c.fidelity = Fidelity::Modeled;
    c.hpccg_nx = 8;
    c.seed = 77;
    c
}

#[test]
fn modeled_256_ranks_process_failure() {
    let r = run_trial(&cfg(256), 0, None);
    assert!(r.completed, "fault {:?}", r.faults);
    assert!(r.breakdown.mpi_recovery_s > 0.1);
}

#[test]
fn modeled_1024_ranks_process_failure() {
    let r = run_trial(&cfg(1024), 0, None);
    assert!(r.completed, "fault {:?}", r.faults);
    // Fig. 6's headline: recovery stays ~constant as ranks grow
    let small = run_trial(&cfg(64), 0, None);
    let ratio = r.breakdown.mpi_recovery_s / small.breakdown.mpi_recovery_s;
    assert!(
        (0.5..2.0).contains(&ratio),
        "Reinit++ recovery must scale ~flat: 64 ranks {} vs 1024 ranks {}",
        small.breakdown.mpi_recovery_s,
        r.breakdown.mpi_recovery_s
    );
}

#[test]
fn modeled_node_failure_at_scale() {
    let mut c = cfg(256);
    c.failure = FailureKind::Node;
    let r = run_trial(&c, 0, None);
    assert!(r.completed, "fault {:?}", r.faults);
    assert!(r.breakdown.mpi_recovery_s > 1.0);
}

#[test]
fn ulfm_recovery_grows_with_scale() {
    // Fig. 6's other headline: ULFM degrades as ranks grow
    let mut small = cfg(16);
    small.recovery = RecoveryKind::Ulfm;
    let mut big = cfg(512);
    big.recovery = RecoveryKind::Ulfm;
    let ts = run_trial(&small, 0, None);
    let tb = run_trial(&big, 0, None);
    assert!(ts.completed && tb.completed);
    assert!(
        tb.breakdown.mpi_recovery_s > 1.5 * ts.breakdown.mpi_recovery_s,
        "ULFM at 512 ranks ({}) must exceed 16 ranks ({})",
        tb.breakdown.mpi_recovery_s,
        ts.breakdown.mpi_recovery_s
    );
}

#[test]
fn cr_flat_and_slowest_at_scale() {
    let mut c = cfg(512);
    c.recovery = RecoveryKind::Cr;
    let cr = run_trial(&c, 0, None);
    let reinit = run_trial(&cfg(512), 0, None);
    assert!(cr.completed && reinit.completed);
    let ratio = cr.breakdown.mpi_recovery_s / reinit.breakdown.mpi_recovery_s;
    assert!(
        ratio > 4.0,
        "paper: CR up to ~6x slower than Reinit++; got {ratio:.1}x ({} vs {})",
        cr.breakdown.mpi_recovery_s,
        reinit.breakdown.mpi_recovery_s
    );
}
