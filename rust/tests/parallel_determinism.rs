//! Determinism under parallelism: the parallel sweep scheduler must produce
//! byte-identical artifacts for any worker count. Trials are seeded,
//! independent simulations; the pool merges results in (point, trial)
//! order, so every `Summary` — and therefore every CSV byte — matches the
//! serial run exactly (acceptance criterion of the PR-2 tentpole).

use reinitpp::config::{
    AppKind, ExperimentConfig, FailureKind, Fidelity, RecoveryKind,
};
use reinitpp::harness::{run_points, write_csv};

fn quick_cfg(ranks: u32, recovery: RecoveryKind) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.app = AppKind::Hpccg;
    c.recovery = recovery;
    c.failure = FailureKind::Process;
    c.ranks = ranks;
    c.iters = 5;
    c.trials = 3;
    c.fidelity = Fidelity::Modeled;
    c.hpccg_nx = 4;
    c
}

fn small_grid() -> Vec<ExperimentConfig> {
    let mut cfgs = Vec::new();
    for ranks in [16u32, 32] {
        for rk in [RecoveryKind::Cr, RecoveryKind::Ulfm, RecoveryKind::Reinit] {
            cfgs.push(quick_cfg(ranks, rk));
        }
    }
    cfgs
}

#[test]
fn jobs1_and_jobs4_emit_identical_csv_bytes_and_summaries() {
    let grid = small_grid();
    let (p1, s1) = run_points(&grid, 1);
    let (p4, s4) = run_points(&grid, 4);
    assert_eq!(s1.jobs, 1);
    assert!(s4.jobs > 1, "grid has enough trials to use several workers");
    assert_eq!(p1.len(), p4.len());

    // Every Summary identical, field for field (f64 bitwise via PartialEq
    // on finite values produced by the same deterministic trials).
    for (a, b) in p1.iter().zip(&p4) {
        assert_eq!(a.cfg.ranks, b.cfg.ranks);
        assert_eq!(a.cfg.recovery, b.cfg.recovery);
        assert_eq!(a.total, b.total);
        assert_eq!(a.ckpt_write, b.ckpt_write);
        assert_eq!(a.ckpt_read, b.ckpt_read);
        assert_eq!(a.recovery, b.recovery);
        assert_eq!(a.app, b.app);
    }

    // And the emitted CSVs are byte-identical.
    let base = std::env::temp_dir().join("reinitpp-par-det");
    let (d1, d4) = (base.join("j1"), base.join("j4"));
    write_csv("det", d1.to_str().unwrap(), &p1).unwrap();
    write_csv("det", d4.to_str().unwrap(), &p4).unwrap();
    let b1 = std::fs::read(d1.join("det.csv")).unwrap();
    let b4 = std::fs::read(d4.join("det.csv")).unwrap();
    assert!(!b1.is_empty());
    assert_eq!(b1, b4, "CSV bytes must not depend on the worker count");
}

#[test]
fn single_point_fans_out_and_merges_in_trial_order() {
    // One expensive point with more trials than workers: trial-granular
    // fan-out must still aggregate exactly like the serial path.
    let mut cfg = quick_cfg(32, RecoveryKind::Reinit);
    cfg.trials = 8;
    let (serial, _) = run_points(std::slice::from_ref(&cfg), 1);
    let (parallel, stats) = run_points(std::slice::from_ref(&cfg), 4);
    assert_eq!(stats.trials, 8);
    assert_eq!(serial[0].total, parallel[0].total);
    assert_eq!(serial[0].recovery, parallel[0].recovery);
    assert_eq!(serial[0].total.n, 8);
}
