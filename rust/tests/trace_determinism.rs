//! Tracing is observation only — the acceptance pins for the tracing &
//! profiling layer:
//!
//! 1. A traced trial is *bit-identical* to an untraced one: executor
//!    counters (events, polls, end time), per-rank digests, the paper
//!    breakdown and the per-failure segments must not move when a recorder
//!    is armed, with or without a category filter.
//! 2. The per-trial artifacts (`trace_<id>.trace.json`, `trace_<id>.folded`,
//!    `trace_<id>.profile.json`) are written under the requested directory,
//!    keyed by the trial's identity hash, and are structurally sound.
//! 3. The synthesized recovery timeline is *exact*: per-name recovery span
//!    totals in the profile sum to the `FailureSegment` decomposition
//!    field-for-field (same saturating clock arithmetic on both sides).
//! 4. Figure CSV bytes are identical with the process-wide trace
//!    destination installed or absent (the CI smoke job re-checks this
//!    through the real binary).

use std::path::{Path, PathBuf};

use reinitpp::config::{AppKind, ExperimentConfig, Fidelity, RecoveryKind};
use reinitpp::harness::{run_points, write_csv};
use reinitpp::recovery::job::{run_trial_with, TrialResult};
use reinitpp::trace::TraceConfig;

/// Unique scratch dir per test (no tempdir dependency).
fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "reinitpp-trace-det-{}-{tag}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// A quick modeled 2-failure storm: two process kills at distinct
/// iterations so the trial exercises detect → redeploy → rollback twice.
fn storm_cfg() -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.app = AppKind::Hpccg;
    c.recovery = RecoveryKind::Reinit;
    c.ranks = 8;
    c.ranks_per_node = 4;
    c.spare_nodes = 1;
    c.iters = 8;
    c.trials = 1;
    c.fidelity = Fidelity::Modeled;
    c.hpccg_nx = 4;
    c.seed = 42;
    c.apply("failures", "proc@2:r1,proc@5:r3").unwrap();
    c
}

fn trace_into(dir: &Path, filter: Option<Vec<String>>) -> TraceConfig {
    TraceConfig {
        dir: dir.to_string_lossy().into_owned(),
        filter,
    }
}

/// Everything a trial result pins, as one comparable value.
fn fingerprint(r: &TrialResult) -> String {
    format!(
        "{:?}|{:?}|{:?}|{:?}|{}",
        r.counters, r.digests, r.breakdown, r.segments, r.sim_events
    )
}

#[test]
fn traced_trial_is_bit_identical_to_untraced() {
    let cfg = storm_cfg();
    let dir = tmp("identical");
    let off = run_trial_with(&cfg, 0, None, None);
    let on = run_trial_with(&cfg, 0, None, Some(&trace_into(&dir, None)));
    assert!(off.completed && on.completed, "storm trial hung");
    assert!(!off.segments.is_empty(), "storm must fire failures");
    assert_eq!(
        off.counters, on.counters,
        "recording moved the executor (events/polls/end time must not change)"
    );
    assert_eq!(fingerprint(&off), fingerprint(&on));

    // A category filter must not perturb results either.
    let filtered = run_trial_with(
        &cfg,
        0,
        None,
        Some(&trace_into(&dir, Some(vec!["recovery".into()]))),
    );
    assert_eq!(fingerprint(&off), fingerprint(&filtered));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn trace_artifacts_are_written_and_recovery_spans_sum_to_segments() {
    let cfg = storm_cfg();
    let dir = tmp("artifacts");
    let r = run_trial_with(&cfg, 0, None, Some(&trace_into(&dir, None)));
    assert!(r.completed);
    let id = format!("{:016x}", r.counters.identity);

    // Perfetto-loadable trace-event JSON: balanced, both pins present.
    let trace =
        std::fs::read_to_string(dir.join(format!("trace_{id}.trace.json"))).unwrap();
    assert!(trace.starts_with("{\"traceEvents\":["));
    assert!(trace.contains("\"cat\":\"recovery\""), "recovery timeline missing");
    assert!(trace.contains("\"ph\":\"X\""));
    assert_eq!(trace.matches('{').count(), trace.matches('}').count());
    assert_eq!(trace.matches('[').count(), trace.matches(']').count());

    // Folded stacks: every line is `trial;<cat>;<name> <ns>`.
    let folded = std::fs::read_to_string(dir.join(format!("trace_{id}.folded"))).unwrap();
    assert!(folded.lines().count() > 0);
    assert!(folded.lines().all(|l| l.starts_with("trial;")));
    assert!(folded.contains(";recovery;detect "));

    // Profile: identity-keyed, counters match the trial result.
    let profile =
        std::fs::read_to_string(dir.join(format!("trace_{id}.profile.json"))).unwrap();
    assert!(profile.contains(&format!("\"identity\": \"{id}\"")));
    assert!(profile.contains(&format!("\"events\": {}", r.counters.events)));
    assert!(profile.contains(&format!("\"polls\": {}", r.counters.polls)));

    // The synthesized recovery spans must reproduce the FailureSegment
    // decomposition exactly: sum the profile's recovery span totals per
    // name and compare to the segment field sums (same ns → s conversion
    // on both sides, so only summation-order rounding is tolerated).
    let span_total = |name: &str| -> f64 {
        profile
            .lines()
            .filter(|l| l.contains("\"cat\": \"recovery\""))
            .filter(|l| l.contains(&format!("\"name\": \"{name}\"")))
            .map(|l| {
                let v = l.split("\"total_s\": ").nth(1).unwrap();
                v.trim_end_matches(&[',', '}', ' '][..]).parse::<f64>().unwrap()
            })
            .sum()
    };
    let seg_sum = |f: fn(&reinitpp::metrics::FailureSegment) -> f64| -> f64 {
        r.segments.iter().map(f).sum()
    };
    let close = |a: f64, b: f64| (a - b).abs() < 1e-9;
    assert!(
        close(span_total("detect"), seg_sum(|s| s.detect_s)),
        "detect spans {} != segment detect sum {}",
        span_total("detect"),
        seg_sum(|s| s.detect_s)
    );
    assert!(
        close(
            span_total("redeploy") + span_total("shrink"),
            seg_sum(|s| s.recovery_s)
        ),
        "recovery spans {} != segment recovery sum {}",
        span_total("redeploy") + span_total("shrink"),
        seg_sum(|s| s.recovery_s)
    );
    assert!(close(span_total("rollback"), seg_sum(|s| s.rollback_s)));
    assert!(close(span_total("failover"), seg_sum(|s| s.failover_s)));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn trace_filter_limits_recorded_categories() {
    let cfg = storm_cfg();
    let dir = tmp("filter");
    let r = run_trial_with(
        &cfg,
        0,
        None,
        Some(&trace_into(&dir, Some(vec!["recovery".into()]))),
    );
    assert!(r.completed);
    let id = format!("{:016x}", r.counters.identity);
    let folded = std::fs::read_to_string(dir.join(format!("trace_{id}.folded"))).unwrap();
    assert!(folded.contains(";recovery;"));
    for cat in ["exec", "mpi", "ckpt", "pool"] {
        assert!(
            !folded.contains(&format!(";{cat};")),
            "filtered-out category {cat} leaked into the capture"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn figure_csv_bytes_identical_with_tracing_on_and_off() {
    // The ONLY test anywhere that installs the process-global trace
    // destination (the sweep pool reads tracing from there, like the CLI).
    // Safe against parallel test threads in this binary: every other test
    // passes its TraceConfig explicitly to `run_trial_with` and never
    // reads the global.
    let mut a = storm_cfg();
    a.trials = 2;
    let mut b = a.clone();
    b.recovery = RecoveryKind::Cr;
    let cfgs = [a, b];

    let off_dir = tmp("csv-off");
    let on_dir = tmp("csv-on");
    let capture_dir = tmp("csv-capture");

    let (pts_off, _) = run_points(&cfgs, 2);
    write_csv("trace_det", &off_dir.to_string_lossy(), &pts_off).unwrap();

    reinitpp::trace::set_global(Some(trace_into(&capture_dir, None)));
    let (pts_on, _) = run_points(&cfgs, 2);
    reinitpp::trace::set_global(None);
    write_csv("trace_det", &on_dir.to_string_lossy(), &pts_on).unwrap();

    let off = std::fs::read(off_dir.join("trace_det.csv")).unwrap();
    let on = std::fs::read(on_dir.join("trace_det.csv")).unwrap();
    assert_eq!(
        off, on,
        "figure CSV bytes moved when tracing was enabled — tracing must be \
         observation only"
    );
    // And the traced sweep really captured something.
    let captured = std::fs::read_dir(&capture_dir)
        .unwrap()
        .filter_map(Result::ok)
        .filter(|e| e.file_name().to_string_lossy().ends_with(".profile.json"))
        .count();
    assert!(captured >= 1, "traced sweep wrote no per-trial profiles");
    for d in [&off_dir, &on_dir, &capture_dir] {
        let _ = std::fs::remove_dir_all(d);
    }
}
