//! Integration: global-restart equivalence at FULL fidelity — every rank
//! executes the real AOT artifact via PJRT, a failure is injected, recovery
//! runs, and the final distributed state must equal the fault-free run
//! bitwise. This exercises all three layers together: Pallas-lowered HLO
//! compute, the MPI layer's deterministic collectives, and each recovery
//! protocol. Needs the `pjrt` feature + `make artifacts`; the assertions
//! below are the contract and stay unmodified.
#![cfg(feature = "pjrt")]

use std::rc::Rc;

use reinitpp::config::{AppKind, ExperimentConfig, FailureKind, Fidelity, RecoveryKind};
use reinitpp::recovery::job::run_trial;
use reinitpp::runtime::XlaRuntime;

fn rt() -> Rc<XlaRuntime> {
    Rc::new(XlaRuntime::load("artifacts").expect("run `make artifacts` first"))
}

fn cfg(app: AppKind, recovery: RecoveryKind, failure: FailureKind) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.app = app;
    c.recovery = recovery;
    c.failure = failure;
    c.ranks = 8;
    c.ranks_per_node = 4;
    c.spare_nodes = 1;
    c.iters = 5;
    c.fidelity = Fidelity::Full;
    c.comd_n = 64;
    c.hpccg_nx = 8;
    c.lulesh_nx = 8;
    c.seed = 42;
    c
}

fn equivalence(app: AppKind, recovery: RecoveryKind, failure: FailureKind) {
    let rt = rt();
    let free = run_trial(&cfg(app, recovery, FailureKind::None), 0, Some(Rc::clone(&rt)));
    assert!(free.completed, "{app}/{recovery} fault-free hung");
    let faulty = run_trial(&cfg(app, recovery, failure), 0, Some(rt));
    assert!(
        faulty.completed,
        "{app}/{recovery}/{failure} hung (fault {:?})",
        faulty.faults
    );
    assert!(faulty.breakdown.mpi_recovery_s > 0.0);
    assert_eq!(
        faulty.digests, free.digests,
        "{app}/{recovery}/{failure}: recovered state != fault-free (fault {:?})",
        faulty.faults
    );
}

#[test]
fn reinit_process_failure_full_fidelity_hpccg() {
    equivalence(AppKind::Hpccg, RecoveryKind::Reinit, FailureKind::Process);
}

#[test]
fn reinit_process_failure_full_fidelity_comd() {
    equivalence(AppKind::CoMD, RecoveryKind::Reinit, FailureKind::Process);
}

#[test]
fn reinit_process_failure_full_fidelity_lulesh() {
    equivalence(AppKind::Lulesh, RecoveryKind::Reinit, FailureKind::Process);
}

#[test]
fn cr_process_failure_full_fidelity_hpccg() {
    equivalence(AppKind::Hpccg, RecoveryKind::Cr, FailureKind::Process);
}

#[test]
fn ulfm_process_failure_full_fidelity_hpccg() {
    equivalence(AppKind::Hpccg, RecoveryKind::Ulfm, FailureKind::Process);
}

#[test]
fn reinit_node_failure_full_fidelity_hpccg() {
    equivalence(AppKind::Hpccg, RecoveryKind::Reinit, FailureKind::Node);
}

#[test]
fn hpccg_actually_converges_through_a_failure() {
    // beyond bit-equality: the distributed CG residual keeps dropping
    // across the recovery (solver-level sanity of the whole stack)
    let rt = rt();
    let mut c = cfg(AppKind::Hpccg, RecoveryKind::Reinit, FailureKind::Process);
    c.iters = 8;
    let r = run_trial(&c, 0, Some(rt));
    assert!(r.completed);
    // digests nonzero and distinct across ranks (real data, not zeros)
    assert!(r.digests.iter().all(|&d| d != 0));
    let uniq: std::collections::HashSet<u64> = r.digests.iter().copied().collect();
    assert!(uniq.len() > 4, "per-rank states should differ");
}
