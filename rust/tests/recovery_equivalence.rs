//! Integration: global-restart equivalence at FULL fidelity — every rank
//! executes the real AOT artifact via PJRT, a failure is injected, recovery
//! runs, and the final distributed state must equal the fault-free run
//! bitwise. This exercises all three layers together: Pallas-lowered HLO
//! compute, the MPI layer's deterministic collectives, and each recovery
//! protocol. Needs the `pjrt` feature + `make artifacts`; the assertions
//! below are the contract and stay unmodified.
#![cfg(feature = "pjrt")]

use std::rc::Rc;

use reinitpp::config::{AppKind, ExperimentConfig, FailureKind, Fidelity, RecoveryKind};
use reinitpp::recovery::job::run_trial;
use reinitpp::runtime::XlaRuntime;

fn rt() -> Rc<XlaRuntime> {
    Rc::new(XlaRuntime::load("artifacts").expect("run `make artifacts` first"))
}

fn cfg(app: AppKind, recovery: RecoveryKind, failure: FailureKind) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.app = app;
    c.recovery = recovery;
    c.failure = failure;
    c.ranks = 8;
    c.ranks_per_node = 4;
    c.spare_nodes = 1;
    c.iters = 5;
    c.fidelity = Fidelity::Full;
    c.comd_n = 64;
    c.hpccg_nx = 8;
    c.lulesh_nx = 8;
    c.seed = 42;
    c
}

fn equivalence(app: AppKind, recovery: RecoveryKind, failure: FailureKind) {
    let rt = rt();
    let free = run_trial(&cfg(app, recovery, FailureKind::None), 0, Some(Rc::clone(&rt)));
    assert!(free.completed, "{app}/{recovery} fault-free hung");
    let faulty = run_trial(&cfg(app, recovery, failure), 0, Some(rt));
    assert!(
        faulty.completed,
        "{app}/{recovery}/{failure} hung (fault {:?})",
        faulty.faults
    );
    assert!(faulty.breakdown.mpi_recovery_s > 0.0);
    assert_eq!(
        faulty.digests, free.digests,
        "{app}/{recovery}/{failure}: recovered state != fault-free (fault {:?})",
        faulty.faults
    );
}

#[test]
fn reinit_process_failure_full_fidelity_hpccg() {
    equivalence(AppKind::Hpccg, RecoveryKind::Reinit, FailureKind::Process);
}

#[test]
fn reinit_process_failure_full_fidelity_comd() {
    equivalence(AppKind::CoMD, RecoveryKind::Reinit, FailureKind::Process);
}

#[test]
fn reinit_process_failure_full_fidelity_lulesh() {
    equivalence(AppKind::Lulesh, RecoveryKind::Reinit, FailureKind::Process);
}

#[test]
fn cr_process_failure_full_fidelity_hpccg() {
    equivalence(AppKind::Hpccg, RecoveryKind::Cr, FailureKind::Process);
}

#[test]
fn ulfm_process_failure_full_fidelity_hpccg() {
    equivalence(AppKind::Hpccg, RecoveryKind::Ulfm, FailureKind::Process);
}

#[test]
fn reinit_node_failure_full_fidelity_hpccg() {
    equivalence(AppKind::Hpccg, RecoveryKind::Reinit, FailureKind::Node);
}

// ---- shrinking recovery: survivors continue with ZERO spare nodes.
// The halo layer (apps/halo.rs grid3 decomposition + exchange) is what
// every one of these apps shrinks through; its degenerate survivor counts
// are pinned separately by the grid3 unit tests.

fn shrink_cfg(app: AppKind, failure: FailureKind) -> ExperimentConfig {
    let mut c = cfg(app, RecoveryKind::Shrink, failure);
    c.spare_nodes = 0; // shrink's whole point: no over-provisioning
    c
}

fn shrink_equivalence(app: AppKind, failure: FailureKind) {
    let rt = rt();
    let free = run_trial(&shrink_cfg(app, FailureKind::None), 0, Some(Rc::clone(&rt)));
    assert!(free.completed, "{app}/shrink fault-free hung");
    let faulty = run_trial(&shrink_cfg(app, failure), 0, Some(rt));
    assert!(
        faulty.completed,
        "{app}/shrink/{failure} hung (fault {:?})",
        faulty.faults
    );
    assert!(faulty.breakdown.mpi_recovery_s > 0.0);
    assert!(faulty.shrinks >= 1, "failure must be absorbed by shrinking");
    assert!(
        !faulty.segments.iter().any(|s| s.degraded_redeploy),
        "{app}/shrink/{failure}: must not degrade with ranks far above min_ranks"
    );
    assert_eq!(
        faulty.digests, free.digests,
        "{app}/shrink/{failure}: shrunken-world state != fault-free (fault {:?})",
        faulty.faults
    );
}

#[test]
fn shrink_process_failure_full_fidelity_hpccg() {
    shrink_equivalence(AppKind::Hpccg, FailureKind::Process);
}

#[test]
fn shrink_process_failure_full_fidelity_comd() {
    shrink_equivalence(AppKind::CoMD, FailureKind::Process);
}

#[test]
fn shrink_process_failure_full_fidelity_lulesh() {
    shrink_equivalence(AppKind::Lulesh, FailureKind::Process);
}

#[test]
fn shrink_node_failure_full_fidelity_hpccg() {
    shrink_equivalence(AppKind::Hpccg, FailureKind::Node);
}

#[test]
fn shrink_node_failure_full_fidelity_comd() {
    shrink_equivalence(AppKind::CoMD, FailureKind::Node);
}

#[test]
fn shrink_node_failure_full_fidelity_lulesh() {
    shrink_equivalence(AppKind::Lulesh, FailureKind::Node);
}

#[test]
fn shrink_matches_cr_and_reinit_results_hpccg() {
    // same app result across families: shrink's N-k-rank continuation must
    // land on the identical final state CR and Reinit++ restore to
    let rt = rt();
    let shrink = run_trial(
        &shrink_cfg(AppKind::Hpccg, FailureKind::Process),
        0,
        Some(Rc::clone(&rt)),
    );
    let cr = run_trial(
        &cfg(AppKind::Hpccg, RecoveryKind::Cr, FailureKind::Process),
        0,
        Some(Rc::clone(&rt)),
    );
    let reinit = run_trial(
        &cfg(AppKind::Hpccg, RecoveryKind::Reinit, FailureKind::Process),
        0,
        Some(rt),
    );
    assert!(shrink.completed && cr.completed && reinit.completed);
    assert_eq!(shrink.digests, cr.digests);
    assert_eq!(shrink.digests, reinit.digests);
}

#[test]
fn shrink_storm_with_zero_spares_never_degrades_above_min_ranks() {
    // three process failures against 8 ranks with spares=0: every event
    // shrinks (8 -> 7 -> 6 -> 5, all >= min_ranks=2); the degraded_redeploy
    // path must never fire, and the result still matches fault-free
    let rt = rt();
    let mut c = shrink_cfg(AppKind::Hpccg, FailureKind::Process);
    c.iters = 8;
    c.apply("failures", "proc@2:r1,proc@4:r3,proc@6:r6").unwrap();
    let free = {
        let mut f = c.clone();
        f.failures.clear();
        f.failure = FailureKind::None;
        run_trial(&f, 0, Some(Rc::clone(&rt)))
    };
    let storm = run_trial(&c, 0, Some(rt));
    assert!(storm.completed);
    assert_eq!(storm.shrinks, 3, "every event absorbed by shrinking");
    assert!(
        !storm.segments.iter().any(|s| s.degraded_redeploy),
        "spares=0 must not degrade until ranks < min_ranks"
    );
    assert_eq!(storm.digests, free.digests);
}

#[test]
fn hpccg_actually_converges_through_a_failure() {
    // beyond bit-equality: the distributed CG residual keeps dropping
    // across the recovery (solver-level sanity of the whole stack)
    let rt = rt();
    let mut c = cfg(AppKind::Hpccg, RecoveryKind::Reinit, FailureKind::Process);
    c.iters = 8;
    let r = run_trial(&c, 0, Some(rt));
    assert!(r.completed);
    // digests nonzero and distinct across ranks (real data, not zeros)
    assert!(r.digests.iter().all(|&d| d != 0));
    let uniq: std::collections::HashSet<u64> = r.digests.iter().copied().collect();
    assert!(uniq.len() > 4, "per-rank states should differ");
}
