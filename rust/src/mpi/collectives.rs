//! Tree-based collectives over the point-to-point layer.
//!
//! Broadcast and reduce use binomial trees (log₂N rounds, N-1 messages);
//! allreduce = reduce-to-0 + broadcast, which keeps the combine order fixed
//! so f32 results are bitwise deterministic — required by the global-restart
//! equivalence tests (a recovered run must reproduce the fault-free run
//! exactly). Barrier is an empty allreduce.
//!
//! Every collective pulls a fresh tag block from the per-comm sequence
//! counter; ranks call collectives in program order, so blocks agree without
//! negotiation (MPI's context-id rule).
//!
//! Allocation discipline: tree hops decode child partials straight off the
//! wire bytes and encode outgoing partials through the per-comm scratch
//! buffer (`Comm::f32_payload`) — one copy into the `Rc` payload the fabric
//! needs anyway, no per-hop `Vec<f32>`/`Vec<u8>` churn. `allreduce`
//! accumulates into the per-comm reusable accumulator (`Comm::coll_acc`)
//! and only the broadcast root encodes a payload, so the steady-state cost
//! per rank is the result `Vec` plus one `Rc` payload
//! (`rust/tests/alloc_pin.rs` pins allocations/message at 256 ranks).

use std::rc::Rc;

use super::comm::{Comm, RecvSrc};
use super::{bytes_to_f32s, MpiError, Payload, Rank, ReduceOp};

impl Comm {
    /// Binomial-tree broadcast of `data` from `root`. Returns the payload on
    /// every rank (shared: one buffer travels the whole tree — fan-out
    /// clones the `Rc`, never the bytes).
    pub async fn bcast(&self, root: Rank, data: Vec<u8>) -> Result<Payload, MpiError> {
        let tag = self.next_coll_tag();
        let t0 = self.trace_begin();
        let out = self.bcast_tagged(root, data.into(), tag).await;
        self.trace_end("bcast", t0);
        out
    }

    async fn bcast_tagged(
        &self,
        root: Rank,
        data: Payload,
        tag: u64,
    ) -> Result<Payload, MpiError> {
        let size = self.size;
        if size <= 1 {
            return Ok(data);
        }
        let vr = (self.rank + size - root) % size; // virtual rank, root = 0
        let unvr = |v: u32| (v + root) % size;

        // Receive phase: find the bit that connects us to our parent.
        let mut buf = data;
        let mut mask = 1u32;
        while mask < size {
            if vr & mask != 0 {
                let parent = unvr(vr - mask);
                let m = self
                    .recv_inner(RecvSrc::From(parent), tag, true)
                    .await?;
                buf = m.data;
                break;
            }
            mask <<= 1;
        }
        // Send phase: fan out to children below our connecting bit.
        mask >>= 1;
        while mask > 0 {
            if vr & mask == 0 && vr + mask < size {
                self.send_payload(unvr(vr + mask), tag, Rc::clone(&buf));
            }
            mask >>= 1;
        }
        Ok(buf)
    }

    /// Binomial-tree reduction to `root`. All ranks pass equal-length f32
    /// vectors; `root` gets the elementwise reduction, others get their
    /// partial (combine order is rank-ascending at each tree join, fixed).
    pub async fn reduce(
        &self,
        root: Rank,
        data: &[f32],
        op: ReduceOp,
    ) -> Result<Vec<f32>, MpiError> {
        let tag = self.next_coll_tag();
        let t0 = self.trace_begin();
        let mut acc = data.to_vec();
        let r = self.reduce_into(root, &mut acc, op, tag).await;
        self.trace_end("reduce", t0);
        r?;
        Ok(acc)
    }

    /// The reduction protocol over a caller-owned accumulator (pre-filled
    /// with this rank's contribution). Keeping the buffer external lets
    /// `allreduce` reuse one accumulator per communicator instead of
    /// allocating a `Vec` per call.
    async fn reduce_into(
        &self,
        root: Rank,
        acc: &mut [f32],
        op: ReduceOp,
        tag: u64,
    ) -> Result<(), MpiError> {
        let size = self.size;
        if size <= 1 {
            return Ok(());
        }
        let vr = (self.rank + size - root) % size;
        let unvr = |v: u32| (v + root) % size;
        let mut mask = 1u32;
        while mask < size {
            if vr & mask == 0 {
                let child = vr | mask;
                if child < size {
                    let m = self
                        .recv_inner(RecvSrc::From(unvr(child)), tag, true)
                        .await?;
                    debug_assert_eq!(m.data.len(), acc.len() * 4);
                    // Fixed order: child-subtree value combines on the
                    // right, decoded straight off the wire bytes (no
                    // per-hop `Vec<f32>`).
                    for (a, c) in acc.iter_mut().zip(m.data.chunks_exact(4)) {
                        *a = op.apply(*a, f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
                    }
                }
            } else {
                let parent = unvr(vr & !mask);
                let payload = self.f32_payload(acc);
                self.send_payload(parent, tag, payload);
                break;
            }
            mask <<= 1;
        }
        Ok(())
    }

    /// Allreduce: reduce to rank `0` then broadcast. Deterministic combine
    /// order (see module docs). Steady-state allocations per call and rank:
    /// the result `Vec` plus at most one `Rc` payload — the accumulator is
    /// the per-comm scratch, and only the root encodes a broadcast payload
    /// (everyone else receives theirs).
    pub async fn allreduce(&self, data: &[f32], op: ReduceOp) -> Result<Vec<f32>, MpiError> {
        let rtag = self.next_coll_tag();
        let btag = self.next_coll_tag();
        let t0 = self.trace_begin();
        let r = self.allreduce_inner(data, op, rtag, btag).await;
        self.trace_end("allreduce", t0);
        r
    }

    async fn allreduce_inner(
        &self,
        data: &[f32],
        op: ReduceOp,
        rtag: u64,
        btag: u64,
    ) -> Result<Vec<f32>, MpiError> {
        let mut acc = self.coll_acc.take();
        acc.clear();
        acc.extend_from_slice(data);
        let reduced = self.reduce_into(0, &mut acc, op, rtag).await;
        let payload = match &reduced {
            // Only the broadcast root's payload carries data; other ranks'
            // input to `bcast_tagged` is overwritten by what they receive.
            Ok(()) if self.rank == 0 => self.f32_payload(&acc),
            _ => self.empty_payload(),
        };
        self.coll_acc.replace(acc); // return the scratch before awaiting again
        reduced?;
        let out = self.bcast_tagged(0, payload, btag).await?;
        Ok(bytes_to_f32s(&out))
    }

    /// Scalar convenience allreduce.
    pub async fn allreduce_scalar(&self, x: f32, op: ReduceOp) -> Result<f32, MpiError> {
        Ok(self.allreduce(&[x], op).await?[0])
    }

    /// Barrier: empty allreduce (tree down + up). Pulls the same two tag
    /// blocks as `allreduce` but records its own span name.
    pub async fn barrier(&self) -> Result<(), MpiError> {
        let rtag = self.next_coll_tag();
        let btag = self.next_coll_tag();
        let t0 = self.trace_begin();
        let r = self.allreduce_inner(&[], ReduceOp::Sum, rtag, btag).await;
        self.trace_end("barrier", t0);
        r?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Topology;
    use crate::config::Calibration;
    use crate::mpi::{FtMode, MpiJob};
    use crate::sim::{Sim, SimDuration, SimTime};
    use std::cell::RefCell;
    use std::rc::Rc;

    /// Run `body(rank, comm)` on `n` ranks; returns per-rank results.
    fn run_ranks<T: 'static + Clone, F, Fut>(n: u32, mode: FtMode, body: F) -> Vec<T>
    where
        F: Fn(u32, Rc<Comm>) -> Fut + 'static + Clone,
        Fut: std::future::Future<Output = T> + 'static,
    {
        let sim = Sim::new();
        let topo = Topology::new(n, 16, 0);
        let job = MpiJob::new(&sim, topo, mode, &Calibration::default());
        let results: Rc<RefCell<Vec<Option<T>>>> =
            Rc::new(RefCell::new(vec![None; n as usize]));
        for r in 0..n {
            let p = sim.spawn_process(format!("r{r}"));
            let job2 = job.clone();
            let res = Rc::clone(&results);
            let body = body.clone();
            let node = topo.home_node(r);
            sim.spawn(p, async move {
                let comm = Rc::new(job2.attach(r, node));
                let out = body(r, comm).await;
                res.borrow_mut()[r as usize] = Some(out);
            });
        }
        let summary = sim.run();
        assert_eq!(summary.tasks_pending, 0, "collective deadlocked");
        Rc::try_unwrap(results)
            .ok()
            .unwrap()
            .into_inner()
            .into_iter()
            .map(|o| o.expect("rank produced no result"))
            .collect()
    }

    #[test]
    fn bcast_from_rank0() {
        for n in [1u32, 2, 3, 7, 16, 33] {
            let out = run_ranks(n, FtMode::Reinit, move |r, c| async move {
                let data = if r == 0 { vec![42u8, 1] } else { vec![] };
                c.bcast(0, data).await.unwrap()
            });
            assert!(out.iter().all(|d| d.as_ref() == &[42u8, 1][..]), "n={n}");
        }
    }

    #[test]
    fn bcast_from_nonzero_root() {
        let out = run_ranks(8, FtMode::Reinit, move |r, c| async move {
            let data = if r == 5 { vec![9u8] } else { vec![] };
            c.bcast(5, data).await.unwrap()
        });
        assert!(out.iter().all(|d| d.as_ref() == &[9u8][..]));
    }

    #[test]
    fn reduce_sum_to_root() {
        for n in [1u32, 4, 5, 16] {
            let out = run_ranks(n, FtMode::Reinit, move |r, c| async move {
                c.reduce(0, &[r as f32, 1.0], ReduceOp::Sum).await.unwrap()
            });
            let expect = (0..n).map(|r| r as f32).sum::<f32>();
            assert_eq!(out[0], vec![expect, n as f32], "n={n}");
        }
    }

    #[test]
    fn allreduce_sum_min_max() {
        let n = 13u32; // non-power-of-two
        let sums = run_ranks(n, FtMode::Reinit, move |r, c| async move {
            c.allreduce(&[r as f32], ReduceOp::Sum).await.unwrap()[0]
        });
        assert!(sums.iter().all(|&s| s == 78.0), "{sums:?}");
        let mins = run_ranks(n, FtMode::Reinit, move |r, c| async move {
            c.allreduce_scalar(r as f32 - 3.0, ReduceOp::Min).await.unwrap()
        });
        assert!(mins.iter().all(|&m| m == -3.0));
        let maxs = run_ranks(n, FtMode::Reinit, move |r, c| async move {
            c.allreduce_scalar(r as f32, ReduceOp::Max).await.unwrap()
        });
        assert!(maxs.iter().all(|&m| m == 12.0));
    }

    #[test]
    fn allreduce_bitwise_deterministic() {
        // adversarial f32s where combine order matters
        let vals: Vec<f32> = (0..16)
            .map(|i| (1.0f32 + i as f32 * 0.7).powi(3) * if i % 2 == 0 { 1.0 } else { -1.0 })
            .collect();
        let run = || {
            let v = vals.clone();
            run_ranks(16, FtMode::Reinit, move |r, c| {
                let x = v[r as usize];
                async move { c.allreduce_scalar(x, ReduceOp::Sum).await.unwrap().to_bits() }
            })
        };
        let a = run();
        let b = run();
        assert_eq!(a, b);
        assert!(a.iter().all(|&x| x == a[0]), "all ranks agree bitwise");
    }

    #[test]
    fn barrier_synchronizes_virtual_time() {
        // rank i sleeps i ms then barriers; all must leave the barrier at
        // >= the slowest rank's arrival.
        let out = run_ranks(8, FtMode::Reinit, move |r, c| async move {
            let sim = sim_of(&c);
            sim.sleep(SimDuration::from_millis(r as u64)).await;
            c.barrier().await.unwrap();
            sim.now()
        });
        let slowest_arrival = SimTime::ZERO + SimDuration::from_millis(7);
        for t in out {
            assert!(t >= slowest_arrival, "{t:?}");
        }
    }

    fn sim_of(c: &Comm) -> Sim {
        c.job.inner.sim.clone()
    }

    #[test]
    fn consecutive_collectives_do_not_cross_match() {
        let out = run_ranks(4, FtMode::Reinit, move |r, c| async move {
            let a = c.allreduce_scalar(r as f32, ReduceOp::Sum).await.unwrap();
            let b = c.allreduce_scalar(1.0, ReduceOp::Sum).await.unwrap();
            let d = c
                .bcast(0, if r == 0 { vec![3] } else { vec![] })
                .await
                .unwrap();
            (a, b, d[0])
        });
        for (a, b, d) in out {
            assert_eq!((a, b, d), (6.0, 4.0, 3));
        }
    }

    #[test]
    fn ulfm_collective_fails_on_any_known_failure() {
        // 4 ranks, rank 3 dies before the collective; others get ProcFailed.
        let sim = Sim::new();
        let topo = Topology::new(4, 16, 0);
        let job = MpiJob::new(&sim, topo, FtMode::Ulfm, &Calibration::default());
        let errs: Rc<RefCell<Vec<MpiError>>> = Rc::new(RefCell::new(Vec::new()));
        for r in 0..3u32 {
            let p = sim.spawn_process(format!("r{r}"));
            let j2 = job.clone();
            let e2 = Rc::clone(&errs);
            sim.spawn(p, async move {
                let c = j2.attach(r, 0);
                let e = c.allreduce_scalar(1.0, ReduceOp::Sum).await.unwrap_err();
                e2.borrow_mut().push(e);
            });
        }
        job.notify_failure(3, SimDuration::from_millis(50));
        let s = sim.run();
        assert_eq!(s.tasks_pending, 0);
        assert_eq!(errs.borrow().len(), 3);
        for e in errs.borrow().iter() {
            assert_eq!(*e, MpiError::ProcFailed { rank: 3 });
        }
    }

    #[test]
    fn collective_message_complexity_is_linear() {
        // reduce+bcast allreduce: 2(N-1) data messages per allreduce
        let sim = Sim::new();
        let topo = Topology::new(32, 16, 0);
        let job = MpiJob::new(&sim, topo, FtMode::Reinit, &Calibration::default());
        for r in 0..32u32 {
            let p = sim.spawn_process(format!("r{r}"));
            let j2 = job.clone();
            sim.spawn(p, async move {
                let c = j2.attach(r, 0);
                c.allreduce_scalar(1.0, ReduceOp::Sum).await.unwrap();
            });
        }
        sim.run();
        let (msgs, _) = job.inner.fabric.stats();
        assert_eq!(msgs, 2 * 31, "allreduce over 32 ranks = 62 messages");
    }
}
