//! ULFM extensions: `MPI_Comm_shrink` + `MPI_Comm_agree` over survivors.
//!
//! Per the ULFM spec both operations must make progress on a *revoked*
//! communicator with known-failed members, so they use an unchecked receive
//! path that ignores the revocation flag and failure knowledge (survivors
//! only talk to survivors).
//!
//! The protocol is the classic two-phase consensus the ULFM global-restart
//! recipe needs: gather the union of locally-known failed sets up a binomial
//! tree of survivors (leader = lowest survivor rank), then broadcast the
//! agreed set down. With a single injected failure one round always
//! converges; the retry loop guards the general case.

use std::rc::Rc;

use super::comm::{Comm, RecvSrc};
use super::{bytes_to_f32s, f32s_to_bytes, MpiError, Payload, Rank};

/// Result of `shrink`: the survivor group and this rank's index in it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Shrunken {
    pub survivors: Vec<Rank>,
    pub my_index: u32,
}

fn encode_set(ranks: &[Rank]) -> Vec<u8> {
    f32s_to_bytes(&ranks.iter().map(|&r| r as f32).collect::<Vec<_>>())
}

fn decode_set(b: &[u8]) -> Vec<Rank> {
    bytes_to_f32s(b).iter().map(|&f| f as Rank).collect()
}

impl Comm {
    /// Agree on the global failed set and return the shrunken survivor
    /// group (`MPI_Comm_shrink` + the `MPI_Comm_agree` consensus in one
    /// protocol, as the ULFM global-restart recipe composes them).
    pub async fn shrink_agree(&self) -> Result<Shrunken, MpiError> {
        // Failure-detector convergence: all survivors enter with identical
        // knowledge (see `Comm::stabilize_failure_knowledge`). This quiet
        // period is part of why ULFM recovery is slower than Reinit++.
        let mut attempts = 0u32;
        loop {
            self.stabilize_failure_knowledge().await;
            let known = self.known_failed();
            let survivors: Vec<Rank> =
                (0..self.size).filter(|r| !known.contains(r)).collect();
            // Tag space derived from the (stabilized) failure knowledge —
            // NOT from the collective sequence counter: survivors are
            // interrupted at *different* operations (a halo recv vs an
            // allreduce), so their op_seq values disagree. Hashing the
            // failed set gives every survivor with the same knowledge the
            // same base without communication; survivors with *different*
            // knowledge use disjoint tags, time out, and retry after the
            // late notifications arrive.
            let mut h: u64 = 0xcbf29ce484222325;
            for r in &known {
                h = (h ^ *r as u64).wrapping_mul(0x100000001b3);
            }
            let tag_base = (1u64 << 46) | ((h & 0xffff_ffff) << 10);
            match self.agree_round(&survivors, &known, tag_base).await? {
                Some(agreed) if agreed == self.known_failed() => {
                    let my_index = survivors
                        .iter()
                        .position(|&r| r == self.rank)
                        .expect("caller is a survivor") as u32;
                    return Ok(Shrunken {
                        survivors,
                        my_index,
                    });
                }
                // timed out, or learned of more failures mid-protocol:
                // re-stabilize and retry with the updated knowledge.
                _ => {}
            }
            attempts += 1;
            if attempts > 16 {
                return Err(MpiError::Revoked); // pathological churn
            }
        }
    }

    /// One gather-union + broadcast round over the survivor tree.
    /// Returns Ok(None) if a receive timed out (peer has different failure
    /// knowledge — caller re-stabilizes and retries).
    async fn agree_round(
        &self,
        survivors: &[Rank],
        known: &[Rank],
        tag: u64,
    ) -> Result<Option<Vec<Rank>>, MpiError> {
        let timeout = crate::sim::SimDuration(self.job.inner.ulfm_stabilize.0 * 4);
        let n = survivors.len() as u32;
        let vr = survivors
            .iter()
            .position(|&r| r == self.rank)
            .expect("not a survivor") as u32;
        let mut acc: Vec<Rank> = known.to_vec();

        // Gather-union up the binomial tree (virtual root = survivor 0).
        let mut mask = 1u32;
        while mask < n {
            if vr & mask == 0 {
                let child = vr | mask;
                if child < n {
                    let Some(m) = self
                        .recv_unchecked_timeout(
                            RecvSrc::From(survivors[child as usize]),
                            tag,
                            timeout,
                        )
                        .await
                    else {
                        return Ok(None);
                    };
                    for r in decode_set(&m.data) {
                        if !acc.contains(&r) {
                            acc.push(r);
                        }
                    }
                }
            } else {
                let parent = survivors[(vr & !mask) as usize];
                self.send_payload(parent, tag, encode_set(&acc).into());
                break;
            }
            mask <<= 1;
        }
        acc.sort_unstable();

        // Broadcast the agreed set down the same tree (shared payload:
        // relayed by Rc clone, not byte copy).
        let btag = tag + 1;
        let mut buf: Payload = encode_set(&acc).into();
        let mut mask = 1u32;
        while mask < n {
            if vr & mask != 0 {
                let parent = survivors[(vr - mask) as usize];
                let Some(m) = self
                    .recv_unchecked_timeout(RecvSrc::From(parent), btag, timeout)
                    .await
                else {
                    return Ok(None);
                };
                buf = m.data;
                break;
            }
            mask <<= 1;
        }
        mask >>= 1;
        while mask > 0 {
            if vr & mask == 0 && vr + mask < n {
                self.send_payload(survivors[(vr + mask) as usize], btag, Rc::clone(&buf));
            }
            mask >>= 1;
        }
        Ok(Some(decode_set(&buf)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Topology;
    use crate::config::Calibration;
    use crate::mpi::{FtMode, MpiJob};
    use crate::sim::{Sim, SimDuration};
    use std::cell::RefCell;
    use std::rc::Rc;

    /// n ranks; `dead` never participates; everyone learns of the failure
    /// (possibly at different times), revokes, then shrinks+agrees.
    fn run_shrink(n: u32, dead: Rank) -> Vec<Shrunken> {
        let sim = Sim::new();
        let topo = Topology::new(n, 16, 0);
        let job = MpiJob::new(&sim, topo, FtMode::Ulfm, &Calibration::default());
        let out: Rc<RefCell<Vec<Shrunken>>> = Rc::new(RefCell::new(Vec::new()));
        for r in (0..n).filter(|&r| r != dead) {
            let p = sim.spawn_process(format!("r{r}"));
            let j2 = job.clone();
            let o2 = Rc::clone(&out);
            sim.spawn(p, async move {
                let c = j2.attach(r, 0);
                // the failure interrupts an application collective
                let e = c.allreduce_scalar(1.0, crate::mpi::ReduceOp::Sum).await;
                assert!(e.is_err());
                c.revoke();
                let s = c.shrink_agree().await.unwrap();
                o2.borrow_mut().push(s);
            });
        }
        job.notify_failure(dead, SimDuration::from_millis(100));
        let summary = sim.run();
        assert_eq!(summary.tasks_pending, 0, "shrink deadlocked");
        Rc::try_unwrap(out).ok().unwrap().into_inner()
    }

    #[test]
    fn all_survivors_agree_on_group() {
        for (n, dead) in [(4u32, 2u32), (8, 0), (13, 12), (16, 5)] {
            let results = run_shrink(n, dead);
            assert_eq!(results.len() as u32, n - 1, "n={n}");
            let expect: Vec<Rank> = (0..n).filter(|&r| r != dead).collect();
            for s in &results {
                assert_eq!(s.survivors, expect, "n={n} dead={dead}");
            }
            // indices form a permutation of 0..n-1
            let mut idx: Vec<u32> = results.iter().map(|s| s.my_index).collect();
            idx.sort_unstable();
            assert_eq!(idx, (0..n - 1).collect::<Vec<_>>());
        }
    }

    #[test]
    fn shrink_works_with_two_failures_known_unevenly() {
        // ranks 1 and 5 both die; notifications race with the protocol.
        let sim = Sim::new();
        let n = 8u32;
        let topo = Topology::new(n, 16, 0);
        let job = MpiJob::new(&sim, topo, FtMode::Ulfm, &Calibration::default());
        let out: Rc<RefCell<Vec<Shrunken>>> = Rc::new(RefCell::new(Vec::new()));
        for r in (0..n).filter(|&r| r != 1 && r != 5) {
            let p = sim.spawn_process(format!("r{r}"));
            let j2 = job.clone();
            let o2 = Rc::clone(&out);
            sim.spawn(p, async move {
                let c = j2.attach(r, 0);
                let _ = c.allreduce_scalar(1.0, crate::mpi::ReduceOp::Sum).await;
                c.revoke();
                // wait until this rank knows BOTH failures before shrinking:
                // mirrors the ULFM recipe of agreeing until stable. Our
                // shrink_agree retries internally; to exercise the retry we
                // enter immediately.
                let s = c.shrink_agree().await.unwrap();
                o2.borrow_mut().push(s);
            });
        }
        job.notify_failure(1, SimDuration::from_millis(60));
        job.notify_failure(5, SimDuration::from_millis(90));
        let summary = sim.run();
        assert_eq!(summary.tasks_pending, 0);
        let results = out.borrow();
        let expect: Vec<Rank> = (0..n).filter(|&r| r != 1 && r != 5).collect();
        for s in results.iter() {
            assert_eq!(s.survivors, expect);
        }
    }
}
