//! MPI-like message layer over the simulated fabric.
//!
//! Implements what the proxy applications and the four recovery families
//! need from MPI: a world communicator with point-to-point matching
//! (src, tag), binomial-tree broadcast/reduce, tree allreduce and barrier,
//! the ULFM extensions (`revoke`, failure notification, `agree`), and the
//! replication family's shadow-state mirroring transfer (`mirror_state`).
//!
//! Failure semantics per recovery mode (paper §2):
//! - **CR**: no user-level fault notification. Operations touching a dead
//!   peer simply block forever; the RTE kills the whole job.
//! - **ULFM**: the RTE (heartbeat + SIGCHLD path) broadcasts failure
//!   notifications as control messages; pending/future operations raise
//!   `MpiError::ProcFailed` / `MpiError::Revoked`, and the application
//!   drives recovery (revoke -> shrink -> agree -> spawn -> merge).
//! - **Reinit++**: ranks are never told about failures through MPI; the
//!   runtime rolls survivors back (SIGREINIT == task cancellation) and
//!   re-spawns the failed ranks, then everyone re-attaches a fresh
//!   communicator generation.
//!
//! Endpoint keys on the fabric are `(generation << 32) | rank`, so stale
//! traffic from before a roll-back can never be matched by the repaired
//! world communicator. The fabric exploits exactly this composition: its
//! routing table is a flat `Vec` indexed by the low (rank) half with a
//! generation tag per slot, so a send is an indexed load + compare — no
//! hashing on the per-message path (see `transport::fabric`).

mod collectives;
mod comm;
pub mod ulfm;

pub use comm::{Comm, RecvSrc};

use std::cell::Cell;
use std::rc::Rc;

use crate::cluster::Topology;
use crate::config::Calibration;
use crate::sim::Sim;
use crate::transport::{Fabric, NetCost};

/// MPI rank index.
pub type Rank = u32;

/// Sender id for runtime-originated control messages.
pub const SYSTEM_SRC: Rank = u32::MAX;

/// Control-plane tags (top of the tag space).
pub mod tags {
    /// RTE failure notification (ULFM mode): payload = failed rank.
    pub const CTRL_FAILURE: u64 = u64::MAX;
    /// Communicator revocation flood.
    pub const CTRL_REVOKE: u64 = u64::MAX - 1;
    /// First tag reserved for collectives (below control, above user tags).
    pub const COLLECTIVE_BASE: u64 = 1 << 48;
}

/// Fault-tolerance mode of the job (which recovery approach is active).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FtMode {
    Cr,
    Ulfm,
    Reinit,
    /// Replication: like Reinit, ranks see no MPI-level failure
    /// notification — the runtime promotes replicas and re-attaches a new
    /// generation.
    Repl,
}

/// Errors surfaced by MPI operations (ULFM semantics).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MpiError {
    /// A process involved in the operation is known to have failed.
    ProcFailed { rank: Rank },
    /// The communicator was revoked.
    Revoked,
}

impl std::fmt::Display for MpiError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MpiError::ProcFailed { rank } => write!(f, "MPI_ERR_PROC_FAILED (rank {rank})"),
            MpiError::Revoked => write!(f, "MPI_ERR_REVOKED"),
        }
    }
}

impl std::error::Error for MpiError {}

/// Shared immutable message payload. Reference-counted so collective-tree
/// fan-out (one buffer forwarded to several children) and multi-hop relays
/// clone a pointer instead of copying bytes per hop.
pub type Payload = Rc<[u8]>;

/// A message on the data plane.
#[derive(Clone, Debug)]
pub struct Msg {
    pub src: Rank,
    pub tag: u64,
    pub data: Payload,
}

pub(crate) struct JobInner {
    pub sim: Sim,
    pub fabric: Fabric<Msg>,
    pub topo: Topology,
    pub mode: FtMode,
    pub generation: Cell<u64>,
    /// Backing-process count of the *current* world. Starts at
    /// `topo.ranks`; shrinking recovery lowers it when survivors adopt a
    /// dead process's domain blocks instead of respawning. The logical
    /// rank space (and hence the fabric keying) never shrinks — only the
    /// number of OS processes carrying it.
    pub world_procs: Cell<u32>,
    /// ULFM fault-free overhead fraction per collective tree level (Fig. 5).
    pub ulfm_frac_per_level: f64,
    /// Quiet period for failure-detector convergence (one heartbeat).
    pub ulfm_stabilize: crate::sim::SimDuration,
    /// The job-wide zero-length payload: every generation's communicators
    /// share one allocation instead of allocating an empty `Rc<[u8]>` per
    /// attach (tens of thousands of attaches across a storm at scale).
    pub empty: Payload,
}

/// Shared per-job MPI state; ranks `attach` to get their `Comm`.
#[derive(Clone)]
pub struct MpiJob {
    pub(crate) inner: Rc<JobInner>,
}

impl MpiJob {
    pub fn new(sim: &Sim, topo: Topology, mode: FtMode, calib: &Calibration) -> Self {
        MpiJob {
            inner: Rc::new(JobInner {
                sim: sim.clone(),
                fabric: Fabric::new(sim, NetCost::from_calib(calib)),
                topo,
                mode,
                generation: Cell::new(0),
                world_procs: Cell::new(topo.ranks),
                ulfm_frac_per_level: calib.ulfm_overhead_frac_per_level,
                ulfm_stabilize: crate::sim::SimDuration::from_secs_f64(
                    calib.ulfm_hb_period_ms * 1e-3,
                ),
                empty: Rc::from(&[][..]),
            }),
        }
    }

    pub fn size(&self) -> u32 {
        self.inner.topo.ranks
    }

    pub fn mode(&self) -> FtMode {
        self.inner.mode
    }

    pub fn generation(&self) -> u64 {
        self.inner.generation.get()
    }

    /// Backing-process count of the current world (`== size()` until a
    /// shrink; see [`MpiJob::shrink_world`]).
    pub fn world_procs(&self) -> u32 {
        self.inner.world_procs.get()
    }

    /// Shrink the world to `procs` backing processes (ULFM
    /// `MPI_Comm_shrink` + agree over survivors). Bumps the communicator
    /// generation — exactly like a Reinit roll-back, stale traffic from
    /// the pre-shrink world can never match the repaired communicator.
    pub fn shrink_world(&self, procs: u32) -> u64 {
        assert!(
            procs >= 1 && procs <= self.inner.world_procs.get(),
            "shrink_world({procs}) from {}",
            self.inner.world_procs.get()
        );
        self.inner.world_procs.set(procs);
        self.bump_generation()
    }

    /// Start a new communicator generation (Reinit++ roll-back / ULFM
    /// repair). Ranks attached to older generations can no longer be
    /// reached — their in-flight traffic is dropped, like post-longjmp
    /// MPI state in the paper (§3.1: only the world communicator survives,
    /// rebuilt).
    pub fn bump_generation(&self) -> u64 {
        let g = self.inner.generation.get() + 1;
        self.inner.generation.set(g);
        g
    }

    pub(crate) fn key(generation: u64, rank: Rank) -> u64 {
        (generation << 32) | rank as u64
    }

    /// Data-plane traffic counters `(messages, bytes)` — perf harnesses
    /// report allocations and host time per delivered message.
    pub fn fabric_stats(&self) -> (u64, u64) {
        self.inner.fabric.stats()
    }

    /// Attach `rank` (currently placed on `node`) to the *current*
    /// generation of the world communicator. The paper's MPI_Init /
    /// post-MPI_Reinit state.
    pub fn attach(&self, rank: Rank, node: u32) -> Comm {
        Comm::attach(self.clone(), rank, node)
    }

    /// RTE-side failure notification (ULFM mode): tell every currently
    /// attached rank that `failed` died, after `delay` of detection
    /// latency (heartbeat period + propagation).
    pub fn notify_failure(&self, failed: Rank, delay: crate::sim::SimDuration) {
        let inner = Rc::clone(&self.inner);
        self.inner.sim.schedule(delay, move || {
            let generation = inner.generation.get();
            let payload: Payload = Rc::from(failed.to_le_bytes().to_vec());
            for r in 0..inner.topo.ranks {
                if r == failed {
                    continue;
                }
                let msg = Msg {
                    src: SYSTEM_SRC,
                    tag: tags::CTRL_FAILURE,
                    data: Rc::clone(&payload),
                };
                inner
                    .fabric
                    .send_from(u32::MAX, Self::key(generation, r), msg, 4);
            }
        });
    }
}

/// User-space tag for the RTE "recovery complete, re-attach" signal
/// (ULFM spawn+merge handshake).
pub const PROCEED_TAG: u64 = 1 << 47;

impl MpiJob {
    /// Transport-level state mirroring (replication mode): push `bytes` of
    /// a primary's state from `from_node` to its shadow replica on
    /// `to_node`, awaiting the transfer — replica pushes serialize on the
    /// primary's NIC, which is exactly the replication bandwidth overhead
    /// the crossover sweep measures. Counted in `fabric_stats`.
    pub async fn mirror_state(&self, from_node: u32, to_node: u32, bytes: usize) {
        let d = self.inner.fabric.charge_mirror(from_node, to_node, bytes);
        self.inner.sim.sleep(d).await;
    }

    /// RTE-originated point message to a rank of a *specific* generation
    /// (used to reach survivors still attached to a revoked communicator).
    pub fn send_system(&self, generation: u64, rank: Rank, tag: u64, data: Vec<u8>) {
        let bytes = data.len().max(1);
        let msg = Msg {
            src: SYSTEM_SRC,
            tag,
            data: data.into(),
        };
        self.inner
            .fabric
            .send_from(u32::MAX, Self::key(generation, rank), msg, bytes);
    }
}

/// Encode a f32 slice little-endian.
pub fn f32s_to_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Decode a little-endian f32 buffer.
pub fn bytes_to_f32s(b: &[u8]) -> Vec<f32> {
    assert_eq!(b.len() % 4, 0, "not a f32 buffer");
    b.chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

/// Elementwise reduction operators.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceOp {
    Sum,
    Min,
    Max,
}

impl ReduceOp {
    pub fn apply(self, a: f32, b: f32) -> f32 {
        match self {
            ReduceOp::Sum => a + b,
            ReduceOp::Min => a.min(b),
            ReduceOp::Max => a.max(b),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_codec_roundtrip() {
        let xs = vec![0.0f32, -1.5, 3.25e7, f32::MIN_POSITIVE];
        assert_eq!(bytes_to_f32s(&f32s_to_bytes(&xs)), xs);
    }

    #[test]
    #[should_panic(expected = "not a f32 buffer")]
    fn f32_codec_rejects_ragged() {
        bytes_to_f32s(&[1, 2, 3]);
    }

    #[test]
    fn reduce_ops() {
        assert_eq!(ReduceOp::Sum.apply(1.0, 2.0), 3.0);
        assert_eq!(ReduceOp::Min.apply(1.0, 2.0), 1.0);
        assert_eq!(ReduceOp::Max.apply(1.0, 2.0), 2.0);
    }

    #[test]
    fn shrink_world_lowers_procs_and_bumps_generation() {
        let sim = Sim::new();
        let topo = Topology::new(8, 4, 1);
        let job = MpiJob::new(&sim, topo, FtMode::Reinit, &Calibration::default());
        assert_eq!(job.world_procs(), 8);
        let g0 = job.generation();
        job.shrink_world(6);
        assert_eq!(job.world_procs(), 6);
        assert_eq!(job.generation(), g0 + 1, "shrink invalidates stale traffic");
        assert_eq!(job.size(), 8, "logical rank space never shrinks");
    }

    #[test]
    #[should_panic(expected = "shrink_world")]
    fn shrink_world_rejects_growth() {
        let sim = Sim::new();
        let topo = Topology::new(4, 4, 0);
        let job = MpiJob::new(&sim, topo, FtMode::Reinit, &Calibration::default());
        job.shrink_world(5);
    }

    #[test]
    fn endpoint_keys_disjoint_across_generations() {
        assert_ne!(MpiJob::key(0, 5), MpiJob::key(1, 5));
        assert_ne!(MpiJob::key(1, 0), MpiJob::key(0, 1));
    }
}
