//! Per-rank communicator handle: point-to-point with (src, tag) matching and
//! ULFM-style failure surfacing.
//!
//! Matching hot path: a freshly arrived message is compared directly
//! against the posted (src, tag) before it ever touches the out-of-order
//! buffer, so the steady state (receiver already waiting) costs one
//! compare — no queue traffic at all. Genuinely out-of-order messages land
//! in `MatchBuf`, a (src, tag)-bucketed store with recycled bucket
//! storage, so matching is O(distinct keys present) instead of O(queued
//! messages) and steady-state churn through it allocates nothing.

use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::rc::Rc;

use super::{tags, FtMode, MpiError, MpiJob, Msg, Payload, Rank};
use crate::sim::Receiver;

/// Source selector for a receive.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecvSrc {
    Any,
    From(Rank),
}

/// One (src, tag) bucket of out-of-order messages, in arrival order.
struct Bucket {
    src: Rank,
    tag: u64,
    q: VecDeque<(u64, Msg)>,
}

/// Out-of-order receive buffer with (src, tag)-bucket indexing and a
/// global arrival sequence, so `RecvSrc::Any` pops in exact arrival order
/// (FIFO per (src, tag) *and* across sources — the MPI matching rule).
/// Emptied buckets return their storage to a free pool; steady state
/// allocates nothing.
#[derive(Default)]
struct MatchBuf {
    buckets: Vec<Bucket>,
    pool: Vec<VecDeque<(u64, Msg)>>,
    next_seq: u64,
    len: usize,
}

impl MatchBuf {
    fn push(&mut self, m: Msg) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.len += 1;
        let (src, tag) = (m.src, m.tag);
        if let Some(b) = self
            .buckets
            .iter_mut()
            .find(|b| b.src == src && b.tag == tag)
        {
            b.q.push_back((seq, m));
            return;
        }
        let mut q = self.pool.pop().unwrap_or_default();
        q.push_back((seq, m));
        self.buckets.push(Bucket { src, tag, q });
    }

    /// Pop the earliest-arrived message matching `(src, tag)`, if any.
    fn take(&mut self, src: RecvSrc, tag: u64) -> Option<Msg> {
        if self.len == 0 {
            return None; // the common fast path
        }
        let idx = match src {
            RecvSrc::From(r) => self
                .buckets
                .iter()
                .position(|b| b.src == r && b.tag == tag)?,
            RecvSrc::Any => {
                // Earliest arrival across every source with this tag.
                let mut best: Option<(usize, u64)> = None;
                for (i, b) in self.buckets.iter().enumerate() {
                    if b.tag != tag {
                        continue;
                    }
                    let seq = b.q.front().expect("buckets are never empty").0;
                    if best.is_none_or(|(_, s)| seq < s) {
                        best = Some((i, seq));
                    }
                }
                best?.0
            }
        };
        let (_seq, m) = self.buckets[idx].q.pop_front().expect("non-empty bucket");
        self.len -= 1;
        if self.buckets[idx].q.is_empty() {
            // Bucket order is irrelevant (selection is by key / arrival
            // seq), so swap_remove + recycle the queue's storage.
            let b = self.buckets.swap_remove(idx);
            self.pool.push(b.q);
        }
        Some(m)
    }
}

/// A rank's handle on the world communicator (one generation).
pub struct Comm {
    pub(crate) job: MpiJob,
    pub rank: Rank,
    pub size: u32,
    pub node: u32,
    /// Backing-process count of this communicator's world, snapshotted at
    /// attach time (the shrink path bumps the generation and re-attaches,
    /// so a generation's proc count never changes under a live handle).
    world_procs: u32,
    generation: u64,
    rx: Receiver<Msg>,
    unmatched: RefCell<MatchBuf>,
    /// Sorted; failures are few, so a dense `Vec` beats hashing on the
    /// per-receive `check_failures` path.
    known_failed: RefCell<Vec<Rank>>,
    revoked: Cell<bool>,
    op_seq: Cell<u64>,
    /// Reusable f32-serialization buffer for the collective tree
    /// (reduce/allreduce partials): hops encode into this scratch and copy
    /// once into the shared payload, instead of allocating a fresh
    /// `Vec<f32>` + `Vec<u8>` per hop.
    coll_scratch: RefCell<Vec<u8>>,
    /// Reusable reduce/allreduce accumulator (see `collectives.rs`).
    pub(crate) coll_acc: RefCell<Vec<f32>>,
}

impl Comm {
    pub(crate) fn attach(job: MpiJob, rank: Rank, node: u32) -> Comm {
        let generation = job.generation();
        let rx = job
            .inner
            .fabric
            .bind(MpiJob::key(generation, rank), node);
        Comm {
            job,
            rank,
            size: 0,
            node,
            world_procs: 0,
            generation,
            rx,
            unmatched: RefCell::new(MatchBuf::default()),
            known_failed: RefCell::new(Vec::new()),
            revoked: Cell::new(false),
            op_seq: Cell::new(0),
            coll_scratch: RefCell::new(Vec::new()),
            coll_acc: RefCell::new(Vec::new()),
        }
        .finish_init()
    }

    fn finish_init(mut self) -> Comm {
        self.size = self.job.size();
        self.world_procs = self.job.world_procs();
        self
    }

    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Backing-process count of this world generation. Equal to `size`
    /// until a shrink; after one, the `size` logical ranks are carried by
    /// `world_procs < size` surviving processes (the shrink path next to
    /// the Reinit re-attach — see `MpiJob::shrink_world`).
    pub fn world_procs(&self) -> u32 {
        self.world_procs
    }

    /// Ranks this communicator knows to have failed (ULFM notification).
    pub fn known_failed(&self) -> Vec<Rank> {
        self.known_failed.borrow().clone() // kept sorted on insert
    }

    pub fn is_revoked(&self) -> bool {
        self.revoked.get()
    }

    /// ULFM compute-inflation factor for this scale (Fig. 5): the always-on
    /// heartbeat + fault-tolerant wrappers tax every compute/comm phase.
    pub fn fault_tolerance_compute_factor(&self) -> f64 {
        match self.job.mode() {
            FtMode::Ulfm => {
                1.0 + self.job.inner.ulfm_frac_per_level
                    * crate::cluster::Topology::tree_levels(self.size) as f64
            }
            _ => 1.0,
        }
    }

    /// Collective span gate: one tracer-flag load when tracing is off
    /// (`None` makes the matching [`Comm::trace_end`] a no-op).
    pub(crate) fn trace_begin(&self) -> Option<crate::sim::SimTime> {
        let sim = &self.job.inner.sim;
        sim.tracer().is_on().then(|| sim.now())
    }

    /// Close a collective span opened by [`Comm::trace_begin`] on this
    /// rank's trace track. Recording only observes — it never schedules
    /// events — so virtual time is untouched.
    pub(crate) fn trace_end(&self, name: &'static str, t0: Option<crate::sim::SimTime>) {
        if let Some(t0) = t0 {
            let sim = &self.job.inner.sim;
            sim.tracer().rank_span("mpi", name, self.rank, t0, sim.now());
        }
    }

    /// Next collective tag block (all ranks call collectives in the same
    /// order, so sequence numbers agree).
    pub(crate) fn next_coll_tag(&self) -> u64 {
        let s = self.op_seq.get();
        self.op_seq.set(s + 1);
        tags::COLLECTIVE_BASE + (s << 8)
    }

    /// Fire-and-forget send (MPI_Send with buffering semantics). Copies
    /// `data` once into a shared payload.
    pub fn send(&self, to: Rank, tag: u64, data: &[u8]) {
        self.send_payload(to, tag, Rc::from(data));
    }

    /// Serialize f32s into a shared payload through the per-comm scratch
    /// buffer: one copy into the `Rc` allocation the fabric needs anyway,
    /// no intermediate `Vec` growth in the steady state.
    pub fn f32_payload(&self, xs: &[f32]) -> Payload {
        let mut scratch = self.coll_scratch.borrow_mut();
        scratch.clear();
        scratch.extend(xs.iter().flat_map(|x| x.to_le_bytes()));
        Payload::from(&scratch[..])
    }

    /// The job-wide zero-length payload (`Rc` clone, no allocation —
    /// shared by every communicator of every generation).
    pub(crate) fn empty_payload(&self) -> Payload {
        Rc::clone(&self.job.inner.empty)
    }

    /// Zero-copy send of an already-shared payload: collective fan-out
    /// forwards one buffer to several children without copying per hop.
    pub fn send_payload(&self, to: Rank, tag: u64, data: Payload) {
        debug_assert!(tag < tags::CTRL_REVOKE);
        let bytes = data.len().max(1); // headers: empty msgs still cost latency
        let msg = Msg {
            src: self.rank,
            tag,
            data,
        };
        self.job
            .inner
            .fabric
            .send_from(self.node, MpiJob::key(self.generation, to), msg, bytes);
    }

    #[inline]
    fn matches(m: &Msg, src: RecvSrc, tag: u64) -> bool {
        m.tag == tag
            && match src {
                RecvSrc::Any => true,
                RecvSrc::From(r) => m.src == r,
            }
    }

    fn take_unmatched(&self, src: RecvSrc, tag: u64) -> Option<Msg> {
        self.unmatched.borrow_mut().take(src, tag)
    }

    fn handle_ctrl(&self, msg: &Msg) -> bool {
        match msg.tag {
            tags::CTRL_FAILURE => {
                let r = Rank::from_le_bytes([
                    msg.data[0],
                    msg.data[1],
                    msg.data[2],
                    msg.data[3],
                ]);
                let mut failed = self.known_failed.borrow_mut();
                if let Err(pos) = failed.binary_search(&r) {
                    failed.insert(pos, r); // kept sorted, deduped
                }
                true
            }
            tags::CTRL_REVOKE => {
                self.revoked.set(true);
                true
            }
            _ => false,
        }
    }

    /// Check ULFM error conditions for an operation that `involves` the
    /// given peers (None = the whole communicator).
    fn check_failures(&self, involves: Option<&[Rank]>) -> Result<(), MpiError> {
        if self.job.mode() != FtMode::Ulfm {
            return Ok(()); // CR/Reinit: no user-level notification
        }
        if self.revoked.get() {
            return Err(MpiError::Revoked);
        }
        let failed = self.known_failed.borrow();
        if failed.is_empty() {
            return Ok(());
        }
        match involves {
            None => Err(MpiError::ProcFailed { rank: failed[0] }),
            Some(peers) => {
                for p in peers {
                    if failed.binary_search(p).is_ok() {
                        return Err(MpiError::ProcFailed { rank: *p });
                    }
                }
                Ok(())
            }
        }
    }

    /// Receive matching (src, tag). `collective` ops fail on *any* known
    /// failure; point-to-point only on the involved peer.
    pub async fn recv_inner(
        &self,
        src: RecvSrc,
        tag: u64,
        collective: bool,
    ) -> Result<Msg, MpiError> {
        loop {
            let involves_buf;
            let involves: Option<&[Rank]> = if collective {
                None
            } else {
                match src {
                    RecvSrc::Any => None,
                    RecvSrc::From(r) => {
                        involves_buf = [r];
                        Some(&involves_buf)
                    }
                }
            };
            self.check_failures(involves)?;
            if let Some(m) = self.take_unmatched(src, tag) {
                self.job.inner.sim.tracer().add("mpi.recv_buffered", 1);
                return Ok(m);
            }
            // Block for the next message (control messages wake us too).
            match self.rx.recv().await {
                Ok(m) => {
                    if self.handle_ctrl(&m) {
                        continue; // loop: re-check failures
                    }
                    // Fast path: nothing queued matched (checked above) and
                    // control state is unchanged since, so a matching
                    // arrival is returned directly — the buffer is only for
                    // genuinely out-of-order traffic.
                    if Self::matches(&m, src, tag) {
                        self.job.inner.sim.tracer().add("mpi.recv_direct", 1);
                        return Ok(m);
                    }
                    self.unmatched.borrow_mut().push(m);
                }
                Err(_) => {
                    // Mailbox closed: treat as revocation (job shutting down)
                    return Err(MpiError::Revoked);
                }
            }
        }
    }

    /// Point-to-point receive.
    pub async fn recv(&self, src: RecvSrc, tag: u64) -> Result<Msg, MpiError> {
        self.recv_inner(src, tag, false).await
    }

    /// Combined send + receive (halo exchange building block).
    pub async fn sendrecv(
        &self,
        to: Rank,
        send_tag: u64,
        data: &[u8],
        from: Rank,
        recv_tag: u64,
    ) -> Result<Msg, MpiError> {
        self.send(to, send_tag, data);
        self.recv(RecvSrc::From(from), recv_tag).await
    }

    /// Unchecked receive: ignores revocation and failure knowledge (the
    /// ULFM spec requires shrink/agree to progress on revoked communicators
    /// with failed members). Returns None only if the mailbox closed.
    pub(crate) async fn recv_unchecked(&self, src: RecvSrc, tag: u64) -> Option<Msg> {
        loop {
            if let Some(m) = self.take_unmatched(src, tag) {
                return Some(m);
            }
            match self.rx.recv().await {
                Ok(m) => {
                    if self.handle_ctrl(&m) {
                        continue;
                    }
                    if Self::matches(&m, src, tag) {
                        return Some(m);
                    }
                    self.unmatched.borrow_mut().push(m);
                }
                Err(_) => return None,
            }
        }
    }

    /// `recv_unchecked` with a relative timeout. UNCHECKED like its
    /// namesake: ignores revocation and failure knowledge, and returns
    /// `None` on timeout OR closed mailbox — so `None` means "no message",
    /// never "peer failed". This is deliberate: the callers are liveness
    /// probes — shrink/agree retries (a survivor blocked on a peer with
    /// different failure knowledge must back off) and heartbeat traffic
    /// (the scale bench) — which must make progress on broken
    /// communicators. Use `recv()` for failure-surfacing receives. The
    /// deadline timer is cancel-aware, so the common early-completion case
    /// leaves no live timer behind.
    pub async fn recv_unchecked_timeout(
        &self,
        src: RecvSrc,
        tag: u64,
        timeout: crate::sim::SimDuration,
    ) -> Option<Msg> {
        let deadline = self.job.inner.sim.now() + timeout;
        loop {
            if let Some(m) = self.take_unmatched(src, tag) {
                return Some(m);
            }
            match self.rx.recv_deadline(deadline).await {
                Ok(m) => {
                    if self.handle_ctrl(&m) {
                        continue;
                    }
                    if Self::matches(&m, src, tag) {
                        return Some(m);
                    }
                    self.unmatched.borrow_mut().push(m);
                }
                Err(_) => return None, // closed or timed out
            }
        }
    }

    /// Wait until failure knowledge is quiescent for one heartbeat period
    /// (failure-detector convergence before entering shrink/agree; all
    /// survivors see RTE notifications with identical delivery delay, so a
    /// quiet period yields identical knowledge — the consistency anchor of
    /// our shrink protocol, see `ulfm.rs`).
    pub async fn stabilize_failure_knowledge(&self) {
        let quiet = self.job.inner.ulfm_stabilize;
        loop {
            let snap = self.known_failed();
            self.job.inner.sim.sleep(quiet).await;
            self.poll_ctrl();
            if self.known_failed() == snap {
                return;
            }
        }
    }

    /// ULFM `MPI_Comm_revoke`: best-effort flood to all ranks, plus local
    /// revocation. Any subsequent operation on this communicator raises
    /// `Revoked` everywhere.
    pub fn revoke(&self) {
        self.revoked.set(true);
        for r in 0..self.size {
            if r == self.rank {
                continue;
            }
            let msg = Msg {
                src: self.rank,
                tag: tags::CTRL_REVOKE,
                data: self.empty_payload(),
            };
            self.job
                .inner
                .fabric
                .send_from(self.node, MpiJob::key(self.generation, r), msg, 1);
        }
    }

    /// Drain any control messages already queued (used before testing
    /// failure knowledge without blocking).
    pub fn poll_ctrl(&self) {
        while let Some(m) = self.rx.try_recv() {
            if !self.handle_ctrl(&m) {
                self.unmatched.borrow_mut().push(m);
            }
        }
    }
}

impl Drop for Comm {
    fn drop(&mut self) {
        // Unconditional unbind + retire of this comm's (generation, rank)
        // key. INVARIANT this relies on: a rank attaches at most once per
        // generation — every recovery path bumps the generation before
        // re-attaching (reinit/ulfm) or builds a fresh fabric (CR) — so no
        // live newer binding can share our key. If a future flow ever
        // re-attaches without bumping, this drop would tear down the new
        // incarnation's endpoint; such a flow must bump the generation.
        let key = MpiJob::key(self.generation, self.rank);
        self.job.inner.fabric.unbind(key);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Topology;
    use crate::config::Calibration;
    use crate::sim::{Sim, SimDuration};
    use std::cell::Cell as StdCell;
    use std::rc::Rc;

    fn job(sim: &Sim, ranks: u32, mode: FtMode) -> MpiJob {
        MpiJob::new(
            sim,
            Topology::new(ranks, 16, 0),
            mode,
            &Calibration::default(),
        )
    }

    #[test]
    fn p2p_send_recv() {
        let sim = Sim::new();
        let j = job(&sim, 2, FtMode::Reinit);
        let ok = Rc::new(StdCell::new(false));
        let p0 = sim.spawn_process("r0");
        let p1 = sim.spawn_process("r1");
        let j0 = j.clone();
        sim.spawn(p0, async move {
            let c = j0.attach(0, 0);
            c.send(1, 7, &[1, 2, 3]);
        });
        let j1 = j.clone();
        let ok2 = Rc::clone(&ok);
        sim.spawn(p1, async move {
            let c = j1.attach(1, 0);
            let m = c.recv(RecvSrc::From(0), 7).await.unwrap();
            assert_eq!(&m.data[..], &[1, 2, 3][..]);
            assert_eq!(m.src, 0);
            ok2.set(true);
        });
        sim.run();
        assert!(ok.get());
    }

    #[test]
    fn tag_matching_out_of_order() {
        let sim = Sim::new();
        let j = job(&sim, 2, FtMode::Reinit);
        let p0 = sim.spawn_process("r0");
        let p1 = sim.spawn_process("r1");
        let j0 = j.clone();
        sim.spawn(p0, async move {
            let c = j0.attach(0, 0);
            c.send(1, 100, &[100]);
            c.send(1, 200, &[200]);
        });
        let j1 = j.clone();
        let ok = Rc::new(StdCell::new(false));
        let ok2 = Rc::clone(&ok);
        sim.spawn(p1, async move {
            let c = j1.attach(1, 0);
            // receive tag 200 first even though 100 arrives first
            let m200 = c.recv(RecvSrc::From(0), 200).await.unwrap();
            let m100 = c.recv(RecvSrc::From(0), 100).await.unwrap();
            assert_eq!((m100.data[0], m200.data[0]), (100, 200));
            ok2.set(true);
        });
        sim.run();
        assert!(ok.get());
    }

    #[test]
    fn recv_any_source() {
        let sim = Sim::new();
        let j = job(&sim, 3, FtMode::Reinit);
        for r in [1u32, 2] {
            let p = sim.spawn_process(format!("r{r}"));
            let jj = j.clone();
            sim.spawn(p, async move {
                let c = jj.attach(r, 0);
                c.send(0, 9, &[r as u8]);
            });
        }
        let p0 = sim.spawn_process("r0");
        let j0 = j.clone();
        let total = Rc::new(StdCell::new(0u8));
        let t2 = Rc::clone(&total);
        sim.spawn(p0, async move {
            let c = j0.attach(0, 0);
            let a = c.recv(RecvSrc::Any, 9).await.unwrap();
            let b = c.recv(RecvSrc::Any, 9).await.unwrap();
            t2.set(a.data[0] + b.data[0]);
        });
        sim.run();
        assert_eq!(total.get(), 3);
    }

    #[test]
    fn indexed_matching_preserves_arrival_order_under_any() {
        // Satellite regression for the (src, tag)-indexed buffer: with
        // messages from two sources interleaved in arrival order
        // a0 b0 a1 b1 a2 b2 (same tag), `RecvSrc::Any` must pop in exact
        // global arrival order, and a `From` receive must preserve
        // per-source FIFO while skipping the other source.
        let sim = Sim::new();
        let j = job(&sim, 3, FtMode::Reinit);
        for (src, base_delay_us) in [(0u32, 0u64), (2, 5)] {
            let p = sim.spawn_process(format!("r{src}"));
            let jj = j.clone();
            let s2 = sim.clone();
            sim.spawn(p, async move {
                let c = jj.attach(src, 0);
                for i in 0..3u64 {
                    s2.sleep(SimDuration::from_micros(base_delay_us + 10 * i))
                        .await;
                    c.send(1, 9, &[src as u8 * 10 + i as u8]);
                }
                // stragglers on another tag force the Any receives below
                // through the out-of-order buffer, not the direct path
                c.send(1, 7, &[99]);
            });
        }
        let p1 = sim.spawn_process("r1");
        let j1 = j.clone();
        let s1 = sim.clone();
        let got = Rc::new(RefCell::new(Vec::new()));
        let g2 = Rc::clone(&got);
        sim.spawn(p1, async move {
            let c = j1.attach(1, 0);
            // let every tag-9 message arrive and buffer first
            let _ = c.recv(RecvSrc::From(0), 7).await.unwrap();
            let _ = c.recv(RecvSrc::From(2), 7).await.unwrap();
            s1.sleep(SimDuration::from_millis(1)).await;
            c.poll_ctrl();
            let mut order = Vec::new();
            order.push(c.recv(RecvSrc::Any, 9).await.unwrap().data[0]); // a0
            order.push(c.recv(RecvSrc::Any, 9).await.unwrap().data[0]); // b0
            order.push(c.recv(RecvSrc::From(0), 9).await.unwrap().data[0]); // a1
            order.push(c.recv(RecvSrc::Any, 9).await.unwrap().data[0]); // b1
            order.push(c.recv(RecvSrc::Any, 9).await.unwrap().data[0]); // a2
            order.push(c.recv(RecvSrc::From(2), 9).await.unwrap().data[0]); // b2
            *g2.borrow_mut() = order;
        });
        let s = sim.run();
        assert_eq!(s.tasks_pending, 0);
        assert_eq!(*got.borrow(), vec![0, 20, 1, 21, 2, 22]);
    }

    #[test]
    fn ulfm_failure_notification_errors_pending_recv() {
        let sim = Sim::new();
        let j = job(&sim, 2, FtMode::Ulfm);
        let p1 = sim.spawn_process("r1");
        let j1 = j.clone();
        let got = Rc::new(StdCell::new(None));
        let g2 = Rc::clone(&got);
        sim.spawn(p1, async move {
            let c = j1.attach(1, 0);
            // rank 0 never sends: it "fails"
            let r = c.recv(RecvSrc::From(0), 7).await;
            g2.set(Some(r.unwrap_err()));
        });
        j.notify_failure(0, SimDuration::from_millis(100));
        sim.run();
        assert_eq!(got.get(), Some(MpiError::ProcFailed { rank: 0 }));
    }

    #[test]
    fn cr_mode_blocks_forever_on_dead_peer() {
        let sim = Sim::new();
        let j = job(&sim, 2, FtMode::Cr);
        let p1 = sim.spawn_process("r1");
        let j1 = j.clone();
        sim.spawn(p1, async move {
            let c = j1.attach(1, 0);
            let _ = c.recv(RecvSrc::From(0), 7).await;
            unreachable!("CR rank must hang, not error");
        });
        j.notify_failure(0, SimDuration::from_millis(100));
        let s = sim.run();
        assert_eq!(s.tasks_pending, 1, "rank 1 still blocked");
    }

    #[test]
    fn revoke_floods_and_errors_peers() {
        let sim = Sim::new();
        let j = job(&sim, 3, FtMode::Ulfm);
        let results: Rc<RefCell<Vec<MpiError>>> = Rc::new(RefCell::new(Vec::new()));
        for r in [1u32, 2] {
            let p = sim.spawn_process(format!("r{r}"));
            let jj = j.clone();
            let res = Rc::clone(&results);
            sim.spawn(p, async move {
                let c = jj.attach(r, 0);
                let e = c.recv(RecvSrc::From(0), 7).await.unwrap_err();
                res.borrow_mut().push(e);
            });
        }
        let p0 = sim.spawn_process("r0");
        let j0 = j.clone();
        let s0 = sim.clone();
        sim.spawn(p0, async move {
            let c = j0.attach(0, 0);
            s0.sleep(SimDuration::from_millis(1)).await;
            c.revoke();
        });
        sim.run();
        assert_eq!(
            *results.borrow(),
            vec![MpiError::Revoked, MpiError::Revoked]
        );
    }

    #[test]
    fn stale_generation_traffic_not_matched() {
        let sim = Sim::new();
        let j = job(&sim, 2, FtMode::Reinit);
        let p0 = sim.spawn_process("r0");
        let j0 = j.clone();
        sim.spawn(p0, async move {
            let old = j0.attach(0, 0);
            old.send(1, 7, &[9]); // sent into generation 0
        });
        // generation bumped before rank 1 attaches (post-rollback)
        let p1 = sim.spawn_process("r1");
        let j1 = j.clone();
        let s1 = sim.clone();
        let pending = Rc::new(StdCell::new(false));
        let pend2 = Rc::clone(&pending);
        sim.spawn(p1, async move {
            s1.sleep(SimDuration::from_micros(10)).await;
            j1.bump_generation();
            let c = j1.attach(1, 0);
            pend2.set(true);
            let _ = c.recv(RecvSrc::From(0), 7).await; // must never arrive
            unreachable!();
        });
        let s = sim.run();
        assert!(pending.get());
        assert_eq!(s.tasks_pending, 1, "old-generation msg must not match");
    }

    #[test]
    fn comm_snapshots_world_procs_at_attach() {
        let sim = Sim::new();
        let j = job(&sim, 8, FtMode::Reinit);
        let pre = j.attach(0, 0);
        assert_eq!(pre.world_procs(), 8);
        j.shrink_world(5);
        let post = j.attach(0, 0);
        assert_eq!(post.world_procs(), 5);
        assert_eq!(post.size, 8, "logical rank space unchanged");
        assert_eq!(pre.world_procs(), 8, "old handle keeps its snapshot");
    }

    #[test]
    fn ulfm_compute_factor_grows_with_scale() {
        let sim = Sim::new();
        let j16 = job(&sim, 16, FtMode::Ulfm);
        let j1024 = job(&sim, 1024, FtMode::Ulfm);
        let c16 = j16.attach(0, 0);
        let c1024 = j1024.attach(0, 0);
        assert!(c16.fault_tolerance_compute_factor() > 1.0);
        assert!(
            c1024.fault_tolerance_compute_factor()
                > c16.fault_tolerance_compute_factor()
        );
        let jr = job(&sim, 1024, FtMode::Reinit);
        assert_eq!(jr.attach(0, 0).fault_tolerance_compute_factor(), 1.0);
    }

    use std::cell::RefCell;
}
