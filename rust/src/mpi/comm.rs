//! Per-rank communicator handle: point-to-point with (src, tag) matching and
//! ULFM-style failure surfacing.

use std::cell::{Cell, RefCell};
use std::collections::{HashSet, VecDeque};
use std::rc::Rc;

use super::{tags, FtMode, MpiError, MpiJob, Msg, Payload, Rank};
use crate::sim::Receiver;

/// Source selector for a receive.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecvSrc {
    Any,
    From(Rank),
}

/// A rank's handle on the world communicator (one generation).
pub struct Comm {
    pub(crate) job: MpiJob,
    pub rank: Rank,
    pub size: u32,
    pub node: u32,
    generation: u64,
    rx: Receiver<Msg>,
    unmatched: RefCell<VecDeque<Msg>>,
    known_failed: RefCell<HashSet<Rank>>,
    revoked: Cell<bool>,
    op_seq: Cell<u64>,
    /// Reusable f32-serialization buffer for the collective tree
    /// (reduce/allreduce partials): hops encode into this scratch and copy
    /// once into the shared payload, instead of allocating a fresh
    /// `Vec<f32>` + `Vec<u8>` per hop.
    coll_scratch: RefCell<Vec<u8>>,
}

impl Comm {
    pub(crate) fn attach(job: MpiJob, rank: Rank, node: u32) -> Comm {
        let generation = job.generation();
        let rx = job
            .inner
            .fabric
            .bind(MpiJob::key(generation, rank), node);
        Comm {
            job,
            rank,
            size: 0,
            node,
            generation,
            rx,
            unmatched: RefCell::new(VecDeque::new()),
            known_failed: RefCell::new(HashSet::new()),
            revoked: Cell::new(false),
            op_seq: Cell::new(0),
            coll_scratch: RefCell::new(Vec::new()),
        }
        .finish_init()
    }

    fn finish_init(mut self) -> Comm {
        self.size = self.job.size();
        self
    }

    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Ranks this communicator knows to have failed (ULFM notification).
    pub fn known_failed(&self) -> Vec<Rank> {
        let mut v: Vec<Rank> = self.known_failed.borrow().iter().copied().collect();
        v.sort_unstable();
        v
    }

    pub fn is_revoked(&self) -> bool {
        self.revoked.get()
    }

    /// ULFM compute-inflation factor for this scale (Fig. 5): the always-on
    /// heartbeat + fault-tolerant wrappers tax every compute/comm phase.
    pub fn fault_tolerance_compute_factor(&self) -> f64 {
        match self.job.mode() {
            FtMode::Ulfm => {
                1.0 + self.job.inner.ulfm_frac_per_level
                    * crate::cluster::Topology::tree_levels(self.size) as f64
            }
            _ => 1.0,
        }
    }

    /// Next collective tag block (all ranks call collectives in the same
    /// order, so sequence numbers agree).
    pub(crate) fn next_coll_tag(&self) -> u64 {
        let s = self.op_seq.get();
        self.op_seq.set(s + 1);
        tags::COLLECTIVE_BASE + (s << 8)
    }

    /// Fire-and-forget send (MPI_Send with buffering semantics). Copies
    /// `data` once into a shared payload.
    pub fn send(&self, to: Rank, tag: u64, data: &[u8]) {
        self.send_payload(to, tag, Rc::from(data));
    }

    /// Serialize f32s into a shared payload through the per-comm scratch
    /// buffer: one copy into the `Rc` allocation the fabric needs anyway,
    /// no intermediate `Vec` growth in the steady state.
    pub(crate) fn f32_payload(&self, xs: &[f32]) -> Payload {
        let mut scratch = self.coll_scratch.borrow_mut();
        scratch.clear();
        scratch.extend(xs.iter().flat_map(|x| x.to_le_bytes()));
        Payload::from(&scratch[..])
    }

    /// Zero-copy send of an already-shared payload: collective fan-out
    /// forwards one buffer to several children without copying per hop.
    pub fn send_payload(&self, to: Rank, tag: u64, data: Payload) {
        debug_assert!(tag < tags::CTRL_REVOKE);
        let bytes = data.len().max(1); // headers: empty msgs still cost latency
        let msg = Msg {
            src: self.rank,
            tag,
            data,
        };
        self.job
            .inner
            .fabric
            .send_from(self.node, MpiJob::key(self.generation, to), msg, bytes);
    }

    fn take_unmatched(&self, src: RecvSrc, tag: u64) -> Option<Msg> {
        let mut q = self.unmatched.borrow_mut();
        let pos = q.iter().position(|m| {
            m.tag == tag
                && match src {
                    RecvSrc::Any => true,
                    RecvSrc::From(r) => m.src == r,
                }
        })?;
        q.remove(pos)
    }

    fn handle_ctrl(&self, msg: &Msg) -> bool {
        match msg.tag {
            tags::CTRL_FAILURE => {
                let r = Rank::from_le_bytes([
                    msg.data[0],
                    msg.data[1],
                    msg.data[2],
                    msg.data[3],
                ]);
                self.known_failed.borrow_mut().insert(r);
                true
            }
            tags::CTRL_REVOKE => {
                self.revoked.set(true);
                true
            }
            _ => false,
        }
    }

    /// Check ULFM error conditions for an operation that `involves` the
    /// given peers (None = the whole communicator).
    fn check_failures(&self, involves: Option<&[Rank]>) -> Result<(), MpiError> {
        if self.job.mode() != FtMode::Ulfm {
            return Ok(()); // CR/Reinit: no user-level notification
        }
        if self.revoked.get() {
            return Err(MpiError::Revoked);
        }
        let failed = self.known_failed.borrow();
        if failed.is_empty() {
            return Ok(());
        }
        match involves {
            None => {
                let r = *failed.iter().min().unwrap();
                Err(MpiError::ProcFailed { rank: r })
            }
            Some(peers) => {
                for p in peers {
                    if failed.contains(p) {
                        return Err(MpiError::ProcFailed { rank: *p });
                    }
                }
                Ok(())
            }
        }
    }

    /// Receive matching (src, tag). `collective` ops fail on *any* known
    /// failure; point-to-point only on the involved peer.
    pub async fn recv_inner(
        &self,
        src: RecvSrc,
        tag: u64,
        collective: bool,
    ) -> Result<Msg, MpiError> {
        loop {
            let involves_buf;
            let involves: Option<&[Rank]> = if collective {
                None
            } else {
                match src {
                    RecvSrc::Any => None,
                    RecvSrc::From(r) => {
                        involves_buf = [r];
                        Some(&involves_buf)
                    }
                }
            };
            self.check_failures(involves)?;
            if let Some(m) = self.take_unmatched(src, tag) {
                return Ok(m);
            }
            // Block for the next message (control messages wake us too).
            match self.rx.recv().await {
                Ok(m) => {
                    if !self.handle_ctrl(&m) {
                        self.unmatched.borrow_mut().push_back(m);
                    }
                    // loop: re-check failures + matching
                }
                Err(_) => {
                    // Mailbox closed: treat as revocation (job shutting down)
                    return Err(MpiError::Revoked);
                }
            }
        }
    }

    /// Point-to-point receive.
    pub async fn recv(&self, src: RecvSrc, tag: u64) -> Result<Msg, MpiError> {
        self.recv_inner(src, tag, false).await
    }

    /// Combined send + receive (halo exchange building block).
    pub async fn sendrecv(
        &self,
        to: Rank,
        send_tag: u64,
        data: &[u8],
        from: Rank,
        recv_tag: u64,
    ) -> Result<Msg, MpiError> {
        self.send(to, send_tag, data);
        self.recv(RecvSrc::From(from), recv_tag).await
    }

    /// Unchecked receive: ignores revocation and failure knowledge (the
    /// ULFM spec requires shrink/agree to progress on revoked communicators
    /// with failed members). Returns None only if the mailbox closed.
    pub(crate) async fn recv_unchecked(&self, src: RecvSrc, tag: u64) -> Option<Msg> {
        loop {
            if let Some(m) = self.take_unmatched(src, tag) {
                return Some(m);
            }
            match self.rx.recv().await {
                Ok(m) => {
                    if !self.handle_ctrl(&m) {
                        self.unmatched.borrow_mut().push_back(m);
                    }
                }
                Err(_) => return None,
            }
        }
    }

    /// `recv_unchecked` with a relative timeout (shrink/agree liveness: a
    /// survivor blocked on a peer that moved to different failure knowledge
    /// must be able to back off and retry).
    pub(crate) async fn recv_unchecked_timeout(
        &self,
        src: RecvSrc,
        tag: u64,
        timeout: crate::sim::SimDuration,
    ) -> Option<Msg> {
        let deadline = self.job.inner.sim.now() + timeout;
        loop {
            if let Some(m) = self.take_unmatched(src, tag) {
                return Some(m);
            }
            match self.rx.recv_deadline(deadline).await {
                Ok(m) => {
                    if !self.handle_ctrl(&m) {
                        self.unmatched.borrow_mut().push_back(m);
                    }
                }
                Err(_) => return None, // closed or timed out
            }
        }
    }

    /// Wait until failure knowledge is quiescent for one heartbeat period
    /// (failure-detector convergence before entering shrink/agree; all
    /// survivors see RTE notifications with identical delivery delay, so a
    /// quiet period yields identical knowledge — the consistency anchor of
    /// our shrink protocol, see `ulfm.rs`).
    pub async fn stabilize_failure_knowledge(&self) {
        let quiet = self.job.inner.ulfm_stabilize;
        loop {
            let snap = self.known_failed();
            self.job.inner.sim.sleep(quiet).await;
            self.poll_ctrl();
            if self.known_failed() == snap {
                return;
            }
        }
    }

    /// ULFM `MPI_Comm_revoke`: best-effort flood to all ranks, plus local
    /// revocation. Any subsequent operation on this communicator raises
    /// `Revoked` everywhere.
    pub fn revoke(&self) {
        self.revoked.set(true);
        let empty: Payload = Rc::from(Vec::new());
        for r in 0..self.size {
            if r == self.rank {
                continue;
            }
            let msg = Msg {
                src: self.rank,
                tag: tags::CTRL_REVOKE,
                data: Rc::clone(&empty),
            };
            self.job
                .inner
                .fabric
                .send_from(self.node, MpiJob::key(self.generation, r), msg, 1);
        }
    }

    /// Drain any control messages already queued (used before testing
    /// failure knowledge without blocking).
    pub fn poll_ctrl(&self) {
        while let Some(m) = self.rx.try_recv() {
            if !self.handle_ctrl(&m) {
                self.unmatched.borrow_mut().push_back(m);
            }
        }
    }
}

impl Drop for Comm {
    fn drop(&mut self) {
        // Unconditional unbind + retire of this comm's (generation, rank)
        // key. INVARIANT this relies on: a rank attaches at most once per
        // generation — every recovery path bumps the generation before
        // re-attaching (reinit/ulfm) or builds a fresh fabric (CR) — so no
        // live newer binding can share our key. If a future flow ever
        // re-attaches without bumping, this drop would tear down the new
        // incarnation's endpoint; such a flow must bump the generation.
        let key = MpiJob::key(self.generation, self.rank);
        self.job.inner.fabric.unbind(key);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Topology;
    use crate::config::Calibration;
    use crate::sim::{Sim, SimDuration};
    use std::cell::Cell as StdCell;
    use std::rc::Rc;

    fn job(sim: &Sim, ranks: u32, mode: FtMode) -> MpiJob {
        MpiJob::new(
            sim,
            Topology::new(ranks, 16, 0),
            mode,
            &Calibration::default(),
        )
    }

    #[test]
    fn p2p_send_recv() {
        let sim = Sim::new();
        let j = job(&sim, 2, FtMode::Reinit);
        let ok = Rc::new(StdCell::new(false));
        let p0 = sim.spawn_process("r0");
        let p1 = sim.spawn_process("r1");
        let j0 = j.clone();
        sim.spawn(p0, async move {
            let c = j0.attach(0, 0);
            c.send(1, 7, &[1, 2, 3]);
        });
        let j1 = j.clone();
        let ok2 = Rc::clone(&ok);
        sim.spawn(p1, async move {
            let c = j1.attach(1, 0);
            let m = c.recv(RecvSrc::From(0), 7).await.unwrap();
            assert_eq!(&m.data[..], &[1, 2, 3][..]);
            assert_eq!(m.src, 0);
            ok2.set(true);
        });
        sim.run();
        assert!(ok.get());
    }

    #[test]
    fn tag_matching_out_of_order() {
        let sim = Sim::new();
        let j = job(&sim, 2, FtMode::Reinit);
        let p0 = sim.spawn_process("r0");
        let p1 = sim.spawn_process("r1");
        let j0 = j.clone();
        sim.spawn(p0, async move {
            let c = j0.attach(0, 0);
            c.send(1, 100, &[100]);
            c.send(1, 200, &[200]);
        });
        let j1 = j.clone();
        let ok = Rc::new(StdCell::new(false));
        let ok2 = Rc::clone(&ok);
        sim.spawn(p1, async move {
            let c = j1.attach(1, 0);
            // receive tag 200 first even though 100 arrives first
            let m200 = c.recv(RecvSrc::From(0), 200).await.unwrap();
            let m100 = c.recv(RecvSrc::From(0), 100).await.unwrap();
            assert_eq!((m100.data[0], m200.data[0]), (100, 200));
            ok2.set(true);
        });
        sim.run();
        assert!(ok.get());
    }

    #[test]
    fn recv_any_source() {
        let sim = Sim::new();
        let j = job(&sim, 3, FtMode::Reinit);
        for r in [1u32, 2] {
            let p = sim.spawn_process(format!("r{r}"));
            let jj = j.clone();
            sim.spawn(p, async move {
                let c = jj.attach(r, 0);
                c.send(0, 9, &[r as u8]);
            });
        }
        let p0 = sim.spawn_process("r0");
        let j0 = j.clone();
        let total = Rc::new(StdCell::new(0u8));
        let t2 = Rc::clone(&total);
        sim.spawn(p0, async move {
            let c = j0.attach(0, 0);
            let a = c.recv(RecvSrc::Any, 9).await.unwrap();
            let b = c.recv(RecvSrc::Any, 9).await.unwrap();
            t2.set(a.data[0] + b.data[0]);
        });
        sim.run();
        assert_eq!(total.get(), 3);
    }

    #[test]
    fn ulfm_failure_notification_errors_pending_recv() {
        let sim = Sim::new();
        let j = job(&sim, 2, FtMode::Ulfm);
        let p1 = sim.spawn_process("r1");
        let j1 = j.clone();
        let got = Rc::new(StdCell::new(None));
        let g2 = Rc::clone(&got);
        sim.spawn(p1, async move {
            let c = j1.attach(1, 0);
            // rank 0 never sends: it "fails"
            let r = c.recv(RecvSrc::From(0), 7).await;
            g2.set(Some(r.unwrap_err()));
        });
        j.notify_failure(0, SimDuration::from_millis(100));
        sim.run();
        assert_eq!(got.get(), Some(MpiError::ProcFailed { rank: 0 }));
    }

    #[test]
    fn cr_mode_blocks_forever_on_dead_peer() {
        let sim = Sim::new();
        let j = job(&sim, 2, FtMode::Cr);
        let p1 = sim.spawn_process("r1");
        let j1 = j.clone();
        sim.spawn(p1, async move {
            let c = j1.attach(1, 0);
            let _ = c.recv(RecvSrc::From(0), 7).await;
            unreachable!("CR rank must hang, not error");
        });
        j.notify_failure(0, SimDuration::from_millis(100));
        let s = sim.run();
        assert_eq!(s.tasks_pending, 1, "rank 1 still blocked");
    }

    #[test]
    fn revoke_floods_and_errors_peers() {
        let sim = Sim::new();
        let j = job(&sim, 3, FtMode::Ulfm);
        let results: Rc<RefCell<Vec<MpiError>>> = Rc::new(RefCell::new(Vec::new()));
        for r in [1u32, 2] {
            let p = sim.spawn_process(format!("r{r}"));
            let jj = j.clone();
            let res = Rc::clone(&results);
            sim.spawn(p, async move {
                let c = jj.attach(r, 0);
                let e = c.recv(RecvSrc::From(0), 7).await.unwrap_err();
                res.borrow_mut().push(e);
            });
        }
        let p0 = sim.spawn_process("r0");
        let j0 = j.clone();
        let s0 = sim.clone();
        sim.spawn(p0, async move {
            let c = j0.attach(0, 0);
            s0.sleep(SimDuration::from_millis(1)).await;
            c.revoke();
        });
        sim.run();
        assert_eq!(
            *results.borrow(),
            vec![MpiError::Revoked, MpiError::Revoked]
        );
    }

    #[test]
    fn stale_generation_traffic_not_matched() {
        let sim = Sim::new();
        let j = job(&sim, 2, FtMode::Reinit);
        let p0 = sim.spawn_process("r0");
        let j0 = j.clone();
        sim.spawn(p0, async move {
            let old = j0.attach(0, 0);
            old.send(1, 7, &[9]); // sent into generation 0
        });
        // generation bumped before rank 1 attaches (post-rollback)
        let p1 = sim.spawn_process("r1");
        let j1 = j.clone();
        let s1 = sim.clone();
        let pending = Rc::new(StdCell::new(false));
        let pend2 = Rc::clone(&pending);
        sim.spawn(p1, async move {
            s1.sleep(SimDuration::from_micros(10)).await;
            j1.bump_generation();
            let c = j1.attach(1, 0);
            pend2.set(true);
            let _ = c.recv(RecvSrc::From(0), 7).await; // must never arrive
            unreachable!();
        });
        let s = sim.run();
        assert!(pending.get());
        assert_eq!(s.tasks_pending, 1, "old-generation msg must not match");
    }

    #[test]
    fn ulfm_compute_factor_grows_with_scale() {
        let sim = Sim::new();
        let j16 = job(&sim, 16, FtMode::Ulfm);
        let j1024 = job(&sim, 1024, FtMode::Ulfm);
        let c16 = j16.attach(0, 0);
        let c1024 = j1024.attach(0, 0);
        assert!(c16.fault_tolerance_compute_factor() > 1.0);
        assert!(
            c1024.fault_tolerance_compute_factor()
                > c16.fault_tolerance_compute_factor()
        );
        let jr = job(&sim, 1024, FtMode::Reinit);
        assert_eq!(jr.attach(0, 0).fault_tolerance_compute_factor(), 1.0);
    }

    use std::cell::RefCell;
}
