//! # reinitpp — a reproduction of "Reinit++: Evaluating the Performance of
//! Global-Restart Recovery Methods for MPI Fault Tolerance" (Georgakoudis,
//! Guo, Laguna; 2021).
//!
//! The crate implements the paper's full experimental system on a
//! **virtual-time simulated cluster**: an Open-MPI-like runtime (root/HNP,
//! per-node daemons, MPI rank processes), three global-restart recovery
//! approaches (Checkpoint-Restart re-deploy, ULFM, Reinit++), multi-tier
//! checkpoint storage (Lustre-model files, local memory, node-disjoint
//! partner replicas, async drain), fault injection/detection, and
//! the three weak-scaled proxy applications (CoMD, HPCCG, LULESH) whose
//! per-rank compute executes real AOT-compiled XLA artifacts via PJRT.
//!
//! Layering (see DESIGN.md):
//! - `log`        — leveled stderr progress logging (`-v` / `--quiet`)
//! - `sim`        — deterministic single-threaded virtual-time async executor
//! - `trace`      — virtual-time tracing/profiling (Perfetto export, profiles)
//! - `transport`  — message cost model + typed mailbox channels
//! - `cluster`    — node/daemon/root topology & deployment cost model
//! - `fs`         — shared-bandwidth parallel-filesystem (Lustre) model
//! - `mpi`        — communicators, point-to-point, collectives, ULFM ext.
//! - `fault`      — fault injection plans
//! - `detect`     — child-exit / channel-break / heartbeat failure detection
//! - `ckptstore`  — multi-tier checkpoint storage (local / partner / fs)
//! - `checkpoint` — checkpoint policy (Table 2) over the tier stacks
//! - `recovery`   — CR, ULFM, Reinit++ global-restart implementations
//! - `runtime`    — PJRT client wrapper: load/compile/execute HLO artifacts
//! - `apps`       — proxy applications + pure-Rust numeric oracle
//! - `metrics`    — phase-time breakdown, t-distribution CIs, table emit
//! - `config`     — TOML-subset config system + presets (Table 1)
//! - `harness`    — per-figure experiment drivers (Figures 4-7, Tables 1-2)
//! - `testkit`    — seeded property-testing micro-framework
//! - `cli`        — argument parsing for the `reinitpp` binary

pub mod log;
pub mod sim;
pub mod trace;
pub mod transport;
pub mod cluster;
pub mod fs;
pub mod mpi;
pub mod fault;
pub mod detect;
pub mod ckptstore;
pub mod checkpoint;
pub mod recovery;
pub mod runtime;
pub mod apps;
pub mod metrics;
pub mod config;
pub mod harness;
pub mod testkit;
pub mod cli;
