//! Leveled progress logging for the harness and CLI.
//!
//! Product output (markdown tables, CSVs) goes to stdout; progress and
//! diagnostics go to stderr. This module puts the stderr side behind one
//! process-wide level so `-v`/`--verbose` and `-q`/`--quiet` work uniformly
//! across every subcommand: sweep heartbeats and "sweep done" throughput
//! lines print at [`Level::Info`] (the default), extra detail at
//! [`Level::Verbose`], and `warnln!` always prints (a degrade or a failed
//! artifact write matters even under `--quiet`).
//!
//! The flags are extracted from argv *before* command parsing
//! ([`extract_flags`] in `main`), so the per-subcommand parsers never see
//! them and need no per-command plumbing.

use std::sync::atomic::{AtomicU8, Ordering};

/// Verbosity of stderr progress output.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// `--quiet`: product output and warnings only.
    Quiet = 0,
    /// Default: progress heartbeats + sweep throughput summaries.
    Info = 1,
    /// `-v`: per-step detail (trace/profile file paths, pool internals).
    Verbose = 2,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the process-wide log level.
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Current process-wide log level.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Quiet,
        2 => Level::Verbose,
        _ => Level::Info,
    }
}

/// Would a message at `l` print right now?
#[inline]
pub fn enabled(l: Level) -> bool {
    LEVEL.load(Ordering::Relaxed) >= l as u8
}

/// Strip the verbosity flags out of `args`, returning the level they select
/// (`None` = no flag present, keep the default). The last flag wins, like
/// most CLIs treat repeated `-v`/`-q`.
pub fn extract_flags(args: &mut Vec<String>) -> Option<Level> {
    let mut lvl = None;
    args.retain(|a| match a.as_str() {
        "-v" | "--verbose" => {
            lvl = Some(Level::Verbose);
            false
        }
        "-q" | "--quiet" => {
            lvl = Some(Level::Quiet);
            false
        }
        _ => true,
    });
    lvl
}

/// Progress output (stderr), shown at the default level and above.
#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        if $crate::log::enabled($crate::log::Level::Info) {
            eprintln!($($arg)*);
        }
    };
}

/// Detail output (stderr), shown only under `-v`.
#[macro_export]
macro_rules! vlog {
    ($($arg:tt)*) => {
        if $crate::log::enabled($crate::log::Level::Verbose) {
            eprintln!($($arg)*);
        }
    };
}

/// Warning output (stderr): always printed, `warning:`-prefixed, so
/// degrades and failed artifact writes survive `--quiet`.
#[macro_export]
macro_rules! warnln {
    ($($arg:tt)*) => {
        eprintln!("warning: {}", format_args!($($arg)*));
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn extract_pulls_flags_and_leaves_the_rest() {
        let mut args = sv(&["storm", "-v", "--max-ranks", "64", "trials=2"]);
        assert_eq!(extract_flags(&mut args), Some(Level::Verbose));
        assert_eq!(args, sv(&["storm", "--max-ranks", "64", "trials=2"]));

        let mut args = sv(&["run", "--quiet", "ranks=16"]);
        assert_eq!(extract_flags(&mut args), Some(Level::Quiet));
        assert_eq!(args, sv(&["run", "ranks=16"]));
    }

    #[test]
    fn extract_without_flags_is_none() {
        let mut args = sv(&["tiers", "--jobs", "2"]);
        assert_eq!(extract_flags(&mut args), None);
        assert_eq!(args.len(), 3);
    }

    #[test]
    fn last_flag_wins() {
        let mut args = sv(&["-v", "run", "-q"]);
        assert_eq!(extract_flags(&mut args), Some(Level::Quiet));
        assert_eq!(args, sv(&["run"]));
    }

    #[test]
    fn levels_order() {
        assert!(Level::Verbose > Level::Info);
        assert!(Level::Info > Level::Quiet);
    }
}
