//! Seeded property-testing micro-framework (the offline build has no
//! proptest). Generates many random cases from a deterministic seed and, on
//! failure, reports the seed + case index so the exact case replays.

use crate::sim::rng::Rng;

/// Run `cases` random checks. `gen` draws a case from the RNG; `prop`
/// returns Err(description) on violation. Panics with a replayable id.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    seed: u64,
    cases: u32,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for i in 0..cases {
        let mut rng = Rng::new(seed).fork(name).fork(&format!("case{i}"));
        let case = gen(&mut rng);
        if let Err(msg) = prop(&case) {
            panic!(
                "property `{name}` failed (seed={seed}, case={i}):\n  case: {case:?}\n  {msg}"
            );
        }
    }
}

/// Convenience: property over a u64 range.
pub fn check_range(
    name: &str,
    seed: u64,
    cases: u32,
    lo: u64,
    hi: u64,
    mut prop: impl FnMut(u64) -> Result<(), String>,
) {
    check(
        name,
        seed,
        cases,
        |rng| lo + rng.gen_range(hi - lo),
        |&v| prop(v),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(
            "count",
            1,
            50,
            |rng| rng.gen_range(100),
            |_| {
                count += 1;
                Ok(())
            },
        );
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn failing_property_panics_with_context() {
        check("always-fails", 2, 10, |rng| rng.gen_range(5), |_| {
            Err("nope".into())
        });
    }

    #[test]
    fn cases_are_deterministic() {
        let mut a = Vec::new();
        let mut b = Vec::new();
        check("det", 3, 20, |r| r.gen_range(1000), |&v| {
            a.push(v);
            Ok(())
        });
        check("det", 3, 20, |r| r.gen_range(1000), |&v| {
            b.push(v);
            Ok(())
        });
        assert_eq!(a, b);
    }
}
