//! `reinitpp` — leader entrypoint: CLI over the experiment harness.

use reinitpp::cli;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match cli::parse(&args) {
        Ok(cmd) => cli::execute(cmd),
        Err(e) => {
            eprintln!("error: {e}\n\n{}", cli::USAGE);
            2
        }
    };
    std::process::exit(code);
}
