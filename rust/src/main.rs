//! `reinitpp` — leader entrypoint: CLI over the experiment harness.

use reinitpp::cli;

fn main() {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    // Global verbosity flags are position-independent and stripped before
    // command parsing (see `reinitpp::log`).
    if let Some(lvl) = reinitpp::log::extract_flags(&mut args) {
        reinitpp::log::set_level(lvl);
    }
    let code = match cli::parse(&args) {
        Ok(cmd) => cli::execute(cmd),
        Err(e) => {
            eprintln!("error: {e}\n\n{}", cli::USAGE);
            2
        }
    };
    std::process::exit(code);
}
