//! Pure-Rust reference implementations of the L2 compute graphs.
//!
//! Third leg of the correctness triangle: Pallas kernels are checked against
//! `ref.py` (pytest), and the PJRT-executed artifacts are checked against
//! *these* (rust integration tests), closing Python->HLO->Rust.
//!
//! Formulas mirror `python/compile/model.py` / `kernels/ref.py` exactly
//! (same constants, same update order). f32 accumulation order may differ
//! from XLA's, so cross-backend comparisons use small tolerances; *within*
//! a backend results are bitwise deterministic, which is what the
//! global-restart equivalence tests rely on.

use crate::runtime::ArrayF32;

// LJ constants (= kernels/ref.py)
pub const LJ_EPS: f32 = 1.0;
pub const LJ_SIGMA: f32 = 1.0;
pub const LJ_CUTOFF: f32 = 2.5;

// Hydro constants (= kernels/ref.py)
pub const HYDRO_GAMMA: f32 = 1.4;
pub const HYDRO_QCOEF: f32 = 2.0;
pub const HYDRO_CFL: f32 = 0.4;
pub const HYDRO_DX: f32 = 1.0;
pub const HYDRO_SS_FLOOR: f32 = 1e-6;

/// LJ 12-6 forces with minimum-image PBC + cutoff. Returns (forces, pe).
pub fn lj_forces(pos: &[f32], n: usize, boxl: f32) -> (Vec<f32>, f32) {
    let mut frc = vec![0.0f32; n * 3];
    let mut pe = 0.0f32;
    let rc2 = LJ_CUTOFF * LJ_CUTOFF;
    for i in 0..n {
        for j in 0..n {
            if i == j {
                continue;
            }
            let mut r = [0.0f32; 3];
            let mut r2 = 0.0f32;
            for d in 0..3 {
                let mut x = pos[i * 3 + d] - pos[j * 3 + d];
                x -= boxl * (x / boxl).round();
                r[d] = x;
                r2 += x * x;
            }
            if r2 >= rc2 || r2 == 0.0 {
                continue;
            }
            let s2 = (LJ_SIGMA * LJ_SIGMA) / r2;
            let s6 = s2 * s2 * s2;
            let s12 = s6 * s6;
            let fmag = 24.0 * LJ_EPS * (2.0 * s12 - s6) / r2;
            for d in 0..3 {
                frc[i * 3 + d] += fmag * r[d];
            }
            pe += 0.5 * 4.0 * LJ_EPS * (s12 - s6);
        }
    }
    (frc, pe)
}

/// One velocity-Verlet step (mass = 1): model.comd_step.
/// Inputs: pos/vel/frc (n*3), dt, box. Outputs (pos', vel', frc', ke, pe).
pub fn comd_step(
    pos: &[f32],
    vel: &[f32],
    frc: &[f32],
    n: usize,
    dt: f32,
    boxl: f32,
) -> (Vec<f32>, Vec<f32>, Vec<f32>, f32, f32) {
    let mut pos2 = vec![0.0f32; n * 3];
    let mut vh = vec![0.0f32; n * 3];
    for k in 0..n * 3 {
        vh[k] = vel[k] + 0.5 * dt * frc[k];
        let x = pos[k] + dt * vh[k];
        pos2[k] = x - boxl * (x / boxl).floor();
    }
    let (frc2, pe) = lj_forces(&pos2, n, boxl);
    let mut vel2 = vec![0.0f32; n * 3];
    let mut ke = 0.0f32;
    for k in 0..n * 3 {
        vel2[k] = vh[k] + 0.5 * dt * frc2[k];
        ke += 0.5 * vel2[k] * vel2[k];
    }
    (pos2, vel2, frc2, ke, pe)
}

#[inline]
fn idx(_nx: usize, ny: usize, _nz: usize, x: usize, y: usize, z: usize) -> usize {
    // row-major (x, y, z) with z fastest — matches numpy C order for
    // shape (nx, ny, nz)
    (x * ny + y) * _nz + z
}

/// 27-point stencil SpMV over a halo-extended field: kernels/ref.py
/// `stencil27_ref`. Input (nx+2, ny+2, nz+2) -> output (nx, ny, nz).
pub fn stencil27(p_halo: &[f32], nx: usize, ny: usize, nz: usize) -> Vec<f32> {
    let (hx, hy, hz) = (nx + 2, ny + 2, nz + 2);
    assert_eq!(p_halo.len(), hx * hy * hz);
    let mut ap = vec![0.0f32; nx * ny * nz];
    for x in 0..nx {
        for y in 0..ny {
            for z in 0..nz {
                let mut acc = 0.0f32;
                for dx in 0..3usize {
                    for dy in 0..3usize {
                        for dz in 0..3usize {
                            acc += p_halo[idx(hx, hy, hz, x + dx, y + dy, z + dz)];
                        }
                    }
                }
                let c = p_halo[idx(hx, hy, hz, x + 1, y + 1, z + 1)];
                ap[idx(nx, ny, nz, x, y, z)] = 28.0 * c - acc;
            }
        }
    }
    ap
}

/// model.hpccg_matvec: (Ap, local p.Ap).
pub fn hpccg_matvec(p_halo: &[f32], nx: usize) -> (Vec<f32>, f32) {
    let ap = stencil27(p_halo, nx, nx, nx);
    let (hx, hy, hz) = (nx + 2, nx + 2, nx + 2);
    let mut pap = 0.0f32;
    for x in 0..nx {
        for y in 0..nx {
            for z in 0..nx {
                pap += p_halo[idx(hx, hy, hz, x + 1, y + 1, z + 1)]
                    * ap[idx(nx, nx, nx, x, y, z)];
            }
        }
    }
    (ap, pap)
}

/// model.hpccg_update: (x', r', local r'.r').
pub fn hpccg_update(
    x: &[f32],
    r: &[f32],
    p: &[f32],
    ap: &[f32],
    alpha: f32,
) -> (Vec<f32>, Vec<f32>, f32) {
    let mut x2 = vec![0.0f32; x.len()];
    let mut r2 = vec![0.0f32; r.len()];
    let mut rr = 0.0f32;
    for k in 0..x.len() {
        x2[k] = x[k] + alpha * p[k];
        r2[k] = r[k] - alpha * ap[k];
        rr += r2[k] * r2[k];
    }
    (x2, r2, rr)
}

/// model.hpccg_direction: p' = r + beta p.
pub fn hpccg_direction(r: &[f32], p: &[f32], beta: f32) -> Vec<f32> {
    r.iter().zip(p).map(|(ri, pi)| ri + beta * pi).collect()
}

/// model.lulesh_step: fused hydro update; returns (e', u', local dt_min).
pub fn lulesh_step(
    e: &[f32],
    u_halo: &[f32],
    nx: usize,
    dt: f32,
) -> (Vec<f32>, Vec<f32>, f32) {
    let (hx, hy, hz) = (nx + 2, nx + 2, nx + 2);
    assert_eq!(u_halo.len(), hx * hy * hz);
    assert_eq!(e.len(), nx * nx * nx);
    let mut e2 = vec![0.0f32; e.len()];
    let mut u2 = vec![0.0f32; e.len()];
    let mut dtmin = f32::INFINITY;
    for x in 0..nx {
        for y in 0..nx {
            for z in 0..nx {
                let uc = u_halo[idx(hx, hy, hz, x + 1, y + 1, z + 1)];
                let lap = u_halo[idx(hx, hy, hz, x + 2, y + 1, z + 1)]
                    + u_halo[idx(hx, hy, hz, x, y + 1, z + 1)]
                    + u_halo[idx(hx, hy, hz, x + 1, y + 2, z + 1)]
                    + u_halo[idx(hx, hy, hz, x + 1, y, z + 1)]
                    + u_halo[idx(hx, hy, hz, x + 1, y + 1, z + 2)]
                    + u_halo[idx(hx, hy, hz, x + 1, y + 1, z)]
                    - 6.0 * uc;
                let div = lap;
                let q = if div < 0.0 { HYDRO_QCOEF * div * div } else { 0.0 };
                let k = idx(nx, nx, nx, x, y, z);
                let p = (HYDRO_GAMMA - 1.0) * e[k];
                e2[k] = e[k] - dt * (p + q) * div;
                let un = uc + dt * (p + q);
                u2[k] = un;
                let ss = (HYDRO_GAMMA * p.max(HYDRO_SS_FLOOR)).sqrt();
                let dtc = HYDRO_CFL * HYDRO_DX / (ss + un.abs());
                dtmin = dtmin.min(dtc);
            }
        }
    }
    (e2, u2, dtmin)
}

/// Dispatch an artifact-style call natively. Input/output conventions match
/// the AOT manifest exactly (same order, shapes, scalar rank-0 arrays).
pub fn execute(name: &str, inputs: &[ArrayF32]) -> Vec<ArrayF32> {
    if let Some(rest) = name.strip_prefix("comd_step_n") {
        let n: usize = rest.parse().expect("comd artifact size");
        let (pos, vel, frc, dt, boxl) = (
            &inputs[0], &inputs[1], &inputs[2], &inputs[3], &inputs[4],
        );
        let (p2, v2, f2, ke, pe) =
            comd_step(&pos.data, &vel.data, &frc.data, n, dt.as_scalar(), boxl.as_scalar());
        return vec![
            ArrayF32::new(vec![n, 3], p2),
            ArrayF32::new(vec![n, 3], v2),
            ArrayF32::new(vec![n, 3], f2),
            ArrayF32::scalar(ke),
            ArrayF32::scalar(pe),
        ];
    }
    if let Some(rest) = name.strip_prefix("hpccg_matvec_") {
        let nx: usize = rest.parse().unwrap();
        let (ap, pap) = hpccg_matvec(&inputs[0].data, nx);
        return vec![ArrayF32::new(vec![nx, nx, nx], ap), ArrayF32::scalar(pap)];
    }
    if let Some(rest) = name.strip_prefix("hpccg_update_") {
        let nx: usize = rest.parse().unwrap();
        let (x2, r2, rr) = hpccg_update(
            &inputs[0].data,
            &inputs[1].data,
            &inputs[2].data,
            &inputs[3].data,
            inputs[4].as_scalar(),
        );
        return vec![
            ArrayF32::new(vec![nx, nx, nx], x2),
            ArrayF32::new(vec![nx, nx, nx], r2),
            ArrayF32::scalar(rr),
        ];
    }
    if let Some(rest) = name.strip_prefix("hpccg_direction_") {
        let nx: usize = rest.parse().unwrap();
        let p2 = hpccg_direction(&inputs[0].data, &inputs[1].data, inputs[2].as_scalar());
        return vec![ArrayF32::new(vec![nx, nx, nx], p2)];
    }
    if let Some(rest) = name.strip_prefix("lulesh_step_") {
        let nx: usize = rest.parse().unwrap();
        let (e2, u2, dtmin) =
            lulesh_step(&inputs[0].data, &inputs[1].data, nx, inputs[2].as_scalar());
        return vec![
            ArrayF32::new(vec![nx, nx, nx], e2),
            ArrayF32::new(vec![nx, nx, nx], u2),
            ArrayF32::scalar(dtmin),
        ];
    }
    panic!("native backend: unknown kernel `{name}`");
}

/// Output shapes of kernel `name` (fully determined by the name). Used by
/// the Ghost backend to emit zero tensors without running the math.
pub fn output_shapes(name: &str) -> Vec<Vec<usize>> {
    if let Some(rest) = name.strip_prefix("comd_step_n") {
        let n: usize = rest.parse().expect("comd artifact size");
        return vec![vec![n, 3], vec![n, 3], vec![n, 3], vec![], vec![]];
    }
    if let Some(rest) = name.strip_prefix("hpccg_matvec_") {
        let nx: usize = rest.parse().unwrap();
        return vec![vec![nx, nx, nx], vec![]];
    }
    if let Some(rest) = name.strip_prefix("hpccg_update_") {
        let nx: usize = rest.parse().unwrap();
        return vec![vec![nx, nx, nx], vec![nx, nx, nx], vec![]];
    }
    if let Some(rest) = name.strip_prefix("hpccg_direction_") {
        let nx: usize = rest.parse().unwrap();
        return vec![vec![nx, nx, nx]];
    }
    if let Some(rest) = name.strip_prefix("lulesh_step_") {
        let nx: usize = rest.parse().unwrap();
        return vec![vec![nx, nx, nx], vec![nx, nx, nx], vec![]];
    }
    panic!("output_shapes: unknown kernel `{name}`");
}

/// Deterministic analytic compute cost for `name` (virtual seconds) — the
/// `Modeled`/`Native` fidelity cost: flops / 2 GFLOP/s effective scalar rate.
pub fn modeled_cost_s(name: &str) -> f64 {
    let flops: f64 = if let Some(rest) = name.strip_prefix("comd_step_n") {
        let n: f64 = rest.parse().unwrap_or(128.0);
        n * n * 60.0
    } else if let Some(rest) = name.strip_prefix("hpccg_matvec_") {
        let nx: f64 = rest.parse().unwrap_or(16.0);
        nx.powi(3) * 29.0 * 2.0
    } else if let Some(rest) = name.strip_prefix("hpccg_update_") {
        let nx: f64 = rest.parse().unwrap_or(16.0);
        nx.powi(3) * 6.0
    } else if let Some(rest) = name.strip_prefix("hpccg_direction_") {
        let nx: f64 = rest.parse().unwrap_or(16.0);
        nx.powi(3) * 2.0
    } else if let Some(rest) = name.strip_prefix("lulesh_step_") {
        let nx: f64 = rest.parse().unwrap_or(16.0);
        nx.powi(3) * 25.0
    } else {
        1e6
    };
    flops / 2e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lj_pair_at_minimum() {
        // two particles at r = 2^(1/6): F ~ 0, pe = -eps
        let r0 = 2.0f32.powf(1.0 / 6.0);
        let pos = vec![1.0, 1.0, 1.0, 1.0 + r0, 1.0, 1.0];
        let (f, pe) = lj_forces(&pos, 2, 50.0);
        for v in &f {
            assert!(v.abs() < 1e-4, "{f:?}");
        }
        assert!((pe + LJ_EPS).abs() < 1e-5, "{pe}");
    }

    #[test]
    fn lj_newtons_third_law() {
        let pos: Vec<f32> = (0..5 * 3).map(|k| (k as f32 * 0.37) % 4.0).collect();
        let (f, _) = lj_forces(&pos, 5, 4.0);
        for d in 0..3 {
            let net: f32 = (0..5).map(|i| f[i * 3 + d]).sum();
            assert!(net.abs() < 1e-2, "net force {net}");
        }
    }

    #[test]
    fn stencil_constant_field() {
        let nx = 4;
        let ph = vec![3.0f32; (nx + 2) * (nx + 2) * (nx + 2)];
        let ap = stencil27(&ph, nx, nx, nx);
        for v in ap {
            assert!((v - 3.0).abs() < 1e-5); // (28-27)*3... wait: 28*3-27*3=3
        }
    }

    #[test]
    fn stencil_zero_halo_corner() {
        // interior ones, zero halo: corner cell sees 7 interior neighbours
        let nx = 4;
        let (hx, hy, hz) = (nx + 2, nx + 2, nx + 2);
        let mut ph = vec![0.0f32; hx * hy * hz];
        for x in 1..=nx {
            for y in 1..=nx {
                for z in 1..=nx {
                    ph[idx(hx, hy, hz, x, y, z)] = 1.0;
                }
            }
        }
        let ap = stencil27(&ph, nx, nx, nx);
        assert_eq!(ap[idx(nx, nx, nx, 0, 0, 0)], 27.0 - 7.0);
        assert_eq!(ap[idx(nx, nx, nx, 1, 1, 1)], 1.0);
    }

    #[test]
    fn cg_single_rank_converges() {
        // full CG loop against the stencil operator: residual drops
        let nx = 6;
        let n = nx * nx * nx;
        let b: Vec<f32> = (0..n).map(|k| ((k * 2654435761usize) % 97) as f32 / 97.0 - 0.5).collect();
        let mut x = vec![0.0f32; n];
        let mut r = b.clone();
        let mut p = b.clone();
        let mut rr: f32 = r.iter().map(|v| v * v).sum();
        let rr0 = rr;
        for _ in 0..12 {
            let ph = embed_halo(&p, nx);
            let (ap, pap) = hpccg_matvec(&ph, nx);
            let alpha = rr / pap;
            let (x2, r2, rr_new) = hpccg_update(&x, &r, &p, &ap, alpha);
            x = x2;
            r = r2;
            let beta = rr_new / rr;
            p = hpccg_direction(&r, &p, beta);
            rr = rr_new;
        }
        assert!(rr / rr0 < 1e-8, "residual ratio {}", rr / rr0);
    }

    fn embed_halo(p: &[f32], nx: usize) -> Vec<f32> {
        let (hx, hy, hz) = (nx + 2, nx + 2, nx + 2);
        let mut ph = vec![0.0f32; hx * hy * hz];
        for x in 0..nx {
            for y in 0..nx {
                for z in 0..nx {
                    ph[idx(hx, hy, hz, x + 1, y + 1, z + 1)] =
                        p[idx(nx, nx, nx, x, y, z)];
                }
            }
        }
        ph
    }

    #[test]
    fn hydro_uniform_field_energy_stationary() {
        let nx = 4;
        let e = vec![1.5f32; nx * nx * nx];
        let u = vec![0.7f32; (nx + 2) * (nx + 2) * (nx + 2)];
        let (e2, u2, _) = lulesh_step(&e, &u, nx, 0.02);
        let p = (HYDRO_GAMMA - 1.0) * 1.5;
        for v in e2 {
            assert!((v - 1.5).abs() < 1e-6);
        }
        for v in u2 {
            assert!((v - (0.7 + 0.02 * p)).abs() < 1e-6);
        }
    }

    #[test]
    fn hydro_dtmin_positive() {
        let nx = 4;
        let e = vec![1.0f32; nx * nx * nx];
        let mut u = vec![0.0f32; (nx + 2) * (nx + 2) * (nx + 2)];
        u[idx(nx + 2, nx + 2, nx + 2, 3, 3, 3)] = -1.0;
        let (_, _, dtmin) = lulesh_step(&e, &u, nx, 0.01);
        assert!(dtmin > 0.0 && dtmin.is_finite());
    }

    #[test]
    fn comd_step_dt0_evaluates_forces_in_place() {
        let pos = vec![0.5, 0.5, 0.5, 1.8, 0.5, 0.5];
        let vel = vec![0.0; 6];
        let frc = vec![0.0; 6];
        let (p2, _, f2, ke, _) = comd_step(&pos, &vel, &frc, 2, 0.0, 10.0);
        assert_eq!(p2, pos);
        assert_eq!(ke, 0.0);
        let (fx, _) = lj_forces(&pos, 2, 10.0);
        assert_eq!(f2, fx);
    }

    #[test]
    fn dispatch_matches_direct_calls() {
        let nx = 4;
        let ph = ArrayF32::new(
            vec![nx + 2, nx + 2, nx + 2],
            (0..(nx + 2) * (nx + 2) * (nx + 2))
                .map(|k| (k % 13) as f32 * 0.1)
                .collect(),
        );
        let out = execute(&format!("hpccg_matvec_{nx}"), &[ph.clone()]);
        let (ap, pap) = hpccg_matvec(&ph.data, nx);
        assert_eq!(out[0].data, ap);
        assert_eq!(out[1].as_scalar(), pap);
    }

    #[test]
    fn modeled_costs_scale_with_size() {
        assert!(modeled_cost_s("hpccg_matvec_16") > modeled_cost_s("hpccg_matvec_8"));
        assert!(modeled_cost_s("comd_step_n128") > modeled_cost_s("comd_step_n64"));
        assert!(modeled_cost_s("hpccg_matvec_16") > 0.0);
    }
}
