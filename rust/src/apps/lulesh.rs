//! LULESH proxy: explicit hydro on an nx³ subdomain per rank, with a
//! Sedov-like point energy deposit at the grid centre, 6-face halo exchange
//! of the velocity carrier field, and the global Courant dt min-allreduce
//! (CalcTimeConstraintsForElems) every iteration.

use super::halo::{build_halo, coords, exchange_faces, grid3};
use super::{decode_blocks, encode_blocks, AppState, LocalBoxFuture, NewWorld, StepCtx};
use crate::mpi::{MpiError, ReduceOp};
use crate::runtime::ArrayF32;
use crate::sim::rng::Rng;

const DT0: f32 = 1e-3;
const DT_CAP: f32 = 1e-2;
const DEPOSIT: f32 = 10.0;

/// Factory for per-rank LULESH state.
pub struct LuleshApp {
    pub nx: u32,
    pub seed: u64,
}

impl super::App for LuleshApp {
    fn name(&self) -> String {
        format!("lulesh_nx{}", self.nx)
    }

    fn new_state(&self, rank: u32, size: u32) -> Box<dyn AppState> {
        Box::new(LuleshState::new(self.nx as usize, self.seed, rank, size))
    }
}

pub struct LuleshState {
    /// Logical decomposition — pinned for the job's life (the Sedov centre
    /// rank and halo partners must not move under a shrink).
    dims: (u32, u32, u32),
    /// Live processor grid, re-derived over survivors by `repartition`.
    /// Model-only: not serialized, not digested.
    live_grid: (u32, u32, u32),
    /// Post-shrink compute inflation (`NewWorld::work_scale`); model-only.
    work_scale: f64,
    nx: usize,
    e: Vec<f32>,
    u: Vec<f32>,
    dt: f32,
    /// Diagnostic: last global dt.
    pub dt_global: f32,
}

impl LuleshState {
    pub fn new(nx: usize, seed: u64, rank: u32, size: u32) -> Self {
        let dims = grid3(size);
        let n = nx * nx * nx;
        // tiny deterministic background perturbation so ranks differ
        let mut rng = Rng::new(seed).fork(&format!("lulesh-init-r{rank}"));
        let mut e: Vec<f32> = (0..n)
            .map(|_| 1.0 + rng.gen_f32_range(-1e-3, 1e-3))
            .collect();
        // Sedov deposit: the rank at the centre of the process grid puts
        // extra energy at its subdomain centre.
        let centre_rank = super::halo::rank_of(
            (dims.0 / 2, dims.1 / 2, dims.2 / 2),
            dims,
        );
        if rank == centre_rank {
            let c = nx / 2;
            e[(c * nx + c) * nx + c] = DEPOSIT;
        }
        let _ = coords(rank, dims);
        LuleshState {
            dims,
            live_grid: dims,
            work_scale: 1.0,
            nx,
            e,
            u: vec![0.0; n],
            dt: DT0,
            dt_global: DT0,
        }
    }

    /// The processor grid currently carrying the blocks (tests/diagnostics).
    pub fn live_grid(&self) -> (u32, u32, u32) {
        self.live_grid
    }
}

impl AppState for LuleshState {
    fn serialize(&self) -> Vec<u8> {
        let scalars = [self.dt, self.dt_global];
        encode_blocks(&[&self.e, &self.u, &scalars])
    }

    fn restore(&mut self, bytes: &[u8]) {
        let blocks = decode_blocks(bytes);
        assert_eq!(blocks.len(), 3, "LULESH checkpoint layout");
        self.e = blocks[0].clone();
        self.u = blocks[1].clone();
        self.dt = blocks[2][0];
        self.dt_global = blocks[2][1];
    }

    fn diagnostic(&self) -> f64 {
        self.dt_global as f64
    }

    fn repartition(&mut self, world: NewWorld) {
        // `dims` stays at the logical decomposition so the deposit centre
        // and face partners are invariant; survivors just carry more work.
        self.live_grid = grid3(world.procs);
        self.work_scale = world.work_scale();
    }

    fn step<'a>(
        &'a mut self,
        cx: StepCtx<'a>,
        _iter: u32,
    ) -> LocalBoxFuture<'a, Result<(), MpiError>> {
        Box::pin(async move {
            let nx = self.nx;
            let faces = exchange_faces(cx.comm, self.dims, &self.u, nx).await?;
            let u_halo = build_halo(&self.u, nx, &faces);
            let mut outs = cx
                .run_kernel_scaled(
                    &format!("lulesh_step_{nx}"),
                    &[
                        ArrayF32::new(vec![nx, nx, nx], self.e.clone()),
                        ArrayF32::new(vec![nx + 2, nx + 2, nx + 2], u_halo),
                        ArrayF32::scalar(self.dt),
                    ],
                    self.work_scale,
                )
                .await;
            let dt_local = outs[2].as_scalar();
            self.e = std::mem::take(&mut outs[0].data);
            self.u = std::mem::take(&mut outs[1].data);
            // CalcTimeConstraints: global Courant minimum
            let dt_min = cx.comm.allreduce_scalar(dt_local, ReduceOp::Min).await?;
            self.dt_global = dt_min;
            self.dt = dt_min.min(DT_CAP);
            Ok(())
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::App;

    #[test]
    fn only_centre_rank_gets_deposit() {
        let dims = grid3(8); // (2,2,2) -> centre rank = coords (1,1,1) = 7
        let centre = super::super::halo::rank_of((1, 1, 1), dims);
        for r in 0..8 {
            let s = LuleshState::new(8, 1, r, 8);
            let max = s.e.iter().cloned().fold(0.0f32, f32::max);
            if r == centre {
                assert!(max >= DEPOSIT);
            } else {
                assert!(max < 1.1);
            }
        }
    }

    #[test]
    fn checkpoint_roundtrip() {
        let app = LuleshApp { nx: 8, seed: 2 };
        let a = app.new_state(7, 8);
        let mut b = app.new_state(0, 8);
        assert_ne!(a.digest(), b.digest());
        b.restore(&a.serialize());
        assert_eq!(a.digest(), b.digest());
    }

    #[test]
    fn repartition_keeps_decomposition_and_digest() {
        let mut s = LuleshState::new(8, 2, 3, 27);
        let before = s.serialize();
        s.repartition(NewWorld { logical: 27, procs: 13 });
        assert_eq!(s.live_grid(), grid3(13));
        assert_eq!(s.dims, grid3(27), "deposit centre must not move");
        assert!((s.work_scale - 27.0 / 13.0).abs() < 1e-12);
        assert_eq!(s.serialize(), before);
    }

    #[test]
    fn initial_dt_sane() {
        let s = LuleshState::new(8, 0, 0, 8);
        assert_eq!(s.dt, DT0);
    }
}
