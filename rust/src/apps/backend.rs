//! Compute backends: how a simulated rank's per-iteration compute runs and
//! what it charges to virtual time.
//!
//! - `Xla`: execute the real AOT artifact via PJRT; charge the *measured*
//!   wall time (full fidelity — the paper's "pure application time").
//! - `Native`: execute the pure-Rust oracle; charge a deterministic
//!   analytic cost (unit tests, bitwise-reproducible protocol runs).
//! - `Ghost`: skip the math, emit zeros of the right shape; charge the
//!   live ranks' running-average measured cost (fast fidelity at 256-1024
//!   ranks — DESIGN.md §8).

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use super::native;
use crate::runtime::{ArrayF32, XlaRuntime};
use crate::sim::SimDuration;

/// Shared per-artifact running average of measured compute cost (seconds).
/// Live ranks record; ghost ranks replay.
#[derive(Clone, Default)]
pub struct CostTracker {
    inner: Rc<RefCell<HashMap<String, (f64, u64)>>>, // (mean, count)
}

impl CostTracker {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, name: &str, secs: f64) {
        let mut m = self.inner.borrow_mut();
        let e = m.entry(name.to_string()).or_insert((0.0, 0));
        e.1 += 1;
        e.0 += (secs - e.0) / e.1 as f64;
    }

    pub fn mean(&self, name: &str) -> Option<f64> {
        self.inner.borrow().get(name).map(|(m, _)| *m)
    }
}

enum Inner {
    Xla {
        rt: Rc<XlaRuntime>,
        tracker: CostTracker,
    },
    Native {
        /// Virtual-time multiplier on the analytic cost
        /// (`calibration.modeled_compute_scale`; 1.0 = the calibrated
        /// figures' charge, bit-exact).
        scale: f64,
    },
    Ghost {
        tracker: CostTracker,
    },
}

/// A rank's compute engine (cheap to clone, shared within a trial).
#[derive(Clone)]
pub struct ComputeBackend {
    inner: Rc<Inner>,
}

impl ComputeBackend {
    pub fn xla(rt: Rc<XlaRuntime>, tracker: CostTracker) -> Self {
        ComputeBackend {
            inner: Rc::new(Inner::Xla { rt, tracker }),
        }
    }

    pub fn native() -> Self {
        Self::native_scaled(1.0)
    }

    /// Native backend with a virtual-time cost multiplier (modeled
    /// fidelity only; host compute is unchanged).
    pub fn native_scaled(scale: f64) -> Self {
        ComputeBackend {
            inner: Rc::new(Inner::Native { scale }),
        }
    }

    pub fn ghost(tracker: CostTracker) -> Self {
        ComputeBackend {
            inner: Rc::new(Inner::Ghost { tracker }),
        }
    }

    pub fn is_ghost(&self) -> bool {
        matches!(*self.inner, Inner::Ghost { .. })
    }

    /// Run kernel `name`; returns outputs + the virtual compute cost to
    /// charge (the caller sleeps it, possibly scaled by the ULFM factor).
    pub fn execute(&self, name: &str, inputs: &[ArrayF32]) -> (Vec<ArrayF32>, SimDuration) {
        match &*self.inner {
            Inner::Xla { rt, tracker } => {
                let (outs, wall) = rt
                    .execute(name, inputs)
                    .unwrap_or_else(|e| panic!("XLA execute {name}: {e:#}"));
                let secs = wall.as_secs_f64();
                tracker.record(name, secs);
                (outs, SimDuration::from_secs_f64(secs))
            }
            Inner::Native { scale } => {
                let outs = native::execute(name, inputs);
                (
                    outs,
                    SimDuration::from_secs_f64(native::modeled_cost_s(name) * scale),
                )
            }
            Inner::Ghost { tracker } => {
                let shapes = native::output_shapes(name);
                let outs = shapes.iter().map(|s| ArrayF32::zeros(s)).collect();
                let secs = tracker
                    .mean(name)
                    .unwrap_or_else(|| native::modeled_cost_s(name));
                (outs, SimDuration::from_secs_f64(secs))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracker_running_mean() {
        let t = CostTracker::new();
        t.record("k", 1.0);
        t.record("k", 3.0);
        assert_eq!(t.mean("k"), Some(2.0));
        assert_eq!(t.mean("other"), None);
    }

    #[test]
    fn native_backend_charges_deterministic_cost() {
        let b = ComputeBackend::native();
        let nx = 4usize;
        let ph = ArrayF32::zeros(&[nx + 2, nx + 2, nx + 2]);
        let (outs, c1) = b.execute("hpccg_matvec_4", &[ph.clone()]);
        let (_, c2) = b.execute("hpccg_matvec_4", &[ph]);
        assert_eq!(c1, c2);
        assert_eq!(outs[0].shape, vec![4, 4, 4]);
    }

    #[test]
    fn ghost_backend_zeros_and_replayed_cost() {
        let t = CostTracker::new();
        t.record("hpccg_matvec_4", 0.125);
        let b = ComputeBackend::ghost(t);
        let ph = ArrayF32::zeros(&[6, 6, 6]);
        let (outs, cost) = b.execute("hpccg_matvec_4", &[ph]);
        assert!(outs[0].data.iter().all(|&v| v == 0.0));
        assert_eq!(cost, SimDuration::from_secs_f64(0.125));
        assert!(b.is_ghost());
    }

    #[test]
    fn ghost_without_observations_falls_back_to_model() {
        let b = ComputeBackend::ghost(CostTracker::new());
        let ph = ArrayF32::zeros(&[6, 6, 6]);
        let (_, cost) = b.execute("hpccg_matvec_4", &[ph]);
        assert!(cost > SimDuration::ZERO);
    }
}
