//! 3D domain decomposition and 6-face halo exchange.
//!
//! Ranks form a near-cubic (px, py, pz) process grid; each holds an
//! nx³ subdomain. One exchange ships the six faces to the face neighbours
//! (HPCCG's exch_externals / LULESH's CommSBN pattern). Edge/corner halo
//! cells are zero — a symmetric truncation of the 27-point operator at
//! subdomain boundaries (documented in DESIGN.md: preserves symmetry /
//! positive-definiteness, hence CG behaviour; identical in fault-free and
//! recovered runs, which is what the experiments compare).

use crate::mpi::{bytes_to_f32s, Comm, MpiError, RecvSrc};

/// User-space tag block for halo faces.
const FACE_TAG_BASE: u64 = 1 << 32;

/// Near-cubic factorization of `n` into (px, py, pz), px >= py >= pz,
/// minimizing total surface (deterministic). Degenerate counts are first
/// class: primes and `n == 1` yield valid *flat* grids (`(n, 1, 1)`) whose
/// unit axes have no neighbours — a 1-wide axis never wraps onto itself.
/// Shrinking recovery re-derives grids over arbitrary survivor counts, so
/// every `n >= 1` must factor cleanly.
pub fn grid3(n: u32) -> (u32, u32, u32) {
    assert!(n >= 1, "grid3 needs at least one rank");
    let mut best = (n, 1, 1);
    let mut best_surface = u64::MAX;
    for pz in 1..=n {
        if n % pz != 0 {
            continue;
        }
        let rest = n / pz;
        for py in 1..=rest {
            if rest % py != 0 {
                continue;
            }
            let px = rest / py;
            if px < py || py < pz {
                continue;
            }
            let surface = (px * py + py * pz + px * pz) as u64;
            if surface < best_surface {
                best_surface = surface;
                best = (px, py, pz);
            }
        }
    }
    best
}

/// Rank -> (cx, cy, cz) in the process grid (x slowest, z fastest).
pub fn coords(rank: u32, dims: (u32, u32, u32)) -> (u32, u32, u32) {
    let (_px, py, pz) = dims;
    (rank / (py * pz), (rank / pz) % py, rank % pz)
}

/// (cx, cy, cz) -> rank.
pub fn rank_of(c: (u32, u32, u32), dims: (u32, u32, u32)) -> u32 {
    let (_px, py, pz) = dims;
    (c.0 * py + c.1) * pz + c.2
}

/// The 6 face directions: (axis, +1/-1).
pub const FACES: [(usize, i32); 6] = [
    (0, -1),
    (0, 1),
    (1, -1),
    (1, 1),
    (2, -1),
    (2, 1),
];

/// Neighbour rank across face `f`, or None at the global boundary.
pub fn neighbor(rank: u32, dims: (u32, u32, u32), f: usize) -> Option<u32> {
    let (axis, dir) = FACES[f];
    let c = coords(rank, dims);
    let dim = [dims.0, dims.1, dims.2][axis];
    let cur = [c.0, c.1, c.2][axis] as i64;
    let next = cur + dir as i64;
    if next < 0 || next >= dim as i64 {
        return None;
    }
    let mut nc = [c.0, c.1, c.2];
    nc[axis] = next as u32;
    Some(rank_of((nc[0], nc[1], nc[2]), dims))
}

#[inline]
fn idx(n: usize, x: usize, y: usize, z: usize) -> usize {
    (x * n + y) * n + z
}

/// Extract the boundary plane of `field` (nx³, C order) facing direction
/// `f` into `out` (cleared first); the plane we *send* to that neighbour.
/// Writing into a caller-owned buffer lets `exchange_faces` reuse one
/// buffer across all six faces of every iteration.
pub fn extract_face_into(field: &[f32], nx: usize, f: usize, out: &mut Vec<f32>) {
    let (axis, dir) = FACES[f];
    let fixed = if dir < 0 { 0 } else { nx - 1 };
    out.clear();
    out.reserve(nx * nx);
    for a in 0..nx {
        for b in 0..nx {
            let (x, y, z) = match axis {
                0 => (fixed, a, b),
                1 => (a, fixed, b),
                _ => (a, b, fixed),
            };
            out.push(field[idx(nx, x, y, z)]);
        }
    }
}

/// Extract the boundary plane of `field` facing direction `f`.
pub fn extract_face(field: &[f32], nx: usize, f: usize) -> Vec<f32> {
    let mut out = Vec::new();
    extract_face_into(field, nx, f, &mut out);
    out
}

/// Assemble the (nx+2)³ halo-extended field from the interior and received
/// faces (None = global boundary = zeros). Edges/corners stay zero.
pub fn build_halo(field: &[f32], nx: usize, faces: &[Option<Vec<f32>>; 6]) -> Vec<f32> {
    let h = nx + 2;
    let mut out = vec![0.0f32; h * h * h];
    for x in 0..nx {
        for y in 0..nx {
            for z in 0..nx {
                out[((x + 1) * h + (y + 1)) * h + (z + 1)] = field[idx(nx, x, y, z)];
            }
        }
    }
    for (f, face) in faces.iter().enumerate() {
        let Some(data) = face else { continue };
        debug_assert_eq!(data.len(), nx * nx);
        let (axis, dir) = FACES[f];
        let fixed = if dir < 0 { 0 } else { h - 1 };
        let mut it = data.iter();
        for a in 0..nx {
            for b in 0..nx {
                let (x, y, z) = match axis {
                    0 => (fixed, a + 1, b + 1),
                    1 => (a + 1, fixed, b + 1),
                    _ => (a + 1, b + 1, fixed),
                };
                out[(x * h + y) * h + z] = *it.next().unwrap();
            }
        }
    }
    out
}

/// Exchange the six faces of `field` with the face neighbours. Returns the
/// received planes, indexed like `FACES` (None at global boundaries).
pub async fn exchange_faces(
    comm: &Comm,
    dims: (u32, u32, u32),
    field: &[f32],
    nx: usize,
) -> Result<[Option<Vec<f32>>; 6], MpiError> {
    // Post all sends first (non-blocking), then receive. One reusable face
    // buffer + the per-comm scratch encoder: each sent face costs exactly
    // the shared `Rc` payload the fabric needs, not a `Vec<f32>` plus a
    // `Vec<u8>` per hop.
    let mut face = Vec::new();
    for f in 0..6 {
        if let Some(to) = neighbor(comm.rank, dims, f) {
            extract_face_into(field, nx, f, &mut face);
            comm.send_payload(to, FACE_TAG_BASE + f as u64, comm.f32_payload(&face));
        }
    }
    let mut out: [Option<Vec<f32>>; 6] = Default::default();
    for f in 0..6 {
        // we receive from the neighbour across face f the plane it sent
        // toward us: its face index is the opposite direction (f ^ 1).
        if let Some(from) = neighbor(comm.rank, dims, f) {
            let m = comm
                .recv(RecvSrc::From(from), FACE_TAG_BASE + (f ^ 1) as u64)
                .await?;
            out[f] = Some(bytes_to_f32s(&m.data));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid3_known_factorizations() {
        assert_eq!(grid3(1), (1, 1, 1));
        assert_eq!(grid3(8), (2, 2, 2));
        assert_eq!(grid3(64), (4, 4, 4));
        assert_eq!(grid3(16), (4, 2, 2));
        assert_eq!(grid3(27), (3, 3, 3));
        let (px, py, pz) = grid3(1024);
        assert_eq!(px * py * pz, 1024);
        assert!(px >= py && py >= pz);
    }

    #[test]
    fn grid3_degenerate_survivor_counts_stay_valid() {
        // shrink can leave any rank count alive; primes and 1 must still
        // factor into a valid (flat) grid
        for n in [1u32, 2, 3, 5, 7, 13] {
            let (px, py, pz) = grid3(n);
            assert_eq!(px * py * pz, n, "n={n}: must cover every rank");
            assert!(px >= py && py >= pz && pz >= 1, "n={n}: ({px},{py},{pz})");
            assert_eq!((py, pz), (1, 1), "n={n}: prime/unit counts are chains");
            for r in 0..n {
                assert_eq!(rank_of(coords(r, dims_of(n)), dims_of(n)), r);
            }
        }
        fn dims_of(n: u32) -> (u32, u32, u32) {
            grid3(n)
        }
    }

    #[test]
    fn flat_grid_neighbors_never_wrap() {
        for n in [1u32, 2, 3, 5, 7, 13] {
            let dims = grid3(n);
            for r in 0..n {
                // unit axes (y, z on a chain) have no neighbours at all
                for f in 2..6 {
                    assert_eq!(neighbor(r, dims, f), None, "n={n} r={r} f={f}");
                }
                let minus = neighbor(r, dims, 0);
                let plus = neighbor(r, dims, 1);
                assert_eq!(minus, (r > 0).then(|| r - 1), "n={n} r={r} -x");
                assert_eq!(plus, (r + 1 < n).then(|| r + 1), "n={n} r={r} +x");
                assert_ne!(minus, Some(r), "no self-wrap");
                assert_ne!(plus, Some(r), "no self-wrap");
            }
        }
    }

    #[test]
    #[should_panic(expected = "grid3 needs at least one rank")]
    fn grid3_rejects_empty_world() {
        grid3(0);
    }

    #[test]
    fn coords_rank_roundtrip() {
        let dims = grid3(64);
        for r in 0..64 {
            assert_eq!(rank_of(coords(r, dims), dims), r);
        }
    }

    #[test]
    fn neighbors_symmetric() {
        let dims = grid3(27);
        for r in 0..27 {
            for f in 0..6 {
                if let Some(n) = neighbor(r, dims, f) {
                    assert_eq!(
                        neighbor(n, dims, f ^ 1),
                        Some(r),
                        "r={r} f={f} n={n}"
                    );
                }
            }
        }
    }

    #[test]
    fn boundary_has_no_neighbor() {
        let dims = grid3(8); // (2,2,2)
        assert_eq!(neighbor(0, dims, 0), None); // -x at corner 0
        assert!(neighbor(0, dims, 1).is_some()); // +x exists
    }

    #[test]
    fn face_extract_insert_roundtrip() {
        let nx = 3;
        let field: Vec<f32> = (0..27).map(|k| k as f32).collect();
        // send +x face of A; B puts it in its -x halo plane
        let face = extract_face(&field, nx, 1);
        assert_eq!(face.len(), 9);
        // A's +x plane is x = nx-1: values (2*3+y)*3+z
        assert_eq!(face[0], field[idx(nx, 2, 0, 0)]);
        let mut faces: [Option<Vec<f32>>; 6] = Default::default();
        faces[0] = Some(face.clone()); // B receives it across its -x face
        let halo = build_halo(&field, nx, &faces);
        let h = nx + 2;
        // B's halo plane x=0 at (y+1, z+1) equals A's sent face
        assert_eq!(halo[(0 * h + 1) * h + 1], face[0]);
        assert_eq!(halo[(0 * h + 2) * h + 3], face[1 * 3 + 2]);
        // interior preserved
        assert_eq!(halo[((1 + 1) * h + (0 + 1)) * h + (2 + 1)], field[idx(nx, 1, 0, 2)]);
    }

    #[test]
    fn build_halo_zero_boundary() {
        let nx = 2;
        let field = vec![1.0f32; 8];
        let faces: [Option<Vec<f32>>; 6] = Default::default();
        let halo = build_halo(&field, nx, &faces);
        let h = nx + 2;
        // all boundary cells zero
        for x in 0..h {
            for y in 0..h {
                for z in 0..h {
                    let v = halo[(x * h + y) * h + z];
                    let interior =
                        (1..=nx).contains(&x) && (1..=nx).contains(&y) && (1..=nx).contains(&z);
                    assert_eq!(v, if interior { 1.0 } else { 0.0 });
                }
            }
        }
    }

    #[test]
    fn exchange_on_two_ranks() {
        use crate::cluster::Topology;
        use crate::config::Calibration;
        use crate::mpi::{FtMode, MpiJob};
        use crate::sim::Sim;
        use std::cell::RefCell;
        use std::rc::Rc;

        let sim = Sim::new();
        let topo = Topology::new(2, 16, 0);
        let job = MpiJob::new(&sim, topo, FtMode::Reinit, &Calibration::default());
        let dims = grid3(2); // (2,1,1): neighbours along x
        let got: Rc<RefCell<Vec<(u32, [Option<Vec<f32>>; 6])>>> =
            Rc::new(RefCell::new(Vec::new()));
        for r in 0..2u32 {
            let p = sim.spawn_process(format!("r{r}"));
            let j2 = job.clone();
            let g2 = Rc::clone(&got);
            sim.spawn(p, async move {
                let c = j2.attach(r, 0);
                let nx = 2usize;
                let field = vec![(r + 1) as f32; nx * nx * nx];
                let faces = exchange_faces(&c, dims, &field, nx).await.unwrap();
                g2.borrow_mut().push((r, faces));
            });
        }
        let s = sim.run();
        assert_eq!(s.tasks_pending, 0);
        for (r, faces) in got.borrow().iter() {
            let other = if *r == 0 { 2.0 } else { 1.0 };
            // rank 0 is at cx=0: +x neighbour only (face index 1)
            let present: Vec<usize> =
                (0..6).filter(|&f| faces[f].is_some()).collect();
            assert_eq!(present.len(), 1);
            let f = present[0];
            assert!(faces[f].as_ref().unwrap().iter().all(|&v| v == other));
        }
    }
}
