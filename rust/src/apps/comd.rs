//! CoMD proxy: Lennard-Jones molecular dynamics, velocity-Verlet.
//!
//! Weak scaling: every rank owns an independent periodic LJ box of `n`
//! particles; the global coupling is the per-iteration (KE, PE) energy
//! allreduce (CoMD's conservation diagnostic). This is the documented
//! simplification from DESIGN.md: the recovery experiments need per-rank
//! compute + a global BSP synchronization point, not cross-rank ghost
//! atoms. dt is small enough that energy is conserved to ~0.1% (tested at
//! the Python layer).

use super::{decode_blocks, encode_blocks, AppState, LocalBoxFuture, NewWorld, StepCtx};
use crate::mpi::{MpiError, ReduceOp};
use crate::runtime::ArrayF32;
use crate::sim::rng::Rng;

const SPACING: f32 = 1.25;
const JITTER: f32 = 0.03;
const VEL_SCALE: f64 = 0.05;
const DT: f32 = 2e-3;

/// Factory for per-rank CoMD state.
pub struct ComdApp {
    pub n: u32,
    pub seed: u64,
}

impl super::App for ComdApp {
    fn name(&self) -> String {
        format!("comd_n{}", self.n)
    }

    fn new_state(&self, rank: u32, _size: u32) -> Box<dyn AppState> {
        Box::new(ComdState::new(self.n as usize, self.seed, rank))
    }
}

pub struct ComdState {
    n: usize,
    boxl: f32,
    pos: Vec<f32>,
    vel: Vec<f32>,
    frc: Vec<f32>,
    /// Forces valid? (first step runs a dt=0 force evaluation)
    initialized: bool,
    /// Last global (ke + pe) — the conservation diagnostic.
    pub energy: f32,
    /// Post-shrink compute inflation (`NewWorld::work_scale`): survivors
    /// integrate the adopted ranks' LJ boxes too. Model-only — excluded
    /// from `serialize`, so digests match fault-free runs.
    work_scale: f64,
}

impl ComdState {
    pub fn new(n: usize, seed: u64, rank: u32) -> Self {
        let mut rng = Rng::new(seed).fork(&format!("comd-init-r{rank}"));
        let side = (n as f64).cbrt().ceil() as usize;
        let boxl = side as f32 * SPACING;
        let mut pos = Vec::with_capacity(n * 3);
        'outer: for x in 0..side {
            for y in 0..side {
                for z in 0..side {
                    if pos.len() >= n * 3 {
                        break 'outer;
                    }
                    for c in [x, y, z] {
                        let jitter = rng.gen_f32_range(-JITTER, JITTER);
                        pos.push(c as f32 * SPACING + SPACING * 0.5 + jitter);
                    }
                }
            }
        }
        let mut vel: Vec<f32> = (0..n * 3)
            .map(|_| (rng.gen_normal() * VEL_SCALE) as f32)
            .collect();
        // zero net momentum per component
        for d in 0..3 {
            let mean: f32 = (0..n).map(|i| vel[i * 3 + d]).sum::<f32>() / n as f32;
            for i in 0..n {
                vel[i * 3 + d] -= mean;
            }
        }
        ComdState {
            n,
            boxl,
            pos,
            vel,
            frc: vec![0.0; n * 3],
            initialized: false,
            energy: 0.0,
            work_scale: 1.0,
        }
    }

    fn kernel(&self) -> String {
        format!("comd_step_n{}", self.n)
    }

    fn arrays(&self, dt: f32) -> Vec<ArrayF32> {
        vec![
            ArrayF32::new(vec![self.n, 3], self.pos.clone()),
            ArrayF32::new(vec![self.n, 3], self.vel.clone()),
            ArrayF32::new(vec![self.n, 3], self.frc.clone()),
            ArrayF32::scalar(dt),
            ArrayF32::scalar(self.boxl),
        ]
    }
}

impl AppState for ComdState {
    fn serialize(&self) -> Vec<u8> {
        let flags = [if self.initialized { 1.0 } else { 0.0 }, self.energy, self.boxl];
        encode_blocks(&[&self.pos, &self.vel, &self.frc, &flags])
    }

    fn restore(&mut self, bytes: &[u8]) {
        let blocks = decode_blocks(bytes);
        assert_eq!(blocks.len(), 4, "CoMD checkpoint layout");
        self.pos = blocks[0].clone();
        self.vel = blocks[1].clone();
        self.frc = blocks[2].clone();
        self.initialized = blocks[3][0] != 0.0;
        self.energy = blocks[3][1];
        self.boxl = blocks[3][2];
    }

    fn diagnostic(&self) -> f64 {
        self.energy as f64
    }

    fn repartition(&mut self, world: NewWorld) {
        self.work_scale = world.work_scale();
    }

    fn step<'a>(
        &'a mut self,
        cx: StepCtx<'a>,
        _iter: u32,
    ) -> LocalBoxFuture<'a, Result<(), MpiError>> {
        Box::pin(async move {
            let name = self.kernel();
            let ws = self.work_scale;
            if !self.initialized {
                // dt = 0: evaluates F(pos) without moving (see model.py)
                let outs = cx.run_kernel_scaled(&name, &self.arrays(0.0), ws).await;
                self.frc = outs[2].data.clone();
                self.initialized = true;
            }
            let mut outs = cx.run_kernel_scaled(&name, &self.arrays(DT), ws).await;
            let ke = outs[3].as_scalar();
            let pe = outs[4].as_scalar();
            self.pos = std::mem::take(&mut outs[0].data);
            self.vel = std::mem::take(&mut outs[1].data);
            self.frc = std::mem::take(&mut outs[2].data);
            // CoMD's global energy reduction (the per-iteration BSP sync)
            let tot = cx.comm.allreduce(&[ke, pe], ReduceOp::Sum).await?;
            self.energy = tot[0] + tot[1];
            Ok(())
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::App;

    #[test]
    fn init_is_deterministic_per_rank() {
        let a = ComdState::new(64, 7, 3);
        let b = ComdState::new(64, 7, 3);
        let c = ComdState::new(64, 7, 4);
        assert_eq!(a.pos, b.pos);
        assert_eq!(a.vel, b.vel);
        assert_ne!(a.pos, c.pos, "ranks get different configs");
    }

    #[test]
    fn init_zero_net_momentum() {
        let s = ComdState::new(100, 1, 0);
        for d in 0..3 {
            let net: f32 = (0..100).map(|i| s.vel[i * 3 + d]).sum();
            assert!(net.abs() < 1e-4, "{net}");
        }
    }

    #[test]
    fn positions_inside_box() {
        let s = ComdState::new(128, 2, 1);
        for &x in &s.pos {
            assert!(x > -JITTER && x < s.boxl + JITTER);
        }
    }

    #[test]
    fn repartition_leaves_checkpoint_alone() {
        let mut s = ComdState::new(64, 7, 3);
        let before = s.serialize();
        s.repartition(NewWorld { logical: 16, procs: 4 });
        assert_eq!(s.work_scale, 4.0);
        assert_eq!(s.serialize(), before, "payload must not encode the scale");
    }

    #[test]
    fn checkpoint_roundtrip_is_identity() {
        let app = ComdApp { n: 64, seed: 3 };
        let a = app.new_state(0, 4);
        let mut b = app.new_state(1, 4); // different content
        assert_ne!(a.digest(), b.digest());
        b.restore(&a.serialize());
        assert_eq!(a.digest(), b.digest());
    }
}
