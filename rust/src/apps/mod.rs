//! The three weak-scaled proxy applications (paper Table 1) and the compute
//! backends they run on.
//!
//! Each app is a per-rank state machine: `step` performs one main-loop
//! iteration — kernel execution (XLA artifact / native oracle / ghost) plus
//! the MPI phases the real proxy app does in that spot (halo exchange,
//! allreduce). `serialize`/`restore` define the checkpoint payload; the
//! rank driver in `recovery::job` owns the loop, fault injection and
//! checkpoint cadence (the paper's Fig. 2 `foo` pattern).

pub mod backend;
pub mod halo;
pub mod native;

mod comd;
mod hpccg;
mod lulesh;

pub use backend::{ComputeBackend, CostTracker};
pub use comd::ComdApp;
pub use hpccg::HpccgApp;
pub use lulesh::LuleshApp;

use std::future::Future;
use std::pin::Pin;
use std::rc::Rc;

use crate::config::{AppKind, ExperimentConfig};
use crate::mpi::{Comm, MpiError};
use crate::sim::Sim;

/// Boxed local future (single-threaded executor: no Send bound).
pub type LocalBoxFuture<'a, T> = Pin<Box<dyn Future<Output = T> + 'a>>;

/// What a step needs from the environment.
pub struct StepCtx<'a> {
    pub sim: &'a Sim,
    pub comm: &'a Comm,
    pub backend: &'a ComputeBackend,
}

impl StepCtx<'_> {
    /// Execute a kernel and charge its virtual cost (scaled by the ULFM
    /// fault-tolerance overhead factor — the Fig. 5 inflation).
    pub async fn run_kernel(
        &self,
        name: &str,
        inputs: &[crate::runtime::ArrayF32],
    ) -> Vec<crate::runtime::ArrayF32> {
        self.run_kernel_scaled(name, inputs, 1.0).await
    }

    /// `run_kernel` with an extra multiplicative cost factor: the app's
    /// post-shrink working-set inflation (see [`NewWorld::work_scale`]).
    /// Scaling touches only the charged virtual time, never the kernel
    /// outputs, so checkpoints and digests are unaffected.
    pub async fn run_kernel_scaled(
        &self,
        name: &str,
        inputs: &[crate::runtime::ArrayF32],
        work_scale: f64,
    ) -> Vec<crate::runtime::ArrayF32> {
        let (outs, cost) = self.backend.execute(name, inputs);
        let f = self.comm.fault_tolerance_compute_factor() * work_scale;
        self.sim
            .sleep(crate::sim::SimDuration::from_secs_f64(cost.secs_f64() * f))
            .await;
        outs
    }
}

/// World shape after a shrinking recovery, handed to
/// [`AppState::repartition`]. The *logical* rank count — the domain
/// decomposition width, ReStore's invariant block count — never changes;
/// what shrinks is the number of live processes carrying those blocks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NewWorld {
    /// Logical ranks (== the job's configured `ranks`).
    pub logical: u32,
    /// Live processes after the shrink (`min_ranks ..= logical`).
    pub procs: u32,
}

impl NewWorld {
    /// Modeled per-rank compute inflation: with `logical` blocks spread
    /// over `procs` survivors, each process serializes `logical / procs`
    /// blocks' worth of kernel work per iteration on average. Data is
    /// untouched — shrink trades permanently slower iterations for
    /// zero respawn cost and zero spare nodes.
    pub fn work_scale(&self) -> f64 {
        assert!(
            self.procs >= 1 && self.procs <= self.logical,
            "NewWorld{{logical: {}, procs: {}}}",
            self.logical,
            self.procs
        );
        self.logical as f64 / self.procs as f64
    }
}

/// Per-rank application state.
pub trait AppState {
    /// Checkpoint payload (paper: what the app saves every iteration).
    fn serialize(&self) -> Vec<u8>;
    /// Restore from a checkpoint payload.
    fn restore(&mut self, bytes: &[u8]);
    /// Order-stable content hash (equivalence tests).
    fn digest(&self) -> u64 {
        fnv1a(&self.serialize())
    }
    /// Scalar progress diagnostic after each step (HPCCG: relative residual;
    /// CoMD: total energy; LULESH: global dt). Used for the e2e examples'
    /// convergence traces.
    fn diagnostic(&self) -> f64 {
        0.0
    }
    /// Adapt modeled costs to a shrunken world (called by the rank driver
    /// after a shrinking recovery, before `restore`). Must not change the
    /// checkpoint payload or digest — the decomposition stays at
    /// `world.logical` blocks; only the live processor grid and the
    /// per-rank working-set scale move. Default: no-op.
    fn repartition(&mut self, _world: NewWorld) {}
    /// One main-loop iteration.
    fn step<'a>(&'a mut self, cx: StepCtx<'a>, iter: u32)
        -> LocalBoxFuture<'a, Result<(), MpiError>>;
}

/// Application factory (one per proxy app).
pub trait App {
    fn name(&self) -> String;
    fn new_state(&self, rank: u32, size: u32) -> Box<dyn AppState>;
}

/// Build the configured app.
pub fn make_app(cfg: &ExperimentConfig) -> Rc<dyn App> {
    match cfg.app {
        AppKind::CoMD => Rc::new(ComdApp {
            n: cfg.comd_n,
            seed: cfg.seed,
        }),
        AppKind::Hpccg => Rc::new(HpccgApp {
            nx: cfg.hpccg_nx,
            seed: cfg.seed,
        }),
        AppKind::Lulesh => Rc::new(LuleshApp {
            nx: cfg.lulesh_nx,
            seed: cfg.seed,
        }),
    }
}

/// FNV-1a 64-bit (digests).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

// ---- checkpoint codec: length-prefixed f32 blocks -------------------------

/// Serialize f32 blocks: [count u32][len u32, data f32*]*.
pub fn encode_blocks(parts: &[&[f32]]) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&(parts.len() as u32).to_le_bytes());
    for p in parts {
        out.extend_from_slice(&(p.len() as u32).to_le_bytes());
        for x in *p {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }
    out
}

/// Inverse of `encode_blocks`.
pub fn decode_blocks(bytes: &[u8]) -> Vec<Vec<f32>> {
    let mut pos = 0usize;
    let read_u32 = |pos: &mut usize| {
        let v = u32::from_le_bytes(bytes[*pos..*pos + 4].try_into().unwrap());
        *pos += 4;
        v
    };
    let count = read_u32(&mut pos) as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let len = read_u32(&mut pos) as usize;
        let mut v = Vec::with_capacity(len);
        for _ in 0..len {
            v.push(f32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()));
            pos += 4;
        }
        out.push(v);
    }
    assert_eq!(pos, bytes.len(), "trailing checkpoint bytes");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_codec_roundtrip() {
        let a = vec![1.0f32, -2.5, 3.25];
        let b = vec![0.0f32];
        let c: Vec<f32> = vec![];
        let enc = encode_blocks(&[&a, &b, &c]);
        assert_eq!(decode_blocks(&enc), vec![a, b, c]);
    }

    #[test]
    fn fnv_distinguishes() {
        assert_ne!(fnv1a(b"abc"), fnv1a(b"abd"));
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
    }

    #[test]
    fn make_app_dispatch() {
        let mut cfg = ExperimentConfig::default();
        for (kind, name) in [
            (AppKind::CoMD, "comd"),
            (AppKind::Hpccg, "hpccg"),
            (AppKind::Lulesh, "lulesh"),
        ] {
            cfg.app = kind;
            assert!(make_app(&cfg).name().starts_with(name));
        }
    }

    #[test]
    #[should_panic(expected = "trailing checkpoint bytes")]
    fn decode_rejects_garbage_suffix() {
        let mut enc = encode_blocks(&[&[1.0f32]]);
        enc.push(0);
        decode_blocks(&enc);
    }

    #[test]
    fn work_scale_is_adoption_ratio() {
        assert_eq!(NewWorld { logical: 8, procs: 8 }.work_scale(), 1.0);
        assert_eq!(NewWorld { logical: 8, procs: 4 }.work_scale(), 2.0);
        assert_eq!(NewWorld { logical: 8, procs: 5 }.work_scale(), 1.6);
        assert_eq!(NewWorld { logical: 1, procs: 1 }.work_scale(), 1.0);
    }

    #[test]
    #[should_panic(expected = "NewWorld")]
    fn work_scale_rejects_grown_world() {
        NewWorld { logical: 4, procs: 5 }.work_scale();
    }

    #[test]
    #[should_panic(expected = "NewWorld")]
    fn work_scale_rejects_empty_world() {
        NewWorld { logical: 4, procs: 0 }.work_scale();
    }
}
