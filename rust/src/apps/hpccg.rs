//! HPCCG proxy: distributed conjugate gradient on the 27-point stencil
//! operator, weak-scaled with an nx³ subdomain per rank.
//!
//! The CG iteration is split at its two allreduce points exactly like the
//! real HPCCG (ddot after the matvec for alpha, ddot on the new residual for
//! beta) with a 6-face halo exchange of the search direction before each
//! matvec (exch_externals). All reductions run through the deterministic
//! tree allreduce, so the distributed solve is bitwise reproducible.

use super::halo::{build_halo, exchange_faces, grid3};
use super::{decode_blocks, encode_blocks, AppState, LocalBoxFuture, NewWorld, StepCtx};
use crate::mpi::{MpiError, ReduceOp};
use crate::runtime::ArrayF32;
use crate::sim::rng::Rng;

/// Factory for per-rank HPCCG state.
pub struct HpccgApp {
    pub nx: u32,
    pub seed: u64,
}

impl super::App for HpccgApp {
    fn name(&self) -> String {
        format!("hpccg_nx{}", self.nx)
    }

    fn new_state(&self, rank: u32, size: u32) -> Box<dyn AppState> {
        Box::new(HpccgState::new(self.nx as usize, self.seed, rank, size))
    }
}

pub struct HpccgState {
    _rank: u32,
    /// Logical decomposition — pinned at `grid3(ranks)` for the job's whole
    /// life (ReStore's invariant block count); halo partners never change.
    dims: (u32, u32, u32),
    /// Live processor grid, re-derived over the survivor count by
    /// `repartition`. Model-only: not serialized, not digested.
    live_grid: (u32, u32, u32),
    /// Post-shrink compute inflation (`NewWorld::work_scale`); model-only.
    work_scale: f64,
    nx: usize,
    x: Vec<f32>,
    r: Vec<f32>,
    p: Vec<f32>,
    /// Global r.r of the current residual (valid once rr_init).
    rr: f32,
    rr_init: bool,
    /// Residual norm ratio (diagnostic).
    pub rel_residual: f32,
    rr0: f32,
}

impl HpccgState {
    pub fn new(nx: usize, seed: u64, rank: u32, size: u32) -> Self {
        let mut rng = Rng::new(seed).fork(&format!("hpccg-init-r{rank}"));
        let n = nx * nx * nx;
        let b: Vec<f32> = (0..n).map(|_| rng.gen_f32_range(-0.5, 0.5)).collect();
        HpccgState {
            _rank: rank,
            dims: grid3(size),
            live_grid: grid3(size),
            work_scale: 1.0,
            nx,
            x: vec![0.0; n],
            r: b.clone(),
            p: b,
            rr: 0.0,
            rr_init: false,
            rel_residual: 1.0,
            rr0: 0.0,
        }
    }

    fn shape(&self) -> Vec<usize> {
        vec![self.nx, self.nx, self.nx]
    }

    /// The processor grid currently carrying the blocks (tests/diagnostics).
    pub fn live_grid(&self) -> (u32, u32, u32) {
        self.live_grid
    }
}

impl AppState for HpccgState {
    fn serialize(&self) -> Vec<u8> {
        let scalars = [
            self.rr,
            if self.rr_init { 1.0 } else { 0.0 },
            self.rel_residual,
            self.rr0,
        ];
        encode_blocks(&[&self.x, &self.r, &self.p, &scalars])
    }

    fn restore(&mut self, bytes: &[u8]) {
        let blocks = decode_blocks(bytes);
        assert_eq!(blocks.len(), 4, "HPCCG checkpoint layout");
        self.x = blocks[0].clone();
        self.r = blocks[1].clone();
        self.p = blocks[2].clone();
        self.rr = blocks[3][0];
        self.rr_init = blocks[3][1] != 0.0;
        self.rel_residual = blocks[3][2];
        self.rr0 = blocks[3][3];
    }

    fn diagnostic(&self) -> f64 {
        self.rel_residual as f64
    }

    fn repartition(&mut self, world: NewWorld) {
        // `dims` stays: the decomposition keeps `world.logical` blocks so
        // halo partners, reductions and hence digests are unchanged. The
        // survivors just run hotter.
        self.live_grid = grid3(world.procs);
        self.work_scale = world.work_scale();
    }

    fn step<'a>(
        &'a mut self,
        cx: StepCtx<'a>,
        _iter: u32,
    ) -> LocalBoxFuture<'a, Result<(), MpiError>> {
        Box::pin(async move {
            let nx = self.nx;
            if !self.rr_init {
                let local: f32 = self.r.iter().map(|v| v * v).sum();
                self.rr = cx.comm.allreduce_scalar(local, ReduceOp::Sum).await?;
                self.rr0 = self.rr;
                self.rr_init = true;
            }
            // exch_externals: ship p's faces to the 6 neighbours
            let faces = exchange_faces(cx.comm, self.dims, &self.p, nx).await?;
            let p_halo = build_halo(&self.p, nx, &faces);

            let ws = self.work_scale;
            let mut outs = cx
                .run_kernel_scaled(
                    &format!("hpccg_matvec_{nx}"),
                    &[ArrayF32::new(vec![nx + 2, nx + 2, nx + 2], p_halo)],
                    ws,
                )
                .await;
            let pap_local = outs[1].as_scalar();
            let ap = std::mem::take(&mut outs[0].data);
            let pap = cx.comm.allreduce_scalar(pap_local, ReduceOp::Sum).await?;
            let alpha = if pap != 0.0 { self.rr / pap } else { 0.0 };

            let mut outs = cx
                .run_kernel_scaled(
                    &format!("hpccg_update_{nx}"),
                    &[
                        ArrayF32::new(self.shape(), self.x.clone()),
                        ArrayF32::new(self.shape(), self.r.clone()),
                        ArrayF32::new(self.shape(), self.p.clone()),
                        ArrayF32::new(self.shape(), ap),
                        ArrayF32::scalar(alpha),
                    ],
                    ws,
                )
                .await;
            let rr_local = outs[2].as_scalar();
            self.x = std::mem::take(&mut outs[0].data);
            self.r = std::mem::take(&mut outs[1].data);
            let rr_new = cx.comm.allreduce_scalar(rr_local, ReduceOp::Sum).await?;
            let beta = if self.rr != 0.0 { rr_new / self.rr } else { 0.0 };

            let mut outs = cx
                .run_kernel_scaled(
                    &format!("hpccg_direction_{nx}"),
                    &[
                        ArrayF32::new(self.shape(), self.r.clone()),
                        ArrayF32::new(self.shape(), self.p.clone()),
                        ArrayF32::scalar(beta),
                    ],
                    ws,
                )
                .await;
            self.p = std::mem::take(&mut outs[0].data);
            self.rr = rr_new;
            self.rel_residual = if self.rr0 > 0.0 {
                (rr_new / self.rr0).sqrt()
            } else {
                0.0
            };
            Ok(())
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::App;

    #[test]
    fn init_deterministic_and_rank_distinct() {
        let a = HpccgState::new(8, 5, 0, 8);
        let b = HpccgState::new(8, 5, 0, 8);
        let c = HpccgState::new(8, 5, 1, 8);
        assert_eq!(a.r, b.r);
        assert_ne!(a.r, c.r);
        assert!(a.x.iter().all(|&v| v == 0.0));
        assert_eq!(a.r, a.p, "p0 = r0 = b");
    }

    #[test]
    fn checkpoint_roundtrip() {
        let app = HpccgApp { nx: 8, seed: 5 };
        let a = app.new_state(2, 8);
        let mut b = app.new_state(3, 8);
        assert_ne!(a.digest(), b.digest());
        b.restore(&a.serialize());
        assert_eq!(a.digest(), b.digest());
    }

    #[test]
    fn repartition_rescales_model_not_state() {
        let mut s = HpccgState::new(8, 5, 2, 8);
        let before = s.serialize();
        let d = s.digest();
        s.repartition(NewWorld { logical: 8, procs: 5 });
        assert_eq!(s.live_grid(), grid3(5), "live grid follows survivors");
        assert_eq!(s.dims, grid3(8), "decomposition is pinned");
        assert_eq!(s.work_scale, 1.6);
        assert_eq!(s.serialize(), before, "checkpoint payload untouched");
        assert_eq!(s.digest(), d, "digest untouched");
    }

    #[test]
    fn checkpoint_size_matches_three_vectors() {
        let app = HpccgApp { nx: 16, seed: 0 };
        let s = app.new_state(0, 1);
        let bytes = s.serialize().len();
        let expect = 4 + 4 * 4 + 3 * 16 * 16 * 16 * 4 + 4 * 4;
        assert_eq!(bytes, expect);
    }
}
