//! Live cluster state: root/daemon/rank simulated processes, slot
//! accounting, kill cascades, and Algorithm 1's least-loaded-node choice.

use std::cell::RefCell;
use std::rc::Rc;

use super::topology::Topology;
use crate::sim::{ProcId, ProcName, Sim};

/// Where a rank currently lives.
#[derive(Clone, Copy, Debug)]
pub struct RankSlot {
    pub proc: ProcId,
    pub node: u32,
    /// Bumped on every re-spawn; composes fabric endpoint keys.
    pub incarnation: u32,
}

/// Static + liveness info for a node.
#[derive(Clone, Copy, Debug)]
pub struct NodeInfo {
    pub id: u32,
    pub alive: bool,
    pub occupied_slots: u32,
}

struct Inner {
    root: ProcId,
    daemons: Vec<ProcId>,
    node_alive: Vec<bool>,
    ranks: Vec<RankSlot>,
    /// Shared `"{job_tag}/rank"` prefix for lazy process names — spawning
    /// (or re-spawning) a rank must not pay a `format!` per process, or
    /// 16k-rank trial setup is dominated by name strings.
    rank_prefix: Rc<str>,
}

/// Shared handle to the cluster state (one per job incarnation).
pub struct Cluster {
    sim: Sim,
    pub topo: Topology,
    inner: Rc<RefCell<Inner>>,
}

impl Clone for Cluster {
    fn clone(&self) -> Self {
        Cluster {
            sim: self.sim.clone(),
            topo: self.topo,
            inner: Rc::clone(&self.inner),
        }
    }
}

impl Cluster {
    /// Create root, one daemon per node (incl. spares), and one process per
    /// rank at its home node. (The *cost* of doing this is charged by the
    /// job driver via `DeployCost::mpirun_launch`.)
    pub fn new(sim: &Sim, topo: Topology, job_tag: &str) -> Self {
        let root = sim.spawn_process(format!("{job_tag}/root"));
        let daemon_prefix: Rc<str> = Rc::from(format!("{job_tag}/daemon"));
        let rank_prefix: Rc<str> = Rc::from(format!("{job_tag}/rank"));
        let daemons: Vec<ProcId> = (0..topo.total_nodes())
            .map(|n| {
                sim.spawn_process(ProcName::Indexed {
                    prefix: Rc::clone(&daemon_prefix),
                    index: n,
                    sub: None,
                })
            })
            .collect();
        let ranks: Vec<RankSlot> = (0..topo.ranks)
            .map(|r| {
                let node = topo.home_node(r);
                RankSlot {
                    proc: sim.spawn_process(ProcName::Indexed {
                        prefix: Rc::clone(&rank_prefix),
                        index: r,
                        sub: Some(0),
                    }),
                    node,
                    incarnation: 0,
                }
            })
            .collect();
        // Topology-aligned shard placement: each proc's events run on the
        // executor shard owning its home node, so intra-node traffic never
        // crosses a shard queue. No-op on the serial (1-shard) executor.
        let shards = sim.shard_count() as u32;
        if shards > 1 {
            sim.assign_proc_shard(root, 0);
            for (n, &d) in daemons.iter().enumerate() {
                sim.assign_proc_shard(d, topo.shard_of_node(n as u32, shards) as u16);
            }
            for slot in &ranks {
                sim.assign_proc_shard(slot.proc, topo.shard_of_node(slot.node, shards) as u16);
            }
        }
        Cluster {
            sim: sim.clone(),
            topo,
            inner: Rc::new(RefCell::new(Inner {
                root,
                daemons,
                node_alive: vec![true; topo.total_nodes() as usize],
                ranks,
                rank_prefix,
            })),
        }
    }

    pub fn root(&self) -> ProcId {
        self.inner.borrow().root
    }

    pub fn daemon(&self, node: u32) -> ProcId {
        self.inner.borrow().daemons[node as usize]
    }

    pub fn rank_slot(&self, rank: u32) -> RankSlot {
        self.inner.borrow().ranks[rank as usize]
    }

    pub fn node_is_alive(&self, node: u32) -> bool {
        self.inner.borrow().node_alive[node as usize]
    }

    pub fn rank_is_alive(&self, rank: u32) -> bool {
        self.sim.is_alive(self.rank_slot(rank).proc)
    }

    /// Kill one MPI process (fail-stop).
    pub fn kill_rank(&self, rank: u32) {
        let proc = self.rank_slot(rank).proc;
        self.sim.kill(proc);
    }

    /// Kill a node: its daemon and every MPI process currently placed there
    /// die at the same instant (the paper equates daemon and node failure).
    pub fn kill_node(&self, node: u32) {
        let (daemon, victims): (ProcId, Vec<ProcId>) = {
            let inner = self.inner.borrow();
            (
                inner.daemons[node as usize],
                inner
                    .ranks
                    .iter()
                    .filter(|s| s.node == node)
                    .map(|s| s.proc)
                    .collect(),
            )
        };
        self.inner.borrow_mut().node_alive[node as usize] = false;
        self.sim.kill(daemon);
        for p in victims {
            self.sim.kill(p);
        }
    }

    /// Re-spawn `rank` on `node`; returns the new process. Panics if the
    /// node is dead (Algorithm 1 never selects a dead node).
    pub fn respawn_rank(&self, rank: u32, node: u32) -> ProcId {
        let mut inner = self.inner.borrow_mut();
        assert!(inner.node_alive[node as usize], "respawn on dead node {node}");
        let prefix = Rc::clone(&inner.rank_prefix);
        let slot = &mut inner.ranks[rank as usize];
        slot.incarnation += 1;
        slot.node = node;
        slot.proc = self.sim.spawn_process(ProcName::Indexed {
            prefix,
            index: rank,
            sub: Some(slot.incarnation),
        });
        let shards = self.sim.shard_count() as u32;
        if shards > 1 {
            // A re-spawn may land on a spare in a different shard block.
            self.sim
                .assign_proc_shard(slot.proc, self.topo.shard_of_node(node, shards) as u16);
        }
        slot.proc
    }

    /// Shrinking recovery: a surviving node *adopts* a dead rank's domain
    /// block — mechanically a re-spawn (fresh process, bumped incarnation,
    /// new placement), but the job driver charges no fork+exec for it: the
    /// block is re-hosted inside an already-running survivor process, not
    /// launched. Panics if `node` is dead, like `respawn_rank`.
    pub fn rehost_rank(&self, rank: u32, node: u32) -> ProcId {
        self.respawn_rank(rank, node)
    }

    /// Algorithm 1 restricted to *compute* nodes: the least-loaded alive
    /// node that is not a spare, or `None` if every compute node is dead.
    /// Shrinking recovery places adopted blocks with this — by definition
    /// it must never draw on the spare pool.
    pub fn least_loaded_alive_compute_node(&self) -> Option<u32> {
        (0..self.topo.compute_nodes)
            .filter(|&node| self.node_is_alive(node))
            .min_by_key(|&node| (self.occupied_slots(node), node))
    }

    /// Alive MPI processes currently placed on `node`.
    pub fn occupied_slots(&self, node: u32) -> u32 {
        let inner = self.inner.borrow();
        inner
            .ranks
            .iter()
            .filter(|s| s.node == node && self.sim.is_alive(s.proc))
            .count() as u32
    }

    /// Algorithm 1: `argmin_{d in D} |Children(d)|` over *alive* daemons;
    /// deterministic tie-break on the lowest node id.
    pub fn least_loaded_alive_node(&self) -> u32 {
        let n = self.topo.total_nodes();
        (0..n)
            .filter(|&node| self.node_is_alive(node))
            .min_by_key(|&node| (self.occupied_slots(node), node))
            .expect("no alive node left")
    }

    /// All ranks whose current process is dead.
    pub fn failed_ranks(&self) -> Vec<u32> {
        (0..self.topo.ranks)
            .filter(|&r| !self.rank_is_alive(r))
            .collect()
    }

    /// All ranks whose current process is alive.
    pub fn alive_ranks(&self) -> Vec<u32> {
        (0..self.topo.ranks)
            .filter(|&r| self.rank_is_alive(r))
            .collect()
    }

    /// Snapshot of node occupancy (debug/metrics).
    pub fn nodes(&self) -> Vec<NodeInfo> {
        (0..self.topo.total_nodes())
            .map(|id| NodeInfo {
                id,
                alive: self.node_is_alive(id),
                occupied_slots: self.occupied_slots(id),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cluster(ranks: u32, rpn: u32, spares: u32) -> (Sim, Cluster) {
        let sim = Sim::new();
        let c = Cluster::new(&sim, Topology::new(ranks, rpn, spares), "job0");
        (sim, c)
    }

    #[test]
    fn initial_placement_and_liveness() {
        let (_sim, c) = cluster(32, 16, 1);
        assert_eq!(c.alive_ranks().len(), 32);
        assert!(c.failed_ranks().is_empty());
        assert_eq!(c.occupied_slots(0), 16);
        assert_eq!(c.occupied_slots(1), 16);
        assert_eq!(c.occupied_slots(2), 0); // spare
    }

    #[test]
    fn kill_rank_updates_liveness_and_slots() {
        let (_sim, c) = cluster(32, 16, 0);
        c.kill_rank(5);
        assert!(!c.rank_is_alive(5));
        assert_eq!(c.failed_ranks(), vec![5]);
        assert_eq!(c.occupied_slots(0), 15);
    }

    #[test]
    fn kill_node_cascades_to_children() {
        let (sim, c) = cluster(32, 16, 1);
        c.kill_node(1);
        assert!(!c.node_is_alive(1));
        assert!(!sim.is_alive(c.daemon(1)));
        assert_eq!(c.failed_ranks(), (16..32).collect::<Vec<_>>());
        assert_eq!(c.occupied_slots(1), 0);
    }

    #[test]
    fn least_loaded_picks_spare_after_node_failure() {
        let (_sim, c) = cluster(32, 16, 1);
        c.kill_node(0);
        // nodes: 0 dead, 1 has 16, 2 (spare) has 0
        assert_eq!(c.least_loaded_alive_node(), 2);
    }

    #[test]
    fn least_loaded_tie_breaks_deterministically() {
        let (_sim, c) = cluster(32, 16, 2);
        // spares 2 and 3 both empty -> lowest id wins
        assert_eq!(c.least_loaded_alive_node(), 2);
    }

    #[test]
    fn respawn_moves_rank_and_bumps_incarnation() {
        let (sim, c) = cluster(32, 16, 1);
        c.kill_node(1);
        let target = c.least_loaded_alive_node();
        for r in 16..32 {
            let p = c.respawn_rank(r, target);
            assert!(sim.is_alive(p));
        }
        assert!(c.failed_ranks().is_empty());
        assert_eq!(c.occupied_slots(target), 16);
        let slot = c.rank_slot(20);
        assert_eq!(slot.node, 2);
        assert_eq!(slot.incarnation, 1);
    }

    #[test]
    #[should_panic(expected = "respawn on dead node")]
    fn respawn_on_dead_node_panics() {
        let (_sim, c) = cluster(16, 16, 0);
        c.kill_node(0);
        c.respawn_rank(0, 0);
    }

    #[test]
    fn process_failure_respawns_on_original_node() {
        // paper §3.2: process failures re-spawn on the original node
        let (_sim, c) = cluster(32, 16, 0);
        c.kill_rank(20);
        let node = c.rank_slot(20).node;
        c.respawn_rank(20, node);
        assert!(c.rank_is_alive(20));
        assert_eq!(c.rank_slot(20).node, 1);
        assert_eq!(c.occupied_slots(1), 16);
    }

    #[test]
    fn compute_node_choice_never_picks_spares() {
        let (_sim, c) = cluster(32, 16, 2);
        c.kill_node(0);
        // substitute path would pick spare node 2; shrink must stay on
        // the surviving compute node 1 even though it is fuller
        assert_eq!(c.least_loaded_alive_node(), 2);
        assert_eq!(c.least_loaded_alive_compute_node(), Some(1));
        c.kill_node(1);
        assert_eq!(c.least_loaded_alive_compute_node(), None);
    }

    #[test]
    fn rehost_adopts_onto_survivor() {
        let (sim, c) = cluster(32, 16, 0);
        c.kill_node(1);
        for r in 16..32 {
            let p = c.rehost_rank(r, c.least_loaded_alive_compute_node().unwrap());
            assert!(sim.is_alive(p));
        }
        assert_eq!(c.occupied_slots(0), 32, "survivor carries every block");
        assert_eq!(c.rank_slot(20).incarnation, 1);
    }

    #[test]
    fn nodes_snapshot() {
        let (_sim, c) = cluster(16, 16, 1);
        let nodes = c.nodes();
        assert_eq!(nodes.len(), 2);
        assert!(nodes[0].alive && nodes[0].occupied_slots == 16);
        assert!(nodes[1].alive && nodes[1].occupied_slots == 0);
    }
}
