//! Cluster topology, deployment cost model, and live cluster state.
//!
//! Mirrors the paper's deployment model (§3.1, Fig. 3): a single *root*
//! process (Open MPI's HNP, on the login node) spawns one *daemon* per
//! compute node; daemons spawn and monitor the node-local *MPI processes*.
//! For node-failure experiments the allocation is over-provisioned with
//! spare nodes (paper §3.2).

mod deploy;
mod state;
mod topology;

pub use deploy::DeployCost;
pub use state::{Cluster, NodeInfo, RankSlot};
pub use topology::Topology;
