//! Static placement: which rank lives on which node.

/// Rank/node arithmetic for a block placement of `ranks` MPI processes at
/// `ranks_per_node` per node, plus idle spare nodes at the end of the
/// allocation (paper §3.2: over-provisioning for node failures).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Topology {
    pub ranks: u32,
    pub ranks_per_node: u32,
    pub compute_nodes: u32,
    pub spare_nodes: u32,
}

impl Topology {
    pub fn new(ranks: u32, ranks_per_node: u32, spare_nodes: u32) -> Self {
        assert!(ranks > 0 && ranks_per_node > 0);
        Topology {
            ranks,
            ranks_per_node,
            compute_nodes: ranks.div_ceil(ranks_per_node),
            spare_nodes,
        }
    }

    pub fn total_nodes(&self) -> u32 {
        self.compute_nodes + self.spare_nodes
    }

    /// Node a rank is initially placed on.
    pub fn home_node(&self, rank: u32) -> u32 {
        assert!(rank < self.ranks);
        rank / self.ranks_per_node
    }

    /// Ranks initially placed on `node` (empty for spares).
    pub fn ranks_on_node(&self, node: u32) -> Vec<u32> {
        if node >= self.compute_nodes {
            return Vec::new();
        }
        let lo = node * self.ranks_per_node;
        let hi = ((node + 1) * self.ranks_per_node).min(self.ranks);
        (lo..hi).collect()
    }

    /// Executor shard owning `node` under a node-aligned partition of the
    /// allocation into `shards` contiguous blocks. Ranks sharing a node
    /// (the intra-node fast path) always share a shard, so only
    /// cross-node traffic can cross shards — which is what makes the
    /// calibration's minimum remote latency a sound lookahead horizon.
    pub fn shard_of_node(&self, node: u32, shards: u32) -> u32 {
        assert!(shards > 0);
        let per = self.total_nodes().div_ceil(shards);
        (node / per).min(shards - 1)
    }

    /// Node ranges `[lo, hi)` covered by each shard (possibly empty for
    /// trailing shards when `shards > total_nodes()`).
    pub fn shard_blocks(&self, shards: u32) -> Vec<(u32, u32)> {
        assert!(shards > 0);
        let total = self.total_nodes();
        let per = total.div_ceil(shards);
        (0..shards)
            .map(|s| ((s * per).min(total), ((s + 1) * per).min(total)))
            .collect()
    }

    /// Depth of a binomial/binary communication tree over `n` participants.
    pub fn tree_levels(n: u32) -> u32 {
        if n <= 1 {
            0
        } else {
            32 - (n - 1).leading_zeros() // ceil(log2(n))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_placement() {
        let t = Topology::new(32, 16, 1);
        assert_eq!(t.compute_nodes, 2);
        assert_eq!(t.total_nodes(), 3);
        assert_eq!(t.home_node(0), 0);
        assert_eq!(t.home_node(15), 0);
        assert_eq!(t.home_node(16), 1);
        assert_eq!(t.ranks_on_node(0), (0..16).collect::<Vec<_>>());
        assert_eq!(t.ranks_on_node(2), Vec::<u32>::new()); // spare
    }

    #[test]
    fn ragged_last_node() {
        let t = Topology::new(20, 16, 0);
        assert_eq!(t.compute_nodes, 2);
        assert_eq!(t.ranks_on_node(1), (16..20).collect::<Vec<_>>());
    }

    #[test]
    fn paper_scales() {
        // Table 1: 16 ranks/node, 16..1024 ranks = 1..64 nodes
        for (ranks, nodes) in [(16, 1), (64, 4), (1024, 64)] {
            assert_eq!(Topology::new(ranks, 16, 0).compute_nodes, nodes);
        }
    }

    #[test]
    fn tree_levels_log2ceil() {
        assert_eq!(Topology::tree_levels(1), 0);
        assert_eq!(Topology::tree_levels(2), 1);
        assert_eq!(Topology::tree_levels(3), 2);
        assert_eq!(Topology::tree_levels(64), 6);
        assert_eq!(Topology::tree_levels(1024), 10);
    }

    #[test]
    fn shard_blocks_are_node_aligned_and_cover_everything() {
        let t = Topology::new(64, 16, 2); // 4 compute + 2 spare = 6 nodes
        for shards in [1, 2, 3, 4, 6, 8] {
            let blocks = t.shard_blocks(shards);
            assert_eq!(blocks.len(), shards as usize);
            // blocks are contiguous, disjoint, and cover [0, total_nodes)
            let mut next = 0;
            for (s, &(lo, hi)) in blocks.iter().enumerate() {
                assert_eq!(lo, next);
                assert!(hi >= lo);
                next = hi;
                for node in lo..hi {
                    assert_eq!(t.shard_of_node(node, shards), s as u32);
                }
            }
            assert_eq!(next, t.total_nodes());
        }
    }

    #[test]
    fn one_shard_owns_all_nodes() {
        let t = Topology::new(20, 16, 1);
        for node in 0..t.total_nodes() {
            assert_eq!(t.shard_of_node(node, 1), 0);
        }
        assert_eq!(t.shard_blocks(1), vec![(0, t.total_nodes())]);
    }

    #[test]
    fn co_resident_ranks_share_a_shard() {
        let t = Topology::new(128, 16, 0);
        for shards in [2, 4] {
            for node in 0..t.compute_nodes {
                let s = t.shard_of_node(node, shards);
                for r in t.ranks_on_node(node) {
                    assert_eq!(t.shard_of_node(t.home_node(r), shards), s);
                }
            }
        }
    }

    #[test]
    fn every_rank_has_exactly_one_home() {
        let t = Topology::new(100, 7, 2);
        let mut seen = vec![0u32; 100];
        for node in 0..t.total_nodes() {
            for r in t.ranks_on_node(node) {
                assert_eq!(t.home_node(r), node);
                seen[r as usize] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1));
    }
}
