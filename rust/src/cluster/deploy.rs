//! Process-management cost model: what `mpirun`, daemon spawning, teardown
//! and wireup charge to virtual time. Constants from `config::Calibration`
//! (DESIGN.md §6); anchored to the paper's ≈3 s CR re-deploy, ≈0.5 s / 1.5 s
//! Reinit++ process/node recovery.

use super::topology::Topology;
use crate::config::Calibration;
use crate::sim::SimDuration;

/// Deployment/teardown/respawn costs.
#[derive(Clone, Debug)]
pub struct DeployCost {
    fork_exec: SimDuration,
    daemon_launch_per_level: SimDuration,
    spawn_serialize: SimDuration,
    teardown: SimDuration,
    mpirun_base: SimDuration,
    wireup_per_level: SimDuration,
    orte_barrier_per_level: SimDuration,
    comm_reinit: SimDuration,
    sigchld_notify: SimDuration,
    tcp_break_detect: SimDuration,
    signal_local: SimDuration,
}

fn ms(v: f64) -> SimDuration {
    SimDuration::from_secs_f64(v * 1e-3)
}

impl DeployCost {
    pub fn from_calib(c: &Calibration) -> Self {
        DeployCost {
            fork_exec: ms(c.fork_exec_ms),
            daemon_launch_per_level: ms(c.daemon_launch_per_level_ms),
            spawn_serialize: ms(c.spawn_serialize_ms),
            teardown: SimDuration::from_secs_f64(c.teardown_s),
            mpirun_base: SimDuration::from_secs_f64(c.mpirun_base_s),
            wireup_per_level: ms(c.wireup_per_level_ms),
            orte_barrier_per_level: ms(c.orte_barrier_per_level_ms),
            comm_reinit: ms(c.comm_reinit_ms),
            sigchld_notify: ms(c.sigchld_notify_ms),
            tcp_break_detect: ms(c.tcp_break_detect_ms),
            signal_local: SimDuration::from_secs_f64(c.signal_local_us * 1e-6),
        }
    }

    /// Spawning `k` MPI processes on ONE node: first pays full fork+exec,
    /// subsequent ones pipeline at the serialization cost.
    pub fn node_spawn(&self, k: u32) -> SimDuration {
        if k == 0 {
            return SimDuration::ZERO;
        }
        self.fork_exec + SimDuration(self.spawn_serialize.0 * (k as u64 - 1))
    }

    /// Full `mpirun` launch: base + daemon tree launch (parallel across the
    /// tree, cost per level) + node-local spawns (parallel across nodes) +
    /// MPI_Init wireup (tree address exchange over all ranks).
    pub fn mpirun_launch(&self, topo: &Topology) -> SimDuration {
        let daemon_levels = Topology::tree_levels(topo.total_nodes() + 1); // root + daemons
        let wireup_levels = Topology::tree_levels(topo.ranks);
        self.mpirun_base
            + SimDuration(self.daemon_launch_per_level.0 * daemon_levels as u64)
            + self.node_spawn(topo.ranks_per_node.min(topo.ranks))
            + SimDuration(self.wireup_per_level.0 * wireup_levels as u64)
    }

    /// RTE cleanup after an abort (before CR can re-deploy).
    pub fn teardown(&self) -> SimDuration {
        self.teardown
    }

    /// ORTE-level barrier across daemons+root (Reinit++'s MPI_Init-like sync).
    pub fn orte_barrier(&self, nodes: u32) -> SimDuration {
        SimDuration(self.orte_barrier_per_level.0 * Topology::tree_levels(nodes + 1) as u64)
    }

    /// Re-initialisation of MPI_COMM_WORLD after roll-back/re-spawn.
    pub fn comm_reinit(&self, ranks: u32) -> SimDuration {
        self.comm_reinit + SimDuration(self.wireup_per_level.0 * Topology::tree_levels(ranks) as u64 / 4)
    }

    /// Shrink+agree collective over `procs` survivors (ULFM
    /// `MPI_Comm_shrink` semantics): survivors agree on the dead set and
    /// rebuild the world in place — a comm re-init over the shrunken
    /// process count, plus one extra tree sweep for the agreement vote.
    /// Deliberately cheaper than the substitute path, which also pays
    /// spawn + ORTE barrier before its `comm_reinit`.
    pub fn comm_shrink(&self, procs: u32) -> SimDuration {
        self.comm_reinit(procs)
            + SimDuration(self.wireup_per_level.0 * Topology::tree_levels(procs) as u64 / 4)
    }

    /// SIGCHLD delivery + daemon-side handling of a dead child.
    pub fn sigchld(&self) -> SimDuration {
        self.sigchld_notify
    }

    /// Time for the root to declare a daemon dead from its broken channel.
    pub fn tcp_break(&self) -> SimDuration {
        self.tcp_break_detect
    }

    /// Local signal (SIGREINIT/SIGKILL) delivery + handler entry.
    pub fn signal(&self) -> SimDuration {
        self.signal_local
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cost() -> DeployCost {
        DeployCost::from_calib(&Calibration::default())
    }

    #[test]
    fn cr_redeploy_anchor_about_3s() {
        // paper Fig. 6: CR ≈ 3 s roughly constant across scales
        let c = cost();
        for ranks in [16u32, 64, 256, 1024] {
            let topo = Topology::new(ranks, 16, 0);
            let total = c.teardown() + c.mpirun_launch(&topo);
            let s = total.secs_f64();
            assert!((2.5..4.2).contains(&s), "ranks={ranks}: {s} s");
        }
    }

    #[test]
    fn redeploy_grows_slowly_with_scale() {
        let c = cost();
        let t16 = c.mpirun_launch(&Topology::new(16, 16, 0)).secs_f64();
        let t1024 = c.mpirun_launch(&Topology::new(1024, 16, 0)).secs_f64();
        assert!(t1024 > t16);
        assert!(t1024 / t16 < 1.5, "launch must scale ~flat: {t16} vs {t1024}");
    }

    #[test]
    fn single_respawn_anchor_under_half_second() {
        // Reinit++ process recovery ≈ 0.5 s incl. barrier + comm re-init
        let c = cost();
        let t = (c.sigchld() + c.node_spawn(1) + c.orte_barrier(64) + c.comm_reinit(1024))
            .secs_f64();
        assert!((0.3..0.7).contains(&t), "{t} s");
    }

    #[test]
    fn node_respawn_anchor() {
        // Reinit++ node recovery ≈ 1.5 s: detection + 16 spawns + re-init
        let c = cost();
        let t = (c.tcp_break() + c.node_spawn(16) + c.orte_barrier(64) + c.comm_reinit(1024))
            .secs_f64();
        assert!((1.0..2.0).contains(&t), "{t} s");
    }

    #[test]
    fn shrink_cheaper_than_substitute_recovery() {
        // shrink skips spawn + ORTE barrier entirely; the whole point of
        // continuing on survivors is to beat the respawn path
        let c = cost();
        let shrink = (c.sigchld() + c.comm_shrink(1023)).secs_f64();
        let substitute =
            (c.sigchld() + c.node_spawn(1) + c.orte_barrier(64) + c.comm_reinit(1024)).secs_f64();
        assert!(shrink < substitute, "{shrink} vs {substitute}");
        assert!(c.comm_shrink(512) > c.comm_reinit(512), "agreement sweep is not free");
    }

    #[test]
    fn node_spawn_zero_and_linear() {
        let c = cost();
        assert_eq!(c.node_spawn(0), SimDuration::ZERO);
        let t1 = c.node_spawn(1);
        let t16 = c.node_spawn(16);
        assert!(t16 > t1);
    }
}
