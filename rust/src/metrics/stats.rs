//! Statistics for the harness: mean and 95% confidence interval via the
//! t-distribution (the paper's §4 methodology: 10 trials, t-based CIs with
//! no normality assumption on the population).

/// Two-sided 97.5% t-distribution quantiles for df = 1..=30 (exact table);
/// falls back to the normal quantile 1.96 for larger df.
const T_975: [f64; 30] = [
    12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228,
    2.201, 2.179, 2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086,
    2.080, 2.074, 2.069, 2.064, 2.060, 2.056, 2.052, 2.048, 2.045, 2.042,
];

fn t_quantile_975(df: usize) -> f64 {
    if df == 0 {
        f64::NAN
    } else if df <= 30 {
        T_975[df - 1]
    } else {
        1.96
    }
}

/// Mean, half-width of the 95% CI, and sample count.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub mean: f64,
    pub ci95: f64,
    pub n: usize,
}

impl Summary {
    pub fn lo(&self) -> f64 {
        self.mean - self.ci95
    }
    pub fn hi(&self) -> f64 {
        self.mean + self.ci95
    }
}

/// Sample mean and 95% t-CI half-width. For n = 1 the CI is 0 (degenerate).
pub fn mean_ci95(xs: &[f64]) -> Summary {
    let n = xs.len();
    assert!(n > 0, "mean_ci95 of empty sample");
    let mean = xs.iter().sum::<f64>() / n as f64;
    if n == 1 {
        return Summary { mean, ci95: 0.0, n };
    }
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n as f64 - 1.0);
    let se = (var / n as f64).sqrt();
    Summary {
        mean,
        ci95: t_quantile_975(n - 1) * se,
        n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_df9_quantile() {
        // 10 trials -> df 9 -> 2.262 (the value the paper's CIs use)
        assert_eq!(t_quantile_975(9), 2.262);
    }

    #[test]
    fn constant_sample_zero_ci() {
        let s = mean_ci95(&[3.0; 10]);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.ci95, 0.0);
    }

    #[test]
    fn known_sample() {
        // mean 2, sd 1, n=4 -> se = 0.5, t(3) = 3.182 -> ci = 1.591
        let s = mean_ci95(&[1.0, 2.0, 2.0, 3.0]);
        assert!((s.mean - 2.0).abs() < 1e-12);
        let sd = (2.0f64 / 3.0).sqrt(); // sample sd of [1,2,2,3]
        let expect = 3.182 * sd / 2.0;
        assert!((s.ci95 - expect).abs() < 1e-9, "{} vs {}", s.ci95, expect);
    }

    #[test]
    fn single_sample_degenerate() {
        let s = mean_ci95(&[5.0]);
        assert_eq!((s.mean, s.ci95, s.n), (5.0, 0.0, 1));
    }

    #[test]
    fn ci_shrinks_with_n() {
        let a: Vec<f64> = (0..5).map(|i| i as f64).collect();
        let b: Vec<f64> = (0..50).map(|i| (i % 5) as f64).collect();
        assert!(mean_ci95(&b).ci95 < mean_ci95(&a).ci95);
    }

    #[test]
    fn large_df_uses_normal() {
        assert_eq!(t_quantile_975(100), 1.96);
    }

    #[test]
    #[should_panic]
    fn empty_sample_panics() {
        mean_ci95(&[]);
    }
}
