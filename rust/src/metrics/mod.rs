//! Measurement: the paper's per-run time breakdown and its statistics
//! (mean + 95% confidence intervals from the t-distribution, 10 trials).

mod bench;
mod stats;

pub use bench::{BenchReport, BenchRow};
pub use stats::{mean_ci95, Summary};

use std::cell::RefCell;
use std::rc::Rc;

use crate::ckptstore::StorageStats;
use crate::sim::{SimDuration, SimTime};

/// Phase breakdown of one trial (paper §4 "Statistical evaluation"):
/// total = app + ckpt_write + ckpt_read + mpi_recovery.
#[derive(Clone, Copy, Debug, Default)]
pub struct Breakdown {
    pub total_s: f64,
    pub ckpt_write_s: f64,
    pub ckpt_read_s: f64,
    pub mpi_recovery_s: f64,
}

impl Breakdown {
    /// Pure application time: everything not attributed elsewhere.
    pub fn app_s(&self) -> f64 {
        (self.total_s - self.ckpt_write_s - self.ckpt_read_s - self.mpi_recovery_s).max(0.0)
    }
}

/// Host-side throughput of one sweep (all points × trials): wall-clock,
/// busy seconds summed over workers, and utilization — the parallel sweep
/// scheduler's scoreboard (EXPERIMENTS.md §Perf "Sweep throughput").
#[derive(Clone, Copy, Debug)]
pub struct SweepStats {
    /// Worker threads used (1 = the old serial path).
    pub jobs: usize,
    /// Trials executed across all points.
    pub trials: usize,
    /// Host wall-clock seconds for the whole sweep.
    pub wall_s: f64,
    /// Sum of per-trial host seconds across all workers (busy time).
    pub busy_s: f64,
}

impl SweepStats {
    pub fn trials_per_sec(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.trials as f64 / self.wall_s
        } else {
            0.0
        }
    }

    /// Fraction of worker capacity that ran trials (1.0 = every worker busy
    /// for the whole sweep; low values mean tail/imbalance or tiny sweeps).
    pub fn utilization(&self) -> f64 {
        if self.wall_s > 0.0 && self.jobs > 0 {
            (self.busy_s / (self.jobs as f64 * self.wall_s)).min(1.0)
        } else {
            0.0
        }
    }
}

/// Mean per-trial storage traffic of one experiment point, in MB (ops as a
/// plain count) — the per-tier read/write/rebuild counters and the shared
/// disk's own stats, exported into every sweep CSV row so storage pressure
/// is visible per point.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StorageMeans {
    pub disk_write_mb: f64,
    pub disk_read_mb: f64,
    pub disk_ops: f64,
    pub local_write_mb: f64,
    pub partner_write_mb: f64,
    pub fs_write_mb: f64,
    pub local_read_mb: f64,
    pub partner_read_mb: f64,
    pub fs_read_mb: f64,
    pub rebuild_mb: f64,
    pub drained_mb: f64,
}

impl StorageMeans {
    pub fn from_trials(stats: &[StorageStats]) -> StorageMeans {
        const MB: f64 = 1e6;
        let mut m = StorageMeans::default();
        if stats.is_empty() {
            return m;
        }
        for s in stats {
            m.disk_write_mb += s.disk.bytes_written as f64 / MB;
            m.disk_read_mb += s.disk.bytes_read as f64 / MB;
            m.disk_ops += s.disk.ops as f64;
            m.local_write_mb += s.local.write_bytes as f64 / MB;
            m.partner_write_mb += s.partner.write_bytes as f64 / MB;
            m.fs_write_mb += s.fs.write_bytes as f64 / MB;
            m.local_read_mb += s.local.read_bytes as f64 / MB;
            m.partner_read_mb += s.partner.read_bytes as f64 / MB;
            m.fs_read_mb += s.fs.read_bytes as f64 / MB;
            m.rebuild_mb +=
                (s.local.rebuild_bytes + s.partner.rebuild_bytes + s.fs.rebuild_bytes) as f64
                    / MB;
            m.drained_mb +=
                (s.local.drained_bytes + s.partner.drained_bytes + s.fs.drained_bytes) as f64
                    / MB;
        }
        let n = stats.len() as f64;
        m.disk_write_mb /= n;
        m.disk_read_mb /= n;
        m.disk_ops /= n;
        m.local_write_mb /= n;
        m.partner_write_mb /= n;
        m.fs_write_mb /= n;
        m.local_read_mb /= n;
        m.partner_read_mb /= n;
        m.fs_read_mb /= n;
        m.rebuild_mb /= n;
        m.drained_mb /= n;
        m
    }
}

struct Inner {
    job_start: SimTime,
    job_end: SimTime,
    fail_at: Option<SimTime>,
    resume_at: Option<SimTime>, // max over ranks re-entering the user fn
    /// Per-rank accumulated phase durations (index = rank).
    ckpt_write: Vec<SimDuration>,
    ckpt_read: Vec<SimDuration>,
    /// Extra recovery time outside the fail->resume window (CR: teardown
    /// and re-deploy happen between jobs; already inside the window).
    recovery_extra: SimDuration,
}

/// Shared collector for one trial.
#[derive(Clone)]
pub struct TrialMetrics {
    inner: Rc<RefCell<Inner>>,
}

impl TrialMetrics {
    pub fn new(ranks: u32) -> Self {
        TrialMetrics {
            inner: Rc::new(RefCell::new(Inner {
                job_start: SimTime::ZERO,
                job_end: SimTime::ZERO,
                fail_at: None,
                resume_at: None,
                ckpt_write: vec![SimDuration::ZERO; ranks as usize],
                ckpt_read: vec![SimDuration::ZERO; ranks as usize],
                recovery_extra: SimDuration::ZERO,
            })),
        }
    }

    pub fn set_job_start(&self, t: SimTime) {
        self.inner.borrow_mut().job_start = t;
    }

    pub fn set_job_end(&self, t: SimTime) {
        self.inner.borrow_mut().job_end = t;
    }

    /// Record the failure instant (the kill).
    pub fn record_failure(&self, t: SimTime) {
        let mut inner = self.inner.borrow_mut();
        if inner.fail_at.is_none() {
            inner.fail_at = Some(t);
        }
    }

    /// A rank re-entered the user function after recovery (before loading
    /// its checkpoint); the job-level recovery ends at the slowest rank.
    pub fn record_resume(&self, t: SimTime) {
        let mut inner = self.inner.borrow_mut();
        inner.resume_at = Some(match inner.resume_at {
            None => t,
            Some(prev) => prev.max(t),
        });
    }

    pub fn add_ckpt_write(&self, rank: u32, d: SimDuration) {
        self.inner.borrow_mut().ckpt_write[rank as usize] += d;
    }

    pub fn add_ckpt_read(&self, rank: u32, d: SimDuration) {
        self.inner.borrow_mut().ckpt_read[rank as usize] += d;
    }

    pub fn fail_at(&self) -> Option<SimTime> {
        self.inner.borrow().fail_at
    }

    /// Finalize into the paper's breakdown. Checkpoint phases use the
    /// slowest rank's accumulated time (the BSP stall path); MPI recovery is
    /// the failure->resume window minus the checkpoint read that happens
    /// inside it (read is reported separately, as in the paper).
    pub fn breakdown(&self) -> Breakdown {
        let inner = self.inner.borrow();
        // job_end < job_start means the run never finished (deadlock);
        // report what we have instead of underflowing.
        let total = inner.job_end.saturating_sub(inner.job_start).secs_f64();
        let wr = inner
            .ckpt_write
            .iter()
            .map(|d| d.secs_f64())
            .fold(0.0, f64::max);
        let rd = inner
            .ckpt_read
            .iter()
            .map(|d| d.secs_f64())
            .fold(0.0, f64::max);
        let recovery = match (inner.fail_at, inner.resume_at) {
            (Some(f), Some(r)) => {
                r.saturating_sub(f).secs_f64() + inner.recovery_extra.secs_f64()
            }
            _ => 0.0,
        };
        Breakdown {
            total_s: total,
            ckpt_write_s: wr,
            ckpt_read_s: rd,
            mpi_recovery_s: recovery,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_accounts_all_phases() {
        let m = TrialMetrics::new(2);
        m.set_job_start(SimTime(0));
        m.set_job_end(SimTime(10_000_000_000)); // 10 s
        m.record_failure(SimTime(4_000_000_000));
        m.record_resume(SimTime(4_500_000_000));
        m.record_resume(SimTime(4_400_000_000)); // earlier rank: ignored
        m.add_ckpt_write(0, SimDuration::from_millis(300));
        m.add_ckpt_write(0, SimDuration::from_millis(200));
        m.add_ckpt_write(1, SimDuration::from_millis(400));
        m.add_ckpt_read(1, SimDuration::from_millis(50));
        let b = m.breakdown();
        assert!((b.total_s - 10.0).abs() < 1e-9);
        assert!((b.mpi_recovery_s - 0.5).abs() < 1e-9);
        assert!((b.ckpt_write_s - 0.5).abs() < 1e-9, "max rank sum = 0.5");
        assert!((b.ckpt_read_s - 0.05).abs() < 1e-9);
        assert!((b.app_s() - (10.0 - 0.5 - 0.5 - 0.05)).abs() < 1e-9);
    }

    #[test]
    fn fault_free_run_has_zero_recovery() {
        let m = TrialMetrics::new(1);
        m.set_job_end(SimTime(1_000_000_000));
        let b = m.breakdown();
        assert_eq!(b.mpi_recovery_s, 0.0);
        assert!((b.app_s() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn sweep_stats_rates() {
        let s = SweepStats {
            jobs: 4,
            trials: 80,
            wall_s: 2.0,
            busy_s: 6.0,
        };
        assert_eq!(s.trials_per_sec(), 40.0);
        assert!((s.utilization() - 0.75).abs() < 1e-12);
        let z = SweepStats {
            jobs: 0,
            trials: 0,
            wall_s: 0.0,
            busy_s: 0.0,
        };
        assert_eq!(z.utilization(), 0.0);
        assert_eq!(z.trials_per_sec(), 0.0);
    }

    #[test]
    fn storage_means_average_per_trial() {
        use crate::ckptstore::TierIo;
        let a = StorageStats {
            local: TierIo {
                write_bytes: 2_000_000,
                ..Default::default()
            },
            disk: crate::fs::DiskStats {
                ops: 4,
                ..Default::default()
            },
            ..Default::default()
        };
        let b = StorageStats::default();
        let m = StorageMeans::from_trials(&[a, b]);
        assert!((m.local_write_mb - 1.0).abs() < 1e-12);
        assert!((m.disk_ops - 2.0).abs() < 1e-12);
        assert_eq!(StorageMeans::from_trials(&[]), StorageMeans::default());
    }

    #[test]
    fn first_failure_time_sticks() {
        let m = TrialMetrics::new(1);
        m.record_failure(SimTime(100));
        m.record_failure(SimTime(200));
        assert_eq!(m.fail_at(), Some(SimTime(100)));
    }
}
