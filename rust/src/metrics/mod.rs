//! Measurement: the paper's per-run time breakdown and its statistics
//! (mean + 95% confidence intervals from the t-distribution, 10 trials).

pub(crate) mod bench;
mod stats;

pub use bench::{BenchReport, BenchRow};
pub use stats::{mean_ci95, Summary};

use std::cell::RefCell;
use std::rc::Rc;

use crate::ckptstore::StorageStats;
use crate::config::FailureKind;
use crate::sim::{SimDuration, SimTime};

/// Phase breakdown of one trial (paper §4 "Statistical evaluation"):
/// total = app + ckpt_write + ckpt_read + mpi_recovery.
///
/// For multi-failure trials this stays the paper's *aggregate* view
/// (`mpi_recovery_s` spans first failure to last resume); the per-event
/// decomposition lives in [`FailureSegment`].
#[derive(Clone, Copy, Debug, Default)]
pub struct Breakdown {
    pub total_s: f64,
    pub ckpt_write_s: f64,
    pub ckpt_read_s: f64,
    pub mpi_recovery_s: f64,
    /// Checkpoint-verification time (checksum scans on load, slowest rank)
    /// — 0 unless the integrity machinery is armed.
    pub verify_s: f64,
}

/// Per-failure-event phase decomposition: each fired fault gets its own
/// detect / recovery / rollback accounting instead of the one aggregate
/// window the paper's single-failure methodology needed.
///
/// - `detect_s`   — kill instant → the recovery layer learning of it
///   (root receiving the SIGCHLD/TCP-break event, or the ULFM RTE
///   issuing notifications).
/// - `recovery_s` — detection → the slowest rank re-entering the user
///   function (the paper's Fig. 6/7 metric, per event).
/// - `rollback_s` — re-entry → the iteration frontier reaching its
///   pre-failure high-water mark again (lost-work re-execution; ≈ one
///   partial iteration at `ckpt_every=1`, real re-execution above it).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FailureSegment {
    pub kind: FailureKind,
    pub victim: u32,
    /// Virtual time of the kill, seconds since application start — the
    /// same clock `FaultAnchor::Time` events are scheduled on.
    pub fail_s: f64,
    pub detect_s: f64,
    pub recovery_s: f64,
    pub rollback_s: f64,
    /// Replica promotion window (replication only): detection → the
    /// slowest rank resuming. Failover segments report their cost here
    /// *instead of* `recovery_s`/`rollback_s` — the promoted replica
    /// already holds the frontier state, so no completed iteration is
    /// re-executed (zero rollback by construction).
    pub failover_s: f64,
    /// This event was recovered by promoting a shadow replica (replication
    /// failover) rather than by a rollback-based recovery.
    pub failover: bool,
    /// A later failure arrived before this event's recovery completed:
    /// the recovery was restarted and is accounted to the later segment.
    pub interrupted: bool,
    /// This failure exhausted the recovery's headroom — the spare pool
    /// (Reinit++/ULFM node failures) or the replica group (replication) —
    /// and degraded to a CR-style full abort + re-deploy.
    pub degraded_redeploy: bool,
    /// This event was recovered by a shrinking recovery: survivors adopted
    /// the victims' blocks, no process was respawned.
    pub shrunk: bool,
    /// This timeline event fired into dead air — its victim no longer
    /// existed in the live world (already dead, between deployments, or the
    /// job had completed). Explicitly recorded instead of silently skipped;
    /// all phase durations are zero and aggregations must exclude it.
    pub noop: bool,
}

impl Breakdown {
    /// Pure application time: everything not attributed elsewhere.
    pub fn app_s(&self) -> f64 {
        (self.total_s - self.ckpt_write_s - self.ckpt_read_s - self.mpi_recovery_s
            - self.verify_s)
            .max(0.0)
    }
}

/// One phase window of a finalized failure segment in *absolute* virtual
/// time — the trace layer's recovery track. Each window's duration is
/// computed with the same saturating subtraction as the corresponding
/// [`FailureSegment`] field, so a trace's recovery spans sum to the metric
/// decomposition exactly (pinned in `tests/trace_determinism.rs`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SegmentWindow {
    /// Index of the segment (kill order, no-ops included) this phase
    /// belongs to.
    pub seg: usize,
    /// Victim rank of the segment.
    pub victim: u32,
    /// Phase name: `detect`, `redeploy`, `failover`, `shrink`, `rollback`.
    pub name: &'static str,
    /// Phase start, absolute virtual time.
    pub begin: SimTime,
    /// Phase end, absolute virtual time (`>= begin`).
    pub end: SimTime,
}

/// Host-side throughput of one sweep (all points × trials): wall-clock,
/// busy seconds summed over workers, and utilization — the parallel sweep
/// scheduler's scoreboard (EXPERIMENTS.md §Perf "Sweep throughput").
#[derive(Clone, Copy, Debug)]
pub struct SweepStats {
    /// Worker threads used (1 = the old serial path).
    pub jobs: usize,
    /// Trials executed across all points.
    pub trials: usize,
    /// Host wall-clock seconds for the whole sweep.
    pub wall_s: f64,
    /// Sum of per-trial host seconds across all workers (busy time).
    pub busy_s: f64,
}

impl SweepStats {
    pub fn trials_per_sec(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.trials as f64 / self.wall_s
        } else {
            0.0
        }
    }

    /// Fraction of worker capacity that ran trials (1.0 = every worker busy
    /// for the whole sweep; low values mean tail/imbalance or tiny sweeps).
    pub fn utilization(&self) -> f64 {
        if self.wall_s > 0.0 && self.jobs > 0 {
            (self.busy_s / (self.jobs as f64 * self.wall_s)).min(1.0)
        } else {
            0.0
        }
    }
}

/// Mean per-trial storage traffic of one experiment point, in MB (ops as a
/// plain count) — the per-tier read/write/rebuild counters and the shared
/// disk's own stats, exported into every sweep CSV row so storage pressure
/// is visible per point.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StorageMeans {
    pub disk_write_mb: f64,
    pub disk_read_mb: f64,
    pub disk_ops: f64,
    pub local_write_mb: f64,
    pub partner_write_mb: f64,
    pub fs_write_mb: f64,
    pub local_read_mb: f64,
    pub partner_read_mb: f64,
    pub fs_read_mb: f64,
    pub rebuild_mb: f64,
    pub drained_mb: f64,
}

impl StorageMeans {
    pub fn from_trials(stats: &[StorageStats]) -> StorageMeans {
        const MB: f64 = 1e6;
        let mut m = StorageMeans::default();
        if stats.is_empty() {
            return m;
        }
        for s in stats {
            m.disk_write_mb += s.disk.bytes_written as f64 / MB;
            m.disk_read_mb += s.disk.bytes_read as f64 / MB;
            m.disk_ops += s.disk.ops as f64;
            m.local_write_mb += s.local.write_bytes as f64 / MB;
            m.partner_write_mb += s.partner.write_bytes as f64 / MB;
            m.fs_write_mb += s.fs.write_bytes as f64 / MB;
            m.local_read_mb += s.local.read_bytes as f64 / MB;
            m.partner_read_mb += s.partner.read_bytes as f64 / MB;
            m.fs_read_mb += s.fs.read_bytes as f64 / MB;
            m.rebuild_mb +=
                (s.local.rebuild_bytes + s.partner.rebuild_bytes + s.fs.rebuild_bytes) as f64
                    / MB;
            m.drained_mb +=
                (s.local.drained_bytes + s.partner.drained_bytes + s.fs.drained_bytes) as f64
                    / MB;
        }
        let n = stats.len() as f64;
        m.disk_write_mb /= n;
        m.disk_read_mb /= n;
        m.disk_ops /= n;
        m.local_write_mb /= n;
        m.partner_write_mb /= n;
        m.fs_write_mb /= n;
        m.local_read_mb /= n;
        m.partner_read_mb /= n;
        m.fs_read_mb /= n;
        m.rebuild_mb /= n;
        m.drained_mb /= n;
        m
    }
}

/// Raw per-event record; finalized into a [`FailureSegment`].
struct SegRaw {
    kind: FailureKind,
    victim: u32,
    fail_at: SimTime,
    detect_at: Option<SimTime>,
    resume_at: Option<SimTime>, // max over ranks re-entering after this event
    /// Iteration frontier (rank 0's last completed iteration) at the kill.
    lost_iter: i64,
    rollback_end: Option<SimTime>,
    failover: bool,
    interrupted: bool,
    degraded: bool,
    shrunk: bool,
    noop: bool,
}

struct Inner {
    job_start: SimTime,
    job_end: SimTime,
    fail_at: Option<SimTime>,
    resume_at: Option<SimTime>, // max over ranks re-entering the user fn
    /// Per-rank accumulated phase durations (index = rank).
    ckpt_write: Vec<SimDuration>,
    ckpt_read: Vec<SimDuration>,
    /// Per-rank checkpoint-verification time (checksum scans on load).
    verify: Vec<SimDuration>,
    /// Iterations of extra rollback caused by falling back to an older
    /// checkpoint generation (corrupted newest copy), summed over events.
    fallback_iters: u64,
    /// Recoveries triggered by a false suspicion (no real failure).
    spurious: u64,
    /// Agreement rounds retried onto an older generation.
    retries: u64,
    /// Recoveries that exhausted the retry budget (or every generation) and
    /// escalated to a full iteration-0 redeploy.
    escalations: u64,
    /// Extra recovery time outside the fail->resume window (CR: teardown
    /// and re-deploy happen between jobs; already inside the window).
    recovery_extra: SimDuration,
    /// Per-failure-event raw segments, in kill order.
    segs: Vec<SegRaw>,
    /// Rank 0's completed-iteration high-water mark (-1 = none yet).
    iter_high: i64,
}

/// Shared collector for one trial.
#[derive(Clone)]
pub struct TrialMetrics {
    inner: Rc<RefCell<Inner>>,
}

impl TrialMetrics {
    pub fn new(ranks: u32) -> Self {
        TrialMetrics {
            inner: Rc::new(RefCell::new(Inner {
                job_start: SimTime::ZERO,
                job_end: SimTime::ZERO,
                fail_at: None,
                resume_at: None,
                ckpt_write: vec![SimDuration::ZERO; ranks as usize],
                ckpt_read: vec![SimDuration::ZERO; ranks as usize],
                verify: vec![SimDuration::ZERO; ranks as usize],
                fallback_iters: 0,
                spurious: 0,
                retries: 0,
                escalations: 0,
                recovery_extra: SimDuration::ZERO,
                segs: Vec::new(),
                iter_high: -1,
            })),
        }
    }

    pub fn set_job_start(&self, t: SimTime) {
        self.inner.borrow_mut().job_start = t;
    }

    pub fn set_job_end(&self, t: SimTime) {
        self.inner.borrow_mut().job_end = t;
    }

    /// Record a failure instant (the kill). Opens a new per-event segment;
    /// a still-recovering prior segment is closed as `interrupted` (the
    /// restarted recovery is accounted to this event).
    pub fn record_failure(&self, t: SimTime, kind: FailureKind, victim: u32) {
        let mut inner = self.inner.borrow_mut();
        if inner.fail_at.is_none() {
            inner.fail_at = Some(t);
        }
        if let Some(last) = inner.segs.iter_mut().rev().find(|s| !s.noop) {
            if last.resume_at.is_none() {
                last.interrupted = true;
            }
        }
        let lost_iter = inner.iter_high;
        inner.segs.push(SegRaw {
            kind,
            victim,
            fail_at: t,
            detect_at: None,
            resume_at: None,
            lost_iter,
            rollback_end: None,
            failover: false,
            interrupted: false,
            degraded: false,
            shrunk: false,
            noop: false,
        });
    }

    /// A timeline event fired into dead air: its victim rank no longer
    /// exists in the live world (already dead, between deployments, or the
    /// job completed). Recorded as an explicit zero-cost segment in kill
    /// order — the storm/shrink analyses must see *every* planned event,
    /// not silently lose the ones a shrunken world could no longer host.
    pub fn record_noop_event(&self, t: SimTime, kind: FailureKind, victim: u32) {
        let mut inner = self.inner.borrow_mut();
        let lost_iter = inner.iter_high;
        inner.segs.push(SegRaw {
            kind,
            victim,
            fail_at: t,
            detect_at: None,
            // closed at birth: a no-op neither interrupts nor recovers
            resume_at: Some(t),
            lost_iter,
            rollback_end: Some(t),
            failover: false,
            interrupted: false,
            degraded: false,
            shrunk: false,
            noop: true,
        });
    }

    /// The recovery layer learned of a failure of this `kind` (root
    /// received the detect event / the RTE issued notifications). Matched
    /// to the oldest undetected segment *of the same kind*: process
    /// (SIGCHLD, ~ms) and node (TCP break, ~400 ms) detections have very
    /// different latencies, so closely-spaced mixed-kind failures must not
    /// have their detect times attributed positionally.
    pub fn record_detect(&self, t: SimTime, kind: FailureKind) {
        let mut inner = self.inner.borrow_mut();
        if let Some(seg) = inner
            .segs
            .iter_mut()
            .find(|s| s.detect_at.is_none() && s.kind == kind && !s.noop)
        {
            seg.detect_at = Some(t);
        }
    }

    /// The in-flight recovery degraded to a full abort + re-deploy.
    /// Attributed to the newest not-yet-degraded segment of the given
    /// `kind`: for Reinit++/ULFM only node failures can exhaust the spare
    /// pool, while replication degrades on whatever kind exhausted the
    /// victim's replica group — and an unrelated kill may have opened a
    /// newer segment inside the detection window, so kind-matching beats
    /// taking the last segment blindly.
    pub fn record_degrade(&self, kind: FailureKind) {
        let mut inner = self.inner.borrow_mut();
        if let Some(seg) = inner
            .segs
            .iter_mut()
            .rev()
            .find(|s| s.kind == kind && !s.degraded && !s.noop)
        {
            seg.degraded = true;
        }
    }

    /// The newest in-flight recovery is a *shrinking* recovery: survivors
    /// adopt the victims' blocks instead of anyone being respawned. The
    /// detect→resume window stays booked as `recovery_s` (it is a real
    /// rollback-based recovery, unlike failover); the flag lets sweeps
    /// separate shrink events from substitute-respawn ones.
    pub fn record_shrink(&self) {
        let mut inner = self.inner.borrow_mut();
        if let Some(seg) = inner
            .segs
            .iter_mut()
            .rev()
            .find(|s| s.resume_at.is_none() && !s.shrunk && !s.noop)
        {
            seg.shrunk = true;
        }
    }

    /// The newest in-flight recovery is a replica promotion (replication
    /// failover): its detect→resume window is accounted as `failover_s`
    /// and its recovery/rollback are zero by construction — the promoted
    /// replica resumes from the iteration frontier, re-executing nothing.
    pub fn record_failover(&self) {
        let mut inner = self.inner.borrow_mut();
        if let Some(seg) = inner
            .segs
            .iter_mut()
            .rev()
            .find(|s| s.resume_at.is_none() && !s.failover && !s.noop)
        {
            seg.failover = true;
        }
    }

    /// A rank re-entered the user function after recovery (before loading
    /// its checkpoint); the job-level recovery ends at the slowest rank.
    pub fn record_resume(&self, t: SimTime) {
        let mut inner = self.inner.borrow_mut();
        inner.resume_at = Some(match inner.resume_at {
            None => t,
            Some(prev) => prev.max(t),
        });
        if let Some(last) = inner.segs.iter_mut().rev().find(|s| !s.noop) {
            last.resume_at = Some(match last.resume_at {
                None => t,
                Some(prev) => prev.max(t),
            });
        }
    }

    /// Rank 0 completed `iter` at `t`: advances the iteration frontier and
    /// closes any segment whose lost work has now been re-executed. The
    /// close condition compares the *just-completed* iteration against the
    /// segment's pre-failure frontier — the monotone high-water mark
    /// already equals it at kill time, so testing the high-water would
    /// close every segment on the first post-resume iteration and
    /// undercount rollback whenever `ckpt_every > 1`.
    pub fn record_iter_done(&self, iter: u32, t: SimTime) {
        let mut inner = self.inner.borrow_mut();
        inner.iter_high = inner.iter_high.max(iter as i64);
        for seg in inner.segs.iter_mut() {
            if seg.resume_at.is_some()
                && seg.rollback_end.is_none()
                && iter as i64 >= seg.lost_iter
            {
                seg.rollback_end = Some(t);
            }
        }
    }

    /// Finalize the per-event decomposition (kill order). Interrupted
    /// segments report zero recovery/rollback — their restarted recovery is
    /// accounted to the interrupting event's segment.
    pub fn segments(&self) -> Vec<FailureSegment> {
        let inner = self.inner.borrow();
        inner
            .segs
            .iter()
            .map(|s| {
                let detect_s = s
                    .detect_at
                    .map(|d| d.saturating_sub(s.fail_at).secs_f64())
                    .unwrap_or(0.0);
                let recovery_s = match (s.resume_at, s.detect_at) {
                    (Some(r), Some(d)) => r.saturating_sub(d).secs_f64(),
                    (Some(r), None) => r.saturating_sub(s.fail_at).secs_f64(),
                    _ => 0.0,
                };
                let rollback_s = match (s.rollback_end, s.resume_at) {
                    (Some(e), Some(r)) => e.saturating_sub(r).secs_f64(),
                    _ => 0.0,
                };
                // Failover segments re-book the detect→resume window as
                // promotion cost; nothing is rolled back or re-executed.
                let (recovery_s, rollback_s, failover_s) = if s.failover {
                    (0.0, 0.0, recovery_s)
                } else {
                    (recovery_s, rollback_s, 0.0)
                };
                FailureSegment {
                    kind: s.kind,
                    victim: s.victim,
                    fail_s: s.fail_at.saturating_sub(inner.job_start).secs_f64(),
                    detect_s,
                    recovery_s,
                    rollback_s,
                    failover_s,
                    failover: s.failover,
                    interrupted: s.interrupted,
                    degraded_redeploy: s.degraded,
                    shrunk: s.shrunk,
                    noop: s.noop,
                }
            })
            .collect()
    }

    /// The per-event phase windows in absolute virtual time, chronological
    /// within each segment: `detect` (kill → detection), then the recovery
    /// phase named by how the event was actually absorbed (`failover`,
    /// `shrink`, or `redeploy` — detection → slowest resume), then
    /// `rollback` (resume → frontier re-reached; rollback-based recoveries
    /// only). Interrupted segments contribute their detect window alone;
    /// no-op segments contribute nothing. Durations match [`Self::segments`]
    /// field-for-field by construction.
    pub fn segment_windows(&self) -> Vec<SegmentWindow> {
        let inner = self.inner.borrow();
        let mut out = Vec::new();
        for (i, s) in inner.segs.iter().enumerate() {
            if s.noop {
                continue;
            }
            if let Some(d) = s.detect_at {
                out.push(SegmentWindow {
                    seg: i,
                    victim: s.victim,
                    name: "detect",
                    begin: s.fail_at,
                    end: d.max(s.fail_at),
                });
            }
            if let Some(r) = s.resume_at {
                let begin = s.detect_at.unwrap_or(s.fail_at);
                let name = if s.failover {
                    "failover"
                } else if s.shrunk {
                    "shrink"
                } else {
                    "redeploy"
                };
                out.push(SegmentWindow {
                    seg: i,
                    victim: s.victim,
                    name,
                    begin,
                    end: r.max(begin),
                });
                if let (false, Some(e)) = (s.failover, s.rollback_end) {
                    out.push(SegmentWindow {
                        seg: i,
                        victim: s.victim,
                        name: "rollback",
                        begin: r,
                        end: e.max(r),
                    });
                }
            }
        }
        out
    }

    /// Number of recorded failure events (fired kills; no-op timeline
    /// events that hit dead air are excluded).
    pub fn failure_count(&self) -> usize {
        self.inner.borrow().segs.iter().filter(|s| !s.noop).count()
    }

    pub fn add_ckpt_write(&self, rank: u32, d: SimDuration) {
        self.inner.borrow_mut().ckpt_write[rank as usize] += d;
    }

    pub fn add_ckpt_read(&self, rank: u32, d: SimDuration) {
        self.inner.borrow_mut().ckpt_read[rank as usize] += d;
    }

    /// Checksum-verification time spent by `rank` while choosing a loadable
    /// checkpoint generation (reported like the ckpt phases: slowest rank).
    pub fn add_verify(&self, rank: u32, d: SimDuration) {
        self.inner.borrow_mut().verify[rank as usize] += d;
    }

    /// Extra rollback (in iterations) from agreeing on an older generation
    /// than the newest stored one because the newer copies were corrupt.
    pub fn add_fallback_iters(&self, n: u64) {
        self.inner.borrow_mut().fallback_iters += n;
    }

    /// A false suspicion of the unreliable detector killed an innocent
    /// rank: the recovery now running is entirely spurious.
    pub fn record_spurious(&self) {
        self.inner.borrow_mut().spurious += 1;
    }

    /// The post-recovery agreement landed on a corrupt generation and
    /// retried from an older one.
    pub fn record_retry(&self) {
        self.inner.borrow_mut().retries += 1;
    }

    /// The recovery exhausted its retry budget (or ran out of generations)
    /// and escalated to a CR-style iteration-0 redeploy.
    pub fn record_escalation(&self) {
        self.inner.borrow_mut().escalations += 1;
    }

    /// Like [`Self::record_degrade`], but kind-agnostic: marks the newest
    /// not-yet-degraded segment whatever its kind. Used by the
    /// corrupt-checkpoint escalation path, where the restart is forced by
    /// storage state rather than by the failure kind's headroom.
    pub fn record_degrade_any(&self) {
        let mut inner = self.inner.borrow_mut();
        if let Some(seg) = inner
            .segs
            .iter_mut()
            .rev()
            .find(|s| !s.degraded && !s.noop)
        {
            seg.degraded = true;
        }
    }

    pub fn fallback_iters(&self) -> u64 {
        self.inner.borrow().fallback_iters
    }

    pub fn spurious_count(&self) -> u64 {
        self.inner.borrow().spurious
    }

    pub fn retry_count(&self) -> u64 {
        self.inner.borrow().retries
    }

    pub fn escalation_count(&self) -> u64 {
        self.inner.borrow().escalations
    }

    pub fn fail_at(&self) -> Option<SimTime> {
        self.inner.borrow().fail_at
    }

    /// Finalize into the paper's breakdown. Checkpoint phases use the
    /// slowest rank's accumulated time (the BSP stall path); MPI recovery is
    /// the failure->resume window minus the checkpoint read that happens
    /// inside it (read is reported separately, as in the paper).
    pub fn breakdown(&self) -> Breakdown {
        let inner = self.inner.borrow();
        // job_end < job_start means the run never finished (deadlock);
        // report what we have instead of underflowing.
        let total = inner.job_end.saturating_sub(inner.job_start).secs_f64();
        let wr = inner
            .ckpt_write
            .iter()
            .map(|d| d.secs_f64())
            .fold(0.0, f64::max);
        let rd = inner
            .ckpt_read
            .iter()
            .map(|d| d.secs_f64())
            .fold(0.0, f64::max);
        let vf = inner
            .verify
            .iter()
            .map(|d| d.secs_f64())
            .fold(0.0, f64::max);
        let recovery = match (inner.fail_at, inner.resume_at) {
            (Some(f), Some(r)) => {
                r.saturating_sub(f).secs_f64() + inner.recovery_extra.secs_f64()
            }
            _ => 0.0,
        };
        Breakdown {
            total_s: total,
            ckpt_write_s: wr,
            ckpt_read_s: rd,
            mpi_recovery_s: recovery,
            verify_s: vf,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_accounts_all_phases() {
        let m = TrialMetrics::new(2);
        m.set_job_start(SimTime(0));
        m.set_job_end(SimTime(10_000_000_000)); // 10 s
        m.record_failure(SimTime(4_000_000_000), FailureKind::Process, 1);
        m.record_resume(SimTime(4_500_000_000));
        m.record_resume(SimTime(4_400_000_000)); // earlier rank: ignored
        m.add_ckpt_write(0, SimDuration::from_millis(300));
        m.add_ckpt_write(0, SimDuration::from_millis(200));
        m.add_ckpt_write(1, SimDuration::from_millis(400));
        m.add_ckpt_read(1, SimDuration::from_millis(50));
        let b = m.breakdown();
        assert!((b.total_s - 10.0).abs() < 1e-9);
        assert!((b.mpi_recovery_s - 0.5).abs() < 1e-9);
        assert!((b.ckpt_write_s - 0.5).abs() < 1e-9, "max rank sum = 0.5");
        assert!((b.ckpt_read_s - 0.05).abs() < 1e-9);
        assert!((b.app_s() - (10.0 - 0.5 - 0.5 - 0.05)).abs() < 1e-9);
    }

    #[test]
    fn fault_free_run_has_zero_recovery() {
        let m = TrialMetrics::new(1);
        m.set_job_end(SimTime(1_000_000_000));
        let b = m.breakdown();
        assert_eq!(b.mpi_recovery_s, 0.0);
        assert!((b.app_s() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn sweep_stats_rates() {
        let s = SweepStats {
            jobs: 4,
            trials: 80,
            wall_s: 2.0,
            busy_s: 6.0,
        };
        assert_eq!(s.trials_per_sec(), 40.0);
        assert!((s.utilization() - 0.75).abs() < 1e-12);
        let z = SweepStats {
            jobs: 0,
            trials: 0,
            wall_s: 0.0,
            busy_s: 0.0,
        };
        assert_eq!(z.utilization(), 0.0);
        assert_eq!(z.trials_per_sec(), 0.0);
    }

    #[test]
    fn storage_means_average_per_trial() {
        use crate::ckptstore::TierIo;
        let a = StorageStats {
            local: TierIo {
                write_bytes: 2_000_000,
                ..Default::default()
            },
            disk: crate::fs::DiskStats {
                ops: 4,
                ..Default::default()
            },
            ..Default::default()
        };
        let b = StorageStats::default();
        let m = StorageMeans::from_trials(&[a, b]);
        assert!((m.local_write_mb - 1.0).abs() < 1e-12);
        assert!((m.disk_ops - 2.0).abs() < 1e-12);
        assert_eq!(StorageMeans::from_trials(&[]), StorageMeans::default());
    }

    #[test]
    fn first_failure_time_sticks() {
        let m = TrialMetrics::new(1);
        m.record_failure(SimTime(100), FailureKind::Process, 0);
        m.record_failure(SimTime(200), FailureKind::Node, 3);
        assert_eq!(m.fail_at(), Some(SimTime(100)));
        assert_eq!(m.failure_count(), 2);
    }

    #[test]
    fn segments_decompose_per_event() {
        const S: u64 = 1_000_000_000;
        let m = TrialMetrics::new(2);
        // event 1: fail @2s, detect @2.1s, resume @2.6s; frontier was 3,
        // re-reached @2.9s
        m.record_iter_done(2, SimTime(S));
        m.record_iter_done(3, SimTime(2 * S));
        m.record_failure(SimTime(2 * S), FailureKind::Process, 1);
        m.record_detect(SimTime(2_100_000_000), FailureKind::Process);
        m.record_resume(SimTime(2_400_000_000));
        m.record_resume(SimTime(2_600_000_000)); // slowest rank wins
        // lost frontier is 3: completing iter 2 again must NOT close rollback
        m.record_iter_done(2, SimTime(2_800_000_000));
        m.record_iter_done(3, SimTime(2_900_000_000));
        // event 2: fail @5s, detect @5.2s, resume @6s, frontier re-reached @6.5s
        m.record_iter_done(4, SimTime(4 * S));
        m.record_failure(SimTime(5 * S), FailureKind::Node, 0);
        m.record_detect(SimTime(5_200_000_000), FailureKind::Node);
        m.record_resume(SimTime(6 * S));
        m.record_iter_done(3, SimTime(6_300_000_000)); // below the frontier: open
        m.record_iter_done(4, SimTime(6_500_000_000));
        let segs = m.segments();
        assert_eq!(segs.len(), 2);
        let s1 = &segs[0];
        assert_eq!((s1.kind, s1.victim), (FailureKind::Process, 1));
        assert!((s1.fail_s - 2.0).abs() < 1e-9);
        assert!((s1.detect_s - 0.1).abs() < 1e-9);
        assert!((s1.recovery_s - 0.5).abs() < 1e-9);
        assert!((s1.rollback_s - 0.3).abs() < 1e-9);
        assert!(!s1.interrupted && !s1.degraded_redeploy);
        let s2 = &segs[1];
        assert!((s2.detect_s - 0.2).abs() < 1e-9);
        assert!((s2.recovery_s - 0.8).abs() < 1e-9);
        assert!((s2.rollback_s - 0.5).abs() < 1e-9);
    }

    #[test]
    fn failure_during_recovery_interrupts_open_segment() {
        const S: u64 = 1_000_000_000;
        let m = TrialMetrics::new(2);
        m.record_iter_done(1, SimTime(S));
        m.record_failure(SimTime(2 * S), FailureKind::Process, 0);
        m.record_detect(SimTime(2_050_000_000), FailureKind::Process);
        // second failure (node kind) lands before any rank resumed
        m.record_failure(SimTime(2_200_000_000), FailureKind::Node, 1);
        m.record_detect(SimTime(2_250_000_000), FailureKind::Node);
        m.record_degrade(FailureKind::Node);
        m.record_resume(SimTime(3 * S));
        m.record_iter_done(1, SimTime(3_300_000_000));
        let segs = m.segments();
        assert_eq!(segs.len(), 2);
        assert!(segs[0].interrupted, "no resume before the second kill");
        assert_eq!(segs[0].recovery_s, 0.0);
        assert_eq!(segs[0].rollback_s, 0.0);
        assert!(
            !segs[0].degraded_redeploy,
            "degrade belongs to the node segment, not the interrupted process one"
        );
        assert!(!segs[1].interrupted);
        assert!(segs[1].degraded_redeploy);
        assert!((segs[1].recovery_s - 0.75).abs() < 1e-9);
        assert!((segs[1].rollback_s - 0.3).abs() < 1e-9);
    }

    #[test]
    fn failover_segment_books_promotion_not_rollback() {
        const S: u64 = 1_000_000_000;
        let m = TrialMetrics::new(2);
        m.record_iter_done(4, SimTime(S));
        m.record_failure(SimTime(2 * S), FailureKind::Process, 1);
        m.record_detect(SimTime(2_010_000_000), FailureKind::Process);
        m.record_failover();
        m.record_resume(SimTime(2_300_000_000));
        // promoted replica resumes past the frontier: first completed
        // iteration is *new* work, yet would close rollback if this were
        // a rollback-based segment
        m.record_iter_done(5, SimTime(2_600_000_000));
        let segs = m.segments();
        assert_eq!(segs.len(), 1);
        let s = &segs[0];
        assert!(s.failover);
        assert!((s.detect_s - 0.01).abs() < 1e-9);
        assert!((s.failover_s - 0.29).abs() < 1e-9, "{segs:?}");
        assert_eq!(s.recovery_s, 0.0, "promotion cost lives in failover_s");
        assert_eq!(s.rollback_s, 0.0, "zero rollback by construction");
    }

    #[test]
    fn failover_marks_newest_open_segment_only() {
        const S: u64 = 1_000_000_000;
        let m = TrialMetrics::new(2);
        // first failover completes normally
        m.record_failure(SimTime(S), FailureKind::Process, 0);
        m.record_failover();
        m.record_resume(SimTime(1_200_000_000));
        // second failure mid-run: failover must land here, not re-mark seg 0
        m.record_failure(SimTime(2 * S), FailureKind::Process, 1);
        m.record_failover();
        m.record_resume(SimTime(2_200_000_000));
        let segs = m.segments();
        assert!(segs[0].failover && segs[1].failover);
    }

    #[test]
    fn degrade_attributes_by_kind() {
        // Replication: a *process* failure can exhaust a replica group, so
        // the degrade lands on the process segment even with a newer node
        // segment open.
        const S: u64 = 1_000_000_000;
        let m = TrialMetrics::new(2);
        m.record_failure(SimTime(S), FailureKind::Process, 0);
        m.record_failure(SimTime(1_100_000_000), FailureKind::Node, 1);
        m.record_degrade(FailureKind::Process);
        m.record_resume(SimTime(2 * S));
        let segs = m.segments();
        assert!(segs[0].degraded_redeploy);
        assert!(!segs[1].degraded_redeploy);
    }

    #[test]
    fn noop_event_is_explicit_and_inert() {
        const S: u64 = 1_000_000_000;
        let m = TrialMetrics::new(2);
        m.record_failure(SimTime(S), FailureKind::Process, 0);
        m.record_detect(SimTime(1_010_000_000), FailureKind::Process);
        // a time-anchored kill fires into dead air mid-recovery: its victim
        // is already gone. It must appear in the segment list without
        // interrupting the open recovery or absorbing its detect/resume.
        m.record_noop_event(SimTime(1_100_000_000), FailureKind::Process, 1);
        m.record_resume(SimTime(2 * S));
        let segs = m.segments();
        assert_eq!(segs.len(), 2, "the no-op is visible, not silently lost");
        assert_eq!(m.failure_count(), 1, "but it is not a fired kill");
        assert!(!segs[0].interrupted, "no-ops never interrupt a recovery");
        assert!(
            (segs[0].recovery_s - 0.99).abs() < 1e-9,
            "resume lands on the real segment: {segs:?}"
        );
        let n = &segs[1];
        assert!(n.noop && !n.interrupted && !n.degraded_redeploy);
        assert_eq!((n.kind, n.victim), (FailureKind::Process, 1));
        assert_eq!((n.detect_s, n.recovery_s, n.rollback_s), (0.0, 0.0, 0.0));
        assert!((n.fail_s - 1.1).abs() < 1e-9, "fires at its planned instant");
    }

    #[test]
    fn shrink_marks_open_segment_and_keeps_recovery_booking() {
        const S: u64 = 1_000_000_000;
        let m = TrialMetrics::new(2);
        m.record_failure(SimTime(S), FailureKind::Node, 0);
        m.record_detect(SimTime(1_400_000_000), FailureKind::Node);
        m.record_shrink();
        m.record_resume(SimTime(2 * S));
        // second event exhausts min_ranks: degraded, not shrunk
        m.record_failure(SimTime(3 * S), FailureKind::Node, 1);
        m.record_detect(SimTime(3_400_000_000), FailureKind::Node);
        m.record_degrade(FailureKind::Node);
        m.record_resume(SimTime(5 * S));
        let segs = m.segments();
        assert!(segs[0].shrunk && !segs[0].degraded_redeploy);
        assert!(
            (segs[0].recovery_s - 0.6).abs() < 1e-9,
            "shrink cost stays booked as recovery_s: {segs:?}"
        );
        assert!(!segs[1].shrunk && segs[1].degraded_redeploy);
    }

    #[test]
    fn mixed_kind_detections_attribute_by_kind_not_position() {
        // A node failure (slow TCP-break detection) followed by a process
        // failure (fast SIGCHLD): the process detection arrives FIRST and
        // must land on the process segment, not the older node one.
        const S: u64 = 1_000_000_000;
        let m = TrialMetrics::new(2);
        m.record_failure(SimTime(S), FailureKind::Node, 0);
        m.record_failure(SimTime(1_050_000_000), FailureKind::Process, 1);
        m.record_detect(SimTime(1_052_000_000), FailureKind::Process); // 2 ms sigchld
        m.record_detect(SimTime(1_400_000_000), FailureKind::Node); // 400 ms break
        m.record_resume(SimTime(2 * S));
        let segs = m.segments();
        assert!((segs[0].detect_s - 0.4).abs() < 1e-9, "{segs:?}");
        assert!((segs[1].detect_s - 0.002).abs() < 1e-9, "{segs:?}");
    }

    #[test]
    fn segment_windows_mirror_segment_durations_exactly() {
        const S: u64 = 1_000_000_000;
        let m = TrialMetrics::new(2);
        // rollback-based event with a real rollback tail
        m.record_iter_done(3, SimTime(S));
        m.record_failure(SimTime(2 * S), FailureKind::Process, 1);
        m.record_detect(SimTime(2_100_000_000), FailureKind::Process);
        m.record_resume(SimTime(2_600_000_000));
        m.record_iter_done(3, SimTime(2_900_000_000));
        // failover event: promotion window, no rollback span
        m.record_failure(SimTime(4 * S), FailureKind::Process, 0);
        m.record_detect(SimTime(4_010_000_000), FailureKind::Process);
        m.record_failover();
        m.record_resume(SimTime(4_300_000_000));
        // no-op event: contributes no window at all
        m.record_noop_event(SimTime(4_500_000_000), FailureKind::Process, 1);
        let segs = m.segments();
        let windows = m.segment_windows();
        // exactly: detect+redeploy+rollback for seg 0, detect+failover for seg 1
        assert_eq!(windows.len(), 5, "{windows:?}");
        assert!(windows.iter().all(|w| w.seg != 2), "no-ops emit no window");
        let sum = |seg: usize, name: &str| -> f64 {
            windows
                .iter()
                .filter(|w| w.seg == seg && w.name == name)
                .map(|w| w.end.saturating_sub(w.begin).secs_f64())
                .sum()
        };
        assert_eq!(sum(0, "detect"), segs[0].detect_s);
        assert_eq!(sum(0, "redeploy"), segs[0].recovery_s);
        assert_eq!(sum(0, "rollback"), segs[0].rollback_s);
        assert_eq!(sum(1, "detect"), segs[1].detect_s);
        assert_eq!(sum(1, "failover"), segs[1].failover_s);
        assert_eq!(sum(1, "redeploy") + sum(1, "rollback"), 0.0);
    }

    #[test]
    fn verify_time_books_like_the_ckpt_phases() {
        let m = TrialMetrics::new(2);
        m.set_job_end(SimTime(10_000_000_000));
        m.add_verify(0, SimDuration::from_millis(30));
        m.add_verify(1, SimDuration::from_millis(50));
        m.add_verify(1, SimDuration::from_millis(20));
        let b = m.breakdown();
        assert!((b.verify_s - 0.07).abs() < 1e-9, "slowest rank's sum");
        assert!((b.app_s() - (10.0 - 0.07)).abs() < 1e-9, "verify not app time");
        // and a trial that never verifies reports exactly zero
        let q = TrialMetrics::new(2);
        q.set_job_end(SimTime(1_000_000_000));
        assert_eq!(q.breakdown().verify_s, 0.0);
    }

    #[test]
    fn integrity_counters_accumulate() {
        let m = TrialMetrics::new(1);
        assert_eq!(
            (m.spurious_count(), m.retry_count(), m.escalation_count(), m.fallback_iters()),
            (0, 0, 0, 0)
        );
        m.record_spurious();
        m.record_retry();
        m.record_retry();
        m.record_escalation();
        m.add_fallback_iters(3);
        m.add_fallback_iters(2);
        assert_eq!(m.spurious_count(), 1);
        assert_eq!(m.retry_count(), 2);
        assert_eq!(m.escalation_count(), 1);
        assert_eq!(m.fallback_iters(), 5);
    }

    #[test]
    fn degrade_any_marks_newest_open_segment_regardless_of_kind() {
        const S: u64 = 1_000_000_000;
        let m = TrialMetrics::new(2);
        m.record_failure(SimTime(S), FailureKind::Node, 0);
        m.record_failure(SimTime(2 * S), FailureKind::Process, 1);
        // corrupt-checkpoint escalation: forced by storage state, so the
        // newest segment takes the degrade whatever its kind
        m.record_degrade_any();
        m.record_resume(SimTime(3 * S));
        let segs = m.segments();
        assert!(!segs[0].degraded_redeploy);
        assert!(segs[1].degraded_redeploy);
        // with no segment at all it is a no-op, not a panic
        let q = TrialMetrics::new(1);
        q.record_degrade_any();
        assert!(q.segments().is_empty());
    }

    #[test]
    fn interrupted_segment_contributes_detect_window_only() {
        const S: u64 = 1_000_000_000;
        let m = TrialMetrics::new(2);
        m.record_failure(SimTime(S), FailureKind::Process, 0);
        m.record_detect(SimTime(1_050_000_000), FailureKind::Process);
        m.record_failure(SimTime(1_200_000_000), FailureKind::Node, 1);
        m.record_detect(SimTime(1_600_000_000), FailureKind::Node);
        m.record_resume(SimTime(2 * S));
        let windows = m.segment_windows();
        let seg0: Vec<_> = windows.iter().filter(|w| w.seg == 0).collect();
        assert_eq!(seg0.len(), 1);
        assert_eq!(seg0[0].name, "detect");
    }
}
