//! Machine-readable micro-bench reports.
//!
//! Each `benches/micro_*.rs` driver emits a `BENCH_<name>.json` next to the
//! repository root so the perf trajectory of the DES engine is tracked
//! across PRs (CI uploads the file as an artifact; EXPERIMENTS.md §Perf
//! records the table). The format is deliberately flat — `bench`, `schema`,
//! and a list of `{name, work, host_seconds, rate_per_sec, unit}` rows plus
//! optional free-form numeric extras — and the writer is dependency-free.

use std::io::Write as _;
use std::path::Path;

/// One measured row of a micro-bench report.
#[derive(Clone, Debug)]
pub struct BenchRow {
    /// Sub-benchmark name, e.g. `timer_storm`.
    pub name: String,
    /// Units of work performed (events, messages, processes, ...).
    pub work: u64,
    /// Host wall-clock seconds for the run.
    pub host_seconds: f64,
    /// `work / host_seconds`.
    pub rate_per_sec: f64,
    /// What the rate counts, e.g. `events+polls/s`.
    pub unit: String,
    /// Extra numeric facts (e.g. heap allocations observed).
    pub extra: Vec<(String, f64)>,
}

impl BenchRow {
    pub fn new(name: &str, work: u64, host_seconds: f64, unit: &str) -> Self {
        BenchRow {
            name: name.to_string(),
            work,
            host_seconds,
            rate_per_sec: if host_seconds > 0.0 {
                work as f64 / host_seconds
            } else {
                0.0
            },
            unit: unit.to_string(),
            extra: Vec::new(),
        }
    }

    pub fn with_extra(mut self, key: &str, value: f64) -> Self {
        self.extra.push((key.to_string(), value));
        self
    }
}

/// A full report: `write_json` renders it without any serde dependency.
#[derive(Clone, Debug, Default)]
pub struct BenchReport {
    pub bench: String,
    /// Provenance of the numbers: `"measured"` (default — the file was
    /// produced by actually running the bench, e.g. in CI) or
    /// `"reference"` (a checked-in snapshot from a development machine,
    /// kept for trend context until the next CI refresh overwrites it).
    pub baseline: String,
    pub rows: Vec<BenchRow>,
}

impl BenchReport {
    pub fn new(bench: &str) -> Self {
        BenchReport {
            bench: bench.to_string(),
            baseline: "measured".to_string(),
            rows: Vec::new(),
        }
    }

    /// Override the provenance tag (see `baseline`).
    pub fn with_baseline(mut self, baseline: &str) -> Self {
        self.baseline = baseline.to_string();
        self
    }

    pub fn push(&mut self, row: BenchRow) {
        self.rows.push(row);
    }

    /// Render the report as pretty-printed JSON. Only numbers and
    /// identifier-ish strings ever enter a report, but strings are escaped
    /// anyway so the output is always valid JSON.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"bench\": {},\n", json_str(&self.bench)));
        s.push_str("  \"schema\": 1,\n");
        s.push_str(&format!("  \"baseline\": {},\n", json_str(&self.baseline)));
        s.push_str("  \"results\": [\n");
        for (i, r) in self.rows.iter().enumerate() {
            s.push_str("    {");
            s.push_str(&format!("\"name\": {}, ", json_str(&r.name)));
            s.push_str(&format!("\"work\": {}, ", r.work));
            s.push_str(&format!("\"host_seconds\": {}, ", json_num(r.host_seconds)));
            s.push_str(&format!("\"rate_per_sec\": {}, ", json_num(r.rate_per_sec)));
            s.push_str(&format!("\"unit\": {}", json_str(&r.unit)));
            for (k, v) in &r.extra {
                s.push_str(&format!(", {}: {}", json_str(k), json_num(*v)));
            }
            s.push('}');
            s.push_str(if i + 1 == self.rows.len() { "\n" } else { ",\n" });
        }
        s.push_str("  ]\n}\n");
        s
    }

    /// Write the JSON report to `path` (best effort: a read-only checkout
    /// must not kill a perf run, so failures are reported, not fatal).
    pub fn write_json(&self, path: impl AsRef<Path>) {
        let path = path.as_ref();
        let write = || -> std::io::Result<()> {
            let mut f = std::fs::File::create(path)?;
            f.write_all(self.to_json().as_bytes())
        };
        match write() {
            Ok(()) => crate::info!("wrote {}", path.display()),
            Err(e) => crate::warnln!("could not write {}: {e}", path.display()),
        }
    }
}

/// Escape a string for embedding in hand-rolled JSON (shared with the
/// trace/profile exporters and sweep-stats writer).
pub(crate) fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// JSON has no NaN/Inf; clamp to null-free sentinels.
pub(crate) fn json_num(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "0".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_renders_valid_flat_json() {
        let mut rep = BenchReport::new("micro_example");
        rep.push(BenchRow::new("storm", 1000, 0.5, "events/s").with_extra("allocs", 42.0));
        let j = rep.to_json();
        assert!(j.contains("\"bench\": \"micro_example\""));
        assert!(j.contains("\"rate_per_sec\": 2000"));
        assert!(j.contains("\"allocs\": 42"));
        assert!(
            j.contains("\"baseline\": \"measured\""),
            "bench runs default to measured provenance"
        );
        let r = BenchReport::new("x").with_baseline("reference");
        assert!(r.to_json().contains("\"baseline\": \"reference\""));
        // crude balance check: every brace/bracket closes
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }

    #[test]
    fn strings_are_escaped() {
        assert_eq!(json_str("a\"b"), "\"a\\\"b\"");
        assert_eq!(json_num(f64::NAN), "0");
    }
}
