//! Latency/bandwidth cost model (constants from `config::Calibration`).

use crate::config::Calibration;
use crate::sim::SimDuration;

/// Transfer-time calculator for the simulated fabric.
#[derive(Clone, Debug)]
pub struct NetCost {
    intra_lat: SimDuration,
    intra_bytes_per_sec: f64,
    inter_lat: SimDuration,
    inter_bytes_per_sec: f64,
    control_lat: SimDuration,
}

const GB: f64 = 1e9;

impl NetCost {
    pub fn from_calib(c: &Calibration) -> Self {
        NetCost {
            intra_lat: SimDuration::from_secs_f64(c.intra_latency_us * 1e-6),
            intra_bytes_per_sec: c.intra_bw_gbps * GB,
            inter_lat: SimDuration::from_secs_f64(c.inter_latency_us * 1e-6),
            inter_bytes_per_sec: c.inter_bw_gbps * GB,
            control_lat: SimDuration::from_secs_f64(c.control_latency_us * 1e-6),
        }
    }

    /// One-way delivery time of `bytes` on the data plane.
    pub fn data_delay(&self, bytes: usize, same_node: bool) -> SimDuration {
        let (lat, bw) = if same_node {
            (self.intra_lat, self.intra_bytes_per_sec)
        } else {
            (self.inter_lat, self.inter_bytes_per_sec)
        };
        lat + SimDuration::from_secs_f64(bytes as f64 / bw)
    }

    /// One-way delivery time of a small control-plane message.
    pub fn control_delay(&self, bytes: usize) -> SimDuration {
        self.control_lat + SimDuration::from_secs_f64(bytes as f64 / self.inter_bytes_per_sec)
    }

    /// Smallest latency any message crossing a node boundary can have —
    /// min(inter-node data latency, control-plane latency). Under the
    /// node-aligned shard plan every cross-shard message is also
    /// cross-node, so this is the conservative lookahead horizon for the
    /// sharded executor's time windows.
    pub fn min_remote_latency(&self) -> SimDuration {
        self.inter_lat.min(self.control_lat)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cost() -> NetCost {
        NetCost::from_calib(&Calibration::default())
    }

    #[test]
    fn latency_dominates_small_messages() {
        let c = cost();
        let d = c.data_delay(8, false);
        // 2 µs latency + ~0.6 ns transfer
        assert!(d.nanos() >= 2_000 && d.nanos() < 2_100, "{d:?}");
    }

    #[test]
    fn bandwidth_dominates_large_messages() {
        let c = cost();
        let d = c.data_delay(125_000_000, false); // 125 MB at 12.5 GB/s = 10 ms
        let secs = d.secs_f64();
        assert!((secs - 0.01).abs() < 0.001, "{secs}");
    }

    #[test]
    fn intra_node_is_faster() {
        let c = cost();
        assert!(c.data_delay(1 << 20, true) < c.data_delay(1 << 20, false));
    }

    #[test]
    fn control_plane_latency() {
        let c = cost();
        assert!(c.control_delay(64).nanos() >= 25_000);
    }

    #[test]
    fn monotone_in_size() {
        let c = cost();
        let mut last = SimDuration::ZERO;
        for bytes in [0usize, 100, 10_000, 1_000_000] {
            let d = c.data_delay(bytes, false);
            assert!(d >= last);
            last = d;
        }
    }
}
