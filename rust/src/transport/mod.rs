//! Simulated interconnect: cost model + addressed message fabric.
//!
//! Two planes, as in Open MPI:
//! - the **data plane** (`Fabric`) carries MPI traffic between ranks with
//!   latency/bandwidth costs depending on intra- vs inter-node placement;
//! - the **control plane** is the set of root<->daemon channels owned by
//!   `cluster` (reliable TCP-like, fixed small latency) — it reuses
//!   `NetCost::control_delay`.

mod cost;
mod fabric;

pub use cost::NetCost;
pub use fabric::{Endpoint, Fabric};
