//! Addressed message fabric: per-endpoint mailboxes with cost-model delays.
//!
//! Endpoints are keyed by `u64`; the MPI layer composes keys as
//! `(generation << 32) | rank` so that a CR re-deploy gets a pristine fabric
//! address space and a re-spawned rank re-binds its own key.
//!
//! Routing is a **generation-tagged flat table indexed by rank** — the
//! large-rank fast path. The seed design kept a `HashMap<u64, Endpoint>`
//! plus a `HashSet<u64>` of retired keys, so every send paid a SipHash
//! lookup and long failure-injection sweeps grew the retired set by one
//! entry per dead incarnation. Here a send is one bounds check + one
//! generation compare on a `Vec` slot, and retirement is a per-rank
//! watermark (`retired_below`): dead generations cost nothing to remember,
//! so memory stays bounded across any number of kill/re-bind cycles.

use std::cell::RefCell;
use std::rc::Rc;

use super::cost::NetCost;
use crate::sim::{channel, Receiver, Sender, Sim, SimDuration};

/// An endpoint binding: where a key currently lives.
#[derive(Clone)]
pub struct Endpoint<M> {
    tx: Sender<M>,
    node: u32,
}

/// Routing state of one rank slot. At most one generation of a rank is
/// bound at a time (every recovery path drops the old incarnation's
/// binding — `Comm::drop` — before a newer generation attaches), so the
/// slot holds a single endpoint tagged with its generation.
struct RankRoute<M> {
    /// Generation of the live binding; meaningful only while `ep` is Some.
    bound_gen: u64,
    ep: Option<Endpoint<M>>,
    /// Retirement watermark: a send to generation `g < retired_below` with
    /// no live binding is a crashed incarnation's traffic and is dropped
    /// (not buffered). Subsumes the seed's unbounded `retired: HashSet`.
    retired_below: u64,
    /// Eager sends racing MPI_Init wireup, tagged with their target
    /// generation; the matching `bind` drains them in arrival order.
    pending: Vec<(u64, u32, M, usize)>,
}

impl<M> RankRoute<M> {
    fn vacant() -> Self {
        RankRoute {
            bound_gen: 0,
            ep: None,
            retired_below: 0,
            pending: Vec::new(),
        }
    }
}

struct Inner<M> {
    routes: Vec<RankRoute<M>>,
    messages_sent: u64,
    bytes_sent: u64,
}

impl<M> Inner<M> {
    fn ensure(&mut self, rank: usize) {
        if rank >= self.routes.len() {
            self.routes.resize_with(rank + 1, RankRoute::vacant);
        }
    }
}

/// Split a fabric key into `(generation, rank)` — the composition
/// `MpiJob::key` uses.
#[inline]
fn split(key: u64) -> (u64, usize) {
    (key >> 32, (key as u32) as usize)
}

/// The data-plane fabric shared by all ranks of a job.
pub struct Fabric<M> {
    sim: Sim,
    cost: NetCost,
    inner: Rc<RefCell<Inner<M>>>,
}

impl<M> Clone for Fabric<M> {
    fn clone(&self) -> Self {
        Fabric {
            sim: self.sim.clone(),
            cost: self.cost.clone(),
            inner: Rc::clone(&self.inner),
        }
    }
}

impl<M: 'static> Fabric<M> {
    pub fn new(sim: &Sim, cost: NetCost) -> Self {
        Fabric {
            sim: sim.clone(),
            cost,
            inner: Rc::new(RefCell::new(Inner {
                routes: Vec::new(),
                messages_sent: 0,
                bytes_sent: 0,
            })),
        }
    }

    /// Conservative lookahead horizon for the sharded executor: the
    /// smallest latency any message crossing a node boundary can have
    /// under this fabric's calibration. Shard partitions are node-aligned,
    /// so every cross-shard message is cross-node and arrives at least
    /// this far in the future.
    pub fn min_remote_latency(&self) -> SimDuration {
        self.cost.min_remote_latency()
    }

    /// Bind (or re-bind, after a re-spawn) `key` on `node`; returns the
    /// mailbox. A re-bind drops the stale mailbox: in-flight messages to the
    /// dead incarnation are lost, like packets to a crashed process. An
    /// explicit re-bind of a retired key revives it (the live-binding check
    /// runs before the retirement watermark).
    pub fn bind(&self, key: u64, node: u32) -> Receiver<M> {
        let (gen, rank) = split(key);
        let (tx, rx) = channel::<M>(&self.sim);
        let backlog = {
            let mut inner = self.inner.borrow_mut();
            inner.ensure(rank);
            let slot = &mut inner.routes[rank];
            // Replacing a live binding of a *different* generation should
            // never happen (recovery drops the old incarnation first); if a
            // future flow ever does it, the displaced generation is dead —
            // retire it so its traffic is dropped rather than buffered.
            if slot.ep.take().is_some() && slot.bound_gen != gen {
                slot.retired_below = slot.retired_below.max(slot.bound_gen + 1);
            }
            slot.bound_gen = gen;
            slot.ep = Some(Endpoint { tx, node });
            if slot.pending.is_empty() {
                Vec::new()
            } else {
                let mut mine = Vec::new();
                let mut rest = Vec::new();
                for e in std::mem::take(&mut slot.pending) {
                    if e.0 == gen {
                        mine.push(e);
                    } else {
                        rest.push(e);
                    }
                }
                slot.pending = rest;
                mine
            }
        };
        // Flush eager sends that raced the bind (delay computed now, which
        // models the connection-establishment handshake completing).
        for (_gen, from_node, msg, bytes) in backlog {
            self.send_from(from_node, key, msg, bytes);
        }
        rx
    }

    /// Remove a binding (process death). The generation is retired via the
    /// watermark: its buffered backlog (if any) is dropped and later eager
    /// sends are discarded rather than buffered, so a crashed incarnation
    /// cannot accumulate traffic forever waiting for a bind that never
    /// comes — at zero memory cost per incarnation.
    pub fn unbind(&self, key: u64) {
        let (gen, rank) = split(key);
        let mut inner = self.inner.borrow_mut();
        inner.ensure(rank);
        let slot = &mut inner.routes[rank];
        if slot.ep.is_some() && slot.bound_gen == gen {
            slot.ep = None;
        }
        slot.retired_below = slot.retired_below.max(gen + 1);
        slot.pending.retain(|e| e.0 != gen);
    }

    /// Node an endpoint lives on, if bound.
    pub fn node_of(&self, key: u64) -> Option<u32> {
        let (gen, rank) = split(key);
        let inner = self.inner.borrow();
        match inner.routes.get(rank) {
            Some(slot) if slot.bound_gen == gen => slot.ep.as_ref().map(|e| e.node),
            _ => None,
        }
    }

    /// Send `msg` (`bytes` long on the wire) from a task on `from_node` to
    /// endpoint `to`. If the endpoint is not bound yet the message is
    /// buffered until `bind` (eager send racing wireup) — unless the
    /// generation is retired (a crashed incarnation), in which case the
    /// message is dropped like packets to a dead host. Returns false in
    /// both cases. The bound fast path is one indexed load + one
    /// generation compare — no hashing.
    pub fn send_from(&self, from_node: u32, to: u64, msg: M, bytes: usize) -> bool {
        let (gen, rank) = split(to);
        let (tx, delay) = {
            let mut inner = self.inner.borrow_mut();
            inner.ensure(rank);
            let slot = &mut inner.routes[rank];
            let live = match &slot.ep {
                Some(ep) if slot.bound_gen == gen => Some((ep.tx.clone(), ep.node)),
                _ => None,
            };
            let Some((tx, ep_node)) = live else {
                if gen >= slot.retired_below {
                    slot.pending.push((gen, from_node, msg, bytes));
                }
                return false;
            };
            inner.messages_sent += 1;
            inner.bytes_sent += bytes as u64;
            (tx, self.cost.data_delay(bytes, ep_node == from_node))
        };
        tx.send(msg, delay);
        true
    }

    /// Account a replica mirror push: `bytes` carried between two nodes to
    /// an endpoint-less shadow replica (transport-level mirroring — the
    /// replica consumes the primary's stream without a mailbox of its own).
    /// Counts in `stats` like any delivered message; returns the wire cost
    /// for the caller to await.
    pub fn charge_mirror(&self, from_node: u32, to_node: u32, bytes: usize) -> SimDuration {
        let mut inner = self.inner.borrow_mut();
        inner.messages_sent += 1;
        inner.bytes_sent += bytes as u64;
        self.cost.data_delay(bytes, from_node == to_node)
    }

    /// Messages currently buffered for a not-yet-bound key (leak audits).
    pub fn pending_len(&self, key: u64) -> usize {
        let (gen, rank) = split(key);
        let inner = self.inner.borrow();
        inner
            .routes
            .get(rank)
            .map_or(0, |s| s.pending.iter().filter(|e| e.0 == gen).count())
    }

    /// Host-memory audit for churn tests: `(rank slots, total buffered
    /// eager sends)`. Both must stay bounded by the topology — never by
    /// the number of dead incarnations.
    pub fn route_table_size(&self) -> (usize, usize) {
        let inner = self.inner.borrow();
        (
            inner.routes.len(),
            inner.routes.iter().map(|s| s.pending.len()).sum(),
        )
    }

    /// Traffic counters `(messages, bytes)` — used by tests and perf metrics.
    pub fn stats(&self) -> (u64, u64) {
        let inner = self.inner.borrow();
        (inner.messages_sent, inner.bytes_sent)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Calibration;
    use std::cell::Cell;
    use std::rc::Rc;

    fn fabric(sim: &Sim) -> Fabric<(u32, Vec<u8>)> {
        Fabric::new(sim, NetCost::from_calib(&Calibration::default()))
    }

    /// Compose a key the way `MpiJob::key` does.
    fn key(gen: u64, rank: u32) -> u64 {
        (gen << 32) | rank as u64
    }

    #[test]
    fn send_and_receive_roundtrip() {
        let sim = Sim::new();
        let f = fabric(&sim);
        let p = sim.spawn_process("r1");
        let rx = f.bind(1, 0);
        assert!(f.send_from(0, 1, (7, vec![1, 2, 3]), 3));
        let got = Rc::new(Cell::new(0));
        let g = Rc::clone(&got);
        sim.spawn(p, async move {
            let (tag, data) = rx.recv().await.unwrap();
            g.set(tag + data.len() as u32);
        });
        sim.run();
        assert_eq!(got.get(), 10);
    }

    #[test]
    fn send_to_unbound_is_buffered_until_bind() {
        let sim = Sim::new();
        let f = fabric(&sim);
        assert!(!f.send_from(0, 99, (7, vec![1]), 1)); // buffered
        let rx = f.bind(99, 0); // flushes
        sim.run();
        assert_eq!(rx.try_recv().map(|m| m.0), Some(7));
    }

    #[test]
    fn crashed_incarnation_eager_sends_are_dropped() {
        // Satellite regression (the `pending` leak): traffic to a key that
        // was bound and then unbound (a crashed incarnation) must be
        // dropped, not buffered forever for a bind that never comes.
        let sim = Sim::new();
        let f = fabric(&sim);
        let _rx = f.bind(5, 2);
        f.unbind(5);
        assert_eq!(f.node_of(5), None);
        for i in 0..100 {
            assert!(!f.send_from(0, 5, (i, vec![1, 2, 3]), 3));
        }
        assert_eq!(f.pending_len(5), 0, "no backlog accumulates");
        assert_eq!(f.stats(), (0, 0), "dropped traffic never hits the wire");
        // An explicit re-bind revives the key with a pristine mailbox...
        let rx2 = f.bind(5, 3);
        sim.run();
        assert!(rx2.is_empty(), "crashed incarnation's sends stay dropped");
        // ...and live delivery works again.
        assert!(f.send_from(0, 5, (7, vec![9]), 1));
        sim.run();
        assert_eq!(rx2.try_recv().map(|m| m.0), Some(7));
    }

    #[test]
    fn unbind_clears_buffered_backlog() {
        // Eager sends buffered for a never-bound key are dropped the moment
        // the key is unbound (its incarnation died before wireup finished).
        let sim = Sim::new();
        let f = fabric(&sim);
        assert!(!f.send_from(0, 9, (1, vec![1]), 1)); // buffered (wireup race)
        assert_eq!(f.pending_len(9), 1);
        f.unbind(9);
        assert_eq!(f.pending_len(9), 0);
        let rx = f.bind(9, 0); // next incarnation
        sim.run();
        assert!(rx.is_empty(), "dead incarnation's backlog not replayed");
    }

    #[test]
    fn rebind_gets_fresh_mailbox() {
        let sim = Sim::new();
        let f = fabric(&sim);
        let _old = f.bind(1, 0);
        assert!(f.send_from(0, 1, (1, vec![]), 0)); // goes to old mailbox
        let new = f.bind(1, 3); // respawned on another node
        assert_eq!(f.node_of(1), Some(3));
        sim.run();
        assert!(new.is_empty(), "message to the dead incarnation is lost");
    }

    #[test]
    fn generations_of_one_rank_route_independently() {
        // Traffic addressed to an older, already-unbound generation must
        // never reach the newer incarnation bound at the same rank slot.
        let sim = Sim::new();
        let f = fabric(&sim);
        let _g0 = f.bind(key(0, 7), 0);
        f.unbind(key(0, 7)); // incarnation 0 dies
        let g1 = f.bind(key(1, 7), 1); // incarnation 1 re-binds the rank
        assert!(!f.send_from(0, key(0, 7), (1, vec![]), 1), "stale gen dropped");
        assert!(f.send_from(0, key(1, 7), (2, vec![]), 1));
        // an even newer generation's eager send buffers until its bind
        assert!(!f.send_from(0, key(2, 7), (3, vec![]), 1));
        assert_eq!(f.pending_len(key(2, 7)), 1);
        sim.run();
        assert_eq!(g1.try_recv().map(|m| m.0), Some(2));
        assert!(g1.is_empty());
        let g2 = f.bind(key(2, 7), 2);
        sim.run();
        assert_eq!(g2.try_recv().map(|m| m.0), Some(3), "wireup race flushed");
    }

    #[test]
    fn retired_state_is_bounded_across_10k_incarnations() {
        // Satellite regression: the seed kept a `HashSet<u64>` of retired
        // keys that grew by one entry per dead incarnation. The
        // generation-tagged table must keep host memory bounded by the
        // topology (one slot per rank) across any number of kill/re-bind
        // cycles, while still dropping every dead generation's traffic.
        let sim = Sim::new();
        let f = fabric(&sim);
        for gen in 0..10_000u64 {
            let _rx = f.bind(key(gen, 3), 0);
            if gen > 0 {
                // eager send to the previous incarnation: dropped, not buffered
                assert!(!f.send_from(0, key(gen - 1, 3), (1, vec![]), 1));
            }
            f.unbind(key(gen, 3));
        }
        let (slots, pending) = f.route_table_size();
        assert_eq!(slots, 4, "one slot per rank, not per incarnation");
        assert_eq!(pending, 0, "no retired-set or backlog growth");
        assert_eq!(f.stats(), (0, 0));
        // the rank is still usable after all that churn
        let rx = f.bind(key(10_000, 3), 0);
        assert!(f.send_from(0, key(10_000, 3), (42, vec![]), 1));
        sim.run();
        assert_eq!(rx.try_recv().map(|m| m.0), Some(42));
    }

    #[test]
    fn intra_node_beats_inter_node_delivery() {
        let sim = Sim::new();
        let f = fabric(&sim);
        let p = sim.spawn_process("r");
        let rx_near = f.bind(1, 0);
        let rx_far = f.bind(2, 1);
        // same payload, sent at t=0 from node 0
        f.send_from(0, 1, (1, vec![0; 1024]), 1024);
        f.send_from(0, 2, (2, vec![0; 1024]), 1024);
        let times = Rc::new(RefCell::new(Vec::new()));
        let t2 = Rc::clone(&times);
        let s2 = sim.clone();
        sim.spawn(p, async move {
            rx_near.recv().await.unwrap();
            t2.borrow_mut().push(s2.now());
            rx_far.recv().await.unwrap();
            t2.borrow_mut().push(s2.now());
        });
        sim.run();
        let t = times.borrow();
        assert!(t[0] < t[1], "near={:?} far={:?}", t[0], t[1]);
    }

    #[test]
    fn mirror_charge_counts_stats_and_prices_locality() {
        let sim = Sim::new();
        let f = fabric(&sim);
        let near = f.charge_mirror(0, 0, 1024);
        let far = f.charge_mirror(0, 1, 1024);
        assert!(near < far, "inter-node mirror pays inter-node cost");
        assert_eq!(f.stats(), (2, 2048), "mirror traffic hits the wire stats");
    }

    #[test]
    fn stats_accumulate() {
        let sim = Sim::new();
        let f = fabric(&sim);
        let _rx = f.bind(1, 0);
        f.send_from(0, 1, (0, vec![0; 10]), 10);
        f.send_from(0, 1, (0, vec![0; 20]), 20);
        assert_eq!(f.stats(), (2, 30));
    }
}
