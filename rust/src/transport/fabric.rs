//! Addressed message fabric: per-endpoint mailboxes with cost-model delays.
//!
//! Endpoints are keyed by `u64`; the MPI layer composes keys from
//! `(job incarnation, rank)` so that a CR re-deploy gets a pristine fabric
//! address space and a re-spawned rank re-binds its own key.

use std::cell::RefCell;
use std::collections::{HashMap, HashSet};
use std::rc::Rc;

use super::cost::NetCost;
use crate::sim::{channel, Receiver, Sender, Sim};

/// An endpoint binding: where a key currently lives.
#[derive(Clone)]
pub struct Endpoint<M> {
    tx: Sender<M>,
    node: u32,
}

struct Inner<M> {
    endpoints: HashMap<u64, Endpoint<M>>,
    /// Messages sent to a not-yet-bound key (eager sends racing MPI_Init
    /// wireup). Flushed on `bind`. Only keys that were never bound buffer
    /// here: a key that was bound and then unbound is a crashed
    /// incarnation, and its traffic is dropped (see `retired`).
    pending: HashMap<u64, Vec<(u32, M, usize)>>,
    /// Keys that were bound once and then unbound (dead incarnations).
    /// Sends to them are dropped instead of buffered — eager traffic to a
    /// crashed process must not accumulate waiting for a bind that never
    /// comes (endpoint keys are generation-tagged, so dead keys are never
    /// reused by recovered worlds).
    retired: HashSet<u64>,
    messages_sent: u64,
    bytes_sent: u64,
}

/// The data-plane fabric shared by all ranks of a job.
pub struct Fabric<M> {
    sim: Sim,
    cost: NetCost,
    inner: Rc<RefCell<Inner<M>>>,
}

impl<M> Clone for Fabric<M> {
    fn clone(&self) -> Self {
        Fabric {
            sim: self.sim.clone(),
            cost: self.cost.clone(),
            inner: Rc::clone(&self.inner),
        }
    }
}

impl<M: 'static> Fabric<M> {
    pub fn new(sim: &Sim, cost: NetCost) -> Self {
        Fabric {
            sim: sim.clone(),
            cost,
            inner: Rc::new(RefCell::new(Inner {
                endpoints: HashMap::new(),
                pending: HashMap::new(),
                retired: HashSet::new(),
                messages_sent: 0,
                bytes_sent: 0,
            })),
        }
    }

    /// Bind (or re-bind, after a re-spawn) `key` on `node`; returns the
    /// mailbox. A re-bind drops the stale mailbox: in-flight messages to the
    /// dead incarnation are lost, like packets to a crashed process.
    pub fn bind(&self, key: u64, node: u32) -> Receiver<M> {
        let (tx, rx) = channel::<M>(&self.sim);
        let backlog = {
            let mut inner = self.inner.borrow_mut();
            inner.retired.remove(&key); // an explicit re-bind revives the key
            inner.endpoints.insert(key, Endpoint { tx, node });
            inner.pending.remove(&key).unwrap_or_default()
        };
        // Flush eager sends that raced the bind (delay computed now, which
        // models the connection-establishment handshake completing).
        for (from_node, msg, bytes) in backlog {
            self.send_from(from_node, key, msg, bytes);
        }
        rx
    }

    /// Remove a binding (process death). The key is retired: its buffered
    /// backlog (if any) is dropped and later eager sends are discarded
    /// rather than buffered, so a crashed incarnation cannot accumulate
    /// traffic forever waiting for a bind that never comes.
    pub fn unbind(&self, key: u64) {
        let mut inner = self.inner.borrow_mut();
        inner.endpoints.remove(&key);
        inner.pending.remove(&key);
        inner.retired.insert(key);
    }

    /// Node an endpoint lives on, if bound.
    pub fn node_of(&self, key: u64) -> Option<u32> {
        self.inner.borrow().endpoints.get(&key).map(|e| e.node)
    }

    /// Send `msg` (`bytes` long on the wire) from a task on `from_node` to
    /// endpoint `to`. If the endpoint is not bound yet the message is
    /// buffered until `bind` (eager send racing wireup) — unless the key is
    /// retired (a crashed incarnation), in which case the message is
    /// dropped like packets to a dead host. Returns false in both cases.
    pub fn send_from(&self, from_node: u32, to: u64, msg: M, bytes: usize) -> bool {
        let (tx, delay) = {
            let mut inner = self.inner.borrow_mut();
            let Some(ep) = inner.endpoints.get(&to) else {
                if !inner.retired.contains(&to) {
                    inner.pending.entry(to).or_default().push((from_node, msg, bytes));
                }
                return false;
            };
            let delay = self.cost.data_delay(bytes, ep.node == from_node);
            let tx = ep.tx.clone();
            inner.messages_sent += 1;
            inner.bytes_sent += bytes as u64;
            (tx, delay)
        };
        tx.send(msg, delay);
        true
    }

    /// Messages currently buffered for a not-yet-bound key (leak audits).
    pub fn pending_len(&self, key: u64) -> usize {
        self.inner.borrow().pending.get(&key).map_or(0, |v| v.len())
    }

    /// Traffic counters `(messages, bytes)` — used by tests and perf metrics.
    pub fn stats(&self) -> (u64, u64) {
        let inner = self.inner.borrow();
        (inner.messages_sent, inner.bytes_sent)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Calibration;
    use std::cell::Cell;
    use std::rc::Rc;

    fn fabric(sim: &Sim) -> Fabric<(u32, Vec<u8>)> {
        Fabric::new(sim, NetCost::from_calib(&Calibration::default()))
    }

    #[test]
    fn send_and_receive_roundtrip() {
        let sim = Sim::new();
        let f = fabric(&sim);
        let p = sim.spawn_process("r1");
        let rx = f.bind(1, 0);
        assert!(f.send_from(0, 1, (7, vec![1, 2, 3]), 3));
        let got = Rc::new(Cell::new(0));
        let g = Rc::clone(&got);
        sim.spawn(p, async move {
            let (tag, data) = rx.recv().await.unwrap();
            g.set(tag + data.len() as u32);
        });
        sim.run();
        assert_eq!(got.get(), 10);
    }

    #[test]
    fn send_to_unbound_is_buffered_until_bind() {
        let sim = Sim::new();
        let f = fabric(&sim);
        assert!(!f.send_from(0, 99, (7, vec![1]), 1)); // buffered
        let rx = f.bind(99, 0); // flushes
        sim.run();
        assert_eq!(rx.try_recv().map(|m| m.0), Some(7));
    }

    #[test]
    fn crashed_incarnation_eager_sends_are_dropped() {
        // Satellite regression (the `pending` leak): traffic to a key that
        // was bound and then unbound (a crashed incarnation) must be
        // dropped, not buffered forever for a bind that never comes.
        let sim = Sim::new();
        let f = fabric(&sim);
        let _rx = f.bind(5, 2);
        f.unbind(5);
        assert_eq!(f.node_of(5), None);
        for i in 0..100 {
            assert!(!f.send_from(0, 5, (i, vec![1, 2, 3]), 3));
        }
        assert_eq!(f.pending_len(5), 0, "no backlog accumulates");
        assert_eq!(f.stats(), (0, 0), "dropped traffic never hits the wire");
        // An explicit re-bind revives the key with a pristine mailbox...
        let rx2 = f.bind(5, 3);
        sim.run();
        assert!(rx2.is_empty(), "crashed incarnation's sends stay dropped");
        // ...and live delivery works again.
        assert!(f.send_from(0, 5, (7, vec![9]), 1));
        sim.run();
        assert_eq!(rx2.try_recv().map(|m| m.0), Some(7));
    }

    #[test]
    fn unbind_clears_buffered_backlog() {
        // Eager sends buffered for a never-bound key are dropped the moment
        // the key is unbound (its incarnation died before wireup finished).
        let sim = Sim::new();
        let f = fabric(&sim);
        assert!(!f.send_from(0, 9, (1, vec![1]), 1)); // buffered (wireup race)
        assert_eq!(f.pending_len(9), 1);
        f.unbind(9);
        assert_eq!(f.pending_len(9), 0);
        let rx = f.bind(9, 0); // next incarnation
        sim.run();
        assert!(rx.is_empty(), "dead incarnation's backlog not replayed");
    }

    #[test]
    fn rebind_gets_fresh_mailbox() {
        let sim = Sim::new();
        let f = fabric(&sim);
        let _old = f.bind(1, 0);
        assert!(f.send_from(0, 1, (1, vec![]), 0)); // goes to old mailbox
        let new = f.bind(1, 3); // respawned on another node
        assert_eq!(f.node_of(1), Some(3));
        sim.run();
        assert!(new.is_empty(), "message to the dead incarnation is lost");
    }

    #[test]
    fn intra_node_beats_inter_node_delivery() {
        let sim = Sim::new();
        let f = fabric(&sim);
        let p = sim.spawn_process("r");
        let rx_near = f.bind(1, 0);
        let rx_far = f.bind(2, 1);
        // same payload, sent at t=0 from node 0
        f.send_from(0, 1, (1, vec![0; 1024]), 1024);
        f.send_from(0, 2, (2, vec![0; 1024]), 1024);
        let times = Rc::new(RefCell::new(Vec::new()));
        let t2 = Rc::clone(&times);
        let s2 = sim.clone();
        sim.spawn(p, async move {
            rx_near.recv().await.unwrap();
            t2.borrow_mut().push(s2.now());
            rx_far.recv().await.unwrap();
            t2.borrow_mut().push(s2.now());
        });
        sim.run();
        let t = times.borrow();
        assert!(t[0] < t[1], "near={:?} far={:?}", t[0], t[1]);
    }

    #[test]
    fn stats_accumulate() {
        let sim = Sim::new();
        let f = fabric(&sim);
        let _rx = f.bind(1, 0);
        f.send_from(0, 1, (0, vec![0; 10]), 10);
        f.send_from(0, 1, (0, vec![0; 20]), 20);
        assert_eq!(f.stats(), (2, 30));
    }
}
