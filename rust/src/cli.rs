//! Command-line interface for the `reinitpp` binary (hand-rolled: the
//! offline build has no clap).
//!
//! ```text
//! reinitpp run       [OPTIONS] [key=value ...]   one experiment point
//! reinitpp reproduce --figure N [OPTIONS] [...]  regenerate a paper figure
//! reinitpp scale     [OPTIONS] [key=value ...]   weak-scaling sweep to 16k ranks
//! reinitpp tiers     [OPTIONS] [key=value ...]   checkpoint tier-stack sweep
//! reinitpp storm     [OPTIONS] [key=value ...]   MTBF failure-storm sweep
//! reinitpp crossover [OPTIONS] [key=value ...]   replication-vs-checkpointing crossover
//! reinitpp shrink    [OPTIONS] [key=value ...]   shrink-vs-substitute-vs-CR sweep
//! reinitpp integrity [OPTIONS] [key=value ...]   imperfect-world sweep (corruption x detector)
//! reinitpp tables    [--which 1|2]               print Tables 1/2
//! reinitpp validate  [OPTIONS] [key=value ...]   global-restart equivalence
//! reinitpp calibrate [key=value ...]             measure artifact exec times
//! ```
//!
//! OPTIONS: `--config FILE` (TOML-subset), `--max-ranks N`, `--outdir DIR`,
//! `--jobs N` (worker threads for trial execution, must be >= 1: default =
//! available parallelism, `1` forces the serial path; output is
//! byte-identical for any value — see `harness::pool`), plus any dotted
//! config key as `key=value` (see `config::ExperimentConfig`).
//!
//! Observability: `run` takes `--trace DIR` (per-trial Perfetto trace +
//! flamegraph + profile JSON, see `trace`) and `--trace-filter CATS`;
//! every sweep takes `--profile-json`; `-v`/`--quiet` are global flags
//! stripped by `main` before parsing (see `log`). Tracing is observation
//! only — virtual-time results and CSV bytes are identical with it on.

use std::rc::Rc;

use crate::config::ExperimentConfig;
use crate::harness::{self, SweepOpts};
use crate::recovery::job::run_trial;
use crate::runtime::XlaRuntime;

/// Parsed command line.
#[derive(Debug)]
pub enum Command {
    Run {
        cfg: ExperimentConfig,
        jobs: usize,
        /// `--shards N`: executor shards per trial (1 = serial event loop).
        shards: usize,
        /// `--trace DIR` (+ optional `--trace-filter`): per-trial trace
        /// export destination, installed process-wide for the run.
        trace: Option<crate::trace::TraceConfig>,
    },
    Reproduce {
        figure: u32,
        cfg: ExperimentConfig,
        opts: SweepOpts,
    },
    Tiers {
        cfg: ExperimentConfig,
        opts: SweepOpts,
    },
    Scale {
        cfg: ExperimentConfig,
        opts: SweepOpts,
    },
    Storm {
        cfg: ExperimentConfig,
        opts: SweepOpts,
    },
    Crossover {
        cfg: ExperimentConfig,
        opts: SweepOpts,
    },
    Shrink {
        cfg: ExperimentConfig,
        opts: SweepOpts,
    },
    Integrity {
        cfg: ExperimentConfig,
        opts: SweepOpts,
    },
    Tables {
        which: Option<u32>,
    },
    Validate {
        cfg: ExperimentConfig,
    },
    Calibrate {
        cfg: ExperimentConfig,
    },
    Help,
}

/// Error with usage context.
#[derive(Debug)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

fn err(m: impl Into<String>) -> CliError {
    CliError(m.into())
}

pub const USAGE: &str = "\
reinitpp — Reinit++ global-restart MPI fault-tolerance study (paper reproduction)

USAGE:
  reinitpp run       [OPTIONS] [key=value ...]   run one experiment point
  reinitpp reproduce --figure N [OPTIONS] [...]  regenerate paper figure N (4-7, or 0 = all)
  reinitpp scale     [OPTIONS] [key=value ...]   large-rank weak-scaling sweep: extends the
                                                 paper's Figure 4 recovery curves past its
                                                 3072-rank ceiling (ranks 512 up to
                                                 --max-ranks: the preset ladder to 16384,
                                                 then doubling rungs, e.g. 262144; all
                                                 recovery methods, process failure, modeled
                                                 fidelity; ULFM capped at 4096 — see
                                                 EXPERIMENTS.md; emits scale_compare.csv
                                                 with a state_bytes_per_rank column)
  reinitpp tiers     [OPTIONS] [key=value ...]   checkpoint tier-stack comparison sweep
                                                 (fs vs local+partner1 vs local+partner2+fs,
                                                 process + node failures; ranks 16/32/64 at
                                                 8 ranks/node; emits tier_compare.csv)
  reinitpp storm     [OPTIONS] [key=value ...]   failure-storm sweep: MTBF arrival process
                                                 x recovery method x ranks 16/64/256, with
                                                 per-event detect/recovery/rollback columns
                                                 (emits storm_compare.csv). Single runs can
                                                 also storm via `run mtbf_s=4` or an explicit
                                                 scenario `run failures=proc@3:r5,node@7:r12`
  reinitpp crossover [OPTIONS] [key=value ...]   replication-vs-checkpointing crossover
                                                 sweep: all four recovery families (repl at
                                                 degree 1 and 2) x MTBF x checkpoint interval
                                                 x ranks 16/64/256 at 8 ranks/node, over the
                                                 storm MTBF engine (emits crossover_compare.csv)
  reinitpp shrink    [OPTIONS] [key=value ...]   shrink-vs-substitute-vs-CR sweep: continue
                                                 on survivors with zero spares (ReStore-style
                                                 checkpoint redistribution) vs spare-pool
                                                 respawn (reinit) vs full re-deploy (cr),
                                                 process + node failure storms x MTBF x
                                                 ranks 16/64/256 at 8 ranks/node
                                                 (emits shrink_compare.csv; min_ranks= sets
                                                 the shrink floor)
  reinitpp integrity [OPTIONS] [key=value ...]   imperfect-world sweep: checkpoint bit-rot x
                                                 unreliable-detector noise x retention depth
                                                 (ckpt_keep) x all five recovery families,
                                                 over process-failure storms at ranks
                                                 16/64/256 (emits integrity_compare.csv).
                                                 Single runs can also go imperfect via e.g.
                                                 `run corrupt_rate=0.2 ckpt_keep=3` or an
                                                 explicit `run failures=corrupt@3:r5,...`
  reinitpp tables    [--which 1|2]               print the paper's tables
  reinitpp validate  [OPTIONS] [key=value ...]   check global-restart equivalence
  reinitpp calibrate [key=value ...]             measure artifact execution costs

OPTIONS:
  --config FILE      load a TOML-subset config file
  --max-ranks N      cap the sweep's rank counts (reproduce/scale/tiers/storm/
                     crossover/shrink/integrity; scale defaults to 16384 and
                     requires a power of two >= 512 — rungs past 16384 keep
                     doubling up to N instead of silently clamping)
  --outdir DIR       CSV output directory (default: results)
  --jobs N           worker threads for trial execution
                     (run/reproduce/scale/tiers/storm/crossover/shrink/integrity).
                     Must be >= 1: default all cores, 1 = serial execution on
                     the calling thread. Tables and CSVs are byte-identical
                     for any N.
  --shards N         executor shards per trial (run + every sweep; default 1 =
                     the serial event loop). Ranks are partitioned into
                     node-aligned shards with window-synchronized cross-shard
                     delivery; a host knob like --jobs: traces, CSVs and
                     digests are byte-identical for any N. Must be >= 1.
  --trace DIR        (run) write per-trial observability artifacts under DIR:
                     trace_<id>.trace.json (Perfetto/chrome trace-event JSON,
                     virtual time: one track per rank group + a recovery
                     timeline), trace_<id>.folded (flamegraph folded stacks),
                     trace_<id>.profile.json (counters + recovery segments),
                     plus pool.trace.json (worker timeline, wall time).
                     Observation only: results are byte-identical with it on.
  --trace-filter C,C (run, with --trace) record only these span categories;
                     known: exec, mpi, ckpt, recovery, pool, integrity,
                     detect, shard
  --profile-json     (sweeps) also write per-trial executor counters as
                     <sweep>_profiles.json next to the sweep CSV (the
                     BENCH_sweep_stats_<sweep>.json throughput summary is
                     always written)
  -v, --verbose      verbose progress on stderr (global flag)
  -q, --quiet        silence progress on stderr (global flag)
  key=value          any config key, e.g. app=hpccg ranks=64 recovery=reinit
                     failure=process trials=10 iters=20 fidelity=auto
                     ckpt_tiers=local+partner2+fs ckpt_drain_interval_s=0.5
                     failures=proc@3:r5,node@7:r12,proc@t1.25:r3 (explicit
                     multi-failure scenario: kind@iteration-or-tSECONDS:victim;
                     kind corrupt marks the victim's newest checkpoint instead
                     of killing anything)
                     mtbf_s=4 max_failures=6 (exponential failure arrivals)
                     ckpt_keep=3 corrupt_rate=0.1 retry_budget=3 (checkpoint
                     integrity: retention depth, seeded bit-rot, agreement
                     retries before an iteration-0 escalation)
                     detect_fp_rate=0.5 detect_jitter_s=0.002
                     suspect_timeout_s=0.01 (unreliable failure detector)
                     calibration.fork_exec_ms=350

EXAMPLES:
  reinitpp run app=hpccg ranks=16 recovery=reinit failure=process trials=3
  reinitpp run failures=proc@3:r5,proc@7:r2 --trace traces/ --trace-filter recovery,ckpt
  reinitpp run ranks=32 ranks_per_node=8 ckpt_tiers=local+partner2+fs trials=3
  reinitpp run failures=proc@3:r5,node@7:r12 spare_nodes=2 trials=3
  reinitpp reproduce --figure 6 --max-ranks 128 --jobs 8 trials=5
  reinitpp scale --max-ranks 16384 --jobs 8 trials=3
  reinitpp scale --max-ranks 262144 --shards 8 --jobs 8 trials=1
  reinitpp tiers --max-ranks 32 --jobs 4 trials=5
  reinitpp storm --max-ranks 256 --jobs 4 trials=5
  reinitpp crossover --max-ranks 64 --jobs 4 trials=3
  reinitpp shrink --max-ranks 64 --jobs 4 trials=3
  reinitpp integrity --max-ranks 64 --jobs 4 trials=3
  reinitpp run corrupt_rate=0.2 ckpt_keep=3 mtbf_s=0.5 trials=3
  reinitpp run recovery=repl repl_degree=2 ranks=32 ranks_per_node=8 trials=3
  reinitpp run recovery=shrink min_ranks=4 spare_nodes=0 failures=node@3:r5 trials=3
  reinitpp validate app=comd recovery=ulfm failure=process
";

/// Parse a `--jobs` value. Zero is rejected here with an explicit message
/// (it must never fall through to the worker pool): `1` is the documented
/// serial convention, there is no meaningful zero-worker execution.
fn parse_jobs(v: &str) -> Result<usize, CliError> {
    match v.parse::<usize>() {
        Ok(0) => Err(err("--jobs: must be >= 1 (use 1 for serial execution)")),
        Ok(n) => Ok(n),
        Err(_) => Err(err(format!("--jobs: not a worker count: {v}"))),
    }
}

/// Parse a `--shards` value: executor shards per trial. Like `--jobs` it
/// is a host knob — traces, CSVs and digests are byte-identical for any
/// value — and like `--jobs`, zero has no meaning (1 = serial event loop).
fn parse_shards(v: &str) -> Result<usize, CliError> {
    match v.parse::<usize>() {
        Ok(0) => Err(err("--shards: must be >= 1 (1 = the serial event loop)")),
        Ok(n) => Ok(n),
        Err(_) => Err(err(format!("--shards: not a shard count: {v}"))),
    }
}

/// Parse a `--trace-filter` value: comma-separated span categories checked
/// against the recorder's category universe, so a typo fails loudly instead
/// of silently recording nothing.
fn parse_trace_filter(v: &str) -> Result<Vec<String>, CliError> {
    let cats: Vec<String> = v
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if cats.is_empty() {
        return Err(err("--trace-filter: empty category list"));
    }
    for c in &cats {
        if !crate::trace::CATEGORIES.contains(&c.as_str()) {
            return Err(err(format!(
                "--trace-filter: unknown category `{c}` (known: {})",
                crate::trace::CATEGORIES.join(", ")
            )));
        }
    }
    Ok(cats)
}

/// Parse the sweep flags shared by `reproduce`/`scale`/`tiers`
/// (`--max-ranks`, `--outdir`, `--jobs`, `--profile-json`) from `leftovers`
/// into `opts`. `extra` handles command-specific flags (returns true if it
/// consumed the arg); anything else errors with the command name.
fn parse_sweep_opts<'a>(
    cmd: &str,
    leftovers: &'a [String],
    opts: &mut SweepOpts,
    mut extra: impl FnMut(&str, &mut std::slice::Iter<'a, String>) -> Result<bool, CliError>,
) -> Result<(), CliError> {
    let mut it = leftovers.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--max-ranks" => {
                let v = it.next().ok_or_else(|| err("--max-ranks needs a value"))?;
                opts.max_ranks = v.parse().map_err(|_| err("--max-ranks: number"))?;
            }
            "--outdir" => {
                opts.outdir = it
                    .next()
                    .ok_or_else(|| err("--outdir needs a value"))?
                    .clone();
            }
            "--jobs" => {
                let v = it.next().ok_or_else(|| err("--jobs needs a value"))?;
                opts.jobs = parse_jobs(v)?;
            }
            "--shards" => {
                let v = it.next().ok_or_else(|| err("--shards needs a value"))?;
                opts.shards = parse_shards(v)?;
            }
            "--profile-json" => {
                opts.profile = true;
            }
            other => {
                if !extra(other, &mut it)? {
                    return Err(err(format!("{cmd}: unknown arg {other}")));
                }
            }
        }
    }
    Ok(())
}

/// Sweeps own their failure axis: an explicit scenario (`failures=`) or an
/// MTBF process (`mtbf_s=`) sneaking in through `key=value` would make
/// every point lie about what it ran. `run`/`validate` are the places for
/// ad-hoc scenarios; `storm` sets `mtbf_s` per grid point itself.
fn reject_scenario_keys(cmd: &str, cfg: &ExperimentConfig) -> Result<(), CliError> {
    if !cfg.failures.is_empty() {
        return Err(err(format!(
            "{cmd}: the sweep owns its failure axis; drop failures= (use `run` \
             for explicit multi-failure scenarios)"
        )));
    }
    if cfg.mtbf_s > 0.0 {
        return Err(err(format!(
            "{cmd}: the sweep owns its failure axis; drop mtbf_s= \
             (the `storm` sweep sets MTBF per point)"
        )));
    }
    Ok(())
}

/// The replication axis is owned the same way: the figure sweeps reproduce
/// the paper's three methods (no replication row), and the grid sweeps set
/// the degree per point — `crossover` sweeps it explicitly. Ad-hoc degrees
/// belong on `run recovery=repl repl_degree=N`.
fn reject_repl_degree(cmd: &str, cfg: &ExperimentConfig) -> Result<(), CliError> {
    if cfg.repl_degree != 1 {
        return Err(err(format!(
            "{cmd}: repl_degree is not a free axis here (the crossover/storm \
             sweeps set it per point); use `run recovery=repl repl_degree=N`"
        )));
    }
    Ok(())
}

/// The imperfect-world knobs are owned the same way: the `integrity` sweep
/// sets corruption, detector noise and retention depth per grid point, and
/// on any sweep a non-default value sneaking in through `key=value` would
/// silently skew every family row. Ad-hoc imperfect-world scenarios belong
/// on `run` (e.g. `run corrupt_rate=0.2 ckpt_keep=3 mtbf_s=0.5`).
fn reject_integrity_keys(cmd: &str, cfg: &ExperimentConfig) -> Result<(), CliError> {
    let d = ExperimentConfig::default();
    let offenders = [
        (cfg.ckpt_keep != d.ckpt_keep, "ckpt_keep"),
        (cfg.corrupt_rate != d.corrupt_rate, "corrupt_rate"),
        (cfg.detect_fp_rate != d.detect_fp_rate, "detect_fp_rate"),
        (cfg.detect_jitter_s != d.detect_jitter_s, "detect_jitter_s"),
        (
            cfg.suspect_timeout_s != d.suspect_timeout_s,
            "suspect_timeout_s",
        ),
        (cfg.retry_budget != d.retry_budget, "retry_budget"),
    ];
    if let Some((_, key)) = offenders.iter().find(|(hit, _)| *hit) {
        return Err(err(format!(
            "{cmd}: {key} is not a free axis here (the `integrity` sweep sets \
             the imperfect-world knobs per point); use `run {key}=...` for \
             ad-hoc imperfect-world scenarios"
        )));
    }
    Ok(())
}

/// `min_ranks` only means anything to the shrinking family: on the figure
/// and grid sweeps it would either silently do nothing or skew one family
/// row, so only `shrink` (which owns that family) and `run`/`validate`
/// accept it.
fn reject_min_ranks(cmd: &str, cfg: &ExperimentConfig) -> Result<(), CliError> {
    if cfg.min_ranks != ExperimentConfig::default().min_ranks {
        return Err(err(format!(
            "{cmd}: min_ranks is a shrinking-recovery knob; use the `shrink` \
             sweep or `run recovery=shrink min_ranks=N`"
        )));
    }
    Ok(())
}

/// Grid axes a sweep subcommand owns (sets per point); user overrides are
/// rejected with a message naming the sweep rather than silently folded in.
/// The production analogue of the tests' `assert_rejects_keys` matrix —
/// one definition instead of a copy-pasted if-chain per subcommand.
struct GridOwnedAxes {
    /// Rank grid description (`"512..16384"`); the ranks axis is always
    /// sweep-owned (capped with `--max-ranks`).
    ranks_grid: &'static str,
    /// `Some` when the sweep runs every recovery method itself.
    recovery_owned: bool,
    /// What the sweep does on the failure axis ("injects a single process
    /// failure", "runs both process and node failures", ...).
    failure_axis: &'static str,
    /// What the sweep does on the checkpoint axis.
    ckpt_axis: &'static str,
    /// `true` when `min_ranks=` stays a free knob — only the `shrink`
    /// sweep, which runs the shrinking family itself.
    min_ranks_free: bool,
}

fn reject_grid_owned_axes(
    cmd: &str,
    cfg: &ExperimentConfig,
    axes: &GridOwnedAxes,
) -> Result<(), CliError> {
    reject_scenario_keys(cmd, cfg)?;
    reject_repl_degree(cmd, cfg)?;
    reject_integrity_keys(cmd, cfg)?;
    if !axes.min_ranks_free {
        reject_min_ranks(cmd, cfg)?;
    }
    let defaults = ExperimentConfig::default();
    if cfg.ranks != defaults.ranks {
        return Err(err(format!(
            "{cmd}: the sweep sets ranks per point ({}); cap the grid with \
             --max-ranks instead",
            axes.ranks_grid
        )));
    }
    if axes.recovery_owned && cfg.recovery != defaults.recovery {
        return Err(err(format!(
            "{cmd}: the sweep runs all recovery methods; drop recovery="
        )));
    }
    if cfg.failure != defaults.failure {
        return Err(err(format!(
            "{cmd}: the sweep {}; drop failure=",
            axes.failure_axis
        )));
    }
    if cfg.ckpt.is_some() || cfg.ckpt_tiers.is_some() {
        return Err(err(format!(
            "{cmd}: the sweep {}; drop ckpt/ckpt_tiers",
            axes.ckpt_axis
        )));
    }
    Ok(())
}

/// Parse argv (without the binary name).
pub fn parse(args: &[String]) -> Result<Command, CliError> {
    let Some(cmd) = args.first() else {
        return Ok(Command::Help);
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "help" | "--help" | "-h" => Ok(Command::Help),
        "tables" => {
            let mut which = None;
            let mut it = rest.iter();
            while let Some(a) = it.next() {
                match a.as_str() {
                    "--which" => {
                        let v = it.next().ok_or_else(|| err("--which needs a value"))?;
                        which = Some(v.parse().map_err(|_| err("--which: 1 or 2"))?);
                    }
                    other => return Err(err(format!("tables: unknown arg {other}"))),
                }
            }
            Ok(Command::Tables { which })
        }
        "run" => {
            let (cfg, leftovers) = parse_cfg(rest)?;
            let mut jobs = crate::harness::default_jobs();
            let mut shards = 1usize;
            let mut trace_dir: Option<String> = None;
            let mut trace_filter: Option<Vec<String>> = None;
            let mut it = leftovers.iter();
            while let Some(a) = it.next() {
                match a.as_str() {
                    "--jobs" => {
                        let v = it.next().ok_or_else(|| err("--jobs needs a value"))?;
                        jobs = parse_jobs(v)?;
                    }
                    "--shards" => {
                        let v = it.next().ok_or_else(|| err("--shards needs a value"))?;
                        shards = parse_shards(v)?;
                    }
                    "--trace" => {
                        let v = it
                            .next()
                            .ok_or_else(|| err("--trace needs a directory"))?;
                        trace_dir = Some(v.clone());
                    }
                    "--trace-filter" => {
                        let v = it.next().ok_or_else(|| {
                            err("--trace-filter needs a comma-separated category list")
                        })?;
                        trace_filter = Some(parse_trace_filter(v)?);
                    }
                    other => return Err(err(format!("run: unknown arg {other}"))),
                }
            }
            if trace_filter.is_some() && trace_dir.is_none() {
                return Err(err("run: --trace-filter needs --trace DIR"));
            }
            let trace = trace_dir.map(|dir| crate::trace::TraceConfig {
                dir,
                filter: trace_filter,
            });
            Ok(Command::Run {
                cfg,
                jobs,
                shards,
                trace,
            })
        }
        "validate" | "calibrate" => {
            let (cfg, leftovers) = parse_cfg(rest)?;
            if let Some(x) = leftovers.first() {
                return Err(err(format!("{cmd}: unknown arg {x}")));
            }
            Ok(match cmd.as_str() {
                "validate" => Command::Validate { cfg },
                _ => Command::Calibrate { cfg },
            })
        }
        "reproduce" => {
            let (cfg, leftovers) = parse_cfg(rest)?;
            reject_scenario_keys("reproduce", &cfg)?;
            reject_repl_degree("reproduce", &cfg)?;
            reject_integrity_keys("reproduce", &cfg)?;
            reject_min_ranks("reproduce", &cfg)?;
            let mut figure = None;
            let mut opts = SweepOpts::default();
            parse_sweep_opts("reproduce", &leftovers, &mut opts, |a, it| {
                if a != "--figure" {
                    return Ok(false);
                }
                let v = it.next().ok_or_else(|| err("--figure needs a value"))?;
                figure = Some(v.parse().map_err(|_| err("--figure: 0 or 4-7"))?);
                Ok(true)
            })?;
            let figure = figure.ok_or_else(|| err("reproduce: missing --figure"))?;
            if figure != 0 && !(4..=7).contains(&figure) {
                return Err(err("reproduce: --figure must be 0 (all) or 4..7"));
            }
            Ok(Command::Reproduce { figure, cfg, opts })
        }
        "scale" => {
            // Scale-sweep defaults: quick modeled trials — the grid reaches
            // 16k ranks, so per-rank work is kept small. Overridable via
            // key=value (except the grid-owned axes below).
            let base = ExperimentConfig {
                trials: 3,
                iters: 6,
                fidelity: crate::config::Fidelity::Modeled,
                hpccg_nx: 4,
                comd_n: 32,
                lulesh_nx: 4,
                ..ExperimentConfig::default()
            };
            let (cfg, leftovers) = parse_cfg_from(base, rest)?;
            reject_grid_owned_axes(
                "scale",
                &cfg,
                &GridOwnedAxes {
                    ranks_grid: "512..16384",
                    recovery_owned: true,
                    failure_axis: "injects a single process failure",
                    ckpt_axis: "uses the paper's Table 2 checkpoint policy per \
                                recovery method",
                    min_ranks_free: false,
                },
            )?;
            let mut opts = SweepOpts {
                max_ranks: 16_384,
                ..SweepOpts::default()
            };
            parse_sweep_opts("scale", &leftovers, &mut opts, |_, _| Ok(false))?;
            Ok(Command::Scale { cfg, opts })
        }
        "tiers" => {
            // Tier-sweep defaults: multiple compute nodes even at the
            // smallest rank count, so node-disjoint replicas (and node
            // failures) are meaningful. Overridable via key=value.
            let base = ExperimentConfig {
                ranks_per_node: crate::config::presets::TIER_SWEEP_RANKS_PER_NODE,
                ..ExperimentConfig::default()
            };
            let (cfg, leftovers) = parse_cfg_from(base, rest)?;
            // recovery_owned: false — the tier sweep compares stacks under
            // whichever single recovery method the user picks.
            reject_grid_owned_axes(
                "tiers",
                &cfg,
                &GridOwnedAxes {
                    ranks_grid: "16/32/64",
                    recovery_owned: false,
                    failure_axis: "runs both process and node failures",
                    ckpt_axis: "sets the checkpoint stack per point \
                                (fs / local+partner1 / local+partner2+fs)",
                    min_ranks_free: false,
                },
            )?;
            // the tier sweep compares stacks under a fixed-size world;
            // shrinking recovery resizes it per failure and has its own sweep
            if cfg.recovery == crate::config::RecoveryKind::Shrink {
                return Err(err(
                    "tiers: shrinking recovery resizes the world per failure; \
                     compare it via `reinitpp shrink` instead",
                ));
            }
            let mut opts = SweepOpts::default();
            parse_sweep_opts("tiers", &leftovers, &mut opts, |_, _| Ok(false))?;
            Ok(Command::Tiers { cfg, opts })
        }
        "storm" => {
            // Storm defaults: quick modeled trials whose *virtual* iteration
            // cost is stretched to paper scale (modeled_compute_scale) so
            // the application clock is long against the MTBF grid, while
            // the host-side per-rank grids stay tiny.
            let mut base = ExperimentConfig {
                trials: 3,
                iters: 40,
                fidelity: crate::config::Fidelity::Modeled,
                hpccg_nx: 4,
                comd_n: 32,
                lulesh_nx: 4,
                max_failures: crate::config::presets::STORM_MAX_FAILURES,
                ..ExperimentConfig::default()
            };
            base.calib.modeled_compute_scale = crate::config::presets::STORM_COMPUTE_SCALE;
            let (cfg, leftovers) = parse_cfg_from(base, rest)?;
            reject_grid_owned_axes(
                "storm",
                &cfg,
                &GridOwnedAxes {
                    ranks_grid: "16/64/256",
                    recovery_owned: true,
                    failure_axis: "injects process-failure storms",
                    ckpt_axis: "uses the paper's Table 2 checkpoint policy per \
                                recovery method",
                    min_ranks_free: false,
                },
            )?;
            let mut opts = SweepOpts::default();
            parse_sweep_opts("storm", &leftovers, &mut opts, |_, _| Ok(false))?;
            Ok(Command::Storm { cfg, opts })
        }
        "crossover" => {
            // Crossover defaults: the storm base (quick modeled trials with
            // paper-scale virtual iteration cost), plus 8 ranks/node so even
            // the 16-rank rung spans two compute nodes — degree-2 shadow
            // placement is a grid axis, not an opt-in.
            let mut base = ExperimentConfig {
                trials: 3,
                iters: 40,
                ranks_per_node: crate::config::presets::CROSSOVER_RANKS_PER_NODE,
                fidelity: crate::config::Fidelity::Modeled,
                hpccg_nx: 4,
                comd_n: 32,
                lulesh_nx: 4,
                max_failures: crate::config::presets::STORM_MAX_FAILURES,
                ..ExperimentConfig::default()
            };
            base.calib.modeled_compute_scale = crate::config::presets::STORM_COMPUTE_SCALE;
            let (cfg, leftovers) = parse_cfg_from(base, rest)?;
            reject_grid_owned_axes(
                "crossover",
                &cfg,
                &GridOwnedAxes {
                    ranks_grid: "16/64/256",
                    recovery_owned: true,
                    failure_axis: "injects process-failure storms",
                    ckpt_axis: "uses the paper's Table 2 checkpoint policy per \
                                recovery method",
                    min_ranks_free: false,
                },
            )?;
            // the checkpoint interval is the sweep's second axis
            if cfg.ckpt_every != ExperimentConfig::default().ckpt_every {
                return Err(err(
                    "crossover: the sweep sets ckpt_every per point; drop ckpt_every=",
                ));
            }
            let mut opts = SweepOpts::default();
            parse_sweep_opts("crossover", &leftovers, &mut opts, |_, _| Ok(false))?;
            Ok(Command::Crossover { cfg, opts })
        }
        "shrink" => {
            // Shrink-sweep defaults: the storm base (quick modeled trials
            // with paper-scale virtual iteration cost) at 8 ranks/node, so
            // a node failure leaves survivors to continue on at every rung.
            let mut base = ExperimentConfig {
                trials: 3,
                iters: 40,
                ranks_per_node: crate::config::presets::CROSSOVER_RANKS_PER_NODE,
                fidelity: crate::config::Fidelity::Modeled,
                hpccg_nx: 4,
                comd_n: 32,
                lulesh_nx: 4,
                max_failures: crate::config::presets::STORM_MAX_FAILURES,
                ..ExperimentConfig::default()
            };
            base.calib.modeled_compute_scale = crate::config::presets::STORM_COMPUTE_SCALE;
            let (cfg, leftovers) = parse_cfg_from(base, rest)?;
            reject_grid_owned_axes(
                "shrink",
                &cfg,
                &GridOwnedAxes {
                    ranks_grid: "16/64/256",
                    recovery_owned: true,
                    failure_axis: "runs both process- and node-failure storms",
                    ckpt_axis: "uses the paper's Table 2 checkpoint policy per \
                                recovery method",
                    min_ranks_free: true,
                },
            )?;
            // spare capacity is the axis under study: set per family row
            // (0 for shrink, 1 for the substitute and CR arms)
            if cfg.spare_nodes != ExperimentConfig::default().spare_nodes {
                return Err(err(
                    "shrink: the sweep sets spare_nodes per family row (0 for \
                     shrink, 1 for substitute/CR); drop spare_nodes=",
                ));
            }
            let mut opts = SweepOpts::default();
            parse_sweep_opts("shrink", &leftovers, &mut opts, |_, _| Ok(false))?;
            Ok(Command::Shrink { cfg, opts })
        }
        "integrity" => {
            // Integrity-sweep defaults: the storm base (quick modeled trials
            // with paper-scale virtual iteration cost) at 8 ranks/node. The
            // imperfect-world knobs themselves (corrupt_rate, detector
            // noise, ckpt_keep) are grid axes and rejected as free keys.
            let mut base = ExperimentConfig {
                trials: 3,
                iters: 40,
                ranks_per_node: crate::config::presets::CROSSOVER_RANKS_PER_NODE,
                fidelity: crate::config::Fidelity::Modeled,
                hpccg_nx: 4,
                comd_n: 32,
                lulesh_nx: 4,
                max_failures: crate::config::presets::STORM_MAX_FAILURES,
                ..ExperimentConfig::default()
            };
            base.calib.modeled_compute_scale = crate::config::presets::STORM_COMPUTE_SCALE;
            let (cfg, leftovers) = parse_cfg_from(base, rest)?;
            reject_grid_owned_axes(
                "integrity",
                &cfg,
                &GridOwnedAxes {
                    ranks_grid: "16/64/256",
                    recovery_owned: true,
                    failure_axis: "injects process-failure storms",
                    ckpt_axis: "uses the paper's Table 2 checkpoint policy per \
                                recovery method",
                    min_ranks_free: false,
                },
            )?;
            // spare capacity is set per family row (0 for shrink, 1 for the
            // respawning and CR families), mirroring the shrink sweep
            if cfg.spare_nodes != ExperimentConfig::default().spare_nodes {
                return Err(err(
                    "integrity: the sweep sets spare_nodes per family row (0 \
                     for shrink, 1 otherwise); drop spare_nodes=",
                ));
            }
            let mut opts = SweepOpts::default();
            parse_sweep_opts("integrity", &leftovers, &mut opts, |_, _| Ok(false))?;
            Ok(Command::Integrity { cfg, opts })
        }
        other => Err(err(format!("unknown command `{other}`\n\n{USAGE}"))),
    }
}

/// Extract `--config FILE` and `key=value` pairs; returns remaining args.
fn parse_cfg(args: &[String]) -> Result<(ExperimentConfig, Vec<String>), CliError> {
    parse_cfg_from(ExperimentConfig::default(), args)
}

/// Like `parse_cfg`, starting from a command-specific base config.
fn parse_cfg_from(
    base: ExperimentConfig,
    args: &[String],
) -> Result<(ExperimentConfig, Vec<String>), CliError> {
    let mut cfg = base;
    let mut leftovers = Vec::new();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        if a == "--config" {
            let path = it.next().ok_or_else(|| err("--config needs a file"))?;
            let text = std::fs::read_to_string(path)
                .map_err(|e| err(format!("reading {path}: {e}")))?;
            let doc = crate::config::toml::parse(&text).map_err(|e| err(e.to_string()))?;
            cfg.apply_doc(&doc).map_err(|e| err(e.to_string()))?;
        } else if let Some((k, v)) = a.split_once('=') {
            if a.starts_with("--") {
                leftovers.push(a.clone());
            } else {
                cfg.apply(k, v).map_err(|e| err(e.to_string()))?;
            }
        } else {
            leftovers.push(a.clone());
        }
    }
    Ok((cfg, leftovers))
}

/// Load the XLA runtime if the chosen fidelity needs it (single-trial
/// paths; the sweep paths resolve runtimes per worker the same way).
fn maybe_xla(cfg: &ExperimentConfig) -> Option<Rc<XlaRuntime>> {
    crate::recovery::job::RtCache::new().resolve(cfg)
}

/// Execute a parsed command; returns a process exit code.
pub fn execute(cmd: Command) -> i32 {
    // Install the process-wide executor shard count before any trial runs
    // (`run_trial` reads it; the pool workers inherit it). A host knob like
    // `--jobs`: any value produces byte-identical results.
    let shards = match &cmd {
        Command::Run { shards, .. } => Some(*shards),
        Command::Reproduce { opts, .. }
        | Command::Tiers { opts, .. }
        | Command::Scale { opts, .. }
        | Command::Storm { opts, .. }
        | Command::Crossover { opts, .. }
        | Command::Shrink { opts, .. }
        | Command::Integrity { opts, .. } => Some(opts.shards),
        _ => None,
    };
    if let Some(n) = shards {
        crate::sim::set_global_shards(n);
    }
    match cmd {
        Command::Help => {
            println!("{USAGE}");
            0
        }
        Command::Tables { which } => {
            match which {
                Some(1) => harness::print_table1(),
                Some(2) => harness::print_table2(),
                None => {
                    harness::print_table1();
                    harness::print_table2();
                }
                Some(n) => {
                    eprintln!("no table {n}");
                    return 2;
                }
            }
            0
        }
        Command::Run {
            cfg,
            jobs,
            shards,
            trace,
        } => {
            if let Err(e) = cfg.validate() {
                eprintln!("{e}");
                return 2;
            }
            // Install the process-wide trace destination before any trial
            // runs; the pool and `run_trial` pick it up from there.
            if trace.is_some() {
                crate::trace::set_global(trace.clone());
            }
            // Header must describe what actually gets injected: an explicit
            // scenario or MTBF process overrides the single-shot `failure=`
            // kind (which `FaultTimeline::plan` then ignores).
            let failure_desc = if !cfg.failures.is_empty() {
                let evs: Vec<String> = cfg.failures.iter().map(|e| e.to_string()).collect();
                format!("failures={}", evs.join(","))
            } else if cfg.mtbf_s > 0.0 {
                format!(
                    "mtbf_s={} ({} failures, <= {} events)",
                    cfg.mtbf_s, cfg.failure, cfg.max_failures
                )
            } else {
                format!("failure={}", cfg.failure)
            };
            println!(
                "# {} | ranks={} | {} | {} | ckpt={} | trials={} | jobs={}",
                cfg.app,
                cfg.ranks,
                cfg.recovery,
                failure_desc,
                cfg.effective_stack(),
                cfg.trials,
                jobs
            );
            if shards > 1 {
                println!(
                    "# executor shards: {shards} (host knob; results are \
                     byte-identical to --shards 1)"
                );
            }
            let p = harness::run_point(&cfg, jobs);
            if let Some(tc) = &trace {
                // Per-trial traces were written as each trial finished; the
                // pool-worker timeline (wall time) spans the whole point.
                let (events, samples) = crate::trace::take_pool_events();
                let dir = std::path::Path::new(&tc.dir);
                let path = dir.join("pool.trace.json");
                let wrote = std::fs::create_dir_all(dir)
                    .and_then(|_| crate::trace::chrome::write_pool(&path, &events, &samples));
                if let Err(e) = wrote {
                    crate::warnln!("could not write {}: {e}", path.display());
                }
                crate::trace::set_global(None);
            }
            harness::print_points("run", std::slice::from_ref(&p));
            if !cfg.failures.is_empty() || cfg.mtbf_s > 0.0 {
                // Multi-failure scenario: surface the per-event decomposition
                // (single-failure output stays byte-identical to the paper's).
                // These are per-trial TOTALS over the trial's segments (the
                // same quantities storm_compare.csv reports), not per-event
                // averages.
                println!(
                    "\nper-trial storm totals: {:.1} fired failure(s) | detect {:.3} s | \
                     recovery {:.3} s | rollback {:.3} s | degraded re-deploys {:.1}",
                    p.failures,
                    p.detect.mean,
                    p.event_recovery.mean,
                    p.rollback.mean,
                    p.degraded
                );
            }
            println!("\n(host busy time: {:.2} s across {jobs} worker(s))", p.wall_s);
            0
        }
        Command::Reproduce { figure, cfg, opts } => {
            let figs: Vec<u32> = if figure == 0 {
                vec![4, 5, 6, 7]
            } else {
                vec![figure]
            };
            for f in figs {
                match f {
                    4 => drop(harness::fig4(&cfg, &opts)),
                    5 => drop(harness::fig5(&cfg, &opts)),
                    6 => drop(harness::fig6(&cfg, &opts)),
                    7 => drop(harness::fig7(&cfg, &opts)),
                    _ => unreachable!(),
                }
            }
            0
        }
        Command::Tiers { cfg, opts } => match harness::tier_sweep(&cfg, &opts) {
            Ok(_) => 0,
            Err(e) => {
                eprintln!("{e}");
                2
            }
        },
        Command::Scale { cfg, opts } => match harness::scale_sweep(&cfg, &opts) {
            Ok(_) => 0,
            Err(e) => {
                eprintln!("{e}");
                2
            }
        },
        Command::Storm { cfg, opts } => match harness::storm_sweep(&cfg, &opts) {
            Ok(_) => 0,
            Err(e) => {
                eprintln!("{e}");
                2
            }
        },
        Command::Crossover { cfg, opts } => match harness::crossover_sweep(&cfg, &opts) {
            Ok(_) => 0,
            Err(e) => {
                eprintln!("{e}");
                2
            }
        },
        Command::Shrink { cfg, opts } => match harness::shrink_sweep(&cfg, &opts) {
            Ok(_) => 0,
            Err(e) => {
                eprintln!("{e}");
                2
            }
        },
        Command::Integrity { cfg, opts } => match harness::integrity_sweep(&cfg, &opts) {
            Ok(_) => 0,
            Err(e) => {
                eprintln!("{e}");
                2
            }
        },
        Command::Validate { cfg } => {
            if let Err(e) = cfg.validate() {
                eprintln!("{e}");
                return 2;
            }
            let xla = maybe_xla(&cfg);
            let mut free_cfg = cfg.clone();
            free_cfg.failure = crate::config::FailureKind::None;
            println!("validating global-restart equivalence: {cfg:?}");
            let free = run_trial(&free_cfg, 0, xla.clone());
            let faulty = run_trial(&cfg, 0, xla);
            if !faulty.completed {
                eprintln!("FAIL: faulty run did not complete (fault {:?})", faulty.faults);
                return 1;
            }
            if faulty.digests != free.digests {
                eprintln!(
                    "FAIL: recovered state differs from fault-free (fault {:?})",
                    faulty.faults
                );
                return 1;
            }
            println!(
                "OK: fault {:?} recovered bitwise-identically ({} ranks, recovery {:.3} s)",
                faulty.faults, cfg.ranks, faulty.breakdown.mpi_recovery_s
            );
            0
        }
        Command::Calibrate { cfg } => {
            let rt = XlaRuntime::load(&cfg.artifacts_dir)
                .expect("loading artifacts (run `make artifacts`)");
            println!("| artifact | mean execute (µs) | modeled cost (µs) |");
            println!("|---|---|---|");
            for name in [
                format!("comd_step_n{}", cfg.comd_n),
                format!("hpccg_matvec_{}", cfg.hpccg_nx),
                format!("hpccg_update_{}", cfg.hpccg_nx),
                format!("hpccg_direction_{}", cfg.hpccg_nx),
                format!("lulesh_step_{}", cfg.lulesh_nx),
            ] {
                if !rt.has_artifact(&name) {
                    println!("| {name} | (missing) | |");
                    continue;
                }
                let sig = rt.signature(&name).unwrap().clone();
                let inputs: Vec<crate::runtime::ArrayF32> = sig
                    .inputs
                    .iter()
                    .map(|s| {
                        let mut a = crate::runtime::ArrayF32::zeros(s);
                        for (i, v) in a.data.iter_mut().enumerate() {
                            *v = 0.5 + 0.1 * ((i % 7) as f32); // benign values
                        }
                        a
                    })
                    .collect();
                // warmup (compile) + timed reps
                let _ = rt.execute(&name, &inputs).unwrap();
                let reps = 10;
                let mut total = 0.0;
                for _ in 0..reps {
                    let (_, wall) = rt.execute(&name, &inputs).unwrap();
                    total += wall.as_secs_f64();
                }
                println!(
                    "| {} | {:.1} | {:.1} |",
                    name,
                    total / reps as f64 * 1e6,
                    crate::apps::native::modeled_cost_s(&name) * 1e6
                );
            }
            0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    /// Shared rejected-key assertion: `cmd` with each arg in `bad` alone
    /// must fail to parse, with an error that names the command (so the
    /// user sees *which* sweep owns the axis). Replaces the per-subcommand
    /// copy-pasted `assert!(parse(..).is_err())` blocks.
    fn assert_rejects_keys(cmd: &str, bad: &[&str]) {
        for arg in bad {
            let e = parse(&sv(&[cmd, arg]))
                .expect_err(&format!("{cmd} must reject `{arg}`"));
            assert!(
                e.to_string().contains(cmd),
                "{cmd} `{arg}`: error must name the command: {e}"
            );
        }
    }

    /// The grid-owned / scenario keys every sweep subcommand must reject
    /// rather than silently fold into its grid.
    #[test]
    fn sweep_subcommands_reject_owned_axes() {
        // (command, rejected key=value overrides)
        let matrix: &[(&str, &[&str])] = &[
            (
                "scale",
                &[
                    "ranks=4096",
                    "recovery=cr",
                    "recovery=shrink",
                    "failure=node",
                    "ckpt=file",
                    "ckpt_tiers=local+partner1",
                    "failures=proc@3:r5",
                    "mtbf_s=2",
                    "repl_degree=2",
                    "min_ranks=4",
                ],
            ),
            (
                "tiers",
                &[
                    "ranks=128",
                    "recovery=shrink",
                    "failure=node",
                    "ckpt_tiers=local+partner3",
                    "ckpt=memory",
                    "failures=proc@3:r5",
                    "mtbf_s=2",
                    "repl_degree=2",
                    "min_ranks=4",
                ],
            ),
            (
                "storm",
                &[
                    "ranks=128",
                    "recovery=cr",
                    "recovery=shrink",
                    "failure=node",
                    "ckpt=file",
                    "ckpt_tiers=local+partner1",
                    "failures=proc@3:r5",
                    "mtbf_s=2",
                    "repl_degree=2",
                    "min_ranks=4",
                ],
            ),
            (
                "crossover",
                &[
                    "ranks=128",
                    "recovery=cr",
                    "recovery=shrink",
                    "failure=node",
                    "ckpt=file",
                    "ckpt_tiers=local+partner1",
                    "failures=proc@3:r5",
                    "mtbf_s=2",
                    "repl_degree=2",
                    "ckpt_every=4",
                    "min_ranks=4",
                ],
            ),
            (
                "shrink",
                &[
                    "ranks=128",
                    "recovery=cr",
                    "failure=node",
                    "ckpt=file",
                    "ckpt_tiers=local+partner1",
                    "failures=proc@3:r5",
                    "mtbf_s=2",
                    "repl_degree=2",
                    "spare_nodes=2",
                    "ckpt_keep=3",
                    "corrupt_rate=0.1",
                ],
            ),
            (
                "integrity",
                &[
                    "ranks=128",
                    "recovery=cr",
                    "failure=node",
                    "ckpt=file",
                    "ckpt_tiers=local+partner1",
                    "failures=proc@3:r5",
                    "mtbf_s=2",
                    "repl_degree=2",
                    "spare_nodes=2",
                    "min_ranks=4",
                    "ckpt_keep=3",
                    "corrupt_rate=0.1",
                    "detect_fp_rate=0.5",
                    "detect_jitter_s=0.002",
                    "suspect_timeout_s=0.01",
                    "retry_budget=5",
                ],
            ),
        ];
        for (cmd, keys) in matrix {
            assert_rejects_keys(cmd, keys);
        }
        // reproduce owns its figure grids the same way for scenario keys,
        // and runs the paper's three methods — no replication axis
        assert!(parse(&sv(&["reproduce", "--figure", "4", "mtbf_s=2"])).is_err());
        assert!(parse(&sv(&["reproduce", "--figure", "4", "failures=proc@3:r5"])).is_err());
        assert!(parse(&sv(&["reproduce", "--figure", "4", "repl_degree=2"])).is_err());
        assert!(parse(&sv(&["reproduce", "--figure", "4", "min_ranks=4"])).is_err());
        // `run` accepts the scenario keys those sweeps reject
        assert!(parse(&sv(&["run", "mtbf_s=2"])).is_ok());
        assert!(parse(&sv(&["run", "failures=proc@3:r5"])).is_ok());
        assert!(parse(&sv(&["run", "recovery=repl", "repl_degree=2"])).is_ok());
        assert!(parse(&sv(&["run", "recovery=shrink", "min_ranks=4"])).is_ok());
        // the shrink sweep owns the shrink family: its floor stays a knob
        assert!(parse(&sv(&["shrink", "min_ranks=4"])).is_ok());
        // the imperfect-world knobs are the integrity sweep's grid; every
        // other sweep rejects them, while ad-hoc scenarios go through `run`
        assert!(parse(&sv(&["storm", "corrupt_rate=0.1"])).is_err());
        assert!(parse(&sv(&["scale", "detect_fp_rate=0.5"])).is_err());
        assert!(parse(&sv(&["reproduce", "--figure", "4", "ckpt_keep=3"])).is_err());
        assert!(parse(&sv(&[
            "run",
            "corrupt_rate=0.2",
            "ckpt_keep=3",
            "retry_budget=5",
            "detect_fp_rate=0.5",
            "detect_jitter_s=0.002",
            "suspect_timeout_s=0.01",
            "failure=process",
        ]))
        .is_ok());
    }

    #[test]
    fn parse_run_with_overrides() {
        let cmd = parse(&sv(&["run", "app=comd", "ranks=64", "trials=3"])).unwrap();
        match cmd {
            Command::Run {
                cfg,
                jobs,
                shards,
                trace,
            } => {
                assert_eq!(cfg.app, crate::config::AppKind::CoMD);
                assert_eq!(cfg.ranks, 64);
                assert_eq!(cfg.trials, 3);
                assert!(jobs >= 1, "defaults to available parallelism");
                assert_eq!(shards, 1, "the serial event loop is the default");
                assert!(trace.is_none(), "tracing is opt-in");
            }
            _ => panic!(),
        }
    }

    #[test]
    fn parse_shards_flag() {
        match parse(&sv(&["run", "--shards", "4", "ranks=16"])).unwrap() {
            Command::Run { shards, .. } => assert_eq!(shards, 4),
            _ => panic!(),
        }
        match parse(&sv(&["scale", "--shards", "2"])).unwrap() {
            Command::Scale { opts, .. } => assert_eq!(opts.shards, 2),
            _ => panic!(),
        }
        match parse(&sv(&["scale"])).unwrap() {
            Command::Scale { opts, .. } => assert_eq!(opts.shards, 1),
            _ => panic!(),
        }
        // zero has no meaning, same convention as --jobs
        for cmd in ["run", "scale", "storm"] {
            let e = parse(&sv(&[cmd, "--shards", "0"])).unwrap_err();
            assert!(e.to_string().contains("serial event loop"), "{cmd}: {e}");
        }
        assert!(parse(&sv(&["run", "--shards", "x"])).is_err());
        assert!(USAGE.contains("--shards"), "--help documents the knob");
    }

    #[test]
    fn parse_run_trace_flags() {
        let cmd = parse(&sv(&[
            "run",
            "ranks=16",
            "--trace",
            "/tmp/traces",
            "--trace-filter",
            "recovery,ckpt",
        ]))
        .unwrap();
        match cmd {
            Command::Run { trace, .. } => {
                let tc = trace.expect("--trace installs a destination");
                assert_eq!(tc.dir, "/tmp/traces");
                assert_eq!(
                    tc.filter.as_deref(),
                    Some(&["recovery".to_string(), "ckpt".to_string()][..])
                );
            }
            _ => panic!(),
        }
        // --trace alone records every category
        match parse(&sv(&["run", "--trace", "/tmp/traces"])).unwrap() {
            Command::Run { trace, .. } => assert!(trace.unwrap().filter.is_none()),
            _ => panic!(),
        }
        // typos fail loudly instead of recording nothing
        let e = parse(&sv(&["run", "--trace", "d", "--trace-filter", "warp"]))
            .unwrap_err();
        assert!(e.to_string().contains("unknown category"), "{e}");
        // --trace-filter without a destination is meaningless
        assert!(parse(&sv(&["run", "--trace-filter", "mpi"])).is_err());
    }

    #[test]
    fn parse_sweeps_profile_json() {
        for cmd in ["tiers", "scale", "storm", "crossover", "shrink", "integrity"] {
            match parse(&sv(&[cmd, "--profile-json"])).unwrap() {
                Command::Tiers { opts, .. }
                | Command::Scale { opts, .. }
                | Command::Storm { opts, .. }
                | Command::Crossover { opts, .. }
                | Command::Shrink { opts, .. }
                | Command::Integrity { opts, .. } => {
                    assert!(opts.profile, "{cmd}: --profile-json sets profile")
                }
                _ => panic!(),
            }
        }
        match parse(&sv(&["reproduce", "--figure", "4", "--profile-json"])).unwrap() {
            Command::Reproduce { opts, .. } => assert!(opts.profile),
            _ => panic!(),
        }
    }

    #[test]
    fn parse_run_with_jobs() {
        let cmd = parse(&sv(&["run", "--jobs", "1", "ranks=16"])).unwrap();
        match cmd {
            Command::Run { jobs, .. } => assert_eq!(jobs, 1),
            _ => panic!(),
        }
    }

    #[test]
    fn parse_reproduce() {
        let cmd = parse(&sv(&[
            "reproduce",
            "--figure",
            "6",
            "--max-ranks",
            "128",
            "--jobs",
            "4",
            "trials=5",
        ]))
        .unwrap();
        match cmd {
            Command::Reproduce { figure, cfg, opts } => {
                assert_eq!(figure, 6);
                assert_eq!(opts.max_ranks, 128);
                assert_eq!(opts.jobs, 4);
                assert_eq!(cfg.trials, 5);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn parse_errors() {
        assert!(parse(&sv(&["reproduce"])).is_err()); // missing --figure
        assert!(parse(&sv(&["reproduce", "--figure", "9"])).is_err());
        assert!(parse(&sv(&["reproduce", "--figure", "6", "--jobs", "0"])).is_err());
        assert!(parse(&sv(&["run", "--jobs", "x"])).is_err());
        assert!(parse(&sv(&["run", "bogus=1"])).is_err());
        assert!(parse(&sv(&["frobnicate"])).is_err());
    }

    #[test]
    fn parse_scale_defaults_and_guardrails() {
        let cmd = parse(&sv(&["scale", "--max-ranks", "2048", "--jobs", "2", "trials=3"]))
            .unwrap();
        match cmd {
            Command::Scale { cfg, opts } => {
                assert_eq!(cfg.trials, 3);
                assert_eq!(cfg.fidelity, crate::config::Fidelity::Modeled);
                assert_eq!(opts.max_ranks, 2048);
                assert_eq!(opts.jobs, 2);
            }
            _ => panic!(),
        }
        match parse(&sv(&["scale"])).unwrap() {
            Command::Scale { opts, .. } => {
                assert_eq!(opts.max_ranks, 16_384, "defaults past the paper's ceiling")
            }
            _ => panic!(),
        }
        // grid-owned axes: covered by sweep_subcommands_reject_owned_axes
        assert!(parse(&sv(&["scale", "--figure", "4"])).is_err(), "unknown arg");
    }

    #[test]
    fn jobs_zero_is_rejected_with_serial_hint() {
        for cmd in ["run", "tiers", "scale", "storm", "crossover", "shrink", "integrity"] {
            let e = parse(&sv(&[cmd, "--jobs", "0"])).unwrap_err();
            assert!(
                e.to_string().contains("use 1 for serial"),
                "{cmd}: error must document the 1 = serial convention: {e}"
            );
        }
        assert!(USAGE.contains("1 = serial"), "--help documents the convention");
    }

    #[test]
    fn parse_tiers_defaults_and_options() {
        let cmd = parse(&sv(&["tiers", "--max-ranks", "32", "--jobs", "2", "trials=4"]))
            .unwrap();
        match cmd {
            Command::Tiers { cfg, opts } => {
                assert_eq!(
                    cfg.ranks_per_node,
                    crate::config::presets::TIER_SWEEP_RANKS_PER_NODE,
                    "tiers base spans multiple nodes"
                );
                assert_eq!(cfg.trials, 4);
                assert_eq!(opts.max_ranks, 32);
                assert_eq!(opts.jobs, 2);
            }
            _ => panic!(),
        }
        assert!(parse(&sv(&["tiers", "--figure", "4"])).is_err(), "unknown arg");
        // grid-owned axes: covered by sweep_subcommands_reject_owned_axes
    }

    #[test]
    fn parse_storm_defaults_and_options() {
        let cmd = parse(&sv(&["storm", "--max-ranks", "64", "--jobs", "2", "trials=4"]))
            .unwrap();
        match cmd {
            Command::Storm { cfg, opts } => {
                assert_eq!(cfg.trials, 4);
                assert_eq!(cfg.fidelity, crate::config::Fidelity::Modeled);
                assert_eq!(
                    cfg.max_failures,
                    crate::config::presets::STORM_MAX_FAILURES
                );
                assert!(cfg.iters >= 20, "storm base stretches the app clock");
                assert_eq!(opts.max_ranks, 64);
                assert_eq!(opts.jobs, 2);
            }
            _ => panic!(),
        }
        assert!(parse(&sv(&["storm", "--figure", "4"])).is_err(), "unknown arg");
        // trial count / iteration knobs stay overridable
        assert!(parse(&sv(&["storm", "iters=60", "max_failures=3"])).is_ok());
    }

    #[test]
    fn parse_crossover_defaults_and_options() {
        let cmd = parse(&sv(&[
            "crossover",
            "--max-ranks",
            "64",
            "--jobs",
            "2",
            "trials=4",
        ]))
        .unwrap();
        match cmd {
            Command::Crossover { cfg, opts } => {
                assert_eq!(cfg.trials, 4);
                assert_eq!(cfg.fidelity, crate::config::Fidelity::Modeled);
                assert_eq!(
                    cfg.ranks_per_node,
                    crate::config::presets::CROSSOVER_RANKS_PER_NODE,
                    "crossover base spans >= 2 nodes on every rung"
                );
                assert_eq!(
                    cfg.max_failures,
                    crate::config::presets::STORM_MAX_FAILURES
                );
                assert_eq!(opts.max_ranks, 64);
                assert_eq!(opts.jobs, 2);
            }
            _ => panic!(),
        }
        assert!(parse(&sv(&["crossover", "--figure", "4"])).is_err(), "unknown arg");
        // trial count / iteration knobs stay overridable
        assert!(parse(&sv(&["crossover", "iters=60", "max_failures=3"])).is_ok());
    }

    #[test]
    fn parse_shrink_defaults_and_options() {
        let cmd = parse(&sv(&[
            "shrink",
            "--max-ranks",
            "64",
            "--jobs",
            "2",
            "trials=4",
            "min_ranks=4",
        ]))
        .unwrap();
        match cmd {
            Command::Shrink { cfg, opts } => {
                assert_eq!(cfg.trials, 4);
                assert_eq!(cfg.min_ranks, 4, "the shrink floor stays overridable");
                assert_eq!(cfg.fidelity, crate::config::Fidelity::Modeled);
                assert_eq!(
                    cfg.ranks_per_node,
                    crate::config::presets::CROSSOVER_RANKS_PER_NODE,
                    "shrink base spans >= 2 nodes on every rung"
                );
                assert_eq!(
                    cfg.max_failures,
                    crate::config::presets::STORM_MAX_FAILURES
                );
                assert_eq!(opts.max_ranks, 64);
                assert_eq!(opts.jobs, 2);
            }
            _ => panic!(),
        }
        assert!(parse(&sv(&["shrink", "--figure", "4"])).is_err(), "unknown arg");
        // trial count / iteration knobs stay overridable
        assert!(parse(&sv(&["shrink", "iters=60", "max_failures=3"])).is_ok());
    }

    #[test]
    fn parse_integrity_defaults_and_options() {
        let cmd = parse(&sv(&[
            "integrity",
            "--max-ranks",
            "64",
            "--jobs",
            "2",
            "trials=4",
        ]))
        .unwrap();
        match cmd {
            Command::Integrity { cfg, opts } => {
                assert_eq!(cfg.trials, 4);
                assert_eq!(cfg.fidelity, crate::config::Fidelity::Modeled);
                assert_eq!(
                    cfg.ranks_per_node,
                    crate::config::presets::CROSSOVER_RANKS_PER_NODE,
                    "integrity base spans >= 2 nodes on every rung"
                );
                assert_eq!(
                    cfg.max_failures,
                    crate::config::presets::STORM_MAX_FAILURES
                );
                // the imperfect-world knobs stay at their perfect defaults
                // on the base config: the sweep arms them per grid point
                assert_eq!(cfg.corrupt_rate, 0.0);
                assert_eq!(cfg.ckpt_keep, 1);
                assert_eq!(opts.max_ranks, 64);
                assert_eq!(opts.jobs, 2);
            }
            _ => panic!(),
        }
        assert!(parse(&sv(&["integrity", "--figure", "4"])).is_err(), "unknown arg");
        // trial count / iteration knobs stay overridable
        assert!(parse(&sv(&["integrity", "iters=60", "max_failures=3"])).is_ok());
    }

    #[test]
    fn parse_run_with_failure_scenario() {
        let cmd = parse(&sv(&[
            "run",
            "failures=proc@3:r5,node@7:r12,proc@t1.25:r3",
            "spare_nodes=2",
        ]))
        .unwrap();
        match cmd {
            Command::Run { cfg, .. } => {
                assert_eq!(cfg.failures.len(), 3);
                assert_eq!(cfg.failures[1].to_string(), "node@7:r12");
            }
            _ => panic!(),
        }
        assert!(parse(&sv(&["run", "failures=warp@1:r0"])).is_err());
    }

    #[test]
    fn parse_tier_stack_overrides() {
        let cmd = parse(&sv(&[
            "run",
            "ranks=32",
            "ranks_per_node=8",
            "ckpt_tiers=local+partner2+fs",
            "ckpt_drain_interval_s=0.5",
        ]))
        .unwrap();
        match cmd {
            Command::Run { cfg, .. } => {
                let s = cfg.effective_stack();
                assert_eq!(s.to_string(), "local+partner2+fs");
                assert_eq!(s.drain_interval_s, 0.5);
            }
            _ => panic!(),
        }
        assert!(parse(&sv(&["run", "ckpt_tiers=warp"])).is_err());
    }

    #[test]
    fn parse_tables_and_help() {
        assert!(matches!(parse(&sv(&[])).unwrap(), Command::Help));
        assert!(matches!(
            parse(&sv(&["tables", "--which", "2"])).unwrap(),
            Command::Tables { which: Some(2) }
        ));
    }
}
