//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation section (§5). Each `figN` driver sweeps the paper's parameter
//! grid, runs `trials` seeded repetitions per point, and emits the same
//! rows/series the paper plots — as a markdown table on stdout and a CSV
//! under `results/`.

mod figures;
mod tables;

pub use figures::{fig4, fig5, fig6, fig7, print_points, write_csv, SweepOpts};
pub use tables::{print_table1, print_table2};

use std::rc::Rc;

use crate::config::ExperimentConfig;
use crate::metrics::{mean_ci95, Summary};
use crate::recovery::job::run_trial;
use crate::runtime::XlaRuntime;

/// Aggregated result of `trials` runs of one experiment point.
#[derive(Clone, Debug)]
pub struct Point {
    pub cfg: ExperimentConfig,
    pub total: Summary,
    pub ckpt_write: Summary,
    pub ckpt_read: Summary,
    pub recovery: Summary,
    pub app: Summary,
    /// Real (host) seconds spent producing this point.
    pub wall_s: f64,
}

/// Run all trials of one point and summarize (the paper's §4 methodology:
/// independent seeded trials, mean + 95% t-CI).
pub fn run_point(cfg: &ExperimentConfig, xla: Option<Rc<XlaRuntime>>) -> Point {
    let t0 = std::time::Instant::now();
    let mut total = Vec::new();
    let mut wr = Vec::new();
    let mut rd = Vec::new();
    let mut rec = Vec::new();
    let mut app = Vec::new();
    for trial in 0..cfg.trials {
        let r = run_trial(cfg, trial, xla.clone());
        assert!(
            r.completed,
            "trial {trial} of {}/{}/{} ranks={} did not complete",
            cfg.app, cfg.recovery, cfg.failure, cfg.ranks
        );
        total.push(r.breakdown.total_s);
        wr.push(r.breakdown.ckpt_write_s);
        rd.push(r.breakdown.ckpt_read_s);
        rec.push(r.breakdown.mpi_recovery_s);
        app.push(r.breakdown.app_s());
    }
    Point {
        cfg: cfg.clone(),
        total: mean_ci95(&total),
        ckpt_write: mean_ci95(&wr),
        ckpt_read: mean_ci95(&rd),
        recovery: mean_ci95(&rec),
        app: mean_ci95(&app),
        wall_s: t0.elapsed().as_secs_f64(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AppKind, FailureKind, Fidelity, RecoveryKind};

    #[test]
    fn run_point_aggregates_trials() {
        let mut cfg = ExperimentConfig::default();
        cfg.app = AppKind::Hpccg;
        cfg.recovery = RecoveryKind::Reinit;
        cfg.failure = FailureKind::Process;
        cfg.ranks = 8;
        cfg.ranks_per_node = 4;
        cfg.iters = 5;
        cfg.trials = 3;
        cfg.fidelity = Fidelity::Modeled;
        cfg.hpccg_nx = 4;
        let p = run_point(&cfg, None);
        assert_eq!(p.recovery.n, 3);
        assert!(p.recovery.mean > 0.2);
        assert!(p.total.mean > p.recovery.mean);
    }
}
