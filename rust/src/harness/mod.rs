//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation section (§5). Each `figN` driver sweeps the paper's parameter
//! grid, runs `trials` seeded repetitions per point, and emits the same
//! rows/series the paper plots — as a markdown table on stdout and a CSV
//! under `results/`.
//!
//! Trials execute on the parallel sweep scheduler (`pool`): the whole
//! sweep is flattened into (point, trial) work items, fanned out over
//! worker threads, and merged back in (point, trial) order, so every table
//! and CSV is bit-identical to a serial run for any `--jobs` value.

mod crossover;
mod figures;
mod integrity;
mod pool;
mod scale;
mod shrink;
mod storm;
mod tables;
mod tiers;

pub use crossover::crossover_sweep;
pub use figures::{fig4, fig5, fig6, fig7, print_points, write_csv, SweepOpts};
pub use integrity::integrity_sweep;
pub use pool::{default_jobs, run_trials, TrialOut, TrialSpec};
pub use scale::scale_sweep;
pub use shrink::shrink_sweep;
pub use storm::storm_sweep;
pub use tables::{print_table1, print_table2};
pub use tiers::tier_sweep;

use crate::config::ExperimentConfig;
use crate::metrics::{mean_ci95, StorageMeans, Summary, SweepStats};

/// Aggregated result of `trials` runs of one experiment point.
#[derive(Clone, Debug)]
pub struct Point {
    pub cfg: ExperimentConfig,
    pub total: Summary,
    pub ckpt_write: Summary,
    pub ckpt_read: Summary,
    pub recovery: Summary,
    pub app: Summary,
    /// Per-trial *sums* over the per-failure-event segments (multi-failure
    /// decomposition; all zero in fault-free runs). `event_recovery` is the
    /// per-event analogue of `recovery`, which stays the paper's aggregate
    /// first-failure → last-resume window.
    pub detect: Summary,
    pub event_recovery: Summary,
    pub rollback: Summary,
    /// Per-trial sum of replication failover (shadow-promotion) windows —
    /// the time a failover segment books instead of recovery + rollback.
    /// Zero for the non-replicated recovery families.
    pub failover: Summary,
    /// Mean number of fired failures per trial (storms: events can also
    /// hit dead air and fire as no-ops).
    pub failures: f64,
    /// Mean number of zero-rollback failovers per trial (replication only).
    pub failovers: f64,
    /// Mean number of degraded (spare-exhausted or below-`min_ranks`)
    /// re-deploys per trial.
    pub degraded: f64,
    /// Mean number of shrink events per trial (shrinking recovery only):
    /// failures absorbed by continuing on survivors instead of respawning.
    pub shrinks: f64,
    /// Mean per-trial checkpoint traffic moved by ReStore-style
    /// redistribution after a shrink, in MB.
    pub redistribute_mb: f64,
    /// Mean per-trial compute stall attributable to state mirroring, and
    /// mean mirrored traffic in MB (replication's steady-state overhead).
    pub mirror_s: f64,
    pub mirror_mb: f64,
    /// Slowest rank's checkpoint verification scans (integrity sweeps;
    /// zero with the machinery off).
    pub verify: Summary,
    /// Mean per-trial integrity/detector counters (all zero under perfect
    /// storage + perfect detection): extra rollback iterations forced by
    /// corrupted newest generations, recoveries triggered by false
    /// suspicions, older-generation agreement retries, and escalations to
    /// an iteration-0 degraded re-deploy.
    pub fallback_iters: f64,
    pub spurious: f64,
    pub retries: f64,
    pub escalations: f64,
    /// Mean per-trial storage traffic (per-tier + shared-disk counters).
    pub storage: StorageMeans,
    /// Host seconds of trial compute attributed to this point (sum over its
    /// trials' busy time; equals elapsed wall-clock only in a serial run).
    pub wall_s: f64,
    /// Per-trial executor counters + trial identity hash, in trial order
    /// (always collected — they are a handful of integers per trial).
    /// `--profile-json` serializes them next to the sweep CSV.
    pub profiles: Vec<crate::trace::TrialCounters>,
}

/// Summarize one point's finished trials (the paper's §4 methodology:
/// independent seeded trials, mean + 95% t-CI). `outs` must hold exactly
/// this point's trials in trial order.
fn aggregate_point(cfg: &ExperimentConfig, outs: &[TrialOut]) -> Point {
    debug_assert_eq!(outs.len(), cfg.trials as usize);
    let mut total = Vec::with_capacity(outs.len());
    let mut wr = Vec::with_capacity(outs.len());
    let mut rd = Vec::with_capacity(outs.len());
    let mut rec = Vec::with_capacity(outs.len());
    let mut app = Vec::with_capacity(outs.len());
    let mut detect: Vec<f64> = Vec::with_capacity(outs.len());
    let mut ev_rec: Vec<f64> = Vec::with_capacity(outs.len());
    let mut rollback: Vec<f64> = Vec::with_capacity(outs.len());
    let mut failover: Vec<f64> = Vec::with_capacity(outs.len());
    let mut fired = 0u32;
    let mut failovers = 0u64;
    let mut degraded = 0u32;
    let mut shrinks = 0u64;
    let mut redistribute_mb = 0.0;
    let mut mirror_s = 0.0;
    let mut mirror_mb = 0.0;
    let mut verify: Vec<f64> = Vec::with_capacity(outs.len());
    let mut fallback_iters = 0u64;
    let mut spurious = 0u64;
    let mut retries = 0u64;
    let mut escalations = 0u64;
    let mut storage = Vec::with_capacity(outs.len());
    for o in outs {
        assert!(
            o.result.completed,
            "trial {} of {}/{}/{} ranks={} did not complete",
            o.trial, cfg.app, cfg.recovery, cfg.failure, cfg.ranks
        );
        total.push(o.result.breakdown.total_s);
        wr.push(o.result.breakdown.ckpt_write_s);
        rd.push(o.result.breakdown.ckpt_read_s);
        rec.push(o.result.breakdown.mpi_recovery_s);
        app.push(o.result.breakdown.app_s());
        detect.push(o.result.segments.iter().map(|s| s.detect_s).sum());
        ev_rec.push(o.result.segments.iter().map(|s| s.recovery_s).sum());
        rollback.push(o.result.segments.iter().map(|s| s.rollback_s).sum());
        failover.push(o.result.segments.iter().map(|s| s.failover_s).sum());
        // `corrupt@` events fire too, but corrupt nothing alive — keep the
        // failure count a count of actual kills.
        fired += o
            .result
            .faults
            .iter()
            .filter(|f| f.fired && !f.event.corrupt)
            .count() as u32;
        failovers += o.result.failovers;
        verify.push(o.result.breakdown.verify_s);
        fallback_iters += o.result.fallback_iters;
        spurious += o.result.spurious_recoveries;
        retries += o.result.ckpt_retries;
        escalations += o.result.escalations;
        degraded += o
            .result
            .segments
            .iter()
            .filter(|s| s.degraded_redeploy)
            .count() as u32;
        shrinks += o.result.shrinks;
        redistribute_mb += o.result.redistribute_mb;
        mirror_s += o.result.mirror_s;
        mirror_mb += o.result.mirror_mb;
        storage.push(o.result.storage);
    }
    let n = outs.len().max(1) as f64;
    Point {
        cfg: cfg.clone(),
        total: mean_ci95(&total),
        ckpt_write: mean_ci95(&wr),
        ckpt_read: mean_ci95(&rd),
        recovery: mean_ci95(&rec),
        app: mean_ci95(&app),
        detect: mean_ci95(&detect),
        event_recovery: mean_ci95(&ev_rec),
        rollback: mean_ci95(&rollback),
        failover: mean_ci95(&failover),
        failures: fired as f64 / n,
        failovers: failovers as f64 / n,
        degraded: degraded as f64 / n,
        shrinks: shrinks as f64 / n,
        redistribute_mb: redistribute_mb / n,
        mirror_s: mirror_s / n,
        mirror_mb: mirror_mb / n,
        verify: mean_ci95(&verify),
        fallback_iters: fallback_iters as f64 / n,
        spurious: spurious as f64 / n,
        retries: retries as f64 / n,
        escalations: escalations as f64 / n,
        storage: StorageMeans::from_trials(&storage),
        wall_s: outs.iter().map(|o| o.host_s).sum(),
        profiles: outs.iter().map(|o| o.result.counters).collect(),
    }
}

/// Run every trial of every point on `jobs` workers (trial-granular
/// fan-out: one expensive point spreads across all cores) and merge back
/// into per-point summaries in (point, trial) order.
pub fn run_points(
    cfgs: &[ExperimentConfig],
    jobs: usize,
) -> (Vec<Point>, SweepStats) {
    let specs: Vec<TrialSpec> = cfgs
        .iter()
        .enumerate()
        .flat_map(|(point, cfg)| {
            (0..cfg.trials).map(move |trial| TrialSpec {
                point,
                trial,
                cfg: cfg.clone(),
            })
        })
        .collect();
    let (outs, stats) = run_trials(specs, jobs);
    let mut points = Vec::with_capacity(cfgs.len());
    let mut off = 0;
    for cfg in cfgs {
        let n = cfg.trials as usize;
        points.push(aggregate_point(cfg, &outs[off..off + n]));
        off += n;
    }
    (points, stats)
}

/// Run all trials of one point and summarize. `jobs = 1` is the old serial
/// path; more workers split the point's trials across cores.
pub fn run_point(cfg: &ExperimentConfig, jobs: usize) -> Point {
    run_points(std::slice::from_ref(cfg), jobs)
        .0
        .pop()
        .expect("one point in, one point out")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AppKind, FailureKind, Fidelity, RecoveryKind};

    fn quick_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.app = AppKind::Hpccg;
        cfg.recovery = RecoveryKind::Reinit;
        cfg.failure = FailureKind::Process;
        cfg.ranks = 8;
        cfg.ranks_per_node = 4;
        cfg.iters = 5;
        cfg.trials = 3;
        cfg.fidelity = Fidelity::Modeled;
        cfg.hpccg_nx = 4;
        cfg
    }

    #[test]
    fn run_point_aggregates_trials() {
        let p = run_point(&quick_cfg(), 1);
        assert_eq!(p.recovery.n, 3);
        assert!(p.recovery.mean > 0.2);
        assert!(p.total.mean > p.recovery.mean);
        assert!(p.wall_s > 0.0);
    }

    #[test]
    fn run_point_parallel_equals_serial() {
        let serial = run_point(&quick_cfg(), 1);
        let parallel = run_point(&quick_cfg(), 3);
        assert_eq!(serial.total, parallel.total);
        assert_eq!(serial.ckpt_write, parallel.ckpt_write);
        assert_eq!(serial.ckpt_read, parallel.ckpt_read);
        assert_eq!(serial.recovery, parallel.recovery);
        assert_eq!(serial.app, parallel.app);
    }

    #[test]
    fn run_points_merges_in_point_order() {
        let mut a = quick_cfg();
        a.recovery = RecoveryKind::Cr;
        let b = quick_cfg();
        let (pts, stats) = run_points(&[a, b], 4);
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[0].cfg.recovery, RecoveryKind::Cr);
        assert_eq!(pts[1].cfg.recovery, RecoveryKind::Reinit);
        assert_eq!(stats.trials, 6);
        assert!(stats.wall_s > 0.0);
    }
}
