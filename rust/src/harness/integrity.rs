//! Imperfect-world sweep (`reinitpp integrity`): checkpoint corruption ×
//! detector noise × retention depth × recovery family, over process-failure
//! storms.
//!
//! Every other sweep assumes a perfect world: checkpoints read back exactly
//! as written and the failure detector never lies. This sweep prices both
//! assumptions. The corruption axis draws seeded per-copy bit-rot
//! (`corrupt_rate`) that verify-on-load only discovers at recovery time;
//! the retention axis (`ckpt_keep`) decides how many older generations the
//! fallback can dig through before escalating to an iteration-0
//! `degraded_redeploy`; the detector axis adds false suspicions that
//! trigger real, fully-costed recoveries plus detection-latency jitter.
//! Crossing them against all five recovery families shows who pays most
//! for an imperfect world: CR re-deploys per spurious recovery, the
//! in-place families re-verify per event, and replication's mirrors dodge
//! the corruption axis entirely (the mirror protocol verifies in-line).
//!
//! Like every harness sweep, the grid is flattened to (point, trial) work
//! items for the pool and merged deterministically, so
//! `integrity_compare.csv` is byte-identical for any `--jobs` value
//! (pinned by the unit test below and a serial-vs-2-worker `cmp` in CI).

use super::figures::{cell, SweepOpts};
use super::{run_points, Point};
use crate::config::{presets, ExperimentConfig, FailureKind, Fidelity, RecoveryKind};

/// The family rows of the grid: (recovery, spare nodes). Shrink runs with
/// zero spares by construction (its whole point); everyone else gets the
/// paper's one spare node.
const FAMILIES: [(RecoveryKind, u32); 5] = [
    (RecoveryKind::Cr, 1),
    (RecoveryKind::Reinit, 1),
    (RecoveryKind::Ulfm, 1),
    (RecoveryKind::Replication, 1),
    (RecoveryKind::Shrink, 0),
];

/// Rank counts the integrity sweep visits (the storm rungs, capped by
/// `--max-ranks`).
fn sweep_ranks(max: u32) -> Vec<u32> {
    presets::STORM_SWEEP_RANKS
        .iter()
        .copied()
        .filter(|&r| r <= max)
        .collect()
}

/// Build the sweep grid: ranks × family × corrupt rate × detector bundle ×
/// retention depth, process-failure storms at the middle storm MTBF,
/// modeled fidelity.
fn build_grid(
    base: &ExperimentConfig,
    opts: &SweepOpts,
) -> Result<Vec<ExperimentConfig>, String> {
    if base.fidelity != Fidelity::Modeled {
        return Err(
            "integrity: the sweep runs fidelity=modeled (storm trials re-execute \
             many iterations); drop fidelity="
                .to_string(),
        );
    }
    let mut cfgs = Vec::new();
    for &ranks in &sweep_ranks(opts.max_ranks) {
        for &(rk, spares) in &FAMILIES {
            for &rate in &presets::INTEGRITY_CORRUPT_RATES {
                for &(fp, jitter, timeout) in &presets::INTEGRITY_DETECTORS {
                    for &keep in &presets::INTEGRITY_KEEP {
                        let mut c = base.clone();
                        c.ranks = ranks;
                        c.recovery = rk;
                        c.failure = FailureKind::Process;
                        c.mtbf_s = presets::INTEGRITY_MTBF_S;
                        c.spare_nodes = spares;
                        c.corrupt_rate = rate;
                        c.detect_fp_rate = fp;
                        c.detect_jitter_s = jitter;
                        c.suspect_timeout_s = timeout;
                        c.ckpt_keep = keep;
                        c.ckpt = None; // Table 2 policy per method
                        if rk == RecoveryKind::Replication {
                            c.repl_degree = presets::STORM_REPL_DEGREE;
                            if c.nodes() < c.repl_degree {
                                continue; // no node-disjoint shadow on this rung
                            }
                        }
                        c.validate().map_err(|e| {
                            format!(
                                "integrity sweep point ranks={ranks} recovery={rk} \
                                 corrupt_rate={rate} detect_fp_rate={fp} \
                                 ckpt_keep={keep}: {e}"
                            )
                        })?;
                        cfgs.push(c);
                    }
                }
            }
        }
    }
    if cfgs.is_empty() {
        return Err(format!(
            "integrity sweep: no rank count of {:?} fits --max-ranks {}",
            presets::STORM_SWEEP_RANKS,
            opts.max_ranks
        ));
    }
    Ok(cfgs)
}

/// Run the imperfect-world sweep: markdown table on stdout, CSV under
/// `outdir/integrity_compare.csv`.
pub fn integrity_sweep(
    base: &ExperimentConfig,
    opts: &SweepOpts,
) -> Result<Vec<Point>, String> {
    let cfgs = build_grid(base, opts)?;
    let trials: u32 = cfgs.iter().map(|c| c.trials).sum();
    crate::info!(
        "  integrity sweep: {} points / {trials} trials (corrupt {:?}, keep {:?}, \
         detectors {:?}) on {} worker(s)...",
        cfgs.len(),
        presets::INTEGRITY_CORRUPT_RATES,
        presets::INTEGRITY_KEEP,
        presets::INTEGRITY_DETECTORS,
        opts.jobs
    );
    let (points, stats) = run_points(&cfgs, opts.jobs);
    super::figures::finish_sweep("integrity_compare", opts, &points, &stats);

    println!(
        "\n## Imperfect world ({}): corruption x detector noise x retention\n",
        base.app
    );
    println!(
        "| ranks | recovery | corrupt | fp/s | keep | failures | spurious | \
         retries | fallback | escal. | verify (s) | total (s) | recovery (s) | \
         degraded |"
    );
    println!("|---|---|---|---|---|---|---|---|---|---|---|---|---|---|");
    for p in &points {
        println!(
            "| {} | {} | {} | {} | {} | {:.1} | {:.1} | {:.1} | {:.1} | {:.1} | \
             {:.4} | {} | {} | {:.1} |",
            p.cfg.ranks,
            p.cfg.recovery,
            p.cfg.corrupt_rate,
            p.cfg.detect_fp_rate,
            p.cfg.ckpt_keep,
            p.failures,
            p.spurious,
            p.retries,
            p.fallback_iters,
            p.escalations,
            p.verify.mean,
            cell(&p.total),
            cell(&p.event_recovery),
            p.degraded,
        );
    }
    println!("\n(expected shape: corruption costs nothing until a recovery reads it —");
    println!(" then keep=1 escalates to iteration-0 re-deploys where keep=3 falls");
    println!(" back a few iterations; a lying detector taxes every family with");
    println!(" real recoveries — see EXPERIMENTS.md §Checkpoint integrity)");

    if let Err(e) = write_integrity_csv(&opts.outdir, &points) {
        crate::warnln!("could not write integrity_compare.csv: {e}");
    }
    Ok(points)
}

/// `integrity_compare.csv`: one row per grid point, with the imperfect-world
/// bookkeeping columns next to the per-event decomposition.
fn write_integrity_csv(outdir: &str, points: &[Point]) -> std::io::Result<()> {
    std::fs::create_dir_all(outdir)?;
    let mut s = String::from(
        "app,ranks,recovery,failure,corrupt_rate,detect_fp_rate,ckpt_keep,\
         retry_budget,mtbf_s,max_failures,failures,spurious,retries,\
         fallback_iters,escalations,degraded,verify_s,\
         total_s,total_ci,detect_s,detect_ci,recovery_s,recovery_ci,\
         rollback_s,rollback_ci,ckpt_write_s,ckpt_read_s,app_s,trials\n",
    );
    for p in points {
        s.push_str(&format!(
            "{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{}\n",
            p.cfg.app,
            p.cfg.ranks,
            p.cfg.recovery,
            p.cfg.failure,
            p.cfg.corrupt_rate,
            p.cfg.detect_fp_rate,
            p.cfg.ckpt_keep,
            p.cfg.retry_budget,
            p.cfg.mtbf_s,
            p.cfg.max_failures,
            p.failures,
            p.spurious,
            p.retries,
            p.fallback_iters,
            p.escalations,
            p.degraded,
            p.verify.mean,
            p.total.mean,
            p.total.ci95,
            p.detect.mean,
            p.detect.ci95,
            p.event_recovery.mean,
            p.event_recovery.ci95,
            p.rollback.mean,
            p.rollback.ci95,
            p.ckpt_write.mean,
            p.ckpt_read.mean,
            p.app.mean,
            p.total.n,
        ));
    }
    std::fs::write(format!("{outdir}/integrity_compare.csv"), s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AppKind;

    fn quick_base() -> ExperimentConfig {
        let mut c = ExperimentConfig::default();
        c.app = AppKind::Hpccg;
        c.trials = 2;
        c.iters = 20;
        c.ranks_per_node = presets::CROSSOVER_RANKS_PER_NODE;
        c.fidelity = Fidelity::Modeled;
        c.hpccg_nx = 4;
        c.max_failures = presets::STORM_MAX_FAILURES;
        // paper-scale virtual iteration cost, same anchor as the storm sweep
        c.calib.modeled_compute_scale = presets::STORM_COMPUTE_SCALE;
        c
    }

    #[test]
    fn grid_shape() {
        let opts = SweepOpts {
            max_ranks: 256,
            outdir: "/tmp/reinitpp-test-results".into(),
            jobs: 1,
            profile: false,
        };
        let cfgs = build_grid(&quick_base(), &opts).unwrap();
        // 3 rungs x 5 families x 2 rates x 2 detectors x 2 keeps (8
        // ranks/node: even the 16-rank rung hosts node-disjoint shadows)
        assert_eq!(
            cfgs.len(),
            presets::STORM_SWEEP_RANKS.len()
                * FAMILIES.len()
                * presets::INTEGRITY_CORRUPT_RATES.len()
                * presets::INTEGRITY_DETECTORS.len()
                * presets::INTEGRITY_KEEP.len()
        );
        // every family appears, shrink with zero spares
        for &(rk, spares) in &FAMILIES {
            assert!(cfgs
                .iter()
                .any(|c| c.recovery == rk && c.spare_nodes == spares));
        }
        // the grid spans the perfect corner and the fully-imperfect corner
        assert!(cfgs.iter().any(|c| c.corrupt_rate == 0.0
            && c.detect_fp_rate == 0.0
            && c.ckpt_keep == 1));
        assert!(cfgs.iter().any(|c| c.corrupt_rate > 0.0
            && c.detect_fp_rate > 0.0
            && c.ckpt_keep > 1));
    }

    #[test]
    fn non_modeled_fidelity_is_rejected() {
        let mut base = quick_base();
        base.fidelity = Fidelity::Auto;
        let err = build_grid(&base, &SweepOpts::default()).unwrap_err();
        assert!(err.contains("modeled"), "{err}");
    }

    #[test]
    fn integrity_sweep_runs_and_is_jobs_deterministic() {
        // The smallest rung, serial vs 2 workers: identical Points and
        // therefore identical integrity_compare.csv bytes.
        let base = quick_base();
        let mk = |jobs, outdir: &str| SweepOpts {
            max_ranks: 16,
            outdir: outdir.into(),
            jobs,
            profile: false,
        };
        let serial = integrity_sweep(
            &base,
            &mk(1, "/tmp/reinitpp-test-results/integrity-j1"),
        )
        .unwrap();
        let par = integrity_sweep(
            &base,
            &mk(2, "/tmp/reinitpp-test-results/integrity-j2"),
        )
        .unwrap();
        assert_eq!(
            serial.len(),
            5 * 2 * 2 * 2,
            "16 ranks x 5 families x 2 rates x 2 detectors x 2 keeps"
        );
        for (a, b) in serial.iter().zip(&par) {
            assert_eq!(a.cfg.recovery, b.cfg.recovery);
            assert_eq!(a.cfg.corrupt_rate, b.cfg.corrupt_rate);
            assert_eq!(a.cfg.ckpt_keep, b.cfg.ckpt_keep);
            assert_eq!(a.total, b.total);
            assert_eq!(a.event_recovery, b.event_recovery);
            assert_eq!(a.verify, b.verify);
            assert_eq!(a.failures, b.failures);
            assert_eq!(a.spurious, b.spurious);
            assert_eq!(a.retries, b.retries);
            assert_eq!(a.fallback_iters, b.fallback_iters);
            assert_eq!(a.escalations, b.escalations);
        }
        let j1 = std::fs::read(
            "/tmp/reinitpp-test-results/integrity-j1/integrity_compare.csv",
        )
        .unwrap();
        let j2 = std::fs::read(
            "/tmp/reinitpp-test-results/integrity-j2/integrity_compare.csv",
        )
        .unwrap();
        assert!(!j1.is_empty());
        assert_eq!(j1, j2, "integrity CSV bytes must not depend on worker count");

        // The perfect corner books no imperfect-world costs at all…
        for p in &serial {
            if p.cfg.corrupt_rate == 0.0 && p.cfg.detect_fp_rate == 0.0 {
                assert_eq!(p.spurious, 0.0, "{}: perfect detector", p.cfg.recovery);
                assert_eq!(p.retries, 0.0);
                assert_eq!(p.fallback_iters, 0.0);
                assert_eq!(p.verify.mean, 0.0, "verify machinery must stay off");
            }
        }
        // …the noisy detector triggers real recoveries somewhere…
        assert!(
            serial
                .iter()
                .any(|p| p.cfg.detect_fp_rate > 0.0 && p.spurious > 0.0),
            "no false suspicion landed a spurious recovery"
        );
        // …and the corruption axis makes some rollback-family recovery
        // verify its generations (replication can dodge via its mirrors).
        assert!(
            serial.iter().any(|p| p.cfg.corrupt_rate > 0.0
                && p.cfg.recovery != RecoveryKind::Replication
                && p.verify.mean > 0.0),
            "no corrupted point ever verified a checkpoint"
        );
    }
}
