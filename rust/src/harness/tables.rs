//! The paper's Tables 1 and 2 as printable artifacts.

use crate::checkpoint::policy::default_scheme;
use crate::config::{presets, FailureKind, RecoveryKind};

/// Table 1: proxy applications and their configuration.
pub fn print_table1() {
    println!("\n## Table 1: proxy applications and their configuration\n");
    println!("| application | paper input | our per-rank analog | rank counts |");
    println!("|---|---|---|---|");
    for row in presets::table1() {
        let ranks: Vec<String> = row.ranks.iter().map(|r| r.to_string()).collect();
        println!(
            "| {} | `{}` | {} | {} |",
            row.app,
            row.paper_input,
            row.our_input,
            ranks.join(", ")
        );
    }
    println!("\n(16 ranks per node, weak scaling — paper §4.)");
}

/// Table 2: checkpointing scheme per recovery approach and failure type.
pub fn print_table2() {
    println!("\n## Table 2: checkpointing per recovery and failure\n");
    println!("| failure | CR | ULFM | Reinit++ |");
    println!("|---|---|---|---|");
    for failure in [FailureKind::Process, FailureKind::Node] {
        let row: Vec<String> = [RecoveryKind::Cr, RecoveryKind::Ulfm, RecoveryKind::Reinit]
            .iter()
            .map(|&rk| default_scheme(rk, failure).to_string())
            .collect();
        println!("| {} | {} | {} | {} |", failure, row[0], row[1], row[2]);
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn tables_print_without_panic() {
        super::print_table1();
        super::print_table2();
    }
}
