//! Failure-storm sweep (`reinitpp storm`): MTBF × recovery × ranks.
//!
//! The paper evaluates every recovery method under exactly one failure per
//! run; ReStore (arXiv 2203.01107) argues repeated failures — including
//! failures landing *inside* a prior recovery — are where recovery schemes
//! actually differentiate, and Shrink-or-Substitute (arXiv 1810.00705)
//! treats spare-pool exhaustion as a first-class scenario. This sweep runs
//! an exponential MTBF arrival process (`fault::FaultTimeline`) over
//! virtual time against every recovery family (`RecoveryKind::ALL`):
//! per point it reports how many failures actually landed, the per-event
//! detect / recovery / rollback / failover sums, and how often in-place
//! recovery degraded to a CR-style re-deploy. Replication runs at
//! node-disjoint degree `presets::STORM_REPL_DEGREE`; rungs with a single
//! compute node cannot place a node-disjoint shadow and skip it.
//!
//! Expected shape: at the generous end of the MTBF grid most trials see at
//! most one failure; as MTBF tightens below the recovery-cost anchors
//! (Reinit++ ≈0.5 s, CR ≈3 s re-deploy) each failure's recovery window
//! attracts the next failure — CR's total time compounds (every event costs
//! a full re-deploy, and arrivals land during the relaunch itself) while
//! Reinit++ absorbs the same storm with per-event in-place recoveries.
//! MTBF is measured on the application clock (arrivals start at the end of
//! the first mpirun launch), matching the paper's timing convention.
//!
//! Like every harness sweep, the grid is flattened to (point, trial) work
//! items for the pool and merged deterministically, so `storm_compare.csv`
//! is byte-identical for any `--jobs` value (pinned by the unit test below
//! and a serial-vs-2-worker `cmp` in CI).

use super::figures::{cell, SweepOpts};
use super::{run_points, Point};
use crate::config::{presets, ExperimentConfig, FailureKind, Fidelity, RecoveryKind};

/// Rank counts the storm sweep visits (capped by `--max-ranks`).
fn sweep_ranks(max: u32) -> Vec<u32> {
    presets::STORM_SWEEP_RANKS
        .iter()
        .copied()
        .filter(|&r| r <= max)
        .collect()
}

/// Build the sweep grid: MTBF × recovery × ranks, process-failure storms,
/// modeled fidelity (storm trials re-execute many iterations).
fn build_grid(
    base: &ExperimentConfig,
    opts: &SweepOpts,
) -> Result<Vec<ExperimentConfig>, String> {
    if base.fidelity != Fidelity::Modeled {
        return Err(
            "storm: the sweep runs fidelity=modeled (storms re-execute many \
             iterations); drop fidelity="
                .to_string(),
        );
    }
    let mut cfgs = Vec::new();
    for &ranks in &sweep_ranks(opts.max_ranks) {
        for rk in RecoveryKind::ALL {
            for &mtbf in &presets::STORM_SWEEP_MTBF_S {
                let mut c = base.clone();
                c.ranks = ranks;
                c.recovery = rk;
                c.failure = FailureKind::Process;
                c.mtbf_s = mtbf;
                c.ckpt = None; // Table 2 policy per method
                if rk == RecoveryKind::Replication {
                    c.repl_degree = presets::STORM_REPL_DEGREE;
                    if c.nodes() < c.repl_degree {
                        continue; // no node-disjoint shadow placement on this rung
                    }
                }
                c.validate().map_err(|e| {
                    format!("storm sweep point ranks={ranks} recovery={rk} mtbf={mtbf}: {e}")
                })?;
                cfgs.push(c);
            }
        }
    }
    if cfgs.is_empty() {
        return Err(format!(
            "storm sweep: no rank count of {:?} fits --max-ranks {}",
            presets::STORM_SWEEP_RANKS,
            opts.max_ranks
        ));
    }
    Ok(cfgs)
}

/// Run the failure-storm sweep: markdown table on stdout, CSV under
/// `outdir/storm_compare.csv`.
pub fn storm_sweep(base: &ExperimentConfig, opts: &SweepOpts) -> Result<Vec<Point>, String> {
    let cfgs = build_grid(base, opts)?;
    let trials: u32 = cfgs.iter().map(|c| c.trials).sum();
    crate::info!(
        "  storm sweep: {} points / {trials} trials (MTBF {:?} s, <= {} failures/trial) on {} worker(s)...",
        cfgs.len(),
        presets::STORM_SWEEP_MTBF_S,
        base.max_failures,
        opts.jobs
    );
    let (points, stats) = run_points(&cfgs, opts.jobs);
    super::figures::finish_sweep("storm_compare", opts, &points, &stats);

    println!(
        "\n## Failure storms ({}): MTBF arrival process, per-event recovery\n",
        base.app
    );
    println!(
        "| ranks | recovery | mtbf (s) | failures | total (s) | detect (s) | \
         recovery (s) | rollback (s) | failover (s) | mirror (s) | degraded |"
    );
    println!("|---|---|---|---|---|---|---|---|---|---|---|");
    for p in &points {
        println!(
            "| {} | {} | {} | {:.1} | {} | {} | {} | {} | {} | {:.3} | {:.1} |",
            p.cfg.ranks,
            p.cfg.recovery,
            p.cfg.mtbf_s,
            p.failures,
            cell(&p.total),
            cell(&p.detect),
            cell(&p.event_recovery),
            cell(&p.rollback),
            cell(&p.failover),
            p.mirror_s,
            p.degraded,
        );
    }
    println!("\n(expected shape: tighter MTBF -> more fired failures; CR pays a full");
    println!(" re-deploy per event while Reinit++ recovers in place each time —");
    println!(" see EXPERIMENTS.md §Failure storms)");

    // The generic figure CSV shape is not used here: storm points need the
    // per-event decomposition columns, not the single-failure breakdown.
    if let Err(e) = write_storm_csv(&opts.outdir, &points) {
        crate::warnln!("could not write storm_compare.csv: {e}");
    }
    Ok(points)
}

/// `storm_compare.csv`: one row per (ranks, recovery, mtbf) point, with the
/// per-event decomposition columns.
fn write_storm_csv(outdir: &str, points: &[Point]) -> std::io::Result<()> {
    std::fs::create_dir_all(outdir)?;
    let mut s = String::from(
        "app,ranks,recovery,repl_degree,mtbf_s,max_failures,failures,failovers,degraded,\
         total_s,total_ci,detect_s,detect_ci,recovery_s,recovery_ci,\
         rollback_s,rollback_ci,failover_s,failover_ci,\
         ckpt_write_s,ckpt_read_s,mirror_s,mirror_mb,app_s,trials\n",
    );
    for p in points {
        s.push_str(&format!(
            "{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{}\n",
            p.cfg.app,
            p.cfg.ranks,
            p.cfg.recovery,
            p.cfg.repl_degree,
            p.cfg.mtbf_s,
            p.cfg.max_failures,
            p.failures,
            p.failovers,
            p.degraded,
            p.total.mean,
            p.total.ci95,
            p.detect.mean,
            p.detect.ci95,
            p.event_recovery.mean,
            p.event_recovery.ci95,
            p.rollback.mean,
            p.rollback.ci95,
            p.failover.mean,
            p.failover.ci95,
            p.ckpt_write.mean,
            p.ckpt_read.mean,
            p.mirror_s,
            p.mirror_mb,
            p.app.mean,
            p.total.n,
        ));
    }
    std::fs::write(format!("{outdir}/storm_compare.csv"), s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AppKind;

    fn quick_base() -> ExperimentConfig {
        let mut c = ExperimentConfig::default();
        c.app = AppKind::Hpccg;
        c.trials = 2;
        c.iters = 20;
        c.fidelity = Fidelity::Modeled;
        c.hpccg_nx = 4;
        c.max_failures = presets::STORM_MAX_FAILURES;
        // paper-scale virtual iteration cost (see presets::STORM_COMPUTE_SCALE):
        // without it the app clock is microseconds and no MTBF arrival lands
        c.calib.modeled_compute_scale = presets::STORM_COMPUTE_SCALE;
        c
    }

    #[test]
    fn grid_shape() {
        let opts = SweepOpts {
            max_ranks: 256,
            outdir: "/tmp/reinitpp-test-results".into(),
            jobs: 1,
            profile: false,
        };
        let cfgs = build_grid(&quick_base(), &opts).unwrap();
        // 16 ranks = 1 node at the paper's 16 ranks/node: replication has
        // no node-disjoint shadow target and is skipped on that rung, so
        // 4 recoveries x 3 MTBFs + 2 rungs x 5 recoveries x 3 MTBFs.
        assert_eq!(cfgs.len(), 12 + 2 * 5 * 3);
        assert!(cfgs
            .iter()
            .all(|c| c.failure == FailureKind::Process && c.mtbf_s > 0.0));
        assert!(!cfgs
            .iter()
            .any(|c| c.recovery == RecoveryKind::Replication && c.ranks == 16));
        assert!(cfgs
            .iter()
            .filter(|c| c.recovery == RecoveryKind::Replication)
            .all(|c| c.repl_degree == presets::STORM_REPL_DEGREE));
    }

    #[test]
    fn non_modeled_fidelity_is_rejected() {
        let mut base = quick_base();
        base.fidelity = Fidelity::Auto;
        let err = build_grid(&base, &SweepOpts::default()).unwrap_err();
        assert!(err.contains("modeled"), "{err}");
    }

    #[test]
    fn storm_sweep_runs_and_is_jobs_deterministic() {
        // The smallest rung, serial vs 2 workers: identical Points and
        // therefore identical storm_compare.csv bytes.
        let base = quick_base();
        let mk = |jobs, outdir: &str| SweepOpts {
            max_ranks: 16,
            outdir: outdir.into(),
            jobs,
            profile: false,
        };
        let serial =
            storm_sweep(&base, &mk(1, "/tmp/reinitpp-test-results/storm-j1")).unwrap();
        let par = storm_sweep(&base, &mk(2, "/tmp/reinitpp-test-results/storm-j2")).unwrap();
        assert_eq!(
            serial.len(),
            12,
            "16 ranks x 4 recoveries x 3 MTBFs (replication needs >= 2 nodes)"
        );
        for (a, b) in serial.iter().zip(&par) {
            assert_eq!(a.cfg.recovery, b.cfg.recovery);
            assert_eq!(a.total, b.total);
            assert_eq!(a.detect, b.detect);
            assert_eq!(a.event_recovery, b.event_recovery);
            assert_eq!(a.rollback, b.rollback);
            assert_eq!(a.failures, b.failures);
        }
        let j1 = std::fs::read("/tmp/reinitpp-test-results/storm-j1/storm_compare.csv")
            .unwrap();
        let j2 = std::fs::read("/tmp/reinitpp-test-results/storm-j2/storm_compare.csv")
            .unwrap();
        assert!(!j1.is_empty());
        assert_eq!(j1, j2, "storm CSV bytes must not depend on worker count");
        // storm shape: the tightest MTBF fires at least as many failures as
        // the loosest, for the same recovery
        let fired = |rk: RecoveryKind, mtbf: f64| {
            serial
                .iter()
                .find(|p| p.cfg.recovery == rk && p.cfg.mtbf_s == mtbf)
                .unwrap()
                .failures
        };
        let tight = presets::STORM_SWEEP_MTBF_S[0];
        let loose = *presets::STORM_SWEEP_MTBF_S.last().unwrap();
        assert!(
            fired(RecoveryKind::Reinit, tight) >= fired(RecoveryKind::Reinit, loose),
            "tighter MTBF cannot fire fewer failures: {} vs {}",
            fired(RecoveryKind::Reinit, tight),
            fired(RecoveryKind::Reinit, loose)
        );
        // at least one storm point actually fired something
        assert!(serial.iter().any(|p| p.failures > 0.0));
    }
}
