//! Per-figure sweep drivers (paper §5, Figures 4-7) and table/CSV emitters.
//!
//! Every driver builds its full point grid up front and hands the flattened
//! (point, trial) work list to the parallel sweep scheduler
//! (`harness::pool`); output order — and therefore every table and CSV
//! byte — is independent of `jobs`.

use super::{default_jobs, run_points, Point};
use crate::config::{
    presets, AppKind, CkptKind, ExperimentConfig, FailureKind, RecoveryKind,
};

/// Options common to all figure drivers.
#[derive(Clone, Debug)]
pub struct SweepOpts {
    /// Cap on rank counts (quick runs / CI).
    pub max_ranks: u32,
    /// Output directory for CSVs (created if missing).
    pub outdir: String,
    /// Worker threads for trial execution (1 = serial; default all cores).
    pub jobs: usize,
    /// Also write per-trial executor counters as `<name>_profiles.json`
    /// next to each sweep CSV (`--profile-json`).
    pub profile: bool,
    /// Executor shards per trial (`--shards`; 1 = serial event loop). A
    /// host-side knob like `jobs`: it must never change simulation output,
    /// so it is carried here rather than in `ExperimentConfig`.
    pub shards: usize,
}

impl Default for SweepOpts {
    fn default() -> Self {
        SweepOpts {
            max_ranks: 1024,
            outdir: "results".to_string(),
            jobs: default_jobs(),
            profile: false,
            shards: 1,
        }
    }
}

fn sweep_ranks(app: AppKind, max: u32) -> Vec<u32> {
    presets::rank_sweep(app)
        .iter()
        .copied()
        .filter(|&r| r <= max)
        .collect()
}

fn point_cfg(
    base: &ExperimentConfig,
    app: AppKind,
    ranks: u32,
    recovery: RecoveryKind,
    failure: FailureKind,
) -> ExperimentConfig {
    let mut c = base.clone();
    c.app = app;
    c.ranks = ranks;
    c.recovery = recovery;
    c.failure = failure;
    c.ckpt = None; // Table 2 policy
    c
}

/// Render one summary as `mean±ci` (shared by the figure and tier tables).
pub(crate) fn cell(s: &crate::metrics::Summary) -> String {
    if s.ci95 > 0.0005 {
        format!("{:.3}±{:.3}", s.mean, s.ci95)
    } else {
        format!("{:.3}", s.mean)
    }
}

/// Print a figure's points as a markdown table.
pub fn print_points(title: &str, points: &[Point]) {
    println!("\n## {title}\n");
    println!(
        "| app | ranks | recovery | ckpt | total (s) | ckpt write (s) | ckpt read (s) | MPI recovery (s) | app (s) |"
    );
    println!("|---|---|---|---|---|---|---|---|---|");
    for p in points {
        println!(
            "| {} | {} | {} | {} | {} | {} | {} | {} | {} |",
            p.cfg.app,
            p.cfg.ranks,
            p.cfg.recovery,
            p.cfg.effective_stack(),
            cell(&p.total),
            cell(&p.ckpt_write),
            cell(&p.ckpt_read),
            cell(&p.recovery),
            cell(&p.app),
        );
    }
}

/// The storage-pressure column block shared by every harness CSV (mean
/// per-trial MB; `fs::DiskStats` plus the per-tier byte counters).
pub(crate) const STORAGE_CSV_HEADER: &str = "disk_write_mb,disk_read_mb,disk_ops,\
     local_write_mb,partner_write_mb,fs_write_mb,local_read_mb,partner_read_mb,\
     fs_read_mb,rebuild_mb,drained_mb";

pub(crate) fn storage_csv_cells(m: &crate::metrics::StorageMeans) -> String {
    format!(
        "{:.3},{:.3},{:.1},{:.3},{:.3},{:.3},{:.3},{:.3},{:.3},{:.3},{:.3}",
        m.disk_write_mb,
        m.disk_read_mb,
        m.disk_ops,
        m.local_write_mb,
        m.partner_write_mb,
        m.fs_write_mb,
        m.local_read_mb,
        m.partner_read_mb,
        m.fs_read_mb,
        m.rebuild_mb,
        m.drained_mb,
    )
}

/// Write the points to `outdir/<name>.csv`.
pub fn write_csv(name: &str, outdir: &str, points: &[Point]) -> std::io::Result<()> {
    std::fs::create_dir_all(outdir)?;
    let mut s = format!(
        "app,ranks,recovery,failure,ckpt,total_s,total_ci,ckpt_write_s,ckpt_write_ci,\
         ckpt_read_s,ckpt_read_ci,mpi_recovery_s,mpi_recovery_ci,app_s,app_ci,\
         {STORAGE_CSV_HEADER},trials\n",
    );
    for p in points {
        s.push_str(&format!(
            "{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{}\n",
            p.cfg.app,
            p.cfg.ranks,
            p.cfg.recovery,
            p.cfg.failure,
            p.cfg.effective_stack(),
            p.total.mean,
            p.total.ci95,
            p.ckpt_write.mean,
            p.ckpt_write.ci95,
            p.ckpt_read.mean,
            p.ckpt_read.ci95,
            p.recovery.mean,
            p.recovery.ci95,
            p.app.mean,
            p.app.ci95,
            storage_csv_cells(&p.storage),
            p.total.n,
        ));
    }
    std::fs::write(format!("{outdir}/{name}.csv"), s)
}

/// Emit one finished sweep's host-side throughput stats as
/// `BENCH_sweep_stats_<name>.json` (same naming family as the micro-bench
/// emitters) and — under `--profile-json` — the per-trial executor counters
/// as `<name>_profiles.json`, both next to the sweep's CSV. Also prints the
/// "sweep done" heartbeat. Best-effort: a failed write warns, never aborts
/// a sweep whose trials already ran.
pub(crate) fn finish_sweep(
    name: &str,
    opts: &SweepOpts,
    points: &[Point],
    stats: &crate::metrics::SweepStats,
) {
    crate::info!(
        "  sweep done: {:.2} s wall, {:.1} trials/s, {:.0}% worker utilization",
        stats.wall_s,
        stats.trials_per_sec(),
        stats.utilization() * 100.0
    );
    if let Err(e) = write_sweep_stats(name, &opts.outdir, stats) {
        crate::warnln!("could not write BENCH_sweep_stats_{name}.json: {e}");
    }
    if opts.profile {
        if let Err(e) = write_profiles(name, &opts.outdir, points) {
            crate::warnln!("could not write {name}_profiles.json: {e}");
        }
    }
}

/// `BENCH_sweep_stats_<name>.json`: jobs/trials/wall/busy plus the derived
/// throughput and utilization, for trend tracking next to the CSVs.
fn write_sweep_stats(
    name: &str,
    outdir: &str,
    stats: &crate::metrics::SweepStats,
) -> std::io::Result<()> {
    use crate::metrics::bench::{json_num, json_str};
    std::fs::create_dir_all(outdir)?;
    let mut s = String::from("{\n  \"schema\": 1,\n");
    s.push_str(&format!("  \"sweep\": {},\n", json_str(name)));
    s.push_str(&format!("  \"jobs\": {},\n", stats.jobs));
    s.push_str(&format!("  \"trials\": {},\n", stats.trials));
    s.push_str(&format!("  \"wall_s\": {},\n", json_num(stats.wall_s)));
    s.push_str(&format!("  \"busy_s\": {},\n", json_num(stats.busy_s)));
    s.push_str(&format!(
        "  \"trials_per_sec\": {},\n",
        json_num(stats.trials_per_sec())
    ));
    s.push_str(&format!(
        "  \"utilization\": {}\n",
        json_num(stats.utilization())
    ));
    s.push_str("}\n");
    std::fs::write(format!("{outdir}/BENCH_sweep_stats_{name}.json"), s)
}

/// `<name>_profiles.json`: one row per (point, trial) with the trial's
/// identity hash and executor counters (`--profile-json`).
fn write_profiles(name: &str, outdir: &str, points: &[Point]) -> std::io::Result<()> {
    use crate::metrics::bench::{json_num, json_str};
    std::fs::create_dir_all(outdir)?;
    let mut s = String::from("{\n  \"schema\": 1,\n");
    s.push_str(&format!("  \"sweep\": {},\n", json_str(name)));
    s.push_str("  \"trials\": [\n");
    let mut first = true;
    for p in points {
        for (trial, c) in p.profiles.iter().enumerate() {
            if !first {
                s.push_str(",\n");
            }
            first = false;
            s.push_str(&format!(
                "    {{\"app\": {}, \"ranks\": {}, \"recovery\": {}, \"failure\": {}, \
                 \"trial\": {trial}, \"identity\": \"{:016x}\", \"end_s\": {}, \
                 \"events\": {}, \"polls\": {}, \"peak_events_pending\": {}, \
                 \"peak_rank_state_bytes\": {}, \"tasks_completed\": {}}}",
                json_str(&p.cfg.app.to_string()),
                p.cfg.ranks,
                json_str(&p.cfg.recovery.to_string()),
                json_str(&p.cfg.failure.to_string()),
                c.identity,
                json_num(c.end_s),
                c.events,
                c.polls,
                c.peak_events_pending,
                c.peak_rank_state_bytes,
                c.tasks_completed,
            ));
        }
    }
    s.push_str("\n  ]\n}\n");
    std::fs::write(format!("{outdir}/{name}_profiles.json"), s)
}

fn run_sweep(
    name: &str,
    base: &ExperimentConfig,
    opts: &SweepOpts,
    apps: &[AppKind],
    recoveries: &[RecoveryKind],
    failure: FailureKind,
) -> Vec<Point> {
    let mut cfgs = Vec::new();
    for &app in apps {
        for &ranks in &sweep_ranks(app, opts.max_ranks) {
            for &rk in recoveries {
                cfgs.push(point_cfg(base, app, ranks, rk, failure));
            }
        }
    }
    let trials: u32 = cfgs.iter().map(|c| c.trials).sum();
    crate::info!(
        "  sweep: {} points / {trials} trials ({failure} failure) on {} worker(s)...",
        cfgs.len(),
        opts.jobs
    );
    let (points, stats) = run_points(&cfgs, opts.jobs);
    finish_sweep(name, opts, &points, &stats);
    points
}

/// Fig. 4: total execution time breakdown under a process failure
/// (CR uses file checkpoints; ULFM/Reinit++ memory — Table 2). The figure
/// sweeps reproduce the paper's evaluation, so they run exactly its three
/// recovery methods (`RecoveryKind::PAPER`) — the replication family has
/// its own crossover sweep and must not perturb the figure CSV bytes.
pub fn fig4(base: &ExperimentConfig, opts: &SweepOpts) -> Vec<Point> {
    let points = run_sweep(
        "fig4_total_time",
        base,
        opts,
        &AppKind::ALL,
        &RecoveryKind::PAPER,
        FailureKind::Process,
    );
    print_points(
        "Figure 4: total execution time breakdown, single process failure",
        &points,
    );
    let _ = write_csv("fig4_total_time", &opts.outdir, &points);
    points
}

/// Fig. 5: pure application time weak scaling (fault-free runs; shows the
/// ULFM inflation).
pub fn fig5(base: &ExperimentConfig, opts: &SweepOpts) -> Vec<Point> {
    let points = run_sweep(
        "fig5_app_time",
        base,
        opts,
        &AppKind::ALL,
        &RecoveryKind::PAPER,
        FailureKind::None,
    );
    print_points(
        "Figure 5: pure application time scaling (fault-free)",
        &points,
    );
    let _ = write_csv("fig5_app_time", &opts.outdir, &points);
    points
}

/// Fig. 6: MPI recovery time under a process failure.
pub fn fig6(base: &ExperimentConfig, opts: &SweepOpts) -> Vec<Point> {
    let points = run_sweep(
        "fig6_process_recovery",
        base,
        opts,
        &AppKind::ALL,
        &RecoveryKind::PAPER,
        FailureKind::Process,
    );
    print_points(
        "Figure 6: MPI recovery time, single process failure",
        &points,
    );
    let _ = write_csv("fig6_process_recovery", &opts.outdir, &points);
    points
}

/// Fig. 7: MPI recovery time under a node failure. As in the paper, only
/// CR and Reinit++ (the ULFM prototype could not run node failures; ours
/// can, but we reproduce the paper's comparison).
pub fn fig7(base: &ExperimentConfig, opts: &SweepOpts) -> Vec<Point> {
    let mut b = base.clone();
    b.spare_nodes = b.spare_nodes.max(1);
    b.ckpt = Some(CkptKind::File);
    let points = run_sweep(
        "fig7_node_recovery",
        &b,
        opts,
        &AppKind::ALL,
        &[RecoveryKind::Cr, RecoveryKind::Reinit],
        FailureKind::Node,
    );
    print_points("Figure 7: MPI recovery time, single node failure", &points);
    let _ = write_csv("fig7_node_recovery", &opts.outdir, &points);
    points
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Fidelity;

    fn quick_base() -> ExperimentConfig {
        let mut c = ExperimentConfig::default();
        c.trials = 2;
        c.iters = 5;
        c.fidelity = Fidelity::Modeled;
        c.hpccg_nx = 4;
        c.comd_n = 32;
        c.lulesh_nx = 4;
        c
    }

    #[test]
    fn fig6_quick_sweep_shapes() {
        let base = quick_base();
        let opts = SweepOpts {
            max_ranks: 32,
            outdir: "/tmp/reinitpp-test-results".into(),
            jobs: 2,
            profile: false,
            shards: 1,
        };
        let pts = run_sweep(
            "unit_fig6_quick",
            &base,
            &opts,
            &[AppKind::Hpccg],
            &RecoveryKind::PAPER,
            FailureKind::Process,
        );
        assert_eq!(pts.len(), 2 * 3); // ranks {16,32} x 3 paper recoveries
        let get = |ranks: u32, rk: RecoveryKind| {
            pts.iter()
                .find(|p| p.cfg.ranks == ranks && p.cfg.recovery == rk)
                .unwrap()
                .recovery
                .mean
        };
        // paper shape at small scale: CR slowest, Reinit fastest-ish
        assert!(get(16, RecoveryKind::Cr) > 2.0 * get(16, RecoveryKind::Reinit));
        assert!(get(32, RecoveryKind::Cr) > 2.0 * get(32, RecoveryKind::Reinit));
    }

    #[test]
    fn csv_written() {
        let base = quick_base();
        let opts = SweepOpts {
            max_ranks: 16,
            outdir: "/tmp/reinitpp-test-results".into(),
            jobs: 1,
            profile: true,
            shards: 1,
        };
        let pts = run_sweep(
            "unit_test",
            &base,
            &opts,
            &[AppKind::Hpccg],
            &[RecoveryKind::Reinit],
            FailureKind::Process,
        );
        write_csv("unit_test", &opts.outdir, &pts).unwrap();
        let text =
            std::fs::read_to_string("/tmp/reinitpp-test-results/unit_test.csv").unwrap();
        assert!(text.starts_with("app,ranks,"));
        assert_eq!(text.lines().count(), 2);
        // finish_sweep side-car artifacts: stats always, profiles on demand
        let stats = std::fs::read_to_string(
            "/tmp/reinitpp-test-results/BENCH_sweep_stats_unit_test.json",
        )
        .unwrap();
        assert!(stats.contains("\"sweep\": \"unit_test\""));
        assert!(stats.contains("\"trials\": 2"));
        let profiles = std::fs::read_to_string(
            "/tmp/reinitpp-test-results/unit_test_profiles.json",
        )
        .unwrap();
        assert!(profiles.contains("\"identity\""));
        assert!(profiles.contains("\"events\""));
        assert_eq!(profiles.matches("\"trial\":").count(), 2);
    }
}
