//! Tier-comparison sweep: the scenario axis the multi-tier checkpoint
//! store opens up (ReStore, arXiv 2203.01107; FTHP-MPI, arXiv 2504.09989).
//!
//! For each rank count the driver runs the canonical stacks
//!
//! - `fs`                  — the paper's shared-filesystem baseline
//! - `local+partner1`      — in-memory with one node-disjoint replica
//! - `local+partner2+fs`   — two replicas backed by the filesystem
//!
//! under both a process and a node failure, and reports recovery/read/write
//! time plus the per-tier storage traffic. Like every harness sweep, the
//! grid is flattened to (point, trial) work items for the pool and merged
//! deterministically, so the CSV is byte-identical for any `--jobs` value.

use super::figures::{cell, storage_csv_cells, SweepOpts, STORAGE_CSV_HEADER};
use super::{run_points, Point};
use crate::config::{presets, ExperimentConfig, FailureKind};

/// Rank counts the tier sweep visits (capped by `--max-ranks`).
fn sweep_ranks(max: u32) -> Vec<u32> {
    presets::TIER_SWEEP_RANKS
        .iter()
        .copied()
        .filter(|&r| r <= max)
        .collect()
}

/// Build the sweep grid. Fails (with a clear message) when an override
/// makes a point invalid — e.g. forcing a single-node topology, where no
/// memory-only stack can survive a node failure.
fn build_grid(
    base: &ExperimentConfig,
    opts: &SweepOpts,
) -> Result<Vec<ExperimentConfig>, String> {
    let mut cfgs = Vec::new();
    for &ranks in &sweep_ranks(opts.max_ranks) {
        for failure in [FailureKind::Process, FailureKind::Node] {
            for stack in presets::tier_sweep_stacks() {
                let mut c = base.clone();
                c.ranks = ranks;
                c.failure = failure;
                c.ckpt = None;
                c.ckpt_tiers = Some(stack);
                if failure == FailureKind::Node {
                    c.spare_nodes = c.spare_nodes.max(1);
                }
                c.validate().map_err(|e| {
                    format!(
                        "tier sweep point ranks={} failure={} stack={}: {e}",
                        c.ranks,
                        c.failure,
                        c.effective_stack()
                    )
                })?;
                cfgs.push(c);
            }
        }
    }
    if cfgs.is_empty() {
        return Err(format!(
            "tier sweep: no rank count of {:?} fits --max-ranks {}",
            presets::TIER_SWEEP_RANKS,
            opts.max_ranks
        ));
    }
    Ok(cfgs)
}

/// Run the tier-comparison sweep: markdown table on stdout, CSV under
/// `outdir/tier_compare.csv`.
pub fn tier_sweep(base: &ExperimentConfig, opts: &SweepOpts) -> Result<Vec<Point>, String> {
    let cfgs = build_grid(base, opts)?;
    let trials: u32 = cfgs.iter().map(|c| c.trials).sum();
    crate::info!(
        "  tier sweep: {} points / {trials} trials on {} worker(s)...",
        cfgs.len(),
        opts.jobs
    );
    let (points, stats) = run_points(&cfgs, opts.jobs);
    super::figures::finish_sweep("tier_compare", opts, &points, &stats);

    println!("\n## Checkpoint tier comparison ({})\n", base.app);
    println!(
        "| stack | failure | ranks | total (s) | ckpt write (s) | ckpt read (s) | \
         MPI recovery (s) | disk wr (MB) | rebuild (MB) |"
    );
    println!("|---|---|---|---|---|---|---|---|---|");
    for p in &points {
        println!(
            "| {} | {} | {} | {} | {} | {} | {} | {:.3} | {:.3} |",
            p.cfg.effective_stack(),
            p.cfg.failure,
            p.cfg.ranks,
            cell(&p.total),
            cell(&p.ckpt_write),
            cell(&p.ckpt_read),
            cell(&p.recovery),
            p.storage.disk_write_mb,
            p.storage.rebuild_mb,
        );
    }
    println!("\n(expected shape: fs-only recovery reads pay the contended disk;");
    println!(" partner tiers recover from memory and survive node failures when");
    println!(" replicas are node-disjoint — see EXPERIMENTS.md §Checkpoint tiers)");

    if let Err(e) = write_tier_csv(&opts.outdir, &points) {
        crate::warnln!("could not write tier_compare.csv: {e}");
    }
    Ok(points)
}

/// `tier_compare.csv`: one row per (stack, failure, ranks) point.
fn write_tier_csv(outdir: &str, points: &[Point]) -> std::io::Result<()> {
    std::fs::create_dir_all(outdir)?;
    let mut s = format!(
        "app,ranks,recovery,failure,stack,drain_s,total_s,total_ci,\
         ckpt_write_s,ckpt_write_ci,ckpt_read_s,ckpt_read_ci,\
         mpi_recovery_s,mpi_recovery_ci,app_s,app_ci,{STORAGE_CSV_HEADER},trials\n"
    );
    for p in points {
        s.push_str(&format!(
            "{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{}\n",
            p.cfg.app,
            p.cfg.ranks,
            p.cfg.recovery,
            p.cfg.failure,
            p.cfg.effective_stack(),
            p.cfg.ckpt_drain_interval_s,
            p.total.mean,
            p.total.ci95,
            p.ckpt_write.mean,
            p.ckpt_write.ci95,
            p.ckpt_read.mean,
            p.ckpt_read.ci95,
            p.recovery.mean,
            p.recovery.ci95,
            p.app.mean,
            p.app.ci95,
            storage_csv_cells(&p.storage),
            p.total.n,
        ));
    }
    std::fs::write(format!("{outdir}/tier_compare.csv"), s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AppKind, Fidelity, RecoveryKind};

    fn quick_base() -> ExperimentConfig {
        let mut c = ExperimentConfig::default();
        c.app = AppKind::Hpccg;
        c.recovery = RecoveryKind::Reinit;
        c.ranks_per_node = presets::TIER_SWEEP_RANKS_PER_NODE;
        c.trials = 2;
        c.iters = 6;
        c.fidelity = Fidelity::Modeled;
        c.hpccg_nx = 4;
        c
    }

    #[test]
    fn grid_covers_stacks_times_failures() {
        let opts = SweepOpts {
            max_ranks: 16,
            outdir: "/tmp/reinitpp-test-results".into(),
            jobs: 1,
            profile: false,
        };
        let cfgs = build_grid(&quick_base(), &opts).unwrap();
        assert_eq!(cfgs.len(), 6, "3 stacks x 2 failures at one rank count");
        for c in &cfgs {
            c.validate().unwrap();
        }
    }

    #[test]
    fn single_node_base_is_rejected_with_context() {
        let mut base = quick_base();
        base.ranks_per_node = 16; // 16 ranks -> 1 compute node
        let opts = SweepOpts {
            max_ranks: 16,
            outdir: "/tmp/reinitpp-test-results".into(),
            jobs: 1,
            profile: false,
        };
        let err = build_grid(&base, &opts).unwrap_err();
        assert!(err.contains("node failure"), "{err}");
    }

    #[test]
    fn tier_sweep_runs_and_orders_recovery_costs() {
        let base = quick_base();
        let opts = SweepOpts {
            max_ranks: 16,
            outdir: "/tmp/reinitpp-test-results/tiers".into(),
            jobs: 2,
            profile: false,
        };
        let pts = tier_sweep(&base, &opts).unwrap();
        assert_eq!(pts.len(), 6);
        let read_of = |stack: &str, failure: FailureKind| {
            pts.iter()
                .find(|p| {
                    p.cfg.effective_stack().to_string() == stack && p.cfg.failure == failure
                })
                .unwrap()
                .ckpt_read
                .mean
        };
        // under a process failure, recovering from memory tiers must beat
        // re-reading everything from the contended shared filesystem
        assert!(
            read_of("fs", FailureKind::Process)
                > read_of("local+partner1", FailureKind::Process),
            "fs read {} vs partner read {}",
            read_of("fs", FailureKind::Process),
            read_of("local+partner1", FailureKind::Process)
        );
        // the CSV exists and has the full grid
        let text = std::fs::read_to_string("/tmp/reinitpp-test-results/tiers/tier_compare.csv")
            .unwrap();
        assert!(text.starts_with("app,ranks,recovery,failure,stack,drain_s,"));
        assert_eq!(text.lines().count(), 7, "header + 6 points");
        assert!(text.contains("local+partner2+fs"));
    }
}
