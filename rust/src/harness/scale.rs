//! Large-rank weak-scaling sweep (`reinitpp scale`): extends the paper's
//! Figure 4 recovery-time curves past its 3072-rank ceiling.
//!
//! The paper's headline claim is that Reinit++ "scales excellently as the
//! number of MPI processes grows", but its evaluation stops at 3072 ranks.
//! ReStore (arXiv 2203.01107) and PartRePer-MPI (arXiv 2310.16370) both
//! argue recovery-time results only become interesting at thousands of
//! processes. With the O(1) fabric routing table, indexed receive matching
//! and allocation-lean collectives, a simulated iteration is cheap enough
//! in host time that the sweep runs the modeled-fidelity grid at
//! 512 ranks up to `--max-ranks` — the preset ladder to 16384, then
//! doubling rungs to the requested cap (`presets::scale_rungs`; a
//! 262144-rank rung is practical on the sharded executor) — under a
//! single process failure for every recovery method (ULFM capped at `presets::SCALE_ULFM_MAX_RANKS` — the survivor
//! sets of shrink/agree are quadratic host memory at extreme scale, and
//! the paper's own ULFM prototype stopped at 3072). Replication runs at
//! node-disjoint degree `presets::SCALE_REPL_DEGREE` on every rung: at
//! 512+ ranks each point spans dozens of nodes, so placement always fits.
//!
//! Like every harness sweep, the grid is flattened to (point, trial) work
//! items for the pool and merged deterministically, so
//! `scale_compare.csv` is byte-identical for any `--jobs` value (pinned by
//! the unit test below and a serial-vs-2-worker `cmp` in CI).

use super::figures::{cell, storage_csv_cells, SweepOpts, STORAGE_CSV_HEADER};
use super::{run_points, Point};
use crate::config::{presets, ExperimentConfig, FailureKind, Fidelity, RecoveryKind};

/// Mean peak live-task state per rank over a point's trials, bytes — the
/// SoA memory budget a giant trial must fit in, normalized per rank.
fn state_bytes_per_rank(p: &Point) -> f64 {
    let n = p.profiles.len().max(1) as f64;
    let mean =
        p.profiles.iter().map(|c| c.peak_rank_state_bytes as f64).sum::<f64>() / n;
    mean / p.cfg.ranks.max(1) as f64
}

/// Build the sweep grid: ranks × recovery methods, single process failure,
/// modeled fidelity (16k ranks cannot execute per-rank artifacts).
fn build_grid(
    base: &ExperimentConfig,
    opts: &SweepOpts,
) -> Result<Vec<ExperimentConfig>, String> {
    if base.fidelity != Fidelity::Modeled {
        return Err(
            "scale: the sweep runs fidelity=modeled (per-rank artifact execution \
             is not feasible at 16k ranks); drop fidelity="
                .to_string(),
        );
    }
    let mut cfgs = Vec::new();
    for &ranks in &presets::scale_rungs(opts.max_ranks)? {
        for rk in RecoveryKind::ALL {
            if rk == RecoveryKind::Ulfm && ranks > presets::SCALE_ULFM_MAX_RANKS {
                continue; // documented cap, mirrors the paper's prototype limit
            }
            let mut c = base.clone();
            c.ranks = ranks;
            c.recovery = rk;
            c.failure = FailureKind::Process;
            c.ckpt = None; // Table 2 policy per method
            if rk == RecoveryKind::Replication {
                c.repl_degree = presets::SCALE_REPL_DEGREE;
            }
            c.validate().map_err(|e| {
                format!("scale sweep point ranks={ranks} recovery={rk}: {e}")
            })?;
            cfgs.push(c);
        }
    }
    debug_assert!(!cfgs.is_empty(), "scale_rungs never returns an empty ladder");
    Ok(cfgs)
}

/// `scale_compare.csv`: the figure-CSV column block plus the sharded
/// executor's memory-footprint column (`state_bytes_per_rank` — mean peak
/// live-task state over the point's trials, divided by rank count).
fn write_scale_csv(outdir: &str, points: &[Point]) -> std::io::Result<()> {
    std::fs::create_dir_all(outdir)?;
    let mut s = format!(
        "app,ranks,recovery,failure,ckpt,total_s,total_ci,ckpt_write_s,ckpt_write_ci,\
         ckpt_read_s,ckpt_read_ci,mpi_recovery_s,mpi_recovery_ci,app_s,app_ci,\
         {STORAGE_CSV_HEADER},state_bytes_per_rank,trials\n",
    );
    for p in points {
        s.push_str(&format!(
            "{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{:.1},{}\n",
            p.cfg.app,
            p.cfg.ranks,
            p.cfg.recovery,
            p.cfg.failure,
            p.cfg.effective_stack(),
            p.total.mean,
            p.total.ci95,
            p.ckpt_write.mean,
            p.ckpt_write.ci95,
            p.ckpt_read.mean,
            p.ckpt_read.ci95,
            p.recovery.mean,
            p.recovery.ci95,
            p.app.mean,
            p.app.ci95,
            storage_csv_cells(&p.storage),
            state_bytes_per_rank(p),
            p.total.n,
        ));
    }
    std::fs::write(format!("{outdir}/scale_compare.csv"), s)
}

/// Run the weak-scaling sweep: markdown table on stdout, CSV under
/// `outdir/scale_compare.csv`.
pub fn scale_sweep(base: &ExperimentConfig, opts: &SweepOpts) -> Result<Vec<Point>, String> {
    let cfgs = build_grid(base, opts)?;
    let trials: u32 = cfgs.iter().map(|c| c.trials).sum();
    crate::info!(
        "  scale sweep: {} points / {trials} trials (to {} ranks) on {} worker(s), \
         {} executor shard(s)...",
        cfgs.len(),
        cfgs.iter().map(|c| c.ranks).max().unwrap_or(0),
        opts.jobs,
        opts.shards
    );
    let (points, stats) = run_points(&cfgs, opts.jobs);
    super::figures::finish_sweep("scale_compare", opts, &points, &stats);

    println!(
        "\n## Large-rank weak scaling ({}): Figure 4 extended past 3072 ranks\n",
        base.app
    );
    println!(
        "| ranks | recovery | ckpt | total (s) | MPI recovery (s) | app (s) | state B/rank |"
    );
    println!("|---|---|---|---|---|---|---|");
    for p in &points {
        println!(
            "| {} | {} | {} | {} | {} | {} | {:.0} |",
            p.cfg.ranks,
            p.cfg.recovery,
            p.cfg.effective_stack(),
            cell(&p.total),
            cell(&p.recovery),
            cell(&p.app),
            state_bytes_per_rank(p),
        );
    }
    println!(
        "\n(expected shape: Reinit++ recovery stays ~flat to 16k ranks, CR pays the"
    );
    println!(
        " full re-deploy at every scale; ULFM — capped at {} ranks, see module docs —",
        presets::SCALE_ULFM_MAX_RANKS
    );
    println!(" degrades with the survivor consensus. See EXPERIMENTS.md §Large-rank scaling)");

    if let Err(e) = write_scale_csv(&opts.outdir, &points) {
        crate::warnln!("could not write scale_compare.csv: {e}");
    }
    Ok(points)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AppKind;

    fn quick_base() -> ExperimentConfig {
        let mut c = ExperimentConfig::default();
        c.app = AppKind::Hpccg;
        c.trials = 2;
        c.iters = 4;
        c.fidelity = Fidelity::Modeled;
        c.hpccg_nx = 4;
        c
    }

    #[test]
    fn grid_shape_and_ulfm_cap() {
        let opts = SweepOpts {
            max_ranks: 16384,
            outdir: "/tmp/reinitpp-test-results".into(),
            jobs: 1,
            profile: false,
            shards: 1,
        };
        let cfgs = build_grid(&quick_base(), &opts).unwrap();
        // 4 rank counts x 5 methods + 2 rank counts x {CR, Reinit, Repl, Shrink}
        assert_eq!(cfgs.len(), 4 * 5 + 2 * 4);
        assert!(cfgs.iter().all(|c| c.failure == FailureKind::Process));
        assert!(
            !cfgs
                .iter()
                .any(|c| c.recovery == RecoveryKind::Ulfm
                    && c.ranks > presets::SCALE_ULFM_MAX_RANKS),
            "ULFM must be capped at {}",
            presets::SCALE_ULFM_MAX_RANKS
        );
        assert!(cfgs.iter().any(|c| c.ranks == 16384));
    }

    #[test]
    fn grid_honors_max_ranks_past_the_preset_ceiling() {
        // The old sweep silently clamped anything above 16384 to the preset
        // list; the ladder now keeps doubling to the requested cap.
        let opts = SweepOpts {
            max_ranks: 65536,
            ..SweepOpts::default()
        };
        let cfgs = build_grid(&quick_base(), &opts).unwrap();
        assert!(
            cfgs.iter().any(|c| c.ranks == 65536),
            "--max-ranks 65536 must produce a 65536-rank rung"
        );
        assert!(cfgs.iter().any(|c| c.ranks == 32768));
        assert!(
            !cfgs
                .iter()
                .any(|c| c.recovery == RecoveryKind::Ulfm && c.ranks > 4096),
            "the ULFM cap still applies on extended rungs"
        );
    }

    #[test]
    fn non_power_of_two_max_ranks_is_an_error() {
        let opts = SweepOpts {
            max_ranks: 3000,
            ..SweepOpts::default()
        };
        let err = build_grid(&quick_base(), &opts).unwrap_err();
        assert!(err.contains("power of two"), "{err}");
    }

    #[test]
    fn non_modeled_fidelity_is_rejected() {
        let mut base = quick_base();
        base.fidelity = Fidelity::Auto;
        let opts = SweepOpts::default();
        let err = build_grid(&base, &opts).unwrap_err();
        assert!(err.contains("modeled"), "{err}");
    }

    #[test]
    fn scale_sweep_runs_and_is_jobs_deterministic() {
        // The smallest rung of the sweep, serial vs 2 workers: identical
        // Points (and therefore identical scale_compare.csv bytes — the
        // same writer the figures use).
        let base = quick_base();
        let mk = |jobs, outdir: &str| SweepOpts {
            max_ranks: 512,
            outdir: outdir.into(),
            jobs,
            profile: false,
            shards: 1,
        };
        let serial =
            scale_sweep(&base, &mk(1, "/tmp/reinitpp-test-results/scale-j1")).unwrap();
        let par = scale_sweep(&base, &mk(2, "/tmp/reinitpp-test-results/scale-j2")).unwrap();
        assert_eq!(serial.len(), 5, "512 ranks x 5 recovery methods");
        for (a, b) in serial.iter().zip(&par) {
            assert_eq!(a.cfg.recovery, b.cfg.recovery);
            assert_eq!(a.total, b.total);
            assert_eq!(a.recovery, b.recovery);
            assert_eq!(a.app, b.app);
        }
        let j1 = std::fs::read("/tmp/reinitpp-test-results/scale-j1/scale_compare.csv")
            .unwrap();
        let j2 = std::fs::read("/tmp/reinitpp-test-results/scale-j2/scale_compare.csv")
            .unwrap();
        assert!(!j1.is_empty());
        assert_eq!(j1, j2, "scale CSV bytes must not depend on worker count");
        let text = String::from_utf8(j1).unwrap();
        let header = text.lines().next().unwrap();
        assert!(
            header.ends_with("state_bytes_per_rank,trials"),
            "scale CSV must report bytes/rank: {header}"
        );
        assert!(
            serial.iter().all(|p| state_bytes_per_rank(p) > 0.0),
            "every point carries a live-task state footprint"
        );
        // paper shape at the 512-rank rung: CR much slower than Reinit++
        let rec = |rk: RecoveryKind| {
            serial
                .iter()
                .find(|p| p.cfg.recovery == rk)
                .unwrap()
                .recovery
                .mean
        };
        assert!(rec(RecoveryKind::Cr) > 2.0 * rec(RecoveryKind::Reinit));
    }
}
