//! Large-rank weak-scaling sweep (`reinitpp scale`): extends the paper's
//! Figure 4 recovery-time curves past its 3072-rank ceiling.
//!
//! The paper's headline claim is that Reinit++ "scales excellently as the
//! number of MPI processes grows", but its evaluation stops at 3072 ranks.
//! ReStore (arXiv 2203.01107) and PartRePer-MPI (arXiv 2310.16370) both
//! argue recovery-time results only become interesting at thousands of
//! processes. With the O(1) fabric routing table, indexed receive matching
//! and allocation-lean collectives, a simulated iteration is cheap enough
//! in host time that the sweep runs the modeled-fidelity grid at
//! 512..16384 ranks under a single process failure for every recovery
//! method (ULFM capped at `presets::SCALE_ULFM_MAX_RANKS` — the survivor
//! sets of shrink/agree are quadratic host memory at extreme scale, and
//! the paper's own ULFM prototype stopped at 3072). Replication runs at
//! node-disjoint degree `presets::SCALE_REPL_DEGREE` on every rung: at
//! 512+ ranks each point spans dozens of nodes, so placement always fits.
//!
//! Like every harness sweep, the grid is flattened to (point, trial) work
//! items for the pool and merged deterministically, so
//! `scale_compare.csv` is byte-identical for any `--jobs` value (pinned by
//! the unit test below and a serial-vs-2-worker `cmp` in CI).

use super::figures::{cell, write_csv, SweepOpts};
use super::{run_points, Point};
use crate::config::{presets, ExperimentConfig, FailureKind, Fidelity, RecoveryKind};

/// Rank counts the scale sweep visits (capped by `--max-ranks`).
fn sweep_ranks(max: u32) -> Vec<u32> {
    presets::SCALE_SWEEP_RANKS
        .iter()
        .copied()
        .filter(|&r| r <= max)
        .collect()
}

/// Build the sweep grid: ranks × recovery methods, single process failure,
/// modeled fidelity (16k ranks cannot execute per-rank artifacts).
fn build_grid(
    base: &ExperimentConfig,
    opts: &SweepOpts,
) -> Result<Vec<ExperimentConfig>, String> {
    if base.fidelity != Fidelity::Modeled {
        return Err(
            "scale: the sweep runs fidelity=modeled (per-rank artifact execution \
             is not feasible at 16k ranks); drop fidelity="
                .to_string(),
        );
    }
    let mut cfgs = Vec::new();
    for &ranks in &sweep_ranks(opts.max_ranks) {
        for rk in RecoveryKind::ALL {
            if rk == RecoveryKind::Ulfm && ranks > presets::SCALE_ULFM_MAX_RANKS {
                continue; // documented cap, mirrors the paper's prototype limit
            }
            let mut c = base.clone();
            c.ranks = ranks;
            c.recovery = rk;
            c.failure = FailureKind::Process;
            c.ckpt = None; // Table 2 policy per method
            if rk == RecoveryKind::Replication {
                c.repl_degree = presets::SCALE_REPL_DEGREE;
            }
            c.validate().map_err(|e| {
                format!("scale sweep point ranks={ranks} recovery={rk}: {e}")
            })?;
            cfgs.push(c);
        }
    }
    if cfgs.is_empty() {
        return Err(format!(
            "scale sweep: no rank count of {:?} fits --max-ranks {}",
            presets::SCALE_SWEEP_RANKS,
            opts.max_ranks
        ));
    }
    Ok(cfgs)
}

/// Run the weak-scaling sweep: markdown table on stdout, CSV under
/// `outdir/scale_compare.csv`.
pub fn scale_sweep(base: &ExperimentConfig, opts: &SweepOpts) -> Result<Vec<Point>, String> {
    let cfgs = build_grid(base, opts)?;
    let trials: u32 = cfgs.iter().map(|c| c.trials).sum();
    crate::info!(
        "  scale sweep: {} points / {trials} trials (to {} ranks) on {} worker(s)...",
        cfgs.len(),
        cfgs.iter().map(|c| c.ranks).max().unwrap_or(0),
        opts.jobs
    );
    let (points, stats) = run_points(&cfgs, opts.jobs);
    super::figures::finish_sweep("scale_compare", opts, &points, &stats);

    println!(
        "\n## Large-rank weak scaling ({}): Figure 4 extended past 3072 ranks\n",
        base.app
    );
    println!("| ranks | recovery | ckpt | total (s) | MPI recovery (s) | app (s) |");
    println!("|---|---|---|---|---|---|");
    for p in &points {
        println!(
            "| {} | {} | {} | {} | {} | {} |",
            p.cfg.ranks,
            p.cfg.recovery,
            p.cfg.effective_stack(),
            cell(&p.total),
            cell(&p.recovery),
            cell(&p.app),
        );
    }
    println!(
        "\n(expected shape: Reinit++ recovery stays ~flat to 16k ranks, CR pays the"
    );
    println!(
        " full re-deploy at every scale; ULFM — capped at {} ranks, see module docs —",
        presets::SCALE_ULFM_MAX_RANKS
    );
    println!(" degrades with the survivor consensus. See EXPERIMENTS.md §Large-rank scaling)");

    if let Err(e) = write_csv("scale_compare", &opts.outdir, &points) {
        crate::warnln!("could not write scale_compare.csv: {e}");
    }
    Ok(points)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AppKind;

    fn quick_base() -> ExperimentConfig {
        let mut c = ExperimentConfig::default();
        c.app = AppKind::Hpccg;
        c.trials = 2;
        c.iters = 4;
        c.fidelity = Fidelity::Modeled;
        c.hpccg_nx = 4;
        c
    }

    #[test]
    fn grid_shape_and_ulfm_cap() {
        let opts = SweepOpts {
            max_ranks: 16384,
            outdir: "/tmp/reinitpp-test-results".into(),
            jobs: 1,
            profile: false,
        };
        let cfgs = build_grid(&quick_base(), &opts).unwrap();
        // 4 rank counts x 5 methods + 2 rank counts x {CR, Reinit, Repl, Shrink}
        assert_eq!(cfgs.len(), 4 * 5 + 2 * 4);
        assert!(cfgs.iter().all(|c| c.failure == FailureKind::Process));
        assert!(
            !cfgs
                .iter()
                .any(|c| c.recovery == RecoveryKind::Ulfm
                    && c.ranks > presets::SCALE_ULFM_MAX_RANKS),
            "ULFM must be capped at {}",
            presets::SCALE_ULFM_MAX_RANKS
        );
        assert!(cfgs.iter().any(|c| c.ranks == 16384));
    }

    #[test]
    fn non_modeled_fidelity_is_rejected() {
        let mut base = quick_base();
        base.fidelity = Fidelity::Auto;
        let opts = SweepOpts::default();
        let err = build_grid(&base, &opts).unwrap_err();
        assert!(err.contains("modeled"), "{err}");
    }

    #[test]
    fn scale_sweep_runs_and_is_jobs_deterministic() {
        // The smallest rung of the sweep, serial vs 2 workers: identical
        // Points (and therefore identical scale_compare.csv bytes — the
        // same writer the figures use).
        let base = quick_base();
        let mk = |jobs, outdir: &str| SweepOpts {
            max_ranks: 512,
            outdir: outdir.into(),
            jobs,
            profile: false,
        };
        let serial =
            scale_sweep(&base, &mk(1, "/tmp/reinitpp-test-results/scale-j1")).unwrap();
        let par = scale_sweep(&base, &mk(2, "/tmp/reinitpp-test-results/scale-j2")).unwrap();
        assert_eq!(serial.len(), 5, "512 ranks x 5 recovery methods");
        for (a, b) in serial.iter().zip(&par) {
            assert_eq!(a.cfg.recovery, b.cfg.recovery);
            assert_eq!(a.total, b.total);
            assert_eq!(a.recovery, b.recovery);
            assert_eq!(a.app, b.app);
        }
        let j1 = std::fs::read("/tmp/reinitpp-test-results/scale-j1/scale_compare.csv")
            .unwrap();
        let j2 = std::fs::read("/tmp/reinitpp-test-results/scale-j2/scale_compare.csv")
            .unwrap();
        assert!(!j1.is_empty());
        assert_eq!(j1, j2, "scale CSV bytes must not depend on worker count");
        // paper shape at the 512-rank rung: CR much slower than Reinit++
        let rec = |rk: RecoveryKind| {
            serial
                .iter()
                .find(|p| p.cfg.recovery == rk)
                .unwrap()
                .recovery
                .mean
        };
        assert!(rec(RecoveryKind::Cr) > 2.0 * rec(RecoveryKind::Reinit));
    }
}
