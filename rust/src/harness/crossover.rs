//! Replication-vs-checkpointing crossover sweep (`reinitpp crossover`):
//! MTBF × recovery family × replication degree × checkpoint interval ×
//! ranks.
//!
//! The classic FT trade-off (rMPI, RedMPI, FTHP-MPI, PartRePer-MPI):
//! checkpointing pays a per-iteration write plus a rollback re-execution
//! per failure; replication pays 2x the processes plus steady-state
//! mirroring bandwidth, and in exchange a primary's failure costs only a
//! shadow promotion — zero rollback. Somewhere between "occasional
//! failure" and "failure storm" the curves cross. This sweep maps that
//! crossover empirically over the `storm` MTBF engine: every recovery
//! family (CR / Reinit++ / ULFM / shrink at degree 1, replication at
//! degree 1 and `presets::STORM_REPL_DEGREE`) against the storm MTBF grid
//! and the `presets::CROSSOVER_CKPT_EVERY` checkpoint-interval axis.
//! Shrinking recovery is the third corner of the trade: no spares, no
//! respawn — each failure shrinks the world and the survivors run hotter.
//!
//! Ranks per node defaults to `presets::CROSSOVER_RANKS_PER_NODE` (set by
//! the CLI base) so the smallest rung already spans two compute nodes and
//! node-disjoint shadow placement fits at every point — degree is a grid
//! axis here, not an opt-in. An override that breaks placement fails the
//! per-point `validate()` with the config layer's actionable message.
//!
//! Like every harness sweep, the grid is flattened to (point, trial) work
//! items for the pool and merged deterministically, so
//! `crossover_compare.csv` is byte-identical for any `--jobs` value
//! (pinned by the unit test below and a serial-vs-2-worker `cmp` in CI).

use super::figures::{cell, SweepOpts};
use super::{run_points, Point};
use crate::config::{presets, ExperimentConfig, FailureKind, Fidelity, RecoveryKind};

/// The family rows of the grid: (recovery, replication degree). Degree-1
/// replication is a deliberate row — it mirrors nothing and degrades to a
/// full re-deploy on the first failure, isolating the cost of the
/// replication *machinery* from the benefit of actual shadows.
const FAMILIES: [(RecoveryKind, u32); 6] = [
    (RecoveryKind::Cr, 1),
    (RecoveryKind::Reinit, 1),
    (RecoveryKind::Ulfm, 1),
    (RecoveryKind::Shrink, 1),
    (RecoveryKind::Replication, 1),
    (RecoveryKind::Replication, presets::STORM_REPL_DEGREE),
];

/// Rank counts the crossover sweep visits (the storm rungs, capped by
/// `--max-ranks`).
fn sweep_ranks(max: u32) -> Vec<u32> {
    presets::STORM_SWEEP_RANKS
        .iter()
        .copied()
        .filter(|&r| r <= max)
        .collect()
}

/// Build the sweep grid: family × ranks × MTBF × checkpoint interval,
/// process-failure storms, modeled fidelity.
fn build_grid(
    base: &ExperimentConfig,
    opts: &SweepOpts,
) -> Result<Vec<ExperimentConfig>, String> {
    if base.fidelity != Fidelity::Modeled {
        return Err(
            "crossover: the sweep runs fidelity=modeled (storm trials re-execute \
             many iterations); drop fidelity="
                .to_string(),
        );
    }
    let mut cfgs = Vec::new();
    for &ranks in &sweep_ranks(opts.max_ranks) {
        for &(rk, degree) in &FAMILIES {
            for &mtbf in &presets::STORM_SWEEP_MTBF_S {
                for &every in &presets::CROSSOVER_CKPT_EVERY {
                    let mut c = base.clone();
                    c.ranks = ranks;
                    c.recovery = rk;
                    c.repl_degree = degree;
                    c.failure = FailureKind::Process;
                    c.mtbf_s = mtbf;
                    c.ckpt_every = every;
                    c.ckpt = None; // Table 2 policy per method
                    c.validate().map_err(|e| {
                        format!(
                            "crossover point ranks={ranks} recovery={rk} degree={degree} \
                             mtbf={mtbf} ckpt_every={every}: {e}"
                        )
                    })?;
                    cfgs.push(c);
                }
            }
        }
    }
    if cfgs.is_empty() {
        return Err(format!(
            "crossover sweep: no rank count of {:?} fits --max-ranks {}",
            presets::STORM_SWEEP_RANKS,
            opts.max_ranks
        ));
    }
    Ok(cfgs)
}

/// Run the crossover sweep: markdown table on stdout, CSV under
/// `outdir/crossover_compare.csv`.
pub fn crossover_sweep(
    base: &ExperimentConfig,
    opts: &SweepOpts,
) -> Result<Vec<Point>, String> {
    let cfgs = build_grid(base, opts)?;
    let trials: u32 = cfgs.iter().map(|c| c.trials).sum();
    crate::info!(
        "  crossover sweep: {} points / {trials} trials (MTBF {:?} s, ckpt every {:?}) on {} worker(s)...",
        cfgs.len(),
        presets::STORM_SWEEP_MTBF_S,
        presets::CROSSOVER_CKPT_EVERY,
        opts.jobs
    );
    let (points, stats) = run_points(&cfgs, opts.jobs);
    super::figures::finish_sweep("crossover_compare", opts, &points, &stats);

    println!(
        "\n## Replication vs checkpointing crossover ({}): MTBF x degree x ckpt interval\n",
        base.app
    );
    println!(
        "| ranks | recovery | deg | mtbf (s) | ckpt every | failures | failovers | \
         total (s) | recovery (s) | rollback (s) | failover (s) | mirror (s) | degraded |"
    );
    println!("|---|---|---|---|---|---|---|---|---|---|---|---|---|");
    for p in &points {
        println!(
            "| {} | {} | {} | {} | {} | {:.1} | {:.1} | {} | {} | {} | {} | {:.3} | {:.1} |",
            p.cfg.ranks,
            p.cfg.recovery,
            p.cfg.repl_degree,
            p.cfg.mtbf_s,
            p.cfg.ckpt_every,
            p.failures,
            p.failovers,
            cell(&p.total),
            cell(&p.event_recovery),
            cell(&p.rollback),
            cell(&p.failover),
            p.mirror_s,
            p.degraded,
        );
    }
    println!("\n(expected shape: at loose MTBF checkpointing wins — replication pays");
    println!(" mirroring for failovers it rarely needs; as MTBF tightens below the");
    println!(" re-deploy/rollback anchors the zero-rollback failover pulls ahead —");
    println!(" see EXPERIMENTS.md §Replication crossover)");

    if let Err(e) = write_crossover_csv(&opts.outdir, &points) {
        crate::warnln!("could not write crossover_compare.csv: {e}");
    }
    Ok(points)
}

/// `crossover_compare.csv`: one row per (ranks, family, mtbf, ckpt_every)
/// point, with the per-event decomposition plus the replication columns.
fn write_crossover_csv(outdir: &str, points: &[Point]) -> std::io::Result<()> {
    std::fs::create_dir_all(outdir)?;
    let mut s = String::from(
        "app,ranks,recovery,repl_degree,mtbf_s,ckpt_every,max_failures,failures,\
         failovers,degraded,total_s,total_ci,detect_s,detect_ci,\
         recovery_s,recovery_ci,failover_s,failover_ci,rollback_s,rollback_ci,\
         ckpt_write_s,ckpt_read_s,mirror_s,mirror_mb,app_s,trials\n",
    );
    for p in points {
        s.push_str(&format!(
            "{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{}\n",
            p.cfg.app,
            p.cfg.ranks,
            p.cfg.recovery,
            p.cfg.repl_degree,
            p.cfg.mtbf_s,
            p.cfg.ckpt_every,
            p.cfg.max_failures,
            p.failures,
            p.failovers,
            p.degraded,
            p.total.mean,
            p.total.ci95,
            p.detect.mean,
            p.detect.ci95,
            p.event_recovery.mean,
            p.event_recovery.ci95,
            p.failover.mean,
            p.failover.ci95,
            p.rollback.mean,
            p.rollback.ci95,
            p.ckpt_write.mean,
            p.ckpt_read.mean,
            p.mirror_s,
            p.mirror_mb,
            p.app.mean,
            p.total.n,
        ));
    }
    std::fs::write(format!("{outdir}/crossover_compare.csv"), s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AppKind;

    fn quick_base() -> ExperimentConfig {
        let mut c = ExperimentConfig::default();
        c.app = AppKind::Hpccg;
        c.trials = 2;
        c.iters = 20;
        c.ranks_per_node = presets::CROSSOVER_RANKS_PER_NODE;
        c.fidelity = Fidelity::Modeled;
        c.hpccg_nx = 4;
        c.max_failures = presets::STORM_MAX_FAILURES;
        // paper-scale virtual iteration cost, same anchor as the storm sweep
        c.calib.modeled_compute_scale = presets::STORM_COMPUTE_SCALE;
        c
    }

    #[test]
    fn grid_shape() {
        let opts = SweepOpts {
            max_ranks: 256,
            outdir: "/tmp/reinitpp-test-results".into(),
            jobs: 1,
            profile: false,
        };
        let cfgs = build_grid(&quick_base(), &opts).unwrap();
        // 3 rungs x 6 family rows x 3 MTBFs x 2 ckpt intervals
        assert_eq!(
            cfgs.len(),
            presets::STORM_SWEEP_RANKS.len()
                * FAMILIES.len()
                * presets::STORM_SWEEP_MTBF_S.len()
                * presets::CROSSOVER_CKPT_EVERY.len()
        );
        assert!(cfgs
            .iter()
            .all(|c| c.failure == FailureKind::Process && c.mtbf_s > 0.0));
        // every rung spans >= 2 nodes: degree 2 placement always fits
        assert!(cfgs
            .iter()
            .all(|c| c.nodes() >= presets::STORM_REPL_DEGREE));
        // all five recovery families are on the grid
        for rk in RecoveryKind::ALL {
            assert!(cfgs.iter().any(|c| c.recovery == rk), "missing {rk}");
        }
    }

    #[test]
    fn non_modeled_fidelity_is_rejected() {
        let mut base = quick_base();
        base.fidelity = Fidelity::Auto;
        let err = build_grid(&base, &SweepOpts::default()).unwrap_err();
        assert!(err.contains("modeled"), "{err}");
    }

    #[test]
    fn crossover_sweep_runs_and_is_jobs_deterministic() {
        // The smallest rung, serial vs 2 workers: identical Points and
        // therefore identical crossover_compare.csv bytes.
        let base = quick_base();
        let mk = |jobs, outdir: &str| SweepOpts {
            max_ranks: 16,
            outdir: outdir.into(),
            jobs,
            profile: false,
        };
        let serial =
            crossover_sweep(&base, &mk(1, "/tmp/reinitpp-test-results/crossover-j1"))
                .unwrap();
        let par =
            crossover_sweep(&base, &mk(2, "/tmp/reinitpp-test-results/crossover-j2"))
                .unwrap();
        assert_eq!(serial.len(), 36, "16 ranks x 6 families x 3 MTBFs x 2 intervals");
        for (a, b) in serial.iter().zip(&par) {
            assert_eq!(a.cfg.recovery, b.cfg.recovery);
            assert_eq!(a.cfg.repl_degree, b.cfg.repl_degree);
            assert_eq!(a.total, b.total);
            assert_eq!(a.failover, b.failover);
            assert_eq!(a.failures, b.failures);
            assert_eq!(a.failovers, b.failovers);
        }
        let j1 =
            std::fs::read("/tmp/reinitpp-test-results/crossover-j1/crossover_compare.csv")
                .unwrap();
        let j2 =
            std::fs::read("/tmp/reinitpp-test-results/crossover-j2/crossover_compare.csv")
                .unwrap();
        assert!(!j1.is_empty());
        assert_eq!(j1, j2, "crossover CSV bytes must not depend on worker count");

        let at = |rk: RecoveryKind, deg: u32, mtbf: f64, every: u32| {
            serial
                .iter()
                .find(|p| {
                    p.cfg.recovery == rk
                        && p.cfg.repl_degree == deg
                        && p.cfg.mtbf_s == mtbf
                        && p.cfg.ckpt_every == every
                })
                .unwrap()
        };
        let tight = presets::STORM_SWEEP_MTBF_S[0];
        // the crossover claim at the storm end of the grid: degree-2
        // replication absorbs failures by failover (zero rollback booked)
        // while CR pays a full re-deploy + rollback per event.
        let repl = at(RecoveryKind::Replication, 2, tight, 1);
        let cr = at(RecoveryKind::Cr, 1, tight, 1);
        if repl.failures > 0.0 {
            assert!(repl.failovers > 0.0, "storm must trigger failovers");
            assert!(
                repl.failover.mean > 0.0 && repl.rollback.mean < cr.rollback.mean,
                "failover books promotion time, not rollback"
            );
        }
        assert!(repl.mirror_mb > 0.0, "degree 2 must mirror state");
        // degree-1 replication never fails over and mirrors nothing
        let solo = at(RecoveryKind::Replication, 1, tight, 1);
        assert_eq!(solo.failovers, 0.0);
        assert_eq!(solo.mirror_mb, 0.0);
    }
}
