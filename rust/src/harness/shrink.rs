//! Shrink-vs-substitute-vs-CR sweep (`reinitpp shrink`): ranks × failure
//! kind × recovery family × MTBF, over the storm arrival engine.
//!
//! Shrink-or-Substitute (arXiv 1810.00705) frames the recovery topology
//! choice: *substitute* the failed capacity from a spare pool (Reinit++'s
//! respawn path) or *shrink* the job and keep computing on survivors.
//! ReStore (arXiv 2203.01107) adds the missing piece for the shrink arm —
//! rapid recovery hinges on load-balanced redistribution of the surviving
//! in-memory checkpoint copies. This sweep maps the trade empirically:
//!
//! - `shrink` runs with **zero** spare nodes (its whole point: no
//!   over-provisioning) and absorbs each failure by continuing smaller —
//!   the survivors run proportionally hotter (`NewWorld::work_scale`);
//! - `reinit` is the substitute arm: spare-pool respawn, in-place
//!   survivors — until the pool runs dry and it degrades to a re-deploy;
//! - `cr` is the paper's baseline: every event pays a full re-deploy.
//!
//! Both failure kinds run: process-failure storms exercise the in-memory
//! redistribution path (Table 2 gives shrink `local+partner1` there, so
//! `redistribute_mb` is live), node-failure storms exercise the
//! spare-pool-vs-survivors capacity question (Table 2 pins `fs`, so
//! redistribution moves nothing — the column pins that too).
//!
//! Like every harness sweep, the grid is flattened to (point, trial) work
//! items for the pool and merged deterministically, so
//! `shrink_compare.csv` is byte-identical for any `--jobs` value (pinned
//! by the unit test below and a serial-vs-2-worker `cmp` in CI).

use super::figures::{cell, SweepOpts};
use super::{run_points, Point};
use crate::config::{presets, ExperimentConfig, FailureKind, Fidelity, RecoveryKind};

/// The family rows of the grid: (recovery, spare nodes). Shrink gets zero
/// spares by construction; the substitute and CR arms get the paper's one
/// spare node, which a storm can exhaust — the `degraded` column is where
/// that shows up.
const FAMILIES: [(RecoveryKind, u32); 3] = [
    (RecoveryKind::Shrink, 0),
    (RecoveryKind::Reinit, 1),
    (RecoveryKind::Cr, 1),
];

/// Rank counts the shrink sweep visits (the storm rungs, capped by
/// `--max-ranks`).
fn sweep_ranks(max: u32) -> Vec<u32> {
    presets::STORM_SWEEP_RANKS
        .iter()
        .copied()
        .filter(|&r| r <= max)
        .collect()
}

/// Build the sweep grid: ranks × failure kind × family × MTBF, modeled
/// fidelity (storm trials re-execute many iterations).
fn build_grid(
    base: &ExperimentConfig,
    opts: &SweepOpts,
) -> Result<Vec<ExperimentConfig>, String> {
    if base.fidelity != Fidelity::Modeled {
        return Err(
            "shrink: the sweep runs fidelity=modeled (storm trials re-execute \
             many iterations); drop fidelity="
                .to_string(),
        );
    }
    let mut cfgs = Vec::new();
    for &ranks in &sweep_ranks(opts.max_ranks) {
        for failure in [FailureKind::Process, FailureKind::Node] {
            for &(rk, spares) in &FAMILIES {
                for &mtbf in &presets::STORM_SWEEP_MTBF_S {
                    let mut c = base.clone();
                    c.ranks = ranks;
                    c.recovery = rk;
                    c.failure = failure;
                    c.mtbf_s = mtbf;
                    c.spare_nodes = spares;
                    c.ckpt = None; // Table 2 policy per method
                    c.validate().map_err(|e| {
                        format!(
                            "shrink sweep point ranks={ranks} recovery={rk} \
                             failure={failure} mtbf={mtbf}: {e}"
                        )
                    })?;
                    cfgs.push(c);
                }
            }
        }
    }
    if cfgs.is_empty() {
        return Err(format!(
            "shrink sweep: no rank count of {:?} fits --max-ranks {}",
            presets::STORM_SWEEP_RANKS,
            opts.max_ranks
        ));
    }
    Ok(cfgs)
}

/// Run the shrink-vs-substitute-vs-CR sweep: markdown table on stdout, CSV
/// under `outdir/shrink_compare.csv`.
pub fn shrink_sweep(base: &ExperimentConfig, opts: &SweepOpts) -> Result<Vec<Point>, String> {
    let cfgs = build_grid(base, opts)?;
    let trials: u32 = cfgs.iter().map(|c| c.trials).sum();
    crate::info!(
        "  shrink sweep: {} points / {trials} trials (MTBF {:?} s, min_ranks {}) on {} worker(s)...",
        cfgs.len(),
        presets::STORM_SWEEP_MTBF_S,
        base.min_ranks,
        opts.jobs
    );
    let (points, stats) = run_points(&cfgs, opts.jobs);
    super::figures::finish_sweep("shrink_compare", opts, &points, &stats);

    println!(
        "\n## Shrink vs substitute vs CR ({}): continue on survivors\n",
        base.app
    );
    println!(
        "| ranks | recovery | spares | failure | mtbf (s) | failures | shrinks | \
         redist (MB) | total (s) | recovery (s) | rollback (s) | degraded |"
    );
    println!("|---|---|---|---|---|---|---|---|---|---|---|---|");
    for p in &points {
        println!(
            "| {} | {} | {} | {} | {} | {:.1} | {:.1} | {:.3} | {} | {} | {} | {:.1} |",
            p.cfg.ranks,
            p.cfg.recovery,
            p.cfg.spare_nodes,
            p.cfg.failure,
            p.cfg.mtbf_s,
            p.failures,
            p.shrinks,
            p.redistribute_mb,
            cell(&p.total),
            cell(&p.event_recovery),
            cell(&p.rollback),
            p.degraded,
        );
    }
    println!("\n(expected shape: shrink absorbs each failure with zero spares — the");
    println!(" survivors run hotter instead of waiting on a fork+exec or re-deploy;");
    println!(" substitute matches it until the spare pool runs dry, CR pays a full");
    println!(" re-deploy per event — see EXPERIMENTS.md §Shrinking recovery)");

    if let Err(e) = write_shrink_csv(&opts.outdir, &points) {
        crate::warnln!("could not write shrink_compare.csv: {e}");
    }
    Ok(points)
}

/// `shrink_compare.csv`: one row per (ranks, failure, family, mtbf) point,
/// with the shrink bookkeeping columns next to the per-event decomposition.
fn write_shrink_csv(outdir: &str, points: &[Point]) -> std::io::Result<()> {
    std::fs::create_dir_all(outdir)?;
    let mut s = String::from(
        "app,ranks,recovery,failure,spare_nodes,min_ranks,mtbf_s,max_failures,\
         failures,shrinks,redistribute_mb,degraded,\
         total_s,total_ci,detect_s,detect_ci,recovery_s,recovery_ci,\
         rollback_s,rollback_ci,ckpt_write_s,ckpt_read_s,app_s,trials\n",
    );
    for p in points {
        s.push_str(&format!(
            "{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{}\n",
            p.cfg.app,
            p.cfg.ranks,
            p.cfg.recovery,
            p.cfg.failure,
            p.cfg.spare_nodes,
            p.cfg.min_ranks,
            p.cfg.mtbf_s,
            p.cfg.max_failures,
            p.failures,
            p.shrinks,
            p.redistribute_mb,
            p.degraded,
            p.total.mean,
            p.total.ci95,
            p.detect.mean,
            p.detect.ci95,
            p.event_recovery.mean,
            p.event_recovery.ci95,
            p.rollback.mean,
            p.rollback.ci95,
            p.ckpt_write.mean,
            p.ckpt_read.mean,
            p.app.mean,
            p.total.n,
        ));
    }
    std::fs::write(format!("{outdir}/shrink_compare.csv"), s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AppKind;

    fn quick_base() -> ExperimentConfig {
        let mut c = ExperimentConfig::default();
        c.app = AppKind::Hpccg;
        c.trials = 2;
        c.iters = 20;
        c.ranks_per_node = presets::CROSSOVER_RANKS_PER_NODE;
        c.fidelity = Fidelity::Modeled;
        c.hpccg_nx = 4;
        c.max_failures = presets::STORM_MAX_FAILURES;
        // paper-scale virtual iteration cost, same anchor as the storm sweep
        c.calib.modeled_compute_scale = presets::STORM_COMPUTE_SCALE;
        c
    }

    #[test]
    fn grid_shape() {
        let opts = SweepOpts {
            max_ranks: 256,
            outdir: "/tmp/reinitpp-test-results".into(),
            jobs: 1,
            profile: false,
        };
        let cfgs = build_grid(&quick_base(), &opts).unwrap();
        // 3 rungs x 2 failure kinds x 3 families x 3 MTBFs
        assert_eq!(
            cfgs.len(),
            presets::STORM_SWEEP_RANKS.len() * 2 * FAMILIES.len()
                * presets::STORM_SWEEP_MTBF_S.len()
        );
        assert!(cfgs.iter().all(|c| c.mtbf_s > 0.0));
        // the shrink arm runs with zero spares, the others with the paper's one
        assert!(cfgs
            .iter()
            .all(|c| (c.recovery == RecoveryKind::Shrink) == (c.spare_nodes == 0)));
        // both failure kinds are on the grid for every family
        for &(rk, _) in &FAMILIES {
            for failure in [FailureKind::Process, FailureKind::Node] {
                assert!(
                    cfgs.iter()
                        .any(|c| c.recovery == rk && c.failure == failure),
                    "missing {rk}/{failure}"
                );
            }
        }
    }

    #[test]
    fn non_modeled_fidelity_is_rejected() {
        let mut base = quick_base();
        base.fidelity = Fidelity::Auto;
        let err = build_grid(&base, &SweepOpts::default()).unwrap_err();
        assert!(err.contains("modeled"), "{err}");
    }

    #[test]
    fn shrink_sweep_runs_and_is_jobs_deterministic() {
        // The smallest rung, serial vs 2 workers: identical Points and
        // therefore identical shrink_compare.csv bytes.
        let base = quick_base();
        let mk = |jobs, outdir: &str| SweepOpts {
            max_ranks: 16,
            outdir: outdir.into(),
            jobs,
            profile: false,
        };
        let serial =
            shrink_sweep(&base, &mk(1, "/tmp/reinitpp-test-results/shrink-j1")).unwrap();
        let par =
            shrink_sweep(&base, &mk(2, "/tmp/reinitpp-test-results/shrink-j2")).unwrap();
        assert_eq!(
            serial.len(),
            18,
            "16 ranks x 2 failure kinds x 3 families x 3 MTBFs"
        );
        for (a, b) in serial.iter().zip(&par) {
            assert_eq!(a.cfg.recovery, b.cfg.recovery);
            assert_eq!(a.cfg.failure, b.cfg.failure);
            assert_eq!(a.total, b.total);
            assert_eq!(a.event_recovery, b.event_recovery);
            assert_eq!(a.failures, b.failures);
            assert_eq!(a.shrinks, b.shrinks);
            assert_eq!(a.redistribute_mb, b.redistribute_mb);
        }
        let j1 = std::fs::read("/tmp/reinitpp-test-results/shrink-j1/shrink_compare.csv")
            .unwrap();
        let j2 = std::fs::read("/tmp/reinitpp-test-results/shrink-j2/shrink_compare.csv")
            .unwrap();
        assert!(!j1.is_empty());
        assert_eq!(j1, j2, "shrink CSV bytes must not depend on worker count");

        // bookkeeping: only the shrink family shrinks or redistributes
        for p in &serial {
            if p.cfg.recovery != RecoveryKind::Shrink {
                assert_eq!(p.shrinks, 0.0, "{} must not shrink", p.cfg.recovery);
                assert_eq!(p.redistribute_mb, 0.0);
            }
        }
        // the tight end of the MTBF grid actually fires shrinks
        assert!(
            serial
                .iter()
                .any(|p| p.cfg.recovery == RecoveryKind::Shrink && p.shrinks > 0.0),
            "no shrink point absorbed a failure"
        );
        for p in &serial {
            if p.cfg.recovery != RecoveryKind::Shrink || p.shrinks == 0.0 {
                continue;
            }
            match p.cfg.failure {
                // process-failure shrink runs the Table 2 memory stack: the
                // victim's lost local copy is always reinstated, so ReStore
                // redistribution moves bytes every time
                FailureKind::Process => {
                    assert!(
                        p.redistribute_mb > 0.0,
                        "process-failure shrink must redistribute"
                    );
                    // 16 ranks, <= STORM_MAX_FAILURES victims: never below
                    // min_ranks, so the spares=0 run never degrades
                    assert_eq!(p.degraded, 0.0, "shrink must not degrade above min_ranks");
                }
                // node-failure shrink runs the fs stack: FS-tier placements
                // never move, pinning the Table 2 policy in the CSV
                FailureKind::Node => assert_eq!(p.redistribute_mb, 0.0),
                FailureKind::None => unreachable!(),
            }
        }
    }
}
