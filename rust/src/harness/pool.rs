//! Parallel sweep scheduler: multi-core trial execution with a
//! deterministic merge.
//!
//! The evaluation grid (apps × rank counts × recovery methods × failure
//! kinds, each point averaged over seeded trials — paper §5) is pleasingly
//! parallel at *trial* granularity: every trial constructs its own
//! deterministic `Sim` and shares nothing with its siblings. The `Sim` is
//! `Rc`-based and `!Send`, so the pool never moves a simulation between
//! threads; instead each worker runs whole trials locally — resolving the
//! XLA runtime per worker via `RtCache`, since `Rc<XlaRuntime>` cannot
//! cross threads either — and sends back a plain `Send` result struct.
//!
//! Work items are handed out from a shared injector queue at (point, trial)
//! granularity, so one expensive point (say 1024 ranks at Full fidelity)
//! fans out across every core instead of serializing its trials. Results
//! are merged back in (point, trial) order, which makes markdown tables,
//! CSVs and `mean_ci95` summaries bit-identical to a serial run regardless
//! of thread count or completion order (`rust/tests/parallel_determinism.rs`
//! pins this).
//!
//! Hand-rolled on `std::thread::scope` + `Mutex<VecDeque>` + `mpsc`: the
//! offline build has no rayon/crossbeam, and a work-stealing deque buys
//! nothing over a single injector lock at this granularity (a trial costs
//! milliseconds to minutes; the lock costs nanoseconds).

use std::cell::RefCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Mutex};
use std::time::Instant;

use crate::config::ExperimentConfig;
use crate::metrics::SweepStats;
use crate::recovery::job::{run_trial, RtCache, TrialResult};

thread_local! {
    /// One runtime cache per thread, living as long as the thread: repeated
    /// sweeps on the same thread (the serial path, or an embedding with
    /// persistent workers) load each artifacts directory once, not once per
    /// `run_trials` call. Pool worker threads are per-sweep, so a parallel
    /// Full-fidelity sweep still pays one load per worker.
    static RT_CACHE: RefCell<RtCache> = RefCell::new(RtCache::new());
}

/// One unit of work: trial `trial` of the point at index `point` in the
/// sweep's point list. Everything a worker needs is owned and `Send`.
pub struct TrialSpec {
    pub point: usize,
    pub trial: u32,
    pub cfg: ExperimentConfig,
}

/// A finished trial, sent back from a worker.
pub struct TrialOut {
    pub point: usize,
    pub trial: u32,
    /// Host seconds this one trial took (busy time on its worker).
    pub host_s: f64,
    pub result: TrialResult,
}

/// Default worker count: all available cores (`--jobs` overrides).
pub fn default_jobs() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Sets the sweep's cancel flag if its worker thread unwinds.
struct CancelOnPanic<'a>(&'a AtomicBool);

impl Drop for CancelOnPanic<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.store(true, Ordering::Relaxed);
        }
    }
}

fn run_one(spec: TrialSpec, worker: usize, traced: bool) -> TrialOut {
    // Resolve the runtime before starting the clock: a thread's one-time
    // XLA load must not be billed to whichever trial it runs first.
    let xla = RT_CACHE.with(|rt| rt.borrow_mut().resolve(&spec.cfg));
    let begin_us = if traced { crate::trace::wall_us() } else { 0.0 };
    let t0 = Instant::now();
    let result = run_trial(&spec.cfg, spec.trial, xla);
    let host_s = t0.elapsed().as_secs_f64();
    if traced {
        crate::trace::pool_record_trial(worker, spec.point, spec.trial, begin_us, host_s * 1e6);
    }
    TrialOut {
        point: spec.point,
        trial: spec.trial,
        host_s,
        result,
    }
}

/// Run every spec — serially on the caller thread for `jobs <= 1`, else on
/// `jobs` scoped worker threads — and return the outputs sorted by
/// (point, trial) plus host-side throughput stats.
pub fn run_trials(specs: Vec<TrialSpec>, jobs: usize) -> (Vec<TrialOut>, SweepStats) {
    let trials = specs.len();
    let jobs = jobs.clamp(1, trials.max(1));
    // One flag read per sweep, not per trial: pool tracing is on exactly
    // when a global trace destination is installed (`--trace`).
    let traced = crate::trace::pool_trace_enabled();
    // Progress heartbeat on stderr (~every 10% of the sweep), so a long
    // figure run is distinguishable from a hung one.
    let progress_every = (trials / 10).max(1);
    let progress = |done: usize| {
        if done % progress_every == 0 && done < trials {
            crate::info!("  {done}/{trials} trials done");
        }
    };
    let t0 = Instant::now();
    let mut outs: Vec<TrialOut> = if jobs == 1 {
        specs
            .into_iter()
            .enumerate()
            .map(|(i, s)| {
                let o = run_one(s, 0, traced);
                progress(i + 1);
                o
            })
            .collect()
    } else {
        let queue: Mutex<VecDeque<TrialSpec>> = Mutex::new(specs.into());
        let cancelled = AtomicBool::new(false);
        let (tx, rx) = mpsc::channel::<TrialOut>();
        std::thread::scope(|scope| {
            for worker in 0..jobs {
                let tx = tx.clone();
                let queue = &queue;
                let cancelled = &cancelled;
                scope.spawn(move || {
                    // Fail fast: if this worker unwinds (a trial panicked),
                    // the guard stops the others from burning through the
                    // rest of a sweep whose results will be discarded when
                    // the scope re-raises the panic.
                    let _guard = CancelOnPanic(cancelled);
                    loop {
                        if cancelled.load(Ordering::Relaxed) {
                            break;
                        }
                        // The lock guard is a temporary: released before the
                        // (long) trial runs.
                        let (next, depth) = {
                            let mut q = queue.lock().unwrap();
                            (q.pop_front(), q.len() as u64)
                        };
                        let Some(spec) = next else { break };
                        if traced {
                            crate::trace::pool_sample("injector_queue_depth", depth);
                        }
                        if tx.send(run_one(spec, worker, traced)).is_err() {
                            break;
                        }
                    }
                });
            }
            drop(tx); // rx drains until every worker's clone is gone
            let mut outs = Vec::with_capacity(trials);
            for o in rx {
                outs.push(o);
                progress(outs.len());
            }
            outs
        })
    };
    outs.sort_unstable_by_key(|o| (o.point, o.trial));
    let busy_s = outs.iter().map(|o| o.host_s).sum();
    let stats = SweepStats {
        jobs,
        trials,
        wall_s: t0.elapsed().as_secs_f64(),
        busy_s,
    };
    (outs, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AppKind, FailureKind, Fidelity, RecoveryKind};

    fn quick_cfg(ranks: u32, recovery: RecoveryKind) -> ExperimentConfig {
        let mut c = ExperimentConfig::default();
        c.app = AppKind::Hpccg;
        c.recovery = recovery;
        c.failure = FailureKind::Process;
        c.ranks = ranks;
        c.iters = 5;
        c.trials = 2;
        c.fidelity = Fidelity::Modeled;
        c.hpccg_nx = 4;
        c
    }

    fn specs_for(cfgs: &[ExperimentConfig]) -> Vec<TrialSpec> {
        cfgs.iter()
            .enumerate()
            .flat_map(|(point, c)| {
                (0..c.trials).map(move |trial| TrialSpec {
                    point,
                    trial,
                    cfg: c.clone(),
                })
            })
            .collect()
    }

    #[test]
    fn parallel_matches_serial_bitwise() {
        let cfgs = [
            quick_cfg(8, RecoveryKind::Reinit),
            quick_cfg(8, RecoveryKind::Cr),
        ];
        let (serial, s_stats) = run_trials(specs_for(&cfgs), 1);
        let (parallel, _) = run_trials(specs_for(&cfgs), 4);
        assert_eq!(s_stats.trials, 4);
        assert_eq!(s_stats.jobs, 1);
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!((a.point, a.trial), (b.point, b.trial));
            let (ra, rb) = (&a.result, &b.result);
            assert_eq!(
                ra.breakdown.total_s.to_bits(),
                rb.breakdown.total_s.to_bits()
            );
            assert_eq!(
                ra.breakdown.mpi_recovery_s.to_bits(),
                rb.breakdown.mpi_recovery_s.to_bits()
            );
            assert_eq!(ra.digests, rb.digests);
            assert_eq!(ra.sim_events, rb.sim_events);
        }
    }

    #[test]
    fn more_jobs_than_work_is_clamped() {
        let cfgs = [quick_cfg(8, RecoveryKind::Reinit)];
        let (outs, stats) = run_trials(specs_for(&cfgs), 64);
        assert_eq!(outs.len(), 2);
        assert_eq!(stats.jobs, 2, "jobs clamped to the number of work items");
        assert!(stats.busy_s > 0.0);
        assert!(stats.utilization() <= 1.0);
    }

    #[test]
    fn empty_spec_list_is_fine() {
        let (outs, stats) = run_trials(Vec::new(), 8);
        assert!(outs.is_empty());
        assert_eq!(stats.trials, 0);
        assert_eq!(stats.trials_per_sec(), 0.0);
    }
}
