//! Application-level checkpointing *policy* (the paper's Table 2): which
//! storage each recovery approach defaults to for each failure type.
//!
//! The storage engines themselves live in [`crate::ckptstore`] — a
//! composable multi-tier stack (local memory, node-disjoint partner
//! replicas, shared filesystem) with an optional asynchronous background
//! drain. The old two-scheme (file / local+buddy) store this module used to
//! host maps onto the stacks `fs` and `local+partner1`; [`CkptStore`] is
//! re-exported here for the experiment drivers.

pub mod policy;

pub use crate::ckptstore::CkptStore;
