//! Application-level checkpointing: serialization, the Table 2 policy, and
//! the two storage schemes (file on the Lustre model; local+buddy memory).

pub mod policy;
mod store;

pub use store::CkptStore;
