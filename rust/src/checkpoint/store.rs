//! Checkpoint storage engines (paper §4 "Checkpointing").
//!
//! Two schemes, as in the paper's own "simple checkpointing library":
//!
//! - **File**: every rank writes its state to the shared parallel
//!   filesystem (`fs::SharedDisk` contention model). Survives anything;
//!   the only option for CR and for node failures (Table 2).
//! - **Memory**: every rank keeps its checkpoint in its own memory *and*
//!   pushes a copy to a buddy — the cyclically next rank (Zheng et al.,
//!   as cited by the paper). A process failure loses the local copy but
//!   the buddy copy survives; a node failure may wipe both, which is why
//!   Table 2 forbids this scheme for node failures.
//!
//! Loss semantics are explicit: the DES keeps all bytes outside the
//! simulated processes, so the fault injector must call `lose_rank` /
//! `lose_node` to model memory destruction.
//!
//! Stores retain the last two iterations per rank: ranks can be one
//! checkpoint apart when a failure lands, and global-restart needs the
//! newest *globally complete* one (agreed via an allreduce-min after
//! recovery).

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::cluster::Topology;
use crate::config::{Calibration, CkptKind};
use crate::fs::SharedDisk;
use crate::sim::{Sim, SimDuration};
use crate::transport::NetCost;

/// Per-rank slot holding the last two checkpoints.
#[derive(Default, Clone)]
struct Slot {
    /// (iteration, payload), newest last. Length <= 2.
    entries: Vec<(u32, Rc<Vec<u8>>)>,
}

impl Slot {
    /// Straight-line two-slot insert (entries stay ascending by iteration):
    /// overwrite a matching iteration, fill an empty slot, or displace the
    /// older entry — anything older than both retained checkpoints is
    /// dropped. No retain/sort/remove churn for a 2-entry buffer.
    fn put(&mut self, iter: u32, data: Rc<Vec<u8>>) {
        if let Some(e) = self.entries.iter_mut().find(|(i, _)| *i == iter) {
            e.1 = data;
            return;
        }
        if self.entries.len() < 2 {
            self.entries.push((iter, data));
        } else if iter > self.entries[0].0 {
            // newer than the oldest retained entry: displace it
            self.entries[0] = (iter, data);
        } else {
            return; // older than both retained checkpoints
        }
        if self.entries.len() == 2 && self.entries[0].0 > self.entries[1].0 {
            self.entries.swap(0, 1);
        }
    }

    fn get(&self, iter: u32) -> Option<Rc<Vec<u8>>> {
        self.entries
            .iter()
            .find(|(i, _)| *i == iter)
            .map(|(_, d)| Rc::clone(d))
    }

    fn latest(&self) -> Option<u32> {
        self.entries.last().map(|(i, _)| *i)
    }
}

struct Inner {
    /// Durable file checkpoints (parallel FS).
    file: HashMap<u32, Slot>,
    /// In-memory local copy, lives in the owner rank's memory.
    local: HashMap<u32, Slot>,
    /// Buddy copy of rank r's checkpoint, lives in rank (r+1)%n's memory.
    buddy: HashMap<u32, Slot>,
}

/// Shared checkpoint store for one experiment trial.
#[derive(Clone)]
pub struct CkptStore {
    sim: Sim,
    scheme: CkptKind,
    disk: SharedDisk,
    net: NetCost,
    mem_bytes_per_sec: f64,
    topo: Topology,
    inner: Rc<RefCell<Inner>>,
}

impl CkptStore {
    pub fn new(sim: &Sim, scheme: CkptKind, topo: Topology, calib: &Calibration) -> Self {
        CkptStore {
            sim: sim.clone(),
            scheme,
            disk: SharedDisk::from_calib(sim, calib),
            net: NetCost::from_calib(calib),
            mem_bytes_per_sec: calib.mem_bw_gbps * 1e9,
            topo,
            inner: Rc::new(RefCell::new(Inner {
                file: HashMap::new(),
                local: HashMap::new(),
                buddy: HashMap::new(),
            })),
        }
    }

    pub fn scheme(&self) -> CkptKind {
        self.scheme
    }

    fn buddy_of(&self, rank: u32) -> u32 {
        (rank + 1) % self.topo.ranks
    }

    fn memcpy_cost(&self, bytes: usize) -> SimDuration {
        SimDuration::from_secs_f64(bytes as f64 / self.mem_bytes_per_sec)
    }

    /// Store rank `rank`'s state for `iter`; awaits the (virtual) storage
    /// cost. `node` is the rank's current placement (buddy transfer cost).
    pub async fn save(&self, rank: u32, node: u32, iter: u32, data: Vec<u8>) {
        let data = Rc::new(data);
        match self.scheme {
            CkptKind::File => {
                self.disk.write(data.len() as u64).await;
                self.inner
                    .borrow_mut()
                    .file
                    .entry(rank)
                    .or_default()
                    .put(iter, data);
            }
            CkptKind::Memory => {
                let buddy = self.buddy_of(rank);
                let buddy_node = self.topo.home_node(buddy.min(self.topo.ranks - 1));
                // local memcpy, then push to buddy over the fabric
                self.sim.sleep(self.memcpy_cost(data.len())).await;
                self.sim
                    .sleep(self.net.data_delay(data.len(), buddy_node == node))
                    .await;
                let mut inner = self.inner.borrow_mut();
                inner
                    .local
                    .entry(rank)
                    .or_default()
                    .put(iter, Rc::clone(&data));
                inner.buddy.entry(rank).or_default().put(iter, data);
            }
        }
    }

    /// Newest iteration available for `rank` (after any losses).
    pub fn latest_iter(&self, rank: u32) -> Option<u32> {
        let inner = self.inner.borrow();
        match self.scheme {
            CkptKind::File => inner.file.get(&rank).and_then(Slot::latest),
            CkptKind::Memory => {
                let l = inner.local.get(&rank).and_then(Slot::latest);
                let b = inner.buddy.get(&rank).and_then(Slot::latest);
                l.max(b)
            }
        }
    }

    /// Load rank `rank`'s checkpoint of `iter`; awaits the retrieval cost.
    /// Returns None if lost (e.g. buddy died too). The payload is shared
    /// (`Rc`): the *virtual* copy cost is charged above, so the *host* pays
    /// no deep copy per load (see EXPERIMENTS.md §Perf).
    pub async fn load(&self, rank: u32, node: u32, iter: u32) -> Option<Rc<Vec<u8>>> {
        match self.scheme {
            CkptKind::File => {
                let data = self.inner.borrow().file.get(&rank)?.get(iter)?;
                self.disk.read(data.len() as u64).await;
                Some(data)
            }
            CkptKind::Memory => {
                // Prefer the local copy; fall back to the buddy's.
                let local = self.inner.borrow().local.get(&rank).and_then(|s| s.get(iter));
                if let Some(d) = local {
                    self.sim.sleep(self.memcpy_cost(d.len())).await;
                    return Some(d);
                }
                let buddy = self.inner.borrow().buddy.get(&rank).and_then(|s| s.get(iter));
                let d = buddy?;
                let bnode = self.topo.home_node(self.buddy_of(rank));
                self.sim
                    .sleep(self.net.data_delay(d.len(), bnode == node))
                    .await;
                Some(d)
            }
        }
    }

    /// Model the memory loss of a failed process: its local checkpoint and
    /// any buddy copy *hosted in its memory* are gone.
    pub fn lose_rank(&self, rank: u32) {
        let mut inner = self.inner.borrow_mut();
        inner.local.remove(&rank);
        // buddy copies of rank k live at (k+1)%n == rank  =>  k = rank-1
        let k = (rank + self.topo.ranks - 1) % self.topo.ranks;
        inner.buddy.remove(&k);
    }

    /// Memory loss of a whole node.
    pub fn lose_node_ranks(&self, ranks: &[u32]) {
        for &r in ranks {
            self.lose_rank(r);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::Cell;

    fn store(scheme: CkptKind, ranks: u32) -> (Sim, CkptStore) {
        let sim = Sim::new();
        let topo = Topology::new(ranks, 16, 0);
        let s = CkptStore::new(&sim, scheme, topo, &Calibration::default());
        (sim, s)
    }

    fn block_on_save(sim: &Sim, s: &CkptStore, rank: u32, iter: u32, data: Vec<u8>) {
        let p = sim.spawn_process("saver");
        let s2 = s.clone();
        sim.spawn(p, async move {
            s2.save(rank, 0, iter, data).await;
        });
        sim.run();
    }

    fn block_on_load(sim: &Sim, s: &CkptStore, rank: u32, iter: u32) -> Option<Vec<u8>> {
        let p = sim.spawn_process("loader");
        let s2 = s.clone();
        let out = Rc::new(RefCell::new(None));
        let o2 = Rc::clone(&out);
        sim.spawn(p, async move {
            // unwrap the shared payload so assertions compare plain bytes
            let loaded = s2.load(rank, 0, iter).await.map(|d| d.as_ref().clone());
            *o2.borrow_mut() = Some(loaded);
        });
        sim.run();
        Rc::try_unwrap(out).ok().unwrap().into_inner().unwrap()
    }

    #[test]
    fn file_save_load_roundtrip() {
        let (sim, s) = store(CkptKind::File, 4);
        block_on_save(&sim, &s, 2, 5, vec![1, 2, 3]);
        assert_eq!(s.latest_iter(2), Some(5));
        assert_eq!(block_on_load(&sim, &s, 2, 5), Some(vec![1, 2, 3]));
    }

    #[test]
    fn memory_save_load_roundtrip() {
        let (sim, s) = store(CkptKind::Memory, 4);
        block_on_save(&sim, &s, 2, 5, vec![9; 100]);
        assert_eq!(block_on_load(&sim, &s, 2, 5), Some(vec![9; 100]));
    }

    #[test]
    fn memory_survives_process_failure_via_buddy() {
        let (sim, s) = store(CkptKind::Memory, 4);
        block_on_save(&sim, &s, 2, 7, vec![42; 10]);
        s.lose_rank(2); // local copy gone
        assert_eq!(s.latest_iter(2), Some(7), "buddy copy at rank 3 survives");
        assert_eq!(block_on_load(&sim, &s, 2, 7), Some(vec![42; 10]));
    }

    #[test]
    fn buddy_hosted_copies_die_with_host() {
        let (sim, s) = store(CkptKind::Memory, 4);
        block_on_save(&sim, &s, 1, 3, vec![1]);
        block_on_save(&sim, &s, 2, 3, vec![2]);
        // rank 2's memory hosts: local[2] and buddy copy of rank 1
        s.lose_rank(2);
        // rank 1 still has its local copy
        assert_eq!(block_on_load(&sim, &s, 1, 3), Some(vec![1]));
        // but if rank 1 then ALSO fails, its buddy copy was at rank 2: gone
        s.lose_rank(1);
        assert_eq!(s.latest_iter(1), None);
        assert_eq!(block_on_load(&sim, &s, 1, 3), None);
    }

    #[test]
    fn node_failure_wipes_local_and_buddy_pairs() {
        // paper Table 2's reason: ranks 0 and 1 on the same node are each
        // other's local/buddy chain; losing both loses rank 0 entirely.
        let sim = Sim::new();
        let topo = Topology::new(4, 2, 0); // 2 ranks/node
        let s = CkptStore::new(&sim, CkptKind::Memory, topo, &Calibration::default());
        block_on_save(&sim, &s, 0, 1, vec![7]);
        s.lose_node_ranks(&[0, 1]);
        assert_eq!(s.latest_iter(0), None, "local at 0 and buddy at 1 both dead");
    }

    #[test]
    fn keeps_last_two_iterations_only() {
        let (sim, s) = store(CkptKind::File, 2);
        for it in 1..=4 {
            block_on_save(&sim, &s, 0, it, vec![it as u8]);
        }
        assert_eq!(s.latest_iter(0), Some(4));
        assert_eq!(block_on_load(&sim, &s, 0, 3), Some(vec![3]));
        assert_eq!(block_on_load(&sim, &s, 0, 2), None, "evicted");
    }

    #[test]
    fn file_write_cost_exceeds_memory_cost() {
        // same payload: file pays metadata + contended disk; memory pays
        // memcpy + one fabric hop. This gap is the whole Fig. 4 story.
        let t_file = {
            let (sim, s) = store(CkptKind::File, 4);
            let t = Rc::new(Cell::new(0.0));
            let (s2, t2, sim2) = (s.clone(), Rc::clone(&t), sim.clone());
            let p = sim.spawn_process("w");
            sim.spawn(p, async move {
                let start = sim2.now();
                s2.save(0, 0, 1, vec![0; 1 << 20]).await;
                t2.set((sim2.now() - start).secs_f64());
            });
            sim.run();
            t.get()
        };
        let t_mem = {
            let (sim, s) = store(CkptKind::Memory, 4);
            let t = Rc::new(Cell::new(0.0));
            let (s2, t2, sim2) = (s.clone(), Rc::clone(&t), sim.clone());
            let p = sim.spawn_process("w");
            sim.spawn(p, async move {
                let start = sim2.now();
                s2.save(0, 0, 1, vec![0; 1 << 20]).await;
                t2.set((sim2.now() - start).secs_f64());
            });
            sim.run();
            t.get()
        };
        assert!(t_file > 5.0 * t_mem, "file={t_file} mem={t_mem}");
    }

    use std::cell::RefCell;
    use std::rc::Rc;
}
