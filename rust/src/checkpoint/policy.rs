//! The paper's Table 2: which checkpoint storage each recovery approach uses
//! for each failure type.
//!
//! | failure  | CR   | ULFM   | Reinit++ |
//! |----------|------|--------|----------|
//! | process  | file | memory | memory   |
//! | node     | file | file   | file     |
//!
//! CR always needs permanent storage (the job is re-deployed, local memory
//! is gone). The paper's memory scheme only survives single-process
//! failures because its cyclic buddy could share the owner's node; the tier
//! stacks in [`crate::ckptstore`] generalize this — `default_stack` maps
//! Table 2 onto them (`file` → `fs`, `memory` → `local+partner1` with
//! node-disjoint placement), and explicit `ckpt_tiers` configs can go
//! beyond the paper (deeper stacks, more replicas, async drain).

use crate::ckptstore::StackSpec;
use crate::config::{CkptKind, FailureKind, RecoveryKind};

/// Default scheme per the paper's Table 2. Fault-free runs keep the scheme
/// they would use under a process failure (checkpoints are written either
/// way; the paper's Fig. 4 breakdown needs the write cost).
pub fn default_scheme(recovery: RecoveryKind, failure: FailureKind) -> CkptKind {
    match (recovery, failure) {
        (RecoveryKind::Cr, _) => CkptKind::File,
        // Replication's checkpoints only matter once the replica group is
        // exhausted and the job degrades to a CR-style redeploy — at which
        // point every in-memory tier is gone, so only permanent storage
        // helps (PartRePer-MPI pairs replication with file checkpoints the
        // same way).
        (RecoveryKind::Replication, _) => CkptKind::File,
        (_, FailureKind::Node) => CkptKind::File,
        // Shrink follows the Reinit++ row: in-memory copies for process
        // failures (ReStore's fast path — they get redistributed over the
        // survivors), file once whole nodes die. A node-disjoint partner
        // tier would actually survive shrink's in-place node loss too, but
        // the Table-2 default stays conservative; opt in via `ckpt_tiers`.
        (RecoveryKind::Ulfm | RecoveryKind::Reinit | RecoveryKind::Shrink, _) => CkptKind::Memory,
    }
}

/// Table 2 as a tier stack — the route every recovery path takes when no
/// explicit `ckpt_tiers` override is configured.
pub fn default_stack(recovery: RecoveryKind, failure: FailureKind) -> StackSpec {
    StackSpec::from_kind(default_scheme(recovery, failure))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_matrix() {
        use CkptKind::*;
        use FailureKind::*;
        use RecoveryKind::*;
        assert_eq!(default_scheme(Cr, Process), File);
        assert_eq!(default_scheme(Ulfm, Process), Memory);
        assert_eq!(default_scheme(Reinit, Process), Memory);
        assert_eq!(default_scheme(Cr, Node), File);
        assert_eq!(default_scheme(Ulfm, Node), File);
        assert_eq!(default_scheme(Reinit, Node), File);
        // replication: checkpoints exist for the degraded-redeploy fallback,
        // which loses all memory — file either way
        assert_eq!(default_scheme(Replication, Process), File);
        assert_eq!(default_scheme(Replication, Node), File);
        // shrink rides the Reinit++ row
        assert_eq!(default_scheme(Shrink, Process), Memory);
        assert_eq!(default_scheme(Shrink, Node), File);
    }

    #[test]
    fn table2_stacks() {
        use FailureKind::*;
        use RecoveryKind::*;
        assert_eq!(default_stack(Cr, Process).to_string(), "fs");
        assert_eq!(default_stack(Reinit, Process).to_string(), "local+partner1");
        assert_eq!(default_stack(Reinit, Node).to_string(), "fs");
    }

    #[test]
    fn fault_free_uses_process_column() {
        assert_eq!(
            default_scheme(RecoveryKind::Reinit, FailureKind::None),
            CkptKind::Memory
        );
        assert_eq!(
            default_scheme(RecoveryKind::Cr, FailureKind::None),
            CkptKind::File
        );
    }
}
