//! The paper's Table 2: which checkpoint storage each recovery approach uses
//! for each failure type.
//!
//! | failure  | CR   | ULFM   | Reinit++ |
//! |----------|------|--------|----------|
//! | process  | file | memory | memory   |
//! | node     | file | file   | file     |
//!
//! CR always needs permanent storage (the job is re-deployed, local memory
//! is gone). Memory/buddy checkpoints only survive single-process failures:
//! a node failure can wipe both the local and the buddy copy.

use crate::config::{CkptKind, FailureKind, RecoveryKind};

/// Default scheme per the paper's Table 2. Fault-free runs keep the scheme
/// they would use under a process failure (checkpoints are written either
/// way; the paper's Fig. 4 breakdown needs the write cost).
pub fn default_scheme(recovery: RecoveryKind, failure: FailureKind) -> CkptKind {
    match (recovery, failure) {
        (RecoveryKind::Cr, _) => CkptKind::File,
        (_, FailureKind::Node) => CkptKind::File,
        (RecoveryKind::Ulfm | RecoveryKind::Reinit, _) => CkptKind::Memory,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_matrix() {
        use CkptKind::*;
        use FailureKind::*;
        use RecoveryKind::*;
        assert_eq!(default_scheme(Cr, Process), File);
        assert_eq!(default_scheme(Ulfm, Process), Memory);
        assert_eq!(default_scheme(Reinit, Process), Memory);
        assert_eq!(default_scheme(Cr, Node), File);
        assert_eq!(default_scheme(Ulfm, Node), File);
        assert_eq!(default_scheme(Reinit, Node), File);
    }

    #[test]
    fn fault_free_uses_process_column() {
        assert_eq!(
            default_scheme(RecoveryKind::Reinit, FailureKind::None),
            CkptKind::Memory
        );
        assert_eq!(
            default_scheme(RecoveryKind::Cr, FailureKind::None),
            CkptKind::File
        );
    }
}
