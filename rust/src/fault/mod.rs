//! Fault injection (paper §4 "Emulating failures").
//!
//! A single process or node failure per run, at a seeded-random iteration of
//! the main loop and a seeded-random victim rank. The draw depends only on
//! `(seed, trial)` — *not* on the recovery approach — so CR, ULFM and
//! Reinit++ face the identical failure, as in the paper's methodology.

use std::cell::Cell;
use std::rc::Rc;

use crate::config::{ExperimentConfig, FailureKind};
use crate::sim::rng::Rng;

/// The failure one trial will inject.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultPlan {
    pub kind: FailureKind,
    /// Main-loop iteration (0-based) at whose start the victim dies.
    pub iteration: u32,
    /// Victim rank. For node failures the victim's *node* dies (the rank
    /// SIGKILLs its parent daemon, per the paper).
    pub rank: u32,
}

impl FaultPlan {
    /// Draw the failure for `(cfg.seed, trial)`.
    pub fn draw(cfg: &ExperimentConfig, trial: u32) -> FaultPlan {
        let mut rng = Rng::new(cfg.seed)
            .fork("fault-injection")
            .fork(&format!("trial{trial}"));
        // Iteration in [1, iters-1): at least one checkpoint exists and the
        // failure lands strictly inside the run.
        let span = cfg.iters.saturating_sub(2).max(1);
        let iteration = 1 + (rng.gen_range(span as u64) as u32);
        let rank = rng.gen_range(cfg.ranks as u64) as u32;
        FaultPlan {
            kind: cfg.failure,
            iteration,
            rank,
        }
    }

    pub fn none() -> FaultPlan {
        FaultPlan {
            kind: FailureKind::None,
            iteration: u32::MAX,
            rank: u32::MAX,
        }
    }
}

/// One-shot trigger shared by all rank tasks of a trial: fires at most once
/// even though the victim's iteration is re-executed after recovery.
#[derive(Clone)]
pub struct FaultTrigger {
    plan: FaultPlan,
    fired: Rc<Cell<bool>>,
}

impl FaultTrigger {
    pub fn new(plan: FaultPlan) -> Self {
        FaultTrigger {
            plan,
            fired: Rc::new(Cell::new(false)),
        }
    }

    pub fn plan(&self) -> FaultPlan {
        self.plan
    }

    /// Should `rank` die at the start of `iteration`? Consumes the trigger
    /// on the first true.
    pub fn should_fire(&self, rank: u32, iteration: u32) -> bool {
        if self.fired.get() || self.plan.kind == FailureKind::None {
            return false;
        }
        if rank == self.plan.rank && iteration == self.plan.iteration {
            self.fired.set(true);
            return true;
        }
        false
    }

    pub fn has_fired(&self) -> bool {
        self.fired.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RecoveryKind;

    fn cfg(seed: u64) -> ExperimentConfig {
        let mut c = ExperimentConfig::default();
        c.seed = seed;
        c.ranks = 64;
        c.iters = 20;
        c
    }

    #[test]
    fn draw_is_deterministic_and_recovery_independent() {
        let mut a = cfg(7);
        a.recovery = RecoveryKind::Cr;
        let mut b = cfg(7);
        b.recovery = RecoveryKind::Reinit;
        assert_eq!(FaultPlan::draw(&a, 0), FaultPlan::draw(&b, 0));
    }

    #[test]
    fn trials_differ() {
        let c = cfg(7);
        let p0 = FaultPlan::draw(&c, 0);
        let p1 = FaultPlan::draw(&c, 1);
        assert!(p0 != p1, "different trials draw different failures");
    }

    #[test]
    fn iteration_in_valid_window() {
        let c = cfg(3);
        for trial in 0..50 {
            let p = FaultPlan::draw(&c, trial);
            assert!(p.iteration >= 1 && p.iteration < c.iters - 1, "{p:?}");
            assert!(p.rank < c.ranks);
        }
    }

    #[test]
    fn rank_coverage_over_trials() {
        let c = cfg(11);
        let mut hit = std::collections::HashSet::new();
        for trial in 0..300 {
            hit.insert(FaultPlan::draw(&c, trial).rank);
        }
        assert!(hit.len() > 32, "injection spreads across ranks: {}", hit.len());
    }

    #[test]
    fn trigger_fires_exactly_once() {
        let t = FaultTrigger::new(FaultPlan {
            kind: FailureKind::Process,
            iteration: 3,
            rank: 5,
        });
        assert!(!t.should_fire(5, 2));
        assert!(!t.should_fire(4, 3));
        assert!(t.should_fire(5, 3));
        assert!(t.has_fired());
        // re-execution of iteration 3 after recovery must not re-kill
        assert!(!t.should_fire(5, 3));
    }

    #[test]
    fn none_plan_never_fires() {
        let t = FaultTrigger::new(FaultPlan::none());
        for i in 0..10 {
            assert!(!t.should_fire(i, i));
        }
    }
}
