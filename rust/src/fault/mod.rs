//! Fault injection (paper §4 "Emulating failures"), generalized to
//! multi-failure *scenarios*.
//!
//! The paper injects exactly one process or node failure per run, at a
//! seeded-random iteration and victim. This module keeps that mode
//! bit-compatible (same RNG stream, same draw order) and generalizes it to
//! a **failure timeline**: an ordered sequence of `FaultEvent`s, each
//! anchored either at a main-loop *iteration* (fires at the start of that
//! iteration, exactly like the paper's model) or at a *virtual time*
//! (fires whenever the clock reaches it — including inside a recovery or
//! checkpoint window, which is where ReStore-style repeated-failure
//! scenarios become interesting).
//!
//! Timelines come from one of three sources, in priority order:
//! 1. an explicit scenario (`failures=proc@3:r5,node@7:r12,proc@t1.25:r3`),
//! 2. an MTBF arrival process (`mtbf_s=4` — exponential inter-arrival over
//!    virtual time, victims uniform, kind = `failure=`), or
//! 3. the paper's single seeded draw (`failure=process|node`).
//!
//! Every draw depends only on `(seed, trial)` — *not* on the recovery
//! approach — so CR, ULFM and Reinit++ face identical failure sequences,
//! as in the paper's methodology.

use std::cell::RefCell;
use std::rc::Rc;

use crate::config::{ExperimentConfig, FailureKind};
use crate::sim::rng::Rng;

/// Where on the trial's axis a fault event fires.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultAnchor {
    /// Start of this main-loop iteration (0-based), the paper's model.
    /// Tolerates rollback: a re-executed iteration does not re-fire.
    Iteration(u32),
    /// Virtual time in seconds *after application start* (the paper times
    /// the application, not the mpirun submission) — may land mid-recovery,
    /// mid-checkpoint, or during a CR re-deploy (then it hits dead air).
    Time(f64),
}

/// One planned failure (or checkpoint-corruption event).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultEvent {
    pub kind: FailureKind,
    pub anchor: FaultAnchor,
    /// Victim rank. For node failures the node *currently hosting* this
    /// rank dies (the rank SIGKILLs its parent daemon, per the paper).
    pub rank: u32,
    /// `corrupt@` event: nothing dies — instead every stored copy of the
    /// victim rank's newest checkpoint generation is silently corrupted
    /// (detected only by verify-on-load). `kind` is `None` for these.
    pub corrupt: bool,
}

impl FaultEvent {
    /// Parse one scenario token: `proc@3:r5` (iteration-anchored process
    /// failure of rank 5 at iteration 3), `node@7:r12`, `proc@t1.25:r3`
    /// (virtual-time-anchored at 1.25 s), `corrupt@4:r2` (silent corruption
    /// of rank 2's newest checkpoint at iteration 4).
    pub fn parse(tok: &str) -> Result<FaultEvent, String> {
        let err = |m: &str| format!("failure event `{tok}`: {m} (expected kind@anchor:rN, e.g. proc@3:r5 or node@t1.25:r12)");
        let (kind_s, rest) = tok.split_once('@').ok_or_else(|| err("missing `@`"))?;
        let (kind, corrupt) = match kind_s.to_ascii_lowercase().as_str() {
            "proc" | "process" => (FailureKind::Process, false),
            "node" => (FailureKind::Node, false),
            "corrupt" => (FailureKind::None, true),
            _ => return Err(err("kind must be one of proc, process, node, corrupt")),
        };
        let (at_s, rank_s) = rest.split_once(':').ok_or_else(|| err("missing `:rN` victim"))?;
        let anchor = if let Some(t) = at_s.strip_prefix('t') {
            let secs: f64 = t.parse().map_err(|_| err("bad virtual-time anchor"))?;
            if !(secs > 0.0 && secs.is_finite()) {
                return Err(err("time anchor must be finite and > 0"));
            }
            FaultAnchor::Time(secs)
        } else {
            FaultAnchor::Iteration(at_s.parse().map_err(|_| err("bad iteration anchor"))?)
        };
        let rank: u32 = rank_s
            .strip_prefix('r')
            .ok_or_else(|| err("victim must be rN"))?
            .parse()
            .map_err(|_| err("bad victim rank"))?;
        Ok(FaultEvent {
            kind,
            anchor,
            rank,
            corrupt,
        })
    }
}

impl std::fmt::Display for FaultEvent {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let kind = if self.corrupt {
            "corrupt"
        } else {
            match self.kind {
                FailureKind::Process => "proc",
                FailureKind::Node => "node",
                FailureKind::None => "none",
            }
        };
        match self.anchor {
            FaultAnchor::Iteration(i) => write!(f, "{kind}@{i}:r{}", self.rank),
            FaultAnchor::Time(t) => write!(f, "{kind}@t{t}:r{}", self.rank),
        }
    }
}

/// Parse a comma-separated scenario list; empty or `none` clears.
pub fn parse_failures(s: &str) -> Result<Vec<FaultEvent>, String> {
    let s = s.trim();
    if s.is_empty() || s.eq_ignore_ascii_case("none") {
        return Ok(Vec::new());
    }
    s.split(',').map(|tok| FaultEvent::parse(tok.trim())).collect()
}

/// The ordered failure plan of one trial.
#[derive(Clone, Debug, Default)]
pub struct FaultTimeline {
    pub events: Vec<FaultEvent>,
}

impl FaultTimeline {
    /// Build the timeline for `(cfg.seed, trial)`. Deterministic and
    /// independent of `cfg.recovery` (asserted by tests).
    pub fn plan(cfg: &ExperimentConfig, trial: u32) -> FaultTimeline {
        if !cfg.failures.is_empty() {
            return FaultTimeline {
                events: cfg.failures.clone(),
            };
        }
        if cfg.mtbf_s > 0.0 {
            return Self::plan_mtbf(cfg, trial);
        }
        if cfg.failure == FailureKind::None {
            return FaultTimeline::default();
        }
        // The paper's single-shot mode: one seeded (iteration, rank) draw.
        // Stream and draw order are bit-compatible with the original
        // `FaultPlan::draw`, so single-failure experiments replay exactly.
        let mut rng = fault_rng(cfg.seed, trial);
        // Iteration in [1, iters-1): at least one checkpoint exists and the
        // failure lands strictly inside the run. Well-formed only for
        // iters >= 3 — smaller values are rejected by config validation
        // (the seed's `.max(1)` clamp silently drew iteration == iters-1
        // at iters == 2).
        assert!(
            cfg.iters >= 3,
            "failure injection needs iters >= 3 (enforced by config validation)"
        );
        let span = (cfg.iters - 2) as u64;
        let iteration = 1 + (rng.gen_range(span) as u32);
        let rank = rng.gen_range(cfg.ranks as u64) as u32;
        FaultTimeline {
            events: vec![FaultEvent {
                kind: cfg.failure,
                anchor: FaultAnchor::Iteration(iteration),
                rank,
                corrupt: false,
            }],
        }
    }

    /// MTBF arrival process: exponential inter-arrival times over virtual
    /// time with mean `mtbf_s`, up to `max_failures` events; victims are
    /// uniform over ranks, kind is `cfg.failure`. Events past the job's end
    /// simply never fire (the job released the allocation).
    fn plan_mtbf(cfg: &ExperimentConfig, trial: u32) -> FaultTimeline {
        let mut rng = fault_rng(cfg.seed, trial);
        let mut t = 0.0f64;
        let mut events = Vec::with_capacity(cfg.max_failures as usize);
        for _ in 0..cfg.max_failures {
            // inverse-CDF draw; clamp keeps two arrivals from colliding on
            // the exact same instant when u ~ 0
            let u = rng.gen_f64();
            t += (cfg.mtbf_s * -(1.0 - u).ln()).max(1e-6);
            let rank = rng.gen_range(cfg.ranks as u64) as u32;
            events.push(FaultEvent {
                kind: cfg.failure,
                anchor: FaultAnchor::Time(t),
                rank,
                corrupt: false,
            });
        }
        FaultTimeline { events }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }
}

/// The failure-injection RNG stream for `(seed, trial)` — forked by label,
/// so it is stable under code reordering and shared by all draw modes.
fn fault_rng(seed: u64, trial: u32) -> Rng {
    Rng::new(seed)
        .fork("fault-injection")
        .fork(&format!("trial{trial}"))
}

/// What became of one planned event after the trial ran.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultOutcome {
    pub event: FaultEvent,
    /// The event killed a live victim.
    pub fired: bool,
    /// The event's instant arrived but hit dead air: victim already dead,
    /// the job between deployments, or the job already complete.
    pub noop: bool,
}

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
enum FireState {
    #[default]
    Unfired,
    Fired,
    Noop,
}

/// Shared firing state over a trial's timeline. One cursor is cloned into
/// every rank task (iteration-anchored events fire from the main loop,
/// exactly once each, tolerating post-rollback re-execution) and into the
/// scheduled virtual-time killers.
#[derive(Clone)]
pub struct TimelineCursor {
    events: Rc<Vec<FaultEvent>>,
    state: Rc<RefCell<Vec<FireState>>>,
}

impl TimelineCursor {
    pub fn new(timeline: FaultTimeline) -> TimelineCursor {
        let n = timeline.events.len();
        TimelineCursor {
            events: Rc::new(timeline.events),
            state: Rc::new(RefCell::new(vec![FireState::Unfired; n])),
        }
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn event(&self, idx: usize) -> FaultEvent {
        self.events[idx]
    }

    /// `(index, seconds)` of every virtual-time-anchored event; the trial
    /// driver schedules each exactly once at trial start.
    pub fn time_schedule(&self) -> Vec<(usize, f64)> {
        self.events
            .iter()
            .enumerate()
            .filter_map(|(i, e)| match e.anchor {
                FaultAnchor::Time(t) => Some((i, t)),
                FaultAnchor::Iteration(_) => None,
            })
            .collect()
    }

    /// Should `rank` die at the start of `iteration`? Consumes the matching
    /// event on the first true: a re-executed iteration after rollback (or a
    /// CR re-deploy) does not re-kill. Events are matched independently of
    /// list order so interleaved rollbacks cannot starve a later event.
    pub fn should_fire(&self, rank: u32, iteration: u32) -> Option<FaultEvent> {
        if self.events.is_empty() {
            return None;
        }
        let mut state = self.state.borrow_mut();
        for (i, ev) in self.events.iter().enumerate() {
            if state[i] != FireState::Unfired {
                continue;
            }
            if let FaultAnchor::Iteration(it) = ev.anchor {
                if it == iteration && ev.rank == rank {
                    state[i] = FireState::Fired;
                    return Some(*ev);
                }
            }
        }
        None
    }

    pub fn mark_fired(&self, idx: usize) {
        self.state.borrow_mut()[idx] = FireState::Fired;
    }

    pub fn mark_noop(&self, idx: usize) {
        self.state.borrow_mut()[idx] = FireState::Noop;
    }

    /// Did any event actually kill something yet? (Gates rollback-path
    /// behaviour in the rank driver: resume accounting, replica rebuild.)
    pub fn any_fired(&self) -> bool {
        self.state.borrow().iter().any(|&s| s == FireState::Fired)
    }

    pub fn fired_count(&self) -> u32 {
        self.state
            .borrow()
            .iter()
            .filter(|&&s| s == FireState::Fired)
            .count() as u32
    }

    pub fn outcomes(&self) -> Vec<FaultOutcome> {
        let state = self.state.borrow();
        self.events
            .iter()
            .zip(state.iter())
            .map(|(e, s)| FaultOutcome {
                event: *e,
                fired: *s == FireState::Fired,
                noop: *s == FireState::Noop,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RecoveryKind;

    fn cfg(seed: u64) -> ExperimentConfig {
        let mut c = ExperimentConfig::default();
        c.seed = seed;
        c.ranks = 64;
        c.iters = 20;
        c
    }

    fn single(t: &FaultTimeline) -> FaultEvent {
        assert_eq!(t.events.len(), 1);
        t.events[0]
    }

    #[test]
    fn single_draw_is_deterministic_and_recovery_independent() {
        let mut a = cfg(7);
        a.recovery = RecoveryKind::Cr;
        let mut b = cfg(7);
        b.recovery = RecoveryKind::Reinit;
        assert_eq!(
            single(&FaultTimeline::plan(&a, 0)),
            single(&FaultTimeline::plan(&b, 0))
        );
    }

    #[test]
    fn trials_differ() {
        let c = cfg(7);
        assert_ne!(
            single(&FaultTimeline::plan(&c, 0)),
            single(&FaultTimeline::plan(&c, 1)),
            "different trials draw different failures"
        );
    }

    #[test]
    fn single_draw_iteration_in_valid_window() {
        let c = cfg(3);
        for trial in 0..50 {
            let e = single(&FaultTimeline::plan(&c, trial));
            let FaultAnchor::Iteration(it) = e.anchor else {
                panic!("single mode is iteration-anchored");
            };
            assert!(it >= 1 && it < c.iters - 1, "{e:?}");
            assert!(e.rank < c.ranks);
        }
    }

    #[test]
    fn single_draw_window_holds_at_minimum_iters() {
        // Satellite regression: the seed's `.max(1)` clamp made iters=2 draw
        // iteration 1 == iters-1, outside [1, iters-1). iters < 3 is now a
        // config-validation error; at the iters=3 minimum the window is the
        // singleton {1}.
        let mut c = cfg(5);
        c.iters = 3;
        for trial in 0..20 {
            let e = single(&FaultTimeline::plan(&c, trial));
            assert_eq!(e.anchor, FaultAnchor::Iteration(1));
        }
    }

    #[test]
    #[should_panic(expected = "iters >= 3")]
    fn single_draw_rejects_tiny_iters() {
        let mut c = cfg(5);
        c.iters = 2;
        let _ = FaultTimeline::plan(&c, 0);
    }

    #[test]
    fn rank_coverage_over_trials() {
        let c = cfg(11);
        let mut hit = std::collections::HashSet::new();
        for trial in 0..300 {
            hit.insert(single(&FaultTimeline::plan(&c, trial)).rank);
        }
        assert!(hit.len() > 32, "injection spreads across ranks: {}", hit.len());
    }

    #[test]
    fn none_plan_is_empty() {
        let mut c = cfg(1);
        c.failure = FailureKind::None;
        assert!(FaultTimeline::plan(&c, 0).is_empty());
        let t = TimelineCursor::new(FaultTimeline::plan(&c, 0));
        for i in 0..10 {
            assert!(t.should_fire(i, i).is_none());
        }
        assert!(!t.any_fired());
    }

    #[test]
    fn event_parse_display_roundtrip() {
        // every kind, both anchors
        for s in [
            "proc@3:r5",
            "node@7:r12",
            "proc@t1.25:r3",
            "node@t0.5:r0",
            "corrupt@4:r2",
            "corrupt@t2.5:r9",
        ] {
            let e = FaultEvent::parse(s).unwrap();
            assert_eq!(e.to_string(), s);
        }
        assert_eq!(
            FaultEvent::parse("process@2:r1").unwrap().kind,
            FailureKind::Process
        );
        let c = FaultEvent::parse("corrupt@4:r2").unwrap();
        assert!(c.corrupt);
        assert_eq!(c.kind, FailureKind::None, "nothing dies on corruption");
        for bad in [
            "proc3:r5",     // no @
            "proc@3",       // no victim
            "proc@3:5",     // victim missing r
            "warp@3:r5",    // unknown kind
            "proc@t-1:r5",  // negative time
            "proc@tx:r5",   // unparsable time
            "proc@:r5",     // empty anchor
            "none@3:r5",    // kind none is not injectable
        ] {
            assert!(FaultEvent::parse(bad).is_err(), "{bad} must not parse");
        }
        // the unknown-kind error enumerates what IS valid
        let msg = FaultEvent::parse("warp@3:r5").unwrap_err();
        assert!(
            msg.contains("proc, process, node, corrupt"),
            "error must enumerate valid kinds: {msg}"
        );
    }

    #[test]
    fn parse_failures_list_and_clear() {
        let v = parse_failures("proc@3:r5, node@7:r12,proc@t1.5:r0").unwrap();
        assert_eq!(v.len(), 3);
        assert_eq!(v[1].kind, FailureKind::Node);
        assert_eq!(v[2].anchor, FaultAnchor::Time(1.5));
        assert!(parse_failures("none").unwrap().is_empty());
        assert!(parse_failures("").unwrap().is_empty());
        assert!(parse_failures("proc@3:r5,bogus").is_err());
    }

    #[test]
    fn explicit_scenario_overrides_single_mode() {
        let mut c = cfg(9);
        c.failures = parse_failures("proc@2:r1,node@5:r6").unwrap();
        let t = FaultTimeline::plan(&c, 0);
        assert_eq!(t.events, c.failures);
        // identical for every trial (explicit scenarios are not re-drawn)
        assert_eq!(FaultTimeline::plan(&c, 3).events, c.failures);
    }

    #[test]
    fn mtbf_draw_is_deterministic_and_recovery_independent() {
        let mut a = cfg(13);
        a.mtbf_s = 2.5;
        a.max_failures = 5;
        a.recovery = RecoveryKind::Cr;
        let mut b = a.clone();
        b.recovery = RecoveryKind::Ulfm;
        let ta = FaultTimeline::plan(&a, 2);
        let tb = FaultTimeline::plan(&b, 2);
        assert_eq!(ta.events, tb.events, "MTBF draw must ignore recovery");
        assert_eq!(ta.len(), 5);
        // arrivals strictly increase and victims are in range
        let mut prev = 0.0;
        for e in &ta.events {
            let FaultAnchor::Time(t) = e.anchor else {
                panic!("MTBF events are time-anchored");
            };
            assert!(t > prev, "arrivals must strictly increase");
            prev = t;
            assert!(e.rank < a.ranks);
            assert_eq!(e.kind, a.failure);
        }
        // different trials draw different storms
        assert_ne!(FaultTimeline::plan(&a, 0).events, ta.events);
    }

    #[test]
    fn mtbf_mean_roughly_matches() {
        let mut c = cfg(21);
        c.mtbf_s = 3.0;
        c.max_failures = 40;
        let mut total = 0.0;
        let trials = 200;
        for trial in 0..trials {
            let t = FaultTimeline::plan(&c, trial);
            let FaultAnchor::Time(last) = t.events.last().unwrap().anchor else {
                unreachable!()
            };
            total += last / c.max_failures as f64;
        }
        let mean = total / trials as f64;
        assert!(
            (mean - 3.0).abs() < 0.3,
            "mean inter-arrival ≈ mtbf_s: {mean}"
        );
    }

    #[test]
    fn cursor_fires_each_event_once_tolerating_reexecution() {
        let t = TimelineCursor::new(FaultTimeline {
            events: parse_failures("proc@3:r5,proc@4:r2").unwrap(),
        });
        assert!(t.should_fire(5, 2).is_none());
        assert!(t.should_fire(4, 3).is_none());
        assert!(t.should_fire(5, 3).is_some());
        assert!(t.any_fired());
        // rollback re-executes iteration 3: no re-kill
        assert!(t.should_fire(5, 3).is_none());
        // second event fires when its (rank, iteration) comes around
        assert!(t.should_fire(2, 4).is_some());
        assert!(t.should_fire(2, 4).is_none());
        assert_eq!(t.fired_count(), 2);
        let outs = t.outcomes();
        assert!(outs.iter().all(|o| o.fired && !o.noop));
    }

    #[test]
    fn cursor_time_schedule_and_noop_accounting() {
        let t = TimelineCursor::new(FaultTimeline {
            events: parse_failures("proc@t0.5:r1,proc@2:r0,node@t2.5:r3").unwrap(),
        });
        assert_eq!(t.time_schedule(), vec![(0, 0.5), (2, 2.5)]);
        t.mark_fired(0);
        t.mark_noop(2);
        assert_eq!(t.fired_count(), 1);
        let outs = t.outcomes();
        assert!(outs[0].fired && !outs[0].noop);
        assert!(!outs[2].fired && outs[2].noop);
        assert!(!outs[1].fired && !outs[1].noop, "iteration event untouched");
    }
}
