//! Checkpoint-Restart (paper §2.2, the baseline): the standard practice of
//! aborting on failure and immediately re-submitting the job.
//!
//! Ranks get no fault notification; on the first detection event the root
//! (mpirun) aborts the whole job — every daemon and MPI process is killed.
//! After RTE teardown the shared trial loop (`job::trial_driver`) re-deploys
//! from scratch (full `mpirun` launch), and the fresh ranks resume from the
//! newest file checkpoint on the parallel filesystem. The re-deployment
//! overhead even for a single process failure is exactly what the paper's
//! Fig. 6 shows as CR's ≈3 s — and under a failure *storm* CR pays it once
//! per event, which is what `reinitpp storm` measures.
//!
//! CR is also the *escalation target* of the imperfect-world model: when
//! verify-on-load exhausts every intact checkpoint generation, every family
//! (this one included) restarts from iteration 0 through the same abort +
//! re-deploy path, booked as a `degraded_redeploy` escalation — see
//! `job::rank_user_main` and EXPERIMENTS.md §Checkpoint integrity.

use super::job::{abort_job, JobCtx, RecoveryDriver, ReinitState};
use super::reinit::spawn_rank;
use crate::config::FailureKind;
use crate::detect::DetectEvent;
use crate::sim::{Receiver, SimDuration};

/// Root behaviour under CR: first failure event => abort everything. A
/// second failure landing during the abort/teardown window hits already-dead
/// processes (no-op); one landing after the re-deploy is detected by the
/// fresh deployment's own root.
async fn cr_root(ctx: JobCtx, detect_rx: Receiver<DetectEvent>) {
    let Ok(ev) = detect_rx.recv().await else {
        return;
    };
    let kind = match ev {
        DetectEvent::RankDead { .. } => FailureKind::Process,
        DetectEvent::NodeDead { .. } => FailureKind::Node,
    };
    ctx.world.metrics.record_detect(ctx.world.sim.now(), kind);
    ctx.world.trace_mark("detect");
    abort_job(&ctx);
}

/// CR hosted on the shared trial loop: spawn plain ranks and a root that
/// aborts on the first detection.
pub struct CrDriver;

impl RecoveryDriver for CrDriver {
    fn tag(&self) -> &'static str {
        "cr"
    }

    fn deploy(&self, ctx: &JobCtx, detect_rx: Receiver<DetectEvent>) {
        let w = &ctx.world;
        for rank in 0..w.cfg.ranks {
            spawn_rank(ctx, rank, ReinitState::New, SimDuration::ZERO);
        }
        let root = ctx.cluster.root();
        let ctx2 = ctx.clone();
        w.sim.clone().spawn(root, async move {
            cr_root(ctx2, detect_rx).await;
        });
    }
}

#[cfg(test)]
mod tests {
    // CR end-to-end behaviour is covered by rust/tests/recovery_equivalence.rs
    // and the unit tests in recovery::tests (job-level), including the
    // multi-failure storm trials driving repeated abort + re-deploy cycles.
}
