//! Checkpoint-Restart (paper §2.2, the baseline): the standard practice of
//! aborting on failure and immediately re-submitting the job.
//!
//! Ranks get no fault notification; on the first detection event the root
//! (mpirun) aborts the whole job — every daemon and MPI process is killed.
//! After RTE teardown the driver re-deploys from scratch (full `mpirun`
//! launch), and the fresh ranks resume from the newest file checkpoint on
//! the parallel filesystem. The re-deployment overhead even for a single
//! process failure is exactly what the paper's Fig. 6 shows as CR's ≈3 s.

use std::rc::Rc;

use super::job::{launch_job, JobCtx, ReinitState, TrialWorld};

use super::reinit::spawn_rank;
use crate::detect::DetectEvent;
use crate::sim::{Receiver, SimDuration};

/// Sentinel "rank id" the root pushes into the done channel on abort.
const ABORT: u32 = u32::MAX;

/// Root behaviour under CR: first failure event => abort everything.
async fn cr_root(ctx: JobCtx, detect_rx: Receiver<DetectEvent>) {
    let Ok(_ev) = detect_rx.recv().await else {
        return;
    };
    // mpirun abort: kill every node (daemon + children). The root's own
    // teardown cost is charged by the driver before re-deploying.
    for node in 0..ctx.cluster.topo.total_nodes() {
        if ctx.cluster.node_is_alive(node) {
            ctx.cluster.kill_node(node);
        }
    }
    ctx.done_tx.send(ABORT, SimDuration::ZERO);
}

/// Whole-trial driver for CR: a sequence of deployments until the job
/// finishes without a failure.
pub async fn cr_trial_driver(w: Rc<TrialWorld>) {
    let mut deployment = 0u32;
    let mut timing_started = false;
    loop {
        let (ctx, detect_rx, done_rx) = launch_job(&w, &format!("cr-deploy{deployment}"));
        w.sim.sleep(w.deploy.mpirun_launch(&w.topo())).await;
        if !timing_started {
            // the paper times the application, not the first submission
            w.metrics.set_job_start(w.sim.now());
            timing_started = true;
        }
        for rank in 0..w.cfg.ranks {
            spawn_rank(&ctx, rank, ReinitState::New, SimDuration::ZERO);
        }
        let root = ctx.cluster.root();
        let ctx2 = ctx.clone();
        w.sim.clone().spawn(root, async move {
            cr_root(ctx2, detect_rx).await;
        });

        // Wait for completion or abort.
        let mut aborted = false;
        while w.completed.count() < w.cfg.ranks {
            match done_rx.recv().await {
                Ok(ABORT) => {
                    aborted = true;
                    break;
                }
                Ok(_rank) => {}
                Err(_) => break,
            }
        }
        if !aborted {
            break;
        }
        // The abort killed every process: in-memory checkpoint tiers (and
        // any undrained copies) die with them. Only the filesystem tier
        // survives re-deployment — which is why CR needs one (Table 2).
        w.ckpt.lose_all_memory();
        // RTE teardown + scheduler epilogue, then re-deploy.
        w.sim.sleep(w.deploy.teardown()).await;
        deployment += 1;
        assert!(deployment < 16, "CR livelock: failure re-injected?");
    }
    w.metrics.set_job_end(w.sim.now());
}

#[cfg(test)]
mod tests {
    // CR end-to-end behaviour is covered by rust/tests/recovery_equivalence.rs
    // and the unit tests in recovery::tests (job-level).
}
