//! Replication: the fourth recovery family — failover without rollback.
//!
//! Each logical rank is backed by a replica group of `repl_degree`
//! processes: one primary that computes, plus `repl_degree - 1` shadow
//! replicas placed *node-disjoint* from the primary (reusing the
//! checkpoint-store placement walk, [`crate::ckptstore::placement`]).
//! Primaries mirror their state to the active shadow every iteration over
//! the fabric; when a primary dies, the root *promotes* the shadow instead
//! of rolling anyone back — the shadow already holds the iteration
//! frontier, so recovery re-executes nothing (FTHP-MPI / PartRePer-MPI
//! style, vs the paper's three rollback-based families).
//!
//! **Degrade path.** A failure that finds the victim's replica group
//! exhausted (degree 1, or every standby node already dead) cannot fail
//! over; the job degrades to a CR-style abort + re-deploy, recorded as
//! `degraded_redeploy` on the event's metric segment — which is why
//! replication still writes file checkpoints ([`crate::checkpoint::policy`]
//! maps it to the File column of Table 2).
//!
//! **Multi-failure semantics.** Same idempotent-under-overlap discipline as
//! [`super::reinit`]: promotion closures re-check the cluster at fire time.
//! A standby node that dies *mid-failover* (after the root picked it,
//! before the promotion fires) re-drives the root loop with a synthetic
//! `RankDead` event, so the rank retries on its next standby or degrades —
//! it can never be silently orphaned. Node failures take out every shadow
//! hosted there too: mirrors on the dead node are dropped and the node is
//! struck from every standby queue.

use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::rc::Rc;

use super::job::{abort_job, arm_child_watcher, JobCtx, RecoveryDriver, ReinitState};
use super::reinit::spawn_rank;
use crate::ckptstore::placement::partners_of;
use crate::cluster::Topology;
use crate::config::{ExperimentConfig, FailureKind};
use crate::detect::DetectEvent;
use crate::sim::{Receiver, SimDuration};

/// Mirror snapshots retained per rank — the frontier iteration plus one
/// behind it, mirroring the checkpoint store's own two-deep window: BSP
/// keeps ranks within one save interval, so the group-wide agreed
/// iteration is always covered.
const MIRROR_WINDOW: usize = 2;

struct ReplInner {
    /// Per-rank standby-node queue; front = the active shadow's host.
    /// Popped on promotion, shrunk by node deaths; empty = exhausted.
    standbys: Vec<VecDeque<u32>>,
    /// Per-rank mirror window `(iter, state)`, newest last. The data the
    /// active shadow holds — dropped if its host node dies.
    mirrors: Vec<VecDeque<(u32, Rc<Vec<u8>>)>>,
    /// Per-rank accumulated primary-side mirror stall (the replication
    /// bandwidth overhead; reported like `ckpt_write` — slowest rank).
    mirror_stall: Vec<SimDuration>,
}

/// Replica-group bookkeeping for one trial, shared across deployments
/// (reset on each deploy: an abort kills shadows with everything else).
pub struct ReplState {
    degree: u32,
    topo: Topology,
    inner: RefCell<ReplInner>,
    failovers: Cell<u64>,
    mirror_pushes: Cell<u64>,
    mirror_bytes: Cell<u64>,
}

impl ReplState {
    pub fn new(cfg: &ExperimentConfig) -> ReplState {
        let topo = Topology::new(cfg.ranks, cfg.ranks_per_node, cfg.spare_nodes);
        let s = ReplState {
            degree: cfg.repl_degree,
            topo,
            inner: RefCell::new(ReplInner {
                standbys: Vec::new(),
                mirrors: Vec::new(),
                mirror_stall: vec![SimDuration::ZERO; cfg.ranks as usize],
            }),
            failovers: Cell::new(0),
            mirror_pushes: Cell::new(0),
            mirror_bytes: Cell::new(0),
        };
        s.reset();
        s
    }

    /// Rebuild standby queues and drop all mirrors — a fresh deployment
    /// respawns every shadow, and an abort killed the old ones' memory.
    /// Accumulated traffic/stall counters survive (they are per-trial).
    pub fn reset(&self) {
        let mut inner = self.inner.borrow_mut();
        let ranks = self.topo.ranks as usize;
        inner.standbys = (0..self.topo.ranks)
            .map(|r| {
                let mut nodes: VecDeque<u32> = VecDeque::new();
                for p in partners_of(&self.topo, r, self.degree - 1, true) {
                    let n = self.topo.home_node(p);
                    if !nodes.contains(&n) {
                        nodes.push_back(n);
                    }
                }
                nodes
            })
            .collect();
        inner.mirrors = vec![VecDeque::new(); ranks];
    }

    /// Host node of `rank`'s active shadow (`None` = group exhausted).
    pub fn shadow_node(&self, rank: u32) -> Option<u32> {
        self.inner.borrow().standbys[rank as usize].front().copied()
    }

    /// Claim the next *live* standby node for a promotion, discarding dead
    /// ones (their hosted mirror died with them). `None` = exhausted.
    pub fn take_standby(&self, rank: u32, cluster: &crate::cluster::Cluster) -> Option<u32> {
        let mut inner = self.inner.borrow_mut();
        let r = rank as usize;
        while let Some(node) = inner.standbys[r].pop_front() {
            if cluster.node_is_alive(node) {
                return Some(node);
            }
            inner.mirrors[r].clear();
        }
        None
    }

    /// A node died: every shadow hosted there is gone — drop its mirror
    /// data and strike the node from all standby queues.
    pub fn lose_node(&self, node: u32) {
        let mut inner = self.inner.borrow_mut();
        let ranks = inner.standbys.len();
        for r in 0..ranks {
            if inner.standbys[r].front() == Some(&node) {
                inner.mirrors[r].clear();
            }
            inner.standbys[r].retain(|&n| n != node);
        }
    }

    /// Record a completed mirror push (window of [`MIRROR_WINDOW`]).
    pub fn push(&self, rank: u32, iter: u32, bytes: Vec<u8>, stall: SimDuration) {
        let mut inner = self.inner.borrow_mut();
        let r = rank as usize;
        let win = &mut inner.mirrors[r];
        win.push_back((iter, Rc::new(bytes)));
        while win.len() > MIRROR_WINDOW {
            win.pop_front();
        }
        self.mirror_pushes.set(self.mirror_pushes.get() + 1);
        let len = win.back().map(|(_, b)| b.len()).unwrap_or(0) as u64;
        self.mirror_bytes.set(self.mirror_bytes.get() + len);
        inner.mirror_stall[r] += stall;
    }

    /// The shadow's copy of `rank`'s state at exactly `iter`, if mirrored.
    pub fn snapshot(&self, rank: u32, iter: u32) -> Option<Rc<Vec<u8>>> {
        self.inner.borrow().mirrors[rank as usize]
            .iter()
            .find(|(i, _)| *i == iter)
            .map(|(_, b)| Rc::clone(b))
    }

    /// Newest mirrored iteration for `rank`.
    pub fn latest_iter(&self, rank: u32) -> Option<u32> {
        self.inner.borrow().mirrors[rank as usize]
            .back()
            .map(|(i, _)| *i)
    }

    pub fn record_failover(&self) {
        self.failovers.set(self.failovers.get() + 1);
    }

    /// Promotions performed this trial.
    pub fn failovers(&self) -> u64 {
        self.failovers.get()
    }

    /// Total mirror traffic this trial: `(pushes, bytes)`.
    pub fn mirror_traffic(&self) -> (u64, u64) {
        (self.mirror_pushes.get(), self.mirror_bytes.get())
    }

    /// Slowest rank's accumulated mirror stall — the BSP-visible
    /// replication bandwidth overhead (same convention as `ckpt_write_s`).
    pub fn mirror_stall_s(&self) -> f64 {
        self.inner
            .borrow()
            .mirror_stall
            .iter()
            .map(|d| d.secs_f64())
            .fold(0.0, f64::max)
    }
}

/// The root's failover loop: promote shadows on primary death, degrade on
/// replica exhaustion. Structured like [`super::reinit::reinit_root`]; the
/// promotion list replaces the spawn list and startup skips the ORTE
/// barrier (shadows are already running processes — re-attaching the world
/// communicator is the only collective step).
pub async fn repl_root(ctx: JobCtx, detect_rx: Receiver<DetectEvent>) {
    let w = Rc::clone(&ctx.world);
    let repl = w.repl.as_ref().expect("repl driver without ReplState");
    let control = SimDuration::from_secs_f64(w.cfg.calib.control_latency_us * 1e-6);
    loop {
        let Ok(ev) = detect_rx.recv().await else {
            return;
        };
        // Build the (rank, standby node) promotion list; degrade the whole
        // job the moment any victim's group is exhausted.
        let (kind, victims): (FailureKind, Vec<u32>) = match ev {
            DetectEvent::RankDead { rank, .. } => {
                if ctx.cluster.rank_is_alive(rank) {
                    continue; // stale notification (already promoted)
                }
                w.metrics.record_detect(w.sim.now(), FailureKind::Process);
                w.trace_mark("detect");
                (FailureKind::Process, vec![rank])
            }
            DetectEvent::NodeDead { node, .. } => {
                // Shadows hosted on the node die with it, whether or not
                // any primary lived there.
                repl.lose_node(node);
                let failed: Vec<u32> = (0..w.cfg.ranks)
                    .filter(|&r| {
                        ctx.cluster.rank_slot(r).node == node && !ctx.cluster.rank_is_alive(r)
                    })
                    .collect();
                if failed.is_empty() {
                    continue;
                }
                w.metrics.record_detect(w.sim.now(), FailureKind::Node);
                w.trace_mark("detect");
                (FailureKind::Node, failed)
            }
        };

        let mut promotions: Vec<(u32, u32)> = Vec::with_capacity(victims.len());
        let mut exhausted = false;
        for &rank in &victims {
            match repl.take_standby(rank, &ctx.cluster) {
                Some(node) => promotions.push((rank, node)),
                None => exhausted = true,
            }
        }
        if exhausted {
            // Replica group outrun: no shadow left to promote. Degrade to a
            // CR-style full re-deploy, restarting from file checkpoints (or
            // iteration 0 if none completed yet).
            w.metrics.record_degrade(kind);
            w.metrics.record_escalation();
            w.trace_mark("degrade");
            abort_job(&ctx);
            return;
        }
        w.metrics.record_failover();
        w.trace_mark("failover");
        repl.record_failover();

        // Broadcast <PROMOTE, list> down the root->daemon control tree.
        let levels = Topology::tree_levels(ctx.cluster.topo.total_nodes() + 1);
        w.sim
            .sleep(SimDuration(control.0 * levels.max(1) as u64))
            .await;

        // Old MPI state is discarded; everyone re-attaches a new
        // generation. No ORTE barrier: nothing is fork+exec'd, the
        // promoted shadows are already running processes.
        ctx.mpi.bump_generation();
        let startup = w.deploy.comm_reinit(w.cfg.ranks);

        // Survivors: cancel + re-enter (same longjmp discipline as
        // Reinit++ — they restore from their own shadow's mirror at the
        // agreed frontier, so the re-entry costs no rollback).
        let signal = w.deploy.signal();
        for rank in 0..w.cfg.ranks {
            if !ctx.cluster.rank_is_alive(rank) {
                continue;
            }
            let ctx2 = ctx.clone();
            w.sim.schedule(signal, move || {
                if !ctx2.cluster.rank_is_alive(rank) {
                    return; // died since the broadcast; its detect covers it
                }
                let cur = ctx2.rank_tasks.borrow()[rank as usize];
                if let Some(t) = cur {
                    ctx2.world.sim.cancel_task(t);
                }
                spawn_rank(&ctx2, rank, ReinitState::Reinited, startup);
            });
        }

        // Promotions: the shadow takes over its rank's slot. Fire-time
        // re-checks keep overlap idempotent; a standby node dead by fire
        // time re-drives this loop with a synthetic RankDead so the rank
        // retries on its next standby (or degrades) instead of stalling.
        for (rank, target) in promotions {
            let ctx2 = ctx.clone();
            w.sim.schedule(signal, move || {
                if ctx2.cluster.rank_is_alive(rank) {
                    return; // an overlapping recovery already covered it
                }
                if !ctx2.cluster.node_is_alive(target) {
                    ctx2.detect_tx.send(
                        DetectEvent::RankDead {
                            rank,
                            at: ctx2.world.sim.now(),
                        },
                        SimDuration::ZERO,
                    );
                    return;
                }
                ctx2.cluster.respawn_rank(rank, target);
                arm_child_watcher(&ctx2, rank);
                spawn_rank(&ctx2, rank, ReinitState::Restarted, startup);
            });
        }
    }
}

/// Replication hosted on the shared trial loop.
#[derive(Default)]
pub struct ReplDriver;

impl RecoveryDriver for ReplDriver {
    fn tag(&self) -> &'static str {
        "repl"
    }

    fn deploy(&self, ctx: &JobCtx, detect_rx: Receiver<DetectEvent>) {
        let w = &ctx.world;
        // Fresh deployment = fresh shadows: full standby queues, empty
        // mirrors (an abort-redeploy killed every process's memory).
        w.repl
            .as_ref()
            .expect("repl driver without ReplState")
            .reset();
        for rank in 0..w.cfg.ranks {
            spawn_rank(ctx, rank, ReinitState::New, SimDuration::ZERO);
        }
        let root = ctx.cluster.root();
        let ctx2 = ctx.clone();
        w.sim.clone().spawn(root, async move {
            repl_root(ctx2, detect_rx).await;
        });
    }
}
