//! Reinit++ (the paper's contribution, §3).
//!
//! Root `HandleFailure` (Algorithm 1): on a process failure the failed rank
//! re-spawns on its original node; on a daemon/node failure the root picks
//! the least-loaded alive node; either way the root broadcasts REINIT to all
//! daemons over the control tree.
//!
//! Daemon `HandleReinit` (Algorithm 2): signal SIGREINIT to survivor
//! children — modeled as cancelling their task and re-entering the rollback
//! point with `MPI_REINIT_REINITED`, memory intact (longjmp semantics) —
//! and fork+exec the assigned replacements (`MPI_REINIT_RESTARTED`).
//!
//! All re-entering ranks synchronize on the ORTE-level barrier and rebuild
//! MPI_COMM_WORLD (a fresh communicator generation); everything older is
//! discarded, exactly the paper's post-MPI_Init semantics.

use std::collections::BTreeMap;
use std::rc::Rc;

use super::job::{
    arm_child_watcher, launch_job, rank_user_main, wait_all_done, JobCtx, ReinitState,
    TrialWorld,
};
use crate::cluster::Topology;
use crate::detect::DetectEvent;
use crate::sim::{Receiver, SimDuration};

/// Spawn (or re-spawn) the rank task entering the rollback point.
pub fn spawn_rank(ctx: &JobCtx, rank: u32, state: ReinitState, startup: SimDuration) {
    let slot = ctx.cluster.rank_slot(rank);
    let sim = ctx.world.sim.clone();
    let ctx2 = ctx.clone();
    let tid = sim.clone().spawn(slot.proc, async move {
        if startup > SimDuration::ZERO {
            sim.sleep(startup).await;
        }
        if rank_user_main(ctx2, rank, state).await.is_err() {
            // CR/Reinit ranks never see MPI errors (no ULFM notification);
            // a closed mailbox means the job is being torn down.
            crate::sim::Sim::halt_forever(&sim).await;
        }
    });
    ctx.rank_tasks.borrow_mut()[rank as usize] = Some(tid);
}

/// The root's failure-handling loop (Algorithm 1 + orchestration of the
/// daemons' Algorithm 2 actions).
pub async fn reinit_root(ctx: JobCtx, detect_rx: Receiver<DetectEvent>) {
    let w = Rc::clone(&ctx.world);
    let control = SimDuration::from_secs_f64(w.cfg.calib.control_latency_us * 1e-6);
    loop {
        let Ok(ev) = detect_rx.recv().await else {
            return;
        };
        // Algorithm 1: build the (daemon, rank) spawn list.
        let spawn_list: Vec<(u32, u32)> = match ev {
            DetectEvent::RankDead { rank, .. } => {
                if ctx.cluster.rank_is_alive(rank) {
                    continue; // stale notification (already re-spawned)
                }
                // process failure: re-spawn on the original node (§3.2)
                vec![(rank, ctx.cluster.rank_slot(rank).node)]
            }
            DetectEvent::NodeDead { node, .. } => {
                let failed: Vec<u32> = (0..w.cfg.ranks)
                    .filter(|&r| {
                        ctx.cluster.rank_slot(r).node == node && !ctx.cluster.rank_is_alive(r)
                    })
                    .collect();
                if failed.is_empty() {
                    continue;
                }
                // d' = argmin_d |Children(d)| over alive daemons
                let target = ctx.cluster.least_loaded_alive_node();
                failed.into_iter().map(|r| (r, target)).collect()
            }
        };

        // Broadcast <REINIT, spawn list> down the root->daemon control tree.
        let levels = Topology::tree_levels(ctx.cluster.topo.total_nodes() + 1);
        w.sim
            .sleep(SimDuration(control.0 * levels.max(1) as u64))
            .await;

        // Old MPI state is discarded; ranks re-attach to a new generation.
        ctx.mpi.bump_generation();
        let startup = w.deploy.orte_barrier(ctx.cluster.topo.total_nodes())
            + w.deploy.comm_reinit(w.cfg.ranks);

        // Algorithm 2 on every daemon — survivors first: SIGREINIT.
        let signal = w.deploy.signal();
        for rank in 0..w.cfg.ranks {
            if !ctx.cluster.rank_is_alive(rank) {
                continue;
            }
            let old_task = ctx.rank_tasks.borrow()[rank as usize];
            let ctx2 = ctx.clone();
            w.sim.schedule(signal, move || {
                if let Some(t) = old_task {
                    ctx2.world.sim.cancel_task(t); // longjmp: drop the stack
                }
                spawn_rank(&ctx2, rank, ReinitState::Reinited, startup);
            });
        }

        // Replacements, grouped per target daemon (parallel across nodes,
        // serialized within one node: fork+exec pipeline).
        let mut by_node: BTreeMap<u32, Vec<u32>> = BTreeMap::new();
        for (rank, node) in spawn_list {
            by_node.entry(node).or_default().push(rank);
        }
        for (node, ranks) in by_node {
            let cost = w.deploy.node_spawn(ranks.len() as u32);
            let ctx2 = ctx.clone();
            w.sim.schedule(cost, move || {
                for &rank in &ranks {
                    ctx2.cluster.respawn_rank(rank, node);
                    arm_child_watcher(&ctx2, rank);
                    spawn_rank(&ctx2, rank, ReinitState::Restarted, startup);
                }
            });
        }
    }
}

/// Whole-trial driver for Reinit++.
pub async fn reinit_trial_driver(w: Rc<TrialWorld>) {
    let (ctx, detect_rx, done_rx) = launch_job(&w, "reinit-job");
    // mpirun deployment (cost only; the paper times the application)
    w.sim.sleep(w.deploy.mpirun_launch(&w.topo())).await;
    w.metrics.set_job_start(w.sim.now());
    for rank in 0..w.cfg.ranks {
        spawn_rank(&ctx, rank, ReinitState::New, SimDuration::ZERO);
    }
    let root = ctx.cluster.root();
    let ctx2 = ctx.clone();
    w.sim.clone().spawn(root, async move {
        reinit_root(ctx2, detect_rx).await;
    });
    wait_all_done(&w, &done_rx).await;
    w.metrics.set_job_end(w.sim.now());
}
