//! Reinit++ (the paper's contribution, §3).
//!
//! Root `HandleFailure` (Algorithm 1): on a process failure the failed rank
//! re-spawns on its original node; on a daemon/node failure the root picks
//! the least-loaded alive node; either way the root broadcasts REINIT to all
//! daemons over the control tree.
//!
//! Daemon `HandleReinit` (Algorithm 2): signal SIGREINIT to survivor
//! children — modeled as cancelling their task and re-entering the rollback
//! point with `MPI_REINIT_REINITED`, memory intact (longjmp semantics) —
//! and fork+exec the assigned replacements (`MPI_REINIT_RESTARTED`).
//!
//! All re-entering ranks synchronize on the ORTE-level barrier and rebuild
//! MPI_COMM_WORLD (a fresh communicator generation); everything older is
//! discarded, exactly the paper's post-MPI_Init semantics.
//!
//! **Multi-failure semantics.** The handler loop is *idempotent under
//! overlap*: a failure landing while a prior recovery is still in flight
//! simply restarts it. The scheduled SIGREINIT/fork+exec closures therefore
//! re-check the cluster at fire time — a survivor that has died since is
//! skipped (its own detect event re-covers it), a respawn onto a node that
//! has died since is skipped (the node's detect event covers every rank on
//! it), and task cancellation targets whatever task currently occupies the
//! rank's slot, never a stale capture — so overlapping recoveries can never
//! double-spawn a rank. Node failures beyond the spare pool abort to the
//! shared trial loop for a CR-style re-deploy (recorded as degraded).

use std::collections::BTreeMap;
use std::rc::Rc;

use super::job::{
    abort_job, arm_child_watcher, rank_user_main, JobCtx, RecoveryDriver, ReinitState,
};
use crate::cluster::Topology;
use crate::detect::DetectEvent;
use crate::sim::{Receiver, SimDuration};

/// Spawn (or re-spawn) the rank task entering the rollback point. No-op if
/// the rank's process is dead (e.g. a timeline kill landed between cluster
/// launch and rank spawn): its detect event brings it back.
pub fn spawn_rank(ctx: &JobCtx, rank: u32, state: ReinitState, startup: SimDuration) {
    if !ctx.cluster.rank_is_alive(rank) {
        return;
    }
    let slot = ctx.cluster.rank_slot(rank);
    let sim = ctx.world.sim.clone();
    let ctx2 = ctx.clone();
    let tid = sim.clone().spawn(slot.proc, async move {
        if startup > SimDuration::ZERO {
            sim.sleep(startup).await;
        }
        if rank_user_main(ctx2, rank, state).await.is_err() {
            // CR/Reinit ranks never see MPI errors (no ULFM notification);
            // a closed mailbox means the job is being torn down.
            crate::sim::Sim::halt_forever(&sim).await;
        }
    });
    ctx.rank_tasks.borrow_mut()[rank as usize] = Some(tid);
}

/// The root's failure-handling loop (Algorithm 1 + orchestration of the
/// daemons' Algorithm 2 actions).
pub async fn reinit_root(ctx: JobCtx, detect_rx: Receiver<DetectEvent>) {
    let w = Rc::clone(&ctx.world);
    let control = SimDuration::from_secs_f64(w.cfg.calib.control_latency_us * 1e-6);
    loop {
        let Ok(ev) = detect_rx.recv().await else {
            return;
        };
        // Algorithm 1: build the (daemon, rank) spawn list.
        let spawn_list: Vec<(u32, u32)> = match ev {
            DetectEvent::RankDead { rank, .. } => {
                if ctx.cluster.rank_is_alive(rank) {
                    continue; // stale notification (already re-spawned)
                }
                w.metrics
                    .record_detect(w.sim.now(), crate::config::FailureKind::Process);
                w.trace_mark("detect");
                // process failure: re-spawn on the original node (§3.2)
                vec![(rank, ctx.cluster.rank_slot(rank).node)]
            }
            DetectEvent::NodeDead { node, .. } => {
                let failed: Vec<u32> = (0..w.cfg.ranks)
                    .filter(|&r| {
                        ctx.cluster.rank_slot(r).node == node && !ctx.cluster.rank_is_alive(r)
                    })
                    .collect();
                if failed.is_empty() {
                    continue;
                }
                w.metrics
                    .record_detect(w.sim.now(), crate::config::FailureKind::Node);
                w.trace_mark("detect");
                // Spare pool outrun: no in-place target left. Degrade to a
                // CR-style full re-deploy (paper §3.2 requires
                // over-provisioning precisely because Reinit++ has no other
                // answer once spares are gone).
                if ctx.spares_exhausted() {
                    w.metrics.record_degrade(crate::config::FailureKind::Node);
                    w.metrics.record_escalation();
                    w.trace_mark("degrade");
                    abort_job(&ctx);
                    return;
                }
                // d' = argmin_d |Children(d)| over alive daemons
                let target = ctx.cluster.least_loaded_alive_node();
                failed.into_iter().map(|r| (r, target)).collect()
            }
        };

        // Broadcast <REINIT, spawn list> down the root->daemon control tree.
        let levels = Topology::tree_levels(ctx.cluster.topo.total_nodes() + 1);
        w.sim
            .sleep(SimDuration(control.0 * levels.max(1) as u64))
            .await;

        // Old MPI state is discarded; ranks re-attach to a new generation.
        ctx.mpi.bump_generation();
        let startup = w.deploy.orte_barrier(ctx.cluster.topo.total_nodes())
            + w.deploy.comm_reinit(w.cfg.ranks);

        // Algorithm 2 on every daemon — survivors first: SIGREINIT. The
        // closure re-reads the rank's state at fire time (see module docs):
        // cancel whatever task currently holds the slot, skip ranks that
        // died in the window.
        let signal = w.deploy.signal();
        for rank in 0..w.cfg.ranks {
            if !ctx.cluster.rank_is_alive(rank) {
                continue;
            }
            let ctx2 = ctx.clone();
            w.sim.schedule(signal, move || {
                if !ctx2.cluster.rank_is_alive(rank) {
                    return; // died since the REINIT broadcast; its detect covers it
                }
                let cur = ctx2.rank_tasks.borrow()[rank as usize];
                if let Some(t) = cur {
                    ctx2.world.sim.cancel_task(t); // longjmp: drop the stack
                }
                spawn_rank(&ctx2, rank, ReinitState::Reinited, startup);
            });
        }

        // Replacements, grouped per target daemon (parallel across nodes,
        // serialized within one node: fork+exec pipeline).
        let mut by_node: BTreeMap<u32, Vec<u32>> = BTreeMap::new();
        for (rank, node) in spawn_list {
            by_node.entry(node).or_default().push(rank);
        }
        for (node, ranks) in by_node {
            let cost = w.deploy.node_spawn(ranks.len() as u32);
            let ctx2 = ctx.clone();
            w.sim.schedule(cost, move || {
                if !ctx2.cluster.node_is_alive(node) {
                    // target died while the fork+exec was in flight; its
                    // NodeDead event re-covers every rank assigned here
                    return;
                }
                for &rank in &ranks {
                    if ctx2.cluster.rank_is_alive(rank) {
                        continue; // an overlapping recovery already re-spawned it
                    }
                    ctx2.cluster.respawn_rank(rank, node);
                    arm_child_watcher(&ctx2, rank);
                    spawn_rank(&ctx2, rank, ReinitState::Restarted, startup);
                }
            });
        }
    }
}

/// Reinit++ hosted on the shared trial loop.
pub struct ReinitDriver;

impl RecoveryDriver for ReinitDriver {
    fn tag(&self) -> &'static str {
        "reinit"
    }

    fn deploy(&self, ctx: &JobCtx, detect_rx: Receiver<DetectEvent>) {
        let w = &ctx.world;
        for rank in 0..w.cfg.ranks {
            spawn_rank(ctx, rank, ReinitState::New, SimDuration::ZERO);
        }
        let root = ctx.cluster.root();
        let ctx2 = ctx.clone();
        w.sim.clone().spawn(root, async move {
            reinit_root(ctx2, detect_rx).await;
        });
    }
}
