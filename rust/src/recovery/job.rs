//! Job runner: deployment, the per-rank driver loop, detection wiring and
//! the protocol-agnostic trial orchestration shared by all five recovery
//! approaches.
//!
//! The heart of this module is [`trial_driver`]: one deployment loop that
//! hosts any [`RecoveryDriver`] (CR, Reinit++, ULFM, replication, shrink) and survives an
//! arbitrary failure *timeline* — N successive process/node failures,
//! failures landing inside a recovery or checkpoint window (virtual-time
//! anchored kills), and node failures beyond the spare pool, which degrade
//! the in-place recoveries to a CR-style full re-deploy (recorded as a
//! `degraded_redeploy` transition on the event's metric segment).

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::rc::Rc;

use crate::apps::{make_app, App, ComputeBackend, CostTracker, StepCtx};
use crate::ckptstore::{CkptStore, Integrity, StorageStats};
use crate::cluster::{Cluster, DeployCost, Topology};
use crate::config::{ExperimentConfig, FailureKind, Fidelity, RecoveryKind};
use crate::detect::{
    detect_jitter, suspicion_backoff, watch_child, watch_daemon, DetectEvent,
    SuspicionSchedule,
};
use crate::fault::{FaultOutcome, FaultTimeline, TimelineCursor};
use crate::metrics::{Breakdown, FailureSegment, TrialMetrics};
use crate::mpi::{Comm, FtMode, MpiError, MpiJob};
use crate::runtime::XlaRuntime;
use crate::sim::{channel, Receiver, Sender, Sim, SimDuration, TaskId};

/// The paper's `MPI_Reinit_state_t` (Fig. 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReinitState {
    /// First execution of this process.
    New,
    /// Survivor rolled back after a failure.
    Reinited,
    /// Re-spawned replacement of a failed process.
    Restarted,
}

/// Outcome of one trial.
#[derive(Clone, Debug)]
pub struct TrialResult {
    pub breakdown: Breakdown,
    /// Final state digest per rank (meaningful for non-ghost ranks).
    pub digests: Vec<u64>,
    pub completed: bool,
    /// The trial's planned timeline and what became of each event.
    pub faults: Vec<FaultOutcome>,
    /// Per-fired-failure detect/recovery/rollback decomposition.
    pub segments: Vec<FailureSegment>,
    pub sim_events: u64,
    /// Rank 0's (virtual time s, iteration, diagnostic) trace.
    pub diag_trace: Vec<(f64, u32, f64)>,
    /// Per-tier checkpoint traffic + shared-disk counters for this trial.
    pub storage: StorageStats,
    /// Replica promotions performed (replication only; else 0).
    pub failovers: u64,
    /// Slowest rank's accumulated mirror-push stall, seconds (replication
    /// bandwidth overhead; 0 for the rollback-based families).
    pub mirror_s: f64,
    /// Total state bytes mirrored to shadows, MB.
    pub mirror_mb: f64,
    /// Iterations of extra rollback forced by corrupted newest generations
    /// (agreed baseline minus the generation recovery finally restored).
    pub fallback_iters: u64,
    /// Recoveries triggered by the unreliable detector's false suspicions.
    pub spurious_recoveries: u64,
    /// Agreement rounds that fell back to an older checkpoint generation.
    pub ckpt_retries: u64,
    /// Recoveries that exhausted every intact generation (or the retry
    /// budget) and escalated to an iteration-0 degraded re-deploy.
    pub escalations: u64,
    /// Shrinking recoveries performed (shrink only; else 0).
    pub shrinks: u64,
    /// Checkpoint payload moved by shrink-time redistribution, MB.
    pub redistribute_mb: f64,
    /// Always-on executor counters + content-addressed trial identity
    /// (`--profile-json` aggregates these; cheap to collect, traced or not).
    pub counters: crate::trace::TrialCounters,
}

/// Per-worker-thread XLA runtime cache. `Rc<XlaRuntime>` cannot cross
/// threads, so the parallel sweep scheduler (`harness::pool`) ships only
/// `Send` inputs — an owned `ExperimentConfig` plus the trial index — and
/// every worker resolves the runtime locally through one of these, loading
/// it at most once per artifacts directory per thread.
#[derive(Default)]
pub struct RtCache {
    loaded: HashMap<String, Rc<XlaRuntime>>,
}

impl RtCache {
    pub fn new() -> RtCache {
        RtCache::default()
    }

    /// The runtime for `cfg`, if its resolved fidelity needs one (lazy
    /// load; `Modeled` runs on the pure-Rust oracle and needs nothing).
    pub fn resolve(&mut self, cfg: &ExperimentConfig) -> Option<Rc<XlaRuntime>> {
        if cfg.fidelity.resolve(cfg.ranks) == Fidelity::Modeled {
            return None;
        }
        let rt = self
            .loaded
            .entry(cfg.artifacts_dir.clone())
            .or_insert_with(|| {
                Rc::new(
                    XlaRuntime::load(&cfg.artifacts_dir)
                        .expect("loading artifacts (run `make artifacts`)"),
                )
            });
        Some(Rc::clone(rt))
    }
}

/// Per-rank backend selection (fidelity, DESIGN.md §8).
pub struct Backends {
    live: ComputeBackend,
    ghost: Option<ComputeBackend>,
    live_count: u32,
}

impl Backends {
    pub fn build(cfg: &ExperimentConfig, xla: Option<Rc<XlaRuntime>>) -> Backends {
        let tracker = CostTracker::new();
        match cfg.fidelity.resolve(cfg.ranks) {
            Fidelity::Modeled => Backends {
                live: ComputeBackend::native_scaled(cfg.calib.modeled_compute_scale),
                ghost: None,
                live_count: cfg.ranks,
            },
            Fidelity::Full => Backends {
                live: ComputeBackend::xla(
                    xla.expect("full fidelity needs the XLA runtime"),
                    tracker,
                ),
                ghost: None,
                live_count: cfg.ranks,
            },
            Fidelity::Fast => Backends {
                live: ComputeBackend::xla(
                    xla.expect("fast fidelity needs the XLA runtime"),
                    tracker.clone(),
                ),
                ghost: Some(ComputeBackend::ghost(tracker)),
                live_count: cfg.ranks_per_node.min(cfg.ranks),
            },
            Fidelity::Auto => unreachable!("resolved above"),
        }
    }

    pub fn for_rank(&self, rank: u32) -> ComputeBackend {
        if rank < self.live_count {
            self.live.clone()
        } else {
            self.ghost.clone().expect("ghost backend")
        }
    }
}

/// Rank-completion tracker: dense bitmap + running count. A 16k-rank trial
/// marks completion once per rank and polls the count on every done
/// message, so both operations are O(1) with no hashing (the seed kept a
/// `HashSet<u32>` here).
pub struct Completed {
    done: RefCell<Vec<bool>>,
    count: Cell<u32>,
}

impl Completed {
    pub fn new(ranks: u32) -> Completed {
        Completed {
            done: RefCell::new(vec![false; ranks as usize]),
            count: Cell::new(0),
        }
    }

    /// Mark `rank` complete (idempotent).
    pub fn mark(&self, rank: u32) {
        let mut done = self.done.borrow_mut();
        if !done[rank as usize] {
            done[rank as usize] = true;
            self.count.set(self.count.get() + 1);
        }
    }

    /// Number of distinct ranks that completed.
    pub fn count(&self) -> u32 {
        self.count.get()
    }
}

/// Everything shared across (re-)deployments of one trial.
pub struct TrialWorld {
    pub sim: Sim,
    pub cfg: ExperimentConfig,
    /// Trial index within the config's `trials` (seeds jitter/bit-rot).
    pub trial: u32,
    pub app: Rc<dyn App>,
    pub backends: Backends,
    pub ckpt: CkptStore,
    pub metrics: TrialMetrics,
    /// The trial's failure timeline and shared firing state.
    pub faults: TimelineCursor,
    /// Checkpoint-integrity machinery armed this trial? True when bit-rot
    /// is configured or the timeline carries `corrupt@` events; false keeps
    /// the agreement protocol and storage byte-identical to the
    /// pre-integrity code paths.
    pub integrity_on: bool,
    /// The unreliable detector's planned false suspicions (empty under the
    /// default perfect detector).
    pub suspicions: SuspicionSchedule,
    /// Prior suspicions per rank, for the detector's confirmation backoff.
    pub suspicion_counts: RefCell<HashMap<u32, u32>>,
    pub deploy: DeployCost,
    pub digests: Rc<RefCell<Vec<Option<u64>>>>,
    pub completed: Rc<Completed>,
    /// Rank 0's per-iteration diagnostic (virtual time s, iter, value) —
    /// the e2e examples' convergence trace across the failure.
    pub diag_trace: Rc<RefCell<Vec<(f64, u32, f64)>>>,
    /// Cluster of the *current* deployment: virtual-time-anchored kills
    /// are scheduled once per trial and must hit whatever incarnation of
    /// the job is live when their instant arrives (a kill landing between
    /// a CR abort and the re-deploy hits dead air). `Cluster`, not
    /// `JobCtx`, to avoid an `Rc` cycle back into this world.
    pub cur_cluster: RefCell<Option<Cluster>>,
    /// Replica-group bookkeeping (standby queues, mirror window, failover
    /// counters). `Some` only under `recovery=repl`.
    pub repl: Option<super::repl::ReplState>,
    /// Shrinking recoveries performed this trial (shrink driver only).
    pub shrinks: Cell<u64>,
}

impl TrialWorld {
    pub fn new(
        sim: &Sim,
        cfg: &ExperimentConfig,
        trial: u32,
        xla: Option<Rc<XlaRuntime>>,
    ) -> Rc<TrialWorld> {
        let topo = Topology::new(cfg.ranks, cfg.ranks_per_node, cfg.spare_nodes);
        let timeline = FaultTimeline::plan(cfg, trial);
        let integrity_on =
            cfg.corrupt_rate > 0.0 || timeline.events.iter().any(|e| e.corrupt);
        let ckpt = CkptStore::new(sim, &cfg.effective_stack(), topo, &cfg.calib);
        ckpt.set_integrity(Integrity {
            keep: cfg.ckpt_keep,
            corrupt_rate: cfg.corrupt_rate,
            seed: cfg.seed,
            trial,
            active: integrity_on,
        });
        Rc::new(TrialWorld {
            sim: sim.clone(),
            cfg: cfg.clone(),
            trial,
            app: make_app(cfg),
            backends: Backends::build(cfg, xla),
            ckpt,
            metrics: TrialMetrics::new(cfg.ranks),
            integrity_on,
            suspicions: SuspicionSchedule::plan(cfg, trial),
            suspicion_counts: RefCell::new(HashMap::new()),
            faults: TimelineCursor::new(timeline),
            deploy: DeployCost::from_calib(&cfg.calib),
            digests: Rc::new(RefCell::new(vec![None; cfg.ranks as usize])),
            completed: Rc::new(Completed::new(cfg.ranks)),
            diag_trace: Rc::new(RefCell::new(Vec::new())),
            cur_cluster: RefCell::new(None),
            repl: (cfg.recovery == RecoveryKind::Replication)
                .then(|| super::repl::ReplState::new(cfg)),
            shrinks: Cell::new(0),
        })
    }

    pub fn topo(&self) -> Topology {
        Topology::new(self.cfg.ranks, self.cfg.ranks_per_node, self.cfg.spare_nodes)
    }

    /// Drop an instant marker on the recovery timeline (track 0) at the
    /// current virtual time. One flag load when tracing is off.
    pub fn trace_mark(&self, name: &'static str) {
        let tr = self.sim.tracer();
        if tr.is_on() {
            tr.instant("recovery", name, 0, self.sim.now());
        }
    }

    pub fn ft_mode(&self) -> FtMode {
        match self.cfg.recovery {
            RecoveryKind::Cr => FtMode::Cr,
            RecoveryKind::Ulfm => FtMode::Ulfm,
            RecoveryKind::Reinit => FtMode::Reinit,
            RecoveryKind::Replication => FtMode::Repl,
            // Shrink shares Reinit++'s rank-side semantics: no ULFM error
            // notification, no per-call FT inflation — the root cancels and
            // re-enters survivors in place.
            RecoveryKind::Shrink => FtMode::Reinit,
        }
    }
}

/// One deployment of the job (the trial loop creates several after aborts).
pub struct JobCtx {
    pub world: Rc<TrialWorld>,
    pub cluster: Cluster,
    pub mpi: MpiJob,
    /// Current driver task per rank, indexed by rank (no hashing: the
    /// reinit root reads/writes one slot per survivor per recovery).
    pub rank_tasks: Rc<RefCell<Vec<Option<TaskId>>>>,
    pub done_tx: Sender<u32>,
    pub detect_tx: Sender<DetectEvent>,
}

impl Clone for JobCtx {
    fn clone(&self) -> Self {
        JobCtx {
            world: Rc::clone(&self.world),
            cluster: self.cluster.clone(),
            mpi: self.mpi.clone(),
            rank_tasks: Rc::clone(&self.rank_tasks),
            done_tx: self.done_tx.clone(),
            detect_tx: self.detect_tx.clone(),
        }
    }
}

impl JobCtx {
    /// Has the spare pool been outrun? True once more nodes are dead than
    /// the allocation over-provisioned (paper §3.2): the next in-place
    /// node recovery has nowhere sane to spawn, so Reinit++/ULFM degrade
    /// to a CR-style abort + re-deploy.
    pub fn spares_exhausted(&self) -> bool {
        let topo = &self.cluster.topo;
        let dead = (0..topo.total_nodes())
            .filter(|&n| !self.cluster.node_is_alive(n))
            .count() as u32;
        dead > topo.spare_nodes
    }
}

/// Sentinel "rank id" a root pushes into the done channel to request a
/// full abort + re-deploy (CR's normal mode; the in-place recoveries'
/// spare-exhaustion fallback).
pub const ABORT: u32 = u32::MAX;

/// mpirun abort: kill every node (daemon + children), then ask the trial
/// loop for a re-deploy. The caller's own teardown cost is charged by the
/// trial loop before re-deploying.
pub fn abort_job(ctx: &JobCtx) {
    ctx.world.trace_mark("abort");
    for node in 0..ctx.cluster.topo.total_nodes() {
        if ctx.cluster.node_is_alive(node) {
            ctx.cluster.kill_node(node);
        }
    }
    ctx.done_tx.send(ABORT, SimDuration::ZERO);
}

/// One recovery protocol, hosted by the shared [`trial_driver`] loop.
/// Implementations spawn the rank tasks plus their root-side control tasks
/// for a fresh deployment; everything else — deployment cost, abort /
/// re-deploy sequencing, timeline arming, completion tracking — is
/// protocol-agnostic.
pub trait RecoveryDriver {
    /// Short tag for process names (`cr`, `reinit`, `ulfm`, `repl`).
    fn tag(&self) -> &'static str;
    /// Spawn all rank tasks and root-side handler tasks onto a freshly
    /// launched deployment.
    fn deploy(&self, ctx: &JobCtx, detect_rx: Receiver<DetectEvent>);
}

/// The driver for a recovery kind.
pub fn driver_for(kind: RecoveryKind) -> Rc<dyn RecoveryDriver> {
    match kind {
        RecoveryKind::Cr => Rc::new(super::cr::CrDriver),
        RecoveryKind::Reinit => Rc::new(super::reinit::ReinitDriver),
        RecoveryKind::Ulfm => Rc::new(super::ulfm::UlfmDriver),
        RecoveryKind::Replication => Rc::new(super::repl::ReplDriver),
        RecoveryKind::Shrink => Rc::new(super::shrink::ShrinkDriver),
    }
}

/// Create the cluster + MPI world + control channels for one deployment and
/// arm all failure detectors. The *cost* of deployment is charged by the
/// caller (approach-specific).
pub fn launch_job(
    world: &Rc<TrialWorld>,
    tag: &str,
) -> (JobCtx, Receiver<DetectEvent>, Receiver<u32>) {
    let sim = &world.sim;
    let topo = world.topo();
    let cluster = Cluster::new(sim, topo, tag);
    let mpi = MpiJob::new(sim, topo, world.ft_mode(), &world.cfg.calib);
    let (done_tx, done_rx) = channel::<u32>(sim);
    let (detect_tx, detect_rx) = channel::<DetectEvent>(sim);
    let ctx = JobCtx {
        world: Rc::clone(world),
        cluster,
        mpi,
        rank_tasks: Rc::new(RefCell::new(vec![None; topo.ranks as usize])),
        done_tx,
        detect_tx,
    };
    // Root watches every daemon (TCP channel break).
    for node in 0..topo.total_nodes() {
        watch_daemon(
            sim,
            ctx.cluster.root(),
            ctx.cluster.daemon(node),
            node,
            world.deploy.tcp_break(),
            ctx.detect_tx.clone(),
        );
    }
    // Each daemon watches its children (SIGCHLD), relayed to the root over
    // the control channel (paper §3.1: the daemon forwards, root decides).
    for rank in 0..topo.ranks {
        arm_child_watcher(&ctx, rank);
    }
    (ctx, detect_rx, done_rx)
}

/// (Re-)arm the SIGCHLD watcher for a rank's current incarnation.
pub fn arm_child_watcher(ctx: &JobCtx, rank: u32) {
    let slot = ctx.cluster.rank_slot(rank);
    let daemon = ctx.cluster.daemon(slot.node);
    if !ctx.world.sim.is_alive(daemon) {
        return; // node is gone; the root's daemon watcher covers this
    }
    // SIGCHLD to the daemon, then relay over the control channel to root.
    // The unreliable detector adds a per-(trial, rank) deterministic latency
    // jitter on top (zero under the default perfect detector).
    let w = &ctx.world;
    let delay = w.deploy.sigchld()
        + SimDuration::from_secs_f64(w.cfg.calib.control_latency_us * 1e-6)
        + detect_jitter(w.cfg.seed, w.trial, rank, w.cfg.detect_jitter_s);
    watch_child(
        &ctx.world.sim,
        daemon,
        slot.proc,
        rank,
        delay,
        ctx.detect_tx.clone(),
    );
}

/// The user's resilient main function (the paper's Fig. 2 `foo`): load the
/// latest globally-consistent checkpoint, then run the main loop with fault
/// injection and per-iteration checkpointing. Returns the communicator
/// alongside any MPI error so ULFM can drive recovery on it.
pub async fn rank_user_main(
    ctx: JobCtx,
    rank: u32,
    state: ReinitState,
) -> Result<(), (MpiError, Rc<Comm>)> {
    let w = &ctx.world;
    let slot = ctx.cluster.rank_slot(rank);
    let comm = Rc::new(ctx.mpi.attach(rank, slot.node));

    // Entering the user function after a recovery == the end of MPI
    // recovery (paper Fig. 6/7 metric). Only meaningful once a fault fired.
    if w.faults.any_fired() {
        w.metrics.record_resume(w.sim.now());
    }

    let backend = w.backends.for_rank(rank);
    let mut app_state = w.app.new_state(rank, w.cfg.ranks);

    // Shrunken world: fewer processes carry the same logical decomposition.
    // Re-partition the app's cost model (live grid + working-set scale)
    // before restoring — state payloads and digests are unaffected.
    let procs = comm.world_procs();
    if procs < w.cfg.ranks {
        app_state.repartition(crate::apps::NewWorld {
            logical: w.cfg.ranks,
            procs,
        });
    }

    // Application recovery (paper §3.1): agree on the newest state every
    // rank can restore — its checkpoints, or under replication the mirror
    // its shadow replica holds — then everyone resumes from it.
    let ckpt_latest = w.ckpt.latest_iter(rank).map(|i| i as i64).unwrap_or(-1);
    let mirror_latest = w
        .repl
        .as_ref()
        .and_then(|r| r.latest_iter(rank))
        .map(|i| i as i64)
        .unwrap_or(-1);
    let my_latest = (ckpt_latest.max(mirror_latest)) as f32;
    let baseline = comm
        .allreduce_scalar(my_latest, crate::mpi::ReduceOp::Min)
        .await
        .map_err(|e| (e, Rc::clone(&comm)))? as i64;
    // The two recovery-only blocks below are boxed: their state machines
    // (verify + multi-round agreement, tiered restore) dominate the inline
    // size of every rank's resident future, yet run at most once per
    // deployment. Boxing them on entry keeps the per-rank steady-state
    // footprint at the main-loop machine only — the SoA memory budget
    // `SimSummary::peak_rank_state_bytes` measures.
    let mut agreed = baseline;
    if w.integrity_on && baseline >= 0 {
        // Imperfect world: the newest stored generation may be torn, rotted
        // or hit by a `corrupt@` event, and checksums only reveal that at
        // load time. Every rank verifies its generations (charged as
        // `verify_s`), then the job agrees on the newest generation *every*
        // rank can actually serve, retrying from older generations up to
        // `retry_budget` rounds before escalating to an iteration-0
        // degraded re-deploy — never crashing on bad storage.
        agreed = Box::pin(async {
            let (intact, vcost) = w.ckpt.verify_generations(rank);
            if vcost > SimDuration::ZERO {
                w.sim.sleep(vcost).await;
                w.metrics.add_verify(rank, vcost);
            }
            // The mirror counts as an intact generation: the replication
            // protocol verifies each push in-line, so a promoted shadow's
            // snapshot never needs the checksum fallback.
            let serves = |gen: i64| {
                intact.binary_search(&(gen as u32)).is_ok() || mirror_latest == gen
            };
            let mut agreed = -1i64;
            let mut bound = baseline;
            let mut rounds = 0u32;
            while bound >= 0 {
                // Candidate: my newest serveable generation at or below the
                // current bound; min-reduce proposes the globally newest one
                // everyone might hold.
                let cand = intact
                    .iter()
                    .rev()
                    .map(|&i| i as i64)
                    .find(|&i| i <= bound)
                    .unwrap_or(-1)
                    .max(if mirror_latest <= bound { mirror_latest } else { -1 });
                let prop = comm
                    .allreduce_scalar(cand as f32, crate::mpi::ReduceOp::Min)
                    .await? as i64;
                if prop < 0 {
                    break; // some rank has nothing intact left: escalate
                }
                // Vote: a rank whose newest intact copy is *older* than the
                // proposal cannot serve it — a second min-reduce detects the
                // hole and the whole job falls back one generation together.
                let vote = if serves(prop) { prop as f32 } else { -1.0 };
                let v = comm
                    .allreduce_scalar(vote, crate::mpi::ReduceOp::Min)
                    .await? as i64;
                if v == prop {
                    agreed = prop;
                    break;
                }
                rounds += 1;
                if rank == 0 {
                    w.metrics.record_retry();
                }
                if rounds > w.cfg.retry_budget {
                    break; // budget exhausted: escalate
                }
                bound = prop - 1;
            }
            if rank == 0 {
                if agreed < 0 {
                    // Every generation corrupted (or disagreement past the
                    // budget): graceful degradation. The job restarts from
                    // iteration 0, booked as an escalated degraded re-deploy
                    // on the failure's segment.
                    w.metrics.record_escalation();
                    w.metrics.record_degrade_any();
                    let tr = w.sim.tracer();
                    if tr.is_on() {
                        tr.instant("integrity", "escalate", 0, w.sim.now());
                    }
                } else if baseline > agreed {
                    w.metrics.add_fallback_iters((baseline - agreed) as u64);
                }
            }
            Ok::<i64, MpiError>(agreed)
        })
        .await
        .map_err(|e| (e, Rc::clone(&comm)))?;
    }
    let mut start_iter = 0u32;
    if agreed >= 0 {
        start_iter = Box::pin(async {
            let it = agreed as u32;
            let mirror = w.repl.as_ref().and_then(|r| r.snapshot(rank, it));
            if let Some(bytes) = mirror {
                // Failover restore: the shadow already holds the agreed
                // iteration in memory on the promoted host — no storage
                // read, no re-execution. This is the zero-rollback path
                // replication buys with its mirror bandwidth.
                app_state.restore(&bytes);
                return it + 1;
            }
            let t0 = w.sim.now();
            match w.ckpt.load(rank, slot.node, it).await {
                Some(bytes) => {
                    app_state.restore(&bytes);
                    w.metrics.add_ckpt_read(rank, w.sim.now() - t0);
                    // Tier-aware recovery: the failure degraded some ranks'
                    // replica sets; every rank re-establishes its missing
                    // copies before resuming, so the next failure finds full
                    // redundancy again. No-op (zero cost) for ranks whose
                    // copies all survived.
                    if w.faults.any_fired() {
                        let t1 = w.sim.now();
                        w.ckpt.rebuild(rank, slot.node, it, &bytes).await;
                        w.metrics.add_ckpt_write(rank, w.sim.now() - t1);
                    }
                    it + 1
                }
                // The agreed copy can legally be gone by load time: a
                // failure landing before the first checkpoint completes, or
                // a second failure erasing the copies between the agreement
                // and this read (mid-recovery storms). Restart from
                // iteration 0 instead of crashing the harness — exactly
                // what a real job would do with nothing on stable storage.
                None => 0,
            }
        })
        .await;
    }

    for iter in start_iter..w.cfg.iters {
        // Fault injection at the start of the anchored iteration (paper §4);
        // the cursor fires each timeline event exactly once, tolerating
        // post-rollback re-execution of the same iteration.
        if let Some(ev) = w.faults.should_fire(rank, iter) {
            if ev.corrupt {
                // Silent storage corruption: every copy of this rank's
                // newest checkpoint generation is torn. Nothing dies and
                // nothing is detected here — the damage surfaces only when
                // a later recovery verifies-on-load.
                w.ckpt.corrupt_rank_latest(rank);
                let tr = w.sim.tracer();
                if tr.is_on() {
                    tr.instant("integrity", "corrupt", 0, w.sim.now());
                }
            } else {
                w.metrics.record_failure(w.sim.now(), ev.kind, rank);
                w.trace_mark("failure");
                match ev.kind {
                    FailureKind::Process => {
                        w.ckpt.lose_rank(rank);
                        ctx.cluster.kill_rank(rank); // SIGKILL to self
                    }
                    FailureKind::Node => {
                        let victims: Vec<u32> = (0..w.cfg.ranks)
                            .filter(|&r| ctx.cluster.rank_slot(r).node == slot.node)
                            .collect();
                        w.ckpt.lose_node_ranks(&victims);
                        ctx.cluster.kill_node(slot.node);
                    }
                    FailureKind::None => unreachable!("corrupt handled above"),
                }
                // The kill drops this task the moment it yields.
                w.sim.halt_forever().await;
            }
        }

        let cx = StepCtx {
            sim: &w.sim,
            comm: &comm,
            backend: &backend,
        };
        app_state
            .step(cx, iter)
            .await
            .map_err(|e| (e, Rc::clone(&comm)))?;
        if rank == 0 {
            w.diag_trace.borrow_mut().push((
                w.sim.now().secs_f64(),
                iter,
                app_state.diagnostic(),
            ));
            // Advance the iteration frontier (closes rollback accounting
            // for recovered failure segments). Host-side only.
            w.metrics.record_iter_done(iter, w.sim.now());
        }

        if iter % w.cfg.ckpt_every == 0 {
            let t0 = w.sim.now();
            w.ckpt
                .save(rank, slot.node, iter, app_state.serialize())
                .await;
            w.metrics.add_ckpt_write(rank, w.sim.now() - t0);
        }

        // Replication: push this iteration's state to the shadow replica
        // (every iteration — the mirror must track the frontier, not the
        // checkpoint interval, or failover would roll back). The transfer
        // serializes on the primary's NIC; that stall is the replication
        // compute/bandwidth overhead the crossover sweep measures.
        if let Some(repl) = w.repl.as_ref() {
            if let Some(shadow) = repl.shadow_node(rank) {
                let bytes = app_state.serialize();
                let t0 = w.sim.now();
                ctx.mpi
                    .mirror_state(ctx.cluster.rank_slot(rank).node, shadow, bytes.len())
                    .await;
                repl.push(rank, iter, bytes, w.sim.now() - t0);
            }
        }
    }

    w.digests.borrow_mut()[rank as usize] = Some(app_state.digest());
    w.completed.mark(rank);
    ctx.done_tx.send(rank, SimDuration::ZERO);
    let _ = state; // informational (apps are state-agnostic; see paper Fig. 2)
    Ok(())
}

/// Schedule every virtual-time-anchored timeline event, exactly once per
/// trial. The scheduled kill resolves its victim against the deployment
/// live at fire time via `TrialWorld::cur_cluster`.
fn arm_time_faults(w: &Rc<TrialWorld>) {
    for (idx, secs) in w.faults.time_schedule() {
        let w2 = Rc::clone(w);
        w.sim.schedule(SimDuration::from_secs_f64(secs), move || {
            fire_time_fault(&w2, idx);
        });
    }
}

/// Execute a virtual-time-anchored kill. Mirrors the iteration-anchored
/// path in `rank_user_main` (record, erase the dead hosts' checkpoint
/// copies, SIGKILL), except it runs from the scheduler, so it can land
/// mid-recovery, mid-checkpoint, or between CR deployments. A kill that
/// finds its victim already dead — or the job complete / torn down — hits
/// dead air and is recorded as a no-op.
fn fire_time_fault(w: &Rc<TrialWorld>, idx: usize) {
    let ev = w.faults.event(idx);
    if w.completed.count() == w.cfg.ranks {
        // job already released the allocation: explicit, logged no-op
        w.faults.mark_noop(idx);
        w.metrics.record_noop_event(w.sim.now(), ev.kind, ev.rank);
        return;
    }
    if ev.corrupt {
        // Storage corruption needs no live victim: the checkpoint copies
        // outlive the process (and, in the fs tier, the deployment), so a
        // `corrupt@tX` lands on whatever the store holds right now.
        w.faults.mark_fired(idx);
        w.ckpt.corrupt_rank_latest(ev.rank);
        let tr = w.sim.tracer();
        if tr.is_on() {
            tr.instant("integrity", "corrupt", 0, w.sim.now());
        }
        return;
    }
    let cluster = w.cur_cluster.borrow().clone();
    let Some(cluster) = cluster else {
        w.faults.mark_noop(idx);
        w.metrics.record_noop_event(w.sim.now(), ev.kind, ev.rank);
        return;
    };
    if !cluster.rank_is_alive(ev.rank) {
        // Between deployments, or the victim is already down — after a
        // shrink the planned victim may simply no longer exist in the live
        // world. Either way the event lands on the metric record as an
        // explicit zero-cost segment instead of vanishing.
        w.faults.mark_noop(idx);
        w.metrics.record_noop_event(w.sim.now(), ev.kind, ev.rank);
        return;
    }
    w.faults.mark_fired(idx);
    w.metrics.record_failure(w.sim.now(), ev.kind, ev.rank);
    w.trace_mark("failure");
    match ev.kind {
        FailureKind::Process => {
            w.ckpt.lose_rank(ev.rank);
            cluster.kill_rank(ev.rank);
        }
        FailureKind::Node => {
            let node = cluster.rank_slot(ev.rank).node;
            let victims: Vec<u32> = (0..w.cfg.ranks)
                .filter(|&r| cluster.rank_slot(r).node == node)
                .collect();
            w.ckpt.lose_node_ranks(&victims);
            cluster.kill_node(node);
        }
        FailureKind::None => unreachable!("corrupt events handled above"),
    }
}

/// Schedule the unreliable detector's false suspicions, exactly once per
/// trial. Each suspicion is delayed by the confirmation backoff — the
/// detector waits `suspect_timeout_s * 2^n` before convicting a rank it
/// has already slandered `n` times — then lands on whatever deployment is
/// live, exactly like a time-anchored kill.
fn arm_suspicions(w: &Rc<TrialWorld>) {
    for s in &w.suspicions.events {
        let nth = {
            let mut counts = w.suspicion_counts.borrow_mut();
            let e = counts.entry(s.rank).or_insert(0);
            let n = *e;
            *e += 1;
            n
        };
        let delay = SimDuration::from_secs_f64(s.at_s)
            + suspicion_backoff(w.cfg.suspect_timeout_s, nth);
        let w2 = Rc::clone(w);
        let rank = s.rank;
        w.sim.schedule(delay, move || {
            fire_suspicion(&w2, rank);
        });
    }
}

/// Execute one false suspicion: the detector convicts a healthy rank, and
/// the runtime — which cannot tell a slander from a SIGKILL — evicts the
/// process and pays for a full, real recovery. A suspicion finding its
/// victim already dead (or the job complete / between deployments) is
/// silently absorbed, as a real group-membership service would.
fn fire_suspicion(w: &Rc<TrialWorld>, rank: u32) {
    if w.completed.count() == w.cfg.ranks {
        return;
    }
    let cluster = w.cur_cluster.borrow().clone();
    let Some(cluster) = cluster else { return };
    if !cluster.rank_is_alive(rank) {
        return;
    }
    w.metrics.record_spurious();
    w.metrics.record_failure(w.sim.now(), FailureKind::Process, rank);
    let tr = w.sim.tracer();
    if tr.is_on() {
        tr.instant("detect", "suspect", 0, w.sim.now());
    }
    // The eviction is indistinguishable from a process failure downstream:
    // in-memory checkpoint copies die with the victim and the normal
    // detect → recover machinery takes over from here.
    w.ckpt.lose_rank(rank);
    cluster.kill_rank(rank);
}

/// The protocol-agnostic whole-trial loop: deploy, hand the deployment to
/// the recovery driver, wait for completion or an abort request, and
/// re-deploy after aborts (CR's every failure; Reinit++/ULFM only on
/// spare-pool exhaustion) until the job finishes.
pub async fn trial_driver(w: Rc<TrialWorld>, driver: Rc<dyn RecoveryDriver>) {
    // Re-deploy bound: CR redeploys at most once per timeline event (false
    // suspicions included — each triggers a real recovery), plus headroom
    // for degraded in-place recoveries.
    let max_deploys = 16 + w.faults.len() as u32 + w.suspicions.len() as u32;
    let mut deployment = 0u32;
    let mut timing_started = false;
    loop {
        let (ctx, detect_rx, done_rx) =
            launch_job(&w, &format!("{}-deploy{deployment}", driver.tag()));
        *w.cur_cluster.borrow_mut() = Some(ctx.cluster.clone());
        w.sim.sleep(w.deploy.mpirun_launch(&w.topo())).await;
        if !timing_started {
            // the paper times the application, not the first submission
            w.metrics.set_job_start(w.sim.now());
            timing_started = true;
            // Virtual-time anchors (explicit `@tX` events, MTBF arrivals)
            // count from application start, the same clock the paper's
            // breakdown uses — not from the mpirun submission. The
            // unreliable detector's false suspicions share that clock.
            arm_time_faults(&w);
            arm_suspicions(&w);
        }
        driver.deploy(&ctx, detect_rx);

        // Wait for completion or an abort request.
        let mut aborted = false;
        while w.completed.count() < w.cfg.ranks {
            match done_rx.recv().await {
                Ok(ABORT) => {
                    aborted = true;
                    break;
                }
                Ok(_rank) => {}
                Err(_) => break,
            }
        }
        if !aborted {
            break;
        }
        // The abort killed every process: in-memory checkpoint tiers (and
        // any undrained copies) die with them. Only the filesystem tier
        // survives re-deployment — which is why CR needs one (Table 2).
        w.ckpt.lose_all_memory();
        // RTE teardown + scheduler epilogue, then re-deploy.
        w.sim.sleep(w.deploy.teardown()).await;
        deployment += 1;
        assert!(
            deployment < max_deploys,
            "recovery livelock: more re-deployments than timeline events"
        );
    }
    w.metrics.set_job_end(w.sim.now());
}

/// Run one trial end to end; returns the paper's breakdown + validation
/// data. Tracing follows the process-wide destination installed by the CLI
/// (`trace::global()`); tests wanting a capture pass one explicitly to
/// [`run_trial_with`].
pub fn run_trial(
    cfg: &ExperimentConfig,
    trial: u32,
    xla: Option<Rc<XlaRuntime>>,
) -> TrialResult {
    run_trial_with(cfg, trial, xla, crate::trace::global().as_ref())
}

/// [`run_trial`] with an explicit trace destination. When `trace` is set,
/// the sim runs with an armed recorder and the trial's capture is written
/// under `trace.dir` as three files keyed by the trial's identity hash:
/// `trace_<id>.trace.json` (Perfetto), `trace_<id>.folded` (flamegraph),
/// and `trace_<id>.profile.json`. Recording is observation-only, so
/// results are identical with or without it. The executor shard count
/// follows the process-wide `--shards` knob.
pub fn run_trial_with(
    cfg: &ExperimentConfig,
    trial: u32,
    xla: Option<Rc<XlaRuntime>>,
    trace: Option<&crate::trace::TraceConfig>,
) -> TrialResult {
    run_trial_opts(cfg, trial, xla, trace, crate::sim::global_shards())
}

/// [`run_trial_with`] with an explicit executor shard count. Sharding is a
/// *host* knob like `--jobs`: results are byte-identical for any value
/// (asserted in `tests/shard_determinism.rs`), so it never enters the
/// trial's identity hash. Tests pass it explicitly instead of mutating the
/// process-wide default, which would leak across parallel test threads.
pub fn run_trial_opts(
    cfg: &ExperimentConfig,
    trial: u32,
    xla: Option<Rc<XlaRuntime>>,
    trace: Option<&crate::trace::TraceConfig>,
    shards: usize,
) -> TrialResult {
    cfg.validate().expect("invalid experiment config");
    let sim = Sim::new();
    // generous runaway guard (events scale with ranks * iters)
    sim.set_event_limit(200_000_000);
    sim.set_shards(shards.max(1));
    if shards > 1 {
        // Conservative lookahead = the smallest latency any cross-node
        // (hence cross-shard, under the node-aligned plan) message can
        // have under this calibration.
        sim.set_lookahead(
            crate::transport::NetCost::from_calib(&cfg.calib).min_remote_latency(),
        );
    }
    if let Some(tc) = trace {
        sim.trace_install(crate::trace::Recorder::new(cfg.ranks, tc.filter.clone()));
    }
    let world = TrialWorld::new(&sim, cfg, trial, xla);

    let driver_proc = sim.spawn_process("trial-driver");
    let w2 = Rc::clone(&world);
    let driver = driver_for(cfg.recovery);
    sim.spawn(driver_proc, async move {
        trial_driver(w2, driver).await;
    });
    let summary = sim.run();
    let completed = world.completed.count() == cfg.ranks;
    let breakdown = world.metrics.breakdown();
    let digests: Vec<u64> = world
        .digests
        .borrow()
        .iter()
        .map(|d| d.unwrap_or(0))
        .collect();
    let faults = world.faults.outcomes();
    let segments = world.metrics.segments();
    let diag_trace = world.diag_trace.borrow().clone();
    let storage = world.ckpt.storage_stats();
    let (failovers, mirror_s, mirror_mb) = match world.repl.as_ref() {
        Some(r) => (
            r.failovers(),
            r.mirror_stall_s(),
            r.mirror_traffic().1 as f64 / 1e6,
        ),
        None => (0, 0.0, 0.0),
    };
    let counters = crate::trace::TrialCounters {
        identity: crate::trace::identity_hash(cfg, trial),
        end_s: summary.end_time.secs_f64(),
        events: summary.events,
        polls: summary.polls,
        peak_events_pending: summary.peak_events_pending,
        peak_rank_state_bytes: summary.peak_rank_state_bytes,
        tasks_completed: summary.tasks_completed,
    };
    if let Some(tc) = trace {
        if let Some(mut rec) = sim.trace_take() {
            // Synthesize the recovery timeline on track 0 from the metric
            // segment windows: the spans use the same saturating clock
            // arithmetic as `TrialMetrics::segments()`, so per-name span
            // totals sum exactly to the FailureSegment durations.
            for wd in world.metrics.segment_windows() {
                rec.span("recovery", wd.name, 0, wd.begin, wd.end);
            }
            write_trial_trace(cfg, trial, &counters, &rec, &segments, tc);
        }
    }
    TrialResult {
        breakdown,
        digests,
        completed,
        faults,
        segments,
        sim_events: summary.events,
        diag_trace,
        shrinks: world.shrinks.get(),
        redistribute_mb: storage.redistributed_bytes as f64 / 1e6,
        storage,
        failovers,
        mirror_s,
        mirror_mb,
        fallback_iters: world.metrics.fallback_iters(),
        spurious_recoveries: world.metrics.spurious_count(),
        ckpt_retries: world.metrics.retry_count(),
        escalations: world.metrics.escalation_count(),
        counters,
    }
}

/// Write one trial's trace artifacts under `tc.dir` (best-effort: export
/// failures warn instead of sinking the trial's results).
fn write_trial_trace(
    cfg: &ExperimentConfig,
    trial: u32,
    counters: &crate::trace::TrialCounters,
    rec: &crate::trace::Recorder,
    segments: &[FailureSegment],
    tc: &crate::trace::TraceConfig,
) {
    let dir = std::path::Path::new(&tc.dir);
    if let Err(e) = std::fs::create_dir_all(dir) {
        crate::warnln!("cannot create trace dir {}: {e}", tc.dir);
        return;
    }
    let id = format!("{:016x}", counters.identity);
    let label = format!("{:?}/{}/{}", cfg.app, cfg.recovery, cfg.ranks);
    let profile = crate::trace::TrialProfile::new(
        label,
        trial,
        *counters,
        rec,
        segments.to_vec(),
    );
    let attempts = [
        crate::trace::chrome::write(dir.join(format!("trace_{id}.trace.json")), rec),
        crate::trace::folded::write(dir.join(format!("trace_{id}.folded")), rec),
        profile.write(dir.join(format!("trace_{id}.profile.json"))),
    ];
    for a in attempts {
        if let Err(e) = a {
            crate::warnln!("trace export failed under {}: {e}", tc.dir);
            return;
        }
    }
    crate::vlog!("trace: wrote trace_{id}.{{trace.json,folded,profile.json}}");
}
