//! The paper's three global-restart recovery approaches (§2, §3), a fourth
//! replication-based family, a fifth shrinking family, and the job runner
//! that hosts them on the simulated cluster.
//!
//! - `job`    — deployment, rank driver (the paper's Fig. 2 pattern:
//!              MPI_Reinit-style rollback point, checkpoint every iteration,
//!              fault injection), detection wiring, and the shared
//!              protocol-agnostic trial loop (`RecoveryDriver` +
//!              `trial_driver`): deployment sequencing, failure-timeline
//!              arming, abort/re-deploy cycles, spare-pool exhaustion.
//! - `cr`     — Checkpoint-Restart: abort on failure, tear down, re-deploy
//!              the whole job, resume from the file checkpoint.
//! - `reinit` — Reinit++: root HandleFailure (Algorithm 1) + daemon
//!              HandleReinit (Algorithm 2); survivors roll back in place,
//!              failed ranks re-spawn; only the world communicator is
//!              rebuilt.
//! - `ulfm`   — ULFM global-restart recipe: failure notification -> pending
//!              ops raise errors -> revoke -> shrink+agree -> RTE re-spawn
//!              -> merge (new communicator generation) -> roll back.
//! - `repl`   — Replication: node-disjoint shadow replicas mirror each
//!              primary's state; a primary failure promotes the shadow
//!              (failover, zero rollback); an exhausted replica group
//!              degrades to a CR-style abort + re-deploy.
//! - `shrink` — Shrinking recovery: no respawn at all — survivors adopt
//!              the victims' domain blocks, rebuild a smaller world in
//!              place, and ReStore-style redistribution rebalances the
//!              surviving checkpoint copies; below `min_ranks` the job
//!              degrades to a CR-style abort + re-deploy.

pub mod cr;
pub mod job;
pub mod reinit;
pub mod repl;
pub mod shrink;
pub mod ulfm;

#[cfg(test)]
mod tests;

pub use job::{
    driver_for, run_trial, RecoveryDriver, ReinitState, RtCache, TrialResult, TrialWorld,
};
