//! End-to-end recovery tests on the native (modeled-fidelity) backend:
//! the core *global-restart equivalence* invariant — a run that suffers a
//! failure and recovers must finish in exactly the fault-free final state —
//! plus the paper's qualitative performance orderings.

use super::job::run_trial;
use crate::config::{
    AppKind, CkptKind, ExperimentConfig, FailureKind, Fidelity, RecoveryKind,
};

fn base_cfg(app: AppKind, recovery: RecoveryKind, failure: FailureKind) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.app = app;
    c.recovery = recovery;
    c.failure = failure;
    c.ranks = 8;
    c.ranks_per_node = 4;
    c.spare_nodes = 1;
    c.iters = 6;
    c.fidelity = Fidelity::Modeled;
    c.comd_n = 32;
    c.hpccg_nx = 4;
    c.lulesh_nx = 4;
    c.seed = 1234;
    c
}

fn digests_of(cfg: &ExperimentConfig, trial: u32) -> Vec<u64> {
    let r = run_trial(cfg, trial, None);
    assert!(r.completed, "{:?}/{:?} did not complete", cfg.app, cfg.recovery);
    assert!(r.digests.iter().all(|&d| d != 0));
    r.digests
}

#[test]
fn fault_free_all_apps_complete() {
    for app in AppKind::ALL {
        let cfg = base_cfg(app, RecoveryKind::Reinit, FailureKind::None);
        let r = run_trial(&cfg, 0, None);
        assert!(r.completed, "{app}");
        assert_eq!(r.breakdown.mpi_recovery_s, 0.0);
        assert!(r.breakdown.total_s > 0.0);
    }
}

#[test]
fn fault_free_digest_identical_across_recovery_modes() {
    // CR and Reinit must not perturb the computation at all; ULFM inflates
    // time but not values; replication's mirroring costs time, not values;
    // shrink shares Reinit++'s fault-free path entirely.
    for app in AppKind::ALL {
        let base = digests_of(&base_cfg(app, RecoveryKind::Reinit, FailureKind::None), 0);
        for rk in [
            RecoveryKind::Cr,
            RecoveryKind::Ulfm,
            RecoveryKind::Replication,
            RecoveryKind::Shrink,
        ] {
            let d = digests_of(&base_cfg(app, rk, FailureKind::None), 0);
            assert_eq!(d, base, "{app} {rk}");
        }
    }
}

fn check_equivalence(app: AppKind, recovery: RecoveryKind, failure: FailureKind, trial: u32) {
    let fault_free = digests_of(&base_cfg(app, recovery, FailureKind::None), trial);
    let cfg = base_cfg(app, recovery, failure);
    let r = run_trial(&cfg, trial, None);
    assert!(
        r.completed,
        "{app}/{recovery}/{failure} trial {trial} hung (fault {:?})",
        r.faults
    );
    assert!(r.breakdown.mpi_recovery_s > 0.0, "no recovery recorded");
    assert_eq!(
        r.digests, fault_free,
        "{app}/{recovery}/{failure}: recovered state differs from fault-free (fault {:?})",
        r.faults
    );
}

#[test]
fn reinit_process_failure_equivalence_all_apps() {
    for app in AppKind::ALL {
        check_equivalence(app, RecoveryKind::Reinit, FailureKind::Process, 0);
    }
}

#[test]
fn cr_process_failure_equivalence_all_apps() {
    for app in AppKind::ALL {
        check_equivalence(app, RecoveryKind::Cr, FailureKind::Process, 0);
    }
}

#[test]
fn ulfm_process_failure_equivalence_all_apps() {
    for app in AppKind::ALL {
        check_equivalence(app, RecoveryKind::Ulfm, FailureKind::Process, 0);
    }
}

#[test]
fn reinit_node_failure_equivalence() {
    for app in [AppKind::Hpccg, AppKind::CoMD] {
        check_equivalence(app, RecoveryKind::Reinit, FailureKind::Node, 0);
    }
}

#[test]
fn cr_node_failure_equivalence() {
    check_equivalence(AppKind::Hpccg, RecoveryKind::Cr, FailureKind::Node, 0);
}

#[test]
fn node_failure_equivalence_all_recoveries_comd_lulesh() {
    // The node column of the equivalence matrix for the two apps the
    // single-failure suite above does not cover (the paper's own ULFM
    // prototype could not run node failures at all; ours can), checked
    // against the fault-free oracle digests.
    for app in [AppKind::CoMD, AppKind::Lulesh] {
        for recovery in RecoveryKind::ALL {
            check_equivalence(app, recovery, FailureKind::Node, 0);
        }
    }
}

#[test]
fn equivalence_over_random_trials_property() {
    // property sweep: several trials = several (iteration, victim) draws
    for trial in 0..4 {
        check_equivalence(AppKind::Hpccg, RecoveryKind::Reinit, FailureKind::Process, trial);
    }
}

#[test]
fn recovery_time_ordering_cr_slowest() {
    // Fig. 6 shape: CR ≈ 3 s; Reinit++ ≈ 0.5 s; ULFM in between at small N.
    let reinit = run_trial(
        &base_cfg(AppKind::Hpccg, RecoveryKind::Reinit, FailureKind::Process),
        0,
        None,
    );
    let cr = run_trial(
        &base_cfg(AppKind::Hpccg, RecoveryKind::Cr, FailureKind::Process),
        0,
        None,
    );
    let ulfm = run_trial(
        &base_cfg(AppKind::Hpccg, RecoveryKind::Ulfm, FailureKind::Process),
        0,
        None,
    );
    let (tr, tc, tu) = (
        reinit.breakdown.mpi_recovery_s,
        cr.breakdown.mpi_recovery_s,
        ulfm.breakdown.mpi_recovery_s,
    );
    assert!(tc > 2.0 && tc < 5.0, "CR anchor ≈3 s, got {tc}");
    assert!(tr > 0.2 && tr < 0.9, "Reinit++ anchor ≈0.5 s, got {tr}");
    assert!(tc > 3.0 * tr, "CR must be several x slower: {tc} vs {tr}");
    assert!(tu > tr * 0.5, "ULFM comparable at small scale: {tu} vs {tr}");
}

#[test]
fn node_failure_recovery_slower_than_process() {
    // Fig. 7: Reinit++ ≈1.5 s for node vs ≈0.5 s for process failures.
    let mut proc_cfg = base_cfg(AppKind::Hpccg, RecoveryKind::Reinit, FailureKind::Process);
    proc_cfg.ckpt = Some(CkptKind::File); // same scheme for a fair contrast
    let node_cfg = base_cfg(AppKind::Hpccg, RecoveryKind::Reinit, FailureKind::Node);
    let tp = run_trial(&proc_cfg, 0, None).breakdown.mpi_recovery_s;
    let tn = run_trial(&node_cfg, 0, None).breakdown.mpi_recovery_s;
    assert!(tn > 1.8 * tp, "node recovery must cost much more: {tn} vs {tp}");
    // at the test's 4 ranks/node the respawn batch is smaller than the
    // paper's 16/node, so the anchor scales down from ~1.5 s accordingly
    assert!(tn > 0.8 && tn < 2.5, "node anchor, got {tn}");
}

#[test]
fn ulfm_inflates_pure_app_time() {
    // Fig. 5: ULFM's heartbeat/FT-wrappers tax fault-free execution.
    // Use a compute-dominated size so the inflation is visible over the
    // (identical) communication time.
    let mut r_cfg = base_cfg(AppKind::Hpccg, RecoveryKind::Reinit, FailureKind::None);
    r_cfg.hpccg_nx = 16;
    let mut u_cfg = r_cfg.clone();
    u_cfg.recovery = RecoveryKind::Ulfm;
    let reinit = run_trial(&r_cfg, 0, None);
    let ulfm = run_trial(&u_cfg, 0, None);
    let (ar, au) = (reinit.breakdown.app_s(), ulfm.breakdown.app_s());
    assert!(au > ar * 1.02, "ULFM app time must inflate: {au} vs {ar}");
}

#[test]
fn file_ckpt_writes_cost_more_than_memory() {
    // Fig. 4 mechanism: CR's file checkpoints vs Reinit++'s buddy memory.
    let cr = run_trial(
        &base_cfg(AppKind::Hpccg, RecoveryKind::Cr, FailureKind::None),
        0,
        None,
    );
    let reinit = run_trial(
        &base_cfg(AppKind::Hpccg, RecoveryKind::Reinit, FailureKind::None),
        0,
        None,
    );
    assert!(
        cr.breakdown.ckpt_write_s > 3.0 * reinit.breakdown.ckpt_write_s,
        "file {} vs memory {}",
        cr.breakdown.ckpt_write_s,
        reinit.breakdown.ckpt_write_s
    );
}

#[test]
fn trial_is_deterministic() {
    let cfg = base_cfg(AppKind::Lulesh, RecoveryKind::Reinit, FailureKind::Process);
    let a = run_trial(&cfg, 1, None);
    let b = run_trial(&cfg, 1, None);
    assert_eq!(a.digests, b.digests);
    assert_eq!(a.breakdown.total_s, b.breakdown.total_s);
    assert_eq!(a.sim_events, b.sim_events);
}

#[test]
fn victim_rank_state_restored_via_buddy() {
    // memory checkpointing: the victim's state must come from its buddy
    let cfg = base_cfg(AppKind::Hpccg, RecoveryKind::Reinit, FailureKind::Process);
    assert_eq!(cfg.effective_ckpt(), CkptKind::Memory);
    let fault_free = digests_of(&base_cfg(cfg.app, cfg.recovery, FailureKind::None), 2);
    let r = run_trial(&cfg, 2, None);
    assert!(r.completed);
    let victim = r.faults.iter().find(|f| f.fired).expect("fault fired").event.rank as usize;
    assert_eq!(r.digests[victim], fault_free[victim], "victim state wrong");
}

// ---- multi-failure scenario engine -------------------------------------

/// Base config with an explicit failure timeline applied.
fn scenario_cfg(recovery: RecoveryKind, failures: &str) -> ExperimentConfig {
    let mut c = base_cfg(AppKind::Hpccg, recovery, FailureKind::Process);
    c.iters = 8;
    c.apply("failures", failures).unwrap();
    c
}

/// Fault-free twin of a scenario config (same app/scale/iters).
fn fault_free_twin(cfg: &ExperimentConfig) -> ExperimentConfig {
    let mut free = cfg.clone();
    free.failures.clear();
    free.mtbf_s = 0.0;
    free.failure = FailureKind::None;
    free
}

#[test]
fn multi_failure_timeline_equivalence_all_recoveries() {
    // One process failure then one node failure in a single trial: the
    // paper's model can express neither. Digests must still match the
    // fault-free oracle under every recovery driver.
    for recovery in RecoveryKind::ALL {
        let cfg = scenario_cfg(recovery, "proc@2:r1,node@5:r6");
        let want = digests_of(&fault_free_twin(&cfg), 0);
        let r = run_trial(&cfg, 0, None);
        assert!(r.completed, "{recovery}: 2-failure trial hung ({:?})", r.faults);
        assert_eq!(r.digests, want, "{recovery}: digests differ after storm");
        assert_eq!(
            r.faults.iter().filter(|f| f.fired).count(),
            2,
            "{recovery}: both events must fire: {:?}",
            r.faults
        );
        assert_eq!(r.segments.len(), 2, "{recovery}: one segment per event");
        assert!(
            r.segments.iter().all(|s| s.recovery_s > 0.0 || s.interrupted),
            "{recovery}: every completed segment records recovery: {:?}",
            r.segments
        );
    }
}

#[test]
fn three_failure_storm_with_mid_recovery_failure_all_recoveries() {
    // Acceptance scenario: process failure, node failure, and a third
    // failure fired by virtual time 90% of the way through the node
    // event's recovery window — inside the CR teardown/relaunch, in the
    // tail of the in-place recoveries. Self-calibrating: a probe run
    // measures the window so the test stays pinned under calibration
    // changes.
    for recovery in RecoveryKind::ALL {
        let probe_cfg = scenario_cfg(recovery, "proc@2:r1,node@5:r6");
        let probe = run_trial(&probe_cfg, 0, None);
        assert!(probe.completed, "{recovery}: probe hung");
        let node_seg = &probe.segments[1];
        assert_eq!(node_seg.kind, FailureKind::Node, "{recovery}: {:?}", probe.segments);
        assert!(node_seg.recovery_s > 0.0, "{recovery}: {node_seg:?}");
        let t3 = node_seg.fail_s + node_seg.detect_s + 0.9 * node_seg.recovery_s;
        let cfg = scenario_cfg(
            recovery,
            &format!("proc@2:r1,node@5:r6,proc@t{t3:.6}:r3"),
        );
        let want = digests_of(&fault_free_twin(&cfg), 0);
        let r = run_trial(&cfg, 0, None);
        assert!(r.completed, "{recovery}: 3-failure trial hung ({:?})", r.faults);
        assert_eq!(r.digests, want, "{recovery}: digests differ after 3-failure storm");
        assert_eq!(
            r.faults.iter().filter(|f| f.fired).count(),
            3,
            "{recovery}: all three must fire: {:?}",
            r.faults
        );
    }
}

#[test]
fn reinit_failure_during_recovery_restarts_recovery_exactly_once() {
    // Probe the recovery window of a single process failure, then land a
    // second kill 20 ms after detection — deterministically before any
    // rank re-enters the user function (survivor startup alone is
    // orte_barrier + comm_reinit ≈ 85 ms at default calibration). The
    // interrupted recovery must restart exactly once and still converge to
    // the fault-free state.
    let probe_cfg = scenario_cfg(RecoveryKind::Reinit, "proc@2:r1");
    let probe = run_trial(&probe_cfg, 0, None);
    assert!(probe.completed);
    let seg = &probe.segments[0];
    assert!(seg.recovery_s > 0.05, "probe recovery window too small: {seg:?}");
    let t2 = seg.fail_s + seg.detect_s + 0.02;
    let cfg = scenario_cfg(
        RecoveryKind::Reinit,
        &format!("proc@2:r1,proc@t{t2:.6}:r4"),
    );
    let want = digests_of(&fault_free_twin(&cfg), 0);
    let r = run_trial(&cfg, 0, None);
    assert!(r.completed, "mid-recovery storm hung ({:?})", r.faults);
    assert_eq!(r.digests, want, "digests differ after interrupted recovery");
    assert_eq!(r.segments.len(), 2, "{:?}", r.segments);
    assert!(
        r.segments[0].interrupted,
        "first recovery must be recorded as restarted: {:?}",
        r.segments
    );
    assert!(!r.segments[1].interrupted);
    assert!(r.segments[1].recovery_s > 0.0);
}

#[test]
fn node_failures_beyond_spares_degrade_to_redeploy() {
    // Two node failures against one spare: the first recovers in place
    // onto the spare, the second exhausts the pool and must degrade to a
    // CR-style abort + re-deploy — recorded on the event's segment — and
    // the trial still converges to the fault-free state.
    for recovery in [RecoveryKind::Reinit, RecoveryKind::Ulfm] {
        let cfg = scenario_cfg(recovery, "node@2:r1,node@5:r6");
        assert_eq!(cfg.spare_nodes, 1);
        let want = digests_of(&fault_free_twin(&cfg), 0);
        let r = run_trial(&cfg, 0, None);
        assert!(r.completed, "{recovery}: exhaustion trial hung ({:?})", r.faults);
        assert_eq!(r.digests, want, "{recovery}: digests differ");
        assert_eq!(r.segments.len(), 2, "{recovery}: {:?}", r.segments);
        assert!(
            !r.segments[0].degraded_redeploy,
            "{recovery}: first node failure fits the spare: {:?}",
            r.segments
        );
        assert!(
            r.segments[1].degraded_redeploy,
            "{recovery}: second node failure must exhaust the pool: {:?}",
            r.segments
        );
    }
    // CR re-deploys on every failure by definition: never "degraded".
    let cfg = scenario_cfg(RecoveryKind::Cr, "node@2:r1,node@5:r6");
    let r = run_trial(&cfg, 0, None);
    assert!(r.completed);
    assert!(r.segments.iter().all(|s| !s.degraded_redeploy));
}

// ---- replication: failover without rollback ----------------------------

/// Scenario config for the replication family at `repl_degree=2` (one
/// node-disjoint shadow per rank; the test topology's 2 compute nodes are
/// exactly enough).
fn repl_cfg(failures: &str) -> ExperimentConfig {
    let mut c = scenario_cfg(RecoveryKind::Replication, failures);
    c.repl_degree = 2;
    c
}

#[test]
fn repl_process_failure_equivalence_all_apps() {
    for app in AppKind::ALL {
        let mut cfg = base_cfg(app, RecoveryKind::Replication, FailureKind::Process);
        cfg.repl_degree = 2;
        let fault_free = digests_of(&base_cfg(app, RecoveryKind::Replication, FailureKind::None), 0);
        let r = run_trial(&cfg, 0, None);
        assert!(r.completed, "{app}: failover trial hung ({:?})", r.faults);
        assert_eq!(r.digests, fault_free, "{app}: failover perturbed the state");
        assert_eq!(r.failovers, 1, "{app}: one promotion expected");
    }
}

#[test]
fn repl_failover_has_zero_rollback_and_books_failover_time() {
    // The tentpole invariant: a primary death promotes the shadow — the
    // run resumes at the iteration frontier, re-executing nothing, and the
    // cost lands in the new failover accounting, not recovery/rollback.
    let cfg = repl_cfg("proc@2:r1");
    let want = digests_of(&fault_free_twin(&cfg), 0);
    let r = run_trial(&cfg, 0, None);
    assert!(r.completed, "failover trial hung ({:?})", r.faults);
    assert_eq!(r.digests, want, "failover must not perturb the computation");
    assert_eq!(r.segments.len(), 1, "{:?}", r.segments);
    let seg = &r.segments[0];
    assert!(seg.failover, "segment must be a failover: {seg:?}");
    assert!(!seg.degraded_redeploy);
    assert!(seg.failover_s > 0.0, "promotion window recorded: {seg:?}");
    assert_eq!(seg.recovery_s, 0.0, "cost lives in failover_s: {seg:?}");
    assert_eq!(seg.rollback_s, 0.0, "zero rollback by construction: {seg:?}");
    assert_eq!(r.failovers, 1);
    // the mirror traffic that buys the zero rollback is visible
    assert!(r.mirror_s > 0.0, "mirror stall must be charged");
    assert!(r.mirror_mb > 0.0, "mirror bytes must be counted");
}

#[test]
fn repl_failover_beats_rollback_recoveries() {
    // Failover skips the ORTE barrier and the checkpoint read and rolls
    // nothing back: its disruption must undercut Reinit++ (the fastest
    // rollback family) for the same failure.
    let repl = run_trial(&repl_cfg("proc@2:r1"), 0, None);
    let reinit = run_trial(&scenario_cfg(RecoveryKind::Reinit, "proc@2:r1"), 0, None);
    assert!(repl.completed && reinit.completed);
    let tf = repl.segments[0].failover_s;
    let tr = reinit.segments[0].recovery_s + reinit.segments[0].rollback_s;
    assert!(
        tf < tr,
        "failover ({tf}) must undercut Reinit++ recovery+rollback ({tr})"
    );
}

#[test]
fn repl_exhausted_group_degrades_to_redeploy() {
    // Two kills on the same logical rank: the first consumes its only
    // shadow, the second finds the group empty and must degrade to a
    // CR-style abort + re-deploy — still converging via file checkpoints.
    let cfg = repl_cfg("proc@2:r1,proc@5:r1");
    let want = digests_of(&fault_free_twin(&cfg), 0);
    let r = run_trial(&cfg, 0, None);
    assert!(r.completed, "exhaustion trial hung ({:?})", r.faults);
    assert_eq!(r.digests, want, "degraded redeploy must still converge");
    assert_eq!(r.segments.len(), 2, "{:?}", r.segments);
    assert!(r.segments[0].failover, "first kill fails over: {:?}", r.segments);
    assert!(!r.segments[0].degraded_redeploy);
    assert!(
        r.segments[1].degraded_redeploy,
        "second kill exhausts the group: {:?}",
        r.segments
    );
    assert!(!r.segments[1].failover);
    assert_eq!(r.failovers, 1);
}

#[test]
fn repl_degree_one_degrades_on_first_failure() {
    // degree 1 = no replicas: replication collapses to CR-style behavior
    // (the crossover sweep's baseline row).
    let cfg = scenario_cfg(RecoveryKind::Replication, "proc@2:r1");
    assert_eq!(cfg.repl_degree, 1);
    let want = digests_of(&fault_free_twin(&cfg), 0);
    let r = run_trial(&cfg, 0, None);
    assert!(r.completed);
    assert_eq!(r.digests, want);
    assert_eq!(r.failovers, 0);
    assert!(r.segments[0].degraded_redeploy, "{:?}", r.segments);
    assert_eq!(r.mirror_mb, 0.0, "no shadow, no mirror traffic");
}

#[test]
fn repl_node_failure_kills_shadows_then_exhausted_rank_degrades() {
    // A node failure takes out four primaries AND the shadows the other
    // four ranks kept there: the dead primaries fail over to their
    // surviving shadows, and a later kill of a shadow-less rank must
    // degrade. The whole storm still converges to the fault-free state.
    let cfg = repl_cfg("node@2:r1,proc@5:r4");
    let want = digests_of(&fault_free_twin(&cfg), 0);
    let r = run_trial(&cfg, 0, None);
    assert!(r.completed, "replica-set storm hung ({:?})", r.faults);
    assert_eq!(r.digests, want, "digests differ after replica-set storm");
    assert_eq!(r.segments.len(), 2, "{:?}", r.segments);
    let node_seg = &r.segments[0];
    assert_eq!(node_seg.kind, FailureKind::Node);
    assert!(node_seg.failover, "node event promotes shadows: {:?}", r.segments);
    assert_eq!(node_seg.rollback_s, 0.0);
    let proc_seg = &r.segments[1];
    assert!(
        proc_seg.degraded_redeploy,
        "rank 4's shadow died with the node; its kill must degrade: {:?}",
        r.segments
    );
    assert_eq!(r.failovers, 1);
}

#[test]
fn repl_failure_mid_failover_still_converges() {
    // Second kill landing inside the first promotion window (20 ms after
    // detection, well under the control-tree + comm-reinit startup): the
    // root must absorb the overlap — both events resolve, digests match.
    let probe = run_trial(&repl_cfg("proc@2:r1"), 0, None);
    assert!(probe.completed);
    let seg = &probe.segments[0];
    let t2 = seg.fail_s + seg.detect_s + 0.5 * seg.failover_s.max(0.02);
    let cfg = repl_cfg(&format!("proc@2:r1,proc@t{t2:.6}:r6"));
    let want = digests_of(&fault_free_twin(&cfg), 0);
    let r = run_trial(&cfg, 0, None);
    assert!(r.completed, "mid-failover storm hung ({:?})", r.faults);
    assert_eq!(r.digests, want, "digests differ after mid-failover storm");
    assert_eq!(r.faults.iter().filter(|f| f.fired).count(), 2, "{:?}", r.faults);
}

// ---- failures before the first checkpoint ------------------------------

#[test]
fn failure_before_first_checkpoint_restarts_from_zero_all_recoveries() {
    // A kill at iteration 0 lands before any checkpoint completes: a legal
    // timeline every driver must absorb by restarting from iteration 0
    // (the seed panicked here: "globally-agreed checkpoint must exist").
    for recovery in RecoveryKind::ALL {
        let cfg = scenario_cfg(recovery, "proc@0:r1");
        let want = digests_of(&fault_free_twin(&cfg), 0);
        let r = run_trial(&cfg, 0, None);
        assert!(
            r.completed,
            "{recovery}: pre-first-checkpoint failure hung ({:?})",
            r.faults
        );
        assert_eq!(r.digests, want, "{recovery}: digests differ");
        assert_eq!(r.faults.iter().filter(|f| f.fired).count(), 1);
    }
    // and with a shadow available, replication fails over instead
    let cfg = repl_cfg("proc@0:r1");
    let want = digests_of(&fault_free_twin(&cfg), 0);
    let r = run_trial(&cfg, 0, None);
    assert!(r.completed, "repl pre-ckpt failure hung ({:?})", r.faults);
    assert_eq!(r.digests, want);
}

#[test]
fn mtbf_storm_trial_is_deterministic_and_correct() {
    // End-to-end MTBF arrival process: deterministic replay, digests equal
    // the fault-free oracle, and the drawn timeline is identical across
    // recovery methods (the draw must not depend on the recovery).
    let mut cfg = base_cfg(AppKind::Hpccg, RecoveryKind::Reinit, FailureKind::Process);
    cfg.iters = 10;
    cfg.mtbf_s = 0.2;
    cfg.max_failures = 3;
    // stretch the app clock so arrivals land inside the run (see
    // presets::STORM_COMPUTE_SCALE)
    cfg.calib.modeled_compute_scale = crate::config::presets::STORM_COMPUTE_SCALE;
    let want = digests_of(&fault_free_twin(&cfg), 1);
    let a = run_trial(&cfg, 1, None);
    let b = run_trial(&cfg, 1, None);
    assert!(a.completed);
    assert_eq!(a.digests, want, "storm must not perturb the computation");
    assert_eq!(a.digests, b.digests);
    assert_eq!(a.sim_events, b.sim_events, "virtual-time determinism");
    assert_eq!(a.faults, b.faults);
    let mut cr = cfg.clone();
    cr.recovery = RecoveryKind::Cr;
    let rc = run_trial(&cr, 1, None);
    assert!(rc.completed, "CR under the same storm hung ({:?})", rc.faults);
    assert_eq!(rc.digests, want);
    assert_eq!(
        rc.faults.iter().map(|f| f.event).collect::<Vec<_>>(),
        a.faults.iter().map(|f| f.event).collect::<Vec<_>>(),
        "timeline must be recovery-independent"
    );
}

// ---- shrinking recovery: continue on survivors -------------------------

/// Scenario config for the shrink family with **zero** spare nodes — the
/// family's whole point is needing no over-provisioning.
fn shrink_cfg(failures: &str) -> ExperimentConfig {
    let mut c = scenario_cfg(RecoveryKind::Shrink, failures);
    c.spare_nodes = 0;
    c
}

#[test]
fn shrink_process_failure_equivalence_all_apps_zero_spares() {
    // Acceptance: shrink digests equal the fault-free oracle for every app
    // under a process failure with no spare capacity at all. The logical
    // decomposition never changes — survivors just carry the victims'
    // blocks — so the recovered state must be bitwise-identical.
    for app in AppKind::ALL {
        let mut cfg = base_cfg(app, RecoveryKind::Shrink, FailureKind::Process);
        cfg.spare_nodes = 0;
        let mut free = cfg.clone();
        free.failure = FailureKind::None;
        let want = digests_of(&free, 0);
        let r = run_trial(&cfg, 0, None);
        assert!(r.completed, "{app}: shrink trial hung ({:?})", r.faults);
        assert_eq!(r.digests, want, "{app}: shrink perturbed the state");
        assert_eq!(r.shrinks, 1, "{app}: exactly one shrink");
        assert_eq!(r.segments.len(), 1, "{app}: {:?}", r.segments);
        let seg = &r.segments[0];
        assert!(seg.shrunk, "{app}: segment must be a shrink: {seg:?}");
        assert!(!seg.degraded_redeploy, "{app}: no spare needed: {seg:?}");
        assert!(seg.recovery_s > 0.0, "{app}: shrink window booked: {seg:?}");
    }
}

#[test]
fn shrink_node_failure_equivalence_all_apps_zero_spares() {
    // The in-place recoveries require >= 1 spare node for node failures
    // (config validation enforces it); shrink is exempt — the survivors of
    // the other node adopt the dead node's blocks.
    for app in AppKind::ALL {
        let mut cfg = base_cfg(app, RecoveryKind::Shrink, FailureKind::Node);
        cfg.spare_nodes = 0;
        let mut free = cfg.clone();
        free.failure = FailureKind::None;
        let want = digests_of(&free, 0);
        let r = run_trial(&cfg, 0, None);
        assert!(r.completed, "{app}: node-shrink trial hung ({:?})", r.faults);
        assert_eq!(r.digests, want, "{app}: node shrink perturbed the state");
        assert_eq!(r.shrinks, 1, "{app}");
        let seg = &r.segments[0];
        assert!(seg.shrunk && !seg.degraded_redeploy, "{app}: {seg:?}");
    }
}

#[test]
fn shrink_books_redistribution_and_beats_cr() {
    // Process failure under the Table 2 memory scheme: redistribution must
    // move payload (at minimum the victim's lost local copy is reinstated
    // on its adopting host), and the shrink — no ORTE respawn barrier, no
    // fork+exec — must undercut CR's full re-deploy for the same failure.
    let shrink = run_trial(&shrink_cfg("proc@2:r1"), 0, None);
    assert!(shrink.completed, "{:?}", shrink.faults);
    assert_eq!(shrink.shrinks, 1);
    assert!(
        shrink.redistribute_mb > 0.0,
        "redistribution must move checkpoint payload"
    );
    assert_eq!(shrink.failovers, 0, "no replication machinery involved");
    let cr = run_trial(&scenario_cfg(RecoveryKind::Cr, "proc@2:r1"), 0, None);
    assert!(cr.completed);
    let (ts, tc) = (shrink.segments[0].recovery_s, cr.segments[0].recovery_s);
    assert!(ts < tc, "shrink ({ts}) must undercut CR re-deploy ({tc})");
}

#[test]
fn shrink_storm_never_degrades_above_min_ranks() {
    // Acceptance: a 3-failure process storm against ZERO spares shrinks
    // 8 -> 7 -> 6 -> 5 live processes — never taking the degraded-redeploy
    // escape hatch while the world stays at or above `min_ranks` — and
    // still converges to the fault-free state.
    let cfg = shrink_cfg("proc@2:r1,proc@4:r3,proc@6:r6");
    assert_eq!(cfg.min_ranks, 2);
    let want = digests_of(&fault_free_twin(&cfg), 0);
    let r = run_trial(&cfg, 0, None);
    assert!(r.completed, "shrink storm hung ({:?})", r.faults);
    assert_eq!(r.digests, want, "digests differ after shrink storm");
    assert_eq!(r.faults.iter().filter(|f| f.fired).count(), 3, "{:?}", r.faults);
    assert_eq!(r.shrinks, 3, "every event shrinks the world");
    assert_eq!(r.segments.len(), 3, "{:?}", r.segments);
    for seg in &r.segments {
        assert!(seg.shrunk || seg.interrupted, "{seg:?}");
        assert!(!seg.degraded_redeploy, "no degrade above min_ranks: {seg:?}");
    }
    assert!(r.redistribute_mb > 0.0, "storm must redistribute copies");
}

#[test]
fn shrink_below_min_ranks_degrades_to_redeploy() {
    // With `min_ranks` pinned to the full world, the very first loss drops
    // the survivor count below the floor: shrink must refuse to continue
    // and degrade to a CR-style abort + re-deploy, still converging (the
    // abort wipes the memory tiers, so the re-deploy restarts from zero).
    let mut cfg = shrink_cfg("proc@2:r1");
    cfg.min_ranks = 8;
    let want = digests_of(&fault_free_twin(&cfg), 0);
    let r = run_trial(&cfg, 0, None);
    assert!(r.completed, "degraded trial hung ({:?})", r.faults);
    assert_eq!(r.digests, want, "degraded redeploy must still converge");
    assert_eq!(r.shrinks, 0, "no shrink below the floor");
    assert_eq!(r.segments.len(), 1, "{:?}", r.segments);
    let seg = &r.segments[0];
    assert!(seg.degraded_redeploy, "{seg:?}");
    assert!(!seg.shrunk, "{seg:?}");
}

#[test]
fn shrink_losing_last_compute_node_degrades() {
    // Two compute nodes, zero spares: the first node failure shrinks onto
    // the other node; the second takes out the last compute node — nothing
    // is left to adopt onto, so the event degrades to a full re-deploy
    // (converging via the node-failure File checkpoints, Table 2).
    let cfg = shrink_cfg("node@2:r1,node@5:r6");
    let want = digests_of(&fault_free_twin(&cfg), 0);
    let r = run_trial(&cfg, 0, None);
    assert!(r.completed, "last-node storm hung ({:?})", r.faults);
    assert_eq!(r.digests, want, "digests differ after last-node storm");
    assert_eq!(r.shrinks, 1, "only the first event shrinks");
    assert_eq!(r.segments.len(), 2, "{:?}", r.segments);
    assert!(r.segments[0].shrunk && !r.segments[0].degraded_redeploy, "{:?}", r.segments);
    assert!(r.segments[1].degraded_redeploy && !r.segments[1].shrunk, "{:?}", r.segments);
}

// ---- imperfect world: corruption, fallback, escalation, false alarms ---

#[test]
fn all_generations_corrupted_escalates_to_iteration_zero_redeploy() {
    // Graceful-degradation pin: `corrupt_rate=1.0` poisons every checkpoint
    // copy ever written, so a process failure finds nothing servable in any
    // tier or generation. The agreement loop must escalate to a graceful
    // iteration-0 restart — booked as an escalation AND a degraded redeploy
    // on the event's segment — instead of panicking or hanging, and the
    // recomputed run must still match the fault-free oracle. Pinned for the
    // paper's two global-restart families and the shrink family (whose
    // redistribution must refuse to launder corrupt copies).
    for recovery in [RecoveryKind::Cr, RecoveryKind::Reinit, RecoveryKind::Shrink] {
        let mut cfg = scenario_cfg(recovery, "proc@3:r2");
        if recovery == RecoveryKind::Shrink {
            cfg.spare_nodes = 0;
        }
        cfg.corrupt_rate = 1.0;
        let want = digests_of(&fault_free_twin(&cfg), 0);
        let r = run_trial(&cfg, 0, None);
        assert!(r.completed, "{recovery}: all-corrupt trial hung ({:?})", r.faults);
        assert_eq!(
            r.digests, want,
            "{recovery}: iteration-0 restart must still converge"
        );
        assert!(r.escalations >= 1, "{recovery}: escalation must be booked");
        assert!(
            r.segments.iter().any(|s| s.degraded_redeploy),
            "{recovery}: escalation lands as a degraded redeploy: {:?}",
            r.segments
        );
        assert!(
            r.breakdown.verify_s > 0.0,
            "{recovery}: the verification scans that found nothing are charged"
        );
    }
}

#[test]
fn corrupt_event_falls_back_to_older_generation_with_deep_retention() {
    // A targeted `corrupt@` timeline event poisons rank 2's newest
    // checkpoint generation inside a 4-iteration checkpoint interval; the
    // verify-on-load agreement must settle on the older intact generation
    // every rank can serve — extra rollback booked as fallback iterations,
    // no escalation, no retry rounds — and still converge.
    let mut cfg = scenario_cfg(RecoveryKind::Reinit, "corrupt@5:r2,proc@6:r1");
    cfg.ckpt_every = 4; // generations at iters 0 and 4; corruption at 5
    cfg.ckpt_keep = 3;
    let want = digests_of(&fault_free_twin(&cfg), 0);
    let r = run_trial(&cfg, 0, None);
    assert!(r.completed, "fallback trial hung ({:?})", r.faults);
    assert_eq!(r.digests, want, "older-generation restart must converge");
    assert!(
        r.faults.iter().any(|f| f.fired && f.event.corrupt),
        "the corrupt event must fire: {:?}",
        r.faults
    );
    assert_eq!(r.segments.len(), 1, "corruption alone opens no segment: {:?}", r.segments);
    assert!(r.fallback_iters >= 1, "rollback deepened by the corruption");
    assert_eq!(r.escalations, 0, "an intact older generation exists");
    assert_eq!(r.ckpt_retries, 0, "the first proposal is globally servable");
    assert!(r.breakdown.verify_s > 0.0, "verification scans charged");
}

#[test]
fn false_suspicions_trigger_fully_costed_spurious_recoveries() {
    // Unreliable-detector pin: an aggressive false-positive rate must
    // trigger real, fully-costed recoveries of innocently suspected ranks —
    // counted as spurious — while the trial still completes, stays
    // deterministic, and converges to the clean-detector oracle (a spurious
    // global restart is still a correct global restart).
    let mut cfg = base_cfg(AppKind::Hpccg, RecoveryKind::Reinit, FailureKind::Process);
    cfg.iters = 10;
    cfg.max_failures = 6;
    cfg.detect_fp_rate = 200.0; // mean 5 ms between false alarms
    cfg.detect_jitter_s = 0.002;
    cfg.suspect_timeout_s = 0.01;
    // stretch the app clock so the alarm stream lands inside the run
    cfg.calib.modeled_compute_scale = crate::config::presets::STORM_COMPUTE_SCALE;
    let mut clean = cfg.clone();
    clean.failure = FailureKind::None;
    clean.detect_fp_rate = 0.0;
    clean.detect_jitter_s = 0.0;
    clean.suspect_timeout_s = 0.0;
    let want = digests_of(&clean, 0);
    let a = run_trial(&cfg, 0, None);
    let b = run_trial(&cfg, 0, None);
    assert!(a.completed, "noisy-detector trial hung ({:?})", a.faults);
    assert_eq!(a.digests, want, "spurious recoveries must not perturb the state");
    assert!(
        a.spurious_recoveries >= 1,
        "the alarm stream must fire at least once: {:?}",
        a.spurious_recoveries
    );
    assert!(
        a.segments.len() as u64 > a.spurious_recoveries,
        "real + spurious events each open a segment: {:?}",
        a.segments
    );
    assert!(a.breakdown.mpi_recovery_s > 0.0, "spurious recoveries are costed");
    // jittered detection + backoff stay replay-deterministic
    assert_eq!(a.digests, b.digests);
    assert_eq!(a.spurious_recoveries, b.spurious_recoveries);
    assert_eq!(a.sim_events, b.sim_events);
}

#[test]
fn shrink_time_event_after_completion_is_explicit_noop() {
    // Satellite: a virtual-time-anchored event whose instant arrives after
    // the job released the allocation must land as an explicit, logged
    // no-op — zero-cost segment, `noop` outcome — not silently vanish.
    let cfg = shrink_cfg("proc@2:r1,proc@t500:r3");
    let want = digests_of(&fault_free_twin(&cfg), 0);
    let r = run_trial(&cfg, 0, None);
    assert!(r.completed, "noop trial hung ({:?})", r.faults);
    assert_eq!(r.digests, want);
    assert_eq!(r.shrinks, 1);
    assert!(r.faults[0].fired && !r.faults[0].noop, "{:?}", r.faults);
    assert!(r.faults[1].noop && !r.faults[1].fired, "{:?}", r.faults);
    assert_eq!(r.segments.len(), 2, "{:?}", r.segments);
    let noop = &r.segments[1];
    assert!(noop.noop, "{noop:?}");
    assert_eq!(noop.detect_s, 0.0, "{noop:?}");
    assert_eq!(noop.recovery_s, 0.0, "{noop:?}");
    assert_eq!(noop.rollback_s, 0.0, "{noop:?}");
    assert!(!noop.shrunk && !noop.degraded_redeploy && !noop.interrupted, "{noop:?}");
}
