//! End-to-end recovery tests on the native (modeled-fidelity) backend:
//! the core *global-restart equivalence* invariant — a run that suffers a
//! failure and recovers must finish in exactly the fault-free final state —
//! plus the paper's qualitative performance orderings.

use super::job::run_trial;
use crate::config::{
    AppKind, CkptKind, ExperimentConfig, FailureKind, Fidelity, RecoveryKind,
};

fn base_cfg(app: AppKind, recovery: RecoveryKind, failure: FailureKind) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.app = app;
    c.recovery = recovery;
    c.failure = failure;
    c.ranks = 8;
    c.ranks_per_node = 4;
    c.spare_nodes = 1;
    c.iters = 6;
    c.fidelity = Fidelity::Modeled;
    c.comd_n = 32;
    c.hpccg_nx = 4;
    c.lulesh_nx = 4;
    c.seed = 1234;
    c
}

fn digests_of(cfg: &ExperimentConfig, trial: u32) -> Vec<u64> {
    let r = run_trial(cfg, trial, None);
    assert!(r.completed, "{:?}/{:?} did not complete", cfg.app, cfg.recovery);
    assert!(r.digests.iter().all(|&d| d != 0));
    r.digests
}

#[test]
fn fault_free_all_apps_complete() {
    for app in AppKind::ALL {
        let cfg = base_cfg(app, RecoveryKind::Reinit, FailureKind::None);
        let r = run_trial(&cfg, 0, None);
        assert!(r.completed, "{app}");
        assert_eq!(r.breakdown.mpi_recovery_s, 0.0);
        assert!(r.breakdown.total_s > 0.0);
    }
}

#[test]
fn fault_free_digest_identical_across_recovery_modes() {
    // CR and Reinit must not perturb the computation at all; ULFM inflates
    // time but not values.
    for app in AppKind::ALL {
        let base = digests_of(&base_cfg(app, RecoveryKind::Reinit, FailureKind::None), 0);
        for rk in [RecoveryKind::Cr, RecoveryKind::Ulfm] {
            let d = digests_of(&base_cfg(app, rk, FailureKind::None), 0);
            assert_eq!(d, base, "{app} {rk}");
        }
    }
}

fn check_equivalence(app: AppKind, recovery: RecoveryKind, failure: FailureKind, trial: u32) {
    let fault_free = digests_of(&base_cfg(app, recovery, FailureKind::None), trial);
    let cfg = base_cfg(app, recovery, failure);
    let r = run_trial(&cfg, trial, None);
    assert!(
        r.completed,
        "{app}/{recovery}/{failure} trial {trial} hung (fault {:?})",
        r.fault
    );
    assert!(r.breakdown.mpi_recovery_s > 0.0, "no recovery recorded");
    assert_eq!(
        r.digests, fault_free,
        "{app}/{recovery}/{failure}: recovered state differs from fault-free (fault {:?})",
        r.fault
    );
}

#[test]
fn reinit_process_failure_equivalence_all_apps() {
    for app in AppKind::ALL {
        check_equivalence(app, RecoveryKind::Reinit, FailureKind::Process, 0);
    }
}

#[test]
fn cr_process_failure_equivalence_all_apps() {
    for app in AppKind::ALL {
        check_equivalence(app, RecoveryKind::Cr, FailureKind::Process, 0);
    }
}

#[test]
fn ulfm_process_failure_equivalence_all_apps() {
    for app in AppKind::ALL {
        check_equivalence(app, RecoveryKind::Ulfm, FailureKind::Process, 0);
    }
}

#[test]
fn reinit_node_failure_equivalence() {
    for app in [AppKind::Hpccg, AppKind::CoMD] {
        check_equivalence(app, RecoveryKind::Reinit, FailureKind::Node, 0);
    }
}

#[test]
fn cr_node_failure_equivalence() {
    check_equivalence(AppKind::Hpccg, RecoveryKind::Cr, FailureKind::Node, 0);
}

#[test]
fn equivalence_over_random_trials_property() {
    // property sweep: several trials = several (iteration, victim) draws
    for trial in 0..4 {
        check_equivalence(AppKind::Hpccg, RecoveryKind::Reinit, FailureKind::Process, trial);
    }
}

#[test]
fn recovery_time_ordering_cr_slowest() {
    // Fig. 6 shape: CR ≈ 3 s; Reinit++ ≈ 0.5 s; ULFM in between at small N.
    let reinit = run_trial(
        &base_cfg(AppKind::Hpccg, RecoveryKind::Reinit, FailureKind::Process),
        0,
        None,
    );
    let cr = run_trial(
        &base_cfg(AppKind::Hpccg, RecoveryKind::Cr, FailureKind::Process),
        0,
        None,
    );
    let ulfm = run_trial(
        &base_cfg(AppKind::Hpccg, RecoveryKind::Ulfm, FailureKind::Process),
        0,
        None,
    );
    let (tr, tc, tu) = (
        reinit.breakdown.mpi_recovery_s,
        cr.breakdown.mpi_recovery_s,
        ulfm.breakdown.mpi_recovery_s,
    );
    assert!(tc > 2.0 && tc < 5.0, "CR anchor ≈3 s, got {tc}");
    assert!(tr > 0.2 && tr < 0.9, "Reinit++ anchor ≈0.5 s, got {tr}");
    assert!(tc > 3.0 * tr, "CR must be several x slower: {tc} vs {tr}");
    assert!(tu > tr * 0.5, "ULFM comparable at small scale: {tu} vs {tr}");
}

#[test]
fn node_failure_recovery_slower_than_process() {
    // Fig. 7: Reinit++ ≈1.5 s for node vs ≈0.5 s for process failures.
    let mut proc_cfg = base_cfg(AppKind::Hpccg, RecoveryKind::Reinit, FailureKind::Process);
    proc_cfg.ckpt = Some(CkptKind::File); // same scheme for a fair contrast
    let node_cfg = base_cfg(AppKind::Hpccg, RecoveryKind::Reinit, FailureKind::Node);
    let tp = run_trial(&proc_cfg, 0, None).breakdown.mpi_recovery_s;
    let tn = run_trial(&node_cfg, 0, None).breakdown.mpi_recovery_s;
    assert!(tn > 1.8 * tp, "node recovery must cost much more: {tn} vs {tp}");
    // at the test's 4 ranks/node the respawn batch is smaller than the
    // paper's 16/node, so the anchor scales down from ~1.5 s accordingly
    assert!(tn > 0.8 && tn < 2.5, "node anchor, got {tn}");
}

#[test]
fn ulfm_inflates_pure_app_time() {
    // Fig. 5: ULFM's heartbeat/FT-wrappers tax fault-free execution.
    // Use a compute-dominated size so the inflation is visible over the
    // (identical) communication time.
    let mut r_cfg = base_cfg(AppKind::Hpccg, RecoveryKind::Reinit, FailureKind::None);
    r_cfg.hpccg_nx = 16;
    let mut u_cfg = r_cfg.clone();
    u_cfg.recovery = RecoveryKind::Ulfm;
    let reinit = run_trial(&r_cfg, 0, None);
    let ulfm = run_trial(&u_cfg, 0, None);
    let (ar, au) = (reinit.breakdown.app_s(), ulfm.breakdown.app_s());
    assert!(au > ar * 1.02, "ULFM app time must inflate: {au} vs {ar}");
}

#[test]
fn file_ckpt_writes_cost_more_than_memory() {
    // Fig. 4 mechanism: CR's file checkpoints vs Reinit++'s buddy memory.
    let cr = run_trial(
        &base_cfg(AppKind::Hpccg, RecoveryKind::Cr, FailureKind::None),
        0,
        None,
    );
    let reinit = run_trial(
        &base_cfg(AppKind::Hpccg, RecoveryKind::Reinit, FailureKind::None),
        0,
        None,
    );
    assert!(
        cr.breakdown.ckpt_write_s > 3.0 * reinit.breakdown.ckpt_write_s,
        "file {} vs memory {}",
        cr.breakdown.ckpt_write_s,
        reinit.breakdown.ckpt_write_s
    );
}

#[test]
fn trial_is_deterministic() {
    let cfg = base_cfg(AppKind::Lulesh, RecoveryKind::Reinit, FailureKind::Process);
    let a = run_trial(&cfg, 1, None);
    let b = run_trial(&cfg, 1, None);
    assert_eq!(a.digests, b.digests);
    assert_eq!(a.breakdown.total_s, b.breakdown.total_s);
    assert_eq!(a.sim_events, b.sim_events);
}

#[test]
fn victim_rank_state_restored_via_buddy() {
    // memory checkpointing: the victim's state must come from its buddy
    let cfg = base_cfg(AppKind::Hpccg, RecoveryKind::Reinit, FailureKind::Process);
    assert_eq!(cfg.effective_ckpt(), CkptKind::Memory);
    let fault_free = digests_of(&base_cfg(cfg.app, cfg.recovery, FailureKind::None), 2);
    let r = run_trial(&cfg, 2, None);
    assert!(r.completed);
    let victim = r.fault.rank as usize;
    assert_eq!(r.digests[victim], fault_free[victim], "victim state wrong");
}
