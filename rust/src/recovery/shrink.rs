//! Shrinking recovery: the fifth family — continue on survivors, no
//! respawn, no spare nodes (ULFM `MPI_Comm_shrink` lineage, with
//! ReStore-style checkpoint redistribution, arXiv 2203.01107).
//!
//! On a process or node failure the root does **not** spawn replacements:
//! the survivors agree on the dead set and rebuild a smaller world in
//! place. The dead processes' domain *blocks* are adopted by surviving
//! compute nodes (the logical decomposition — ReStore's invariant block
//! count — never changes, so halo partners, reductions and digests are
//! identical to a fault-free run; the survivors just run proportionally
//! hotter, see [`crate::apps::NewWorld::work_scale`]). Before anyone
//! reloads, the root redistributes the surviving in-memory checkpoint
//! copies over the live topology ([`crate::ckptstore::CkptStore::
//! redistribute`]): cheapest-surviving-tier sources, transport-charged
//! moves, and a balanced destination walk that keeps hosted-copy counts
//! within one of each other.
//!
//! **Degrade path.** Shrinking below `min_ranks` live processes — or
//! losing the last compute node — leaves nothing worth continuing on; the
//! job degrades to a CR-style abort + re-deploy (fresh full-size
//! allocation), recorded as `degraded_redeploy` on the event's segment.
//!
//! **Multi-failure semantics.** Same idempotent-under-overlap discipline
//! as [`super::reinit`]: scheduled closures re-check the cluster at fire
//! time, adoption targets are re-picked per victim, and a second failure
//! landing mid-shrink simply re-drives the loop (the world shrinks again).

use std::rc::Rc;

use super::job::{abort_job, arm_child_watcher, JobCtx, RecoveryDriver, ReinitState};
use super::reinit::spawn_rank;
use crate::cluster::Topology;
use crate::config::FailureKind;
use crate::detect::DetectEvent;
use crate::sim::{Receiver, SimDuration};

/// The root's shrink loop: agree on the dead set, adopt blocks onto
/// survivors, redistribute checkpoint copies, cancel + re-enter everyone.
pub async fn shrink_root(ctx: JobCtx, detect_rx: Receiver<DetectEvent>) {
    let w = Rc::clone(&ctx.world);
    let control = SimDuration::from_secs_f64(w.cfg.calib.control_latency_us * 1e-6);
    loop {
        let Ok(ev) = detect_rx.recv().await else {
            return;
        };
        let (kind, victims): (FailureKind, Vec<u32>) = match ev {
            DetectEvent::RankDead { rank, .. } => {
                if ctx.cluster.rank_is_alive(rank) {
                    continue; // stale notification (already adopted)
                }
                w.metrics.record_detect(w.sim.now(), FailureKind::Process);
                w.trace_mark("detect");
                (FailureKind::Process, vec![rank])
            }
            DetectEvent::NodeDead { node, .. } => {
                let failed: Vec<u32> = (0..w.cfg.ranks)
                    .filter(|&r| {
                        ctx.cluster.rank_slot(r).node == node && !ctx.cluster.rank_is_alive(r)
                    })
                    .collect();
                if failed.is_empty() {
                    continue;
                }
                w.metrics.record_detect(w.sim.now(), FailureKind::Node);
                w.trace_mark("detect");
                (FailureKind::Node, failed)
            }
        };

        // Shrink decision: each fired event removes its victim processes
        // from the world. Below `min_ranks` — or with no compute node left
        // to adopt onto — continuing is pointless: degrade to a CR-style
        // re-deploy on a fresh full-size allocation.
        let remaining = ctx.mpi.world_procs().saturating_sub(victims.len() as u32);
        if remaining < w.cfg.min_ranks
            || ctx.cluster.least_loaded_alive_compute_node().is_none()
        {
            w.metrics.record_degrade(kind);
            w.metrics.record_escalation();
            w.trace_mark("degrade");
            abort_job(&ctx);
            return;
        }
        w.metrics.record_shrink();
        w.trace_mark("shrink");
        w.shrinks.set(w.shrinks.get() + 1);

        // Broadcast <SHRINK, adoption list> down the root->daemon tree.
        let levels = Topology::tree_levels(ctx.cluster.topo.total_nodes() + 1);
        w.sim
            .sleep(SimDuration(control.0 * levels.max(1) as u64))
            .await;

        // Adoption walk: every victim block re-hosts onto the least-loaded
        // surviving *compute* node — never a spare; shrink's whole point is
        // needing zero over-provisioning. Re-picked per victim (balances a
        // whole node's worth of blocks) and re-checked at this instant: a
        // storm kill during the broadcast can empty the compute pool.
        let mut adopted = true;
        for &rank in &victims {
            match ctx.cluster.least_loaded_alive_compute_node() {
                Some(target) => {
                    ctx.cluster.rehost_rank(rank, target); // no fork+exec
                    arm_child_watcher(&ctx, rank);
                }
                None => {
                    adopted = false;
                    break;
                }
            }
        }
        if !adopted {
            w.metrics.record_degrade(kind);
            w.metrics.record_escalation();
            w.trace_mark("degrade");
            abort_job(&ctx);
            return;
        }

        // Survivors agree on the dead set and rebuild the smaller world in
        // place (fresh generation; stale traffic is dropped).
        ctx.mpi.shrink_world(remaining);
        let startup = w.deploy.comm_shrink(remaining);

        // ReStore redistribution: rebalance the surviving in-memory
        // checkpoint copies over the live topology before any rank loads.
        // The root awaits it, so its transport cost rides the recovery
        // window (paper Fig. 6/7 booking).
        let node_of: Vec<u32> = (0..w.cfg.ranks)
            .map(|r| ctx.cluster.rank_slot(r).node)
            .collect();
        w.ckpt.redistribute(&node_of).await;

        // Everyone re-enters the rollback point: survivors via the
        // SIGREINIT cancel+re-enter (longjmp discipline), adopted blocks as
        // fresh `Restarted` entries inside their hosting survivor.
        let signal = w.deploy.signal();
        for rank in 0..w.cfg.ranks {
            let state = if victims.contains(&rank) {
                ReinitState::Restarted
            } else {
                ReinitState::Reinited
            };
            let ctx2 = ctx.clone();
            w.sim.schedule(signal, move || {
                if !ctx2.cluster.rank_is_alive(rank) {
                    return; // died since the broadcast; its detect covers it
                }
                let cur = ctx2.rank_tasks.borrow()[rank as usize];
                if let Some(t) = cur {
                    ctx2.world.sim.cancel_task(t);
                }
                spawn_rank(&ctx2, rank, state, startup);
            });
        }
    }
}

/// Shrinking recovery hosted on the shared trial loop.
pub struct ShrinkDriver;

impl RecoveryDriver for ShrinkDriver {
    fn tag(&self) -> &'static str {
        "shrink"
    }

    fn deploy(&self, ctx: &JobCtx, detect_rx: Receiver<DetectEvent>) {
        let w = &ctx.world;
        for rank in 0..w.cfg.ranks {
            spawn_rank(ctx, rank, ReinitState::New, SimDuration::ZERO);
        }
        let root = ctx.cluster.root();
        let ctx2 = ctx.clone();
        w.sim.clone().spawn(root, async move {
            shrink_root(ctx2, detect_rx).await;
        });
    }
}
