//! ULFM global-restart (paper §2.2): the application-level recipe built
//! from the ULFM extensions.
//!
//! Failure path: the RTE (SIGCHLD / channel break + the always-on heartbeat
//! detector) notifies every rank; pending MPI operations raise
//! `MPI_ERR_PROC_FAILED`; the application then
//!   1. revokes the world communicator (flood),
//!   2. shrinks it + agrees on the failed set (consensus over survivors),
//!   3. the leader asks the RTE to spawn replacements,
//!   4. everyone merges into a repaired world communicator (a new
//!      generation) and rolls back to the restart point.
//!
//! The measured slowness of the ULFM prototype's shrink/agree/merge at scale
//! (paper §5.3: parity with Reinit++ up to 64 ranks, ≈3× at 1024) is charged
//! as the calibrated-to-paper `ulfm_recover_base/per_rank` term — the
//! protocol messages themselves are simulated, but the prototype's
//! implementation inefficiency is not something message latency reproduces.
//!
//! **Multi-failure semantics.** Each recovery round is one shrink/agree/
//! spawn/merge cycle; a failure landing mid-round makes the next collective
//! on the repaired communicator fail and starts another round (the ULFM
//! recipe's own retry shape). Two mechanics make the rounds converge under
//! storms: (a) failure-detector state survives communicator repair — deaths
//! that *raced* a round's generation bump are re-announced into the new
//! generation, so a repaired world can never block on a silently-dead peer;
//! (b) the RTE spawner re-checks rank/node liveness at fork+exec time, so
//! overlapping spawn requests cannot double-spawn and a dead target node
//! defers its ranks to the following round. Node failures beyond the spare
//! pool abort to the shared trial loop for a CR-style re-deploy.

use std::collections::BTreeMap;
use std::rc::Rc;

use super::job::{
    abort_job, arm_child_watcher, rank_user_main, JobCtx, RecoveryDriver, ReinitState,
};
use crate::detect::DetectEvent;
use crate::mpi::{Comm, RecvSrc, PROCEED_TAG, SYSTEM_SRC};
use crate::sim::{channel, Receiver, Sender, SimDuration};

/// Spawn a ULFM rank task: user main inside the recover-and-retry loop.
/// No-op if the rank's process is dead (a timeline kill raced the spawn);
/// its detect event routes it through the next recovery round.
pub fn spawn_ulfm_rank(
    ctx: &JobCtx,
    spawn_req_tx: Sender<Vec<u32>>,
    rank: u32,
    state: ReinitState,
    startup: SimDuration,
) {
    if !ctx.cluster.rank_is_alive(rank) {
        return;
    }
    let slot = ctx.cluster.rank_slot(rank);
    let sim = ctx.world.sim.clone();
    let ctx2 = ctx.clone();
    let tid = sim.clone().spawn(slot.proc, async move {
        if startup > SimDuration::ZERO {
            sim.sleep(startup).await;
        }
        let mut state = state;
        loop {
            match rank_user_main(ctx2.clone(), rank, state).await {
                Ok(()) => return,
                Err((_e, comm)) => {
                    survivor_recover(&ctx2, &spawn_req_tx, rank, comm).await;
                    state = ReinitState::Reinited;
                }
            }
        }
    });
    ctx.rank_tasks.borrow_mut()[rank as usize] = Some(tid);
}

/// The survivor side of the global-restart recipe.
async fn survivor_recover(
    ctx: &JobCtx,
    spawn_req_tx: &Sender<Vec<u32>>,
    _rank: u32,
    comm: Rc<Comm>,
) {
    let w = &ctx.world;
    // 1. MPI_Comm_revoke: make sure everyone's pending ops fail fast.
    comm.revoke();
    // 2. MPI_Comm_shrink + MPI_Comm_agree over survivors.
    let Ok(shr) = comm.shrink_agree().await else {
        w.sim.halt_forever().await;
        unreachable!();
    };
    // 3. Leader (lowest survivor) asks the RTE to spawn replacements.
    if shr.my_index == 0 {
        let failed: Vec<u32> = (0..comm.size)
            .filter(|r| !shr.survivors.contains(r))
            .collect();
        let control = SimDuration::from_secs_f64(w.cfg.calib.control_latency_us * 1e-6);
        spawn_req_tx.send(failed, control);
    }
    // Calibrated-to-paper cost of the prototype's shrink/agree/merge
    // collectives at this scale (§5.3).
    let extra = SimDuration::from_secs_f64(
        w.cfg.calib.ulfm_recover_base_ms * 1e-3
            + w.cfg.calib.ulfm_recover_per_rank_us * 1e-6 * comm.size as f64,
    );
    w.sim.sleep(extra).await;
    // 4. Wait for the RTE's PROCEED, then merge = re-attach a fresh
    //    generation (done by the caller loop re-entering rank_user_main).
    let _ = comm
        .recv_unchecked(RecvSrc::From(SYSTEM_SRC), PROCEED_TAG)
        .await;
    w.sim.sleep(w.deploy.comm_reinit(w.cfg.ranks)).await;
}

/// RTE side: failure notification fan-out (heartbeat-floor latency).
async fn ulfm_notifier(ctx: JobCtx, detect_rx: Receiver<DetectEvent>) {
    let w = Rc::clone(&ctx.world);
    let hb = SimDuration::from_secs_f64(w.cfg.calib.ulfm_hb_period_ms * 1e-3);
    loop {
        let Ok(ev) = detect_rx.recv().await else {
            return;
        };
        match ev {
            DetectEvent::RankDead { rank, .. } => {
                if !ctx.cluster.rank_is_alive(rank) {
                    w.metrics
                        .record_detect(w.sim.now(), crate::config::FailureKind::Process);
                    w.trace_mark("detect");
                    ctx.mpi.notify_failure(rank, hb);
                }
            }
            DetectEvent::NodeDead { node, .. } => {
                let dead: Vec<u32> = (0..w.cfg.ranks)
                    .filter(|&r| {
                        ctx.cluster.rank_slot(r).node == node && !ctx.cluster.rank_is_alive(r)
                    })
                    .collect();
                if dead.is_empty() {
                    continue;
                }
                w.metrics
                    .record_detect(w.sim.now(), crate::config::FailureKind::Node);
                w.trace_mark("detect");
                // Spare pool outrun: degrade to a CR-style full re-deploy
                // (recorded on the event's metric segment).
                if ctx.spares_exhausted() {
                    w.metrics.record_degrade(crate::config::FailureKind::Node);
                    w.metrics.record_escalation();
                    w.trace_mark("degrade");
                    abort_job(&ctx);
                    return;
                }
                for r in dead {
                    ctx.mpi.notify_failure(r, hb);
                }
            }
        }
    }
}

/// RTE side: handle the leader's spawn request — re-spawn failed processes,
/// open a new communicator generation, release the survivors.
async fn ulfm_spawner(
    ctx: JobCtx,
    spawn_req_tx: Sender<Vec<u32>>,
    spawn_req_rx: Receiver<Vec<u32>>,
) {
    let w = Rc::clone(&ctx.world);
    let hb = SimDuration::from_secs_f64(w.cfg.calib.ulfm_hb_period_ms * 1e-3);
    loop {
        let Ok(failed) = spawn_req_rx.recv().await else {
            return;
        };
        let old_gen = ctx.mpi.generation();
        ctx.mpi.bump_generation();
        // Failure-detector state survives communicator repair: a rank that
        // died after this round's agreement (so it is absent from `failed`)
        // must be re-announced into the new generation, or the repaired
        // world would block forever on a peer nobody knows is dead. The
        // notifications are buffered by the fabric until the new
        // generation's endpoints bind. No-op in single-failure runs.
        for r in 0..w.cfg.ranks {
            if !failed.contains(&r) && !ctx.cluster.rank_is_alive(r) {
                ctx.mpi.notify_failure(r, hb);
            }
        }
        let survivors: Vec<u32> = (0..w.cfg.ranks)
            .filter(|r| !failed.contains(r))
            .collect();
        // choose targets: original node if alive, else least loaded
        let mut by_node: BTreeMap<u32, Vec<u32>> = BTreeMap::new();
        for &rank in &failed {
            let home = ctx.cluster.rank_slot(rank).node;
            let node = if ctx.cluster.node_is_alive(home) {
                home
            } else {
                ctx.cluster.least_loaded_alive_node()
            };
            by_node.entry(node).or_default().push(rank);
        }
        let startup = w.deploy.comm_reinit(w.cfg.ranks);
        let mut spawn_cost = SimDuration::ZERO;
        for (node, ranks) in &by_node {
            spawn_cost = spawn_cost.max(w.deploy.node_spawn(ranks.len() as u32));
            let ctx2 = ctx.clone();
            let tx2 = spawn_req_tx.clone();
            let ranks = ranks.clone();
            let node = *node;
            let cost = w.deploy.node_spawn(ranks.len() as u32);
            w.sim.schedule(cost, move || {
                if !ctx2.cluster.node_is_alive(node) {
                    // target died while the fork+exec was in flight: these
                    // ranks stay dead and notified; the survivors' next
                    // collective fails and the following round re-places them
                    return;
                }
                for &rank in &ranks {
                    if ctx2.cluster.rank_is_alive(rank) {
                        continue; // an overlapping round already re-spawned it
                    }
                    ctx2.cluster.respawn_rank(rank, node);
                    arm_child_watcher(&ctx2, rank);
                    spawn_ulfm_rank(&ctx2, tx2.clone(), rank, ReinitState::Restarted, startup);
                }
            });
        }
        // Release survivors once the replacements exist.
        let mpi = ctx.mpi.clone();
        w.sim.schedule(spawn_cost, move || {
            for &r in &survivors {
                mpi.send_system(old_gen, r, PROCEED_TAG, Vec::new());
            }
        });
    }
}

/// ULFM hosted on the shared trial loop.
pub struct UlfmDriver;

impl RecoveryDriver for UlfmDriver {
    fn tag(&self) -> &'static str {
        "ulfm"
    }

    fn deploy(&self, ctx: &JobCtx, detect_rx: Receiver<DetectEvent>) {
        let w = &ctx.world;
        let (spawn_req_tx, spawn_req_rx) = channel::<Vec<u32>>(&w.sim);
        for rank in 0..w.cfg.ranks {
            spawn_ulfm_rank(
                ctx,
                spawn_req_tx.clone(),
                rank,
                ReinitState::New,
                SimDuration::ZERO,
            );
        }
        let root = ctx.cluster.root();
        let ctx2 = ctx.clone();
        w.sim.clone().spawn(root, async move {
            ulfm_notifier(ctx2, detect_rx).await;
        });
        let ctx3 = ctx.clone();
        let tx2 = spawn_req_tx.clone();
        w.sim.clone().spawn(root, async move {
            ulfm_spawner(ctx3, tx2, spawn_req_rx).await;
        });
    }
}
