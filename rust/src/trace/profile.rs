//! Per-trial profiling snapshots.
//!
//! [`TrialCounters`] is the always-on lightweight layer: a handful of
//! executor totals plus a content-addressed trial identity hash, collected
//! for every trial (traced or not) and aggregated by the sweeps behind
//! `--profile-json`. [`TrialProfile`] is the full snapshot written next to
//! a `--trace` capture: counters + exact span totals + the per-failure
//! segment decomposition, rendered as dependency-free JSON.
//!
//! The identity hash is FNV-1a over the `Debug` rendering of the full
//! `ExperimentConfig` plus the trial number — the exact key a persistent
//! trial-result cache needs (ROADMAP item 4: determinism makes results
//! perfectly cacheable, so `(config, trial)` content-addresses a result).

use std::io::{BufWriter, Write};
use std::path::Path;

use crate::config::ExperimentConfig;
use crate::metrics::bench::{json_num, json_str};
use crate::metrics::FailureSegment;

use super::{Recorder, SpanTotal};

/// FNV-1a 64-bit over `bytes`, continuing from `h`.
fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Content-address a `(config, trial)` pair: equal configs and trial
/// numbers hash equal (determinism then guarantees equal results).
pub fn identity_hash(cfg: &ExperimentConfig, trial: u32) -> u64 {
    let mut h = fnv1a(0xcbf2_9ce4_8422_2325, format!("{cfg:?}").as_bytes());
    h = fnv1a(h, &trial.to_le_bytes());
    h
}

/// Lightweight per-trial executor counters, collected for *every* trial
/// (tracing on or off) and carried on `TrialResult`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TrialCounters {
    /// Content-addressed `(config, trial)` identity.
    pub identity: u64,
    /// Virtual end time of the trial, seconds.
    pub end_s: f64,
    /// DES events fired.
    pub events: u64,
    /// Task polls executed.
    pub polls: u64,
    /// Pending-event high-water mark.
    pub peak_events_pending: u64,
    /// Live-task state high-water mark, bytes (boxed futures + slab
    /// slots) — the SoA memory budget a giant trial must fit in.
    pub peak_rank_state_bytes: u64,
    /// Tasks run to completion.
    pub tasks_completed: u64,
}

/// Full per-trial profiling snapshot written alongside a `--trace` capture.
#[derive(Clone, Debug)]
pub struct TrialProfile {
    /// Human label: `app/recovery/ranks`.
    pub label: String,
    /// Trial number within its point.
    pub trial: u32,
    /// The always-on executor counters.
    pub counters: TrialCounters,
    /// Monotonic named counters from the recorder (recv match kinds,
    /// wake/timer tallies, …).
    pub named: Vec<(String, u64)>,
    /// Exact per-(category, name) span statistics.
    pub spans: Vec<SpanTotal>,
    /// The trial's per-failure-event decomposition.
    pub segments: Vec<FailureSegment>,
}

impl TrialProfile {
    /// Assemble a profile from the recorder and the finalized metrics.
    pub fn new(
        label: String,
        trial: u32,
        counters: TrialCounters,
        rec: &Recorder,
        segments: Vec<FailureSegment>,
    ) -> TrialProfile {
        TrialProfile {
            label,
            trial,
            counters,
            named: rec
                .counters()
                .iter()
                .map(|(&k, &v)| (k.to_string(), v))
                .collect(),
            spans: rec.span_totals(),
            segments,
        }
    }

    /// Render as pretty-ish JSON (same hand-rolled style as the bench
    /// reports; no serde in this crate).
    pub fn to_json(&self) -> String {
        let c = &self.counters;
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str("  \"schema\": 1,\n");
        s.push_str(&format!("  \"label\": {},\n", json_str(&self.label)));
        s.push_str(&format!("  \"trial\": {},\n", self.trial));
        s.push_str(&format!(
            "  \"identity\": {},\n",
            json_str(&format!("{:016x}", c.identity))
        ));
        s.push_str(&format!("  \"end_time_s\": {},\n", json_num(c.end_s)));
        s.push_str(&format!("  \"events\": {},\n", c.events));
        s.push_str(&format!("  \"polls\": {},\n", c.polls));
        s.push_str(&format!(
            "  \"peak_events_pending\": {},\n",
            c.peak_events_pending
        ));
        s.push_str(&format!(
            "  \"peak_rank_state_bytes\": {},\n",
            c.peak_rank_state_bytes
        ));
        s.push_str(&format!("  \"tasks_completed\": {},\n", c.tasks_completed));
        s.push_str("  \"counters\": {");
        for (i, (k, v)) in self.named.iter().enumerate() {
            if i > 0 {
                s.push_str(", ");
            }
            s.push_str(&format!("{}: {v}", json_str(k)));
        }
        s.push_str("},\n");
        s.push_str("  \"spans\": [\n");
        for (i, t) in self.spans.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"cat\": {}, \"name\": {}, \"count\": {}, \"total_s\": {}}}{}\n",
                json_str(t.cat),
                json_str(t.name),
                t.count,
                json_num(t.total_ns as f64 / 1e9),
                if i + 1 == self.spans.len() { "" } else { "," }
            ));
        }
        s.push_str("  ],\n");
        s.push_str("  \"segments\": [\n");
        for (i, g) in self.segments.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"kind\": {}, \"victim\": {}, \"fail_s\": {}, \
                 \"detect_s\": {}, \"recovery_s\": {}, \"rollback_s\": {}, \
                 \"failover_s\": {}, \"failover\": {}, \"interrupted\": {}, \
                 \"degraded_redeploy\": {}, \"shrunk\": {}, \"noop\": {}}}{}\n",
                json_str(&format!("{:?}", g.kind)),
                g.victim,
                json_num(g.fail_s),
                json_num(g.detect_s),
                json_num(g.recovery_s),
                json_num(g.rollback_s),
                json_num(g.failover_s),
                g.failover,
                g.interrupted,
                g.degraded_redeploy,
                g.shrunk,
                g.noop,
                if i + 1 == self.segments.len() { "" } else { "," }
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }

    /// Write the profile JSON to `path`.
    pub fn write(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let f = std::fs::File::create(path)?;
        let mut w = BufWriter::new(f);
        w.write_all(self.to_json().as_bytes())?;
        w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::super::Tracer;
    use super::*;
    use crate::sim::SimTime;

    #[test]
    fn identity_is_stable_and_trial_sensitive() {
        let cfg = ExperimentConfig::default();
        let a = identity_hash(&cfg, 0);
        let b = identity_hash(&cfg, 0);
        let c = identity_hash(&cfg, 1);
        assert_eq!(a, b, "same (config, trial) must hash equal");
        assert_ne!(a, c, "trial number must perturb the identity");
        let mut cfg2 = ExperimentConfig::default();
        cfg2.ranks += 1;
        assert_ne!(a, identity_hash(&cfg2, 0), "config must perturb it too");
    }

    #[test]
    fn profile_json_is_balanced_and_carries_counters() {
        let tr = Tracer::new();
        tr.install(Recorder::new(2, None));
        tr.span("mpi", "allreduce", 1, SimTime(0), SimTime(2_000_000_000));
        tr.add("mpi.recv_direct", 9);
        let rec = tr.take().unwrap();
        let p = TrialProfile::new(
            "hpccg/reinit/8".into(),
            3,
            TrialCounters {
                identity: 0xdead_beef,
                end_s: 1.5,
                events: 100,
                polls: 200,
                peak_events_pending: 7,
                peak_rank_state_bytes: 4096,
                tasks_completed: 12,
            },
            &rec,
            vec![],
        );
        let j = p.to_json();
        assert!(j.contains("\"identity\": \"00000000deadbeef\""));
        assert!(j.contains("\"peak_rank_state_bytes\": 4096"));
        assert!(j.contains("\"mpi.recv_direct\": 9"));
        assert!(j.contains("\"total_s\": 2"));
        assert!(j.contains("\"segments\": [\n  ]"));
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }
}
