//! Chrome trace-event JSON exporter (Perfetto-loadable).
//!
//! Renders a [`Recorder`](super::Recorder) as the legacy trace-event array
//! format that both `chrome://tracing` and <https://ui.perfetto.dev> load:
//! `"X"` complete events for spans, `"i"` instants, `"C"` counters, and
//! `"M"` metadata naming processes and threads. Virtual time maps onto the
//! trace `ts` axis (µs); host wall time rides along in `args.wall_us`.
//!
//! Track layout: pid 1 is the simulated trial — tid 0 the recovery
//! timeline, tids 1.. one per rank group. Pool-worker activity (host wall
//! time) is a separate file on pid 2 with one tid per worker, written by
//! [`write_pool`].

use std::io::{BufWriter, Write};
use std::path::Path;

use crate::metrics::bench::{json_num, json_str};

use super::{Ev, PoolEvent, PoolSample, Recorder};

/// pid of the simulated-trial tracks (virtual time).
const PID_SIM: u32 = 1;
/// pid of the pool-worker tracks (host wall time).
const PID_POOL: u32 = 2;

fn meta_process(out: &mut String, pid: u32, name: &str) {
    out.push_str(&format!(
        "{{\"ph\":\"M\",\"pid\":{pid},\"tid\":0,\"name\":\"process_name\",\
         \"args\":{{\"name\":{}}}}}",
        json_str(name)
    ));
}

fn meta_thread(out: &mut String, pid: u32, tid: u32, name: &str) {
    out.push_str(&format!(
        "{{\"ph\":\"M\",\"pid\":{pid},\"tid\":{tid},\"name\":\"thread_name\",\
         \"args\":{{\"name\":{}}}}}",
        json_str(name)
    ));
}

/// Virtual nanoseconds → trace-axis microseconds.
fn vt_us(ns: u64) -> f64 {
    ns as f64 / 1e3
}

/// Render one recorder as a trace-event JSON string.
pub fn render(rec: &Recorder) -> String {
    let mut items: Vec<String> = Vec::with_capacity(rec.len() + 16);

    let mut s = String::new();
    meta_process(&mut s, PID_SIM, "reinitpp sim (virtual time)");
    items.push(std::mem::take(&mut s));
    for (tid, name) in rec.track_names() {
        meta_thread(&mut s, PID_SIM, tid, &name);
        items.push(std::mem::take(&mut s));
    }

    for ev in &rec.events {
        let item = match *ev {
            Ev::Span {
                cat,
                name,
                track,
                begin_ns,
                dur_ns,
                wall_us,
            } => format!(
                "{{\"ph\":\"X\",\"pid\":{PID_SIM},\"tid\":{track},\
                 \"cat\":{},\"name\":{},\"ts\":{},\"dur\":{},\
                 \"args\":{{\"wall_us\":{}}}}}",
                json_str(cat),
                json_str(name),
                json_num(vt_us(begin_ns)),
                json_num(vt_us(dur_ns)),
                json_num(wall_us)
            ),
            Ev::Instant {
                cat,
                name,
                track,
                at_ns,
                wall_us,
            } => format!(
                "{{\"ph\":\"i\",\"pid\":{PID_SIM},\"tid\":{track},\
                 \"cat\":{},\"name\":{},\"ts\":{},\"s\":\"t\",\
                 \"args\":{{\"wall_us\":{}}}}}",
                json_str(cat),
                json_str(name),
                json_num(vt_us(at_ns)),
                json_num(wall_us)
            ),
            Ev::Counter {
                cat,
                name,
                at_ns,
                value,
            } => format!(
                "{{\"ph\":\"C\",\"pid\":{PID_SIM},\"tid\":0,\
                 \"cat\":{},\"name\":{},\"ts\":{},\
                 \"args\":{{\"value\":{value}}}}}",
                json_str(cat),
                json_str(name),
                json_num(vt_us(at_ns))
            ),
        };
        items.push(item);
    }

    let mut counters = String::from("{");
    for (i, (k, v)) in rec.counters().iter().enumerate() {
        if i > 0 {
            counters.push(',');
        }
        counters.push_str(&format!("{}:{v}", json_str(k)));
    }
    counters.push('}');

    format!(
        "{{\"traceEvents\":[\n{}\n],\n\"displayTimeUnit\":\"ms\",\n\
         \"otherData\":{{\"clock\":\"virtual\",\"dropped_events\":{},\
         \"counters\":{counters}}}}}\n",
        items.join(",\n"),
        rec.dropped()
    )
}

/// Write a recorder's trace to `path`.
pub fn write(path: impl AsRef<Path>, rec: &Recorder) -> std::io::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    w.write_all(render(rec).as_bytes())?;
    w.flush()
}

/// Render the pool-worker timeline (host wall time, µs from the process
/// epoch) as its own trace-event JSON.
pub fn render_pool(events: &[PoolEvent], samples: &[PoolSample]) -> String {
    let mut items: Vec<String> = Vec::with_capacity(events.len() + samples.len() + 8);

    let mut s = String::new();
    meta_process(&mut s, PID_POOL, "reinitpp pool (wall time)");
    items.push(std::mem::take(&mut s));
    let mut workers: Vec<usize> = events.iter().map(|e| e.worker).collect();
    workers.sort_unstable();
    workers.dedup();
    for w in &workers {
        meta_thread(&mut s, PID_POOL, *w as u32, &format!("worker {w}"));
        items.push(std::mem::take(&mut s));
    }

    for e in events {
        items.push(format!(
            "{{\"ph\":\"X\",\"pid\":{PID_POOL},\"tid\":{},\
             \"cat\":\"pool\",\"name\":{},\"ts\":{},\"dur\":{},\
             \"args\":{{\"point\":{},\"trial\":{}}}}}",
            e.worker,
            json_str(&format!("p{}t{}", e.point, e.trial)),
            json_num(e.begin_us),
            json_num(e.dur_us),
            e.point,
            e.trial
        ));
    }
    for c in samples {
        items.push(format!(
            "{{\"ph\":\"C\",\"pid\":{PID_POOL},\"tid\":0,\
             \"cat\":\"pool\",\"name\":{},\"ts\":{},\
             \"args\":{{\"value\":{}}}}}",
            json_str(c.name),
            json_num(c.at_us),
            c.value
        ));
    }

    format!(
        "{{\"traceEvents\":[\n{}\n],\n\"displayTimeUnit\":\"ms\",\n\
         \"otherData\":{{\"clock\":\"wall\"}}}}\n",
        items.join(",\n")
    )
}

/// Write the pool-worker timeline to `path`.
pub fn write_pool(
    path: impl AsRef<Path>,
    events: &[PoolEvent],
    samples: &[PoolSample],
) -> std::io::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    w.write_all(render_pool(events, samples).as_bytes())?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::super::Tracer;
    use super::*;
    use crate::sim::SimTime;

    #[test]
    fn render_emits_balanced_trace_event_json() {
        let tr = Tracer::new();
        tr.install(Recorder::new(4, None));
        tr.span("mpi", "allreduce", 1, SimTime(1_000), SimTime(3_000));
        tr.instant("recovery", "abort", 0, SimTime(2_000));
        tr.counter("exec", "events_pending", SimTime(2_500), 17);
        tr.add("mpi.recv_direct", 3);
        let rec = tr.take().unwrap();
        let j = render(&rec);
        assert!(j.starts_with("{\"traceEvents\":["));
        assert!(j.contains("\"ph\":\"X\""));
        assert!(j.contains("\"ph\":\"i\""));
        assert!(j.contains("\"ph\":\"C\""));
        assert!(j.contains("\"process_name\""));
        assert!(j.contains("\"name\":\"allreduce\""));
        assert!(j.contains("\"mpi.recv_direct\":3"));
        assert!(j.contains("\"dropped_events\":0"));
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }

    #[test]
    fn span_timestamps_are_microseconds_of_virtual_time() {
        let tr = Tracer::new();
        tr.install(Recorder::new(1, None));
        tr.span("ckpt", "save", 1, SimTime(2_000_000), SimTime(5_000_000));
        let j = render(&tr.take().unwrap());
        assert!(j.contains("\"ts\":2000"), "{j}");
        assert!(j.contains("\"dur\":3000"), "{j}");
    }

    #[test]
    fn pool_render_names_workers() {
        let ev = vec![PoolEvent {
            worker: 2,
            point: 0,
            trial: 1,
            begin_us: 10.0,
            dur_us: 5.0,
        }];
        let smp = vec![PoolSample {
            name: "queue_depth",
            at_us: 12.0,
            value: 7,
        }];
        let j = render_pool(&ev, &smp);
        assert!(j.contains("\"worker 2\""));
        assert!(j.contains("\"p0t1\""));
        assert!(j.contains("\"queue_depth\""));
        assert_eq!(j.matches('{').count(), j.matches('}').count());
    }
}
