//! Virtual-time tracing & profiling layer.
//!
//! A lightweight [`Recorder`] collects ring-buffered span, instant and
//! counter events carrying *both* clocks — the DES virtual clock
//! ([`SimTime`], nanoseconds) and host wall time (microseconds since the
//! process epoch) — threaded through the executor, MPI collectives and
//! recv matching, the checkpoint store, all five recovery drivers, and the
//! sweep worker pool. Exporters render it as Chrome trace-event JSON
//! (loadable in Perfetto, [`chrome`]), folded stacks for flamegraphs
//! ([`folded`]), and a machine-readable per-trial [`TrialProfile`] snapshot
//! ([`profile`]).
//!
//! Design constraints (EXPERIMENTS.md §Observability):
//!
//! - **Zero cost when off.** Every `Sim` owns a [`Tracer`] whose hot-path
//!   check is a single `Cell<bool>` load; the disabled path performs no
//!   allocation (span/counter names are `&'static str`) and is pinned by
//!   the alloc test. Instrumentation sites read the virtual clock *only
//!   after* checking the flag.
//! - **Observation only.** Recording never schedules events or awaits, so
//!   virtual-time behavior, figure CSVs, golden traces and digests are
//!   byte-identical with tracing on, off, or absent
//!   (`tests/trace_determinism.rs` + a CI cmp enforce this).
//! - **Bounded memory.** The ring drops the *oldest* events past capacity
//!   and counts the drops; monotonic counters and span totals are exact
//!   regardless of drops.

pub mod chrome;
pub mod folded;
pub mod profile;

pub use profile::{identity_hash, TrialCounters, TrialProfile};

use std::cell::{Cell, RefCell};
use std::collections::{BTreeMap, VecDeque};
use std::sync::{Mutex, OnceLock, RwLock};
use std::time::Instant;

use crate::sim::SimTime;

/// Ring capacity default: ~262k events (~16 MB); oldest dropped beyond it.
const DEFAULT_CAP: usize = 1 << 18;

/// Simulated ranks are folded onto at most this many rank-group tracks so a
/// 16k-rank trace still renders as a handful of Perfetto rows.
const MAX_RANK_TRACKS: u32 = 8;

/// Known span categories, in display order — the `--trace-filter` universe.
/// `integrity` carries checkpoint-corruption instants (`corrupt`,
/// `escalate`), `detect` the unreliable detector's `suspect` instants; both
/// are silent unless the imperfect-world knobs are armed. `shard` carries
/// the sharded executor's per-shard fired-event counter tracks (silent at
/// `--shards 1`).
pub const CATEGORIES: [&str; 8] =
    ["exec", "mpi", "ckpt", "recovery", "pool", "integrity", "detect", "shard"];

/// Process-wide trace destination, installed once by the CLI before any
/// trial runs. Tests pass a config explicitly to `run_trial_with` instead
/// of touching this, so parallel test threads cannot race on it (the one
/// exception, the CSV-determinism test, is the only global-touching test
/// in its binary and restores `None` before asserting).
#[derive(Clone, Debug, Default)]
pub struct TraceConfig {
    /// Output directory; per-trial artifacts are written under it.
    pub dir: String,
    /// `--trace-filter`: only record these categories (`None` = all).
    pub filter: Option<Vec<String>>,
}

fn global_slot() -> &'static RwLock<Option<TraceConfig>> {
    static G: OnceLock<RwLock<Option<TraceConfig>>> = OnceLock::new();
    G.get_or_init(|| RwLock::new(None))
}

/// Install (or clear) the process-wide trace destination.
pub fn set_global(cfg: Option<TraceConfig>) {
    *global_slot().write().unwrap() = cfg;
}

/// The process-wide trace destination, if any.
pub fn global() -> Option<TraceConfig> {
    global_slot().read().unwrap().clone()
}

/// Shared wall-clock epoch for every recorder and pool event in the
/// process, so the sim tracks and the pool tracks line up in one timeline.
fn process_epoch() -> Instant {
    static E: OnceLock<Instant> = OnceLock::new();
    *E.get_or_init(Instant::now)
}

/// Microseconds of host wall time since the process epoch.
pub fn wall_us() -> f64 {
    process_epoch().elapsed().as_secs_f64() * 1e6
}

/// One recorded event. Virtual timestamps are nanoseconds of [`SimTime`];
/// wall timestamps are µs from the process epoch.
#[derive(Clone, Debug)]
pub(crate) enum Ev {
    /// A closed interval on a track ("X" in trace-event JSON).
    Span {
        cat: &'static str,
        name: &'static str,
        track: u32,
        begin_ns: u64,
        dur_ns: u64,
        wall_us: f64,
    },
    /// A point-in-time marker ("i").
    Instant {
        cat: &'static str,
        name: &'static str,
        track: u32,
        at_ns: u64,
        wall_us: f64,
    },
    /// A sampled counter value ("C").
    Counter {
        cat: &'static str,
        name: &'static str,
        at_ns: u64,
        value: u64,
    },
}

/// Aggregated per-(category, name) span statistics, exact even when the
/// ring dropped events.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SpanTotal {
    /// Span category (one of [`CATEGORIES`]).
    pub cat: &'static str,
    /// Span name.
    pub name: &'static str,
    /// Number of spans recorded.
    pub count: u64,
    /// Total virtual-time duration, nanoseconds.
    pub total_ns: u64,
}

/// Ring-buffered trace collector for one trial.
#[derive(Debug)]
pub struct Recorder {
    cap: usize,
    events: VecDeque<Ev>,
    dropped: u64,
    /// Monotonic named counters (recv match kinds, wake/timer tallies…).
    counters: BTreeMap<&'static str, u64>,
    /// Exact span totals, immune to ring drops.
    totals: BTreeMap<(&'static str, &'static str), (u64, u64)>,
    filter: Option<Vec<String>>,
    ranks: u32,
    /// Ranks folded per rank-group track (track = 1 + rank / group).
    group: u32,
}

impl Recorder {
    /// A recorder for a trial of `ranks` simulated ranks, recording only
    /// the categories in `filter` (`None` = all).
    pub fn new(ranks: u32, filter: Option<Vec<String>>) -> Recorder {
        Recorder::with_capacity(ranks, filter, DEFAULT_CAP)
    }

    /// [`Recorder::new`] with an explicit ring capacity (tests).
    pub fn with_capacity(ranks: u32, filter: Option<Vec<String>>, cap: usize) -> Recorder {
        let group = ranks.div_ceil(MAX_RANK_TRACKS).max(1);
        Recorder {
            cap: cap.max(1),
            events: VecDeque::new(),
            dropped: 0,
            counters: BTreeMap::new(),
            totals: BTreeMap::new(),
            filter,
            ranks,
            group,
        }
    }

    #[inline]
    fn wants(&self, cat: &str) -> bool {
        match &self.filter {
            None => true,
            Some(f) => f.iter().any(|s| s == cat),
        }
    }

    fn push(&mut self, ev: Ev) {
        if self.events.len() == self.cap {
            self.events.pop_front();
            self.dropped += 1;
        }
        self.events.push_back(ev);
    }

    /// The rank-group track a simulated rank renders on (track 0 is the
    /// recovery timeline).
    pub fn track_for_rank(&self, rank: u32) -> u32 {
        1 + rank / self.group
    }

    /// Record a closed span `[begin, end]` of virtual time.
    pub(crate) fn span(
        &mut self,
        cat: &'static str,
        name: &'static str,
        track: u32,
        begin: SimTime,
        end: SimTime,
    ) {
        if !self.wants(cat) {
            return;
        }
        let begin_ns = begin.nanos();
        let dur_ns = end.nanos().saturating_sub(begin_ns);
        let t = self.totals.entry((cat, name)).or_insert((0, 0));
        t.0 += 1;
        t.1 += dur_ns;
        self.push(Ev::Span {
            cat,
            name,
            track,
            begin_ns,
            dur_ns,
            wall_us: wall_us(),
        });
    }

    /// Record a point-in-time marker.
    pub(crate) fn instant(&mut self, cat: &'static str, name: &'static str, track: u32, at: SimTime) {
        if !self.wants(cat) {
            return;
        }
        self.push(Ev::Instant {
            cat,
            name,
            track,
            at_ns: at.nanos(),
            wall_us: wall_us(),
        });
    }

    /// Record a sampled counter value at a virtual timestamp.
    pub(crate) fn counter(&mut self, cat: &'static str, name: &'static str, at: SimTime, value: u64) {
        if !self.wants(cat) {
            return;
        }
        self.push(Ev::Counter {
            cat,
            name,
            at_ns: at.nanos(),
            value,
        });
    }

    /// Bump a monotonic named counter (no timestamp, never dropped).
    pub(crate) fn add(&mut self, key: &'static str, delta: u64) {
        *self.counters.entry(key).or_insert(0) += delta;
    }

    /// Events currently buffered.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// True when nothing has been buffered.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Events evicted from the ring.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Monotonic named counters.
    pub fn counters(&self) -> &BTreeMap<&'static str, u64> {
        &self.counters
    }

    /// Exact per-(category, name) span statistics, sorted by key.
    pub fn span_totals(&self) -> Vec<SpanTotal> {
        self.totals
            .iter()
            .map(|(&(cat, name), &(count, total_ns))| SpanTotal {
                cat,
                name,
                count,
                total_ns,
            })
            .collect()
    }

    /// Total virtual nanoseconds of spans named `name` under `cat` (0 when
    /// none) — the determinism tests compare these to segment metrics.
    pub fn span_total_ns(&self, cat: &str, name: &str) -> u64 {
        self.totals
            .iter()
            .filter(|&(&(c, n), _)| c == cat && n == name)
            .map(|(_, &(_, ns))| ns)
            .sum()
    }

    /// Track-id → display-name table for the exporters: track 0 is the
    /// recovery timeline, then one track per rank group.
    pub(crate) fn track_names(&self) -> Vec<(u32, String)> {
        let mut out = vec![(0, "recovery".to_string())];
        if self.ranks > 0 {
            let tracks = self.ranks.div_ceil(self.group);
            for t in 0..tracks {
                let lo = t * self.group;
                let hi = ((t + 1) * self.group).min(self.ranks) - 1;
                let name = if lo == hi {
                    format!("rank {lo}")
                } else {
                    format!("ranks {lo}-{hi}")
                };
                out.push((1 + t, name));
            }
        }
        out
    }
}

/// The `Sim`'s always-present trace slot. Disabled cost: one `Cell<bool>`
/// load per site, no allocation, no `RefCell` borrow.
#[derive(Debug, Default)]
pub struct Tracer {
    on: Cell<bool>,
    rec: RefCell<Option<Recorder>>,
}

impl Tracer {
    /// A disabled tracer (the default state of every `Sim`).
    pub fn new() -> Tracer {
        Tracer::default()
    }

    /// Arm the tracer with a recorder.
    pub fn install(&self, rec: Recorder) {
        *self.rec.borrow_mut() = Some(rec);
        self.on.set(true);
    }

    /// Disarm and take the recorder (if any) for export.
    pub fn take(&self) -> Option<Recorder> {
        self.on.set(false);
        self.rec.borrow_mut().take()
    }

    /// Hot-path gate: is recording active?
    #[inline]
    pub fn is_on(&self) -> bool {
        self.on.get()
    }

    /// Record a span on an explicit track.
    #[inline]
    pub fn span(&self, cat: &'static str, name: &'static str, track: u32, begin: SimTime, end: SimTime) {
        if !self.on.get() {
            return;
        }
        if let Some(r) = self.rec.borrow_mut().as_mut() {
            r.span(cat, name, track, begin, end);
        }
    }

    /// Record a span on the rank-group track of `rank`.
    #[inline]
    pub fn rank_span(&self, cat: &'static str, name: &'static str, rank: u32, begin: SimTime, end: SimTime) {
        if !self.on.get() {
            return;
        }
        if let Some(r) = self.rec.borrow_mut().as_mut() {
            let track = r.track_for_rank(rank);
            r.span(cat, name, track, begin, end);
        }
    }

    /// Record an instant marker on an explicit track.
    #[inline]
    pub fn instant(&self, cat: &'static str, name: &'static str, track: u32, at: SimTime) {
        if !self.on.get() {
            return;
        }
        if let Some(r) = self.rec.borrow_mut().as_mut() {
            r.instant(cat, name, track, at);
        }
    }

    /// Record a sampled counter value.
    #[inline]
    pub fn counter(&self, cat: &'static str, name: &'static str, at: SimTime, value: u64) {
        if !self.on.get() {
            return;
        }
        if let Some(r) = self.rec.borrow_mut().as_mut() {
            r.counter(cat, name, at, value);
        }
    }

    /// Bump a monotonic named counter.
    #[inline]
    pub fn add(&self, key: &'static str, delta: u64) {
        if !self.on.get() {
            return;
        }
        if let Some(r) = self.rec.borrow_mut().as_mut() {
            r.add(key, delta);
        }
    }
}

/// One pool-worker trial execution, in host wall time (µs from the
/// process epoch). Collected across OS threads, so this side of the layer
/// is mutex-buffered rather than `Cell`-gated.
#[derive(Clone, Debug)]
pub struct PoolEvent {
    /// Worker index (0 = the serial path).
    pub worker: usize,
    /// Sweep point index of the trial.
    pub point: usize,
    /// Trial number within the point.
    pub trial: u32,
    /// Start, µs from the process epoch.
    pub begin_us: f64,
    /// Duration, µs.
    pub dur_us: f64,
}

/// A sampled pool-wide counter (injector queue depth) in host wall time.
#[derive(Clone, Debug)]
pub struct PoolSample {
    /// Counter name.
    pub name: &'static str,
    /// Sample time, µs from the process epoch.
    pub at_us: f64,
    /// Sampled value.
    pub value: u64,
}

#[derive(Default)]
struct PoolSink {
    events: Vec<PoolEvent>,
    samples: Vec<PoolSample>,
}

fn pool_sink() -> &'static Mutex<PoolSink> {
    static S: OnceLock<Mutex<PoolSink>> = OnceLock::new();
    S.get_or_init(|| Mutex::new(PoolSink::default()))
}

/// Should the pool record its events? (Checked once per sweep.)
pub fn pool_trace_enabled() -> bool {
    global_slot().read().unwrap().is_some()
}

/// Record one worker-trial execution.
pub fn pool_record_trial(worker: usize, point: usize, trial: u32, begin_us: f64, dur_us: f64) {
    pool_sink().lock().unwrap().events.push(PoolEvent {
        worker,
        point,
        trial,
        begin_us,
        dur_us,
    });
}

/// Record a pool-wide counter sample at the current wall time.
pub fn pool_sample(name: &'static str, value: u64) {
    let at_us = wall_us();
    pool_sink().lock().unwrap().samples.push(PoolSample { name, at_us, value });
}

/// Drain everything the pool recorded (exporter side).
pub fn take_pool_events() -> (Vec<PoolEvent>, Vec<PoolSample>) {
    let mut s = pool_sink().lock().unwrap();
    (std::mem::take(&mut s.events), std::mem::take(&mut s.samples))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(ns: u64) -> SimTime {
        SimTime(ns)
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let tr = Tracer::new();
        assert!(!tr.is_on());
        tr.span("exec", "x", 0, t(0), t(10));
        tr.add("k", 1);
        assert!(tr.take().is_none());
    }

    #[test]
    fn span_totals_are_exact() {
        let tr = Tracer::new();
        tr.install(Recorder::new(4, None));
        tr.span("mpi", "allreduce", 1, t(100), t(250));
        tr.span("mpi", "allreduce", 1, t(300), t(400));
        tr.span("ckpt", "save", 1, t(0), t(50));
        let rec = tr.take().unwrap();
        assert_eq!(rec.span_total_ns("mpi", "allreduce"), 250);
        assert_eq!(rec.span_total_ns("ckpt", "save"), 50);
        assert_eq!(rec.span_total_ns("mpi", "nope"), 0);
        let totals = rec.span_totals();
        assert_eq!(totals.len(), 2);
        assert_eq!(totals[1].count, 2);
        assert_eq!(rec.len(), 3);
    }

    #[test]
    fn ring_drops_oldest_but_totals_survive() {
        let mut rec = Recorder::with_capacity(1, None, 2);
        rec.span("exec", "a", 0, t(0), t(1));
        rec.span("exec", "b", 0, t(1), t(2));
        rec.span("exec", "c", 0, t(2), t(3));
        assert_eq!(rec.len(), 2);
        assert_eq!(rec.dropped(), 1);
        assert_eq!(rec.span_totals().len(), 3);
    }

    #[test]
    fn filter_drops_unwanted_categories() {
        let mut rec = Recorder::new(1, Some(vec!["mpi".to_string()]));
        rec.span("exec", "poll", 0, t(0), t(1));
        rec.span("mpi", "bcast", 1, t(0), t(1));
        assert_eq!(rec.len(), 1);
        assert_eq!(rec.span_total_ns("exec", "poll"), 0);
        assert_eq!(rec.span_total_ns("mpi", "bcast"), 1);
    }

    #[test]
    fn monotonic_counters_accumulate() {
        let mut rec = Recorder::new(1, None);
        rec.add("mpi.recv_direct", 3);
        rec.add("mpi.recv_direct", 2);
        rec.add("mpi.recv_buffered", 1);
        assert_eq!(rec.counters()["mpi.recv_direct"], 5);
        assert_eq!(rec.counters()["mpi.recv_buffered"], 1);
    }

    #[test]
    fn rank_groups_fold_onto_at_most_eight_tracks() {
        let rec = Recorder::new(16_384, None);
        assert_eq!(rec.track_for_rank(0), 1);
        assert_eq!(rec.track_for_rank(16_383), 8);
        let names = rec.track_names();
        assert_eq!(names.len(), 9); // recovery + 8 groups
        assert_eq!(names[0].1, "recovery");
        assert_eq!(names[1].1, "ranks 0-2047");

        let small = Recorder::new(4, None);
        assert_eq!(small.track_names().len(), 5);
        assert_eq!(small.track_names()[1].1, "rank 0");
    }

    #[test]
    fn global_config_roundtrip() {
        // Only this test touches the global slot (run_trial reads it via
        // the CLI path, which tests never exercise).
        assert!(global().is_none() || global().is_some()); // no panic
        let before = global();
        set_global(Some(TraceConfig {
            dir: "x".into(),
            filter: None,
        }));
        assert_eq!(global().unwrap().dir, "x");
        set_global(before);
    }
}
