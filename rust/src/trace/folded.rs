//! Folded-stack exporter for flamegraphs.
//!
//! Renders the recorder's *exact* span totals (immune to ring drops) in
//! the `flamegraph.pl` / inferno folded format: one line per stack with a
//! cumulative sample count. Stacks are `trial;<category>;<name>` and the
//! count is total virtual-time nanoseconds, so the flame widths show where
//! virtual time goes across a trial.

use std::io::{BufWriter, Write};
use std::path::Path;

use super::Recorder;

/// Render the folded-stack text for one recorder.
pub fn render(rec: &Recorder) -> String {
    let mut out = String::new();
    for t in rec.span_totals() {
        out.push_str(&format!("trial;{};{} {}\n", t.cat, t.name, t.total_ns));
    }
    out
}

/// Write the folded stacks to `path`.
pub fn write(path: impl AsRef<Path>, rec: &Recorder) -> std::io::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    w.write_all(render(rec).as_bytes())?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::super::Tracer;
    use super::*;
    use crate::sim::SimTime;

    #[test]
    fn folded_lines_carry_exact_totals() {
        let tr = Tracer::new();
        tr.install(Recorder::new(2, None));
        tr.span("mpi", "allreduce", 1, SimTime(0), SimTime(150));
        tr.span("mpi", "allreduce", 1, SimTime(200), SimTime(250));
        tr.span("ckpt", "save", 1, SimTime(0), SimTime(40));
        let rec = tr.take().unwrap();
        let text = render(&rec);
        assert!(text.contains("trial;mpi;allreduce 200\n"));
        assert!(text.contains("trial;ckpt;save 40\n"));
        assert_eq!(text.lines().count(), 2);
    }
}
