//! Failure detection building blocks (paper §3.1 "Fault Detection").
//!
//! - A daemon is the parent of its node's MPI processes: a child crash is
//!   observed via SIGCHLD (`watch_child`, with the SIGCHLD handling delay).
//! - The root holds a reliable control channel to each daemon: a daemon
//!   (node) crash is observed as a channel break (`watch_daemon`, with the
//!   TCP keepalive/RST detection delay).
//!
//! Both emit `DetectEvent`s into the observer's control mailbox. The ULFM
//! heartbeat detector is modeled as an additional notification latency on
//! the RTE->rank path (see `recovery::ulfm`), per Bosilca et al.'s
//! always-on observation ring.

use crate::sim::{ProcId, Sender, Sim, SimDuration, SimTime};

/// A detected failure, delivered to whoever monitors the process.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DetectEvent {
    /// An MPI process died (daemon-level SIGCHLD).
    RankDead { rank: u32, at: SimTime },
    /// A daemon (= node) died (root-level channel break).
    NodeDead { node: u32, at: SimTime },
}

impl DetectEvent {
    /// Virtual time of the underlying death (the kill instant, before the
    /// SIGCHLD/TCP-break delivery delay) — the spread to `Sim::now()` at
    /// delivery is the raw detection latency. The recovery metrics layer
    /// computes per-event latency from the injection-side kill record
    /// instead (`TrialMetrics::record_failure`/`record_detect`), so this
    /// accessor serves observers of the detect channel itself (tests,
    /// latency audits).
    pub fn at(&self) -> SimTime {
        match self {
            DetectEvent::RankDead { at, .. } | DetectEvent::NodeDead { at, .. } => *at,
        }
    }
}

/// Watch one MPI child process from its parent daemon. Spawns a monitor
/// task on `observer`; on death, delivers `RankDead` after the SIGCHLD
/// handling delay.
pub fn watch_child(
    sim: &Sim,
    observer: ProcId,
    child: ProcId,
    rank: u32,
    sigchld_delay: SimDuration,
    tx: Sender<DetectEvent>,
) {
    let sim2 = sim.clone();
    sim.spawn(observer, async move {
        let at = sim2.watch(child).await;
        tx.send(DetectEvent::RankDead { rank, at }, sigchld_delay);
    });
}

/// Watch a daemon from the root. On death, delivers `NodeDead` after the
/// TCP break-detection delay.
pub fn watch_daemon(
    sim: &Sim,
    observer: ProcId,
    daemon: ProcId,
    node: u32,
    break_delay: SimDuration,
    tx: Sender<DetectEvent>,
) {
    let sim2 = sim.clone();
    sim.spawn(observer, async move {
        let at = sim2.watch(daemon).await;
        tx.send(DetectEvent::NodeDead { node, at }, break_delay);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::channel;
    use std::cell::RefCell;
    use std::rc::Rc;

    #[test]
    fn child_death_detected_after_sigchld_delay() {
        let sim = Sim::new();
        let daemon = sim.spawn_process("daemon");
        let child = sim.spawn_process("rank3");
        let (tx, rx) = channel::<DetectEvent>(&sim);
        watch_child(&sim, daemon, child, 3, SimDuration::from_millis(1), tx);
        let s2 = sim.clone();
        sim.schedule(SimDuration::from_millis(50), move || s2.kill(child));
        let seen = Rc::new(RefCell::new(Vec::new()));
        let s3 = sim.clone();
        let seen2 = Rc::clone(&seen);
        sim.spawn(daemon, async move {
            let e = rx.recv().await.unwrap();
            seen2.borrow_mut().push((e, s3.now().nanos()));
        });
        sim.run();
        let v = seen.borrow();
        assert_eq!(v.len(), 1);
        let (e, at) = v[0];
        assert!(matches!(e, DetectEvent::RankDead { rank: 3, .. }));
        assert_eq!(at, 51_000_000); // kill at 50ms + 1ms SIGCHLD
        assert_eq!(e.at().nanos(), 50_000_000, "event carries the kill time");
    }

    #[test]
    fn daemon_death_detected_after_break_delay() {
        let sim = Sim::new();
        let root = sim.spawn_process("root");
        let daemon = sim.spawn_process("daemon2");
        let (tx, rx) = channel::<DetectEvent>(&sim);
        watch_daemon(&sim, root, daemon, 2, SimDuration::from_millis(400), tx);
        let s2 = sim.clone();
        sim.schedule(SimDuration::from_millis(10), move || s2.kill(daemon));
        let seen = Rc::new(RefCell::new(None));
        let s3 = sim.clone();
        let seen2 = Rc::clone(&seen);
        sim.spawn(root, async move {
            let e = rx.recv().await.unwrap();
            *seen2.borrow_mut() = Some((e, s3.now().nanos()));
        });
        sim.run();
        let (e, at) = seen.borrow().unwrap();
        assert!(matches!(e, DetectEvent::NodeDead { node: 2, .. }));
        assert_eq!(at, 410_000_000);
    }

    #[test]
    fn watcher_dies_with_its_observer() {
        // if the observer (daemon) itself dies, its monitor tasks vanish:
        // no spurious events, no hung tasks.
        let sim = Sim::new();
        let daemon = sim.spawn_process("daemon");
        let child = sim.spawn_process("rank0");
        let (tx, _rx) = channel::<DetectEvent>(&sim);
        watch_child(&sim, daemon, child, 0, SimDuration::from_millis(1), tx);
        sim.kill(daemon);
        let s = sim.run();
        assert_eq!(s.tasks_pending, 0);
    }
}
