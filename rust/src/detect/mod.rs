//! Failure detection building blocks (paper §3.1 "Fault Detection").
//!
//! - A daemon is the parent of its node's MPI processes: a child crash is
//!   observed via SIGCHLD (`watch_child`, with the SIGCHLD handling delay).
//! - The root holds a reliable control channel to each daemon: a daemon
//!   (node) crash is observed as a channel break (`watch_daemon`, with the
//!   TCP keepalive/RST detection delay).
//!
//! Both emit `DetectEvent`s into the observer's control mailbox. The ULFM
//! heartbeat detector is modeled as an additional notification latency on
//! the RTE->rank path (see `recovery::ulfm`), per Bosilca et al.'s
//! always-on observation ring.

use crate::sim::{ProcId, Sender, Sim, SimDuration, SimTime};

/// A detected failure, delivered to whoever monitors the process.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DetectEvent {
    /// An MPI process died (daemon-level SIGCHLD).
    RankDead { rank: u32, at: SimTime },
    /// A daemon (= node) died (root-level channel break).
    NodeDead { node: u32, at: SimTime },
}

impl DetectEvent {
    /// Virtual time of the underlying death (the kill instant, before the
    /// SIGCHLD/TCP-break delivery delay) — the spread to `Sim::now()` at
    /// delivery is the raw detection latency. The recovery metrics layer
    /// computes per-event latency from the injection-side kill record
    /// instead (`TrialMetrics::record_failure`/`record_detect`), so this
    /// accessor serves observers of the detect channel itself (tests,
    /// latency audits).
    pub fn at(&self) -> SimTime {
        match self {
            DetectEvent::RankDead { at, .. } | DetectEvent::NodeDead { at, .. } => *at,
        }
    }
}

/// Watch one MPI child process from its parent daemon. Spawns a monitor
/// task on `observer`; on death, delivers `RankDead` after the SIGCHLD
/// handling delay.
pub fn watch_child(
    sim: &Sim,
    observer: ProcId,
    child: ProcId,
    rank: u32,
    sigchld_delay: SimDuration,
    tx: Sender<DetectEvent>,
) {
    let sim2 = sim.clone();
    sim.spawn(observer, async move {
        let at = sim2.watch(child).await;
        tx.send(DetectEvent::RankDead { rank, at }, sigchld_delay);
    });
}

/// Watch a daemon from the root. On death, delivers `NodeDead` after the
/// TCP break-detection delay.
pub fn watch_daemon(
    sim: &Sim,
    observer: ProcId,
    daemon: ProcId,
    node: u32,
    break_delay: SimDuration,
    tx: Sender<DetectEvent>,
) {
    let sim2 = sim.clone();
    sim.spawn(observer, async move {
        let at = sim2.watch(daemon).await;
        tx.send(DetectEvent::NodeDead { node, at }, break_delay);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::channel;
    use std::cell::RefCell;
    use std::rc::Rc;

    #[test]
    fn child_death_detected_after_sigchld_delay() {
        let sim = Sim::new();
        let daemon = sim.spawn_process("daemon");
        let child = sim.spawn_process("rank3");
        let (tx, rx) = channel::<DetectEvent>(&sim);
        watch_child(&sim, daemon, child, 3, SimDuration::from_millis(1), tx);
        let s2 = sim.clone();
        sim.schedule(SimDuration::from_millis(50), move || s2.kill(child));
        let seen = Rc::new(RefCell::new(Vec::new()));
        let s3 = sim.clone();
        let seen2 = Rc::clone(&seen);
        sim.spawn(daemon, async move {
            let e = rx.recv().await.unwrap();
            seen2.borrow_mut().push((e, s3.now().nanos()));
        });
        sim.run();
        let v = seen.borrow();
        assert_eq!(v.len(), 1);
        let (e, at) = v[0];
        assert!(matches!(e, DetectEvent::RankDead { rank: 3, .. }));
        assert_eq!(at, 51_000_000); // kill at 50ms + 1ms SIGCHLD
        assert_eq!(e.at().nanos(), 50_000_000, "event carries the kill time");
    }

    #[test]
    fn daemon_death_detected_after_break_delay() {
        let sim = Sim::new();
        let root = sim.spawn_process("root");
        let daemon = sim.spawn_process("daemon2");
        let (tx, rx) = channel::<DetectEvent>(&sim);
        watch_daemon(&sim, root, daemon, 2, SimDuration::from_millis(400), tx);
        let s2 = sim.clone();
        sim.schedule(SimDuration::from_millis(10), move || s2.kill(daemon));
        let seen = Rc::new(RefCell::new(None));
        let s3 = sim.clone();
        let seen2 = Rc::clone(&seen);
        sim.spawn(root, async move {
            let e = rx.recv().await.unwrap();
            *seen2.borrow_mut() = Some((e, s3.now().nanos()));
        });
        sim.run();
        let (e, at) = seen.borrow().unwrap();
        assert!(matches!(e, DetectEvent::NodeDead { node: 2, .. }));
        assert_eq!(at, 410_000_000);
    }

    #[test]
    fn watcher_dies_with_its_observer() {
        // if the observer (daemon) itself dies, its monitor tasks vanish:
        // no spurious events, no hung tasks.
        let sim = Sim::new();
        let daemon = sim.spawn_process("daemon");
        let child = sim.spawn_process("rank0");
        let (tx, _rx) = channel::<DetectEvent>(&sim);
        watch_child(&sim, daemon, child, 0, SimDuration::from_millis(1), tx);
        sim.kill(daemon);
        let s = sim.run();
        assert_eq!(s.tasks_pending, 0);
    }

    /// Deterministic xorshift64 for the pseudo-property loops below: the
    /// offline build has no proptest, so seeded loops over randomized group
    /// shapes give the same coverage reproducibly.
    fn xorshift(state: &mut u64) -> u64 {
        let mut x = *state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        *state = x;
        x
    }

    #[test]
    fn no_false_positive_for_slow_but_alive_replicas() {
        // Property: detection is death-triggered, never latency-triggered.
        // A replica that is merely slow — its process sits in a virtual
        // sleep far longer than any SIGCHLD delay — must produce no event,
        // for any group size and any per-watcher delay.
        let mut seed = 0x5eed_0001_u64;
        for round in 0..16 {
            let sim = Sim::new();
            let daemon = sim.spawn_process("daemon");
            let (tx, rx) = channel::<DetectEvent>(&sim);
            let group = 1 + (xorshift(&mut seed) % 8) as u32;
            for r in 0..group {
                let child = sim.spawn_process("replica");
                let delay = SimDuration::from_millis(1 + xorshift(&mut seed) % 500);
                watch_child(&sim, daemon, child, r, delay, tx.clone());
                let s2 = sim.clone();
                sim.spawn(child, async move {
                    s2.sleep(SimDuration::from_secs_f64(30.0)).await;
                });
            }
            let seen = Rc::new(RefCell::new(0u32));
            let seen2 = Rc::clone(&seen);
            sim.spawn(daemon, async move {
                while rx.recv().await.is_ok() {
                    *seen2.borrow_mut() += 1;
                }
            });
            sim.run();
            assert_eq!(
                *seen.borrow(),
                0,
                "round {round}: a slow-but-alive replica was misdetected"
            );
        }
    }

    #[test]
    fn exactly_one_detection_per_real_death() {
        // Property: over a replica group of any shape, killing an arbitrary
        // subset at arbitrary times yields exactly one event per killed
        // rank — no duplicates, no misses, no events for survivors.
        let mut seed = 0xdead_beef_u64;
        for round in 0..16 {
            let sim = Sim::new();
            let daemon = sim.spawn_process("daemon");
            let (tx, rx) = channel::<DetectEvent>(&sim);
            let group = 2 + (xorshift(&mut seed) % 7) as u32;
            let mut killed: Vec<u32> = Vec::new();
            for r in 0..group {
                let child = sim.spawn_process("replica");
                let delay = SimDuration::from_millis(1 + xorshift(&mut seed) % 20);
                watch_child(&sim, daemon, child, r, delay, tx.clone());
                // kill roughly half the group; always kill rank 0 so every
                // round has at least one real death
                if r == 0 || xorshift(&mut seed) % 2 == 0 {
                    let t = SimDuration::from_millis(1 + xorshift(&mut seed) % 200);
                    let s2 = sim.clone();
                    sim.schedule(t, move || s2.kill(child));
                    killed.push(r);
                }
            }
            let seen = Rc::new(RefCell::new(Vec::new()));
            let seen2 = Rc::clone(&seen);
            sim.spawn(daemon, async move {
                while let Ok(e) = rx.recv().await {
                    seen2.borrow_mut().push(e);
                }
            });
            sim.run();
            let mut got: Vec<u32> = seen
                .borrow()
                .iter()
                .map(|e| match e {
                    DetectEvent::RankDead { rank, .. } => *rank,
                    other => panic!("round {round}: unexpected event {other:?}"),
                })
                .collect();
            got.sort_unstable();
            assert_eq!(
                got, killed,
                "round {round}: one detection per real death, nothing else"
            );
        }
    }

    #[test]
    fn detection_latency_is_bounded_by_the_configured_delay() {
        // Property: a replica group member's detection latency is exactly
        // its watcher's configured delivery delay (SIGCHLD handling or TCP
        // break detection) — in particular it is bounded by that delay and
        // independent of group size or kill timing.
        let mut seed = 0x1a7e_c0de_u64;
        for round in 0..16 {
            let sim = Sim::new();
            let daemon = sim.spawn_process("daemon");
            let (tx, rx) = channel::<DetectEvent>(&sim);
            let group = 1 + (xorshift(&mut seed) % 6) as u32;
            let mut delays = Vec::new();
            for r in 0..group {
                let child = sim.spawn_process("replica");
                let delay = SimDuration::from_millis(1 + xorshift(&mut seed) % 400);
                delays.push(delay);
                watch_child(&sim, daemon, child, r, delay, tx.clone());
                let t = SimDuration::from_millis(1 + xorshift(&mut seed) % 300);
                let s2 = sim.clone();
                sim.schedule(t, move || s2.kill(child));
            }
            let seen = Rc::new(RefCell::new(Vec::new()));
            let seen2 = Rc::clone(&seen);
            let s3 = sim.clone();
            sim.spawn(daemon, async move {
                while let Ok(e) = rx.recv().await {
                    seen2.borrow_mut().push((e, s3.now()));
                }
            });
            sim.run();
            let v = seen.borrow();
            assert_eq!(v.len(), group as usize, "round {round}: every death detected");
            for (e, delivered) in v.iter() {
                let DetectEvent::RankDead { rank, at } = e else {
                    panic!("round {round}: unexpected event {e:?}");
                };
                let latency = *delivered - *at;
                assert_eq!(
                    latency, delays[*rank as usize],
                    "round {round} rank {rank}: latency must equal the configured delay"
                );
            }
        }
    }
}
