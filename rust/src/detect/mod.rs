//! Failure detection building blocks (paper §3.1 "Fault Detection").
//!
//! - A daemon is the parent of its node's MPI processes: a child crash is
//!   observed via SIGCHLD (`watch_child`, with the SIGCHLD handling delay).
//! - The root holds a reliable control channel to each daemon: a daemon
//!   (node) crash is observed as a channel break (`watch_daemon`, with the
//!   TCP keepalive/RST detection delay).
//!
//! Both emit `DetectEvent`s into the observer's control mailbox. The ULFM
//! heartbeat detector is modeled as an additional notification latency on
//! the RTE->rank path (see `recovery::ulfm`), per Bosilca et al.'s
//! always-on observation ring.
//!
//! The paper assumes this machinery is *perfect*: every death is noticed
//! exactly once after a fixed delay and nothing else ever fires. The
//! unreliable-detector extension (`detect_fp_rate`, `detect_jitter_s`,
//! `suspect_timeout_s`) prices the imperfect world of real heartbeat
//! detectors (cf. FTHP-MPI): [`SuspicionSchedule`] pre-draws a
//! per-(seed,trial)-deterministic stream of *false suspicions* — each one
//! kills an innocent rank for real, triggering a fully-costed spurious
//! recovery — and [`detect_jitter`] adds a pure-hash latency jitter to
//! every true detection. Both are independent of the recovery method under
//! test, mirroring the fault-injection methodology.

use crate::config::ExperimentConfig;
use crate::sim::rng::Rng;
use crate::sim::{ProcId, Sender, Sim, SimDuration, SimTime};

/// A detected failure, delivered to whoever monitors the process.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DetectEvent {
    /// An MPI process died (daemon-level SIGCHLD).
    RankDead { rank: u32, at: SimTime },
    /// A daemon (= node) died (root-level channel break).
    NodeDead { node: u32, at: SimTime },
}

impl DetectEvent {
    /// Virtual time of the underlying death (the kill instant, before the
    /// SIGCHLD/TCP-break delivery delay) — the spread to `Sim::now()` at
    /// delivery is the raw detection latency. The recovery metrics layer
    /// computes per-event latency from the injection-side kill record
    /// instead (`TrialMetrics::record_failure`/`record_detect`), so this
    /// accessor serves observers of the detect channel itself (tests,
    /// latency audits).
    pub fn at(&self) -> SimTime {
        match self {
            DetectEvent::RankDead { at, .. } | DetectEvent::NodeDead { at, .. } => *at,
        }
    }
}

/// Watch one MPI child process from its parent daemon. Spawns a monitor
/// task on `observer`; on death, delivers `RankDead` after the SIGCHLD
/// handling delay.
pub fn watch_child(
    sim: &Sim,
    observer: ProcId,
    child: ProcId,
    rank: u32,
    sigchld_delay: SimDuration,
    tx: Sender<DetectEvent>,
) {
    let sim2 = sim.clone();
    sim.spawn(observer, async move {
        let at = sim2.watch(child).await;
        tx.send(DetectEvent::RankDead { rank, at }, sigchld_delay);
    });
}

/// Watch a daemon from the root. On death, delivers `NodeDead` after the
/// TCP break-detection delay.
pub fn watch_daemon(
    sim: &Sim,
    observer: ProcId,
    daemon: ProcId,
    node: u32,
    break_delay: SimDuration,
    tx: Sender<DetectEvent>,
) {
    let sim2 = sim.clone();
    sim.spawn(observer, async move {
        let at = sim2.watch(daemon).await;
        tx.send(DetectEvent::NodeDead { node, at }, break_delay);
    });
}

/// One planned false suspicion of the unreliable detector.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Suspicion {
    /// Virtual seconds after application start when the suspicion fires
    /// (before the confirmation timeout/backoff is added).
    pub at_s: f64,
    /// The innocently suspected rank.
    pub rank: u32,
}

/// The false-suspicion stream of one trial's unreliable detector.
///
/// Pre-drawn at trial start from its own RNG lineage (`detector` fork), so
/// the stream depends only on `(seed, trial)` and the detector knobs —
/// never on the recovery method, the failure timeline, or event ordering.
/// Inter-arrival times are exponential with mean `1 / detect_fp_rate`
/// (false positives are a Poisson process, like the real failures they
/// imitate); victims are uniform; the stream is capped at `max_failures`
/// events to bound pathological rates.
#[derive(Clone, Debug, Default)]
pub struct SuspicionSchedule {
    pub events: Vec<Suspicion>,
}

impl SuspicionSchedule {
    pub fn plan(cfg: &ExperimentConfig, trial: u32) -> SuspicionSchedule {
        if cfg.detect_fp_rate <= 0.0 {
            return SuspicionSchedule::default();
        }
        let mut rng = Rng::new(cfg.seed)
            .fork("detector")
            .fork(&format!("trial{trial}"));
        let mean = 1.0 / cfg.detect_fp_rate;
        let mut t = 0.0f64;
        let mut events = Vec::with_capacity(cfg.max_failures as usize);
        for _ in 0..cfg.max_failures {
            let u = rng.gen_f64();
            t += (mean * -(1.0 - u).ln()).max(1e-6);
            let rank = rng.gen_range(cfg.ranks as u64) as u32;
            events.push(Suspicion { at_s: t, rank });
        }
        SuspicionSchedule { events }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }
}

fn mix64(mut x: u64) -> u64 {
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Detection-latency jitter for one real detection: a pure hash of
/// `(seed, trial, rank)` mapped uniformly onto `[0, jitter_s]`. Being a
/// pure hash (not a stream draw), the jitter a given victim sees is
/// independent of how many detections preceded it — recovery methods that
/// detect the same death in different orders still see identical delays.
pub fn detect_jitter(seed: u64, trial: u32, rank: u32, jitter_s: f64) -> SimDuration {
    if jitter_s <= 0.0 {
        return SimDuration::ZERO;
    }
    let h = mix64(mix64(seed ^ 0x7e57_ab1e_dead_10cc) ^ ((trial as u64) << 32 | rank as u64));
    let unit = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
    SimDuration::from_secs_f64(unit * jitter_s)
}

/// Confirmation delay before acting on the `nth` suspicion of a rank
/// (0-based): the base timeout doubled per prior suspicion — the classic
/// accrual-style backoff that makes repeatedly suspected ranks harder to
/// declare dead.
pub fn suspicion_backoff(timeout_s: f64, nth: u32) -> SimDuration {
    if timeout_s <= 0.0 {
        return SimDuration::ZERO;
    }
    SimDuration::from_secs_f64(timeout_s * (1u64 << nth.min(32)) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::channel;
    use std::cell::RefCell;
    use std::rc::Rc;

    #[test]
    fn child_death_detected_after_sigchld_delay() {
        let sim = Sim::new();
        let daemon = sim.spawn_process("daemon");
        let child = sim.spawn_process("rank3");
        let (tx, rx) = channel::<DetectEvent>(&sim);
        watch_child(&sim, daemon, child, 3, SimDuration::from_millis(1), tx);
        let s2 = sim.clone();
        sim.schedule(SimDuration::from_millis(50), move || s2.kill(child));
        let seen = Rc::new(RefCell::new(Vec::new()));
        let s3 = sim.clone();
        let seen2 = Rc::clone(&seen);
        sim.spawn(daemon, async move {
            let e = rx.recv().await.unwrap();
            seen2.borrow_mut().push((e, s3.now().nanos()));
        });
        sim.run();
        let v = seen.borrow();
        assert_eq!(v.len(), 1);
        let (e, at) = v[0];
        assert!(matches!(e, DetectEvent::RankDead { rank: 3, .. }));
        assert_eq!(at, 51_000_000); // kill at 50ms + 1ms SIGCHLD
        assert_eq!(e.at().nanos(), 50_000_000, "event carries the kill time");
    }

    #[test]
    fn daemon_death_detected_after_break_delay() {
        let sim = Sim::new();
        let root = sim.spawn_process("root");
        let daemon = sim.spawn_process("daemon2");
        let (tx, rx) = channel::<DetectEvent>(&sim);
        watch_daemon(&sim, root, daemon, 2, SimDuration::from_millis(400), tx);
        let s2 = sim.clone();
        sim.schedule(SimDuration::from_millis(10), move || s2.kill(daemon));
        let seen = Rc::new(RefCell::new(None));
        let s3 = sim.clone();
        let seen2 = Rc::clone(&seen);
        sim.spawn(root, async move {
            let e = rx.recv().await.unwrap();
            *seen2.borrow_mut() = Some((e, s3.now().nanos()));
        });
        sim.run();
        let (e, at) = seen.borrow().unwrap();
        assert!(matches!(e, DetectEvent::NodeDead { node: 2, .. }));
        assert_eq!(at, 410_000_000);
    }

    #[test]
    fn watcher_dies_with_its_observer() {
        // if the observer (daemon) itself dies, its monitor tasks vanish:
        // no spurious events, no hung tasks.
        let sim = Sim::new();
        let daemon = sim.spawn_process("daemon");
        let child = sim.spawn_process("rank0");
        let (tx, _rx) = channel::<DetectEvent>(&sim);
        watch_child(&sim, daemon, child, 0, SimDuration::from_millis(1), tx);
        sim.kill(daemon);
        let s = sim.run();
        assert_eq!(s.tasks_pending, 0);
    }

    /// Deterministic xorshift64 for the pseudo-property loops below: the
    /// offline build has no proptest, so seeded loops over randomized group
    /// shapes give the same coverage reproducibly.
    fn xorshift(state: &mut u64) -> u64 {
        let mut x = *state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        *state = x;
        x
    }

    #[test]
    fn no_false_positive_for_slow_but_alive_replicas() {
        // Property: detection is death-triggered, never latency-triggered.
        // A replica that is merely slow — its process sits in a virtual
        // sleep far longer than any SIGCHLD delay — must produce no event,
        // for any group size and any per-watcher delay.
        let mut seed = 0x5eed_0001_u64;
        for round in 0..16 {
            let sim = Sim::new();
            let daemon = sim.spawn_process("daemon");
            let (tx, rx) = channel::<DetectEvent>(&sim);
            let group = 1 + (xorshift(&mut seed) % 8) as u32;
            for r in 0..group {
                let child = sim.spawn_process("replica");
                let delay = SimDuration::from_millis(1 + xorshift(&mut seed) % 500);
                watch_child(&sim, daemon, child, r, delay, tx.clone());
                let s2 = sim.clone();
                sim.spawn(child, async move {
                    s2.sleep(SimDuration::from_secs_f64(30.0)).await;
                });
            }
            let seen = Rc::new(RefCell::new(0u32));
            let seen2 = Rc::clone(&seen);
            sim.spawn(daemon, async move {
                while rx.recv().await.is_ok() {
                    *seen2.borrow_mut() += 1;
                }
            });
            sim.run();
            assert_eq!(
                *seen.borrow(),
                0,
                "round {round}: a slow-but-alive replica was misdetected"
            );
        }
    }

    #[test]
    fn exactly_one_detection_per_real_death() {
        // Property: over a replica group of any shape, killing an arbitrary
        // subset at arbitrary times yields exactly one event per killed
        // rank — no duplicates, no misses, no events for survivors.
        let mut seed = 0xdead_beef_u64;
        for round in 0..16 {
            let sim = Sim::new();
            let daemon = sim.spawn_process("daemon");
            let (tx, rx) = channel::<DetectEvent>(&sim);
            let group = 2 + (xorshift(&mut seed) % 7) as u32;
            let mut killed: Vec<u32> = Vec::new();
            for r in 0..group {
                let child = sim.spawn_process("replica");
                let delay = SimDuration::from_millis(1 + xorshift(&mut seed) % 20);
                watch_child(&sim, daemon, child, r, delay, tx.clone());
                // kill roughly half the group; always kill rank 0 so every
                // round has at least one real death
                if r == 0 || xorshift(&mut seed) % 2 == 0 {
                    let t = SimDuration::from_millis(1 + xorshift(&mut seed) % 200);
                    let s2 = sim.clone();
                    sim.schedule(t, move || s2.kill(child));
                    killed.push(r);
                }
            }
            let seen = Rc::new(RefCell::new(Vec::new()));
            let seen2 = Rc::clone(&seen);
            sim.spawn(daemon, async move {
                while let Ok(e) = rx.recv().await {
                    seen2.borrow_mut().push(e);
                }
            });
            sim.run();
            let mut got: Vec<u32> = seen
                .borrow()
                .iter()
                .map(|e| match e {
                    DetectEvent::RankDead { rank, .. } => *rank,
                    other => panic!("round {round}: unexpected event {other:?}"),
                })
                .collect();
            got.sort_unstable();
            assert_eq!(
                got, killed,
                "round {round}: one detection per real death, nothing else"
            );
        }
    }

    #[test]
    fn detection_latency_is_bounded_by_the_configured_delay() {
        // Property: a replica group member's detection latency is exactly
        // its watcher's configured delivery delay (SIGCHLD handling or TCP
        // break detection) — in particular it is bounded by that delay and
        // independent of group size or kill timing.
        let mut seed = 0x1a7e_c0de_u64;
        for round in 0..16 {
            let sim = Sim::new();
            let daemon = sim.spawn_process("daemon");
            let (tx, rx) = channel::<DetectEvent>(&sim);
            let group = 1 + (xorshift(&mut seed) % 6) as u32;
            let mut delays = Vec::new();
            for r in 0..group {
                let child = sim.spawn_process("replica");
                let delay = SimDuration::from_millis(1 + xorshift(&mut seed) % 400);
                delays.push(delay);
                watch_child(&sim, daemon, child, r, delay, tx.clone());
                let t = SimDuration::from_millis(1 + xorshift(&mut seed) % 300);
                let s2 = sim.clone();
                sim.schedule(t, move || s2.kill(child));
            }
            let seen = Rc::new(RefCell::new(Vec::new()));
            let seen2 = Rc::clone(&seen);
            let s3 = sim.clone();
            sim.spawn(daemon, async move {
                while let Ok(e) = rx.recv().await {
                    seen2.borrow_mut().push((e, s3.now()));
                }
            });
            sim.run();
            let v = seen.borrow();
            assert_eq!(v.len(), group as usize, "round {round}: every death detected");
            for (e, delivered) in v.iter() {
                let DetectEvent::RankDead { rank, at } = e else {
                    panic!("round {round}: unexpected event {e:?}");
                };
                let latency = *delivered - *at;
                assert_eq!(
                    latency, delays[*rank as usize],
                    "round {round} rank {rank}: latency must equal the configured delay"
                );
            }
        }
    }

    // ---- unreliable-detector pseudo-property tests ----

    fn noisy_cfg(seed: u64, ranks: u32, fp_rate: f64) -> ExperimentConfig {
        let mut c = ExperimentConfig::default();
        c.seed = seed;
        c.ranks = ranks;
        c.detect_fp_rate = fp_rate;
        c.max_failures = 6;
        c
    }

    #[test]
    fn suspicion_stream_is_deterministic_per_seed_and_trial() {
        // Property: same (seed, trial) -> identical stream; different trials
        // (or seeds) -> different streams. Randomized shapes, seeded loop.
        let mut s = 0x5eed_0001_u64;
        for round in 0..16 {
            let seed = xorshift(&mut s);
            let ranks = 4 + (xorshift(&mut s) % 60) as u32;
            let trial = (xorshift(&mut s) % 8) as u32;
            let cfg = noisy_cfg(seed, ranks, 0.5);
            let a = SuspicionSchedule::plan(&cfg, trial);
            let b = SuspicionSchedule::plan(&cfg, trial);
            assert_eq!(a.events, b.events, "round {round}: replan must replay");
            assert_eq!(a.len(), cfg.max_failures as usize);
            let c = SuspicionSchedule::plan(&cfg, trial + 1);
            assert_ne!(a.events, c.events, "round {round}: trials must differ");
            let mut prev = 0.0;
            for ev in &a.events {
                assert!(ev.at_s > prev, "round {round}: arrivals strictly increase");
                prev = ev.at_s;
                assert!(ev.rank < ranks, "round {round}: victim in range");
            }
        }
    }

    #[test]
    fn suspicion_stream_ignores_recovery_and_failure_timeline() {
        // Property: the stream depends only on (seed, trial) and the
        // detector knobs — CR and Reinit face identical false positives,
        // and adding real failures does not perturb it.
        use crate::config::{FailureKind, RecoveryKind};
        let mut s = 0xdead_beef_u64;
        for round in 0..16 {
            let seed = xorshift(&mut s);
            let trial = (xorshift(&mut s) % 5) as u32;
            let mut a = noisy_cfg(seed, 32, 0.25);
            a.recovery = RecoveryKind::Cr;
            let mut b = noisy_cfg(seed, 32, 0.25);
            b.recovery = RecoveryKind::Reinit;
            b.failure = FailureKind::Node;
            b.mtbf_s = 0.5;
            assert_eq!(
                SuspicionSchedule::plan(&a, trial).events,
                SuspicionSchedule::plan(&b, trial).events,
                "round {round}: stream must ignore recovery and timeline"
            );
        }
        // a perfect detector draws nothing at all
        let quiet = noisy_cfg(1, 32, 0.0);
        assert!(SuspicionSchedule::plan(&quiet, 0).is_empty());
    }

    #[test]
    fn jitter_is_pure_bounded_and_order_free() {
        // Property: detect_jitter is a pure function of (seed, trial, rank)
        // bounded by jitter_s — identical no matter when or how often it is
        // asked, and zero exactly when jitter is off.
        let mut s = 0x1a7e_c0de_u64;
        for round in 0..16 {
            let seed = xorshift(&mut s);
            let trial = (xorshift(&mut s) % 6) as u32;
            let rank = (xorshift(&mut s) % 64) as u32;
            let jitter_s = 0.001 + (xorshift(&mut s) % 100) as f64 / 1000.0;
            let a = detect_jitter(seed, trial, rank, jitter_s);
            let b = detect_jitter(seed, trial, rank, jitter_s);
            assert_eq!(a, b, "round {round}: pure function");
            assert!(
                a.secs_f64() <= jitter_s,
                "round {round}: jitter {a:?} exceeds bound {jitter_s}"
            );
            assert_eq!(
                detect_jitter(seed, trial, rank, 0.0),
                SimDuration::ZERO,
                "round {round}: no jitter when off"
            );
            assert_ne!(
                detect_jitter(seed, trial, rank.wrapping_add(1) % 64, jitter_s),
                a,
                "round {round}: distinct ranks draw distinct jitter (w.h.p.)"
            );
        }
    }

    #[test]
    fn suspicion_backoff_doubles_per_prior_suspicion() {
        assert_eq!(suspicion_backoff(0.0, 3), SimDuration::ZERO);
        assert_eq!(
            suspicion_backoff(0.5, 0),
            SimDuration::from_millis(500)
        );
        assert_eq!(suspicion_backoff(0.5, 1), SimDuration::from_secs_f64(1.0));
        assert_eq!(suspicion_backoff(0.5, 3), SimDuration::from_secs_f64(4.0));
    }
}
