//! Minimal TOML-subset parser (offline build: no serde/toml crates).
//!
//! Supported: `[section]` headers, `key = value` pairs with string, integer,
//! float, boolean and flat-array values, `#` comments. This covers every
//! config file the harness reads; nested tables and datetimes are rejected
//! with a line-numbered error.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed scalar value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// Parse error with 1-based line number.
#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ParseError {}

/// A parsed document: `(section, key) -> value`; top-level keys use "".
#[derive(Clone, Debug, Default)]
pub struct Doc {
    entries: BTreeMap<(String, String), Value>,
}

impl Doc {
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.entries.get(&(section.to_string(), key.to_string()))
    }

    pub fn section(&self, section: &str) -> Vec<(&str, &Value)> {
        self.entries
            .iter()
            .filter(|((s, _), _)| s == section)
            .map(|((_, k), v)| (k.as_str(), v))
            .collect()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

fn err(line: usize, msg: impl Into<String>) -> ParseError {
    ParseError {
        line,
        message: msg.into(),
    }
}

/// Parse one scalar (or array) value.
fn parse_value(raw: &str, line: usize) -> Result<Value, ParseError> {
    let raw = raw.trim();
    if raw.is_empty() {
        return Err(err(line, "empty value"));
    }
    if let Some(stripped) = raw.strip_prefix('"') {
        let Some(end) = stripped.find('"') else {
            return Err(err(line, "unterminated string"));
        };
        if !stripped[end + 1..].trim().is_empty() {
            return Err(err(line, "trailing characters after string"));
        }
        return Ok(Value::Str(stripped[..end].to_string()));
    }
    if raw.starts_with('[') {
        if !raw.ends_with(']') {
            return Err(err(line, "unterminated array"));
        }
        let inner = &raw[1..raw.len() - 1];
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            // flat arrays only: split on commas outside strings
            let mut depth_str = false;
            let mut cur = String::new();
            for c in inner.chars() {
                match c {
                    '"' => {
                        depth_str = !depth_str;
                        cur.push(c);
                    }
                    ',' if !depth_str => {
                        items.push(parse_value(&cur, line)?);
                        cur.clear();
                    }
                    _ => cur.push(c),
                }
            }
            if !cur.trim().is_empty() {
                items.push(parse_value(&cur, line)?);
            }
        }
        return Ok(Value::Array(items));
    }
    match raw {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = raw.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = raw.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(err(line, format!("cannot parse value `{raw}`")))
}

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> Result<Doc, ParseError> {
    let mut doc = Doc::default();
    let mut section = String::new();
    for (idx, line_raw) in text.lines().enumerate() {
        let lineno = idx + 1;
        // strip comments (naive: `#` not inside a string)
        let mut in_str = false;
        let mut line = String::new();
        for c in line_raw.chars() {
            match c {
                '"' => {
                    in_str = !in_str;
                    line.push(c);
                }
                '#' if !in_str => break,
                _ => line.push(c),
            }
        }
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let Some(name) = rest.strip_suffix(']') else {
                return Err(err(lineno, "malformed section header"));
            };
            if name.contains('[') || name.contains('.') {
                return Err(err(lineno, "nested tables are not supported"));
            }
            section = name.trim().to_string();
            continue;
        }
        let Some(eq) = line.find('=') else {
            return Err(err(lineno, "expected `key = value`"));
        };
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(err(lineno, "empty key"));
        }
        let value = parse_value(&line[eq + 1..], lineno)?;
        doc.entries
            .insert((section.clone(), key.to_string()), value);
    }
    Ok(doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenario_strings_survive_verbatim() {
        // Failure-timeline values (`failures = "proc@3:r5,node@7:r12"`) are
        // plain strings to this layer: `@`, `:` and `,` inside the quotes
        // must reach `config::apply` untouched for `fault::parse_failures`.
        let doc = parse("failures = \"proc@3:r5,node@7:r12,proc@t1.25:r3\"\n").unwrap();
        assert_eq!(
            doc.get("", "failures").unwrap().as_str(),
            Some("proc@3:r5,node@7:r12,proc@t1.25:r3")
        );
    }

    #[test]
    fn parses_scalars_and_sections() {
        let doc = parse(
            r#"
# experiment
app = "hpccg"
ranks = 64
[calibration]
fork_exec_ms = 150.5
fast = true
"#,
        )
        .unwrap();
        assert_eq!(doc.get("", "app").unwrap().as_str(), Some("hpccg"));
        assert_eq!(doc.get("", "ranks").unwrap().as_i64(), Some(64));
        assert_eq!(
            doc.get("calibration", "fork_exec_ms").unwrap().as_f64(),
            Some(150.5)
        );
        assert_eq!(doc.get("calibration", "fast").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn parses_arrays() {
        let doc = parse("ranks = [16, 32, 64]\nnames = [\"a\", \"b\"]").unwrap();
        let arr = doc.get("", "ranks").unwrap().as_array().unwrap();
        assert_eq!(
            arr.iter().map(|v| v.as_i64().unwrap()).collect::<Vec<_>>(),
            vec![16, 32, 64]
        );
        assert_eq!(doc.get("", "names").unwrap().as_array().unwrap().len(), 2);
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let doc = parse("\n# full line\na = 1 # trailing\n\n").unwrap();
        assert_eq!(doc.len(), 1);
        assert_eq!(doc.get("", "a").unwrap().as_i64(), Some(1));
    }

    #[test]
    fn hash_inside_string_kept() {
        let doc = parse("s = \"a#b\"").unwrap();
        assert_eq!(doc.get("", "s").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn int_coerces_to_f64_but_not_reverse() {
        let doc = parse("i = 3\nf = 3.5").unwrap();
        assert_eq!(doc.get("", "i").unwrap().as_f64(), Some(3.0));
        assert_eq!(doc.get("", "f").unwrap().as_i64(), None);
    }

    #[test]
    fn error_reports_line() {
        let e = parse("a = 1\nbad line\n").unwrap_err();
        assert_eq!(e.line, 2);
    }

    #[test]
    fn rejects_nested_tables() {
        assert!(parse("[a.b]\nx = 1").is_err());
    }

    #[test]
    fn rejects_unterminated_string() {
        assert!(parse("s = \"oops").is_err());
    }

    #[test]
    fn empty_doc_ok() {
        assert!(parse("").unwrap().is_empty());
    }

    #[test]
    fn section_iteration() {
        let doc = parse("[s]\na = 1\nb = 2\n[t]\nc = 3").unwrap();
        let keys: Vec<&str> = doc.section("s").into_iter().map(|(k, _)| k).collect();
        assert_eq!(keys, vec!["a", "b"]);
    }
}
