//! Cost-model calibration constants (DESIGN.md §6).
//!
//! Every latency/bandwidth the simulated cluster charges to virtual time is
//! drawn from this table. Defaults are calibrated so the simulated cluster
//! reproduces the paper's absolute anchors: ≈3 s CR re-deploy, ≈0.5 s
//! Reinit++ process recovery, ≈1.5 s Reinit++ node recovery, ULFM parity with
//! Reinit++ at ≤64 ranks degrading to ≈3× at 1024. Constants whose only
//! source is the paper's own measurement (the ULFM prototype's scaling) are
//! marked `calibrated-to-paper`. All values can be overridden from the config
//! file / CLI (`calibration.*` keys).

/// All tunable cost-model constants.
#[derive(Clone, Debug, PartialEq)]
pub struct Calibration {
    // ---- fabric (data plane) ----
    /// One-way latency between ranks on the same node (shared memory), µs.
    pub intra_latency_us: f64,
    /// Intra-node copy bandwidth, GB/s.
    pub intra_bw_gbps: f64,
    /// One-way latency between ranks on different nodes, µs.
    pub inter_latency_us: f64,
    /// Inter-node link bandwidth (100 Gb IB class), GB/s.
    pub inter_bw_gbps: f64,

    // ---- control plane (root <-> daemon TCP) ----
    /// One-way root<->daemon control message latency, µs.
    pub control_latency_us: f64,

    // ---- process management (ORTE) ----
    /// fork+exec+MPI-library-load of one MPI process, ms.
    pub fork_exec_ms: f64,
    /// Per-tree-level cost of launching ORTE daemons (mpirun tree spawn), ms.
    pub daemon_launch_per_level_ms: f64,
    /// Per-process daemon-local spawn serialization, ms (processes on one
    /// node spawn back-to-back; nodes proceed in parallel).
    pub spawn_serialize_ms: f64,
    /// RTE teardown after an abort (job cleanup, scheduler epilogue), s.
    pub teardown_s: f64,
    /// Fixed mpirun start cost (allocation handshake, binary broadcast), s.
    pub mpirun_base_s: f64,
    /// MPI_Init wireup cost per tree level (address exchange), ms.
    pub wireup_per_level_ms: f64,
    /// ORTE-level barrier cost per tree level (Reinit++ re-init sync), ms.
    pub orte_barrier_per_level_ms: f64,
    /// Rebuilding MPI_COMM_WORLD state after Reinit++ roll-back, ms.
    pub comm_reinit_ms: f64,

    // ---- fault detection ----
    /// SIGCHLD delivery + daemon handling, ms.
    pub sigchld_notify_ms: f64,
    /// Detection of a broken daemon TCP channel (node failure), ms.
    pub tcp_break_detect_ms: f64,
    /// Local kill/suicide signal handling, µs.
    pub signal_local_us: f64,

    // ---- parallel filesystem (Lustre) ----
    /// Aggregate OST bandwidth shared by all writers, GB/s.
    pub lustre_agg_gbps: f64,
    /// Per-client cap (single OST stripe path), GB/s.
    pub lustre_client_gbps: f64,
    /// Metadata open/close round trip per file op, ms.
    pub lustre_meta_ms: f64,

    // ---- in-memory / partner checkpointing ----
    /// Local memcpy bandwidth, GB/s.
    pub mem_bw_gbps: f64,
    /// Background checkpoint-drain trickle bandwidth cap, GB/s (the rate at
    /// which the async drain pushes copies down the tier stack; deliberately
    /// below the fabric/link rates so draining never starves the app).
    pub drain_bw_gbps: f64,

    // ---- modeled-fidelity compute ----
    /// Multiplier on the analytic per-kernel cost the modeled (native)
    /// backend charges to virtual time. Purely a virtual-time knob — host
    /// compute is unchanged — so storm-style scenarios can stretch the
    /// application clock to paper-scale iteration times (~tens of ms)
    /// while keeping the tiny per-rank grids that make 256-rank sweeps
    /// cheap to host. 1.0 (default) reproduces the calibrated figures
    /// bit-exactly.
    pub modeled_compute_scale: f64,

    // ---- ULFM prototype behaviour ----
    /// Heartbeat observation period, ms (failure detection latency floor).
    pub ulfm_hb_period_ms: f64,
    /// Fault-free overhead ULFM adds per application MPI phase, as a
    /// fraction per collective tree level: inflation = frac * log2(N).
    /// calibrated-to-paper (Fig. 5: visible growth by 1024 ranks).
    pub ulfm_overhead_frac_per_level: f64,
    /// Base cost of the revoke+shrink+agree+spawn+merge sequence, ms.
    /// calibrated-to-paper (Fig. 6: parity with Reinit++ at small scale).
    pub ulfm_recover_base_ms: f64,
    /// Per-rank component of the agreement/shrink collectives, µs.
    /// calibrated-to-paper (Fig. 6: ≈3× Reinit++ at 1024 ranks).
    pub ulfm_recover_per_rank_us: f64,
}

impl Default for Calibration {
    fn default() -> Self {
        Calibration {
            intra_latency_us: 1.0,
            intra_bw_gbps: 20.0,
            inter_latency_us: 2.0,
            inter_bw_gbps: 12.5,
            control_latency_us: 25.0,
            fork_exec_ms: 350.0,
            daemon_launch_per_level_ms: 80.0,
            spawn_serialize_ms: 35.0,
            teardown_s: 0.7,
            mpirun_base_s: 1.1,
            wireup_per_level_ms: 10.0,
            orte_barrier_per_level_ms: 2.0,
            comm_reinit_ms: 80.0,
            sigchld_notify_ms: 1.0,
            tcp_break_detect_ms: 400.0,
            signal_local_us: 50.0,
            lustre_agg_gbps: 12.0,
            lustre_client_gbps: 1.2,
            lustre_meta_ms: 15.0,
            mem_bw_gbps: 8.0,
            drain_bw_gbps: 1.0,
            modeled_compute_scale: 1.0,
            ulfm_hb_period_ms: 25.0,
            ulfm_overhead_frac_per_level: 0.022,
            ulfm_recover_base_ms: 20.0,
            ulfm_recover_per_rank_us: 1300.0,
        }
    }
}

impl Calibration {
    /// Apply one `calibration.<field> = <f64>` override. Returns false for
    /// an unknown key.
    pub fn set(&mut self, key: &str, value: f64) -> bool {
        macro_rules! table {
            ($($name:ident),* $(,)?) => {
                match key {
                    $(stringify!($name) => { self.$name = value; true })*
                    _ => false,
                }
            };
        }
        table!(
            intra_latency_us,
            intra_bw_gbps,
            inter_latency_us,
            inter_bw_gbps,
            control_latency_us,
            fork_exec_ms,
            daemon_launch_per_level_ms,
            spawn_serialize_ms,
            teardown_s,
            mpirun_base_s,
            wireup_per_level_ms,
            orte_barrier_per_level_ms,
            comm_reinit_ms,
            sigchld_notify_ms,
            tcp_break_detect_ms,
            signal_local_us,
            lustre_agg_gbps,
            lustre_client_gbps,
            lustre_meta_ms,
            mem_bw_gbps,
            drain_bw_gbps,
            modeled_compute_scale,
            ulfm_hb_period_ms,
            ulfm_overhead_frac_per_level,
            ulfm_recover_base_ms,
            ulfm_recover_per_rank_us,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_positive() {
        let c = Calibration::default();
        assert!(c.intra_bw_gbps > 0.0 && c.lustre_agg_gbps > 0.0);
        assert!(c.teardown_s + c.mpirun_base_s > 1.5, "CR anchor ≈ 3 s");
    }

    #[test]
    fn set_known_key() {
        let mut c = Calibration::default();
        assert!(c.set("fork_exec_ms", 123.0));
        assert_eq!(c.fork_exec_ms, 123.0);
    }

    #[test]
    fn set_unknown_key_rejected() {
        let mut c = Calibration::default();
        assert!(!c.set("warp_drive_ms", 1.0));
    }
}
