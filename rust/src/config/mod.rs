//! Typed experiment configuration: enums, defaults (the paper's Table 1
//! setup), TOML-subset config files, and dotted-key CLI overrides.

pub mod calibration;
pub mod presets;
pub mod toml;

pub use calibration::Calibration;

use crate::ckptstore::StackSpec;
use crate::fault::{parse_failures, FaultAnchor, FaultEvent};

use std::fmt;

/// Which proxy application to run (paper §4, Table 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AppKind {
    CoMD,
    Hpccg,
    Lulesh,
}

impl AppKind {
    pub const ALL: [AppKind; 3] = [AppKind::CoMD, AppKind::Hpccg, AppKind::Lulesh];

    pub fn parse(s: &str) -> Option<AppKind> {
        match s.to_ascii_lowercase().as_str() {
            "comd" => Some(AppKind::CoMD),
            "hpccg" => Some(AppKind::Hpccg),
            "lulesh" => Some(AppKind::Lulesh),
            _ => None,
        }
    }
}

impl fmt::Display for AppKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AppKind::CoMD => write!(f, "CoMD"),
            AppKind::Hpccg => write!(f, "HPCCG"),
            AppKind::Lulesh => write!(f, "LULESH"),
        }
    }
}

/// Recovery approach: the paper's three global-restart families (§4) plus
/// replication (FTHP-MPI / PartRePer-MPI lineage) — the one family that
/// recovers without rollback.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RecoveryKind {
    /// Checkpoint-Restart: abort + full re-deploy.
    Cr,
    /// User-Level Failure Mitigation (revoke/shrink/agree/spawn/merge).
    Ulfm,
    /// Reinit++ (this paper's contribution).
    Reinit,
    /// Replication: each rank backed by `repl_degree - 1` node-disjoint
    /// shadow replicas; a primary's death promotes a replica (failover,
    /// zero rollback) until the group is exhausted.
    Replication,
    /// Shrinking recovery (Shrink-or-Substitute / ReStore lineage): no
    /// respawn at all — survivors adopt the failed processes' domain
    /// blocks, the world communicator shrinks to the survivor process
    /// count, and the in-memory checkpoint copies are redistributed
    /// load-balanced over the live topology. Needs zero spare nodes;
    /// degrades to a CR-style re-deploy only below `min_ranks`.
    Shrink,
}

impl RecoveryKind {
    pub const ALL: [RecoveryKind; 5] = [
        RecoveryKind::Cr,
        RecoveryKind::Ulfm,
        RecoveryKind::Reinit,
        RecoveryKind::Replication,
        RecoveryKind::Shrink,
    ];

    /// The three families the source paper evaluates — the figure sweeps
    /// reproduce its plots and must not grow rows when new families join
    /// [`RecoveryKind::ALL`].
    pub const PAPER: [RecoveryKind; 3] =
        [RecoveryKind::Cr, RecoveryKind::Ulfm, RecoveryKind::Reinit];

    pub fn parse(s: &str) -> Option<RecoveryKind> {
        match s.to_ascii_lowercase().as_str() {
            "cr" => Some(RecoveryKind::Cr),
            "ulfm" => Some(RecoveryKind::Ulfm),
            "reinit" | "reinit++" | "reinitpp" => Some(RecoveryKind::Reinit),
            "repl" | "replication" => Some(RecoveryKind::Replication),
            "shrink" => Some(RecoveryKind::Shrink),
            _ => None,
        }
    }
}

impl fmt::Display for RecoveryKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RecoveryKind::Cr => write!(f, "CR"),
            RecoveryKind::Ulfm => write!(f, "ULFM"),
            RecoveryKind::Reinit => write!(f, "Reinit++"),
            RecoveryKind::Replication => write!(f, "Replication"),
            RecoveryKind::Shrink => write!(f, "Shrink"),
        }
    }
}

/// What failure to inject (paper §4: a single process OR node failure,
/// at a seeded-random iteration and rank).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FailureKind {
    None,
    Process,
    Node,
}

impl FailureKind {
    pub fn parse(s: &str) -> Option<FailureKind> {
        match s.to_ascii_lowercase().as_str() {
            "none" => Some(FailureKind::None),
            "process" | "proc" => Some(FailureKind::Process),
            "node" => Some(FailureKind::Node),
            _ => None,
        }
    }
}

impl fmt::Display for FailureKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FailureKind::None => write!(f, "none"),
            FailureKind::Process => write!(f, "process"),
            FailureKind::Node => write!(f, "node"),
        }
    }
}

/// Checkpoint storage scheme (paper Table 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CkptKind {
    /// Per-rank files on the shared parallel filesystem (Lustre model).
    File,
    /// Local + one node-disjoint partner copy in memory (maps to the
    /// `local+partner1` tier stack).
    Memory,
}

impl CkptKind {
    pub fn parse(s: &str) -> Option<CkptKind> {
        match s.to_ascii_lowercase().as_str() {
            "file" => Some(CkptKind::File),
            "memory" | "mem" => Some(CkptKind::Memory),
            _ => None,
        }
    }
}

impl fmt::Display for CkptKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CkptKind::File => write!(f, "file"),
            CkptKind::Memory => write!(f, "memory"),
        }
    }
}

/// Compute fidelity (DESIGN.md §8).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Fidelity {
    /// Every rank executes the real PJRT artifact each iteration.
    Full,
    /// One node of live ranks executes; others replay measured cost.
    Fast,
    /// Analytic per-iteration cost; no PJRT (unit tests).
    Modeled,
    /// Full for <= 128 ranks, Fast above.
    Auto,
}

impl Fidelity {
    pub fn parse(s: &str) -> Option<Fidelity> {
        match s.to_ascii_lowercase().as_str() {
            "full" => Some(Fidelity::Full),
            "fast" => Some(Fidelity::Fast),
            "modeled" => Some(Fidelity::Modeled),
            "auto" => Some(Fidelity::Auto),
            _ => None,
        }
    }

    /// Resolve `Auto` for a given rank count.
    pub fn resolve(self, ranks: u32) -> Fidelity {
        match self {
            Fidelity::Auto => {
                if ranks <= 128 {
                    Fidelity::Full
                } else {
                    Fidelity::Fast
                }
            }
            other => other,
        }
    }
}

/// One experiment = (app, scale, recovery, failure, checkpointing) x trials.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub app: AppKind,
    pub ranks: u32,
    pub ranks_per_node: u32,
    /// Extra idle nodes for re-spawning after a node failure
    /// (the paper's over-provisioning requirement, §3.2).
    pub spare_nodes: u32,
    pub recovery: RecoveryKind,
    /// Replication group size per logical rank (`repl_degree=2` = one
    /// node-disjoint shadow replica). 1 = no replicas: every failure
    /// degrades to a CR-style redeploy. Only meaningful with
    /// `recovery=repl`.
    pub repl_degree: u32,
    /// Shrinking recovery floor: the job keeps shrinking onto survivors
    /// while at least this many backing processes remain; one more loss
    /// degrades to a CR-style re-deploy (`degraded_redeploy`). Only
    /// consulted by `recovery=shrink`.
    pub min_ranks: u32,
    pub failure: FailureKind,
    /// Explicit multi-failure scenario
    /// (`failures=proc@3:r5,node@7:r12,proc@t1.25:r3`); overrides the
    /// single seeded draw and the MTBF process when non-empty.
    pub failures: Vec<FaultEvent>,
    /// Mean time between failures in virtual seconds (`mtbf_s=4`):
    /// exponential inter-arrival over virtual time, up to `max_failures`
    /// events of kind `failure`. 0 = disabled (the paper's single draw).
    pub mtbf_s: f64,
    /// Cap on MTBF-drawn events per trial (bounds storm length).
    pub max_failures: u32,
    /// Checkpoint generations retained per rank (`ckpt_keep=3` keeps the
    /// last three); the extra generations are what verify-on-load falls
    /// back to when the newest copy is corrupt. 1 = the classic
    /// latest-only model (plus the one-apart agreement slack).
    pub ckpt_keep: u32,
    /// Seeded bit-rot probability per installed checkpoint copy
    /// (`corrupt_rate=0.01`); 0 disables the integrity machinery unless a
    /// `corrupt@` timeline event arms it.
    pub corrupt_rate: f64,
    /// False-suspicion rate of the unreliable detector, in suspicions per
    /// virtual second across the job (`detect_fp_rate=0.002`). Each false
    /// positive kills an innocent rank and triggers a real, fully-costed
    /// spurious recovery. 0 = the paper's perfect detector.
    pub detect_fp_rate: f64,
    /// Detection-latency jitter bound in seconds: each real detection's
    /// propagation delay gains a per-(seed,trial,rank) uniform draw from
    /// [0, detect_jitter_s]. 0 = the paper's fixed delay.
    pub detect_jitter_s: f64,
    /// Suspicion confirmation timeout in seconds: a suspicion (true or
    /// false) is only acted on after this delay, doubling per repeated
    /// suspicion of the same rank (backoff). 0 = act immediately.
    pub suspect_timeout_s: f64,
    /// Recovery attempts allowed to fall back to older checkpoint
    /// generations before escalating to a full iteration-0 redeploy.
    pub retry_budget: u32,
    /// None = pick per the paper's Table 2 policy.
    pub ckpt: Option<CkptKind>,
    /// Explicit checkpoint tier stack (`ckpt_tiers=local+partner2+fs`);
    /// overrides `ckpt` / Table 2 when set.
    pub ckpt_tiers: Option<StackSpec>,
    /// Background drain cadence in seconds; 0 = synchronous write-through
    /// (the paper's blocking model).
    pub ckpt_drain_interval_s: f64,
    pub iters: u32,
    /// Store a checkpoint every k iterations (paper: every iteration).
    pub ckpt_every: u32,
    pub seed: u64,
    pub trials: u32,
    pub fidelity: Fidelity,
    /// CoMD particles per rank.
    pub comd_n: u32,
    /// HPCCG local grid edge per rank.
    pub hpccg_nx: u32,
    /// LULESH local grid edge per rank.
    pub lulesh_nx: u32,
    pub calib: Calibration,
    /// Directory with AOT artifacts (manifest.txt).
    pub artifacts_dir: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            app: AppKind::Hpccg,
            ranks: 16,
            ranks_per_node: 16,
            spare_nodes: 1,
            recovery: RecoveryKind::Reinit,
            repl_degree: 1,
            min_ranks: 2,
            failure: FailureKind::Process,
            failures: Vec::new(),
            mtbf_s: 0.0,
            max_failures: 4,
            ckpt_keep: 1,
            corrupt_rate: 0.0,
            detect_fp_rate: 0.0,
            detect_jitter_s: 0.0,
            suspect_timeout_s: 0.0,
            retry_budget: 3,
            ckpt: None,
            ckpt_tiers: None,
            ckpt_drain_interval_s: 0.0,
            iters: 20,
            ckpt_every: 1,
            seed: 20210621,
            trials: 10,
            fidelity: Fidelity::Auto,
            comd_n: 128,
            hpccg_nx: 16,
            lulesh_nx: 16,
            calib: Calibration::default(),
            artifacts_dir: "artifacts".to_string(),
        }
    }
}

/// Error applying a config key.
#[derive(Debug)]
pub struct ConfigError(pub String);

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config error: {}", self.0)
    }
}

impl std::error::Error for ConfigError {}

fn cerr(msg: impl Into<String>) -> ConfigError {
    ConfigError(msg.into())
}

impl ExperimentConfig {
    /// Number of compute nodes (excluding spares) for this scale.
    pub fn nodes(&self) -> u32 {
        self.ranks.div_ceil(self.ranks_per_node)
    }

    /// Which failure kinds this experiment can inject, over every scenario
    /// source: `(process, node)`. An explicit `failures=` scenario overrides
    /// the single-shot/MTBF kind, mirroring `FaultTimeline::plan`.
    pub fn configured_failure_kinds(&self) -> (bool, bool) {
        // `corrupt@` events kill nothing; only real failures count here.
        if self.failures.iter().any(|e| !e.corrupt) {
            return (
                self.failures
                    .iter()
                    .any(|e| !e.corrupt && e.kind == FailureKind::Process),
                self.failures
                    .iter()
                    .any(|e| !e.corrupt && e.kind == FailureKind::Node),
            );
        }
        if !self.failures.is_empty() {
            // corruption-only scenario: no kill is drawn from `failure`
            return (false, false);
        }
        (
            self.failure == FailureKind::Process,
            self.failure == FailureKind::Node,
        )
    }

    /// The failure kind that drives the Table 2 checkpoint-scheme choice:
    /// node failures dominate (they need permanent or node-disjoint
    /// storage). Identical to `failure` for single-shot configs.
    pub fn policy_failure(&self) -> FailureKind {
        match self.configured_failure_kinds() {
            (_, true) => FailureKind::Node,
            (true, false) => FailureKind::Process,
            (false, false) => self.failure,
        }
    }

    /// Checkpoint scheme after applying the paper's Table 2 policy
    /// (ignored when an explicit `ckpt_tiers` stack is set).
    pub fn effective_ckpt(&self) -> CkptKind {
        if let Some(k) = self.ckpt {
            return k;
        }
        crate::checkpoint::policy::default_scheme(self.recovery, self.policy_failure())
    }

    /// The checkpoint tier stack this experiment runs: an explicit
    /// `ckpt_tiers` override, or the Table 2 scheme mapped onto a stack
    /// (`file` → `fs`, `memory` → `local+partner1`), with the configured
    /// drain cadence applied either way.
    pub fn effective_stack(&self) -> StackSpec {
        let mut stack = match &self.ckpt_tiers {
            Some(s) => s.clone(),
            None => StackSpec::from_kind(self.effective_ckpt()),
        };
        stack.drain_interval_s = self.ckpt_drain_interval_s;
        stack
    }

    /// Apply a dotted-key override, e.g. `ranks=64`, `app=comd`,
    /// `calibration.fork_exec_ms=100`.
    pub fn apply(&mut self, key: &str, value: &str) -> Result<(), ConfigError> {
        if let Some(field) = key.strip_prefix("calibration.") {
            let v: f64 = value
                .parse()
                .map_err(|_| cerr(format!("calibration.{field}: not a number: {value}")))?;
            if !self.calib.set(field, v) {
                return Err(cerr(format!("unknown calibration key: {field}")));
            }
            return Ok(());
        }
        macro_rules! num {
            () => {
                value
                    .parse()
                    .map_err(|_| cerr(format!("{key}: bad number: {value}")))?
            };
        }
        match key {
            "app" => {
                self.app = AppKind::parse(value)
                    .ok_or_else(|| cerr(format!("unknown app: {value}")))?
            }
            "ranks" => self.ranks = num!(),
            "ranks_per_node" => self.ranks_per_node = num!(),
            "spare_nodes" => self.spare_nodes = num!(),
            "recovery" => {
                self.recovery = RecoveryKind::parse(value)
                    .ok_or_else(|| cerr(format!("unknown recovery: {value}")))?
            }
            "repl_degree" => {
                let v: u32 = num!();
                if v == 0 {
                    return Err(cerr("repl_degree must be >= 1 (1 = no replicas)"));
                }
                self.repl_degree = v;
            }
            "min_ranks" => {
                let v: u32 = num!();
                if v == 0 {
                    return Err(cerr("min_ranks must be >= 1"));
                }
                self.min_ranks = v;
            }
            "failure" => {
                self.failure = FailureKind::parse(value)
                    .ok_or_else(|| cerr(format!("unknown failure: {value}")))?
            }
            "failures" => self.failures = parse_failures(value).map_err(cerr)?,
            "mtbf_s" => {
                // Satellite bugfix: mtbf_s=0 used to silently disable the
                // arrival process, making a typo'd exponent (0.5 -> 0)
                // indistinguishable from "no storm". Disabling is now the
                // explicit `off`/`none`; numbers must be a real mean.
                if value.eq_ignore_ascii_case("off") || value.eq_ignore_ascii_case("none") {
                    self.mtbf_s = 0.0;
                    return Ok(());
                }
                let v: f64 = value
                    .parse()
                    .map_err(|_| cerr(format!("{key}: bad number: {value}")))?;
                if !(v > 0.0 && v.is_finite()) {
                    return Err(cerr(
                        "mtbf_s must be > 0 (use mtbf_s=off to disable the arrival process)",
                    ));
                }
                self.mtbf_s = v;
            }
            "max_failures" => {
                let v: u32 = num!();
                if v == 0 {
                    return Err(cerr("max_failures must be >= 1"));
                }
                self.max_failures = v;
            }
            "ckpt_keep" => {
                let v: u32 = num!();
                if v == 0 {
                    return Err(cerr(
                        "ckpt_keep must be >= 1 (1 = keep the latest generation only)",
                    ));
                }
                self.ckpt_keep = v;
            }
            "corrupt_rate" => {
                let v: f64 = value
                    .parse()
                    .map_err(|_| cerr(format!("{key}: bad number: {value}")))?;
                if !((0.0..=1.0).contains(&v) && v.is_finite()) {
                    return Err(cerr("corrupt_rate must be a probability in [0, 1]"));
                }
                self.corrupt_rate = v;
            }
            "detect_fp_rate" => {
                let v: f64 = value
                    .parse()
                    .map_err(|_| cerr(format!("{key}: bad number: {value}")))?;
                if !(v >= 0.0 && v.is_finite()) {
                    return Err(cerr(
                        "detect_fp_rate must be >= 0 (false suspicions per virtual second)",
                    ));
                }
                self.detect_fp_rate = v;
            }
            "detect_jitter_s" => {
                let v: f64 = value
                    .parse()
                    .map_err(|_| cerr(format!("{key}: bad number: {value}")))?;
                if !(v >= 0.0 && v.is_finite()) {
                    return Err(cerr("detect_jitter_s must be >= 0"));
                }
                self.detect_jitter_s = v;
            }
            "suspect_timeout_s" => {
                let v: f64 = value
                    .parse()
                    .map_err(|_| cerr(format!("{key}: bad number: {value}")))?;
                if !(v >= 0.0 && v.is_finite()) {
                    return Err(cerr("suspect_timeout_s must be >= 0"));
                }
                self.suspect_timeout_s = v;
            }
            "retry_budget" => self.retry_budget = num!(),
            "ckpt" => {
                self.ckpt = Some(
                    CkptKind::parse(value)
                        .ok_or_else(|| cerr(format!("unknown ckpt: {value}")))?,
                )
            }
            "ckpt_tiers" => {
                if value.eq_ignore_ascii_case("auto") || value.eq_ignore_ascii_case("table2")
                {
                    self.ckpt_tiers = None;
                } else {
                    self.ckpt_tiers = Some(StackSpec::parse(value).map_err(cerr)?);
                }
            }
            "ckpt_drain_interval_s" => {
                let v: f64 = value
                    .parse()
                    .map_err(|_| cerr(format!("{key}: bad number: {value}")))?;
                if !(v >= 0.0 && v.is_finite()) {
                    return Err(cerr("ckpt_drain_interval_s must be >= 0"));
                }
                self.ckpt_drain_interval_s = v;
            }
            "iters" => self.iters = num!(),
            "ckpt_every" => self.ckpt_every = num!(),
            "seed" => self.seed = num!(),
            "trials" => self.trials = num!(),
            "fidelity" => {
                self.fidelity = Fidelity::parse(value)
                    .ok_or_else(|| cerr(format!("unknown fidelity: {value}")))?
            }
            "comd_n" => self.comd_n = num!(),
            "hpccg_nx" => self.hpccg_nx = num!(),
            "lulesh_nx" => self.lulesh_nx = num!(),
            "artifacts_dir" => self.artifacts_dir = value.to_string(),
            _ => return Err(cerr(format!("unknown config key: {key}"))),
        }
        Ok(())
    }

    /// Load overrides from a TOML-subset document (top-level keys plus a
    /// `[calibration]` section).
    pub fn apply_doc(&mut self, doc: &toml::Doc) -> Result<(), ConfigError> {
        let items: Vec<(String, String)> = doc
            .section("")
            .into_iter()
            .map(|(k, v)| (k.to_string(), value_to_string(v)))
            .chain(
                doc.section("calibration")
                    .into_iter()
                    .map(|(k, v)| (format!("calibration.{k}"), value_to_string(v))),
            )
            .collect();
        for (k, v) in items {
            self.apply(&k, &v)?;
        }
        Ok(())
    }

    /// Validate cross-field invariants; call before running.
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.ranks == 0 || self.ranks_per_node == 0 {
            return Err(cerr("ranks and ranks_per_node must be > 0"));
        }
        if self.iters == 0 {
            return Err(cerr("iters must be > 0"));
        }
        if self.ckpt_every == 0 {
            return Err(cerr("ckpt_every must be > 0"));
        }
        if !self.failures.is_empty() && self.mtbf_s > 0.0 {
            return Err(cerr(
                "failures= and mtbf_s= both set: pick one scenario source \
                 (an explicit timeline or the MTBF arrival process)",
            ));
        }
        if self.mtbf_s > 0.0 && self.failure == FailureKind::None {
            return Err(cerr(
                "mtbf_s needs failure=process|node (the kind every drawn event injects)",
            ));
        }
        let (has_process, has_node) = self.configured_failure_kinds();
        // An unreliable detector's false positives kill innocent ranks for
        // real, so the stack and topology must survive process failures.
        let has_process = has_process || self.detect_fp_rate > 0.0;
        let any_failure = has_process || has_node;
        if any_failure && self.iters < 3 {
            // Iteration draws need a non-degenerate [1, iters-1) window (the
            // seed silently drew iteration == iters-1 at iters == 2), and
            // even explicit scenarios need at least one checkpointed
            // iteration strictly inside the run.
            return Err(cerr(
                "failure injection needs iters >= 3 (one checkpoint before the \
                 failure, one iteration after it)",
            ));
        }
        for ev in &self.failures {
            if ev.kind == FailureKind::None && !ev.corrupt {
                return Err(cerr(format!("failure event `{ev}`: kind cannot be none")));
            }
            if ev.rank >= self.ranks {
                return Err(cerr(format!(
                    "failure event `{ev}`: victim rank out of range (ranks={})",
                    self.ranks
                )));
            }
            match ev.anchor {
                FaultAnchor::Iteration(i) if i >= self.iters => {
                    return Err(cerr(format!(
                        "failure event `{ev}`: iteration anchor past the run (iters={})",
                        self.iters
                    )));
                }
                FaultAnchor::Time(t) if !(t > 0.0 && t.is_finite()) => {
                    return Err(cerr(format!(
                        "failure event `{ev}`: time anchor must be finite and > 0"
                    )));
                }
                _ => {}
            }
        }
        if has_node && self.spare_nodes == 0 && self.recovery != RecoveryKind::Shrink {
            // Shrink is exempt: its whole point is surviving node loss with
            // zero over-provisioning — survivors adopt the dead node's ranks.
            return Err(cerr(
                "node-failure experiments need spare_nodes >= 1 (over-provisioning, paper §3.2)",
            ));
        }
        if self.recovery == RecoveryKind::Shrink && (self.min_ranks == 0 || self.min_ranks > self.ranks)
        {
            return Err(cerr(format!(
                "min_ranks={} must be in 1..=ranks ({})",
                self.min_ranks, self.ranks
            )));
        }
        if self.repl_degree > 1 && self.recovery != RecoveryKind::Replication {
            return Err(cerr(format!(
                "repl_degree={} is only meaningful with recovery=repl (got recovery={})",
                self.repl_degree, self.recovery
            )));
        }
        if self.repl_degree > self.nodes() {
            // A same-node shadow replica dies with its primary and defeats
            // the whole point; refuse the degenerate placement outright.
            return Err(cerr(format!(
                "repl_degree={} needs at least {} compute nodes for node-disjoint \
                 replica placement, but {} ranks at ranks_per_node={} give only {} \
                 — lower ranks_per_node (more nodes) or lower repl_degree",
                self.repl_degree,
                self.repl_degree,
                self.ranks,
                self.ranks_per_node,
                self.nodes()
            )));
        }
        let stack = self.effective_stack();
        stack.check().map_err(cerr)?;
        if has_process && !stack.survives_process_failure(self.ranks) {
            return Err(cerr(format!(
                "checkpoint stack `{stack}` cannot survive a process failure \
                 (add a partner or fs tier)"
            )));
        }
        if has_node && !stack.survives_node_failure(self.nodes()) {
            return Err(cerr(format!(
                "checkpoint stack `{stack}` cannot survive a node failure at this scale \
                 (need a node-disjoint partner tier with >= 2 compute nodes, or an fs \
                 tier — paper Table 2's memory scheme maps to the 1-node case)"
            )));
        }
        if self.app == AppKind::Lulesh {
            // paper: LULESH requires a cube number of ranks
            let c = (self.ranks as f64).cbrt().round() as u32;
            if c * c * c != self.ranks {
                return Err(cerr(format!(
                    "LULESH needs a cube rank count (got {})",
                    self.ranks
                )));
            }
        }
        Ok(())
    }
}

fn value_to_string(v: &toml::Value) -> String {
    match v {
        toml::Value::Str(s) => s.clone(),
        toml::Value::Int(i) => i.to_string(),
        toml::Value::Float(f) => f.to_string(),
        toml::Value::Bool(b) => b.to_string(),
        toml::Value::Array(_) => "<array>".to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_valid() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn apply_basic_keys() {
        let mut c = ExperimentConfig::default();
        c.apply("app", "comd").unwrap();
        c.apply("ranks", "64").unwrap();
        c.apply("recovery", "ulfm").unwrap();
        c.apply("failure", "node").unwrap();
        c.apply("ckpt", "file").unwrap();
        assert_eq!(c.app, AppKind::CoMD);
        assert_eq!(c.ranks, 64);
        assert_eq!(c.recovery, RecoveryKind::Ulfm);
        assert_eq!(c.failure, FailureKind::Node);
        assert_eq!(c.ckpt, Some(CkptKind::File));
    }

    #[test]
    fn apply_calibration_key() {
        let mut c = ExperimentConfig::default();
        c.apply("calibration.teardown_s", "2.5").unwrap();
        assert_eq!(c.calib.teardown_s, 2.5);
    }

    #[test]
    fn unknown_keys_error() {
        let mut c = ExperimentConfig::default();
        assert!(c.apply("bogus", "1").is_err());
        assert!(c.apply("calibration.bogus", "1").is_err());
        assert!(c.apply("app", "gromacs").is_err());
    }

    #[test]
    fn nodes_round_up() {
        let mut c = ExperimentConfig::default();
        c.ranks = 17;
        c.ranks_per_node = 16;
        assert_eq!(c.nodes(), 2);
    }

    #[test]
    fn lulesh_cube_rank_check() {
        let mut c = ExperimentConfig::default();
        c.app = AppKind::Lulesh;
        c.ranks = 27;
        c.validate().unwrap();
        c.ranks = 32;
        assert!(c.validate().is_err());
    }

    #[test]
    fn memory_ckpt_with_node_failure_rejected() {
        // default scale = one compute node: no node-disjoint placement
        // exists, so the memory stack cannot survive (paper Table 2).
        let mut c = ExperimentConfig::default();
        c.failure = FailureKind::Node;
        c.ckpt = Some(CkptKind::Memory);
        assert!(c.validate().is_err());
    }

    #[test]
    fn node_disjoint_stack_allows_node_failure_at_multi_node_scale() {
        let mut c = ExperimentConfig::default();
        c.ranks = 16;
        c.ranks_per_node = 4; // 4 compute nodes
        c.failure = FailureKind::Node;
        c.apply("ckpt_tiers", "local+partner1").unwrap();
        c.validate().unwrap();
        // ...but a same-node partner stays rejected
        c.apply("ckpt_tiers", "local+partner1.same").unwrap();
        assert!(c.validate().is_err());
        // and a local-only stack cannot even survive a process failure
        c.failure = FailureKind::Process;
        c.apply("ckpt_tiers", "local").unwrap();
        assert!(c.validate().is_err());
    }

    #[test]
    fn effective_stack_maps_table2_and_honors_overrides() {
        let c = ExperimentConfig::default(); // Reinit + process
        assert_eq!(c.effective_stack().to_string(), "local+partner1");
        let mut c = ExperimentConfig::default();
        c.recovery = RecoveryKind::Cr;
        assert_eq!(c.effective_stack().to_string(), "fs");
        c.apply("ckpt_tiers", "local+partner2+fs").unwrap();
        c.apply("ckpt_drain_interval_s", "0.25").unwrap();
        let s = c.effective_stack();
        assert_eq!(s.to_string(), "local+partner2+fs");
        assert_eq!(s.drain_interval_s, 0.25);
        // `auto` clears the override back to the Table 2 route
        c.apply("ckpt_tiers", "auto").unwrap();
        assert_eq!(c.effective_stack().to_string(), "fs");
    }

    #[test]
    fn ckpt_tier_keys_reject_garbage() {
        let mut c = ExperimentConfig::default();
        assert!(c.apply("ckpt_tiers", "warp").is_err());
        assert!(c.apply("ckpt_tiers", "fs+local").is_err());
        assert!(c.apply("ckpt_drain_interval_s", "-1").is_err());
        assert!(c.apply("ckpt_drain_interval_s", "x").is_err());
    }

    #[test]
    fn node_failure_needs_spares() {
        let mut c = ExperimentConfig::default();
        c.failure = FailureKind::Node;
        c.ckpt = Some(CkptKind::File);
        c.spare_nodes = 0;
        assert!(c.validate().is_err());
        c.spare_nodes = 1;
        c.validate().unwrap();
        // shrink is exempt: it continues on survivors with zero spares
        c.spare_nodes = 0;
        c.apply("recovery", "shrink").unwrap();
        c.validate().unwrap();
    }

    #[test]
    fn min_ranks_applies_and_validates() {
        let mut c = ExperimentConfig::default();
        assert_eq!(c.min_ranks, 2, "default shrink floor");
        assert!(c.apply("min_ranks", "0").is_err());
        assert!(c.apply("min_ranks", "x").is_err());
        c.apply("min_ranks", "4").unwrap();
        assert_eq!(c.min_ranks, 4);
        // the floor is only checked against ranks when shrink is active
        c.min_ranks = 99;
        c.validate().unwrap();
        c.apply("recovery", "shrink").unwrap();
        assert!(c.validate().is_err(), "min_ranks > ranks under shrink");
        c.apply("min_ranks", "2").unwrap();
        c.validate().unwrap();
    }

    #[test]
    fn apply_doc_roundtrip() {
        let doc = toml::parse(
            "app = \"lulesh\"\nranks = 27\n[calibration]\nfork_exec_ms = 99.0\n",
        )
        .unwrap();
        let mut c = ExperimentConfig::default();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.app, AppKind::Lulesh);
        assert_eq!(c.ranks, 27);
        assert_eq!(c.calib.fork_exec_ms, 99.0);
    }

    #[test]
    fn fidelity_auto_resolution() {
        assert_eq!(Fidelity::Auto.resolve(64), Fidelity::Full);
        assert_eq!(Fidelity::Auto.resolve(256), Fidelity::Fast);
        assert_eq!(Fidelity::Modeled.resolve(1024), Fidelity::Modeled);
    }

    #[test]
    fn failure_scenario_keys_parse_and_validate() {
        let mut c = ExperimentConfig::default();
        c.apply("failures", "proc@3:r5,node@7:r12").unwrap();
        assert_eq!(c.failures.len(), 2);
        c.validate().unwrap();
        // node event in the scenario drives Table 2 to the file scheme and
        // demands spares
        assert_eq!(c.policy_failure(), FailureKind::Node);
        assert_eq!(c.effective_ckpt(), CkptKind::File);
        c.spare_nodes = 0;
        assert!(c.validate().is_err(), "node events need spares");
        c.spare_nodes = 1;
        // scenario + MTBF is ambiguous
        c.apply("mtbf_s", "2.0").unwrap();
        assert!(c.validate().is_err());
        c.apply("mtbf_s", "off").unwrap();
        // out-of-range events are rejected
        c.apply("failures", "proc@3:r99").unwrap();
        assert!(c.validate().is_err(), "victim out of range");
        c.apply("failures", "proc@25:r5").unwrap();
        assert!(c.validate().is_err(), "iteration past the run");
        c.apply("failures", "none").unwrap();
        c.validate().unwrap();
        assert!(c.apply("failures", "warp@1:r0").is_err());
        assert!(c.apply("mtbf_s", "-1").is_err());
        assert!(c.apply("max_failures", "0").is_err());
    }

    #[test]
    fn mtbf_zero_and_negative_need_explicit_off() {
        // Satellite bugfix: mtbf_s=0 silently disabled the arrival process;
        // disabling is now the explicit `off`/`none`.
        let mut c = ExperimentConfig::default();
        for bad in ["0", "0.0", "-3", "nan", "inf"] {
            let msg = c.apply("mtbf_s", bad).unwrap_err().to_string();
            assert!(msg.contains("mtbf_s=off"), "{bad}: actionable error: {msg}");
        }
        c.apply("mtbf_s", "2.5").unwrap();
        assert_eq!(c.mtbf_s, 2.5);
        c.apply("mtbf_s", "off").unwrap();
        assert_eq!(c.mtbf_s, 0.0);
        c.apply("mtbf_s", "1.5").unwrap();
        c.apply("mtbf_s", "none").unwrap();
        assert_eq!(c.mtbf_s, 0.0);
    }

    #[test]
    fn integrity_and_detector_keys_parse_and_validate() {
        let mut c = ExperimentConfig::default();
        c.apply("ckpt_keep", "3").unwrap();
        c.apply("corrupt_rate", "0.25").unwrap();
        c.apply("detect_fp_rate", "0.002").unwrap();
        c.apply("detect_jitter_s", "0.01").unwrap();
        c.apply("suspect_timeout_s", "0.5").unwrap();
        c.apply("retry_budget", "2").unwrap();
        c.validate().unwrap();
        assert_eq!(c.ckpt_keep, 3);
        assert_eq!(c.corrupt_rate, 0.25);
        assert_eq!(c.detect_fp_rate, 0.002);
        assert_eq!(c.detect_jitter_s, 0.01);
        assert_eq!(c.suspect_timeout_s, 0.5);
        assert_eq!(c.retry_budget, 2);
        // actionable rejections
        let msg = c.apply("ckpt_keep", "0").unwrap_err().to_string();
        assert!(msg.contains("latest generation"), "{msg}");
        assert!(c.apply("corrupt_rate", "1.5").is_err());
        assert!(c.apply("corrupt_rate", "-0.1").is_err());
        assert!(c.apply("detect_fp_rate", "-1").is_err());
        assert!(c.apply("detect_jitter_s", "nan").is_err());
        assert!(c.apply("suspect_timeout_s", "-0.5").is_err());
        assert!(c.apply("retry_budget", "x").is_err());
        // retry_budget=0 is legal: first corrupt load escalates immediately
        c.apply("retry_budget", "0").unwrap();
    }

    #[test]
    fn corrupt_events_validate_like_failures_but_kill_nothing() {
        let mut c = ExperimentConfig::default();
        c.apply("failures", "corrupt@2:r1,proc@3:r5").unwrap();
        c.validate().unwrap();
        let (has_proc, has_node) = c.configured_failure_kinds();
        assert!(has_proc && !has_node, "corrupt events are not failures");
        // corruption-only scenario: no kill kind at all
        c.apply("failures", "corrupt@2:r1").unwrap();
        c.validate().unwrap();
        assert_eq!(c.configured_failure_kinds(), (false, false));
        // rank/anchor range checks still apply to corrupt events
        c.apply("failures", "corrupt@2:r99").unwrap();
        assert!(c.validate().is_err(), "victim out of range");
        c.apply("failures", "corrupt@25:r1").unwrap();
        assert!(c.validate().is_err(), "iteration past the run");
    }

    #[test]
    fn unreliable_detector_demands_process_survivable_stack() {
        let mut c = ExperimentConfig::default();
        c.failure = FailureKind::None;
        c.apply("detect_fp_rate", "0.01").unwrap();
        c.apply("ckpt_tiers", "local").unwrap();
        assert!(
            c.validate().is_err(),
            "false positives kill ranks for real; local-only cannot survive"
        );
        c.apply("ckpt_tiers", "local+partner1").unwrap();
        c.validate().unwrap();
    }

    #[test]
    fn mtbf_validation() {
        let mut c = ExperimentConfig::default();
        c.apply("mtbf_s", "4.0").unwrap();
        c.apply("max_failures", "6").unwrap();
        c.validate().unwrap();
        c.failure = FailureKind::None;
        assert!(c.validate().is_err(), "mtbf needs a failure kind");
        c.failure = FailureKind::Node;
        assert_eq!(c.policy_failure(), FailureKind::Node);
        c.spare_nodes = 1;
        c.ranks = 32;
        c.ranks_per_node = 8;
        c.validate().unwrap();
    }

    #[test]
    fn tiny_iters_with_failure_rejected() {
        // Satellite regression: iters=2 used to draw iteration 1 == iters-1,
        // outside the documented [1, iters-1) window.
        let mut c = ExperimentConfig::default();
        c.iters = 2;
        assert!(c.validate().is_err());
        c.iters = 3;
        c.validate().unwrap();
        // fault-free runs may be arbitrarily short
        c.iters = 1;
        c.failure = FailureKind::None;
        c.validate().unwrap();
        // explicit scenarios are held to the same floor
        c.iters = 2;
        c.apply("failures", "proc@1:r0").unwrap();
        assert!(c.validate().is_err());
    }

    #[test]
    fn scenario_keys_roundtrip_through_toml() {
        let doc = toml::parse(
            "failures = \"proc@2:r1,node@4:r6\"\nmax_failures = 7\nmtbf_s = \"off\"\n",
        )
        .unwrap();
        let mut c = ExperimentConfig::default();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.failures.len(), 2);
        assert_eq!(c.max_failures, 7);
        let doc = toml::parse("mtbf_s = 3.5\n").unwrap();
        let mut c = ExperimentConfig::default();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.mtbf_s, 3.5);
    }

    #[test]
    fn display_names_match_paper() {
        assert_eq!(RecoveryKind::Reinit.to_string(), "Reinit++");
        assert_eq!(RecoveryKind::Cr.to_string(), "CR");
        assert_eq!(RecoveryKind::Replication.to_string(), "Replication");
        assert_eq!(AppKind::Hpccg.to_string(), "HPCCG");
    }

    #[test]
    fn recovery_all_includes_replication_and_paper_stays_three() {
        assert_eq!(RecoveryKind::ALL.len(), 5);
        assert!(RecoveryKind::ALL.contains(&RecoveryKind::Replication));
        assert!(RecoveryKind::ALL.contains(&RecoveryKind::Shrink));
        assert_eq!(
            RecoveryKind::PAPER,
            [RecoveryKind::Cr, RecoveryKind::Ulfm, RecoveryKind::Reinit],
            "figure sweeps reproduce the paper's three families only"
        );
        assert_eq!(RecoveryKind::parse("repl"), Some(RecoveryKind::Replication));
        assert_eq!(
            RecoveryKind::parse("replication"),
            Some(RecoveryKind::Replication)
        );
        assert_eq!(RecoveryKind::parse("shrink"), Some(RecoveryKind::Shrink));
        assert_eq!(RecoveryKind::Shrink.to_string(), "Shrink");
    }

    #[test]
    fn repl_degree_applies_and_validates() {
        let mut c = ExperimentConfig::default();
        assert_eq!(c.repl_degree, 1, "default: no replicas");
        assert!(c.apply("repl_degree", "0").is_err());
        assert!(c.apply("repl_degree", "x").is_err());
        c.apply("repl_degree", "2").unwrap();
        assert_eq!(c.repl_degree, 2);
        // degree > 1 without recovery=repl is a config error
        assert!(c.validate().is_err());
        c.apply("recovery", "repl").unwrap();
        // default scale is a single compute node: node-disjoint placement
        // impossible, and the message must say how to fix it
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("node-disjoint"), "{err}");
        assert!(err.contains("ranks_per_node"), "{err}");
        c.apply("ranks_per_node", "8").unwrap(); // 16 ranks -> 2 nodes
        c.validate().unwrap();
        // replication without replicas is valid everywhere (degrades to CR)
        let mut c = ExperimentConfig::default();
        c.apply("recovery", "repl").unwrap();
        c.validate().unwrap();
    }
}
