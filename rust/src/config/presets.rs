//! The paper's experimental presets (Table 1) and sweep definitions, plus
//! the checkpoint tier-comparison grid (beyond the paper; see
//! `harness::tier_sweep`).

use super::AppKind;
use crate::ckptstore::StackSpec;

/// Rank counts of the paper's weak-scaling sweep (Table 1).
pub const RANK_SWEEP: [u32; 7] = [16, 32, 64, 128, 256, 512, 1024];

/// LULESH requires a cube number of ranks (paper Table 1); the usable subset.
pub const LULESH_RANK_SWEEP: [u32; 3] = [27, 64, 512];

/// Ranks per node in the paper's deployment.
pub const RANKS_PER_NODE: u32 = 16;

/// Rank counts used for an app in the sweep.
pub fn rank_sweep(app: AppKind) -> &'static [u32] {
    match app {
        AppKind::Lulesh => &LULESH_RANK_SWEEP,
        _ => &RANK_SWEEP,
    }
}

/// Canonical checkpoint stacks the tier-comparison sweep contrasts:
/// the paper's shared-FS baseline, in-memory with one node-disjoint
/// replica, and a two-replica stack backed by the filesystem.
pub const TIER_SWEEP_STACKS: [&str; 3] = ["fs", "local+partner1", "local+partner2+fs"];

/// Rank counts of the tier sweep. Smaller than the paper's weak-scaling
/// grid: the comparison needs several compute nodes, not extreme scale.
pub const TIER_SWEEP_RANKS: [u32; 3] = [16, 32, 64];

/// Ranks per node for the tier sweep — deliberately below the paper's 16 so
/// even the smallest point spans multiple nodes (node-disjoint replicas and
/// node failures are the whole object of study).
pub const TIER_SWEEP_RANKS_PER_NODE: u32 = 8;

/// Rank counts of the large-rank weak-scaling sweep (`reinitpp scale`):
/// picks up where the paper's Figure 4 grid tops out and extends the
/// recovery-time curves past the paper's 3072-rank ceiling.
pub const SCALE_SWEEP_RANKS: [u32; 6] = [512, 1024, 2048, 4096, 8192, 16384];

/// Rank counts `reinitpp scale` actually visits for a given `--max-ranks`:
/// the preset rungs up to the cap, then doubling past the preset ceiling
/// all the way to the cap itself (262144-rank rungs and beyond ride the
/// sharded executor). Requests below the smallest rung or off the
/// power-of-two ladder are errors, not silent clamps.
pub fn scale_rungs(max: u32) -> Result<Vec<u32>, String> {
    if !max.is_power_of_two() {
        return Err(format!(
            "scale: --max-ranks {max} is not a power of two; the weak-scaling \
             ladder doubles from {} (e.g. 4096, 16384, 262144)",
            SCALE_SWEEP_RANKS[0]
        ));
    }
    if max < SCALE_SWEEP_RANKS[0] {
        return Err(format!(
            "scale: --max-ranks {max} is below the smallest rung {}",
            SCALE_SWEEP_RANKS[0]
        ));
    }
    let mut rungs: Vec<u32> = SCALE_SWEEP_RANKS
        .iter()
        .copied()
        .filter(|&r| r <= max)
        .collect();
    let top = SCALE_SWEEP_RANKS[SCALE_SWEEP_RANKS.len() - 1];
    let mut r = top.saturating_mul(2);
    while r <= max {
        rungs.push(r);
        r = r.saturating_mul(2);
    }
    Ok(rungs)
}

/// ULFM points of the scale sweep are capped here: the shrink/agree
/// protocol materializes the survivor set on every rank, which is
/// quadratic host memory at extreme scale — and the paper's ULFM
/// prototype itself topped out at 3072 ranks (§5.3), so the comparison
/// past this point is CR vs Reinit++, exactly like the paper's Figure 7.
pub const SCALE_ULFM_MAX_RANKS: u32 = 4096;

/// Rank counts of the failure-storm sweep (`reinitpp storm`). Modest
/// scales: the object of study is repeated-failure dynamics (recovery
/// restarts, spare exhaustion, rollback churn), not extreme rank counts —
/// and every recovery method, including ULFM, must be runnable.
pub const STORM_SWEEP_RANKS: [u32; 3] = [16, 64, 256];

/// Mean-time-between-failures grid of the storm sweep, in virtual seconds
/// after application start. Chosen around the recovery-cost anchors
/// (Reinit++ ≈0.5 s, CR ≈3 s re-deploy): 2.0 is the "occasional failure"
/// regime, 0.5 lands storms against in-flight CR re-deploys, and 0.1
/// cascades failures inside every method's recovery window.
pub const STORM_SWEEP_MTBF_S: [f64; 3] = [0.1, 0.5, 2.0];

/// Cap on MTBF-drawn events per storm trial: bounds trial length (and the
/// CR re-deploy count) while leaving room for several back-to-back
/// failures at the tightest MTBF.
pub const STORM_MAX_FAILURES: u32 = 6;

/// `calibration.modeled_compute_scale` for the storm base config: at the
/// storm's tiny per-rank grid (hpccg_nx=4 ≈ 2 µs modeled compute/iteration)
/// this stretches a 40-iteration application run to ≈ 1 s of virtual time —
/// paper-scale iteration cost, so the MTBF grid above actually lands
/// failures inside the run — at zero extra host cost.
pub const STORM_COMPUTE_SCALE: f64 = 12_000.0;

/// Replica-group size the storm sweep runs replication at. Degree 2 (one
/// shadow per primary) is the canonical rSDC/FTHP-MPI configuration: 2x
/// the processes, one free failover per group. Storm rungs whose node
/// count cannot host node-disjoint shadows skip replication entirely.
pub const STORM_REPL_DEGREE: u32 = 2;

/// Replica-group size of the scale sweep's replication points (see
/// `STORM_REPL_DEGREE`; at 512+ ranks every rung has plenty of nodes).
pub const SCALE_REPL_DEGREE: u32 = 2;

/// Checkpoint-interval axis of the crossover sweep (`reinitpp crossover`):
/// every iteration (the paper's Table 2 policy) vs. every 4th — the knob
/// that trades rollback distance against write bandwidth, which is exactly
/// what replication's zero-rollback failover competes with.
pub const CROSSOVER_CKPT_EVERY: [u32; 2] = [1, 4];

/// Ranks per node for the crossover sweep — below the paper's 16 so even
/// the 16-rank rung spans two compute nodes and can place node-disjoint
/// shadow replicas (degree 2 is a grid axis, not an opt-in).
pub const CROSSOVER_RANKS_PER_NODE: u32 = 8;

/// Bit-rot axis of the integrity sweep (`reinitpp integrity`): perfect
/// storage next to a harsh 20% per-copy corruption draw — high enough
/// that multi-generation retention (`ckpt_keep`) and the verify-then-
/// fall-back path visibly earn their keep within a handful of trials.
pub const INTEGRITY_CORRUPT_RATES: [f64; 2] = [0.0, 0.2];

/// Detector axis of the integrity sweep: `(fp_rate/s, jitter_s,
/// suspect_timeout_s)` bundles. The first is the perfect detector every
/// other sweep assumes; the second suspects a healthy rank about every
/// two virtual seconds, smears detection latency by up to 2 ms and holds
/// each suspicion for a 10 ms confirmation timeout (doubling per repeat
/// offence) — enough spurious recoveries per ≈1 s storm trial to price
/// imperfect detection without drowning the real failures.
pub const INTEGRITY_DETECTORS: [(f64, f64, f64); 2] =
    [(0.0, 0.0, 0.0), (0.5, 0.002, 0.01)];

/// Retention axis of the integrity sweep: keep only the newest generation
/// (every other sweep's behaviour) vs a three-deep history for the
/// verify-on-load fallback to dig through.
pub const INTEGRITY_KEEP: [u32; 2] = [1, 3];

/// The integrity sweep's single MTBF rung: the middle of the storm grid,
/// tight enough that every trial recovers several times (each recovery is
/// a verify-and-agree round) without the 0.1 s cascade regime swamping
/// the corruption signal.
pub const INTEGRITY_MTBF_S: f64 = 0.5;

/// The parsed tier-sweep stacks.
pub fn tier_sweep_stacks() -> Vec<StackSpec> {
    TIER_SWEEP_STACKS
        .iter()
        .map(|s| StackSpec::parse(s).expect("preset stacks parse"))
        .collect()
}

/// Table 1 descriptor row: the paper's inputs and our simulated analog.
pub struct Table1Row {
    pub app: AppKind,
    pub paper_input: &'static str,
    pub our_input: &'static str,
    pub ranks: &'static [u32],
}

/// Paper's Table 1 alongside the weak-scaled per-rank inputs we run.
pub fn table1() -> Vec<Table1Row> {
    vec![
        Table1Row {
            app: AppKind::CoMD,
            paper_input: "-i4 -j2 -k2 -x 80 -y 40 -z 40 -N 20 (weak-scaled)",
            our_input: "128 LJ particles/rank, velocity-Verlet, dt=2e-3",
            ranks: &RANK_SWEEP,
        },
        Table1Row {
            app: AppKind::Hpccg,
            paper_input: "64 64 64 (per-rank grid)",
            our_input: "16^3 27-pt stencil grid/rank, CG iterations",
            ranks: &RANK_SWEEP,
        },
        Table1Row {
            app: AppKind::Lulesh,
            paper_input: "-s 48 (cube ranks only)",
            our_input: "16^3 hydro grid/rank, Sedov-like deposit",
            ranks: &LULESH_RANK_SWEEP,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_matches_paper() {
        assert_eq!(RANK_SWEEP.to_vec(), vec![16, 32, 64, 128, 256, 512, 1024]);
        assert_eq!(RANKS_PER_NODE, 16);
    }

    #[test]
    fn lulesh_ranks_are_cubes() {
        for r in LULESH_RANK_SWEEP {
            let c = (r as f64).cbrt().round() as u32;
            assert_eq!(c * c * c, r);
        }
    }

    #[test]
    fn tier_sweep_presets_parse_and_span_nodes() {
        let stacks = tier_sweep_stacks();
        assert_eq!(stacks.len(), 3);
        assert_eq!(stacks[2].to_string(), "local+partner2+fs");
        for r in TIER_SWEEP_RANKS {
            assert!(
                r / TIER_SWEEP_RANKS_PER_NODE >= 2,
                "every tier-sweep point must span >= 2 nodes"
            );
        }
    }

    #[test]
    fn storm_presets_are_sane() {
        assert!(STORM_SWEEP_MTBF_S.windows(2).all(|w| w[0] < w[1]));
        assert!(STORM_SWEEP_MTBF_S.iter().all(|&m| m > 0.0));
        assert!(STORM_SWEEP_RANKS.windows(2).all(|w| w[0] < w[1]));
        assert!(STORM_MAX_FAILURES >= 2, "storms need repeated failures");
        assert!(STORM_REPL_DEGREE >= 2, "degree 1 replication never fails over");
        assert!(SCALE_REPL_DEGREE >= 2);
    }

    #[test]
    fn crossover_presets_span_nodes_and_intervals() {
        assert!(CROSSOVER_CKPT_EVERY.windows(2).all(|w| w[0] < w[1]));
        assert!(CROSSOVER_CKPT_EVERY.iter().all(|&k| k >= 1));
        for r in STORM_SWEEP_RANKS {
            assert!(
                r / CROSSOVER_RANKS_PER_NODE >= STORM_REPL_DEGREE,
                "every crossover rung must host node-disjoint degree-{STORM_REPL_DEGREE} groups"
            );
        }
    }

    #[test]
    fn integrity_presets_are_sane() {
        assert_eq!(INTEGRITY_CORRUPT_RATES[0], 0.0, "perfect-storage baseline");
        assert!(INTEGRITY_CORRUPT_RATES
            .iter()
            .all(|&r| (0.0..=1.0).contains(&r)));
        let (fp0, j0, t0) = INTEGRITY_DETECTORS[0];
        assert_eq!((fp0, j0, t0), (0.0, 0.0, 0.0), "perfect-detector baseline");
        assert!(INTEGRITY_DETECTORS
            .iter()
            .all(|&(fp, j, t)| fp >= 0.0 && j >= 0.0 && t >= 0.0));
        assert_eq!(INTEGRITY_KEEP[0], 1, "single-generation baseline");
        assert!(INTEGRITY_KEEP.windows(2).all(|w| w[0] < w[1]));
        assert!(
            STORM_SWEEP_MTBF_S.contains(&INTEGRITY_MTBF_S),
            "integrity rides a storm MTBF rung"
        );
    }

    #[test]
    fn scale_rungs_extend_past_the_preset_ceiling() {
        assert_eq!(scale_rungs(512).unwrap(), vec![512]);
        assert_eq!(
            scale_rungs(16384).unwrap(),
            SCALE_SWEEP_RANKS.to_vec(),
            "preset cap is the unextended ladder"
        );
        let big = scale_rungs(262_144).unwrap();
        assert_eq!(
            &big[SCALE_SWEEP_RANKS.len()..],
            &[32_768, 65_536, 131_072, 262_144],
            "past 16384 the ladder keeps doubling to the cap"
        );
        assert!(scale_rungs(3000).is_err(), "non-power-of-two is rejected");
        assert!(scale_rungs(256).is_err(), "below the smallest rung");
        let err = scale_rungs(24_000).unwrap_err();
        assert!(err.contains("power of two"), "{err}");
    }

    #[test]
    fn table1_covers_all_apps() {
        let rows = table1();
        assert_eq!(rows.len(), 3);
        for app in AppKind::ALL {
            assert!(rows.iter().any(|r| r.app == app));
        }
    }
}
