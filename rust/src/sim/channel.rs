//! Typed simulated channels: multi-producer single-consumer mailboxes whose
//! deliveries occur after a caller-supplied virtual-time delay (the transport
//! layer computes the delay from its cost model).
//!
//! Failure semantics are deliberately *not* built in here: a message sent to
//! a mailbox whose owner died is silently delivered into the queue (nobody
//! will read it), exactly like bytes arriving at a crashed TCP endpoint.
//! Death detection is layered above via `Sim::watch` — mirroring how Open MPI
//! detects failures via SIGCHLD / broken control channels, not via magic
//! knowledge in the fabric.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::future::Future;
use std::pin::Pin;
use std::rc::Rc;
use std::task::{Context, Poll, Waker};

use super::executor::{Deliverable, Sim};
use super::time::{SimDuration, SimTime};

/// Error returned by `Receiver::recv`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecvError {
    /// Channel explicitly closed and drained.
    Closed,
    /// `recv_deadline` expired.
    Timeout,
}

impl std::fmt::Display for RecvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RecvError::Closed => write!(f, "channel closed"),
            RecvError::Timeout => write!(f, "recv timeout"),
        }
    }
}

impl std::error::Error for RecvError {}

struct ChanInner<T> {
    queue: VecDeque<T>,
    waiter: Option<Waker>,
    closed: bool,
    /// Messages scheduled for future delivery, parked here until their
    /// delivery event fires. Slots are recycled through `free`, so a
    /// steady-state send allocates nothing (the executor's `Deliver` event
    /// carries only an `Rc` clone + the slot index — no boxed closure).
    inflight: Vec<Option<T>>,
    free: Vec<u32>,
    /// Token of the currently armed deadline timer (cancel-awareness).
    /// Arming a timed recv bumps it and records the new value; completing
    /// or dropping that recv bumps it again, so an in-flight timer event
    /// firing later sees a mismatch and does nothing — no spurious wake,
    /// no boxed waker closure kept alive (the ULFM heartbeat hot path).
    armed_timer: u64,
    /// Executor shard owning this mailbox: the shard of the task that
    /// created the channel (= the receiver's home under the topology-aligned
    /// plan). Deliveries and deadline timers are scheduled onto this shard's
    /// event queue, so a cross-shard `send` goes through the inbox/window
    /// machinery while intra-shard traffic stays on the local queue.
    home_shard: u16,
}

impl<T> ChanInner<T> {
    /// Park `msg` in a recycled (or new) inflight slot; returns the slot.
    fn park(&mut self, msg: T) -> u32 {
        match self.free.pop() {
            Some(slot) => {
                debug_assert!(self.inflight[slot as usize].is_none());
                self.inflight[slot as usize] = Some(msg);
                slot
            }
            None => {
                self.inflight.push(Some(msg));
                (self.inflight.len() - 1) as u32
            }
        }
    }
}

impl<T: 'static> Deliverable for RefCell<ChanInner<T>> {
    /// The delivery event fired: move the parked message into the queue
    /// (or drop it if the channel closed meanwhile, like TCP RST).
    fn deliver(&self, slot: u32) {
        let mut ch = self.borrow_mut();
        let msg = ch.inflight[slot as usize]
            .take()
            .expect("delivery slot must be occupied");
        ch.free.push(slot);
        if ch.closed {
            return; // dropped on the floor
        }
        ch.queue.push_back(msg);
        if let Some(w) = ch.waiter.take() {
            w.wake();
        }
    }

    /// A deadline timer fired. Stale tokens (the timed recv that armed this
    /// timer already completed or was dropped) are ignored: the task is NOT
    /// spuriously woken.
    fn timer(&self, token: u64) {
        let mut ch = self.borrow_mut();
        if ch.armed_timer != token {
            return; // cancelled: recv finished before its deadline
        }
        if let Some(w) = ch.waiter.take() {
            w.wake(); // genuine timeout: the recv polls and reports Timeout
        }
    }
}

/// Sending half (cloneable).
pub struct Sender<T> {
    sim: Sim,
    inner: Rc<RefCell<ChanInner<T>>>,
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        Sender {
            sim: self.sim.clone(),
            inner: Rc::clone(&self.inner),
        }
    }
}

/// Receiving half (single consumer).
pub struct Receiver<T> {
    inner: Rc<RefCell<ChanInner<T>>>,
    sim: Sim,
}

/// Create a simulated channel. Delays are chosen per `send`.
pub fn channel<T: 'static>(sim: &Sim) -> (Sender<T>, Receiver<T>) {
    let inner = Rc::new(RefCell::new(ChanInner {
        queue: VecDeque::new(),
        waiter: None,
        closed: false,
        inflight: Vec::new(),
        free: Vec::new(),
        armed_timer: 0,
        home_shard: sim.current_shard(),
    }));
    (
        Sender {
            sim: sim.clone(),
            inner: Rc::clone(&inner),
        },
        Receiver {
            inner,
            sim: sim.clone(),
        },
    )
}

impl<T: 'static> Sender<T> {
    /// Deliver `msg` after `delay` of virtual time. Allocation-free in the
    /// steady state: the message parks in a recycled inflight slot and the
    /// executor's `Deliver` event is an `Rc` clone plus the slot index.
    pub fn send(&self, msg: T, delay: SimDuration) {
        let (slot, home) = {
            let mut ch = self.inner.borrow_mut();
            (ch.park(msg), ch.home_shard)
        };
        let target: Rc<dyn Deliverable> = Rc::clone(&self.inner);
        self.sim.schedule_deliver_to(home, delay, target, slot);
    }

    /// Mark the channel closed (pending undelivered messages are dropped,
    /// queued ones remain readable).
    pub fn close(&self) {
        let mut ch = self.inner.borrow_mut();
        ch.closed = true;
        if let Some(w) = ch.waiter.take() {
            w.wake();
        }
    }
}

impl<T> Receiver<T> {
    /// Non-blocking poll of the queue.
    pub fn try_recv(&self) -> Option<T> {
        self.inner.borrow_mut().queue.pop_front()
    }

    /// Await the next message.
    pub fn recv(&self) -> Recv<'_, T> {
        Recv {
            rx: self,
            deadline: None,
            timer_token: None,
        }
    }

    /// Await the next message until an absolute virtual deadline.
    pub fn recv_deadline(&self, deadline: SimTime) -> Recv<'_, T> {
        Recv {
            rx: self,
            deadline: Some(deadline),
            timer_token: None,
        }
    }

    /// Await with a relative timeout.
    pub fn recv_timeout(&self, d: SimDuration) -> Recv<'_, T> {
        self.recv_deadline(self.sim.now() + d)
    }

    pub fn len(&self) -> usize {
        self.inner.borrow().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Future returned by `Receiver::recv*`.
///
/// Deadline timers are cancel-aware and allocation-free: arming schedules
/// an executor `Timer` event (an `Rc` clone + token, no boxed closure) and
/// records the token in the channel; completing or dropping the `Recv`
/// invalidates the token, so a timer firing after an early completion is a
/// silent no-op instead of a spurious task wake-up.
pub struct Recv<'a, T> {
    rx: &'a Receiver<T>,
    deadline: Option<SimTime>,
    /// Token of the deadline timer this recv armed, if any.
    timer_token: Option<u64>,
}

impl<'a, T: 'static> Future for Recv<'a, T> {
    type Output = Result<T, RecvError>;

    fn poll(mut self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<Self::Output> {
        let mut ch = self.rx.inner.borrow_mut();
        if let Some(msg) = ch.queue.pop_front() {
            return Poll::Ready(Ok(msg));
        }
        if ch.closed {
            return Poll::Ready(Err(RecvError::Closed));
        }
        if let Some(dl) = self.deadline {
            if self.rx.sim.now() >= dl {
                return Poll::Ready(Err(RecvError::Timeout));
            }
        }
        ch.waiter = Some(cx.waker().clone());
        if let Some(dl) = self.deadline {
            if self.timer_token.is_none() {
                // Arm the cancel-aware deadline timer (see struct docs).
                let token = ch.armed_timer.wrapping_add(1);
                ch.armed_timer = token;
                let home = ch.home_shard;
                drop(ch);
                self.timer_token = Some(token);
                let delay = dl - self.rx.sim.now();
                let target: Rc<dyn Deliverable> = Rc::clone(&self.rx.inner);
                self.rx.sim.schedule_timer_to(home, delay, target, token);
            }
        }
        Poll::Pending
    }
}

impl<T> Drop for Recv<'_, T> {
    fn drop(&mut self) {
        // Invalidate our deadline timer (if it is still the armed one):
        // completion, cancellation, and task death all funnel through here,
        // so the pending timer event fires stale and wakes nobody.
        if let Some(token) = self.timer_token.take() {
            let mut ch = self.rx.inner.borrow_mut();
            if ch.armed_timer == token {
                ch.armed_timer = ch.armed_timer.wrapping_add(1);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimTime;
    use std::cell::Cell;

    #[test]
    fn message_arrives_after_latency() {
        let sim = Sim::new();
        let p = sim.spawn_process("p");
        let (tx, rx) = channel::<u32>(&sim);
        let got = Rc::new(Cell::new((0u32, SimTime::ZERO)));
        let g2 = Rc::clone(&got);
        let s2 = sim.clone();
        sim.spawn(p, async move {
            let v = rx.recv().await.unwrap();
            g2.set((v, s2.now()));
        });
        tx.send(7, SimDuration::from_micros(42));
        sim.run();
        assert_eq!(got.get(), (7, SimTime(42_000)));
    }

    #[test]
    fn fifo_per_sender_and_time_ordering() {
        let sim = Sim::new();
        let p = sim.spawn_process("p");
        let (tx, rx) = channel::<u32>(&sim);
        // later-sent but lower-latency message overtakes: delivery is by time
        tx.send(1, SimDuration::from_micros(100));
        tx.send(2, SimDuration::from_micros(10));
        let order = Rc::new(RefCell::new(Vec::new()));
        let o2 = Rc::clone(&order);
        sim.spawn(p, async move {
            for _ in 0..2 {
                o2.borrow_mut().push(rx.recv().await.unwrap());
            }
        });
        sim.run();
        assert_eq!(*order.borrow(), vec![2, 1]);
    }

    #[test]
    fn same_delay_messages_keep_send_order() {
        let sim = Sim::new();
        let p = sim.spawn_process("p");
        let (tx, rx) = channel::<u32>(&sim);
        for i in 0..5 {
            tx.send(i, SimDuration::from_micros(10));
        }
        let order = Rc::new(RefCell::new(Vec::new()));
        let o2 = Rc::clone(&order);
        sim.spawn(p, async move {
            for _ in 0..5 {
                o2.borrow_mut().push(rx.recv().await.unwrap());
            }
        });
        sim.run();
        assert_eq!(*order.borrow(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn recv_timeout_fires() {
        let sim = Sim::new();
        let p = sim.spawn_process("p");
        let (tx, rx) = channel::<u32>(&sim);
        let _keep = tx; // no messages ever sent
        let result = Rc::new(Cell::new(None));
        let r2 = Rc::clone(&result);
        let s2 = sim.clone();
        sim.spawn(p, async move {
            let r = rx.recv_timeout(SimDuration::from_millis(5)).await;
            r2.set(Some((r, s2.now().nanos())));
        });
        sim.run();
        assert_eq!(result.get(), Some((Err(RecvError::Timeout), 5_000_000)));
    }

    #[test]
    fn recv_timeout_beaten_by_message() {
        let sim = Sim::new();
        let p = sim.spawn_process("p");
        let (tx, rx) = channel::<u32>(&sim);
        tx.send(9, SimDuration::from_millis(1));
        let result = Rc::new(Cell::new(None));
        let r2 = Rc::clone(&result);
        sim.spawn(p, async move {
            r2.set(Some(rx.recv_timeout(SimDuration::from_millis(50)).await));
        });
        sim.run();
        assert_eq!(result.get(), Some(Ok(9)));
    }

    #[test]
    fn early_completed_recv_timeout_leaves_no_live_timer() {
        // Satellite regression (deadline-timer leak): a timed recv that
        // completes early must leave only a *stale* timer behind — the
        // event still pops at the deadline (virtual time is unchanged) but
        // wakes nobody and polls nothing.
        let sim = Sim::new();
        let p = sim.spawn_process("p");
        let (tx, rx) = channel::<u32>(&sim);
        tx.send(9, SimDuration::from_millis(1));
        let s2 = sim.clone();
        sim.spawn(p, async move {
            let v = rx.recv_timeout(SimDuration::from_millis(50)).await;
            assert_eq!(v, Ok(9), "message beats the deadline");
            // park well past the stale deadline: a spurious wake would poll
            s2.sleep(SimDuration::from_millis(100)).await;
        });
        let s = sim.run();
        // events: deliver@1ms, stale timer@50ms, sleep wake@101ms
        assert_eq!(s.events, 3);
        // polls: initial (arms timer), after deliver, after the sleep —
        // the stale timer contributes NO poll (pre-fix it woke the task).
        assert_eq!(s.polls, 3, "stale deadline timer must not wake the task");
        assert_eq!(s.end_time.nanos(), 101_000_000);
        assert_eq!(s.tasks_completed, 1);
    }

    #[test]
    fn stale_timer_does_not_disturb_a_later_timed_recv() {
        // recv #1 completes early (its 50 ms timer goes stale); recv #2 on
        // the same channel must still time out exactly on its own deadline.
        let sim = Sim::new();
        let p = sim.spawn_process("p");
        let (tx, rx) = channel::<u32>(&sim);
        tx.send(7, SimDuration::from_millis(1));
        let results = Rc::new(RefCell::new(Vec::new()));
        let r2 = Rc::clone(&results);
        let s2 = sim.clone();
        sim.spawn(p, async move {
            let a = rx.recv_timeout(SimDuration::from_millis(50)).await;
            let b = rx.recv_timeout(SimDuration::from_millis(10)).await;
            r2.borrow_mut().push((a, b, s2.now().nanos()));
        });
        let s = sim.run();
        assert_eq!(
            *results.borrow(),
            vec![(Ok(7), Err(RecvError::Timeout), 11_000_000)]
        );
        // deliver@1ms + genuine timer@11ms + stale timer@50ms
        assert_eq!(s.events, 3);
        assert_eq!(s.polls, 3, "one poll per event that matters");
    }

    #[test]
    fn close_wakes_receiver_with_closed() {
        let sim = Sim::new();
        let p = sim.spawn_process("p");
        let (tx, rx) = channel::<u32>(&sim);
        let result = Rc::new(Cell::new(None));
        let r2 = Rc::clone(&result);
        sim.spawn(p, async move {
            r2.set(Some(rx.recv().await));
        });
        let tx2 = tx.clone();
        sim.schedule(SimDuration::from_millis(3), move || tx2.close());
        sim.run();
        assert_eq!(result.get(), Some(Err(RecvError::Closed)));
    }

    #[test]
    fn multiple_senders_interleave() {
        let sim = Sim::new();
        let p = sim.spawn_process("p");
        let (tx, rx) = channel::<u32>(&sim);
        let tx2 = tx.clone();
        tx.send(1, SimDuration::from_micros(30));
        tx2.send(2, SimDuration::from_micros(20));
        let sum = Rc::new(Cell::new(0));
        let s2 = Rc::clone(&sum);
        sim.spawn(p, async move {
            let a = rx.recv().await.unwrap();
            let b = rx.recv().await.unwrap();
            s2.set(a * 10 + b);
        });
        sim.run();
        assert_eq!(sum.get(), 21); // 2 then 1
    }

    #[test]
    fn message_to_dead_receiver_is_harmless() {
        let sim = Sim::new();
        let p = sim.spawn_process("p");
        let (tx, rx) = channel::<u32>(&sim);
        sim.spawn(p, async move {
            let _ = rx.recv().await;
            unreachable!("receiver killed before delivery");
        });
        let s2 = sim.clone();
        sim.schedule(SimDuration::from_micros(1), move || s2.kill(p));
        tx.send(1, SimDuration::from_millis(1));
        let summary = sim.run();
        assert_eq!(summary.tasks_pending, 0);
    }

    #[test]
    fn inflight_slots_are_recycled() {
        let sim = Sim::new();
        let (tx, rx) = channel::<u32>(&sim);
        // 100 concurrent sends grow the inflight slab to its high-water mark
        for i in 0..100 {
            tx.send(i, SimDuration::from_micros(1));
        }
        sim.run();
        assert_eq!(rx.inner.borrow().inflight.len(), 100);
        assert_eq!(rx.inner.borrow().free.len(), 100, "all slots returned");
        // a second wave reuses the freed slots: no further growth
        for i in 0..100 {
            tx.send(i, SimDuration::from_micros(1));
        }
        sim.run();
        assert_eq!(rx.inner.borrow().inflight.len(), 100, "slab did not grow");
        assert_eq!(rx.len(), 200, "every message delivered");
    }

    #[test]
    fn try_recv_nonblocking() {
        let sim = Sim::new();
        let (tx, rx) = channel::<u32>(&sim);
        assert_eq!(rx.try_recv(), None);
        tx.send(5, SimDuration::ZERO);
        sim.run(); // deliver
        assert_eq!(rx.try_recv(), Some(5));
        assert_eq!(rx.try_recv(), None);
    }
}
