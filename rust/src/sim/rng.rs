//! Deterministic pseudo-random numbers (xoshiro256** seeded by splitmix64).
//!
//! The offline build has no `rand` crate; this is the standard, well-tested
//! generator pair from Blackman & Vigna used by most language runtimes.
//! Every stochastic choice in the system (fault-injection iteration/rank,
//! initial particle jitter, workload draws) flows from one of these,
//! forked per subsystem so experiments are replayable and individually
//! perturbable.

/// splitmix64: seeds the main generator and provides stream forking.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** deterministic PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream for a named subsystem. The label keeps
    /// forks stable under code reordering (unlike a fork counter).
    pub fn fork(&self, label: &str) -> Rng {
        let mut h: u64 = 0xcbf29ce484222325; // FNV-1a
        for b in label.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        let mut sm = self.s[0] ^ h;
        Rng::new(splitmix64(&mut sm))
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection method.
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn gen_f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.gen_f64() as f32) * (hi - lo)
    }

    /// Standard normal via Box-Muller (one value per call; simple & exact).
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = (self.gen_f64()).max(1e-300);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Random permutation index choice without replacement.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_replay() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn fork_streams_are_stable_and_independent() {
        let root = Rng::new(7);
        let mut f1 = root.fork("fault");
        let mut f1b = root.fork("fault");
        let mut f2 = root.fork("workload");
        assert_eq!(f1.next_u64(), f1b.next_u64());
        assert_ne!(f1.next_u64(), f2.next_u64());
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.gen_range(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn gen_f64_unit_interval_mean() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.gen_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gen_normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    #[should_panic]
    fn gen_range_zero_panics() {
        Rng::new(0).gen_range(0);
    }
}
