//! The virtual-time async executor.
//!
//! Single-threaded and deterministic: tasks run until all are blocked, then
//! the clock jumps to the earliest scheduled event. See `sim/mod.rs` for the
//! design discussion.

use std::cell::RefCell;
use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::future::Future;
use std::pin::Pin;
use std::rc::Rc;
use std::sync::{Arc, Mutex};
use std::task::{Context, Poll, Wake, Waker};

use super::proc::{ProcEntry, ProcId, ProcStatus};
use super::time::{SimDuration, SimTime};

/// Identifier of a spawned task.
pub type TaskId = u64;

/// Why `Sim::run` returned.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExitReason {
    /// No runnable tasks and no pending events: simulation quiesced.
    Idle,
    /// Event budget exhausted (runaway guard).
    EventLimit,
}

/// Counters describing a finished run (used by tests and the perf harness).
#[derive(Clone, Copy, Debug)]
pub struct SimSummary {
    pub end_time: SimTime,
    pub events: u64,
    pub polls: u64,
    pub tasks_completed: u64,
    /// Tasks still pending at exit (> 0 usually indicates a deadlock,
    /// unless tasks were deliberately left blocked, e.g. idle daemons).
    pub tasks_pending: u64,
    pub reason: ExitReason,
}

enum Event {
    Wake(Waker),
    Run(Box<dyn FnOnce()>),
}

struct EventEntry {
    time: SimTime,
    seq: u64,
    event: Event,
}

impl PartialEq for EventEntry {
    fn eq(&self, o: &Self) -> bool {
        self.time == o.time && self.seq == o.seq
    }
}
impl Eq for EventEntry {}
impl PartialOrd for EventEntry {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for EventEntry {
    // Reversed: BinaryHeap is a max-heap; we want earliest (time, seq) first.
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        (o.time, o.seq).cmp(&(self.time, self.seq))
    }
}

struct TaskEntry {
    fut: Pin<Box<dyn Future<Output = ()>>>,
    proc: ProcId,
    /// Already sitting in the ready queue (dedup flag: avoids an O(n)
    /// `contains` scan per external wake — see EXPERIMENTS.md §Perf).
    queued: bool,
}

#[derive(Default)]
struct WakeQueue {
    queue: Mutex<VecDeque<TaskId>>,
}

impl WakeQueue {
    fn push(&self, t: TaskId) {
        self.queue.lock().unwrap().push_back(t);
    }
    fn drain(&self) -> Vec<TaskId> {
        self.queue.lock().unwrap().drain(..).collect()
    }
}

struct TaskWaker {
    id: TaskId,
    queue: Arc<WakeQueue>,
}

impl Wake for TaskWaker {
    fn wake(self: Arc<Self>) {
        self.queue.push(self.id);
    }
}

struct Inner {
    now: SimTime,
    next_seq: u64,
    next_task: TaskId,
    events: BinaryHeap<EventEntry>,
    ready: VecDeque<TaskId>,
    tasks: HashMap<TaskId, TaskEntry>,
    procs: Vec<ProcEntry>,
    events_fired: u64,
    polls: u64,
    tasks_completed: u64,
    event_limit: u64,
}

/// Handle to the simulation world. Cheap to clone; every task captures one.
#[derive(Clone)]
pub struct Sim {
    inner: Rc<RefCell<Inner>>,
    wakes: Arc<WakeQueue>,
}

impl Default for Sim {
    fn default() -> Self {
        Self::new()
    }
}

impl Sim {
    pub fn new() -> Self {
        Sim {
            inner: Rc::new(RefCell::new(Inner {
                now: SimTime::ZERO,
                next_seq: 0,
                next_task: 0,
                events: BinaryHeap::new(),
                ready: VecDeque::new(),
                tasks: HashMap::new(),
                procs: Vec::new(),
                events_fired: 0,
                polls: 0,
                tasks_completed: 0,
                event_limit: u64::MAX,
            })),
            wakes: Arc::new(WakeQueue::default()),
        }
    }

    /// Guard against runaway simulations (default: unlimited).
    pub fn set_event_limit(&self, limit: u64) {
        self.inner.borrow_mut().event_limit = limit;
    }

    pub fn now(&self) -> SimTime {
        self.inner.borrow().now
    }

    /// Register a new simulated process.
    pub fn spawn_process(&self, name: impl Into<String>) -> ProcId {
        let mut inner = self.inner.borrow_mut();
        let id = ProcId(inner.procs.len() as u32);
        inner.procs.push(ProcEntry::new(name.into()));
        id
    }

    pub fn proc_status(&self, p: ProcId) -> ProcStatus {
        self.inner.borrow().procs[p.0 as usize].status
    }

    pub fn proc_name(&self, p: ProcId) -> String {
        self.inner.borrow().procs[p.0 as usize].name.clone()
    }

    pub fn is_alive(&self, p: ProcId) -> bool {
        matches!(self.proc_status(p), ProcStatus::Alive)
    }

    /// Spawn a task belonging to process `p`. Panics if `p` is dead —
    /// callers must re-create processes through their manager (daemon).
    pub fn spawn(&self, p: ProcId, fut: impl Future<Output = ()> + 'static) -> TaskId {
        let mut inner = self.inner.borrow_mut();
        assert!(
            matches!(inner.procs[p.0 as usize].status, ProcStatus::Alive),
            "spawn on dead {:?} ({})",
            p,
            inner.procs[p.0 as usize].name
        );
        let id = inner.next_task;
        inner.next_task += 1;
        inner.tasks.insert(
            id,
            TaskEntry {
                fut: Box::pin(fut),
                proc: p,
                queued: true,
            },
        );
        inner.ready.push_back(id);
        id
    }

    /// Schedule `f` to run at `now + delay` (used for message delivery).
    pub fn schedule(&self, delay: SimDuration, f: impl FnOnce() + 'static) {
        let mut inner = self.inner.borrow_mut();
        let time = inner.now + delay;
        let seq = inner.next_seq;
        inner.next_seq += 1;
        inner.events.push(EventEntry {
            time,
            seq,
            event: Event::Run(Box::new(f)),
        });
    }

    fn schedule_wake(&self, at: SimTime, w: Waker) {
        let mut inner = self.inner.borrow_mut();
        let seq = inner.next_seq;
        inner.next_seq += 1;
        let time = at.max(inner.now);
        inner.events.push(EventEntry {
            time,
            seq,
            event: Event::Wake(w),
        });
    }

    /// Advance this task's virtual clock by `d`.
    pub fn sleep(&self, d: SimDuration) -> Sleep {
        Sleep {
            sim: self.clone(),
            deadline: self.now() + d,
            registered: false,
        }
    }

    /// Reschedule the current task behind everything already runnable.
    pub fn yield_now(&self) -> YieldNow {
        YieldNow { polled: false }
    }

    /// Resolve when process `p` dies; yields the death time. Resolves
    /// immediately if already dead.
    pub fn watch(&self, p: ProcId) -> Watch {
        Watch {
            sim: self.clone(),
            proc: p,
        }
    }

    /// Fail-stop kill: drop all tasks of `p` (no victim code runs again),
    /// mark dead, wake watchers. Safe to call from within any task,
    /// including a task of `p` itself (suicide).
    pub fn kill(&self, p: ProcId) {
        let mut victims: Vec<TaskEntry> = Vec::new();
        {
            let mut inner = self.inner.borrow_mut();
            let entry = &mut inner.procs[p.0 as usize];
            if !matches!(entry.status, ProcStatus::Alive) {
                return;
            }
            let at = inner.now;
            let entry = &mut inner.procs[p.0 as usize];
            entry.status = ProcStatus::Dead { at };
            let watchers = std::mem::take(&mut entry.watchers);
            let tids: Vec<TaskId> = inner
                .tasks
                .iter()
                .filter(|(_, t)| t.proc == p)
                .map(|(id, _)| *id)
                .collect();
            for t in tids {
                if let Some(e) = inner.tasks.remove(&t) {
                    victims.push(e);
                }
            }
            for w in watchers {
                w.wake();
            }
        }
        // Drop victim futures outside the borrow: their drop glue may touch
        // the Sim (e.g. guards), which would otherwise re-borrow.
        drop(victims);
    }

    /// Cancel a single task without killing its process: the DES analog of
    /// interrupting a thread (Reinit++'s SIGREINIT/longjmp roll-back drops
    /// the survivor's call stack but keeps the process and its memory).
    /// No-op if the task already finished. Must not target the running task.
    pub fn cancel_task(&self, tid: TaskId) {
        let removed = self.inner.borrow_mut().tasks.remove(&tid);
        drop(removed); // drop glue runs without the borrow held
    }

    /// A future that never resolves: what a just-SIGKILLed process "runs".
    pub fn halt_forever(&self) -> HaltForever {
        HaltForever
    }

    fn poll_task(&self, tid: TaskId) {
        let (mut fut, proc) = {
            let mut inner = self.inner.borrow_mut();
            match inner.tasks.remove(&tid) {
                // Task finished or was killed after being scheduled: skip.
                None => return,
                Some(e) => (e.fut, e.proc),
            }
        };
        let waker = Waker::from(Arc::new(TaskWaker {
            id: tid,
            queue: Arc::clone(&self.wakes),
        }));
        let mut cx = Context::from_waker(&waker);
        let res = fut.as_mut().poll(&mut cx);
        let mut inner = self.inner.borrow_mut();
        inner.polls += 1;
        match res {
            Poll::Ready(()) => {
                inner.tasks_completed += 1;
            }
            Poll::Pending => {
                // If the task killed its own process during the poll, its
                // future must die with it.
                if matches!(inner.procs[proc.0 as usize].status, ProcStatus::Alive) {
                    inner.tasks.insert(
                        tid,
                        TaskEntry {
                            fut,
                            proc,
                            queued: false,
                        },
                    );
                } else {
                    drop(inner);
                    drop(fut);
                }
            }
        }
    }

    /// Run until quiescence (no runnable tasks, no pending events).
    pub fn run(&self) -> SimSummary {
        loop {
            // 1. External wakes -> ready queue (dedup via the task flag).
            let wakes = self.wakes.drain();
            if !wakes.is_empty() {
                let mut inner = self.inner.borrow_mut();
                for t in wakes {
                    if let Some(e) = inner.tasks.get_mut(&t) {
                        if !e.queued {
                            e.queued = true;
                            inner.ready.push_back(t);
                        }
                    }
                }
            }
            // 2. Poll one runnable task.
            let next = self.inner.borrow_mut().ready.pop_front();
            if let Some(tid) = next {
                self.poll_task(tid);
                continue;
            }
            // 3. Nothing runnable: advance virtual time to the next event.
            enum Step {
                Fire(Event),
                Exit(ExitReason),
            }
            let step = {
                let mut inner = self.inner.borrow_mut();
                if inner.events_fired >= inner.event_limit {
                    Step::Exit(ExitReason::EventLimit)
                } else {
                    match inner.events.pop() {
                        None => Step::Exit(ExitReason::Idle),
                        Some(e) => {
                            debug_assert!(e.time >= inner.now);
                            inner.now = e.time;
                            inner.events_fired += 1;
                            Step::Fire(e.event)
                        }
                    }
                }
            };
            match step {
                Step::Exit(reason) => return self.summary(reason),
                Step::Fire(Event::Wake(w)) => w.wake(),
                Step::Fire(Event::Run(f)) => f(), // runs without the borrow held
            }
        }
    }

    fn summary(&self, reason: ExitReason) -> SimSummary {
        let inner = self.inner.borrow();
        SimSummary {
            end_time: inner.now,
            events: inner.events_fired,
            polls: inner.polls,
            tasks_completed: inner.tasks_completed,
            tasks_pending: inner.tasks.len() as u64,
            reason,
        }
    }
}

/// Future returned by `Sim::sleep`.
pub struct Sleep {
    sim: Sim,
    deadline: SimTime,
    registered: bool,
}

impl Future for Sleep {
    type Output = ();
    fn poll(mut self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<()> {
        if self.sim.now() >= self.deadline {
            return Poll::Ready(());
        }
        if !self.registered {
            self.registered = true;
            let deadline = self.deadline;
            self.sim.schedule_wake(deadline, cx.waker().clone());
        }
        Poll::Pending
    }
}

/// Future returned by `Sim::halt_forever` (never ready).
pub struct HaltForever;

impl Future for HaltForever {
    type Output = ();
    fn poll(self: Pin<&mut Self>, _cx: &mut Context<'_>) -> Poll<()> {
        Poll::Pending
    }
}

/// Future returned by `Sim::yield_now`.
pub struct YieldNow {
    polled: bool,
}

impl Future for YieldNow {
    type Output = ();
    fn poll(mut self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<()> {
        if self.polled {
            Poll::Ready(())
        } else {
            self.polled = true;
            cx.waker().wake_by_ref();
            Poll::Pending
        }
    }
}

/// Future returned by `Sim::watch`.
pub struct Watch {
    sim: Sim,
    proc: ProcId,
}

impl Future for Watch {
    type Output = SimTime;
    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<SimTime> {
        let mut inner = self.sim.inner.borrow_mut();
        match inner.procs[self.proc.0 as usize].status {
            ProcStatus::Dead { at } => Poll::Ready(at),
            ProcStatus::Alive => {
                inner.procs[self.proc.0 as usize]
                    .watchers
                    .push(cx.waker().clone());
                Poll::Pending
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::Cell;

    #[test]
    fn empty_sim_quiesces_at_zero() {
        let sim = Sim::new();
        let s = sim.run();
        assert_eq!(s.end_time, SimTime::ZERO);
        assert_eq!(s.reason, ExitReason::Idle);
        assert_eq!(s.tasks_pending, 0);
    }

    #[test]
    fn sleep_advances_virtual_clock() {
        let sim = Sim::new();
        let p = sim.spawn_process("a");
        let done = Rc::new(Cell::new(SimTime::ZERO));
        let d2 = Rc::clone(&done);
        let s2 = sim.clone();
        sim.spawn(p, async move {
            s2.sleep(SimDuration::from_millis(250)).await;
            d2.set(s2.now());
        });
        let s = sim.run();
        assert_eq!(done.get().nanos(), 250_000_000);
        assert_eq!(s.end_time.nanos(), 250_000_000);
        assert_eq!(s.tasks_completed, 1);
    }

    #[test]
    fn sequential_sleeps_accumulate() {
        let sim = Sim::new();
        let p = sim.spawn_process("a");
        let s2 = sim.clone();
        sim.spawn(p, async move {
            for _ in 0..10 {
                s2.sleep(SimDuration::from_millis(10)).await;
            }
        });
        assert_eq!(sim.run().end_time.nanos(), 100_000_000);
    }

    #[test]
    fn concurrent_tasks_interleave_by_time() {
        let sim = Sim::new();
        let p = sim.spawn_process("a");
        let order = Rc::new(RefCell::new(Vec::new()));
        for (label, ms) in [("fast", 10u64), ("slow", 30), ("mid", 20)] {
            let s2 = sim.clone();
            let o2 = Rc::clone(&order);
            sim.spawn(p, async move {
                s2.sleep(SimDuration::from_millis(ms)).await;
                o2.borrow_mut().push(label);
            });
        }
        sim.run();
        assert_eq!(*order.borrow(), vec!["fast", "mid", "slow"]);
    }

    #[test]
    fn zero_duration_sleep_completes() {
        let sim = Sim::new();
        let p = sim.spawn_process("a");
        let s2 = sim.clone();
        sim.spawn(p, async move {
            s2.sleep(SimDuration::ZERO).await;
        });
        let s = sim.run();
        assert_eq!(s.tasks_completed, 1);
    }

    #[test]
    fn yield_now_reschedules_fairly() {
        let sim = Sim::new();
        let p = sim.spawn_process("a");
        let order = Rc::new(RefCell::new(Vec::new()));
        for label in ["t1", "t2"] {
            let s2 = sim.clone();
            let o2 = Rc::clone(&order);
            sim.spawn(p, async move {
                for i in 0..3 {
                    o2.borrow_mut().push((label, i));
                    s2.yield_now().await;
                }
            });
        }
        sim.run();
        // strict alternation: yield_now puts the task behind its peer
        assert_eq!(
            *order.borrow(),
            vec![
                ("t1", 0),
                ("t2", 0),
                ("t1", 1),
                ("t2", 1),
                ("t1", 2),
                ("t2", 2)
            ]
        );
    }

    #[test]
    fn kill_cancels_tasks_and_wakes_watcher() {
        let sim = Sim::new();
        let victim = sim.spawn_process("victim");
        let observer = sim.spawn_process("observer");
        let progressed = Rc::new(Cell::new(0u32));
        let death_seen = Rc::new(Cell::new(None));

        let s2 = sim.clone();
        let p2 = Rc::clone(&progressed);
        sim.spawn(victim, async move {
            p2.set(1);
            s2.sleep(SimDuration::from_millis(100)).await;
            p2.set(2); // must never run
        });

        let s3 = sim.clone();
        sim.spawn(observer, async move {
            s3.sleep(SimDuration::from_millis(50)).await;
            s3.kill(victim);
        });

        let s4 = sim.clone();
        let d2 = Rc::clone(&death_seen);
        sim.spawn(observer, async move {
            let at = s4.watch(victim).await;
            d2.set(Some(at.nanos()));
        });

        let summary = sim.run();
        assert_eq!(progressed.get(), 1, "victim body after kill must not run");
        assert_eq!(death_seen.get(), Some(50_000_000));
        assert!(!sim.is_alive(victim));
        assert_eq!(summary.tasks_pending, 0);
    }

    #[test]
    fn suicide_is_safe_and_stops_the_task() {
        let sim = Sim::new();
        let p = sim.spawn_process("kamikaze");
        let after = Rc::new(Cell::new(false));
        let s2 = sim.clone();
        let a2 = Rc::clone(&after);
        sim.spawn(p, async move {
            s2.sleep(SimDuration::from_millis(5)).await;
            s2.kill(p); // SIGKILL to self
            s2.sleep(SimDuration::from_millis(5)).await;
            a2.set(true); // unreachable
        });
        let s = sim.run();
        assert!(!after.get());
        assert!(!sim.is_alive(p));
        assert_eq!(s.tasks_completed, 0);
        assert_eq!(s.tasks_pending, 0);
    }

    #[test]
    fn watch_already_dead_resolves_immediately() {
        let sim = Sim::new();
        let p = sim.spawn_process("p");
        let q = sim.spawn_process("q");
        sim.kill(p);
        let seen = Rc::new(Cell::new(false));
        let s2 = sim.clone();
        let seen2 = Rc::clone(&seen);
        sim.spawn(q, async move {
            let at = s2.watch(p).await;
            assert_eq!(at, SimTime::ZERO);
            seen2.set(true);
        });
        sim.run();
        assert!(seen.get());
    }

    #[test]
    fn double_kill_is_idempotent() {
        let sim = Sim::new();
        let p = sim.spawn_process("p");
        sim.kill(p);
        let first_death = match sim.proc_status(p) {
            ProcStatus::Dead { at } => at,
            _ => panic!(),
        };
        sim.kill(p);
        assert_eq!(sim.proc_status(p), ProcStatus::Dead { at: first_death });
    }

    #[test]
    #[should_panic(expected = "spawn on dead")]
    fn spawn_on_dead_proc_panics() {
        let sim = Sim::new();
        let p = sim.spawn_process("p");
        sim.kill(p);
        sim.spawn(p, async {});
    }

    #[test]
    fn schedule_runs_closures_in_time_order() {
        let sim = Sim::new();
        let order = Rc::new(RefCell::new(Vec::new()));
        for (label, ms) in [("c", 30u64), ("a", 10), ("b", 20)] {
            let o2 = Rc::clone(&order);
            sim.schedule(SimDuration::from_millis(ms), move || {
                o2.borrow_mut().push(label);
            });
        }
        sim.run();
        assert_eq!(*order.borrow(), vec!["a", "b", "c"]);
    }

    #[test]
    fn same_time_events_fire_in_fifo_seq_order() {
        let sim = Sim::new();
        let order = Rc::new(RefCell::new(Vec::new()));
        for i in 0..5 {
            let o2 = Rc::clone(&order);
            sim.schedule(SimDuration::from_millis(10), move || {
                o2.borrow_mut().push(i);
            });
        }
        sim.run();
        assert_eq!(*order.borrow(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn event_limit_stops_runaway() {
        let sim = Sim::new();
        sim.set_event_limit(100);
        let p = sim.spawn_process("looper");
        let s2 = sim.clone();
        sim.spawn(p, async move {
            loop {
                s2.sleep(SimDuration::from_nanos(1)).await;
            }
        });
        let s = sim.run();
        assert_eq!(s.reason, ExitReason::EventLimit);
    }

    #[test]
    fn cancel_task_drops_future_keeps_process() {
        let sim = Sim::new();
        let p = sim.spawn_process("p");
        let progressed = Rc::new(Cell::new(0u32));
        let s2 = sim.clone();
        let pr = Rc::clone(&progressed);
        let tid = sim.spawn(p, async move {
            pr.set(1);
            s2.sleep(SimDuration::from_millis(100)).await;
            pr.set(2); // must not run
        });
        let s3 = sim.clone();
        sim.schedule(SimDuration::from_millis(10), move || s3.cancel_task(tid));
        let summary = sim.run();
        assert_eq!(progressed.get(), 1);
        assert!(sim.is_alive(p), "process survives a task cancel");
        assert_eq!(summary.tasks_pending, 0);
    }

    #[test]
    fn cancel_finished_task_is_noop() {
        let sim = Sim::new();
        let p = sim.spawn_process("p");
        let tid = sim.spawn(p, async {});
        sim.run();
        sim.cancel_task(tid); // no panic
    }

    #[test]
    fn determinism_same_program_same_trace() {
        fn trace() -> (u64, u64, SimTime) {
            let sim = Sim::new();
            let p = sim.spawn_process("p");
            for i in 0..20u64 {
                let s2 = sim.clone();
                sim.spawn(p, async move {
                    s2.sleep(SimDuration::from_micros(i * 7 % 13)).await;
                    s2.sleep(SimDuration::from_micros(i)).await;
                });
            }
            let s = sim.run();
            (s.events, s.polls, s.end_time)
        }
        assert_eq!(trace(), trace());
    }
}
